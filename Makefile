.PHONY: test test-shard1 test-shard2 test-cov test-multidevice deps \
	lint test-sanitize \
	bench-stream bench-fleet bench-adapt bench-int bench-int4 \
	bench-control bench bench-mesh bench-serve bench-cascade

deps:
	pip install -r requirements-dev.txt

# Tier-1 verify (ROADMAP.md): must pass on CPU.
test:
	PYTHONPATH=src python -m pytest -x -q

# CI shards: two parallel jobs that together run the full suite.
# tests/test_ci_shards.py asserts SHARD1 + SHARD2 == every tests/test_*.py,
# so a new test file that lands in neither shard fails CI.
SHARD1_FILES = tests/test_kernels.py tests/test_kernels_batch.py \
	tests/test_kernels_perm.py tests/test_int_datapath.py \
	tests/test_workingset.py tests/test_parity_matrix.py \
	tests/test_stream.py tests/test_fleet.py \
	tests/test_sensing.py tests/test_adc_quantize.py tests/test_golden.py \
	tests/test_sharding.py tests/test_control_loop.py tests/test_serve.py \
	tests/test_cascade.py
SHARD2_FILES = tests/test_arch_smoke.py tests/test_cells.py \
	tests/test_data_pipeline.py tests/test_gate.py tests/test_hdc_core.py \
	tests/test_hypersense.py tests/test_online.py tests/test_system.py \
	tests/test_train_runtime.py tests/test_ci_shards.py \
	tests/test_analysis.py

# PYTEST_EXTRA lets CI attach coverage flags (see .github/workflows/ci.yml);
# plain local runs need no pytest-cov install.
test-shard1:
	PYTHONPATH=src python -m pytest -x -q $(PYTEST_EXTRA) $(SHARD1_FILES)

test-shard2:
	PYTHONPATH=src python -m pytest -x -q $(PYTEST_EXTRA) $(SHARD2_FILES)

# Static gates: ruff (baseline hygiene; skipped with a notice when not
# installed — the container image has no pip access) + the repo-specific
# jit/Pallas linter. `--check` exits nonzero on any unwaived finding.
lint:
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check src tests benchmarks; \
	else \
		echo "ruff not installed; skipping ruff (repro.analysis still runs)"; \
	fi
	PYTHONPATH=src python -m repro.analysis --check src

# Shard 1 under the runtime sanitizer harness: jax_debug_nans,
# tracer-leak checks, the suite-wide compile ledger, and transfer guards
# armed inside every sanitize.no_implicit_transfers() block.
test-sanitize:
	REPRO_SANITIZE=1 $(MAKE) test-shard1

# Coverage-gated kernels+sensing run (shard 1 exercises those packages).
test-cov:
	$(MAKE) test-shard1 PYTEST_EXTRA="--cov=src/repro/kernels \
	--cov=src/repro/sensing --cov-report=term --cov-fail-under=70"

# shard_map / 2-D (sensors x hyperdim) sharding against a real 8-device
# host mesh. MESH=4x2 (etc.) filters test_parity_matrix's mesh matrix to
# one shape via FLEET_TEST_MESH so CI can fan the shapes out across jobs;
# unset, every shape runs in-process.
test-multidevice:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(if $(MESH),FLEET_TEST_MESH=$(MESH)) PYTHONPATH=src \
	python -m pytest -x -q tests/test_fleet.py tests/test_sharding.py \
	tests/test_stream.py tests/test_parity_matrix.py tests/test_online.py \
	tests/test_golden.py tests/test_serve.py

bench-stream:
	PYTHONPATH=src python benchmarks/stream_throughput.py

bench-fleet:
	PYTHONPATH=src python benchmarks/fleet_throughput.py

bench-adapt:
	PYTHONPATH=src python benchmarks/adaptation.py

bench-int:
	PYTHONPATH=src python benchmarks/int_datapath.py

# the CI regression gate for the integer datapaths (int8 rolling-shift
# kernel vs the expanded-slab baseline, packed int4 AUC parity, binary
# D-vs-AUC curve, large-W working set, determinism)
bench-int4:
	PYTHONPATH=src python benchmarks/int_datapath.py --check

bench-control:
	PYTHONPATH=src python benchmarks/control_loop.py

# the 2-D mesh scale-out gate: S=1024 on the sensor axis, D=16384 on the
# hyperdim axis (forced-8-device host mesh), bitwise parity + VMEM
# certification enforced
bench-mesh:
	PYTHONPATH=src python benchmarks/fleet_throughput.py --mesh --check

# the serving-layer gate: async double-buffered FleetService >= synchronous
# FleetRunner fps, bitwise parity churn-off, zero recompiles under churn,
# bitwise checkpoint kill-and-resume
bench-serve:
	PYTHONPATH=src python benchmarks/serve_throughput.py --check

# the full-loop gate → detector cascade gate: batched async backbone
# serving bitwise-equal to eager per-frame evaluation, exactly one
# backbone compile across ragged HP drains, duty-cycled system energy
# strictly below the always-on backbone at matched missed positives
bench-cascade:
	PYTHONPATH=src python -m benchmarks.fig16_speedup --system --check

bench:
	PYTHONPATH=src python -m benchmarks.run
