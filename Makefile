.PHONY: test test-multidevice deps bench-stream bench-fleet bench-adapt bench

deps:
	pip install -r requirements-dev.txt

# Tier-1 verify (ROADMAP.md): must pass on CPU.
test:
	PYTHONPATH=src python -m pytest -x -q

# shard_map / sensor-axis sharding against a real 8-device host mesh.
test-multidevice:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
	python -m pytest -x -q tests/test_fleet.py tests/test_sharding.py \
	tests/test_stream.py

bench-stream:
	PYTHONPATH=src python benchmarks/stream_throughput.py

bench-fleet:
	PYTHONPATH=src python benchmarks/fleet_throughput.py

bench-adapt:
	PYTHONPATH=src python benchmarks/adaptation.py

bench:
	PYTHONPATH=src python -m benchmarks.run
