.PHONY: test deps bench-stream bench

deps:
	pip install -r requirements-dev.txt

# Tier-1 verify (ROADMAP.md): must pass on CPU.
test:
	PYTHONPATH=src python -m pytest -x -q

bench-stream:
	PYTHONPATH=src python benchmarks/stream_throughput.py

bench:
	PYTHONPATH=src python -m benchmarks.run
