"""Fault-tolerant checkpointing (atomic, keep-K, async, reshardable)."""

from repro.ckpt import checkpoint  # noqa: F401
