"""Fault-tolerant checkpointing (no orbax in this container — built here).

Design for 1000+ node runs:

* **Atomic**: write to ``step_N.tmp/``, fsync, rename to ``step_N/`` —
  a crash mid-write never corrupts the latest valid checkpoint.
* **Keep-K** with a manifest (``MANIFEST.json``) recording step, mesh
  shape, param tree structure and dtypes.
* **Mesh-reshardable**: tensors are saved *unsharded by logical identity*
  (each host writes its owned shards; restore reassembles and re-shards to
  ANY new mesh) — node-failure restart and elastic rescale are the same
  code path. In this single-process container, save gathers to host numpy;
  the per-host sharded-write layout is the same format with per-shard
  files, documented in the manifest.
* **Async**: ``save_async`` snapshots device arrays to host, then writes
  on a daemon thread — the train loop keeps stepping.
* **Preemption-safe**: ``install_preemption_handler`` saves on
  SIGTERM/SIGINT before exit.

Format: one ``.npy`` per leaf (path-encoded filename) + manifest JSON.
"""

from __future__ import annotations

import json
import os
import shutil
import signal
import threading
import time
from typing import Any, Callable

import jax
import numpy as np


def _flatten_with_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        safe = key.replace("/", "_").replace("'", "").replace("[", "(") \
            .replace("]", ")")
        out.append((safe, leaf))
    return out


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3,
         extra: dict | None = None) -> str:
    """Synchronous atomic checkpoint. Returns the final directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = _flatten_with_paths(tree)
    manifest = {"step": step, "time": time.time(),
                "extra": extra or {}, "leaves": {}}
    for name, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, name + ".npy"), arr)
        manifest["leaves"][name] = {"shape": list(arr.shape),
                                    "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic on POSIX
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
    for d in os.listdir(ckpt_dir):          # orphaned tmp dirs from crashes
        if d.endswith(".tmp"):
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(ckpt_dir, d, "MANIFEST.json"))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, like, *, step: int | None = None,
            shardings=None) -> tuple[Any, dict]:
    """Restore into the structure of ``like``; re-shards to ``shardings``
    (any mesh — elastic restore). Returns (tree, manifest_extra)."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(d, "MANIFEST.json")) as f:
        manifest = json.load(f)

    names = [n for n, _ in _flatten_with_paths(like)]
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(names))
    out = []
    for name, leaf, sh in zip(names, leaves_like, shard_leaves):
        arr = np.load(os.path.join(d, name + ".npy"))
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{name}: ckpt {arr.shape} != {want_shape}")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]


def restore_tree(ckpt_dir: str, *, step: int | None = None
                 ) -> tuple[dict, dict]:
    """Restore a checkpoint WITHOUT a ``like`` tree.

    The structure-free twin of :func:`restore` for state whose leaf set
    varies run to run — e.g. the fleet service's parked-slot pool and
    per-sensor capture logs, where the number of parked sensors at save
    time is not knowable at restore time. The checkpoint must have been
    saved from a single-level ``dict`` tree; returns ``({key: np.ndarray},
    manifest extra)`` with the original dict keys recovered from the
    path-encoded leaf filenames.
    """
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(d, "MANIFEST.json")) as f:
        manifest = json.load(f)
    leaves = {}
    for name in manifest["leaves"]:
        # single-level dict keys encode as "(key)" (keystr "['key']"
        # through the filename sanitizer) — undo exactly that
        key = name[1:-1] if name.startswith("(") and name.endswith(")") \
            else name
        leaves[key] = np.load(os.path.join(d, name + ".npy"))
    return leaves, manifest["extra"]


class AsyncCheckpointer:
    """Snapshot-to-host then background write; at most one in flight."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree, extra: dict | None = None) -> None:
        self.wait()                       # one in flight
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)    # snapshot before training mutates

        def _write():
            try:
                save(self.ckpt_dir, step, host_tree, keep=self.keep,
                     extra=extra)
            except BaseException as e:    # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()


def install_preemption_handler(save_fn: Callable[[], None]) -> None:
    """Save a checkpoint on SIGTERM (cluster preemption) before exit."""
    def handler(signum, frame):
        save_fn()
        raise SystemExit(128 + signum)

    signal.signal(signal.SIGTERM, handler)
