"""The paper's own workload config (HyperSense sensing, §V).

Defaults match the FPGA evaluation point: fragment 96x96, hypervector
dimensionality 5K, 8-bit data path, CRUW-geometry 128x128 frames.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class HyperSenseConfig:
    frame_h: int = 128
    frame_w: int = 128
    fragment: int = 96          # paper Table II operating point
    stride: int = 8
    dim: int = 5000             # hypervector dimensionality (5K)
    adc_low_bits: int = 4
    adc_high_bits: int = 12
    t_score: float = 0.0
    t_detection: int = 0
    retrain_epochs: int = 20
    base_kind: str = "perm"     # permutation-structured (accelerator path)
    nonlinearity: str = "rff"


def config() -> HyperSenseConfig:
    return HyperSenseConfig()


def smoke() -> HyperSenseConfig:
    return HyperSenseConfig(frame_h=32, frame_w=32, fragment=8, stride=4,
                            dim=256, retrain_epochs=3)
