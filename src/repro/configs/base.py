"""Config dataclasses: model architecture + input-shape cells.

One ``ModelConfig`` per assigned architecture lives in
``repro.configs.<arch_id>`` (exact public-literature numbers) together with
a ``smoke()`` reduced config of the same family for CPU tests.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Literal


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: Literal["dense", "moe", "hybrid", "ssm", "encoder", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    norm: str = "rmsnorm"
    qk_norm: bool = False
    rope_theta: float = 10000.0
    causal: bool = True
    is_encoder: bool = False
    activation: str = "silu"
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_dispatch_int8: bool = False
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    shared_attn_every: int = 0        # zamba2: shared block cadence
    slstm_every: int = 0              # xlstm: every k-th block is sLSTM
    ssm_chunk: int = 256
    # --- VLM ---
    n_image_tokens: int = 0
    # --- embeds-in stub (audio/vlm frontends per assignment) ---
    embeds_in: bool = False           # inputs are embeddings, not token ids
    # --- execution ---
    scan_layers: bool = True
    remat: str = "full"               # full | dots | none
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


# The assigned shape set (identical for every LM arch).
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

#: smoke-test shape (reduced)
SMOKE_SHAPE = ShapeConfig("smoke", 64, 2, "train")


def applicable_shapes(cfg: ModelConfig) -> dict[str, ShapeConfig | None]:
    """Which of the 4 assigned shapes run for this arch (None = skip).

    Skip rules (DESIGN.md §4): encoder-only archs have no decode step;
    long_500k runs only for sub-quadratic (ssm/hybrid) archs.
    """
    out: dict[str, ShapeConfig | None] = dict(SHAPES)
    if cfg.is_encoder:
        out["decode_32k"] = None
        out["long_500k"] = None
    if cfg.family not in ("ssm", "hybrid"):
        out["long_500k"] = None
    return out


SKIP_REASONS = {
    ("encoder", "decode_32k"): "encoder-only arch: no decode step exists",
    ("encoder", "long_500k"): "encoder-only arch: no decode step exists",
    ("full_attn", "long_500k"):
        "pure full-attention arch: 500K context requires sub-quadratic "
        "attention (assignment: run only for SSM/hybrid/linear-attn)",
}
