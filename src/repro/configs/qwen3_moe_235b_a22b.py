"""qwen3-moe-235b-a22b — 128-expert top-8 MoE [hf:Qwen/Qwen3-30B-A3B; hf].

94L d_model=4096 64H (GQA kv=4) d_ff=1536 (per expert) vocab=151936,
MoE 128e top-8, head_dim=128, QK-norm (Qwen3 family).
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen3-moe-235b-a22b", family="moe",
        n_layers=94, d_model=4096, n_heads=64, kv_heads=4,
        head_dim=128, d_ff=1536, vocab=151936,
        n_experts=128, top_k=8, qk_norm=True,
        rope_theta=1e6,
        scan_layers=True,
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, kv_heads=2, head_dim=16,
        d_ff=64, vocab=512, n_experts=8, top_k=2,
        compute_dtype="float32", remat="none")
