"""internlm2-1.8b — dense GQA [arXiv:2403.17297; hf].

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="internlm2-1.8b", family="dense",
        n_layers=24, d_model=2048, n_heads=16, kv_heads=8,
        d_ff=8192, vocab=92544,
        scan_layers=True,
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, kv_heads=2, d_ff=128,
        vocab=512, compute_dtype="float32", remat="none")
