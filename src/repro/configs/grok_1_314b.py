"""grok-1-314b — 8-expert top-2 MoE [hf:xai-org/grok-1; unverified].

64L d_model=6144 48H (GQA kv=8) d_ff=32768 (per expert) vocab=131072.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="grok-1-314b", family="moe",
        n_layers=64, d_model=6144, n_heads=48, kv_heads=8,
        d_ff=32768, vocab=131072,
        n_experts=8, top_k=2,
        scan_layers=True,
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=96, n_heads=6, kv_heads=2, d_ff=128,
        vocab=512, n_experts=4, top_k=2,
        compute_dtype="float32", remat="none")
