"""internvl2-76b — InternViT + InternLM2 VLM [arXiv:2404.16821; unverified].

Backbone (per the assignment, frontend is a STUB providing precomputed
patch embeddings): 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256, 256 image tokens prepended to the text sequence.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="internvl2-76b", family="vlm",
        n_layers=80, d_model=8192, n_heads=64, kv_heads=8,
        d_ff=28672, vocab=128256,
        n_image_tokens=256,
        scan_layers=True,
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, kv_heads=2, d_ff=128,
        vocab=512, n_image_tokens=8,
        compute_dtype="float32", remat="none")
