"""hubert-xlarge — encoder-only audio transformer [arXiv:2106.07447; unverified].

48L d_model=1280 16H (MHA kv=16) d_ff=5120 vocab=504 (masked-unit targets).
Per the assignment, the conv waveform frontend is a STUB: ``input_specs``
provides precomputed frame embeddings (b, s, d_model); the transformer
backbone + unit-prediction head are real. No decode step (encoder-only).
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="hubert-xlarge", family="encoder",
        n_layers=48, d_model=1280, n_heads=16, kv_heads=16,
        d_ff=5120, vocab=504,
        is_encoder=True, causal=False, embeds_in=True,
        norm="layernorm", activation="gelu",
        scan_layers=True,
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, kv_heads=4, d_ff=128,
        vocab=64, compute_dtype="float32", remat="none")
