"""deepseek-67b — dense llama-arch [arXiv:2401.02954; hf].

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="deepseek-67b", family="dense",
        n_layers=95, d_model=8192, n_heads=64, kv_heads=8,
        d_ff=22016, vocab=102400,
        scan_layers=True,
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=3, d_model=64, n_heads=4, kv_heads=2, d_ff=192,
        vocab=512, compute_dtype="float32", remat="none")
