"""olmo-1b — dense, non-parametric LayerNorm [arXiv:2402.00838; hf].

16L d_model=2048 16H (MHA kv=16) d_ff=8192 vocab=50304.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="olmo-1b", family="dense",
        n_layers=16, d_model=2048, n_heads=16, kv_heads=16,
        d_ff=8192, vocab=50304,
        norm="nonparametric_ln",
        scan_layers=True,
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, kv_heads=4, d_ff=128,
        vocab=512, compute_dtype="float32", remat="none")
