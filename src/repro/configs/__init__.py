"""Architecture registry: ``--arch <id>`` -> exact public-literature config.

Each module defines ``config()`` (the exact assigned numbers) and
``smoke()`` (a reduced same-family config for CPU tests).
"""

from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    SHAPES,
    SMOKE_SHAPE,
    ModelConfig,
    ShapeConfig,
    applicable_shapes,
)

ARCH_IDS = [
    "zamba2-1.2b",
    "qwen3-moe-235b-a22b",
    "grok-1-314b",
    "hubert-xlarge",
    "olmo-1b",
    "codeqwen1.5-7b",
    "internlm2-1.8b",
    "deepseek-67b",
    "xlstm-350m",
    "internvl2-76b",
]

#: the paper's own workload (HyperSense sensing config)
PAPER_CONFIG_ID = "hypersense"


def _module(arch_id: str):
    name = arch_id.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).config()


def get_smoke(arch_id: str) -> ModelConfig:
    return _module(arch_id).smoke()
