"""codeqwen1.5-7b — dense qwen1.5-arch [hf:Qwen/CodeQwen1.5-7B; hf].

32L d_model=4096 32H (MHA kv=32) d_ff=13440 vocab=92416.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="codeqwen1.5-7b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, kv_heads=32,
        d_ff=13440, vocab=92416,
        rope_theta=1e6,
        scan_layers=True,
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, kv_heads=4, d_ff=192,
        vocab=512, compute_dtype="float32", remat="none")
