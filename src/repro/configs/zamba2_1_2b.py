"""zamba2-1.2b — Mamba2 backbone + shared attention block [arXiv:2411.15242; hf].

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64.
The shared transformer block (one parameter copy) runs every 6 Mamba
layers with an embedding re-injection (Zamba-style); simplification vs the
HF checkpoint: re-injection is additive-projected rather than concat+LoRA
(documented in DESIGN.md §4).
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, kv_heads=32,
        d_ff=8192, vocab=32000,
        ssm_state=64, ssm_head_dim=64, ssm_expand=2,
        shared_attn_every=6,
        scan_layers=True,
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=4, d_model=64, n_heads=4, kv_heads=4, d_ff=128,
        vocab=512, ssm_state=8, ssm_head_dim=16, shared_attn_every=2,
        ssm_chunk=16, compute_dtype="float32", remat="none")
