"""xlstm-350m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

24L d_model=1024 4H d_ff=0 (blocks carry internal expansions only)
vocab=50304. Every 8th block is sLSTM (xLSTM[7:1]-style ratio), the rest
mLSTM; sub-quadratic -> runs the long_500k shape.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="xlstm-350m", family="ssm",
        n_layers=24, d_model=1024, n_heads=4, kv_heads=4,
        d_ff=0, vocab=50304,
        slstm_every=8,
        scan_layers=False,   # heterogeneous block mix
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=3, d_model=64, n_heads=2, vocab=512, slstm_every=3,
        ssm_chunk=16, compute_dtype="float32", remat="none")
