"""Binary-detection metrics: ROC, AUC, partial AUC, F1 (paper §V-B).

Pure numpy/jnp — no sklearn in this container. Matches the paper's
evaluation protocol:

* ROC curves sweep the decision threshold over every observed score.
* Table I reports "AUC considering TPR larger than 0.8": the area between
  the ROC curve and the TPR=0.8 line, i.e. ``integral max(TPR(f)-0.8, 0) df``
  over FPR in [0,1] — maximum attainable value 0.2.
"""

from __future__ import annotations

import numpy as np


def roc_curve(scores, labels):
    """Standard ROC sweep.

    Returns ``(fpr, tpr, thresholds)`` with (0,0) and (1,1) endpoints.
    """
    scores = np.asarray(scores, dtype=np.float64)
    labels = np.asarray(labels).astype(bool)
    order = np.argsort(-scores, kind="stable")
    s, y = scores[order], labels[order]
    P = max(int(y.sum()), 1)
    N = max(int((~y).sum()), 1)
    tp = np.cumsum(y)
    fp = np.cumsum(~y)
    # collapse threshold ties: keep last index of each distinct score
    distinct = np.r_[s[1:] != s[:-1], True]
    tpr = np.r_[0.0, tp[distinct] / P]
    fpr = np.r_[0.0, fp[distinct] / N]
    thr = np.r_[np.inf, s[distinct]]
    return fpr, tpr, thr


def auc(fpr, tpr) -> float:
    """Trapezoidal area under an ROC curve."""
    return float(np.trapezoid(tpr, fpr))


def partial_auc_above_tpr(fpr, tpr, tpr_floor: float = 0.8) -> float:
    """Paper Table I metric: area of the ROC region above ``tpr_floor``.

    ``integral_0^1 max(TPR(f) - tpr_floor, 0) dFPR``; max value
    ``1 - tpr_floor``.
    """
    f = np.asarray(fpr, dtype=np.float64)
    t = np.clip(np.asarray(tpr, dtype=np.float64) - tpr_floor, 0.0, None)
    return float(np.trapezoid(t, f))


def tpr_at_fpr(fpr, tpr, target_fpr: float) -> float:
    """Maximum TPR achievable at FPR <= target (paper Fig. 15 heatmaps)."""
    f = np.asarray(fpr)
    t = np.asarray(tpr)
    ok = f <= target_fpr + 1e-12
    return float(t[ok].max()) if ok.any() else 0.0


def threshold_at_fpr(fpr, tpr, thr, target_fpr: float) -> float:
    """Score threshold realizing the max-TPR operating point at target FPR."""
    f = np.asarray(fpr)
    ok = np.where(f <= target_fpr + 1e-12)[0]
    if len(ok) == 0:
        return float("inf")
    best = ok[np.argmax(np.asarray(tpr)[ok])]
    return float(np.asarray(thr)[best])


def f1_score(pred, labels) -> float:
    pred = np.asarray(pred).astype(bool)
    labels = np.asarray(labels).astype(bool)
    tp = int((pred & labels).sum())
    fp = int((pred & ~labels).sum())
    fn = int((~pred & labels).sum())
    denom = 2 * tp + fp + fn
    return 2 * tp / denom if denom else 0.0


def confusion(pred, labels) -> dict:
    pred = np.asarray(pred).astype(bool)
    labels = np.asarray(labels).astype(bool)
    return {
        "tp": int((pred & labels).sum()),
        "fp": int((pred & ~labels).sum()),
        "tn": int((~pred & ~labels).sum()),
        "fn": int((~pred & labels).sum()),
    }
