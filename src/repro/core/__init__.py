"""HyperSense core: HDC ops, encoders, fragment/frame models, sensor control.

The paper's primary contribution as a composable JAX library:

* :mod:`repro.core.hdc`            — bundle / bind / permute / similarity
* :mod:`repro.core.encoding`       — RFF + permutation-structured encoders,
  naive and computation-reuse sliding-window frame encoders
* :mod:`repro.core.fragment_model` — HDC fragment classifier (train/retrain)
* :mod:`repro.core.hypersense`     — frame-level detector (T_score,
  T_detection, stride)
* :mod:`repro.core.sensor_control` — the intelligent-sensor-control gate
* :mod:`repro.core.energy`         — end-to-end energy model (Fig 17)
* :mod:`repro.core.metrics`        — ROC / AUC / partial-AUC / F1
"""

from repro.core import (  # noqa: F401
    encoding,
    energy,
    fragment_model,
    hdc,
    hypersense,
    metrics,
    sensor_control,
)
