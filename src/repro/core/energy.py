"""End-to-end energy model (paper §V-E, Fig. 17, Table III).

Per-frame energy accounting for three system variants:

* ``conventional``        — high-precision ADC always on, every frame
  transmitted (3G) and processed by the cloud model.
* ``compressive_sensing`` — conventional + bit-depth compression (BDC [11])
  on the transmitted payload.
* ``hypersense``          — low-precision path + near-sensor HDC always on;
  the high-precision ADC, transmission and cloud model run only on frames
  the gate passes. Duty cycle ``d = (1-p)*FPR + p*TPR`` for object
  probability ``p`` at the chosen ROC operating point.

Constants are literature-grounded defaults (documented inline); because the
paper does not publish its exact per-component numbers, :func:`calibrate`
can least-squares fit the 3 free scale constants against Table III, and the
benchmark reports both default and calibrated reproductions.

Energy component sources:
  sensor RF front-end: TI AWR1843 ~30 W at 60 fps  -> 0.5 J/frame [21,34],
    split ~50/50 between RF chain (ungated) and ADC+digital (gated).
  low-precision ADC: energy/conversion scales ~2^bits (SAR model) [29]
  HDC near-sensor accel: 8.2 W FPGA at 303 fps (paper Table II) -> 27 mJ
  3G transmission: ~2.5 J/Mbit (typical 3G radio energy)
  cloud inference + PUE: server-side CNN inference per [31]-style estimate.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np


@dataclass(frozen=True)
class EnergyParams:
    # --- per-frame Joules ---
    rf_frontend_j: float = 0.25      # ungated analog front-end
    adc_hp_j: float = 0.25           # high-precision ADC + digital capture
    adc_lp_bits: int = 4             # low-precision ADC bit depth
    adc_hp_bits: int = 12            # high-precision ADC bit depth
    hdc_accel_j: float = 0.027       # 8.2 W / 303 fps  (paper Table II/V-D)
    #: relative energy of the int8 datapath's near-sensor HDC work vs the
    #: float32 path. int8 MAC switching energy is ~0.15-0.3x fp32
    #: (Horowitz, ISSCC'14: 8b add 0.03 pJ vs fp32 add 0.9 pJ; 8b mult
    #: 0.2 pJ vs fp32 mult 3.7 pJ) and operand memory traffic is 4x
    #: smaller; 0.35 is a conservative blended factor in line with the
    #: SCM always-on accelerator's low-bitwidth datapath [Eggimann 2021].
    hdc_int8_factor: float = 0.35
    #: int4 datapath factor: halved operand traffic vs int8 (two codes
    #: per wire byte) on top of the sub-byte MAC scaling — multiplier
    #: energy scales ~quadratically in operand width (Horowitz, ISSCC'14),
    #: so 4b work sits well under the int8 blend; 0.22 keeps the same
    #: conservatism as the 0.35 int8 factor.
    hdc_int4_factor: float = 0.22
    #: binary (±1 slab/class) datapath factor: the multiplies degenerate
    #: to sign-conditioned adds (XOR-popcount in the SCM accelerator,
    #: Eggimann 2021, which runs binarized at ~5 uW; Basaklar 2021 report
    #: order-of-magnitude energy wins for 1-bit hypervectors). 0.12 is a
    #: conservative blend — code traffic and the float epilogue are
    #: unchanged, so it does not approach the raw 1b/8b MAC ratio.
    hdc_binary_factor: float = 0.12
    frame_bits: float = 128 * 128 * 8
    comm_j_per_mbit: float = 2.5     # 3G radio
    cloud_j: float = 6.0             # server inference + network + PUE
    bdc_ratio: float = 0.5           # compressive-sensing payload ratio [11]

    @property
    def adc_lp_j(self) -> float:
        """SAR-ADC energy ~ 2^bits: lp = hp * 2^(lp_bits - hp_bits) [29]."""
        return self.adc_hp_j * (2.0 ** (self.adc_lp_bits - self.adc_hp_bits))

    @property
    def comm_j(self) -> float:
        return self.comm_j_per_mbit * self.frame_bits / 1e6


@dataclass(frozen=True)
class EnergyBreakdown:
    sensor: float
    adc: float
    hdc: float
    comm: float
    cloud: float

    @property
    def edge(self) -> float:
        return self.sensor + self.adc + self.hdc + self.comm

    @property
    def total(self) -> float:
        return self.edge + self.cloud


def conventional(params: EnergyParams = EnergyParams()) -> EnergyBreakdown:
    return EnergyBreakdown(sensor=params.rf_frontend_j, adc=params.adc_hp_j,
                           hdc=0.0, comm=params.comm_j, cloud=params.cloud_j)


def compressive_sensing(params: EnergyParams = EnergyParams()
                        ) -> EnergyBreakdown:
    """BDC compression shrinks the payload, everything else unchanged."""
    return EnergyBreakdown(sensor=params.rf_frontend_j, adc=params.adc_hp_j,
                           hdc=0.0, comm=params.comm_j * params.bdc_ratio,
                           cloud=params.cloud_j)


def duty_cycle(fpr: float, tpr: float, p_object: float) -> float:
    """Fraction of frames the gate passes to the expensive path."""
    return (1.0 - p_object) * fpr + p_object * tpr


def _hdc_j(params: EnergyParams, precision: str) -> float:
    """Per-scored-frame HDC accelerator energy for a datapath precision.

    The ONE precision->cost rule both accounts share, so
    :func:`from_capture_log` can never disagree with
    :func:`hypersense_measured` about the same ``precision`` argument.
    """
    factors = {"float32": 1.0,
               "int8": params.hdc_int8_factor,
               "int4": params.hdc_int4_factor,
               "binary": params.hdc_binary_factor}
    if precision not in factors:
        raise ValueError(f"unknown datapath precision {precision!r}")
    return params.hdc_accel_j * factors[precision]


def hypersense_measured(duty: float,
                        params: EnergyParams = EnergyParams(),
                        precision: str = "float32") -> EnergyBreakdown:
    """Per-frame energy at a *measured* duty cycle (e.g. from StreamStats).

    The analytic :func:`hypersense` predicts the duty cycle from an ROC
    operating point; this variant takes the duty cycle a stream driver
    actually observed — the form the fleet runtime aggregates over sensors.

    ``precision="int8"`` bills the always-on near-sensor HDC work at the
    integer datapath's reduced switching/memory cost
    (``hdc_int8_factor``); the gated high-precision side is unchanged —
    the gate's *decisions*, not its arithmetic, control that.
    """
    hdc = _hdc_j(params, precision)
    return EnergyBreakdown(
        sensor=params.rf_frontend_j,
        adc=params.adc_lp_j + duty * params.adc_hp_j,
        hdc=hdc,
        comm=duty * params.comm_j,
        cloud=duty * params.cloud_j,
    )


def hypersense(fpr: float, tpr: float, p_object: float = 0.01,
               params: EnergyParams = EnergyParams(),
               precision: str = "float32") -> EnergyBreakdown:
    return hypersense_measured(duty_cycle(fpr, tpr, p_object), params,
                               precision)


def adc_conversion_j(bits: int, params: EnergyParams = EnergyParams()
                     ) -> float:
    """Per-frame conversion energy at an arbitrary bit depth.

    The SAR-ADC model [29] anchored at the high-precision point:
    energy/conversion scales ~``2^bits``, so
    ``adc_conversion_j(params.adc_lp_bits) == params.adc_lp_j`` exactly.
    """
    return params.adc_hp_j * (2.0 ** (bits - params.adc_hp_bits))


def _resolve_log_bits(log, params: EnergyParams,
                      on_missing_bits: str) -> tuple[int, int]:
    """The explicit ``None``-depth policy for capture-log billing.

    A log records ``lp_bits``/``hp_bits`` = ``None`` when the runner had
    no explicit depth configured (open loop: ``adc_bits=None`` /
    ``control=None``). Billing must decide what that means — callers must
    NOT paper over it by substituting depths themselves:

    * ``"params"`` — the open-loop convention: bill at the
      :class:`EnergyParams` default depths. This is what makes an
      open-loop run reduce exactly to :func:`hypersense_measured`.
    * ``"error"`` — refuse: the caller claims to know the real burst
      depth (e.g. the gated cascade billing actual backbone input), so a
      ``None`` is a wiring bug, not a convention.
    """
    if on_missing_bits not in ("params", "error"):
        raise ValueError(f"on_missing_bits must be 'params' or 'error', "
                         f"got {on_missing_bits!r}")
    if on_missing_bits == "error" and log.hp_bits is None:
        raise ValueError(
            "capture log has hp_bits=None (open-loop run: no "
            "CaptureConfig) but this billing requires the real burst "
            "depth — run the producer with control=CaptureConfig(...) or "
            "bill with on_missing_bits='params'")
    lp_bits = params.adc_lp_bits if log.lp_bits is None else log.lp_bits
    hp_bits = params.adc_hp_bits if log.hp_bits is None else log.hp_bits
    return lp_bits, hp_bits


def from_capture_log(log, params: EnergyParams | None = None,
                     precision: str = "float32",
                     on_missing_bits: str = "params") -> EnergyBreakdown:
    """Per-frame mean energy billed from what was *actually* captured.

    ``log`` is a :class:`~repro.core.sensor_control.CaptureLog` (duck —
    anything with ``sampled``/``gated`` arrays and ``lp_bits``/``hp_bits``
    depths): each LP conversion made, each HP burst conversion made, and
    each frame transmitted is billed individually — the near-sensor HDC
    accelerator only runs on frames the LP ADC converted. This replaces
    the duty-fraction approximation of :func:`hypersense_measured` as the
    runtime's primary account: when the closed loop subsamples idle
    frames, the LP-side energy drops below the always-on term
    ``adc_lp_j + hdc_accel_j`` that approximation bills unconditionally.

    ``None`` depths are handled here, explicitly, by ``on_missing_bits``
    (see :func:`_resolve_log_bits`) — never by the log's producer: the
    default ``"params"`` is the open-loop convention, ``"error"`` rejects
    logs without a real recorded burst depth.

    When every frame is sampled and the log's depths equal the params'
    (the open-loop regime), this reduces *exactly* to
    ``hypersense_measured(duty)`` — asserted bitwise in
    ``tests/test_control_loop.py``.
    """
    params = params or EnergyParams()
    sampled = np.asarray(log.sampled, bool)
    gated = np.asarray(log.gated, bool)
    lp_bits, hp_bits = _resolve_log_bits(log, params, on_missing_bits)
    f_lp = float(sampled.mean())        # fraction of frames LP-converted
    duty = float(gated.mean())          # fraction HP-converted+transmitted
    hdc = _hdc_j(params, precision)
    return EnergyBreakdown(
        sensor=params.rf_frontend_j,
        adc=f_lp * adc_conversion_j(lp_bits, params)
        + duty * adc_conversion_j(hp_bits, params),
        hdc=f_lp * hdc,
        comm=duty * params.comm_j,
        cloud=duty * params.cloud_j,
    )


# ---------------------------------------------------------------------------
# Downstream-backbone cost (the gated cascade's "cloud" term)
# ---------------------------------------------------------------------------

#: Effective edge-accelerator energy per FLOP for the downstream backbone.
#: Grounded on Jetson AGX Orin-class sustained efficiency (the paper's
#: end-to-end comparison platform): ~5 TFLOP/s FP32 useful throughput at
#: ~40 W wall → ~8 pJ/FLOP. A constant, like the other per-component
#: Joules above — the cascade claims are *ratios* (duty × backbone vs
#: always-on backbone), which a shared constant cancels out of.
EDGE_J_PER_FLOP = 8e-12


@dataclass(frozen=True)
class BackboneCost:
    """Measured per-frame cost of the downstream detector/backbone.

    ``flops``/``bytes`` come from the compiled step's XLA
    ``cost_analysis()`` divided by its batch size;
    ``joules = flops * j_per_flop`` is the energy the cascade bills per
    frame the gate lets through (the term that replaces the 3G+cloud
    ``cloud_j`` when the backbone runs on-device next to the gate).
    """
    flops: float
    bytes: float
    joules: float


def backbone_cost(compiled, batch: int, *,
                  j_per_flop: float = EDGE_J_PER_FLOP) -> BackboneCost:
    """Per-frame :class:`BackboneCost` from a compiled backbone step.

    ``compiled`` is a ``jax.stages.Compiled`` whose step processes
    ``batch`` frames; FLOPs/bytes are read from ``cost_analysis()`` (the
    same source the roofline model uses) and amortized per frame.
    """
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0)) / batch
    nbytes = float(cost.get("bytes accessed", 0.0)) / batch
    return BackboneCost(flops=flops, bytes=nbytes,
                        joules=flops * j_per_flop)


def cascade_system(log, backbone: BackboneCost,
                   params: EnergyParams | None = None,
                   precision: str = "float32") -> EnergyBreakdown:
    """Per-frame energy of the full gate→backbone cascade (paper §V-E).

    The capture-log account (:func:`from_capture_log`) with the
    gated-path downstream swapped for the *measured* backbone: the
    backbone runs co-located with the gate, so the 3G transmission and
    cloud terms vanish and ``cloud`` becomes
    ``duty × backbone.joules`` — gate duty cycle × backbone cost, the
    paper's system-level arithmetic. Requires a real recorded burst
    depth (``on_missing_bits="error"``): a cascade is by construction a
    closed-loop producer, so ``hp_bits=None`` here is a wiring bug.
    """
    params = params or EnergyParams()
    base = from_capture_log(log, params, precision,
                            on_missing_bits="error")
    duty = float(np.asarray(log.gated, bool).mean())
    return EnergyBreakdown(sensor=base.sensor, adc=base.adc, hdc=base.hdc,
                           comm=0.0, cloud=duty * backbone.joules)


def always_on_backbone(backbone: BackboneCost,
                       params: EnergyParams | None = None
                       ) -> EnergyBreakdown:
    """Per-frame energy of the cascade's baseline: no gate, the
    high-precision ADC converts every frame and the backbone processes
    every frame (duty ≡ 1, no HDC, no transmission — same co-located
    deployment as :func:`cascade_system`, so the two differ only in
    what the gate saves)."""
    params = params or EnergyParams()
    return EnergyBreakdown(sensor=params.rf_frontend_j,
                           adc=params.adc_hp_j, hdc=0.0, comm=0.0,
                           cloud=backbone.joules)


def savings(ours: EnergyBreakdown, base: EnergyBreakdown) -> dict:
    return {
        "total_saving": 1.0 - ours.total / base.total,
        "edge_saving": 1.0 - ours.edge / base.edge,
    }


def quality_loss(tpr: float) -> float:
    """Fraction of object frames the gate drops (paper Table III)."""
    return 1.0 - tpr


# ---------------------------------------------------------------------------
# Calibration against paper Table III
# ---------------------------------------------------------------------------

#: paper Table III @ p_object = 1%: FPR -> (total saving, edge saving, QL)
PAPER_TABLE_III = {
    0.05: (0.921, 0.647, 0.0744),
    0.10: (0.898, 0.606, 0.0493),
    0.20: (0.806, 0.524, 0.0292),
    0.30: (0.713, 0.442, 0.0195),
}


def calibrate(p_object: float = 0.01,
              table: dict | None = None) -> EnergyParams:
    """Least-squares fit (rf_frontend, comm, cloud) to Table III.

    TPR at each operating point is implied by the paper's quality loss
    (QL = 1 - TPR). Keeps ADC/HDC constants at their documented defaults.

    The fit is *bounded* to the physical domain (``method="trf"``,
    ``bounds=(0, inf)``): the constants are Joules, and the earlier
    unconstrained LM solve wrapped in ``abs()`` could silently accept a
    sign-flipped (non-physical) optimum whose folded-back magnitudes no
    longer minimize anything. (Freed from that distortion the fit finds
    a better Table III residual — ~0.020 vs LM's ~0.030 — by riding the
    table's scale degeneracy: savings are energy *ratios*, so the
    optimizer may return large absolute magnitudes. Fine for reproducing
    the paper's saving percentages, which is all this is used for; the
    documented defaults remain the physically-grounded constants.)
    """
    from scipy.optimize import least_squares

    table = table or PAPER_TABLE_III
    base = EnergyParams()

    def residuals(x):
        rf, comm_scale, cloud = x
        p = replace(base, rf_frontend_j=float(rf),
                    comm_j_per_mbit=float(comm_scale), cloud_j=float(cloud))
        res = []
        for fpr, (tot, edge, ql) in table.items():
            tpr = 1.0 - ql
            ours = hypersense(fpr, tpr, p_object, p)
            conv = conventional(p)
            s = savings(ours, conv)
            res += [s["total_saving"] - tot, s["edge_saving"] - edge]
        return res

    x0 = [base.rf_frontend_j, base.comm_j_per_mbit, base.cloud_j]
    sol = least_squares(residuals, x0, method="trf",
                        bounds=(0.0, np.inf))
    rf, comm_scale, cloud = [float(v) for v in sol.x]
    return replace(base, rf_frontend_j=rf, comm_j_per_mbit=comm_scale,
                   cloud_j=cloud)
