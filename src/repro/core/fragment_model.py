"""The *Fragment model* — HDC classification over fragments (paper §III-C a).

Pipeline (paper Fig. 5a):
  (1) sample balanced positive/negative fragments  -> ``repro.sensing.fragments``
  (2) normalize + HDC-encode                        -> ``repro.core.encoding``
  (3) initial training: class hypervectors by bundling
  (4) iterative retraining: similarity-scaled perceptron updates
  (5) model selection on validation metrics

The model is a pytree (NamedTuple) so it jit/vmaps/shards cleanly.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import hdc
from repro.core.encoding import NonLin, encode_fragments

Array = jax.Array


class FragmentModel(NamedTuple):
    """HDC classifier state.

    ``class_hvs``: (C, D) class hypervectors, C=2 for HyperSense
    (index 0 = negative / no-object, 1 = positive / object).
    ``B``: (n, D) base projection, ``b``: (D,) RFF phase.
    """
    class_hvs: Array
    B: Array
    b: Array


def _encode(model: FragmentModel, frags: Array, nonlinearity: NonLin) -> Array:
    return encode_fragments(frags, model.B, model.b,
                            nonlinearity=nonlinearity, normalize=True)


@partial(jax.jit, static_argnames=("num_classes",))
def bundle_init(hvs: Array, labels: Array, num_classes: int = 2) -> Array:
    """Initial training (paper step 3): ``C_i = sum_{y_j = i} phi(x_j)``."""
    one_hot = jax.nn.one_hot(labels, num_classes, dtype=hvs.dtype)  # (N, C)
    return one_hot.T @ hvs                                          # (C, D)


def init_fragment_model(key: Array, hvs: Array, labels: Array, B: Array,
                        b: Array, num_classes: int = 2) -> FragmentModel:
    del key  # bundling is deterministic; kept for API symmetry
    return FragmentModel(bundle_init(hvs, labels, num_classes), B, b)


@jax.jit
def retrain_epoch(class_hvs: Array, hvs: Array, labels: Array,
                  lr: float = 1.0) -> Array:
    """One retraining epoch (paper step 4).

    For each sample, if mispredicted, update with similarity-scaled rate:
      ``C_l  += lr * (1 - delta) * phi(x)``   (true class)
      ``C_l' -= lr * (1 - delta) * phi(x)``   (predicted wrong class)

    Sequential over samples (the paper's online rule): a ``lax.scan`` of
    :func:`repro.core.online.online_update` — the same rule the streaming
    runtime applies chunk-by-chunk (``repro.core.online.chunk_update``),
    so offline retraining and online adaptation share one definition.
    """
    from repro.core import online

    def step(chvs: Array, xy):
        hv, y = xy
        return online.online_update(chvs, hv, y, lr)

    class_hvs, miss = jax.lax.scan(step, class_hvs, (hvs, labels))
    return class_hvs


def retrain(model: FragmentModel, hvs: Array, labels: Array, *,
            epochs: int = 20, lr: float = 1.0,
            val_hvs: Array | None = None,
            val_labels: Array | None = None) -> tuple[FragmentModel, dict]:
    """Iterative retraining with best-epoch selection (paper steps 4-5)."""
    best = model.class_hvs
    best_metric = -jnp.inf
    history = []
    chvs = model.class_hvs
    vh = hvs if val_hvs is None else val_hvs
    vl = labels if val_labels is None else val_labels
    for _ in range(epochs):
        chvs = retrain_epoch(chvs, hvs, labels, lr)
        acc = accuracy(chvs, vh, vl)
        history.append(float(acc))
        if acc > best_metric:
            best_metric, best = acc, chvs
    return model._replace(class_hvs=best), {
        "val_accuracy": history, "best": float(best_metric)}


@jax.jit
def scores(class_hvs: Array, hvs: Array) -> Array:
    """(N, C) cosine-similarity scores (paper inference, §III-A step 3)."""
    return hdc.class_scores(hvs, class_hvs)


@jax.jit
def positive_score(class_hvs: Array, hvs: Array) -> Array:
    """Scalar detection score in [-1, 1]: sim(pos) - sim(neg).

    Used as the fragment prediction score ``s_i`` that ``T_score``
    thresholds. Monotone in the paper's argmax rule and ROC-sweepable.
    """
    s = hdc.class_scores(hvs, class_hvs)
    return s[:, 1] - s[:, 0]


@jax.jit
def predict(class_hvs: Array, hvs: Array) -> Array:
    return jnp.argmax(hdc.class_scores(hvs, class_hvs), axis=-1)


@jax.jit
def accuracy(class_hvs: Array, hvs: Array, labels: Array) -> Array:
    return jnp.mean(predict(class_hvs, hvs) == labels)


# ---------------------------------------------------------------------------
# End-to-end convenience: train a fragment model from raw fragments
# ---------------------------------------------------------------------------

def train_fragment_model(key: Array, frags: Array, labels: Array, *,
                         dim: int, epochs: int = 20, lr: float = 1.0,
                         base_kind: str = "perm",
                         nonlinearity: NonLin = "rff",
                         val_frags: Array | None = None,
                         val_labels: Array | None = None
                         ) -> tuple[FragmentModel, dict]:
    """Train on raw fragments ``(N, h, w)`` with permutation-structured base.

    ``base_kind='perm'`` matches the accelerator datapath (paper §IV-B);
    ``'iid'`` is the textbook encoder (§III-A) for ablations.
    """
    from repro.core import encoding

    n, h, w = frags.shape[0], frags.shape[1], frags.shape[2]
    if base_kind == "perm":
        B0, b = encoding.make_perm_base_rows(key, h, dim)
        B = encoding.flat_perm_base(B0, w)
    elif base_kind == "iid":
        B, b = encoding.make_iid_base(key, h * w, dim)
    else:
        raise ValueError(base_kind)

    hvs = encode_fragments(frags, B, b, nonlinearity=nonlinearity)
    model = FragmentModel(bundle_init(hvs, labels), B, b)
    v_hvs = v_lab = None
    if val_frags is not None:
        v_hvs = encode_fragments(val_frags, B, b, nonlinearity=nonlinearity)
        v_lab = val_labels
    model, info = retrain(model, hvs, labels, epochs=epochs, lr=lr,
                          val_hvs=v_hvs, val_labels=v_lab)
    return model, info
