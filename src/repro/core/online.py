"""Online learning for the HDC classifier (paper §I, §III-A).

The paper's "memory-centricity and real-time learning" claim: class
hypervectors are a lightweight associative memory, so the model can keep
learning *in the stream*. This module factors the similarity-scaled
perceptron rule out of ``fragment_model.retrain_epoch`` into pure,
scan-able pieces the streaming runtime threads through its chunks:

* :func:`online_update` — one sample, one update. Exactly the step body of
  ``retrain_epoch``; the offline loop is now literally a scan of it.
* :func:`chunk_update` — label-feedback mode: fold a chunk of (hv, label)
  samples through :func:`online_update` sequentially. Because each step
  scores with the *running* class hypervectors, folding a sample sequence
  chunk-by-chunk is identical to one ``retrain_epoch`` pass over the whole
  sequence — chunk size is invisible to the learning trajectory (tested in
  ``tests/test_online.py``).
* :func:`chunk_update_pseudo` — self-supervised mode for label-free
  streams: each sample is pseudo-labeled with the model's own prediction
  and *reinforced* only when the prediction is confident (top-2 score
  margin >= ``confidence``). Low-confidence samples are skipped, which is
  what keeps self-training from amplifying its own mistakes under drift.
* :class:`AdaptConfig` — the static (hashable) adaptation policy the
  runners carry: mode, learning rate, confidence gate, and — for fleets —
  whether streams share one classifier or adapt per-stream.

Everything is pure jnp over explicit ``class_hvs`` state: jit/vmap/scan
safe, no hidden mutation — the runners own the state
(``repro.sensing.stream.StreamState``) and thread it through chunks.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import hdc

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdaptConfig:
    """Static adaptation policy for the streaming runners.

    ``mode``:
      * ``"label"``  — supervised label feedback: the caller passes
        per-frame labels to ``process(frames, labels)`` (e.g. delayed
        ground truth fed back from the gated high-precision path).
      * ``"pseudo"`` — confidence-gated self-training: no labels; the
        model reinforces its own confident predictions.

    ``lr`` scales the similarity-scaled update rate. ``confidence`` is the
    minimum top-2 score margin for a pseudo-label update (ignored in
    ``"label"`` mode). ``scope`` is fleet-only: ``"shared"`` folds every
    stream's samples into one classifier (time-ordered, stream-index
    tie-break); ``"per-stream"`` gives each sensor its own classifier,
    updated by a ``vmap`` over streams.

    Frozen dataclass => hashable => usable as a jit static argument.
    """
    mode: Literal["label", "pseudo"] = "label"
    lr: float = 0.5
    confidence: float = 0.25
    scope: Literal["shared", "per-stream"] = "shared"


def online_update(class_hvs: Array, hv: Array, y: Array,
                  lr: float = 1.0) -> tuple[Array, Array]:
    """One similarity-scaled perceptron update (paper step 4), pure.

    If the sample is mispredicted, move the true class toward it and the
    wrongly predicted class away, scaled by how unfamiliar it looked:

      ``C_y    += lr * (1 - delta_y) * hv``
      ``C_pred -= lr * (1 - delta_y) * hv``

    Returns ``(new class_hvs, wrong)``. This IS the step body of
    ``fragment_model.retrain_epoch`` — the offline epoch is a scan of it.
    """
    scores = hdc.class_scores(hv[None, :], class_hvs)[0]           # (C,)
    pred = jnp.argmax(scores)
    delta = scores[y]
    rate = lr * (1.0 - delta)
    wrong = pred != y
    upd = jnp.zeros_like(class_hvs).at[y].set(rate * hv)
    upd = upd.at[pred].add(jnp.where(wrong, -rate, 0.0) * hv)
    class_hvs = class_hvs + jnp.where(wrong, 1.0, 0.0) * upd
    return class_hvs, wrong


def pseudo_update(class_hvs: Array, hv: Array, *, lr: float = 1.0,
                  confidence: float = 0.25) -> tuple[Array, Array]:
    """One confidence-gated self-training update (no label), pure.

    The sample is pseudo-labeled ``argmax`` and the predicted class is
    *reinforced* (pulled toward the sample) — but only when the top-2
    score margin clears ``confidence``. (The perceptron rule itself would
    be a no-op under its own prediction, so self-training needs this
    reinforcement form; the gate keeps it from chasing noise.)

    Returns ``(new class_hvs, updated)``.
    """
    scores = hdc.class_scores(hv[None, :], class_hvs)[0]           # (C,)
    top2 = jax.lax.top_k(scores, 2)[0]
    pred = jnp.argmax(scores)
    margin = top2[0] - top2[1]
    rate = lr * (1.0 - scores[pred])
    confident = margin >= confidence
    upd = jnp.zeros_like(class_hvs).at[pred].set(rate * hv)
    class_hvs = class_hvs + jnp.where(confident, 1.0, 0.0) * upd
    return class_hvs, confident


def chunk_update(class_hvs: Array, hvs: Array, labels: Array, *,
                 lr: float = 1.0,
                 valid: Array | None = None) -> tuple[Array, Array]:
    """Fold a chunk of labeled samples through :func:`online_update`.

    ``valid`` masks padded tail samples (they leave the state untouched).
    Each step scores against the running state, so chaining
    ``chunk_update`` over consecutive chunks reproduces ``retrain_epoch``
    over the concatenated sequence exactly, for any chunk size.

    Returns ``(new class_hvs, wrong (N,) bool)``.
    """
    if valid is None:
        valid = jnp.ones(hvs.shape[0], bool)

    def step(chvs, xyv):
        hv, y, v = xyv
        new, wrong = online_update(chvs, hv, y, lr)
        return jnp.where(v, new, chvs), wrong & v   # exact select: a masked
        # step must leave the state bitwise untouched (chunking invariance)

    return jax.lax.scan(step, class_hvs,
                        (hvs, labels, valid.astype(bool)))


def chunk_update_pseudo(class_hvs: Array, hvs: Array, *, lr: float = 1.0,
                        confidence: float = 0.25,
                        valid: Array | None = None) -> tuple[Array, Array]:
    """Fold a chunk of *unlabeled* samples through :func:`pseudo_update`.

    Returns ``(new class_hvs, updated (N,) bool)``.
    """
    if valid is None:
        valid = jnp.ones(hvs.shape[0], bool)

    def step(chvs, xv):
        hv, v = xv
        new, did = pseudo_update(chvs, hv, lr=lr, confidence=confidence)
        return jnp.where(v, new, chvs), did & v

    return jax.lax.scan(step, class_hvs, (hvs, valid.astype(bool)))


def apply_chunk(config: AdaptConfig, class_hvs: Array, hvs: Array,
                labels: Array, valid: Array | None = None
                ) -> tuple[Array, Array]:
    """Dispatch one chunk of samples through the configured update mode.

    In ``"pseudo"`` mode ``labels`` is ignored (pass anything — the
    runners pass zeros when the caller gave none).
    """
    if config.mode == "label":
        return chunk_update(class_hvs, hvs, labels, lr=config.lr,
                            valid=valid)
    if config.mode == "pseudo":
        return chunk_update_pseudo(class_hvs, hvs, lr=config.lr,
                                   confidence=config.confidence,
                                   valid=valid)
    raise ValueError(f"unknown adaptation mode {config.mode!r}")
