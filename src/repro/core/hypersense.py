"""The *HyperSense model* — frame-level detection (paper §III-C b, Fig. 5b).

Given a trained :class:`~repro.core.fragment_model.FragmentModel` and three
hyperparameters (``stride``, ``t_score``, ``t_detection``):

  (6) crop fragments from the frame in a sliding-window manner (``stride``)
  (7) score every fragment with the Fragment model
  (8) threshold each score by ``t_score``  -> per-fragment 0/1 prediction
  (9) frame is positive iff  ``sum(predictions) > t_detection``

ROC machinery: for a fixed ``t_detection = T``, the frame decision
``count(s_i > t) > T`` is equivalent to ``kth_largest(s, T+1) > t`` — so the
frame-level detection *score* is the (T+1)-th order statistic of the
fragment scores, and standard ROC analysis applies (used for Figs. 12-15).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import hdc
from repro.core.encoding import NonLin, encode_frame_naive, encode_frame_reuse
from repro.core.fragment_model import FragmentModel

Array = jax.Array


class HyperSenseModel(NamedTuple):
    """Frame detector = Fragment model + (h, w, stride, t_score, t_detection).

    ``B0`` is the permutation-generator base ``(h, D)`` the sliding encoder
    consumes; ``class_hvs``/``b`` come from the trained Fragment model.
    """
    class_hvs: Array          # (2, D)
    B0: Array                 # (h, D) permutation generators
    b: Array                  # (D,)
    h: int
    w: int
    stride: int
    t_score: float
    t_detection: int
    nonlinearity: NonLin = "rff"


def from_fragment_model(model: FragmentModel, B0: Array, *, h: int, w: int,
                        stride: int, t_score: float = 0.0,
                        t_detection: int = 0,
                        nonlinearity: NonLin = "rff") -> HyperSenseModel:
    """Assemble a HyperSense model (no additional training — paper §III-C)."""
    return HyperSenseModel(model.class_hvs, B0, model.b, h, w, stride,
                           t_score, t_detection, nonlinearity)


@partial(jax.jit, static_argnames=("h", "w", "stride", "nonlinearity",
                                   "reuse", "backend"))
def fragment_score_map(frame: Array, class_hvs: Array, B0: Array, b: Array,
                       *, h: int, w: int, stride: int,
                       nonlinearity: NonLin = "rff", reuse: bool = True,
                       backend: str = "jnp") -> Array:
    """Score every sliding-window fragment of a frame -> ``(my, mx)``.

    ``backend='pallas'`` routes encode + similarity through the TPU kernels
    (``repro.kernels``); ``'jnp'`` uses the pure-jnp path.
    """
    if backend == "pallas":
        from repro.kernels import ops as kops
        return kops.fragment_score_map(frame, class_hvs, B0, b, h=h, w=w,
                                       stride=stride,
                                       nonlinearity=nonlinearity)
    enc = encode_frame_reuse if reuse else encode_frame_naive
    hv = enc(frame, B0, b, h=h, w=w, stride=stride,
             nonlinearity=nonlinearity)                     # (my, mx, D)
    my, mx, dim = hv.shape
    s = hdc.class_scores(hv.reshape(my * mx, dim), class_hvs)
    s = s[:, 1] - s[:, 0]
    return s.reshape(my, mx)


def score_frame(model: HyperSenseModel, frame: Array, *,
                reuse: bool = True, backend: str = "jnp") -> Array:
    return fragment_score_map(
        frame, model.class_hvs, model.B0, model.b, h=model.h, w=model.w,
        stride=model.stride, nonlinearity=model.nonlinearity, reuse=reuse,
        backend=backend)


def detect(model: HyperSenseModel, frame: Array, *,
           backend: str = "jnp") -> Array:
    """Boolean frame-level decision (paper steps 8-9)."""
    s = score_frame(model, frame, backend=backend)
    count = jnp.sum(s > model.t_score)
    return count > model.t_detection


def frame_detection_score(scores: Array, t_detection: int) -> Array:
    """ROC-sweepable frame score: the (t_detection+1)-th largest fragment
    score. ``frame positive at threshold t  <=>  score > t``.

    The hot path only needs the (T+1)-th order statistic, so this is
    ``lax.top_k(flat, T+1)`` — O(M log(T+1))-ish — instead of a full
    O(M log M) sort. ``t_detection`` is static in every caller (it sizes
    ``top_k``); a traced value falls back to the sort.
    """
    flat = scores.reshape(-1)
    try:
        k = min(int(t_detection), flat.shape[0] - 1)
    except (TypeError, jax.errors.TracerIntegerConversionError):
        k = jnp.minimum(t_detection, flat.shape[0] - 1)
        return jnp.sort(flat)[::-1][k]
    return jax.lax.top_k(flat, k + 1)[0][k]


def detect_batch(model: HyperSenseModel, frames: Array, *,
                 backend: str = "jnp", tiles=None) -> Array:
    """Vectorized detection over ``(N, H, W)`` frames -> ``(N,)`` bool.

    Routed through :func:`frame_scores_batch` — ONE kernel launch for the
    whole batch on the ``pallas`` backend (vs one per frame when vmapping
    :func:`detect`) — using the order-statistic equivalence
    ``count(s_i > t) > T  <=>  kth_largest(s, T+1) > t``, valid while
    ``T < my*mx``; past that the count can never exceed T, so nothing
    fires.
    """
    from repro.core.encoding import num_windows

    N, H, W = frames.shape
    my = num_windows(H, model.h, model.stride)
    mx = num_windows(W, model.w, model.stride)
    if model.t_detection >= my * mx:
        return jnp.zeros(N, bool)
    scores = frame_scores_batch(model, frames, backend=backend, tiles=tiles)
    return scores > model.t_score


def frame_scores_batch(model: HyperSenseModel, frames: Array,
                       t_detection: int | None = None, *,
                       backend: str = "jnp",
                       sequential: bool = False,
                       tiles=None,
                       precision: str = "float32",
                       adc_bits: int = 8) -> Array:
    """Frame-level ROC scores for a batch of frames -> ``(N,)`` float.

    ``backend='pallas'`` (non-sequential) scores the whole batch in ONE
    kernel launch via :func:`repro.kernels.ops.fragment_score_map_batch`,
    reusing a single per-model tile precompute (pass ``tiles`` from
    :func:`repro.kernels.ops.precompute_tiles` to amortize it across
    calls). ``sequential=True`` scores frames one jit call at a time — use
    for large D / many frames on the jnp path, where the vmapped
    rolled-product intermediate (N x H x W x D) would blow host memory.

    The integer precisions (``"int8"``, ``"int4"``, ``"binary"``) run the
    low-precision integer datapath
    (:mod:`repro.kernels.sliding_scores_int`): ``frames`` may be raw
    integer ADC codes (consumed untouched) or floats (quantized to
    ``adc_bits`` codes first — the simulated converter). ``tiles`` must
    then come from :func:`repro.kernels.ops.precompute_tiles_int` (built
    with the matching ``mode`` for ``"binary"``). ``"int4"`` requires
    ``adc_bits <= 4`` and an even frame width; its codes ride the
    two-per-byte wire format (packed here at the kernel boundary,
    unpacked in-kernel). Scores stay on the float path's scale (the ADC
    LSB cancels in the window normalization), so ``t_score``/ROC sweeps
    transfer unchanged.
    """
    td = model.t_detection if t_detection is None else t_detection

    from repro.sensing import adc as adc_sim

    if precision not in adc_sim.PRECISIONS:
        raise ValueError(f"precision must be one of {adc_sim.PRECISIONS}, "
                         f"got {precision!r}")
    if precision in adc_sim.INT_PRECISIONS:
        from repro.kernels import ops as kops
        from repro.kernels import sliding_scores_int as ssi

        if precision == "int4" and adc_bits > 4:
            raise ValueError(
                f"precision='int4' packs two codes per byte, so adc_bits "
                f"must be <= 4 (got {adc_bits})")
        if jnp.issubdtype(frames.dtype, jnp.integer):
            # pre-converted codes must actually fit adc_bits, or the
            # overflow bounds below are checked at the wrong depth
            adc_sim.check_codes_range(frames, adc_bits)
            codes = frames
        else:
            codes = adc_sim.pack_codes(
                adc_sim.quantize_codes(frames, adc_bits), adc_bits)
        packed = precision == "int4"
        kops.assert_int_datapath_fits(adc_bits, *codes.shape[-2:],
                                      model.h, model.w,
                                      stride=model.stride, packed=packed)
        if tiles is None:
            tiles = kops.precompute_tiles_int(
                model.B0, model.b, model.class_hvs, W=codes.shape[-1],
                w=model.w, stride=model.stride,
                mode="binary" if precision == "binary" else "int8")
        if packed:
            codes = adc_sim.pack_nibbles(codes)

        def score_maps(c):
            if backend == "pallas":
                return kops.fragment_score_map_batch_int(
                    c, model.class_hvs, model.B0, model.b, h=model.h,
                    w=model.w, stride=model.stride,
                    nonlinearity=model.nonlinearity, tiles=tiles,
                    packed=packed)
            return ssi.fragment_scores_batch_int_ref(
                c, tiles, h=model.h, w=model.w, stride=model.stride,
                nonlinearity=model.nonlinearity, packed=packed)

        if sequential:
            # one frame per (jitted) call: the same memory escape hatch
            # the float path documents — the jnp oracle materializes
            # (N, my, mx, D) projections, which this caps at N = 1
            return jnp.stack([
                frame_detection_score(score_maps(codes[i:i + 1])[0], td)
                for i in range(codes.shape[0])])
        maps = score_maps(codes)
        return jax.vmap(lambda m: frame_detection_score(m, td))(maps)

    if backend == "pallas" and not sequential:
        from repro.kernels import ops as kops
        maps = kops.fragment_score_map_batch(
            frames, model.class_hvs, model.B0, model.b, h=model.h,
            w=model.w, stride=model.stride,
            nonlinearity=model.nonlinearity, tiles=tiles)   # (N, my, mx)
        return jax.vmap(lambda m: frame_detection_score(m, td))(maps)

    def one(f):
        return frame_detection_score(
            score_frame(model, f, backend=backend), td)

    if sequential:
        one_j = jax.jit(one)
        return jnp.stack([one_j(f) for f in frames])
    return jax.vmap(one)(frames)
