"""Intelligent Sensor Control (paper §III-B, Fig. 3-4).

The control loop: a low-precision always-on path feeds the HDC HyperSense
model; its frame-level decision gates the high-precision ADC (and everything
downstream — transmission + cloud model). Generalized here to *compute
gating*: the "high-precision ADC + cloud model" can be any expensive
backend, including the LM backbones in ``repro.models``.

``SensorController`` is a small state machine with hysteresis:

* idle: sample at ``base_rate`` (e.g. 1 fps) through the low-precision path
* when HDC fires: switch the high-precision path on for ``hold`` frames
  (re-armed on every positive), i.e. the 60 fps burst the paper describes.

``simulate_stream`` replays a recorded/synthetic frame stream through the
controller and returns per-frame gate decisions + accounting used by the
energy model (Fig. 17 / Table III).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import numpy as np


@dataclass
class ControllerConfig:
    base_rate_hz: float = 1.0     # low-precision always-on sampling
    active_rate_hz: float = 60.0  # high-precision burst rate when triggered
    hold_frames: int = 3          # keep HP path on for this many frames
                                  # after the last positive (hysteresis)


@dataclass
class StreamStats:
    decisions: np.ndarray         # bool (N,)  HDC fired per frame
    gated_on: np.ndarray          # bool (N,)  HP path enabled per frame
    duty_cycle: float             # fraction of frames HP path was on
    missed_positive: float        # fraction of object frames with HP off
    false_active: float           # fraction of empty frames with HP on


class SensorController:
    """Stateful gate. ``step(fired) -> bool`` (is the HP path on?)."""

    def __init__(self, config: ControllerConfig | None = None):
        self.config = config or ControllerConfig()
        self._hold = 0

    def reset(self) -> None:
        self._hold = 0

    def step(self, fired: bool) -> bool:
        if fired:
            self._hold = self.config.hold_frames
            return True
        if self._hold > 0:
            self._hold -= 1
            return True
        return False


def stats_from(decisions: np.ndarray, gated: np.ndarray,
               labels: np.ndarray) -> StreamStats:
    """Accounting shared by every stream driver (frame-at-a-time and the
    chunked-batched runtime must produce identical StreamStats)."""
    labels = np.asarray(labels).astype(bool)
    pos = max(int(labels.sum()), 1)
    neg = max(int((~labels).sum()), 1)
    return StreamStats(
        decisions=decisions,
        gated_on=gated,
        duty_cycle=float(gated.mean()),
        missed_positive=float((labels & ~gated).sum() / pos),
        false_active=float((~labels & gated).sum() / neg),
    )


def stats_from_batch(decisions: np.ndarray, gated: np.ndarray,
                     labels: np.ndarray) -> list[StreamStats]:
    """Per-stream accounting for a sensor fleet.

    ``decisions``/``gated``/``labels`` are ``(S, N)`` stacks — one row per
    sensor stream; row ``s`` gets exactly the :class:`StreamStats` an
    independent single-stream driver would have produced.
    """
    decisions = np.asarray(decisions)
    gated = np.asarray(gated)
    labels = np.asarray(labels)
    assert decisions.shape == gated.shape == labels.shape, (
        decisions.shape, gated.shape, labels.shape)
    return [stats_from(decisions[s], gated[s], labels[s])
            for s in range(decisions.shape[0])]


def simulate_stream(decide: Callable[[np.ndarray], bool],
                    frames: np.ndarray, labels: np.ndarray,
                    config: ControllerConfig | None = None) -> StreamStats:
    """Run the controller over a frame stream.

    Args:
      decide: frame -> bool, the HyperSense detection (low-precision path).
      frames: (N, H, W) low-precision frames.
      labels: (N,) bool, ground-truth object presence.
    """
    ctrl = SensorController(config)
    n = len(frames)
    decisions = np.zeros(n, dtype=bool)
    gated = np.zeros(n, dtype=bool)
    for i in range(n):
        decisions[i] = bool(decide(frames[i]))
        gated[i] = ctrl.step(decisions[i])
    return stats_from(decisions, gated, labels)
