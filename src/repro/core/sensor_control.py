"""Intelligent Sensor Control (paper §III-B, Fig. 3-4).

The control loop: a low-precision always-on path feeds the HDC HyperSense
model; its frame-level decision gates the high-precision ADC (and everything
downstream — transmission + cloud model). Generalized here to *compute
gating*: the "high-precision ADC + cloud model" can be any expensive
backend, including the LM backbones in ``repro.models``.

``SensorController`` is a small state machine with hysteresis:

* idle: sample at ``base_rate`` (e.g. 1 fps) through the low-precision path
* when HDC fires: switch the high-precision path on for ``hold`` frames
  (re-armed on every positive), i.e. the 60 fps burst the paper describes.

``simulate_stream`` replays a recorded/synthetic frame stream through the
controller and returns per-frame gate decisions + accounting used by the
energy model (Fig. 17 / Table III).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import numpy as np


@dataclass
class ControllerConfig:
    base_rate_hz: float = 1.0     # low-precision always-on sampling
    active_rate_hz: float = 60.0  # high-precision burst rate when triggered
    hold_frames: int = 3          # keep HP path on for this many frames
                                  # after the last positive (hysteresis)


def decimation(config: ControllerConfig) -> int:
    """Idle-phase LP sampling period, in frames of the ``active_rate_hz``
    frame clock: the closed-loop ADC converts 1 of every ``decimation``
    frames while idle, every frame while the gate holds the burst on.
    ``base == active`` gives 1 (no subsampling — the open-loop behavior).
    """
    if config.base_rate_hz <= 0 or config.active_rate_hz <= 0:
        raise ValueError(f"rates must be positive, got "
                         f"base={config.base_rate_hz}, "
                         f"active={config.active_rate_hz}")
    if config.active_rate_hz < config.base_rate_hz:
        raise ValueError(f"active_rate_hz {config.active_rate_hz} < "
                         f"base_rate_hz {config.base_rate_hz}: the burst "
                         "rate is the stream's frame clock and cannot be "
                         "slower than the idle trickle")
    return max(1, int(round(config.active_rate_hz / config.base_rate_hz)))


@dataclass(frozen=True)
class CaptureConfig:
    """Closed-loop ADC capture policy — the runners' ``control=`` argument.

    With a ``CaptureConfig`` the gate decision at frame ``t`` modulates
    *capture* at frame ``t+1``: idle frames are temporally subsampled to
    ``ControllerConfig.base_rate_hz`` (the low-precision ADC converts one
    frame per :func:`decimation` period; skipped frames are never scored
    and can never fire), and gated frames burst at ``active_rate_hz``
    with the high-precision ADC on — HP frames are materialized into a
    bounded gather buffer as the runtime's deliverable.

    ``subsample=False`` keeps the closed-loop machinery on but converts
    every frame (bitwise-identical outputs to ``control=None``; same for
    ``base_rate_hz == active_rate_hz``). ``hp_bits`` is the burst bit
    depth (the energy model's ``adc_hp_bits``). ``hp_buffer`` bounds how
    many HP frames one chunk step can materialize (``None`` → the
    runner's ``chunk_size``; ``0`` → log-only, no frames kept).
    """
    subsample: bool = True
    hp_bits: int = 12
    hp_buffer: int | None = None


@dataclass
class CaptureLog:
    """Per-frame record of what the ADC *actually* converted.

    ``sampled``/``gated`` are ``(N,)`` (single stream) or ``(S, N)``
    (fleet) bools: ``sampled[i]`` — the low-precision ADC converted frame
    ``i`` (so the HDC gate scored it); ``gated[i]`` — the high-precision
    ADC converted it and the frame was transmitted downstream. Bit
    depths of ``None`` fall back to the billing-time
    :class:`~repro.core.energy.EnergyParams` defaults.

    This is the ground truth :func:`repro.core.energy.from_capture_log`
    bills from — Joules per conversion actually made and frame actually
    sent, replacing the duty-fraction approximation.
    """
    sampled: np.ndarray
    gated: np.ndarray
    lp_bits: int | None = None    # always-on conversion depth
    hp_bits: int | None = None    # gated burst depth
    frame_pixels: int = 0         # samples (pixel conversions) per frame

    def samples_converted(self) -> int:
        """Total ADC conversions made: LP frames + HP frames, at
        ``frame_pixels`` conversions each."""
        return int((np.asarray(self.sampled, bool).sum()
                    + np.asarray(self.gated, bool).sum())
                   * self.frame_pixels)


def assemble_capture_log(sampled_blocks, gated_blocks, *,
                         lp_bits: int | None,
                         control: CaptureConfig | None,
                         frame_pixels: int, axis: int = 0) -> CaptureLog:
    """Build a :class:`CaptureLog` from a runner's per-chunk blocks.

    The ONE place every stream front-end (``StreamRunner``,
    ``FleetRunner``, ``FleetService``) assembles its billing log, so the
    ``hp_bits`` convention cannot drift between them: ``control=None``
    (open loop) records ``hp_bits=None`` — billing-time code decides what
    that means (see :func:`repro.core.energy.from_capture_log`); a
    closed-loop runner records ``control.hp_bits``, the depth its HP
    bursts were actually converted at.

    ``axis`` is the frame axis blocks concatenate along: 0 for ``(n,)``
    single-stream blocks, 1 for ``(S, n)`` fleet blocks. With no blocks
    yet the arrays are empty with the right rank (``(0,)`` / ``(0, 0)``).
    """
    def cat(blocks):
        if blocks:
            return np.concatenate([np.asarray(b, bool) for b in blocks],
                                  axis=axis)
        return np.zeros((0,) * (axis + 1), bool)

    return CaptureLog(sampled=cat(sampled_blocks), gated=cat(gated_blocks),
                      lp_bits=lp_bits,
                      hp_bits=None if control is None else control.hp_bits,
                      frame_pixels=frame_pixels)


@dataclass
class StreamStats:
    """Per-stream gate accounting.

    ``missed_positive`` / ``false_active`` are class-conditional rates:
    on a stream with *no* frames of the conditioning class (no object
    frames / no empty frames) the rate is undefined and reported as
    ``float("nan")`` — never clamped to a perfect 0.0 score. NaN
    propagates through :func:`stats_from_batch` and
    :func:`repro.sensing.fleet.fleet_report` untouched (energy billing
    only consumes ``duty_cycle``, which is always defined).
    """
    decisions: np.ndarray         # bool (N,)  HDC fired per frame
    gated_on: np.ndarray          # bool (N,)  HP path enabled per frame
    duty_cycle: float             # fraction of frames HP path was on
    missed_positive: float        # fraction of object frames with HP off
    false_active: float           # fraction of empty frames with HP on


class SensorController:
    """Stateful gate. ``step(fired) -> bool`` (is the HP path on?)."""

    def __init__(self, config: ControllerConfig | None = None):
        self.config = config or ControllerConfig()
        self._hold = 0

    def reset(self) -> None:
        self._hold = 0

    def step(self, fired: bool) -> bool:
        if fired:
            self._hold = self.config.hold_frames
            return True
        if self._hold > 0:
            self._hold -= 1
            return True
        return False


class RateController:
    """Rate-aware stateful gate: ``step(fired) -> (sampled, gated)``.

    The closed-loop twin of :class:`SensorController`: besides the HP
    hysteresis it decides whether the low-precision ADC converts each
    frame at all. Idle, it samples one frame per :func:`decimation`
    period (``base_rate_hz`` out of the ``active_rate_hz`` frame clock);
    a skipped frame is never scored, so its ``fired`` input is ignored.
    While the gate holds a burst on, every frame is sampled. With
    ``decimation == 1`` (``base == active``, or ``subsample=False``) the
    ``gated`` output is bit-identical to :class:`SensorController`.

    :func:`repro.sensing.stream.control_scan` is the jittable scan twin
    (property-tested equivalent in ``tests/test_control_loop.py``).
    """

    def __init__(self, config: ControllerConfig | None = None, *,
                 subsample: bool = True):
        self.config = config or ControllerConfig()
        self.decim = decimation(self.config) if subsample else 1
        self._hold = 0
        self._phase = 0           # frames until the next idle LP sample

    def reset(self) -> None:
        self._hold = 0
        self._phase = 0

    def step(self, fired: bool) -> tuple[bool, bool]:
        sampled = self._phase == 0 or self._hold > 0
        fired = bool(fired) and sampled
        gated = fired or self._hold > 0
        self._hold = (self.config.hold_frames if fired
                      else max(self._hold - 1, 0))
        self._phase = self.decim - 1 if sampled else self._phase - 1
        return sampled, gated


def stats_from(decisions: np.ndarray, gated: np.ndarray,
               labels: np.ndarray) -> StreamStats:
    """Accounting shared by every stream driver (frame-at-a-time and the
    chunked-batched runtime must produce identical StreamStats).

    Class-conditional rates over an empty class are undefined — reported
    as NaN, not clamped to a perfect score (see :class:`StreamStats`).
    """
    labels = np.asarray(labels).astype(bool)
    pos = int(labels.sum())
    neg = int((~labels).sum())
    return StreamStats(
        decisions=decisions,
        gated_on=gated,
        duty_cycle=float(gated.mean()),
        missed_positive=(float((labels & ~gated).sum() / pos) if pos
                         else float("nan")),
        false_active=(float((~labels & gated).sum() / neg) if neg
                      else float("nan")),
    )


def stats_from_batch(decisions: np.ndarray, gated: np.ndarray,
                     labels: np.ndarray) -> list[StreamStats]:
    """Per-stream accounting for a sensor fleet.

    ``decisions``/``gated``/``labels`` are ``(S, N)`` stacks — one row per
    sensor stream; row ``s`` gets exactly the :class:`StreamStats` an
    independent single-stream driver would have produced.
    """
    decisions = np.asarray(decisions)
    gated = np.asarray(gated)
    labels = np.asarray(labels)
    assert decisions.shape == gated.shape == labels.shape, (
        decisions.shape, gated.shape, labels.shape)
    return [stats_from(decisions[s], gated[s], labels[s])
            for s in range(decisions.shape[0])]


def simulate_stream(decide: Callable[[np.ndarray], bool],
                    frames: np.ndarray, labels: np.ndarray,
                    config: ControllerConfig | None = None) -> StreamStats:
    """Run the controller over a frame stream.

    Args:
      decide: frame -> bool, the HyperSense detection (low-precision path).
      frames: (N, H, W) low-precision frames.
      labels: (N,) bool, ground-truth object presence.
    """
    ctrl = SensorController(config)
    n = len(frames)
    decisions = np.zeros(n, dtype=bool)
    gated = np.zeros(n, dtype=bool)
    for i in range(n):
        decisions[i] = bool(decide(frames[i]))
        gated[i] = ctrl.step(decisions[i])
    return stats_from(decisions, gated, labels)
