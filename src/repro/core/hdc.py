"""Fundamental hyperdimensional-computing operations (paper §III-A).

Hypervectors are plain ``jnp.ndarray`` rows of shape ``(..., D)`` with
D ~ 1K-10K. All three brain-inspired primitives — bundling, binding,
permutation — plus the similarity measure used throughout HyperSense.

Everything here is pure jnp and jit-safe; the Pallas kernels in
``repro.kernels`` accelerate the hot paths (encoding, similarity) and are
validated against these definitions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def bundle(*hvs: Array) -> Array:
    """Bundling (+): element-wise addition — cognitive *memorization*.

    ``bundle(h1, h2)`` is similar to both ``h1`` and ``h2``.
    """
    out = hvs[0]
    for h in hvs[1:]:
        out = out + h
    return out


def bind(h1: Array, h2: Array) -> Array:
    """Binding (*): element-wise multiplication — cognitive *association*.

    The result is dissimilar to both operands but preserves similarity:
    ``sim(v*h1, v*h2) ~= sim(h1, h2)``.
    """
    return h1 * h2


def permute(h: Array, shift: int = 1, axis: int = -1) -> Array:
    """Permutation (rho): cyclic rotation of vector elements.

    Encodes order/position: ``sim(permute(h), h) ~= 0`` for random ``h``.
    HyperSense generates spatially adjacent base hypervectors by repeated
    permutation (Eq. 1) — the property the computation-reuse kernel exploits.
    """
    return jnp.roll(h, shift, axis=axis)


def cosine_similarity(a: Array, b: Array, eps: float = 1e-9) -> Array:
    """delta(a, b): cosine similarity along the last (hyperdimension) axis.

    Broadcasts over leading axes, e.g. ``a: (N, D)``, ``b: (C, D)`` is *not*
    broadcast — use :func:`class_scores` for the classifier matmul form.
    """
    num = jnp.sum(a * b, axis=-1)
    den = jnp.linalg.norm(a, axis=-1) * jnp.linalg.norm(b, axis=-1)
    return num / jnp.maximum(den, eps)


def class_scores(queries: Array, class_hvs: Array, eps: float = 1e-9) -> Array:
    """Cosine similarity of each query against each class hypervector.

    Args:
      queries:   ``(N, D)`` encoded query hypervectors.
      class_hvs: ``(C, D)`` class hypervectors.

    Returns:
      ``(N, C)`` similarity matrix.
    """
    qn = queries / jnp.maximum(
        jnp.linalg.norm(queries, axis=-1, keepdims=True), eps
    )
    cn = class_hvs / jnp.maximum(
        jnp.linalg.norm(class_hvs, axis=-1, keepdims=True), eps
    )
    return qn @ cn.T


def hamming_similarity(a: Array, b: Array) -> Array:
    """Normalized agreement of sign-quantized hypervectors (bipolar HDC)."""
    return jnp.mean(jnp.sign(a) == jnp.sign(b), axis=-1).astype(jnp.float32)
