"""HyperSenseGate: the paper's technique as a compute front-end.

Generalizes Intelligent Sensor Control (paper §III-B) from gating an ADC
to gating *any* expensive backend — in this framework, the LM backbones:
frames/segments that the HDC model rejects never enter the backend batch,
so backend FLOPs scale with the duty cycle exactly as the paper's
high-precision-ADC energy does (EXPERIMENTS §Paper/energy).

Pipeline-level (host numpy + jitted per-frame scoring), deliberately
outside jit: this is the data-loading stage in front of
``repro.train.loop`` / ``repro.launch.decode``.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np

from repro.core import hypersense
from repro.core.sensor_control import ControllerConfig, SensorController


@dataclass
class GateStats:
    n_seen: int = 0
    n_passed: int = 0

    @property
    def duty_cycle(self) -> float:
        return self.n_passed / max(self.n_seen, 1)


class HyperSenseGate:
    """Stateful stream gate: ``select(frames) -> indices`` of frames the
    backend should process (controller hysteresis included)."""

    def __init__(self, model: hypersense.HyperSenseModel,
                 controller: ControllerConfig | None = None,
                 backend: str = "jnp"):
        self.model = model
        self.controller = SensorController(controller)
        self.stats = GateStats()
        self._decide = jax.jit(
            lambda f: hypersense.detect(model, f, backend=backend))

    def select(self, frames) -> np.ndarray:
        """Indices of gated-on frames, in stream order."""
        keep = []
        for i, frame in enumerate(np.asarray(frames)):
            fired = bool(self._decide(frame))
            on = self.controller.step(fired)
            self.stats.n_seen += 1
            if on:
                self.stats.n_passed += 1
                keep.append(i)
        return np.asarray(keep, dtype=np.int64)

    def filter(self, frames, payloads=None):
        """Gate a stream; returns (kept_payloads, kept_indices).

        ``payloads`` default to the frames themselves — pass the
        high-precision captures (or token batches) the backend consumes.
        """
        idx = self.select(frames)
        src = frames if payloads is None else payloads
        return np.asarray(src)[idx], idx


def backend_flops_saved(stats: GateStats, flops_per_item: float) -> dict:
    """Backend-compute accounting mirroring the paper's energy table."""
    full = stats.n_seen * flops_per_item
    used = stats.n_passed * flops_per_item
    return {"duty_cycle": stats.duty_cycle,
            "backend_flops_full": full,
            "backend_flops_gated": used,
            "backend_saving": 1.0 - used / max(full, 1.0)}
