"""Training runtime: optimizers, schedules, loop, compression, fault tolerance."""

from repro.train import optim  # noqa: F401
