"""Production train loop: grad accumulation, checkpoint/restart, preemption.

The loop is mesh-agnostic: the same code drives a 1-device smoke run and a
512-chip pjit run (shardings come from the cell builders). Fault-tolerance
contract:

* checkpoint every ``ckpt_every`` steps (async) + on preemption signal
* restart resumes from the latest valid checkpoint — including data
  pipeline state (step counter seeds the data RNG, so batches are
  exactly-once across restarts)
* elastic: restore re-shards to whatever mesh the relaunch built
  (``ckpt.restore(..., shardings=new_shardings)``).

Straggler mitigation at this layer = synchronous SPMD with async
checkpointing + preemption handoff; cluster-level replacement is the
launcher's job (see launch/train.py docstring).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Iterator

import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as ckpt
from repro.models import lm
from repro.train import optim

Array = jax.Array


@dataclass
class TrainConfig:
    steps: int = 100
    microbatches: int = 1          # grad accumulation factor
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    lr: float = 3e-4
    warmup: int = 10
    weight_decay: float = 0.1


def make_train_step(model: lm.Model, opt: optim.AdamW,
                    microbatches: int = 1):
    """Returns ``step(params, opt_state, batch) -> (params, opt, metrics)``.

    With ``microbatches > 1`` the batch's leading dim is split and
    gradients accumulate in a ``lax.scan`` (XLA overlaps each
    microbatch's reduce with the next one's compute).
    """

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def step(params, opt_state, batch: lm.Batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(x):
                if x is None:
                    return None
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches,
                                 *x.shape[1:])

            mb = lm.Batch(*(split(x) for x in batch))

            def accum(carry, mb_i):
                loss_sum, g_sum = carry
                batch_i = lm.Batch(*mb_i)
                li, gi = jax.value_and_grad(loss_fn)(params, batch_i)
                return (loss_sum + li,
                        jax.tree.map(jnp.add, g_sum, gi)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                accum, (jnp.float32(0.0), zeros), mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        gnorm = optim.global_norm(grads)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return step


def train(model: lm.Model, data: Iterator[lm.Batch], tc: TrainConfig,
          *, params=None, jit_kwargs: dict | None = None,
          on_metrics: Callable[[int, dict], None] | None = None) -> dict:
    """Run (or resume) training. Returns final {params, opt_state, step}."""
    opt = optim.AdamW(
        lr=optim.warmup_cosine(tc.lr, tc.warmup, tc.steps),
        weight_decay=tc.weight_decay)
    if params is None:
        params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)

    start_step = 0
    latest = ckpt.latest_step(tc.ckpt_dir)
    if latest is not None:
        (params, opt_state), extra = ckpt.restore(
            tc.ckpt_dir, (params, opt_state))
        start_step = extra.get("step", latest)
        print(f"[train] resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(model, opt, tc.microbatches),
                      **(jit_kwargs or {}), donate_argnums=(0, 1))

    saver = ckpt.AsyncCheckpointer(tc.ckpt_dir, keep=tc.keep)
    state = {"params": params, "opt_state": opt_state, "step": start_step}

    def emergency_save():
        saver.wait()
        ckpt.save(tc.ckpt_dir, state["step"],
                  (state["params"], state["opt_state"]),
                  keep=tc.keep, extra={"step": state["step"]})
        print(f"[train] preemption checkpoint at step {state['step']}")

    ckpt.install_preemption_handler(emergency_save)

    t0 = time.time()
    history = []
    for step_i in range(start_step, tc.steps):
        batch = next(data)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        state.update(params=params, opt_state=opt_state, step=step_i + 1)
        if (step_i + 1) % tc.log_every == 0 or step_i == start_step:
            loss = float(metrics["loss"])
            history.append(loss)
            dt = time.time() - t0
            print(f"[train] step {step_i + 1}/{tc.steps} "
                  f"loss {loss:.4f} gnorm {float(metrics['grad_norm']):.3f} "
                  f"({dt:.1f}s)")
            if on_metrics:
                on_metrics(step_i + 1, {k: float(v)
                                        for k, v in metrics.items()})
        if (step_i + 1) % tc.ckpt_every == 0:
            saver.save(step_i + 1, (params, opt_state),
                       extra={"step": step_i + 1})
    saver.wait()
    ckpt.save(tc.ckpt_dir, tc.steps, (params, opt_state), keep=tc.keep,
              extra={"step": tc.steps})
    return {"params": params, "opt_state": opt_state,
            "step": tc.steps, "history": history}


def synthetic_lm_data(cfg, batch: int, seq: int,
                      start_step: int = 0) -> Iterator[lm.Batch]:
    """Deterministic synthetic LM stream keyed by step (exactly-once
    across restarts: step -> key -> batch)."""
    step = start_step
    while True:
        key = jax.random.fold_in(jax.random.PRNGKey(1234), step)
        ks = jax.random.split(key, 2)
        if cfg.embeds_in:
            embeds = jax.random.normal(ks[0], (batch, seq, cfg.d_model))
            labels = jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab)
            yield lm.Batch(tokens=None, labels=labels, embeds=embeds)
        else:
            tokens = jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab)
            labels = jnp.concatenate(
                [tokens[:, 1:], tokens[:, :1]], axis=1)
            embeds = None
            if cfg.family == "vlm":
                embeds = jax.random.normal(
                    ks[1], (batch, cfg.n_image_tokens, cfg.d_model))
            yield lm.Batch(tokens=tokens, labels=labels, embeds=embeds)
        step += 1
