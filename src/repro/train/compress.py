"""Gradient compression for cross-pod all-reduce (DESIGN.md §5).

int8 block-quantized gradients with **error feedback** (the residual of
the quantization is carried to the next step, preserving convergence —
1-bit Adam / EF-SGD lineage). At 512+ chips the cross-pod data-parallel
all-reduce is the dominant collective for large dense models; int8 cuts
its payload 4x vs fp32 (2x vs bf16) at equal step-quality (error feedback
absorbs the quantization bias).

Usage (inside the train step, before the optimizer):

    grads_q, ef_state = compress_grads(grads, ef_state)
    # grads_q are int8+scale pytrees; all-reduce happens on these (under
    # pjit the mean over the data axis is expressed by the sharding of the
    # batch; for explicit-collective setups use psum on the quantized
    # payload), then:
    grads = decompress_grads(grads_q)
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
BLOCK = 256


class QGrad(NamedTuple):
    q: Array          # int8 quantized blocks
    scale: Array      # per-block fp32 scale


def _quantize(g: Array) -> tuple[QGrad, Array]:
    """Block-wise symmetric int8 quantization; returns (qgrad, error)."""
    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    err = (blocks - deq).reshape(-1)[:n].reshape(g.shape)
    return QGrad(q=q, scale=scale[:, 0]), err.astype(g.dtype)


def _dequantize(qg: QGrad, shape, dtype) -> Array:
    deq = qg.q.astype(jnp.float32) * qg.scale[:, None]
    n = 1
    for s in shape:
        n *= s
    return deq.reshape(-1)[:n].reshape(shape).astype(dtype)


def init_error_feedback(grads) -> dict:
    return jax.tree.map(jnp.zeros_like, grads)


def compress_grads(grads, ef_state):
    """-> (quantized pytree, new error-feedback state).

    The error from this round's quantization is added to next round's
    gradients before quantizing (error feedback).
    """
    corrected = jax.tree.map(lambda g, e: g + e, grads, ef_state)
    qs_and_errs = jax.tree.map(_quantize, corrected)
    qgrads = jax.tree.map(lambda t: t[0], qs_and_errs,
                          is_leaf=lambda x: isinstance(x, tuple)
                          and len(x) == 2 and isinstance(x[0], QGrad))
    new_ef = jax.tree.map(lambda t: t[1], qs_and_errs,
                          is_leaf=lambda x: isinstance(x, tuple)
                          and len(x) == 2 and isinstance(x[0], QGrad))
    return qgrads, new_ef


def decompress_grads(qgrads, like):
    return jax.tree.map(
        lambda qg, l: _dequantize(qg, l.shape, l.dtype), qgrads, like,
        is_leaf=lambda x: isinstance(x, QGrad))


def compression_ratio(grads) -> float:
    """Payload bytes ratio vs fp32 (int8 + per-block fp32 scales)."""
    def bytes_of(x):
        return x.size * x.dtype.itemsize

    raw = sum(x.size * 4 for x in jax.tree.leaves(grads))
    comp = sum(x.size * 1 + (x.size // BLOCK + 1) * 4
               for x in jax.tree.leaves(grads))
    return comp / raw
