"""Optimizers and LR schedules in pure JAX (no optax in this container).

Implements the pieces a production trainer needs:

* AdamW with decoupled weight decay, bias-corrected moments
* global-norm gradient clipping
* warmup + cosine / linear / constant schedules
* SGD-momentum (for baselines)

All state is a pytree of the same structure as params, so it shards with
the params' shardings (crucial: optimizer state inherits the logical-axis
sharding; no extra rules needed).
"""

from __future__ import annotations

import math
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = object


class AdamWState(NamedTuple):
    step: Array      # ()
    mu: PyTree       # first moment
    nu: PyTree       # second moment


class AdamW(NamedTuple):
    """AdamW config; behaves like optax's GradientTransformation."""
    lr: Callable[[Array], Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: float | None = 1.0

    def init(self, params: PyTree) -> AdamWState:
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                          nu=jax.tree.map(jnp.zeros_like, params))

    def update(self, grads: PyTree, state: AdamWState, params: PyTree
               ) -> tuple[PyTree, AdamWState]:
        step = state.step + 1
        if self.clip_norm is not None:
            grads = clip_by_global_norm(grads, self.clip_norm)
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                          state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self.lr(step) if callable(self.lr) else self.lr

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            u = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p
            return (-lr * u).astype(p.dtype)

        updates = jax.tree.map(upd, params, mu, nu)
        return updates, AdamWState(step=step, mu=mu, nu=nu)


class SGDState(NamedTuple):
    step: Array
    momentum: PyTree


class SGD(NamedTuple):
    lr: Callable[[Array], Array] | float = 1e-2
    momentum: float = 0.9
    clip_norm: float | None = None

    def init(self, params: PyTree) -> SGDState:
        return SGDState(step=jnp.zeros((), jnp.int32),
                        momentum=jax.tree.map(jnp.zeros_like, params))

    def update(self, grads: PyTree, state: SGDState, params: PyTree
               ) -> tuple[PyTree, SGDState]:
        step = state.step + 1
        if self.clip_norm is not None:
            grads = clip_by_global_norm(grads, self.clip_norm)
        mom = jax.tree.map(lambda m, g: self.momentum * m + g,
                           state.momentum, grads)
        lr = self.lr(step) if callable(self.lr) else self.lr
        updates = jax.tree.map(lambda p, m: (-lr * m).astype(p.dtype),
                               params, mom)
        return updates, SGDState(step=step, momentum=mom)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: p + u, params, updates)


def global_norm(tree: PyTree) -> Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads)


# ---------------------------------------------------------------------------
# Schedules: step (int32 array) -> lr
# ---------------------------------------------------------------------------

def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1) -> Callable[[Array], Array]:
    def sched(step: Array) -> Array:
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup_steps, 1)
        prog = jnp.clip((s - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 +
                                                     jnp.cos(math.pi * prog))
        return jnp.where(s < warmup_steps, warm, peak_lr * cos)
    return sched


def warmup_linear(peak_lr: float, warmup_steps: int, total_steps: int
                  ) -> Callable[[Array], Array]:
    def sched(step: Array) -> Array:
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup_steps, 1)
        prog = jnp.clip((s - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        return jnp.where(s < warmup_steps, warm, peak_lr * (1 - prog))
    return sched


def constant(lr: float) -> Callable[[Array], Array]:
    return lambda step: jnp.full((), lr, jnp.float32)
