"""Synthetic radar-like dataset (CRUW stand-in; see DESIGN.md §1).

The CRUW camera-radar dataset [34] is not available offline, so we generate
frames with matching geometry (128x128 range-azimuth maps) and the
statistics that matter for the paper's claims:

* background: speckle-like noise (Rayleigh magnitude, as in coherent radar)
  plus a range-dependent gain ramp;
* objects: localized Gaussian blobs (point-target responses smeared by the
  antenna pattern), with random intensity, anisotropic width, and azimuth
  sidelobe streaks;
* streams: objects follow linear tracks over time so that Fig. 6-style
  heatmaps show the horizontal/vertical-movement structure the paper plots.

Everything is generated with jax.random under explicit keys -> fully
reproducible and shardable (the LM pipelines reuse the same tokenizer-free
design: deterministic synthesis keyed by (epoch, shard, index)).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclass(frozen=True)
class RadarConfig:
    height: int = 128
    width: int = 128
    noise_sigma: float = 0.12       # Rayleigh scale of speckle background
    min_objects: int = 1
    max_objects: int = 3
    blob_sigma_lo: float = 2.0      # point-target response width (pixels)
    blob_sigma_hi: float = 6.0
    intensity_lo: float = 0.45
    intensity_hi: float = 1.0
    sidelobe_gain: float = 0.15     # azimuth streak amplitude
    range_ramp: float = 0.08        # range-dependent background gain


def _speckle(key: Array, cfg: RadarConfig) -> Array:
    """Rayleigh-magnitude background + range ramp."""
    k1, k2 = jax.random.split(key)
    re = jax.random.normal(k1, (cfg.height, cfg.width))
    im = jax.random.normal(k2, (cfg.height, cfg.width))
    mag = cfg.noise_sigma * jnp.sqrt(re * re + im * im)
    ramp = cfg.range_ramp * (1.0 - jnp.linspace(0, 1, cfg.height))[:, None]
    return mag + ramp


def _blob(cfg: RadarConfig, cy: Array, cx: Array, sy: Array, sx: Array,
          amp: Array) -> Array:
    yy = jnp.arange(cfg.height, dtype=jnp.float32)[:, None]
    xx = jnp.arange(cfg.width, dtype=jnp.float32)[None, :]
    g = jnp.exp(-(((yy - cy) / sy) ** 2 + ((xx - cx) / sx) ** 2) / 2.0)
    # azimuth sidelobe streak (radar antenna pattern artifact)
    streak = jnp.exp(-(((yy - cy) / sy) ** 2) / 2.0) * cfg.sidelobe_gain \
        * jnp.exp(-jnp.abs(xx - cx) / (6.0 * sx))
    return amp * (g + streak)


@partial(jax.jit, static_argnames=("cfg", "with_object"))
def render_frame(key: Array, cfg: RadarConfig, with_object: bool
                 ) -> tuple[Array, Array]:
    """One frame + object-position mask ``(H, W)`` (mask empty if negative).

    The mask marks blob centers (used for positive-fragment sampling).
    """
    kn, ko, kc = jax.random.split(key, 3)
    frame = _speckle(kn, cfg)
    mask = jnp.zeros((cfg.height, cfg.width), jnp.float32)
    if with_object:
        n_obj = cfg.max_objects
        keys = jax.random.split(ko, n_obj)
        active = (jnp.arange(n_obj)
                  < jax.random.randint(kc, (), cfg.min_objects, n_obj + 1))
        for i in range(n_obj):
            k1, k2, k3, k4, k5 = jax.random.split(keys[i], 5)
            cy = jax.random.uniform(k1, (), minval=8, maxval=cfg.height - 8)
            cx = jax.random.uniform(k2, (), minval=8, maxval=cfg.width - 8)
            sy = jax.random.uniform(k3, (), minval=cfg.blob_sigma_lo,
                                    maxval=cfg.blob_sigma_hi)
            sx = jax.random.uniform(k4, (), minval=cfg.blob_sigma_lo,
                                    maxval=cfg.blob_sigma_hi)
            amp = jax.random.uniform(k5, (), minval=cfg.intensity_lo,
                                     maxval=cfg.intensity_hi)
            on = active[i].astype(jnp.float32)
            frame = frame + on * _blob(cfg, cy, cx, sy, sx, amp)
            yy = jnp.arange(cfg.height)[:, None]
            xx = jnp.arange(cfg.width)[None, :]
            hit = ((jnp.abs(yy - cy) < 2 * sy) &
                   (jnp.abs(xx - cx) < 2 * sx)).astype(jnp.float32)
            mask = jnp.maximum(mask, on * hit)
    return jnp.clip(frame, 0.0, 1.5), mask


def make_dataset(key: Array, n_frames: int, cfg: RadarConfig | None = None,
                 p_object: float = 0.5
                 ) -> tuple[Array, Array, Array]:
    """Balanced frame dataset: ``(frames, masks, labels)``.

    labels[i] = 1 iff frame i contains at least one object.
    """
    cfg = cfg or RadarConfig()
    keys = jax.random.split(key, n_frames)
    labels = (jnp.arange(n_frames) < int(n_frames * p_object))
    labels = jax.random.permutation(jax.random.fold_in(key, 7), labels)
    pos = jax.vmap(lambda k: render_frame(k, cfg, True))(keys)
    neg = jax.vmap(lambda k: render_frame(k, cfg, False))(keys)
    sel = labels.astype(jnp.float32)[:, None, None]
    frames = sel * pos[0] + (1 - sel) * neg[0]
    masks = sel * pos[1]
    return frames, masks, labels.astype(jnp.int32)


def _event_tracks(key: Array, n_frames: int, cfg: RadarConfig,
                  event_prob: float, event_len: int, margin_y: int,
                  margin_x: int) -> tuple[np.ndarray, list]:
    """Shared event machinery: bursts of ``event_len`` frames on linear
    tracks. Returns ``(labels (N,), events [(start, len, cy, cx, vy, vx)])``.
    """
    # repro-lint: disable=RA002 (host-side scenario generator: the rng is derived from the jax key, so replay stays key-deterministic)
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0,
                                                       2**31 - 1)))
    labels = np.zeros(n_frames, dtype=np.int32)
    i = 0
    events = []
    while i < n_frames:
        if rng.random() < event_prob:
            length = min(event_len, n_frames - i)
            labels[i:i + length] = 1
            events.append((i, length,
                           rng.uniform(margin_y, cfg.height - margin_y),
                           rng.uniform(margin_x, cfg.width - margin_x),
                           rng.uniform(-3, 3), rng.uniform(-3, 3)))
            i += length
        else:
            i += 1
    return labels, events


def _paint_tracks(frames: np.ndarray, events: list, cfg: RadarConfig,
                  amps: np.ndarray) -> np.ndarray:
    """Add the tracked object blobs (amplitude per absolute frame index)."""
    for (start, length, cy, cx, vy, vx) in events:
        for t in range(length):
            fy = np.clip(cy + vy * t, 6, cfg.height - 6)
            fx = np.clip(cx + vx * t, 6, cfg.width - 6)
            blob = _blob(cfg, jnp.float32(fy), jnp.float32(fx),
                         jnp.float32(3.0), jnp.float32(3.0),
                         jnp.float32(amps[start + t]))
            frames[start + t] += np.asarray(blob)
    return frames


def make_stream(key: Array, n_frames: int, cfg: RadarConfig | None = None,
                event_prob: float = 0.05, event_len: int = 12
                ) -> tuple[Array, Array]:
    """Temporal stream with object *tracks* (for Fig-6 demos + control sim).

    Objects appear in bursts of ``event_len`` frames and move on a linear
    track — the regime where "activity of interest is infrequent".
    Returns ``(frames (N,H,W), labels (N,))``. numpy-side orchestration,
    jax-side rendering.
    """
    cfg = cfg or RadarConfig()
    labels, events = _event_tracks(key, n_frames, cfg, event_prob,
                                   event_len, 16, 16)
    base_keys = jax.random.split(key, n_frames)
    bg = jax.vmap(lambda k: _speckle(k, cfg))(base_keys)
    frames = np.asarray(bg).copy()
    frames = _paint_tracks(frames, events, cfg,
                           np.full(n_frames, 0.8, np.float32))
    return jnp.clip(jnp.asarray(frames), 0.0, 1.5), jnp.asarray(labels)


# ---------------------------------------------------------------------------
# Distribution drift (online-learning scenarios)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DriftConfig:
    """Linear distribution drift across a stream (start -> end values).

    The three drifts the always-on sensing literature worries about (and
    the adaptation benchmark exercises):

    * ``background_gain`` — an additive DC background offset ramping up
      (e.g. temperature-dependent sensor bias / changing ambient return);
    * ``noise_sigma`` — the speckle scale drifting (weather, RF
      interference);
    * ``object_intensity`` — object blob amplitude drifting (target
      distance / RCS shift).

    Each is ``(start, end)``, linearly interpolated over the stream.
    ``None`` spans inherit the un-drifted value (``cfg.noise_sigma``,
    :func:`make_stream`'s 0.8 blob amplitude), so the default config
    reproduces :func:`make_stream` statistics for *any* RadarConfig.
    """
    background_gain: tuple[float, float] = (0.0, 0.0)
    noise_sigma: tuple[float, float] | None = None
    object_intensity: tuple[float, float] | None = None


def _speckle_drift(key: Array, cfg: RadarConfig, sigma: Array,
                   gain: Array) -> Array:
    """Parametric speckle: traced noise scale + additive background gain."""
    k1, k2 = jax.random.split(key)
    re = jax.random.normal(k1, (cfg.height, cfg.width))
    im = jax.random.normal(k2, (cfg.height, cfg.width))
    mag = sigma * jnp.sqrt(re * re + im * im)
    ramp = cfg.range_ramp * (1.0 - jnp.linspace(0, 1, cfg.height))[:, None]
    return mag + ramp + gain


def drift_schedule(n_frames: int, span: tuple[float, float]) -> np.ndarray:
    """Per-frame linearly interpolated drift values ``(n_frames,)``."""
    return np.linspace(span[0], span[1], n_frames).astype(np.float32)


def make_drift_stream(key: Array, n_frames: int,
                      cfg: RadarConfig | None = None,
                      drift: DriftConfig | None = None,
                      event_prob: float = 0.05, event_len: int = 12
                      ) -> tuple[Array, Array]:
    """:func:`make_stream` under distribution drift (adaptation scenario).

    Same event/track structure as :func:`make_stream` (object bursts on
    linear tracks), but the background gain, speckle sigma, and object
    intensity follow the linear schedules in ``drift``. A model trained on
    the early (clean) statistics degrades toward the end of the stream —
    the regime the online-learning runners are built for.

    Returns ``(frames (N,H,W), labels (N,))``.
    """
    cfg = cfg or RadarConfig()
    drift = drift or DriftConfig()
    sigma_span = (drift.noise_sigma if drift.noise_sigma is not None
                  else (cfg.noise_sigma, cfg.noise_sigma))
    amp_span = (drift.object_intensity
                if drift.object_intensity is not None else (0.8, 0.8))
    # track-start margin: make_stream's 16 px, shrunk for small frames
    labels, events = _event_tracks(key, n_frames, cfg, event_prob,
                                   event_len, min(16, cfg.height // 3),
                                   min(16, cfg.width // 3))

    gains = jnp.asarray(drift_schedule(n_frames, drift.background_gain))
    sigmas = jnp.asarray(drift_schedule(n_frames, sigma_span))
    amps = drift_schedule(n_frames, amp_span)

    base_keys = jax.random.split(key, n_frames)
    bg = jax.vmap(lambda k, s, g: _speckle_drift(k, cfg, s, g))(
        base_keys, sigmas, gains)
    frames = _paint_tracks(np.asarray(bg).copy(), events, cfg, amps)
    return jnp.clip(jnp.asarray(frames), 0.0, 1.5), jnp.asarray(labels)
