"""ADC simulation: quantization + noise of the low/high-precision paths.

HyperSense's premise (paper §III-B, [29]): a low-precision ADC is orders of
magnitude cheaper, and HDC tolerates the resulting quantization noise. The
HDC gate therefore always sees ``quantize(frame, low_bits)``; the backend
sees the high-precision frame only when the gate fires.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

Array = jax.Array


@partial(jax.jit, static_argnames=("bits",))
def quantize(frame: Array, bits: int, v_max: float = 1.5) -> Array:
    """Uniform mid-rise quantization to ``bits`` bits over [0, v_max].

    Defined as ``quantize_codes(frame) * (v_max / levels)`` — the
    reconstruction is the integer code times the LSB step *by
    construction*, so the float path can never drift from what the
    integer near-sensor datapath computes (asserted exactly in
    ``tests/test_sensing.py``). Idempotent: requantizing an already
    quantized frame is the identity, which is what makes pre-quantized
    and internally-quantized streams produce identical stats.
    """
    levels = (1 << bits) - 1
    return quantize_codes(frame, bits, v_max) * jnp.float32(v_max / levels)


@partial(jax.jit, static_argnames=("bits",))
def quantize_codes(frame: Array, bits: int, v_max: float = 1.5) -> Array:
    """Integer ADC codes (what the near-sensor datapath actually consumes)."""
    levels = (1 << bits) - 1
    return jnp.round(jnp.clip(frame, 0.0, v_max) / v_max * levels
                     ).astype(jnp.int32)


def adc_noise(key: Array, frame: Array, thermal_sigma: float = 0.01) -> Array:
    """Additive thermal/reference noise ahead of the converter."""
    return frame + thermal_sigma * jax.random.normal(key, frame.shape)


def low_precision_view(key: Array, frame: Array, bits: int = 4,
                       thermal_sigma: float = 0.01) -> Array:
    """The always-on sensing path: noisy low-precision capture."""
    return quantize(adc_noise(key, frame, thermal_sigma), bits)
