"""ADC simulation: quantization + noise of the low/high-precision paths.

HyperSense's premise (paper §III-B, [29]): a low-precision ADC is orders of
magnitude cheaper, and HDC tolerates the resulting quantization noise. The
HDC gate therefore always sees ``quantize(frame, low_bits)``; the backend
sees the high-precision frame only when the gate fires.

Two representations of the same capture:

* ``quantize``       — the float *reconstruction* ``codes * LSB`` the
  float32 datapath consumes;
* ``quantize_codes`` (+ :func:`pack_codes`) — the raw integer ADC codes the
  integer precisions consume untouched (the paper's FPGA front-end never
  materializes floats; see ``repro.kernels.sliding_scores_int``). The
  ``"int4"`` precision additionally rides the two-codes-per-byte wire
  format (:func:`pack_nibbles` / :func:`unpack_nibbles`).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

#: full-scale voltage of the simulated converter (shared by both paths)
V_MAX = 1.5

#: the datapath precisions of the scoring hot path. "float32" consumes ADC
#: reconstructions; the rest consume raw ADC codes (int32 accumulation,
#: float only at the similarity epilogue — the rolling-shift kernel in
#: ``repro.kernels.sliding_scores_int``): "int8" quantizes slabs/classes to
#: int8, "int4" additionally packs two 4-bit codes per wire byte (unpacked
#: in-kernel, adc_bits <= 4), "binary" sign-quantizes slabs and class HVs
#: to ±1 (XOR-popcount-style similarity as int8 matmuls, reduced-D
#: operating points)
PRECISIONS = ("float32", "int8", "int4", "binary")

#: the precisions that run the integer-code datapath (everything except
#: the float reconstruction path)
INT_PRECISIONS = ("int8", "int4", "binary")


def lsb(bits: int, v_max: float = V_MAX) -> float:
    """The quantization step: ``reconstruction = codes * lsb(bits)``."""
    return v_max / ((1 << bits) - 1)


def codes_dtype(bits: int):
    """Narrowest jnp dtype that holds every ``bits``-bit ADC code.

    ``uint8`` up to 8 bits, ``uint16`` up to 16 (the high-precision burst
    depths — widening those to int32 would quadruple the wire traffic the
    memory-bandwidth claim is about), ``int32`` beyond.
    """
    if bits <= 8:
        return jnp.uint8
    return jnp.uint16 if bits <= 16 else jnp.int32


@partial(jax.jit, static_argnames=("bits",))
def quantize(frame: Array, bits: int, v_max: float = V_MAX) -> Array:
    """Uniform mid-rise quantization to ``bits`` bits over [0, v_max].

    Defined as ``quantize_codes(frame) * (v_max / levels)`` — the
    reconstruction is the integer code times the LSB step *by
    construction*, so the float path can never drift from what the
    integer near-sensor datapath computes (asserted exactly in
    ``tests/test_sensing.py``). Idempotent: requantizing an already
    quantized frame is the identity, which is what makes pre-quantized
    and internally-quantized streams produce identical stats.
    """
    levels = (1 << bits) - 1
    return quantize_codes(frame, bits, v_max) * jnp.float32(v_max / levels)


@partial(jax.jit, static_argnames=("bits",))
def quantize_codes(frame: Array, bits: int, v_max: float = V_MAX) -> Array:
    """Integer ADC codes (what the near-sensor datapath actually consumes)."""
    levels = (1 << bits) - 1
    return jnp.round(jnp.clip(frame, 0.0, v_max) / v_max * levels
                     ).astype(jnp.int32)


@partial(jax.jit, static_argnames=("bits",))
def pack_codes(codes: Array, bits: int) -> Array:
    """Narrow ``int32`` codes to the wire dtype (:func:`codes_dtype`).

    The int8 datapath stores and streams codes at 1 byte/sample — the 4x
    memory-traffic reduction the low-precision claim is about — and the
    9-16-bit high-precision bursts ride ``uint16`` (2 bytes, 2x).
    Lossless (codes of a ``bits``-bit converter always fit; see
    :func:`unpack_codes` for the exact inverse).
    """
    return codes.astype(codes_dtype(bits))


def unpack_codes(packed: Array) -> Array:
    """Widen packed codes back to ``int32`` (exact inverse of ``pack``)."""
    return packed.astype(jnp.int32)


@jax.jit
def pack_nibbles(codes: Array) -> Array:
    """``(..., W)`` 4-bit codes -> ``(..., W/2)`` uint8, two per byte.

    The ``precision="int4"`` wire format: adjacent row pairs share a byte
    (low nibble first), halving code memory traffic below what
    :func:`pack_codes` reaches. Codes must already be 4-bit
    (:func:`check_codes_range` guards the entry points) and the row width
    even. The kernel unpacks nibbles in-place
    (``sliding_scores_int._unpack_nibbles_i32`` — parity pinned in
    ``tests/test_adc_quantize.py``); :func:`unpack_nibbles` is the host
    inverse.
    """
    if codes.shape[-1] % 2:
        raise ValueError(
            f"int4 nibble packing needs an even row width, got "
            f"{codes.shape[-1]} — pad or crop the frame")
    c = codes.astype(jnp.uint8)
    return c[..., 0::2] | (c[..., 1::2] << 4)


@jax.jit
def unpack_nibbles(packed: Array) -> Array:
    """``(..., W/2)`` packed bytes -> ``(..., W)`` int32 (exact inverse)."""
    p = packed.astype(jnp.int32)
    lo = p & 0xF
    hi = p >> 4
    return jnp.concatenate([lo[..., None], hi[..., None]],
                           axis=-1).reshape(*p.shape[:-1], -1)


def check_codes_range(codes: Array, bits: int) -> None:
    """Reject codes outside ``[0, 2^bits - 1]`` (concrete values only).

    Packing such codes would silently wrap modulo 256 and the int32
    overflow bounds would be checked against the wrong depth — every
    entry point that accepts pre-converted integer codes calls this
    before trusting them. This sits on the streaming hot path, so the
    min and max are fused into ONE device reduction fetched with a
    single device->host sync (not two blocking ``int()`` pulls). A
    no-op on empty arrays and under tracing (shapes-only contexts).
    """
    if codes.size == 0:
        return
    extrema = jnp.stack([jnp.min(codes), jnp.max(codes)])
    try:
        # repro-lint: disable=RA003 (deliberate: ONE fused extrema fetch, not two blocking int() pulls; tracing falls through to the except)
        lo, hi = (int(v) for v in np.asarray(extrema))
    except jax.errors.TracerArrayConversionError:
        return
    if lo < 0 or hi > (1 << bits) - 1:
        raise ValueError(
            f"integer input holds codes in [{lo}, {hi}], outside the "
            f"{bits}-bit range [0, {(1 << bits) - 1}] — the pack would "
            f"silently wrap; requantize (or pass the matching adc_bits)")


@jax.jit
def quantize_codes_per_frame(frames: Array, bits: Array,
                             v_max: float = V_MAX) -> Array:
    """Variable-depth conversion: frame ``i``'s codes at ``bits[i]`` bits.

    The closed-loop capture primitive: one batch can mix idle
    low-precision frames, high-precision burst frames, and skipped frames
    (``bits[i] == 0`` → the converter never ran → all-zero codes).
    ``bits`` is traced — the per-frame depth is runtime data decided by
    the controller, not a static compile-time constant.
    """
    frames = jnp.asarray(frames)
    bits = jnp.asarray(bits, jnp.int32)
    levels = (jnp.left_shift(1, bits) - 1).reshape(
        bits.shape + (1,) * (frames.ndim - bits.ndim)).astype(jnp.float32)
    codes = jnp.round(jnp.clip(frames, 0.0, v_max) / v_max
                      * jnp.maximum(levels, 1.0))
    return jnp.where(levels > 0, codes, 0.0).astype(jnp.int32)


@jax.jit
def quantize_per_frame(frames: Array, bits: Array,
                       v_max: float = V_MAX) -> Array:
    """Reconstruction twin of :func:`quantize_codes_per_frame`:
    ``codes * per-frame LSB`` (skipped frames, ``bits == 0``, are zeros).
    At a uniform depth ``b`` this matches ``quantize(frames, b)``."""
    bits = jnp.asarray(bits, jnp.int32)
    levels = (jnp.left_shift(1, bits) - 1).reshape(
        bits.shape + (1,) * (frames.ndim - bits.ndim)).astype(jnp.float32)
    codes = quantize_codes_per_frame(frames, bits, v_max)
    return jnp.where(
        levels > 0,
        codes.astype(jnp.float32) * (jnp.float32(v_max)
                                     / jnp.maximum(levels, 1.0)),
        0.0)


def adc_noise(key: Array, frame: Array, thermal_sigma: float = 0.01) -> Array:
    """Additive thermal/reference noise ahead of the converter."""
    return frame + thermal_sigma * jax.random.normal(key, frame.shape)


def low_precision_view(key: Array, frame: Array, bits: int = 4,
                       thermal_sigma: float = 0.01) -> Array:
    """The always-on sensing path: noisy low-precision capture."""
    return quantize(adc_noise(key, frame, thermal_sigma), bits)
