"""Batched streaming runtime: chunked scoring + gating (one jit per chunk).

The paper's sensing loop (§III-B/C) scores *every* incoming frame with the
HDC HyperSense model and gates the expensive high-precision path in real
time. ``repro.core.sensor_control.simulate_stream`` does that one frame per
call — one kernel launch (or one jnp dispatch) per frame. This module is
the throughput path: frames are consumed in fixed-size chunks and each
chunk runs

  batched fragment scoring  ->  frame_detection_score  ->  threshold
  ->  SensorController hysteresis (as a ``lax.scan``)

inside a single jitted step. On the ``pallas`` backend the whole chunk is
ONE kernel launch (grid ``(N, my, n_dt)``) against one per-model
:class:`~repro.kernels.sliding_scores.ScoreTiles` precompute.

:func:`gate_scan` is the exact jnp twin of
:class:`~repro.core.sensor_control.SensorController`; the carried ``hold``
state crosses chunk boundaries, so chunking is invisible:
:func:`simulate_stream_batched` returns :class:`StreamStats` identical to
the frame-at-a-time ``simulate_stream``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hypersense
from repro.core.hypersense import HyperSenseModel, frame_detection_score
from repro.core.sensor_control import (ControllerConfig, StreamStats,
                                       stats_from)
from repro.sensing import adc as adc_sim

Array = jax.Array


def adc_view(frames: Array, bits: int, *, sigma: float = 0.0,
             key: Array | None = None, start_index: int = 0) -> Array:
    """Low-precision ADC capture of ``(N, H, W)`` frames (paper Fig. 3).

    Thermal noise (``sigma > 0``) is keyed by *absolute frame index*
    (``start_index + i``), not by call count — re-slicing a stream into
    different ``process()`` calls yields bit-identical captures, which is
    what keeps the runners' slicing-invariance property intact with the
    ADC in the loop.
    """
    frames = jnp.asarray(frames)
    if sigma > 0.0:
        if key is None:
            raise ValueError("adc noise (sigma > 0) requires a PRNG key")
        idx = jnp.arange(frames.shape[0]) + start_index
        keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(idx)
        frames = jax.vmap(
            lambda k, f: adc_sim.adc_noise(k, f, sigma))(keys, frames)
    return adc_sim.quantize(frames, bits)


def gate_scan(decisions: Array, hold_frames: int,
              init_hold: Array | int = 0) -> tuple[Array, Array]:
    """Jittable ``SensorController``: ``(gated (N,) bool, holds (N,) i32)``.

    ``holds[i]`` is the controller state *after* frame ``i`` — feed
    ``holds[last_real_frame]`` back as ``init_hold`` of the next chunk.
    """
    def step(hold, fired):
        gated = fired | (hold > 0)
        hold = jnp.where(fired, hold_frames, jnp.maximum(hold - 1, 0))
        return hold, (gated, hold)

    _, (gated, holds) = jax.lax.scan(
        step, jnp.asarray(init_hold, jnp.int32), decisions.astype(bool))
    return gated, holds


def super_chunk_fn(frames, class_hvs, B0, b, tiles, t_score, holds,
                   n_valid, *, h, w, stride, nonlinearity, t_detection,
                   hold_frames, backend):
    """One streaming step over an ``(S, C, H, W)`` super-chunk.

    The shared core of both runners: ``StreamRunner`` calls it with
    ``S = 1``, :class:`~repro.sensing.fleet.FleetRunner` with S concurrent
    streams. The ``S*C`` axis is flattened into the batched scorer (one
    kernel launch on the ``pallas`` backend) and each stream's gate is a
    ``vmap``'d :func:`gate_scan` — the batch axis is parallel everywhere,
    so a fleet step is exactly S independent stream steps.

    ``n_valid`` masks a padded tail chunk; pad frames never fire, and the
    carried ``(S,)`` hold state is read at the last *valid* frame.
    """
    S, C, H, W = frames.shape
    my = (H - h) // stride + 1
    mx = (W - w) // stride + 1

    if backend == "pallas":
        from repro.kernels import ops as kops
        maps = kops.fragment_score_map_fleet(
            frames, class_hvs, B0, b, h=h, w=w, stride=stride,
            nonlinearity=nonlinearity, tiles=tiles)          # (S, C, my, mx)
    else:
        maps = jax.vmap(lambda f: hypersense.fragment_score_map(
            f, class_hvs, B0, b, h=h, w=w, stride=stride,
            nonlinearity=nonlinearity, backend=backend))(
                frames.reshape(S * C, H, W)).reshape(S, C, my, mx)

    scores = jax.vmap(jax.vmap(
        lambda m: frame_detection_score(m, t_detection)))(maps)  # (S, C)

    # count(s_i > t) > T  <=>  (T+1)-th largest > t, provided T < my*mx;
    # with T >= my*mx the count can never exceed T -> never fires.
    valid = jnp.arange(C) < n_valid
    if t_detection >= my * mx:
        fired = jnp.zeros((S, C), bool)
    else:
        fired = (scores > t_score) & valid[None, :]

    gated, holds_seq = jax.vmap(
        lambda f, h0: gate_scan(f, hold_frames, h0))(fired, holds)
    hold_out = jnp.where(n_valid > 0,
                         holds_seq[:, jnp.maximum(n_valid - 1, 0)], holds)
    return scores, fired, gated, hold_out


#: module-level jit: every runner instance shares one trace cache.
super_chunk_step = jax.jit(
    super_chunk_fn, static_argnames=("h", "w", "stride", "nonlinearity",
                                     "t_detection", "hold_frames",
                                     "backend"))


def model_tiles(model: HyperSenseModel, W: int, block_d: int):
    """ScoreTiles precompute for ``model`` on width-``W`` frames."""
    from repro.kernels import ops as kops
    return kops.precompute_tiles(model.B0, model.b, model.class_hvs, W=W,
                                 w=model.w, stride=model.stride,
                                 block_d=block_d)


class StreamRunner:
    """Stateful chunked scorer+gate. ``process(frames)`` any number of times.

    The controller ``hold`` state carries across ``process`` calls, so a
    long stream can be fed incrementally in arbitrary slices; every
    internal step is one fixed-shape jit call (tail chunks are padded and
    masked, so no recompiles).
    """

    def __init__(self, model: HyperSenseModel,
                 config: ControllerConfig | None = None, *,
                 chunk_size: int = 32, backend: str = "jnp",
                 t_detection: int | None = None, block_d: int = 512,
                 adc_bits: int | None = None, adc_sigma: float = 0.0,
                 adc_key: Array | int = 0):
        if chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
        if adc_sigma > 0.0 and adc_bits is None:
            raise ValueError("adc_sigma > 0 without adc_bits: the ADC is "
                             "only in the loop when adc_bits is set")
        self.model = model
        self.config = config or ControllerConfig()
        self.chunk_size = chunk_size
        self.backend = backend
        self.block_d = block_d
        self.t_detection = (model.t_detection if t_detection is None
                            else t_detection)
        self.adc_bits = adc_bits
        self.adc_sigma = adc_sigma
        self._adc_key = (jax.random.PRNGKey(adc_key)
                         if isinstance(adc_key, int) else adc_key)
        self._tiles = None      # (W, ScoreTiles) — keyed on frame width
        self._hold = jnp.zeros((), jnp.int32)
        self._n_seen = 0        # absolute frame index (keys the ADC noise)

    def reset(self) -> None:
        self._hold = jnp.zeros((), jnp.int32)
        self._n_seen = 0

    def _ensure_tiles(self, W: int):
        if self.backend != "pallas":
            return None
        if self._tiles is None or self._tiles[0] != W:
            self._tiles = (W, model_tiles(self.model, W, self.block_d))
        return self._tiles[1]

    def process(self, frames) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(n, H, W) frames -> (scores (n,), fired (n,), gated (n,)).

        With ``adc_bits`` set, the scorer sees the low-precision ADC
        capture of each frame (:func:`adc_view`) — the paper's always-on
        path — while the caller keeps the raw high-precision frames for
        whatever the gate lets through.
        """
        frames = jnp.asarray(frames)
        if self.adc_bits is not None:
            frames = adc_view(frames, self.adc_bits, sigma=self.adc_sigma,
                              key=self._adc_key, start_index=self._n_seen)
        n = frames.shape[0]
        self._n_seen += n
        m = self.model
        tiles = self._ensure_tiles(frames.shape[-1])
        scores = np.empty(n, np.float32)
        fired = np.empty(n, bool)
        gated = np.empty(n, bool)
        for start in range(0, n, self.chunk_size):
            chunk = frames[start:start + self.chunk_size]
            n_valid = chunk.shape[0]
            if n_valid < self.chunk_size:
                pad = self.chunk_size - n_valid
                chunk = jnp.pad(chunk, ((0, pad), (0, 0), (0, 0)))
            s, f, g, hold_out = super_chunk_step(
                chunk[None], m.class_hvs, m.B0, m.b, tiles,
                jnp.float32(m.t_score), self._hold[None],
                jnp.int32(n_valid), h=m.h, w=m.w, stride=m.stride,
                nonlinearity=m.nonlinearity, t_detection=self.t_detection,
                hold_frames=self.config.hold_frames, backend=self.backend)
            self._hold = hold_out[0]
            sl = slice(start, start + n_valid)
            scores[sl] = np.asarray(s)[0, :n_valid]
            fired[sl] = np.asarray(f)[0, :n_valid]
            gated[sl] = np.asarray(g)[0, :n_valid]
        return scores, fired, gated


def simulate_stream_batched(model: HyperSenseModel, frames, labels,
                            config: ControllerConfig | None = None, *,
                            chunk_size: int = 32, backend: str = "jnp",
                            t_detection: int | None = None,
                            block_d: int = 512,
                            adc_bits: int | None = None,
                            adc_sigma: float = 0.0,
                            adc_key: Array | int = 0) -> StreamStats:
    """Chunked-batched twin of ``sensor_control.simulate_stream``.

    Produces identical :class:`StreamStats` to replaying
    ``hypersense.detect`` frame-at-a-time through ``SensorController``,
    but runs ``len(frames)/chunk_size`` jitted steps instead of
    ``len(frames)`` dispatches (one kernel launch per chunk on the
    ``pallas`` backend). ``adc_bits`` puts the simulated low-precision
    ADC in front of the gate (pass raw frames).
    """
    runner = StreamRunner(model, config, chunk_size=chunk_size,
                          backend=backend, t_detection=t_detection,
                          block_d=block_d, adc_bits=adc_bits,
                          adc_sigma=adc_sigma, adc_key=adc_key)
    _, fired, gated = runner.process(frames)
    return stats_from(fired, gated, labels)
