"""Batched streaming runtime: chunked scoring + gating + online learning.

The paper's sensing loop (§III-B/C) scores *every* incoming frame with the
HDC HyperSense model and gates the expensive high-precision path in real
time. ``repro.core.sensor_control.simulate_stream`` does that one frame per
call — one kernel launch (or one jnp dispatch) per frame. This module is
the throughput path: frames are consumed in fixed-size chunks and each
chunk runs

  batched fragment scoring  ->  frame_detection_score  ->  threshold
  ->  SensorController hysteresis (as a ``lax.scan``)
  ->  (optionally) an online classifier update

inside a single jitted step. On the ``pallas`` backend the whole chunk is
ONE kernel launch (grid ``(N, my, n_dt)``).

**Mutable model state.** The model is no longer frozen at construction:
every chunk threads a :class:`StreamState` pytree — class hypervectors,
per-stream gate holds, absolute frame index — through
:func:`super_chunk_fn`. With ``adapt=None`` the class hypervectors simply
pass through unchanged and the step is the frozen scorer (bitwise
identical to the pre-online-learning runtime on the ``pallas`` backend).
With an :class:`~repro.core.online.AdaptConfig` the step also

  1. extracts each frame's *top-scoring fragment*, re-encodes it (an
     ``O(h*w*D)`` matmul per frame — tiny next to scoring), and
  2. folds those sample hypervectors through the similarity-scaled
     perceptron rule (``repro.core.online``) — supervised label feedback
     or confidence-gated pseudo-labels — producing the next chunk's
     classifier.

On the ``pallas`` backend the adaptive step holds only the class-agnostic
:class:`~repro.kernels.sliding_scores.ScoreGeometry`; the fresh classifier
is installed by the jitted, device-side ``retile_classes`` (one gather per
class) — no host-side ``precompute_tiles`` ever runs mid-stream.

Within a chunk, scoring uses the chunk-start classifier while the update
folds the chunk's samples sequentially (exactly ``retrain_epoch`` over the
extracted sample sequence); ``chunk_size=1`` recovers pure per-frame
online learning.

:func:`gate_scan` is the exact jnp twin of
:class:`~repro.core.sensor_control.SensorController`; the carried ``hold``
state crosses chunk boundaries, so chunking is invisible:
:func:`simulate_stream_batched` returns :class:`StreamStats` identical to
the frame-at-a-time ``simulate_stream``.

**Closed capture loop.** With ``control=``
(:class:`~repro.core.sensor_control.CaptureConfig`) the gate drives the
ADC itself: :func:`control_scan` (the jnp twin of
:class:`~repro.core.sensor_control.RateController`) carries a per-stream
``(hold, phase)`` state so the decision at frame ``t`` decides whether
frame ``t+1`` is converted at all — idle trickle at ``base_rate_hz`` /
``adc_bits``, gated bursts at ``active_rate_hz`` with high-precision
frames gathered into a bounded buffer (:func:`hp_capture`,
``runner.drain_hp()``). Every runner keeps a
:class:`~repro.core.sensor_control.CaptureLog`;
:func:`repro.core.energy.from_capture_log` bills from it directly.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hypersense, online
from repro.core.encoding import encode_fragments, flat_perm_base
from repro.core.hypersense import HyperSenseModel, frame_detection_score
from repro.core.online import AdaptConfig
from repro.core.sensor_control import (CaptureConfig, CaptureLog,
                                       ControllerConfig, StreamStats,
                                       assemble_capture_log, decimation,
                                       stats_from)
from repro.sensing import adc as adc_sim

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StreamState:
    """Mutable stream state threaded through every chunk step.

    ``class_hvs`` is ``(2, D)`` for a single stream / fleet-shared
    classifier, or ``(S, 2, D)`` when a fleet adapts per-stream models.
    ``holds`` is the ``(S,)`` controller hysteresis state; ``phases`` the
    ``(S,)`` closed-loop ADC state (frames until the next idle
    low-precision sample — identically zero in open-loop mode);
    ``frame_idx`` the absolute index of the next frame (i32 scalar).
    """
    class_hvs: Array
    holds: Array
    phases: Array
    frame_idx: Array


def init_stream_state(class_hvs: Array, n_streams: int,
                      per_stream: bool = False) -> StreamState:
    """Fresh state: model's classifier, zero holds/phases, frame 0."""
    chvs = jnp.asarray(class_hvs)
    if per_stream and chvs.ndim == 2:
        chvs = jnp.broadcast_to(chvs, (n_streams, *chvs.shape))
    return StreamState(class_hvs=chvs,
                       holds=jnp.zeros((n_streams,), jnp.int32),
                       phases=jnp.zeros((n_streams,), jnp.int32),
                       frame_idx=jnp.zeros((), jnp.int32))


def validate_runner_args(chunk_size: int, adc_bits: int | None,
                         adc_sigma: float, precision: str) -> None:
    """Shared constructor validation for every streaming front-end.

    ``StreamRunner``, :class:`~repro.sensing.fleet.FleetRunner` and
    :class:`~repro.launch.serve.FleetService` all accept the same
    (chunk, ADC, precision) surface; this is the ONE place its
    consistency rules live, so the three cannot drift apart.
    """
    if chunk_size < 1:
        raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
    if adc_sigma > 0.0 and adc_bits is None:
        raise ValueError("adc_sigma > 0 without adc_bits: the ADC is "
                         "only in the loop when adc_bits is set")
    if precision not in adc_sim.PRECISIONS:
        raise ValueError(f"precision must be one of "
                         f"{adc_sim.PRECISIONS}, got {precision!r}")
    if precision in adc_sim.INT_PRECISIONS and adc_bits is None:
        raise ValueError(f'precision="{precision}" consumes ADC codes: '
                         "set adc_bits (the simulated converter's depth)")
    if precision == "int4" and adc_bits is not None and adc_bits > 4:
        raise ValueError(f'precision="int4" packs two codes per byte, '
                         f"so adc_bits must be <= 4 (got {adc_bits})")


def adc_view(frames: Array, bits: int, *, sigma: float = 0.0,
             key: Array | None = None, start_index: int = 0) -> Array:
    """Low-precision ADC capture of ``(N, H, W)`` frames (paper Fig. 3).

    Thermal noise (``sigma > 0``) is keyed by *absolute frame index*
    (``start_index + i``), not by call count — re-slicing a stream into
    different ``process()`` calls yields bit-identical captures, which is
    what keeps the runners' slicing-invariance property intact with the
    ADC in the loop.
    """
    return adc_sim.quantize(
        _noisy_capture(frames, sigma, key, start_index), bits)


def _noisy_capture(frames: Array, sigma: float, key: Array | None,
                   start_index: int) -> Array:
    """Pre-conversion thermal noise, keyed by absolute frame index.

    The ONE implementation both ADC views share — the float and codes
    captures are the same converter by construction, so their noise
    keying can never drift apart.
    """
    frames = jnp.asarray(frames)
    if sigma <= 0.0:
        return frames
    if key is None:
        raise ValueError("adc noise (sigma > 0) requires a PRNG key")
    idx = jnp.arange(frames.shape[0]) + start_index
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(idx)
    return jax.vmap(
        lambda k, f: adc_sim.adc_noise(k, f, sigma))(keys, frames)


def adc_view_codes(frames: Array, bits: int, *, sigma: float = 0.0,
                   key: Array | None = None, start_index: int = 0) -> Array:
    """Raw integer ADC codes of ``(N, H, W)`` frames (the int datapath).

    The codes twin of :func:`adc_view` — same capture (identical noise
    keying by absolute frame index, identical quantizer), but the output
    is the packed integer codes the fused int kernel consumes directly,
    never the float reconstruction. Integer input is treated as
    already-converted codes and only (re)packed — feeding a code stream
    back through is the identity, mirroring ``quantize`` idempotence.
    Codes outside ``[0, 2^bits - 1]`` are rejected (when the values are
    concrete) rather than silently wrapped by the pack.
    """
    frames = jnp.asarray(frames)
    if jnp.issubdtype(frames.dtype, jnp.integer):
        if sigma > 0.0:
            raise ValueError("adc noise applies before conversion; input "
                             "is already integer ADC codes")
        adc_sim.check_codes_range(frames, bits)
        return adc_sim.pack_codes(frames.astype(jnp.int32), bits)
    frames = _noisy_capture(frames, sigma, key, start_index)
    return adc_sim.pack_codes(adc_sim.quantize_codes(frames, bits), bits)


def gate_scan(decisions: Array, hold_frames: int,
              init_hold: Array | int = 0) -> tuple[Array, Array]:
    """Jittable ``SensorController``: ``(gated (N,) bool, holds (N,) i32)``.

    ``holds[i]`` is the controller state *after* frame ``i`` — feed
    ``holds[last_real_frame]`` back as ``init_hold`` of the next chunk.
    """
    def step(hold, fired):
        gated = fired | (hold > 0)
        hold = jnp.where(fired, hold_frames, jnp.maximum(hold - 1, 0))
        return hold, (gated, hold)

    _, (gated, holds) = jax.lax.scan(
        step, jnp.asarray(init_hold, jnp.int32), decisions.astype(bool))
    return gated, holds


def control_scan(decisions: Array, hold_frames: int, decim: int,
                 init_hold: Array | int = 0, init_phase: Array | int = 0
                 ) -> tuple[Array, Array, Array, Array]:
    """Jittable :class:`~repro.core.sensor_control.RateController`:
    ``(sampled, gated, holds, phases)``, each ``(N,)``.

    The closed-loop twin of :func:`gate_scan`: the carried ``(hold,
    phase)`` pair decides per frame whether the LP ADC converts it at
    all — a skipped frame's decision input is masked out (the HDC never
    saw it), which is how the gate decision at frame ``t`` modulates
    capture at ``t+1`` *inside* one scan. ``holds[i]``/``phases[i]`` are
    the state after frame ``i``; feed the last valid frame's values back
    as the next chunk's ``init_*``. With ``decim == 1`` the phase is
    identically 0, every frame is sampled, and ``gated``/``holds`` are
    bitwise :func:`gate_scan`'s.
    """
    def step(carry, f):
        hold, phase = carry
        sampled = (phase == 0) | (hold > 0)
        fired = f & sampled
        gated = fired | (hold > 0)
        hold = jnp.where(fired, hold_frames, jnp.maximum(hold - 1, 0))
        phase = jnp.where(sampled, decim - 1, phase - 1)
        return (hold, phase), (sampled, gated, hold, phase)

    init = (jnp.asarray(init_hold, jnp.int32),
            jnp.asarray(init_phase, jnp.int32))
    _, (sampled, gated, holds, phases) = jax.lax.scan(
        step, init, decisions.astype(bool))
    return sampled, gated, holds, phases


@functools.partial(jax.jit, static_argnames=("k", "bits"))
def hp_capture(raw: Array, gated: Array, n_valid: Array, k: int, bits: int
               ) -> tuple[Array, Array, Array]:
    """Bounded gather buffer: the first ``k`` gated frames of a chunk,
    captured at the high-precision depth — the closed loop's deliverable.

    ``raw`` is the ``(C, H, W)`` *raw* (pre-LP-conversion) chunk; returns
    ``(buf (k, H, W) float32, idx (k,) i32, count i32)`` where ``idx[j]``
    is the in-chunk frame index materialized in slot ``j`` (``-1`` =
    empty slot) and ``count`` is the total gated frames — ``count > k``
    means the buffer overflowed and ``count - k`` burst frames were
    dropped (the runners surface this as ``hp_dropped``). Fixed shapes
    keep the step a single jit trace for every gate outcome.
    """
    C = raw.shape[0]
    pos = jnp.arange(C)
    take = gated.astype(bool) & (pos < n_valid)
    rank = jnp.cumsum(take) - 1                    # 0-based among taken
    slot = jnp.where(take & (rank < k), rank, k)   # k = spill slot
    q = adc_sim.quantize_per_frame(raw, jnp.where(take, bits, 0))
    buf = jnp.zeros((k + 1, *raw.shape[1:]), jnp.float32).at[slot].set(q)
    idx = jnp.full((k + 1,), -1, jnp.int32).at[slot].set(pos)
    return buf[:k], idx[:k], take.sum()


def resolve_hp_buffer(control: CaptureConfig | None, chunk_size: int,
                      frames_dtype) -> int:
    """Per-chunk HP buffer size for a runner (0 = no materialization).

    The ONE place both runners resolve ``CaptureConfig.hp_buffer``
    (``None`` → ``chunk_size``) and reject integer-code input, which has
    no raw frames to HP-capture from.
    """
    if control is None:
        return 0
    k = chunk_size if control.hp_buffer is None else control.hp_buffer
    if k > 0 and jnp.issubdtype(frames_dtype, jnp.integer):
        raise ValueError(
            "high-precision materialization needs the raw frames; the "
            "input is already low-precision ADC codes — pass "
            "control=CaptureConfig(hp_buffer=0) to run the closed loop "
            "log-only")
    return k


def collect_hp(raw_chunk: Array, gated: Array, n_valid: int, k: int,
               bits: int, base) -> tuple[list[list], int]:
    """Drain one chunk's bounded HP buffers to host land.

    ``raw_chunk`` is ``(S, C, H, W)`` (padded to the chunk size), ``gated``
    the step's ``(S, C)`` gate output. ``base`` offsets the in-chunk frame
    positions to absolute stream indices — a scalar when every stream sits
    at the same absolute frame (the runners), or an ``(S,)`` vector when
    streams run out of phase (the serving layer's ragged slots). Returns
    (one ``[(absolute_frame_idx, hp_frame), ...]`` list per stream — in
    frame order — and the number of burst frames dropped to full
    buffers); shared by every front-end so the drop accounting can never
    diverge.
    """
    buf, idx, cnt = jax.vmap(
        lambda r, gt: hp_capture(r, gt, jnp.int32(n_valid), k, bits))(
            raw_chunk, gated)
    idx, buf = np.asarray(idx), np.asarray(buf)
    base = np.broadcast_to(np.asarray(base, np.int64), (idx.shape[0],))
    out, dropped = [], 0
    for si in range(idx.shape[0]):
        kept = idx[si] >= 0
        out.append(list(zip((base[si] + idx[si][kept]).tolist(),
                            buf[si][kept])))
        dropped += max(int(cnt[si]) - int(kept.sum()), 0)
    return out, dropped


def hp_drain_arrays(entries, frame_hw: tuple[int, int] | None
                    ) -> tuple[np.ndarray, np.ndarray]:
    """One stream's ``[(abs_idx, frame), ...]`` buffer → drain arrays.

    The drain-shape contract every front-end shares: ``(indices (M,)
    int64, frames (M, H, W) float32)`` — an EMPTY drain still carries
    the real frame shape ``(0, H, W)`` (float32 is the ``hp_bits``
    dtype: :func:`hp_capture` materializes bursts as float32
    reconstructions at ``control.hp_bits``), so a consumer can
    ``np.concatenate`` drains across ticks unconditionally — exactly
    what the gated cascade does. Only before any frame has fixed the
    shape (``frame_hw=None``) is the degenerate ``(0, 0, 0)`` returned.
    """
    idx = np.asarray([i for i, _ in entries], np.int64)
    if entries:
        frames = np.stack([np.asarray(f, np.float32) for _, f in entries])
    else:
        hw = (0, 0) if frame_hw is None else tuple(frame_hw)
        frames = np.zeros((0, *hw), np.float32)
    return idx, frames


def _top_fragment_hvs(frames: Array, maps: Array, B0: Array, b: Array, *,
                      h: int, w: int, stride: int, mx: int,
                      nonlinearity) -> Array:
    """Re-encode each frame's top-scoring fragment -> ``(S, C, D)``.

    The online update's sample stream: per frame, the fragment the model
    found most object-like (hard positive on object frames, hard negative
    on empty ones). One ``(h*w, D)`` projection per frame — negligible
    next to the full score map.
    """
    S, C, H, W = frames.shape
    top = jnp.argmax(maps.reshape(S, C, -1), axis=-1)            # (S, C)
    iy = (top // mx) * stride
    ix = (top % mx) * stride
    crop = jax.vmap(jax.vmap(
        lambda f, y, x: jax.lax.dynamic_slice(f, (y, x), (h, w))))
    frags = crop(frames, iy, ix)                                 # (S,C,h,w)
    Bf = flat_perm_base(B0, w)                                   # (h*w, D)
    hv = encode_fragments(frags.reshape(S * C, h, w), Bf, b,
                          nonlinearity=nonlinearity, normalize=True)
    return hv.reshape(S, C, -1)


def super_chunk_fn(frames, state: StreamState, B0, b, tiles, t_score,
                   n_valid, labels, slot_mask=None, *, h, w, stride,
                   nonlinearity, t_detection, hold_frames, backend,
                   adapt: AdaptConfig | None = None,
                   precision: str = "float32", adc_lsb: float = 1.0,
                   decim: int | None = None,
                   park_masked: bool = False,
                   sensor_axes: tuple[str, ...] | None = None,
                   hyperdim_axes: tuple[str, ...] | None = None):
    """One streaming step over an ``(S, C, H, W)`` super-chunk.

    The shared core of both runners: ``StreamRunner`` calls it with
    ``S = 1``, :class:`~repro.sensing.fleet.FleetRunner` with S concurrent
    streams. The ``S*C`` axis is flattened into the batched scorer (one
    kernel launch on the ``pallas`` backend) and each stream's gate is a
    ``vmap``'d :func:`gate_scan`.

    ``state`` carries the mutable model: scoring uses
    ``state.class_hvs``; with ``adapt`` set, the returned state holds the
    chunk-updated classifier. On the ``pallas`` backend ``tiles`` is the
    full host-precomputed :class:`~repro.kernels.sliding_scores.ScoreTiles`
    when frozen (``adapt=None`` — that path's kernel inputs, and hence
    outputs, are bitwise identical to the pre-refactor runtime), or just
    the :class:`~repro.kernels.sliding_scores.ScoreGeometry` when
    adapting — the current classifier is re-tiled *inside* the step by
    the jitted ``retile_classes`` gather.

    ``n_valid`` masks a padded tail chunk; pad frames never fire, never
    contribute updates, and the carried ``(S,)`` hold state is read at the
    last *valid* frame. ``labels`` is ``(S, C)`` i32 — only consumed in
    ``adapt.mode == "label"`` (pass zeros otherwise).

    With an integer precision (``"int8"``, ``"int4"``, ``"binary"``) the
    ``frames`` argument is the *integer ADC code* super-chunk (from
    :func:`adc_view_codes`) and ``tiles`` the int precompute
    (:class:`~repro.kernels.sliding_scores_int.IntScoreTiles`, or the int
    geometry when adapting) — on BOTH backends: the jnp execution of the
    int path is the quantized-operand oracle
    ``fragment_scores_batch_int_ref``, so jnp==pallas parity holds per
    precision. ``"int4"`` codes are nibble-packed here at the kernel
    boundary (two per byte, unpacked in-kernel) — everything outside the
    scorer, including the adapt re-encode, sees plain codes. ``adc_lsb``
    (static; ``v_max/levels`` of the converter) only matters to the
    online-learning re-encode, which dequantizes the top fragment crop —
    scoring itself is LSB-free.

    ``decim`` switches on the *closed capture loop*: ``None`` (default)
    is the open-loop step — every valid frame is LP-converted and the
    gate is the plain :func:`gate_scan` hysteresis, a code path bitwise
    identical to the pre-closed-loop runtime. An integer ``decim`` runs
    :func:`control_scan` instead, with the per-stream ``state.phases``
    ADC state carried across chunks: idle frames are subsampled to one
    LP conversion per ``decim`` frames, a skipped frame can never fire
    (its score is still computed — simulation artifact — but masked out
    of the decision, the gate, and the online update), and ``decim == 1``
    reproduces the open-loop outputs bitwise.

    ``slot_mask`` (``(S,)`` bool, default all-true) marks *real* sensor
    slots: the fleet pads S up to the mesh extent with masked slots so a
    non-divisible fleet still shards (never a recompile or an unsharded
    fallback). Masked slots never fire, never sample, and never
    contribute to a shared-scope update — their presence is an exact
    no-op on every real slot's outputs and on the shared classifier.

    ``park_masked`` additionally freezes the masked slots' *carried
    state* in place: their hold/phase counters (which would otherwise
    decay through the chunk) and, in per-stream scope, their classifier
    rows pass through unchanged. This is the serving layer's slot-pool
    semantics (:class:`repro.launch.serve.FleetService`): a sensor that
    sent no frames this tick experienced no time, so a later reattach
    resumes exactly where it detached. With an all-true ``slot_mask``
    the selects are identities — the parked step is bitwise the plain
    one, which is what lets the service share this trace.

    ``sensor_axes`` / ``hyperdim_axes`` name the mesh axes this step is
    ``shard_map``'d over (None outside a mesh). ``hyperdim_axes`` flows
    to the scorer's tile fold (tiled all_gather before a fixed-order
    reduction — see ``sliding_scores._ordered_tile_fold``);
    ``sensor_axes`` makes the shared-scope online fold all_gather the
    per-shard samples so every device folds the full fleet's samples in
    the identical global time-then-stream order. Both keep outputs
    bitwise-identical to the unsharded step — a ``psum`` of per-shard
    deltas could NOT, because each perceptron step depends on the
    running classifier state.

    Returns ``(scores (S, C), fired, gated, sampled, new_state)``;
    ``sampled`` marks the frames the LP ADC actually converted.
    """
    S, C, H, W = frames.shape
    my = (H - h) // stride + 1
    mx = (W - w) // stride + 1
    class_hvs = state.class_hvs
    per_stream = adapt is not None and adapt.scope == "per-stream"

    if precision in adc_sim.INT_PRECISIONS:
        from repro.kernels import ops as kops
        from repro.kernels import sliding_scores_int as ssi
        if adapt is None:
            ktiles = tiles                       # frozen: IntScoreTiles
        elif per_stream:                         # tiles: IntScoreGeometry
            ktiles = kops.retile_classes_int_fleet(tiles, class_hvs)
        else:
            ktiles = kops.retile_classes_int(tiles, class_hvs)
        packed = precision == "int4"
        kframes = adc_sim.pack_nibbles(frames) if packed else frames
        if backend == "pallas":
            maps = kops.fragment_score_map_fleet_int(
                kframes, class_hvs, B0, b, h=h, w=w, stride=stride,
                nonlinearity=nonlinearity, tiles=ktiles, packed=packed,
                hyperdim_axes=hyperdim_axes)                 # (S,C,my,mx)
        else:
            fps = C if ktiles.cpos_t.ndim == 4 else None
            maps = ssi.fragment_scores_batch_int_ref(
                kframes.reshape(S * C, H, kframes.shape[-1]), ktiles,
                h=h, w=w, stride=stride, nonlinearity=nonlinearity,
                frames_per_stream=fps, packed=packed,
                hyperdim_axes=hyperdim_axes).reshape(S, C, my, mx)
    elif backend == "pallas":
        from repro.kernels import ops as kops
        if adapt is None:
            ktiles = tiles                       # frozen: host precompute
        elif per_stream:                         # tiles is a ScoreGeometry
            ktiles = kops.retile_classes_fleet(tiles, class_hvs)
        else:
            ktiles = kops.retile_classes(tiles, class_hvs)
        maps = kops.fragment_score_map_fleet(
            frames, class_hvs, B0, b, h=h, w=w, stride=stride,
            nonlinearity=nonlinearity, tiles=ktiles,
            hyperdim_axes=hyperdim_axes)                     # (S, C, my, mx)
    elif per_stream:
        maps = jax.vmap(lambda fs, cv: jax.vmap(
            lambda f: hypersense.fragment_score_map(
                f, cv, B0, b, h=h, w=w, stride=stride,
                nonlinearity=nonlinearity, backend=backend))(fs))(
                    frames, class_hvs)
    else:
        maps = jax.vmap(lambda f: hypersense.fragment_score_map(
            f, class_hvs, B0, b, h=h, w=w, stride=stride,
            nonlinearity=nonlinearity, backend=backend))(
                frames.reshape(S * C, H, W)).reshape(S, C, my, mx)

    scores = jax.vmap(jax.vmap(
        lambda m: frame_detection_score(m, t_detection)))(maps)  # (S, C)

    # count(s_i > t) > T  <=>  (T+1)-th largest > t, provided T < my*mx;
    # with T >= my*mx the count can never exceed T -> never fires.
    valid = jnp.arange(C) < n_valid
    if t_detection >= my * mx:
        fired = jnp.zeros((S, C), bool)
    else:
        fired = (scores > t_score) & valid[None, :]
    if slot_mask is not None:
        fired = fired & slot_mask[:, None]

    if decim is None:
        sampled = jnp.broadcast_to(valid[None, :], (S, C))
        if slot_mask is not None:
            sampled = sampled & slot_mask[:, None]
        gated, holds_seq = jax.vmap(
            lambda f, h0: gate_scan(f, hold_frames, h0))(fired, state.holds)
        phase_out = state.phases
    else:
        sampled, gated, holds_seq, phases_seq = jax.vmap(
            lambda f, h0, p0: control_scan(f, hold_frames, decim, h0, p0))(
                fired, state.holds, state.phases)
        fired = fired & sampled
        if slot_mask is not None:
            sampled = sampled & slot_mask[:, None]
        phase_out = jnp.where(n_valid > 0,
                              phases_seq[:, jnp.maximum(n_valid - 1, 0)],
                              state.phases)
    hold_out = jnp.where(n_valid > 0,
                         holds_seq[:, jnp.maximum(n_valid - 1, 0)],
                         state.holds)

    if adapt is not None:
        # the int path re-encodes from the dequantized crop (h*w values per
        # frame — never a full float frame); the fragment normalization
        # makes the LSB cancel, so this matches the float path's samples
        # up to int8 rounding of the codes themselves
        obs = (frames.astype(jnp.float32) * jnp.float32(adc_lsb)
               if precision in adc_sim.INT_PRECISIONS else frames)
        hv = _top_fragment_hvs(obs, maps, B0, b, h=h, w=w,
                               stride=stride, mx=mx,
                               nonlinearity=nonlinearity)    # (S, C, D)
        labels = labels.astype(jnp.int32)

        def _shared_fold(chvs, hv, labels, mask2d):
            # One shared classifier: fold samples in time order (stream
            # index breaks ties), matching real arrival order. Under
            # sensor sharding, all_gather the per-shard samples first
            # (tiled = global stream order restored) and run the SAME
            # sequential fold replicated on every device — the perceptron
            # step depends on the running classifier, so this, not a psum
            # of deltas, is the all-reduce that matches unsharded bitwise.
            if sensor_axes:
                hv = jax.lax.all_gather(hv, sensor_axes, axis=0, tiled=True)
                labels = jax.lax.all_gather(labels, sensor_axes, axis=0,
                                            tiled=True)
                mask2d = jax.lax.all_gather(mask2d, sensor_axes, axis=0,
                                            tiled=True)
            s_all, dim = hv.shape[0], hv.shape[-1]
            hv_t = hv.transpose(1, 0, 2).reshape(C * s_all, dim)
            lab_t = labels.T.reshape(C * s_all)
            val_t = mask2d.T.reshape(C * s_all)
            return online.apply_chunk(adapt, chvs, hv_t, lab_t, val_t)[0]

        def _per_stream_fold(chvs, hv, labels, mask2d):
            # lax.map, NOT vmap: XLA's batched dot inside apply_chunk
            # reassociates with the batch extent, so a vmap'd fold is not
            # bitwise stable when sensor sharding changes the per-device
            # batch. lax.map runs each stream through the identical
            # unbatched program — any partition of the stream axis gives
            # the same per-row bits (tests/test_parity_matrix.py pins the
            # full mesh matrix on this).
            return jax.lax.map(
                lambda a: online.apply_chunk(adapt, a[0], a[1],
                                             a[2], a[3])[0],
                (chvs, hv, labels, mask2d))

        if decim is None:
            # masked pad slots contribute nothing (exact no-op selects)
            mask2d = jnp.broadcast_to(valid[None, :], (S, C))
            if slot_mask is not None:
                mask2d = mask2d & slot_mask[:, None]
            if per_stream:
                class_hvs = _per_stream_fold(class_hvs, hv, labels, mask2d)
            else:
                class_hvs = _shared_fold(class_hvs, hv, labels, mask2d)
        else:
            # closed loop: a frame the LP ADC skipped was never scored —
            # it must not feed the online update either (sampled already
            # carries the slot mask)
            seen = sampled & valid[None, :]                     # (S, C)
            if per_stream:
                class_hvs = _per_stream_fold(class_hvs, hv, labels, seen)
            else:
                class_hvs = _shared_fold(class_hvs, hv, labels, seen)

    if park_masked and slot_mask is not None:
        # slot-pool semantics: a masked slot's carried state is parked in
        # place — no hold/phase decay, no classifier churn — so detached
        # or silent sensors resume bitwise where they stopped
        hold_out = jnp.where(slot_mask, hold_out, state.holds)
        phase_out = jnp.where(slot_mask, phase_out, state.phases)
        if class_hvs.ndim == 3:
            class_hvs = jnp.where(slot_mask[:, None, None], class_hvs,
                                  state.class_hvs)

    new_state = StreamState(class_hvs=class_hvs, holds=hold_out,
                            phases=phase_out,
                            frame_idx=state.frame_idx
                            + jnp.asarray(n_valid, jnp.int32))
    return scores, fired, gated, sampled, new_state


_STEP_STATIC = ("h", "w", "stride", "nonlinearity", "t_detection",
                "hold_frames", "backend", "adapt", "precision", "adc_lsb",
                "decim", "park_masked", "sensor_axes", "hyperdim_axes")

#: module-level jit: every runner instance shares one trace cache.
super_chunk_step = jax.jit(super_chunk_fn, static_argnames=_STEP_STATIC)

#: the serving twin: identical trace, but the carried
#: :class:`StreamState` (arg 1) is DONATED — XLA aliases it into the
#: step's output state, so a long-running
#: :class:`repro.launch.serve.FleetService` rolls one state allocation
#: forever instead of allocating per chunk. (The super-chunk buffer
#: itself is donated one stage earlier, at the service's ADC-convert
#: jit, where input and output shapes actually alias; no step output
#: matches the ``(S, C, H, W)`` frames, so donating arg 0 here could
#: never be used.) Donated inputs are dead after the call; only the
#: service (which never re-reads its carried state) may use this.
super_chunk_step_donated = jax.jit(super_chunk_fn,
                                   static_argnames=_STEP_STATIC,
                                   donate_argnums=(1,))


def model_geometry(model: HyperSenseModel, W: int, block_d: int,
                   precision: str = "float32"):
    """Class-independent geometry for ``model`` on width-``W`` frames
    (:class:`ScoreGeometry`, or the int twin for the integer precisions —
    ±1 sign-quantized slabs under ``precision="binary"``)."""
    from repro.kernels import ops as kops
    if precision in adc_sim.INT_PRECISIONS:
        return kops.precompute_geometry_int(
            model.B0, model.b, W=W, w=model.w, stride=model.stride,
            block_d=block_d,
            mode="binary" if precision == "binary" else "int8")
    return kops.precompute_geometry(model.B0, model.b, W=W, w=model.w,
                                    stride=model.stride, block_d=block_d)


def model_tiles(model: HyperSenseModel, W: int, block_d: int,
                precision: str = "float32"):
    """Tile precompute for ``model`` on width-``W`` frames (per precision)."""
    from repro.kernels import ops as kops
    geom = model_geometry(model, W, block_d, precision)
    fn = (kops.retile_classes_int if precision in adc_sim.INT_PRECISIONS
          else kops.retile_classes)
    return fn(geom, model.class_hvs)


class StreamRunner:
    """Stateful chunked scorer+gate(+learner). ``process(frames)`` freely.

    The :class:`StreamState` — controller ``hold``, absolute frame index,
    and (with ``adapt``) the live class hypervectors — carries across
    ``process`` calls, so a long stream can be fed incrementally in
    arbitrary slices; every internal step is one fixed-shape jit call
    (tail chunks are padded and masked, so no recompiles).

    ``adapt=None`` (default) is the frozen runtime — bitwise identical to
    the pre-online-learning runner on the ``pallas`` backend. With an
    :class:`~repro.core.online.AdaptConfig` the classifier updates every
    chunk; in ``"label"`` mode pass per-frame labels to ``process``. The
    live classifier is ``runner.class_hvs``; :meth:`set_class_hvs`
    installs an external update mid-stream (a jitted ``retile_classes``
    gather on the ``pallas`` backend — never a host-side re-precompute;
    the tile cache is keyed on class-hv *identity*, so stale tiles are
    impossible).

    ``control=`` (a :class:`~repro.core.sensor_control.CaptureConfig`)
    closes the capture loop: the ``ControllerConfig`` rates stop being
    decorative — idle frames are LP-converted at ``base_rate_hz`` only
    (temporal decimation inside the chunk scan; skipped frames can never
    fire), gate bursts capture every frame, and the gated frames are
    additionally converted at ``control.hp_bits`` into a bounded buffer,
    drained via :meth:`drain_hp` — the runtime's deliverable to the
    downstream backend. Every runner (open- or closed-loop) keeps a
    :attr:`capture_log` of what the ADC actually converted, which
    :func:`repro.core.energy.from_capture_log` bills directly. With
    ``base == active`` rates or ``subsample=False`` the closed-loop
    outputs are bitwise-identical to ``control=None``.
    """

    def __init__(self, model: HyperSenseModel,
                 config: ControllerConfig | None = None, *,
                 chunk_size: int = 32, backend: str = "jnp",
                 t_detection: int | None = None, block_d: int = 512,
                 adc_bits: int | None = None, adc_sigma: float = 0.0,
                 adc_key: Array | int = 0,
                 adapt: AdaptConfig | None = None,
                 precision: str = "float32",
                 control: CaptureConfig | None = None):
        validate_runner_args(chunk_size, adc_bits, adc_sigma, precision)
        if adapt is not None and adapt.scope == "per-stream":
            raise ValueError('scope="per-stream" is a FleetRunner mode; '
                             "a StreamRunner has exactly one stream — "
                             'use scope="shared"')
        self.precision = precision
        self.model = model
        self.config = config or ControllerConfig()
        self.chunk_size = chunk_size
        self.backend = backend
        self.block_d = block_d
        self.t_detection = (model.t_detection if t_detection is None
                            else t_detection)
        self.adc_bits = adc_bits
        self.adc_sigma = adc_sigma
        self._adc_key = (jax.random.PRNGKey(adc_key)
                         if isinstance(adc_key, int) else adc_key)
        self.adapt = adapt
        self.control = control
        self._decim = (None if control is None
                       else (decimation(self.config) if control.subsample
                             else 1))
        self._geom = None       # (W, ScoreGeometry) — class-independent
        self._tiles = None      # (W, class_hvs-ref, ScoreTiles) frozen path
        self._state = init_stream_state(model.class_hvs, 1)
        self._n_seen = 0        # absolute frame index (keys the ADC noise)
        self._log_sampled: list[np.ndarray] = []
        self._log_gated: list[np.ndarray] = []
        self._frame_pixels = 0
        self._frame_hw: tuple[int, int] | None = None
        self._hp_idx: list[int] = []
        self._hp_frames: list[np.ndarray] = []
        self.hp_dropped = 0     # burst frames lost to a full HP buffer

    def reset(self) -> None:
        self._state = init_stream_state(self.model.class_hvs, 1)
        self._n_seen = 0
        self._tiles = None
        self._log_sampled = []
        self._log_gated = []
        self._hp_idx = []
        self._hp_frames = []
        self.hp_dropped = 0

    @property
    def class_hvs(self) -> Array:
        """The live classifier (updates under ``adapt``)."""
        return self._state.class_hvs

    @property
    def _hold(self) -> Array:   # back-compat scalar view of the gate state
        return self._state.holds[0]

    def set_class_hvs(self, class_hvs: Array) -> None:
        """Install an externally updated classifier mid-stream.

        Device-side cost only: the next chunk re-tiles via the jitted
        ``retile_classes`` gather against the cached geometry (the frozen
        tile cache self-invalidates — it is keyed on class-hv identity).
        """
        class_hvs = jnp.asarray(class_hvs)
        self.model = self.model._replace(class_hvs=class_hvs)
        self._state = dataclasses.replace(self._state,
                                          class_hvs=class_hvs)

    def _ensure_geom(self, W: int):
        if self._geom is None or self._geom[0] != W:
            self._geom = (W, model_geometry(self.model, W, self.block_d,
                                            self.precision))
        return self._geom[1]

    def _ensure_tiles(self, W: int):
        """Frozen-path tile cache, keyed on (width, class-hv identity)."""
        from repro.kernels import ops as kops
        retile = (kops.retile_classes_int
                  if self.precision in adc_sim.INT_PRECISIONS
                  else kops.retile_classes)
        chvs = self._state.class_hvs
        if (self._tiles is None or self._tiles[0] != W
                or self._tiles[1] is not chvs):
            self._tiles = (W, chvs, retile(self._ensure_geom(W), chvs))
        return self._tiles[2]

    @property
    def _adc_lsb(self) -> float:
        return (adc_sim.lsb(self.adc_bits)
                if self.precision in adc_sim.INT_PRECISIONS else 1.0)

    @property
    def capture_log(self) -> CaptureLog:
        """What the ADC actually converted so far (across ``process``
        calls; cleared by :meth:`reset`) — the billing ground truth for
        :func:`repro.core.energy.from_capture_log`."""
        return assemble_capture_log(self._log_sampled, self._log_gated,
                                    lp_bits=self.adc_bits,
                                    control=self.control,
                                    frame_pixels=self._frame_pixels)

    def drain_hp(self) -> tuple[np.ndarray, np.ndarray]:
        """Take the high-precision burst frames captured so far.

        Returns ``(indices (M,) — absolute frame indices, frames
        (M, H, W) at control.hp_bits)`` and empties the buffer; frames a
        full per-chunk buffer dropped are counted in ``hp_dropped``. An
        empty drain keeps the real ``(0, H, W)`` frame shape
        (:func:`hp_drain_arrays`) so cross-drain concatenation works.
        """
        idx, frames = hp_drain_arrays(
            list(zip(self._hp_idx, self._hp_frames)), self._frame_hw)
        self._hp_idx, self._hp_frames = [], []
        return idx, frames

    def process(self, frames, labels=None
                ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(n, H, W) frames -> (scores (n,), fired (n,), gated (n,)).

        With ``adc_bits`` set, the scorer sees the low-precision ADC
        capture of each frame (:func:`adc_view`) — the paper's always-on
        path — while the caller keeps the raw high-precision frames for
        whatever the gate lets through. With an integer precision the
        capture stays *integer codes* end to end (:func:`adc_view_codes`
        into the fused int kernel; raw integer input is treated as
        already-converted codes — ``"int4"`` additionally nibble-packs
        at the kernel boundary). ``labels`` (``(n,)`` ints) feeds
        ``adapt.mode == "label"`` updates.
        """
        frames = jnp.asarray(frames)
        raw = frames
        self._frame_pixels = int(frames.shape[-2] * frames.shape[-1])
        self._frame_hw = (int(frames.shape[-2]), int(frames.shape[-1]))
        hp_k = resolve_hp_buffer(self.control, self.chunk_size,
                                 frames.dtype)
        base = self._n_seen
        if self.adapt is not None and self.adapt.mode == "label":
            if labels is None:
                raise ValueError('adapt.mode == "label" needs per-frame '
                                 "labels passed to process()")
            labels = jnp.asarray(labels, jnp.int32)
            if labels.shape != frames.shape[:1]:
                raise ValueError(f"labels shape {labels.shape} != "
                                 f"(n,) = {frames.shape[:1]}")
        if self.precision in adc_sim.INT_PRECISIONS:
            from repro.kernels import ops as kops
            kops.assert_int_datapath_fits(self.adc_bits, *frames.shape[-2:],
                                          self.model.h, self.model.w,
                                          stride=self.model.stride,
                                          block_d=self.block_d,
                                          packed=self.precision == "int4")
            frames = adc_view_codes(frames, self.adc_bits,
                                    sigma=self.adc_sigma,
                                    key=self._adc_key,
                                    start_index=self._n_seen)
        elif self.adc_bits is not None:
            frames = adc_view(frames, self.adc_bits, sigma=self.adc_sigma,
                              key=self._adc_key, start_index=self._n_seen)
        n = frames.shape[0]
        self._n_seen += n
        m = self.model
        if (self.backend == "pallas"
                or self.precision in adc_sim.INT_PRECISIONS):
            tiles = (self._ensure_geom(frames.shape[-1])
                     if self.adapt is not None
                     else self._ensure_tiles(frames.shape[-1]))
        else:
            tiles = None
        scores = np.empty(n, np.float32)
        fired = np.empty(n, bool)
        gated = np.empty(n, bool)
        for start in range(0, n, self.chunk_size):
            chunk = frames[start:start + self.chunk_size]
            lab = (labels[start:start + self.chunk_size]
                   if labels is not None
                   else jnp.zeros(chunk.shape[0], jnp.int32))
            n_valid = chunk.shape[0]
            if n_valid < self.chunk_size:
                pad = self.chunk_size - n_valid
                chunk = jnp.pad(chunk, ((0, pad), (0, 0), (0, 0)))
                lab = jnp.pad(lab, (0, pad))
            s, f, g, smp, new_state = super_chunk_step(
                chunk[None], self._state, m.B0, m.b, tiles,
                jnp.float32(m.t_score), jnp.int32(n_valid), lab[None],
                h=m.h, w=m.w, stride=m.stride,
                nonlinearity=m.nonlinearity, t_detection=self.t_detection,
                hold_frames=self.config.hold_frames, backend=self.backend,
                adapt=self.adapt, precision=self.precision,
                adc_lsb=self._adc_lsb, decim=self._decim)
            if self.adapt is None:
                # keep the ORIGINAL class-hv ref: values are untouched and
                # the identity-keyed tile cache must not churn
                new_state = dataclasses.replace(
                    new_state, class_hvs=self._state.class_hvs)
            self._state = new_state
            sl = slice(start, start + n_valid)
            scores[sl] = np.asarray(s)[0, :n_valid]
            fired[sl] = np.asarray(f)[0, :n_valid]
            gated[sl] = np.asarray(g)[0, :n_valid]
            self._log_sampled.append(np.asarray(smp)[0, :n_valid])
            self._log_gated.append(gated[sl].copy())
            if hp_k > 0:
                raw_chunk = raw[start:start + self.chunk_size]
                if n_valid < self.chunk_size:
                    raw_chunk = jnp.pad(
                        raw_chunk,
                        ((0, self.chunk_size - n_valid), (0, 0), (0, 0)))
                entries, dropped = collect_hp(
                    raw_chunk[None], g, n_valid, hp_k,
                    self.control.hp_bits, base + start)
                self._hp_idx.extend(i for i, _ in entries[0])
                self._hp_frames.extend(f for _, f in entries[0])
                self.hp_dropped += dropped
        return scores, fired, gated


def simulate_stream_batched(model: HyperSenseModel, frames, labels,
                            config: ControllerConfig | None = None, *,
                            chunk_size: int = 32, backend: str = "jnp",
                            t_detection: int | None = None,
                            block_d: int = 512,
                            adc_bits: int | None = None,
                            adc_sigma: float = 0.0,
                            adc_key: Array | int = 0,
                            adapt: AdaptConfig | None = None,
                            precision: str = "float32",
                            control: CaptureConfig | None = None
                            ) -> StreamStats:
    """Chunked-batched twin of ``sensor_control.simulate_stream``.

    Produces identical :class:`StreamStats` to replaying
    ``hypersense.detect`` frame-at-a-time through ``SensorController``,
    but runs ``len(frames)/chunk_size`` jitted steps instead of
    ``len(frames)`` dispatches (one kernel launch per chunk on the
    ``pallas`` backend). ``adc_bits`` puts the simulated low-precision
    ADC in front of the gate (pass raw frames). ``adapt`` switches on
    online learning — in ``"label"`` mode the ground-truth ``labels``
    double as the feedback signal.
    """
    runner = StreamRunner(model, config, chunk_size=chunk_size,
                          backend=backend, t_detection=t_detection,
                          block_d=block_d, adc_bits=adc_bits,
                          adc_sigma=adc_sigma, adc_key=adc_key,
                          adapt=adapt, precision=precision,
                          control=control)
    feed = (labels if adapt is not None and adapt.mode == "label"
            else None)
    _, fired, gated = runner.process(frames, labels=feed)
    return stats_from(fired, gated, labels)
