"""Batched streaming runtime: chunked scoring + gating (one jit per chunk).

The paper's sensing loop (§III-B/C) scores *every* incoming frame with the
HDC HyperSense model and gates the expensive high-precision path in real
time. ``repro.core.sensor_control.simulate_stream`` does that one frame per
call — one kernel launch (or one jnp dispatch) per frame. This module is
the throughput path: frames are consumed in fixed-size chunks and each
chunk runs

  batched fragment scoring  ->  frame_detection_score  ->  threshold
  ->  SensorController hysteresis (as a ``lax.scan``)

inside a single jitted step. On the ``pallas`` backend the whole chunk is
ONE kernel launch (grid ``(N, my, n_dt)``) against one per-model
:class:`~repro.kernels.sliding_scores.ScoreTiles` precompute.

:func:`gate_scan` is the exact jnp twin of
:class:`~repro.core.sensor_control.SensorController`; the carried ``hold``
state crosses chunk boundaries, so chunking is invisible:
:func:`simulate_stream_batched` returns :class:`StreamStats` identical to
the frame-at-a-time ``simulate_stream``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hypersense
from repro.core.hypersense import HyperSenseModel, frame_detection_score
from repro.core.sensor_control import (ControllerConfig, StreamStats,
                                       stats_from)

Array = jax.Array


def gate_scan(decisions: Array, hold_frames: int,
              init_hold: Array | int = 0) -> tuple[Array, Array]:
    """Jittable ``SensorController``: ``(gated (N,) bool, holds (N,) i32)``.

    ``holds[i]`` is the controller state *after* frame ``i`` — feed
    ``holds[last_real_frame]`` back as ``init_hold`` of the next chunk.
    """
    def step(hold, fired):
        gated = fired | (hold > 0)
        hold = jnp.where(fired, hold_frames, jnp.maximum(hold - 1, 0))
        return hold, (gated, hold)

    _, (gated, holds) = jax.lax.scan(
        step, jnp.asarray(init_hold, jnp.int32), decisions.astype(bool))
    return gated, holds


@functools.partial(jax.jit, static_argnames=("h", "w", "stride",
                                             "nonlinearity", "t_detection",
                                             "hold_frames", "backend"))
def _chunk_step(frames, class_hvs, B0, b, tiles, t_score, hold, n_valid, *,
                h, w, stride, nonlinearity, t_detection, hold_frames,
                backend):
    """One jitted streaming step over a fixed-size chunk.

    ``n_valid`` masks a padded tail chunk; pad frames never fire, and the
    carried hold state is read at the last *valid* frame.
    """
    N, H, W = frames.shape
    my = (H - h) // stride + 1
    mx = (W - w) // stride + 1

    if backend == "pallas":
        from repro.kernels import ops as kops
        maps = kops.fragment_score_map_batch(
            frames, class_hvs, B0, b, h=h, w=w, stride=stride,
            nonlinearity=nonlinearity, tiles=tiles)          # (N, my, mx)
    else:
        maps = jax.vmap(lambda f: hypersense.fragment_score_map(
            f, class_hvs, B0, b, h=h, w=w, stride=stride,
            nonlinearity=nonlinearity, backend=backend))(frames)

    scores = jax.vmap(
        lambda m: frame_detection_score(m, t_detection))(maps)  # (N,)

    # count(s_i > t) > T  <=>  (T+1)-th largest > t, provided T < my*mx;
    # with T >= my*mx the count can never exceed T -> never fires.
    valid = jnp.arange(N) < n_valid
    if t_detection >= my * mx:
        fired = jnp.zeros((N,), bool)
    else:
        fired = (scores > t_score) & valid

    gated, holds = gate_scan(fired, hold_frames, hold)
    hold_out = jnp.where(n_valid > 0,
                         holds[jnp.maximum(n_valid - 1, 0)], hold)
    return scores, fired, gated, hold_out


class StreamRunner:
    """Stateful chunked scorer+gate. ``process(frames)`` any number of times.

    The controller ``hold`` state carries across ``process`` calls, so a
    long stream can be fed incrementally in arbitrary slices; every
    internal step is one fixed-shape jit call (tail chunks are padded and
    masked, so no recompiles).
    """

    def __init__(self, model: HyperSenseModel,
                 config: ControllerConfig | None = None, *,
                 chunk_size: int = 32, backend: str = "jnp",
                 t_detection: int | None = None, block_d: int = 512):
        if chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
        self.model = model
        self.config = config or ControllerConfig()
        self.chunk_size = chunk_size
        self.backend = backend
        self.block_d = block_d
        self.t_detection = (model.t_detection if t_detection is None
                            else t_detection)
        self._tiles = None      # (W, ScoreTiles) — keyed on frame width
        self._hold = jnp.zeros((), jnp.int32)

    def reset(self) -> None:
        self._hold = jnp.zeros((), jnp.int32)

    def _ensure_tiles(self, W: int):
        if self.backend != "pallas":
            return None
        if self._tiles is None or self._tiles[0] != W:
            from repro.kernels import ops as kops
            self._tiles = (W, kops.precompute_tiles(
                self.model.B0, self.model.b, self.model.class_hvs, W=W,
                w=self.model.w, stride=self.model.stride,
                block_d=self.block_d))
        return self._tiles[1]

    def process(self, frames) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(n, H, W) frames -> (scores (n,), fired (n,), gated (n,))."""
        frames = jnp.asarray(frames)
        n = frames.shape[0]
        m = self.model
        tiles = self._ensure_tiles(frames.shape[-1])
        scores = np.empty(n, np.float32)
        fired = np.empty(n, bool)
        gated = np.empty(n, bool)
        for start in range(0, n, self.chunk_size):
            chunk = frames[start:start + self.chunk_size]
            n_valid = chunk.shape[0]
            if n_valid < self.chunk_size:
                pad = self.chunk_size - n_valid
                chunk = jnp.pad(chunk, ((0, pad), (0, 0), (0, 0)))
            s, f, g, self._hold = _chunk_step(
                chunk, m.class_hvs, m.B0, m.b, tiles,
                jnp.float32(m.t_score), self._hold, jnp.int32(n_valid),
                h=m.h, w=m.w, stride=m.stride,
                nonlinearity=m.nonlinearity, t_detection=self.t_detection,
                hold_frames=self.config.hold_frames, backend=self.backend)
            sl = slice(start, start + n_valid)
            scores[sl] = np.asarray(s)[:n_valid]
            fired[sl] = np.asarray(f)[:n_valid]
            gated[sl] = np.asarray(g)[:n_valid]
        return scores, fired, gated


def simulate_stream_batched(model: HyperSenseModel, frames, labels,
                            config: ControllerConfig | None = None, *,
                            chunk_size: int = 32, backend: str = "jnp",
                            t_detection: int | None = None,
                            block_d: int = 512) -> StreamStats:
    """Chunked-batched twin of ``sensor_control.simulate_stream``.

    Produces identical :class:`StreamStats` to replaying
    ``hypersense.detect`` frame-at-a-time through ``SensorController``,
    but runs ``len(frames)/chunk_size`` jitted steps instead of
    ``len(frames)`` dispatches (one kernel launch per chunk on the
    ``pallas`` backend).
    """
    runner = StreamRunner(model, config, chunk_size=chunk_size,
                          backend=backend, t_detection=t_detection,
                          block_d=block_d)
    _, fired, gated = runner.process(frames)
    return stats_from(fired, gated, labels)
