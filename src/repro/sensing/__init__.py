"""Sensing substrate: synthetic radar data, ADC simulation, fragment
sampling, baseline detectors (CRUW stand-in; DESIGN.md §1)."""

from repro.sensing import adc, baselines, fragments, synthetic  # noqa: F401
