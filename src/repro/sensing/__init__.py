"""Sensing substrate: synthetic radar data, ADC simulation, fragment
sampling, baseline detectors (CRUW stand-in; DESIGN.md §1), and the
batched streaming runtime (:mod:`repro.sensing.stream`)."""

from repro.sensing import (adc, baselines, fragments, stream,  # noqa: F401
                           synthetic)
