"""Sensing substrate: synthetic radar data, ADC simulation, fragment
sampling, baseline detectors (CRUW stand-in; DESIGN.md §1), the batched
streaming runtime (:mod:`repro.sensing.stream`), and the multi-sensor
fleet runtime (:mod:`repro.sensing.fleet`)."""

from repro.sensing import (adc, baselines, fleet, fragments,  # noqa: F401
                           stream, synthetic)
