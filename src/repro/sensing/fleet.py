"""Multi-sensor fleet streaming runtime (paper §I: escalating sensor counts).

HyperSense's always-on HDC front-end is fleet-scale in deployment — one
edge site aggregates many radar/camera feeds (cf. Eggimann et al.'s
always-on SCM accelerator, HyperCam's camera fleets). This module
multiplies the single-stream chunked runtime (:mod:`repro.sensing.stream`)
along a sensor axis without multiplying kernel launches:

* ``(S, C, H, W)`` **super-chunks** — S concurrent streams, C frames each —
  are flattened to an ``S*C`` batch and scored by ONE ``pallas_call``
  (grid ``(S*C, my, n_dt)``) against one shared
  :class:`~repro.kernels.sliding_scores.ScoreTiles` precompute
  (:func:`repro.kernels.ops.fragment_score_map_fleet`);
* per-stream controller hysteresis is ``vmap(gate_scan)`` — S independent
  ``lax.scan`` hold states carried across super-chunks, so every stream
  sees exactly the gating an independent :class:`StreamRunner` would give;
* the optional low-precision **ADC** sits in front of the gate
  (``adc_bits=4`` reproduces the paper's Fig. 3 loop: the gate scores the
  cheap capture, the caller keeps the raw frames for gated-on delivery);
* the sensor axis is **sharded across devices** with ``shard_map`` via the
  logical-axis rules in :mod:`repro.distributed.sharding` ("sensors" maps
  to the data-parallel mesh axes). Streams are independent, so the sharded
  step needs no communication; without a mesh (or when S doesn't divide)
  the exact same code runs unsharded — CPU tests are unchanged.

:func:`fleet_report` turns the per-stream gate decisions into per-stream
:class:`~repro.core.sensor_control.StreamStats` plus a fleet-aggregate
energy account built on :mod:`repro.core.energy`.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import energy
from repro.core.hypersense import HyperSenseModel
from repro.core.sensor_control import (ControllerConfig, StreamStats,
                                       stats_from_batch)
from repro.distributed import sharding as shlib
from repro.sensing.stream import (adc_view, model_tiles, super_chunk_fn,
                                  super_chunk_step)

Array = jax.Array


def _sensor_axes(S: int, mesh) -> tuple[str, ...] | None:
    """Mesh axes the "sensors" logical dim resolves to (None = unsharded)."""
    if mesh is None:
        return None
    part = shlib.spec_for((S,), ("sensors",), mesh)
    if not part or part[0] is None:
        return None
    ax = part[0]
    return ax if isinstance(ax, tuple) else (ax,)


def _build_step(mesh, axes, **static):
    """Fleet step callable: the shared module-level jit, or shard_map'd.

    Unsharded, this is just :func:`repro.sensing.stream.super_chunk_step`
    with the static config bound — every runner shares its global trace
    cache. Under a mesh, the raw step body is ``shard_map``'d over the
    sensor axis and jitted per (mesh, axes); streams are independent, so
    the sharded body is the unsharded body on a local slice of sensors —
    ``check_rep=False`` because there is no replicated output to verify,
    and no collective is ever emitted.
    """
    if axes is None:
        return functools.partial(super_chunk_step, **static)
    from jax.experimental.shard_map import shard_map
    s4, s2, s1 = P(axes, None, None, None), P(axes, None), P(axes)
    rep = P()
    return jax.jit(shard_map(
        functools.partial(super_chunk_fn, **static), mesh=mesh,
        in_specs=(s4, rep, rep, rep, rep, rep, s1, rep),
        out_specs=(s2, s2, s2, s1),
        check_rep=False))


@dataclasses.dataclass(frozen=True)
class FleetReport:
    """Per-stream stats + fleet-aggregate energy accounting."""
    stats: list[StreamStats]              # one per sensor stream
    n_frames: int                         # frames per stream
    duty_cycle: float                     # fleet-mean fraction gated on
    energy_per_frame: energy.EnergyBreakdown  # fleet-mean, HyperSense path
    energy_total_j: float                 # fleet total over all frames
    baseline_total_j: float               # always-on conventional fleet

    @property
    def n_sensors(self) -> int:
        return len(self.stats)

    @property
    def total_saving(self) -> float:
        return 1.0 - self.energy_total_j / self.baseline_total_j


def fleet_report(fired, gated, labels,
                 params: energy.EnergyParams | None = None) -> FleetReport:
    """(S, N) gate decisions -> per-stream stats + fleet energy account.

    Each stream is billed at its own *measured* duty cycle
    (:func:`repro.core.energy.hypersense_measured`); the baseline is the
    conventional always-on pipeline on every stream.
    """
    params = params or energy.EnergyParams()
    stats = stats_from_batch(fired, gated, labels)
    n = int(np.asarray(fired).shape[1])
    per_stream = [energy.hypersense_measured(s.duty_cycle, params)
                  for s in stats]
    total = sum(b.total for b in per_stream) * n
    base = energy.conventional(params).total * len(stats) * n
    duty = float(np.mean([s.duty_cycle for s in stats]))
    mean = energy.hypersense_measured(duty, params)
    return FleetReport(stats=stats, n_frames=n, duty_cycle=duty,
                       energy_per_frame=mean, energy_total_j=float(total),
                       baseline_total_j=float(base))


class FleetRunner:
    """Stateful fleet scorer+gate: ``process((S, n, H, W))`` incrementally.

    Semantically S independent :class:`~repro.sensing.stream.StreamRunner`
    instances — per-stream scores/fired/gated are asserted identical in
    ``tests/test_fleet.py`` — executed as one batched pipeline: each
    ``(S, chunk_size)`` super-chunk is a single jitted step (one kernel
    launch on the ``pallas`` backend) and the ``(S,)`` hold vector carries
    across ``process`` calls.

    ``adc_bits`` puts the simulated low-precision ADC in front of the
    gate; noise (``adc_sigma > 0``) is keyed per (stream, absolute frame
    index), so stream slicing stays invisible. Under an active
    :func:`repro.distributed.sharding.use_mesh` (or an explicit ``mesh=``)
    the sensor axis is ``shard_map``'d across the mesh axes the "sensors"
    rule resolves to.
    """

    def __init__(self, model: HyperSenseModel,
                 config: ControllerConfig | None = None, *,
                 chunk_size: int = 32, backend: str = "jnp",
                 t_detection: int | None = None, block_d: int = 512,
                 adc_bits: int | None = None, adc_sigma: float = 0.0,
                 adc_key: Array | int = 0, mesh=None):
        if chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
        if adc_sigma > 0.0 and adc_bits is None:
            raise ValueError("adc_sigma > 0 without adc_bits: the ADC is "
                             "only in the loop when adc_bits is set")
        self.model = model
        self.config = config or ControllerConfig()
        self.chunk_size = chunk_size
        self.backend = backend
        self.block_d = block_d
        self.t_detection = (model.t_detection if t_detection is None
                            else t_detection)
        self.adc_bits = adc_bits
        self.adc_sigma = adc_sigma
        self._adc_key = (jax.random.PRNGKey(adc_key)
                         if isinstance(adc_key, int) else adc_key)
        self._mesh = mesh
        self._tiles = None      # (W, ScoreTiles) — keyed on frame width
        self._holds = None      # (S,) i32, allocated on first process()
        self._n_seen = 0
        self._step = None
        self._step_key = None

    def reset(self) -> None:
        self._holds = None
        self._n_seen = 0

    @property
    def holds(self) -> Array | None:
        """(S,) controller hold state after the last processed frame."""
        return self._holds

    def _ensure_tiles(self, W: int):
        if self.backend != "pallas":
            return None
        if self._tiles is None or self._tiles[0] != W:
            self._tiles = (W, model_tiles(self.model, W, self.block_d))
        return self._tiles[1]

    def _ensure_step(self, S: int):
        mesh = self._mesh if self._mesh is not None else shlib.current_mesh()
        axes = _sensor_axes(S, mesh)
        key = (id(mesh) if axes else None, axes)
        if self._step is None or self._step_key != key:
            m = self.model
            self._step = _build_step(
                mesh, axes, h=m.h, w=m.w, stride=m.stride,
                nonlinearity=m.nonlinearity, t_detection=self.t_detection,
                hold_frames=self.config.hold_frames, backend=self.backend)
            self._step_key = key
        return self._step

    def process(self, frames) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(S, n, H, W) super-stream -> ((S, n) scores, fired, gated)."""
        frames = jnp.asarray(frames)
        if frames.ndim != 4:
            raise ValueError(f"expected (S, n, H, W) frames, "
                             f"got shape {frames.shape}")
        S, n = frames.shape[:2]
        if self._holds is None:
            self._holds = jnp.zeros((S,), jnp.int32)
        elif self._holds.shape[0] != S:
            raise ValueError(f"fleet size changed: carried state has "
                             f"{self._holds.shape[0]} streams, got {S}")
        if self.adc_bits is not None:
            keys = jax.vmap(
                lambda s: jax.random.fold_in(self._adc_key, s))(
                    jnp.arange(S))
            frames = jax.vmap(lambda k, f: adc_view(
                f, self.adc_bits, sigma=self.adc_sigma, key=k,
                start_index=self._n_seen))(keys, frames)
        self._n_seen += n

        m = self.model
        tiles = self._ensure_tiles(frames.shape[-1])
        step = self._ensure_step(S)
        scores = np.empty((S, n), np.float32)
        fired = np.empty((S, n), bool)
        gated = np.empty((S, n), bool)
        for start in range(0, n, self.chunk_size):
            chunk = frames[:, start:start + self.chunk_size]
            n_valid = chunk.shape[1]
            if n_valid < self.chunk_size:
                pad = self.chunk_size - n_valid
                chunk = jnp.pad(chunk, ((0, 0), (0, pad), (0, 0), (0, 0)))
            s, f, g, self._holds = step(
                chunk, m.class_hvs, m.B0, m.b, tiles,
                jnp.float32(m.t_score), self._holds, jnp.int32(n_valid))
            sl = slice(start, start + n_valid)
            scores[:, sl] = np.asarray(s)[:, :n_valid]
            fired[:, sl] = np.asarray(f)[:, :n_valid]
            gated[:, sl] = np.asarray(g)[:, :n_valid]
        return scores, fired, gated


def simulate_fleet(model: HyperSenseModel, frames, labels,
                   config: ControllerConfig | None = None, *,
                   chunk_size: int = 32, backend: str = "jnp",
                   t_detection: int | None = None, block_d: int = 512,
                   adc_bits: int | None = None, adc_sigma: float = 0.0,
                   adc_key: Array | int = 0, mesh=None,
                   energy_params: energy.EnergyParams | None = None
                   ) -> FleetReport:
    """Run a whole ``(S, N, H, W)`` fleet recording end-to-end.

    One :class:`FleetRunner` pass followed by :func:`fleet_report`:
    per-stream :class:`StreamStats` (identical to S independent
    single-stream simulations) plus the fleet energy account.
    """
    runner = FleetRunner(model, config, chunk_size=chunk_size,
                         backend=backend, t_detection=t_detection,
                         block_d=block_d, adc_bits=adc_bits,
                         adc_sigma=adc_sigma, adc_key=adc_key, mesh=mesh)
    _, fired, gated = runner.process(frames)
    return fleet_report(fired, gated, labels, energy_params)
