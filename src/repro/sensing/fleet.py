"""Multi-sensor fleet streaming runtime (paper §I: escalating sensor counts).

HyperSense's always-on HDC front-end is fleet-scale in deployment — one
edge site aggregates many radar/camera feeds (cf. Eggimann et al.'s
always-on SCM accelerator, HyperCam's camera fleets). This module
multiplies the single-stream chunked runtime (:mod:`repro.sensing.stream`)
along a sensor axis without multiplying kernel launches:

* ``(S, C, H, W)`` **super-chunks** — S concurrent streams, C frames each —
  are flattened to an ``S*C`` batch and scored by ONE ``pallas_call``
  (grid ``(S*C, my, n_dt)``) against one shared
  :class:`~repro.kernels.sliding_scores.ScoreTiles` precompute
  (:func:`repro.kernels.ops.fragment_score_map_fleet`);
* per-stream controller hysteresis is ``vmap(gate_scan)`` — S independent
  ``lax.scan`` hold states carried across super-chunks, so every stream
  sees exactly the gating an independent :class:`StreamRunner` would give;
* the optional low-precision **ADC** sits in front of the gate
  (``adc_bits=4`` reproduces the paper's Fig. 3 loop: the gate scores the
  cheap capture, the caller keeps the raw frames for gated-on delivery);
* the fleet step is **sharded across a 2-D device mesh** with
  ``shard_map`` via the logical-axis rules in
  :mod:`repro.distributed.sharding`: "sensors" partitions S over the
  data-parallel axes (padded with masked slots when S doesn't divide —
  never an unsharded fallback) and "hyperdim" partitions the D-tile axis
  of slabs + class tiles over "model" (one order-preserving all_gather in
  the score epilogue; shared-scope online updates all_gather their
  samples and fold replicated). Every mesh shape is bitwise-identical to
  the unsharded runner; without a mesh the exact same code runs
  unsharded — CPU tests are unchanged.

:func:`fleet_report` turns the per-stream gate decisions into per-stream
:class:`~repro.core.sensor_control.StreamStats` plus a fleet-aggregate
energy account built on :mod:`repro.core.energy`.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import energy
from repro.core.hypersense import HyperSenseModel
from repro.core.online import AdaptConfig
from repro.core.sensor_control import (CaptureConfig, CaptureLog,
                                       ControllerConfig, StreamStats,
                                       assemble_capture_log, decimation,
                                       stats_from_batch)
from repro.distributed import sharding as shlib
from repro.sensing import adc as adc_sim
from repro.sensing import stream as stream_mod
from repro.sensing.stream import (StreamState, adc_view, adc_view_codes,
                                  init_stream_state, model_geometry,
                                  super_chunk_fn, super_chunk_step)

Array = jax.Array


def _sensor_axes(mesh) -> tuple[tuple[str, ...] | None, int]:
    """("sensors" mesh axes or None, their total extent k).

    Padding-aware: resolved via :func:`repro.distributed.sharding.
    mesh_extent`, which keeps non-divisible axes — the fleet pads S up
    to a multiple of ``k`` with masked slots instead of ever falling
    back to an unsharded step.
    """
    if mesh is None:
        return None, 1
    axes, k = shlib.mesh_extent("sensors", mesh)
    return (axes or None), k


def _hyperdim_axes(mesh, tiles, backend: str,
                   precision: str) -> tuple[str, ...] | None:
    """Mesh axes the "hyperdim" (D-tile) dim shards over, or None.

    The float ``jnp`` backend has no tiled scorer, so only the
    ``pallas`` backend and the integer precisions (whose jnp oracle is
    tiled) can partition D. A tile count the mesh extent doesn't divide
    falls back to replicated tiles (the :func:`spec_for` divisibility
    rule) — sensors-only sharding still applies.
    """
    if mesh is None or tiles is None:
        return None
    if backend != "pallas" and precision not in adc_sim.INT_PRECISIONS:
        return None
    geom = getattr(tiles, "geom", tiles)
    slabs = geom.slabs_q if hasattr(geom, "slabs_q") else geom.slabs
    part = shlib.spec_for((slabs.shape[0],), ("hyperdim",), mesh)
    if not part or part[0] is None:
        return None
    ax = part[0]
    return ax if isinstance(ax, tuple) else (ax,)


def _tiles_specs(tiles, hd: tuple[str, ...] | None):
    """PartitionSpec pytree for the step's ``tiles`` argument.

    Only the D-tile-leading arrays (slabs, bias/idx, class tiles) shard
    over the hyperdim axes; window masks, scales and the full-D class
    norms stay replicated — norms ARE full-D quantities, which is what
    keeps the sharded cosine epilogue exact. Built by
    ``dataclasses.replace`` on the live tiles instance so static fields
    (and hence the pytree structure) match the argument exactly.
    """
    if tiles is None:
        return None
    hd3 = P(hd, None, None) if hd else P()
    rep = P()

    def geom_specs(g):
        if hasattr(g, "slabs_q"):
            return dataclasses.replace(g, slabs_q=hd3, win_mask=rep,
                                       bias_t=hd3, idx=hd3, slab_scale=rep)
        return dataclasses.replace(g, slabs=hd3, bias_t=hd3, idx=hd3)

    if hasattr(tiles, "geom"):
        cls = (P(None, hd, None, None) if hd else P()) \
            if tiles.cpos_t.ndim == 4 else hd3
        return dataclasses.replace(tiles, geom=geom_specs(tiles.geom),
                                   cpos_t=cls, cneg_t=cls,
                                   cpos_norm=rep, cneg_norm=rep)
    return geom_specs(tiles)


def _build_step(mesh, axes, hd_axes, tiles_spec, donate: bool = False,
                **static):
    """Fleet step callable: the shared module-level jit, or shard_map'd.

    Unsharded, this is just :func:`repro.sensing.stream.super_chunk_step`
    with the static config bound — every runner shares its global trace
    cache. ``donate=True`` (the always-on serving layer,
    :class:`repro.launch.serve.FleetService`) switches to the donated
    twin ``super_chunk_step_donated``: the carried ``StreamState``
    pytree is donated to XLA so a service that steps forever rolls one
    state allocation instead of reallocating per chunk — callers must
    never re-read a donated input after the call.
    Under a mesh, the raw step body is ``shard_map``'d over BOTH
    logical axes — sensors (streams partition like a batch) and hyperdim
    (each device holds a contiguous D-shard of slabs + class tiles) —
    and jitted per (mesh, axes, tiles structure).

    Collectives, all inside the step body and all order-preserving:

    * the scorer's tile fold all_gathers per-tile partials over
      ``hd_axes`` before a fixed left-to-right reduction
      (``sliding_scores._ordered_tile_fold``) — bitwise-equal to the
      single-device epilogue;
    * a shared-scope online update all_gathers the masked samples over
      ``axes`` and replays the identical sequential fold on every
      device (``stream.super_chunk_fn._shared_fold``) — the former
      "falls back to unsharded" case, now sharded and still bitwise.

    ``check_rep=False`` because replicated outputs (shared classifiers)
    are produced by identical replicated folds the checker can't see
    through.
    """
    if axes is None and hd_axes is None:
        return functools.partial(
            stream_mod.super_chunk_step_donated if donate
            else super_chunk_step, **static)
    from jax.experimental.shard_map import shard_map
    s4, s3, s2, s1 = (P(axes, None, None, None), P(axes, None, None),
                      P(axes, None), P(axes))
    rep = P()
    per_stream = (static.get("adapt") is not None
                  and static["adapt"].scope == "per-stream")
    state_in = StreamState(class_hvs=s3 if per_stream else rep,
                           holds=s1, phases=s1, frame_idx=rep)
    return jax.jit(shard_map(
        functools.partial(super_chunk_fn, sensor_axes=axes,
                          hyperdim_axes=hd_axes, **static), mesh=mesh,
        in_specs=(s4, state_in, rep, rep, tiles_spec, rep, rep, s2, s1),
        out_specs=(s2, s2, s2, s2, state_in),
        check_rep=False), donate_argnums=(1,) if donate else ())


@dataclasses.dataclass(frozen=True)
class FleetReport:
    """Per-stream stats + fleet-aggregate energy accounting."""
    stats: list[StreamStats]              # one per sensor stream
    n_frames: int                         # frames per stream
    duty_cycle: float                     # fleet-mean fraction gated on
    energy_per_frame: energy.EnergyBreakdown  # fleet-mean, HyperSense path
    energy_total_j: float                 # fleet total over all frames
    baseline_total_j: float               # always-on conventional fleet

    @property
    def n_sensors(self) -> int:
        return len(self.stats)

    @property
    def total_saving(self) -> float:
        return 1.0 - self.energy_total_j / self.baseline_total_j


def fleet_report(fired, gated, labels,
                 params: energy.EnergyParams | None = None,
                 precision: str = "float32",
                 capture: CaptureLog | None = None) -> FleetReport:
    """(S, N) gate decisions -> per-stream stats + fleet energy account.

    With a ``capture`` log (the runners maintain one) the fleet is billed
    from what the ADCs *actually* converted and transmitted
    (:func:`repro.core.energy.from_capture_log`) — the primary account:
    closed-loop idle subsampling shows up as real Joules saved, which the
    duty-fraction approximation structurally cannot see. Without one,
    each stream is billed at its own *measured* duty cycle
    (:func:`repro.core.energy.hypersense_measured`, every frame assumed
    LP-converted — exactly what the capture log degenerates to in
    open-loop mode). The baseline is the conventional always-on pipeline
    on every stream. ``precision`` is the datapath the gate actually ran
    on — the integer precisions bill the always-on HDC work at their
    reduced per-precision cost (``EnergyParams.hdc_*_factor``).
    """
    params = params or energy.EnergyParams()
    stats = stats_from_batch(fired, gated, labels)
    n = int(np.asarray(fired).shape[1])
    duty = float(np.mean([s.duty_cycle for s in stats]))
    if capture is not None:
        mean = energy.from_capture_log(capture, params, precision)
        total = mean.total * len(stats) * n
    else:
        per_stream = [energy.hypersense_measured(s.duty_cycle, params,
                                                 precision)
                      for s in stats]
        total = sum(b.total for b in per_stream) * n
        mean = energy.hypersense_measured(duty, params, precision)
    base = energy.conventional(params).total * len(stats) * n
    return FleetReport(stats=stats, n_frames=n, duty_cycle=duty,
                       energy_per_frame=mean, energy_total_j=float(total),
                       baseline_total_j=float(base))


class FleetRunner:
    """Stateful fleet scorer+gate: ``process((S, n, H, W))`` incrementally.

    Semantically S independent :class:`~repro.sensing.stream.StreamRunner`
    instances — per-stream scores/fired/gated are asserted identical in
    ``tests/test_fleet.py`` — executed as one batched pipeline: each
    ``(S, chunk_size)`` super-chunk is a single jitted step (one kernel
    launch on the ``pallas`` backend) and the ``(S,)`` hold vector carries
    across ``process`` calls.

    ``adc_bits`` puts the simulated low-precision ADC in front of the
    gate; noise (``adc_sigma > 0``) is keyed per (stream, absolute frame
    index), so stream slicing stays invisible. Under an active
    :func:`repro.distributed.sharding.use_mesh` (or an explicit ``mesh=``)
    the sensor axis is ``shard_map``'d across the mesh axes the "sensors"
    rule resolves to.

    ``adapt`` switches on online learning
    (:class:`~repro.core.online.AdaptConfig`): ``scope="shared"`` folds
    every stream's samples (time-ordered) into ONE fleet classifier;
    ``scope="per-stream"`` gives each sensor its own ``(S, 2, D)``
    classifier — updates are ``vmap``'d over streams, scoring stays one
    kernel launch (stream-indexed class-tile BlockSpecs), and the sharded
    step continues to partition cleanly (no collectives). Shared-scope
    updates shard too: the step all_gathers every shard's masked samples
    and replays the identical time-ordered fold on each device, so the
    shared classifier stays replicated and bitwise-equal to unsharded.

    ``control=`` (:class:`~repro.core.sensor_control.CaptureConfig`)
    closes each stream's capture loop independently: per-stream
    ``(hold, phase)`` ADC state rides the same sharded
    :class:`~repro.sensing.stream.StreamState` (still no collectives —
    the control scan is per-stream), idle frames are subsampled to
    ``base_rate_hz``, and gated bursts are HP-captured into per-stream
    bounded buffers (:meth:`drain_hp`). The fleet's
    :attr:`capture_log` is the ``(S, N)`` billing ground truth
    :func:`fleet_report` prefers over the duty-cycle approximation.
    """

    def __init__(self, model: HyperSenseModel,
                 config: ControllerConfig | None = None, *,
                 chunk_size: int = 32, backend: str = "jnp",
                 t_detection: int | None = None, block_d: int = 512,
                 adc_bits: int | None = None, adc_sigma: float = 0.0,
                 adc_key: Array | int = 0, mesh=None,
                 adapt: AdaptConfig | None = None,
                 precision: str = "float32",
                 control: CaptureConfig | None = None):
        stream_mod.validate_runner_args(chunk_size, adc_bits, adc_sigma,
                                        precision)
        self.precision = precision
        self.model = model
        self.config = config or ControllerConfig()
        self.chunk_size = chunk_size
        self.backend = backend
        self.block_d = block_d
        self.t_detection = (model.t_detection if t_detection is None
                            else t_detection)
        self.adc_bits = adc_bits
        self.adc_sigma = adc_sigma
        self._adc_key = (jax.random.PRNGKey(adc_key)
                         if isinstance(adc_key, int) else adc_key)
        self._mesh = mesh
        self.adapt = adapt
        self.control = control
        self._decim = (None if control is None
                       else (decimation(self.config) if control.subsample
                             else 1))
        self._geom = None       # (W, ScoreGeometry) — class-independent
        self._tiles = None      # (W, class_hvs-ref, ScoreTiles) frozen path
        self._state = None      # StreamState, allocated on first process()
        self._n_seen = 0
        self._step = None
        self._step_key = None
        self._log_sampled: list[np.ndarray] = []   # (S, chunk) blocks
        self._log_gated: list[np.ndarray] = []
        self._frame_pixels = 0
        self._frame_hw: tuple[int, int] | None = None
        self._hp: list[list] = []   # per stream: [(abs_idx, frame), ...]
        self.hp_dropped = 0

    def reset(self) -> None:
        self._state = None
        self._n_seen = 0
        self._tiles = None
        self._log_sampled = []
        self._log_gated = []
        self._hp = []
        self.hp_dropped = 0

    @property
    def holds(self) -> Array | None:
        """(S,) controller hold state after the last processed frame."""
        return None if self._state is None else self._state.holds

    @property
    def class_hvs(self) -> Array:
        """The live classifier: ``(2, D)`` shared, ``(S, 2, D)`` per-stream
        (before the first ``process`` call: the model's)."""
        return (self.model.class_hvs if self._state is None
                else self._state.class_hvs)

    def set_class_hvs(self, class_hvs: Array) -> None:
        """Install an externally updated classifier mid-stream.

        Accepts ``(2, D)`` (broadcast to every stream in per-stream
        scope) or ``(S, 2, D)`` in per-stream scope. Device-side cost
        only — next chunk re-tiles via the jitted ``retile_classes``; the
        identity-keyed tile cache self-invalidates.
        """
        class_hvs = jnp.asarray(class_hvs)
        if class_hvs.ndim == 3 and not self._per_stream():
            raise ValueError("(S, 2, D) classifiers need "
                             'adapt scope="per-stream"')
        if class_hvs.ndim == 2:
            self.model = self.model._replace(class_hvs=class_hvs)
        if self._state is None:
            if class_hvs.ndim == 3:
                # fleet size is fixed by the stack; allocate state now so
                # the per-stream classifiers are not silently dropped
                self._state = init_stream_state(
                    class_hvs, class_hvs.shape[0], per_stream=True)
            return  # ndim == 2: first process() initializes from model
        chvs = class_hvs
        if self._state.class_hvs.ndim == 3 and chvs.ndim == 2:
            chvs = jnp.broadcast_to(chvs, self._state.class_hvs.shape)
        if chvs.shape != self._state.class_hvs.shape:
            raise ValueError(f"class_hvs shape {chvs.shape} != carried "
                             f"state {self._state.class_hvs.shape}")
        self._state = dataclasses.replace(self._state, class_hvs=chvs)

    def _per_stream(self) -> bool:
        return self.adapt is not None and self.adapt.scope == "per-stream"

    def _ensure_geom(self, W: int):
        if self._geom is None or self._geom[0] != W:
            self._geom = (W, model_geometry(self.model, W, self.block_d,
                                            self.precision))
        return self._geom[1]

    def _ensure_tiles(self, W: int):
        """Frozen-path tile cache, keyed on (width, class-hv identity)."""
        from repro.kernels import ops as kops
        retile = (kops.retile_classes_int
                  if self.precision in adc_sim.INT_PRECISIONS
                  else kops.retile_classes)
        chvs = self._state.class_hvs
        if (self._tiles is None or self._tiles[0] != W
                or self._tiles[1] is not chvs):
            self._tiles = (W, chvs, retile(self._ensure_geom(W), chvs))
        return self._tiles[2]

    @property
    def _adc_lsb(self) -> float:
        return (adc_sim.lsb(self.adc_bits)
                if self.precision in adc_sim.INT_PRECISIONS else 1.0)

    @property
    def capture_log(self) -> CaptureLog:
        """(S, N) record of what each stream's ADC actually converted —
        the billing ground truth :func:`fleet_report` prefers."""
        return assemble_capture_log(self._log_sampled, self._log_gated,
                                    lp_bits=self.adc_bits,
                                    control=self.control,
                                    frame_pixels=self._frame_pixels,
                                    axis=1)

    def drain_hp(self) -> list[tuple[np.ndarray, np.ndarray]]:
        """Per-stream HP burst deliverables captured so far.

        Returns one ``(indices (M_s,), frames (M_s, H, W))`` pair per
        stream (absolute frame indices; frames at ``control.hp_bits``)
        and empties the buffers. Per-chunk buffer overflows are counted
        fleet-wide in ``hp_dropped``. Empty drains keep the real
        ``(0, H, W)`` frame shape
        (:func:`~repro.sensing.stream.hp_drain_arrays`) so per-stream
        cross-drain concatenation works.
        """
        out = [stream_mod.hp_drain_arrays(entries, self._frame_hw)
               for entries in self._hp]
        self._hp = [[] for _ in self._hp]
        return out

    def _ensure_step(self, tiles):
        """Step callable + the sensor-axis extent k (S pads to k·⌈S/k⌉).

        Cached per (mesh, resolved axes, adapt config, tiles pytree
        structure) — a new tiles *instance* (every frozen-cache refresh)
        reuses the step as long as its structure is unchanged, so
        sharding never causes per-chunk retraces. Shared-scope
        adaptation shards like everything else (the step all_gathers the
        samples and folds replicated); there is no unsharded fallback.
        """
        mesh = self._mesh if self._mesh is not None else shlib.current_mesh()
        axes, k = _sensor_axes(mesh)
        hd_axes = _hyperdim_axes(mesh, tiles, self.backend, self.precision)
        key = (id(mesh) if (axes or hd_axes) else None, axes, hd_axes,
               self.adapt, jax.tree_util.tree_structure(tiles))
        if self._step is None or self._step_key != key:
            m = self.model
            self._step = _build_step(
                mesh, axes, hd_axes, _tiles_specs(tiles, hd_axes),
                h=m.h, w=m.w, stride=m.stride,
                nonlinearity=m.nonlinearity, t_detection=self.t_detection,
                hold_frames=self.config.hold_frames, backend=self.backend,
                adapt=self.adapt, precision=self.precision,
                adc_lsb=self._adc_lsb, decim=self._decim)
            self._step_key = key
        return self._step, k

    def process(self, frames, labels=None
                ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(S, n, H, W) super-stream -> ((S, n) scores, fired, gated).

        ``labels`` (``(S, n)`` ints) feeds ``adapt.mode == "label"``
        updates.
        """
        frames = jnp.asarray(frames)
        if frames.ndim != 4:
            raise ValueError(f"expected (S, n, H, W) frames, "
                             f"got shape {frames.shape}")
        S, n = frames.shape[:2]
        raw = frames
        self._frame_pixels = int(frames.shape[-2] * frames.shape[-1])
        self._frame_hw = (int(frames.shape[-2]), int(frames.shape[-1]))
        hp_k = stream_mod.resolve_hp_buffer(self.control, self.chunk_size,
                                            frames.dtype)
        if not self._hp:
            self._hp = [[] for _ in range(S)]
        base = self._n_seen
        if self.adapt is not None and self.adapt.mode == "label":
            if labels is None:
                raise ValueError('adapt.mode == "label" needs per-frame '
                                 "labels passed to process()")
            labels = jnp.asarray(labels, jnp.int32)
            if labels.shape != (S, n):
                raise ValueError(f"labels shape {labels.shape} != "
                                 f"(S, n) = {(S, n)}")
        if self._state is None:
            self._state = init_stream_state(self.model.class_hvs, S,
                                            per_stream=self._per_stream())
        elif self._state.holds.shape[0] != S:
            raise ValueError(f"fleet size changed: carried state has "
                             f"{self._state.holds.shape[0]} streams, "
                             f"got {S}")
        if self.precision in adc_sim.INT_PRECISIONS:
            from repro.kernels import ops as kops
            kops.assert_int_datapath_fits(self.adc_bits, *frames.shape[-2:],
                                          self.model.h, self.model.w,
                                          stride=self.model.stride,
                                          block_d=self.block_d,
                                          packed=self.precision == "int4")
            if jnp.issubdtype(frames.dtype, jnp.integer):
                # already-converted codes: concrete range check + pack
                # (sigma forwarded so configured noise can't silently
                # drop — integer input + sigma > 0 raises, as on
                # StreamRunner)
                frames = adc_view_codes(frames, self.adc_bits,
                                        sigma=self.adc_sigma)
            else:
                keys = jax.vmap(
                    lambda s: jax.random.fold_in(self._adc_key, s))(
                        jnp.arange(S))
                frames = jax.vmap(lambda k, f: adc_view_codes(
                    f, self.adc_bits, sigma=self.adc_sigma, key=k,
                    start_index=self._n_seen))(keys, frames)
        elif self.adc_bits is not None:
            keys = jax.vmap(
                lambda s: jax.random.fold_in(self._adc_key, s))(
                    jnp.arange(S))
            frames = jax.vmap(lambda k, f: adc_view(
                f, self.adc_bits, sigma=self.adc_sigma, key=k,
                start_index=self._n_seen))(keys, frames)
        self._n_seen += n

        m = self.model
        if (self.backend == "pallas"
                or self.precision in adc_sim.INT_PRECISIONS):
            tiles = (self._ensure_geom(frames.shape[-1])
                     if self.adapt is not None
                     else self._ensure_tiles(frames.shape[-1]))
        else:
            tiles = None
        step, k = self._ensure_step(tiles)
        # Pad the sensor axis to the mesh extent with masked slots: the
        # padded step shards for ANY S (never a recompile per S, never an
        # unsharded fallback); masked slots are exact no-ops on every
        # real slot (tests/test_fleet.py pins S=5/S=9 on 8 devices
        # bitwise). Carried state stays at the real S.
        S_pad = -(-S // k) * k
        slot_mask = jnp.arange(S_pad) < S
        scores = np.empty((S, n), np.float32)
        fired = np.empty((S, n), bool)
        gated = np.empty((S, n), bool)
        for start in range(0, n, self.chunk_size):
            chunk = frames[:, start:start + self.chunk_size]
            lab = (labels[:, start:start + self.chunk_size]
                   if labels is not None
                   else jnp.zeros(chunk.shape[:2], jnp.int32))
            n_valid = chunk.shape[1]
            if n_valid < self.chunk_size:
                pad = self.chunk_size - n_valid
                chunk = jnp.pad(chunk, ((0, 0), (0, pad), (0, 0), (0, 0)))
                lab = jnp.pad(lab, ((0, 0), (0, pad)))
            state = self._state
            if S_pad != S:
                pad_s = S_pad - S
                chunk = jnp.pad(chunk,
                                ((0, pad_s),) + ((0, 0),) * 3)
                lab = jnp.pad(lab, ((0, pad_s), (0, 0)))
                chvs = state.class_hvs
                if chvs.ndim == 3:
                    # pad slots carry (discarded) copies of the model's
                    # classifier — real values, so retiling them can
                    # never poison a shared kernel launch with NaNs
                    chvs = jnp.concatenate(
                        [chvs, jnp.broadcast_to(
                            self.model.class_hvs,
                            (pad_s,) + self.model.class_hvs.shape)], 0)
                state = StreamState(
                    class_hvs=chvs,
                    holds=jnp.pad(state.holds, (0, pad_s)),
                    phases=jnp.pad(state.phases, (0, pad_s)),
                    frame_idx=state.frame_idx)
            s, f, g, smp, new_state = step(
                chunk, state, m.B0, m.b, tiles,
                jnp.float32(m.t_score), jnp.int32(n_valid), lab, slot_mask)
            if S_pad != S:
                s, f, g, smp = s[:S], f[:S], g[:S], smp[:S]
                new_state = StreamState(
                    class_hvs=(new_state.class_hvs[:S]
                               if new_state.class_hvs.ndim == 3
                               else new_state.class_hvs),
                    holds=new_state.holds[:S],
                    phases=new_state.phases[:S],
                    frame_idx=new_state.frame_idx)
            if self.adapt is None:
                # keep the ORIGINAL class-hv ref: values are untouched and
                # the identity-keyed tile cache must not churn
                new_state = dataclasses.replace(
                    new_state, class_hvs=self._state.class_hvs)
            self._state = new_state
            sl = slice(start, start + n_valid)
            scores[:, sl] = np.asarray(s)[:, :n_valid]
            fired[:, sl] = np.asarray(f)[:, :n_valid]
            gated[:, sl] = np.asarray(g)[:, :n_valid]
            self._log_sampled.append(np.asarray(smp)[:, :n_valid])
            self._log_gated.append(gated[:, sl].copy())
            if hp_k > 0:
                raw_chunk = raw[:, start:start + self.chunk_size]
                if n_valid < self.chunk_size:
                    raw_chunk = jnp.pad(
                        raw_chunk, ((0, 0), (0, self.chunk_size - n_valid),
                                    (0, 0), (0, 0)))
                entries, dropped = stream_mod.collect_hp(
                    raw_chunk, g, n_valid, hp_k, self.control.hp_bits,
                    base + start)
                for si in range(S):
                    self._hp[si].extend(entries[si])
                self.hp_dropped += dropped
        return scores, fired, gated


def simulate_fleet(model: HyperSenseModel, frames, labels,
                   config: ControllerConfig | None = None, *,
                   chunk_size: int = 32, backend: str = "jnp",
                   t_detection: int | None = None, block_d: int = 512,
                   adc_bits: int | None = None, adc_sigma: float = 0.0,
                   adc_key: Array | int = 0, mesh=None,
                   adapt: AdaptConfig | None = None,
                   energy_params: energy.EnergyParams | None = None,
                   precision: str = "float32",
                   control: CaptureConfig | None = None) -> FleetReport:
    """Run a whole ``(S, N, H, W)`` fleet recording end-to-end.

    One :class:`FleetRunner` pass followed by :func:`fleet_report`:
    per-stream :class:`StreamStats` (identical to S independent
    single-stream simulations) plus the fleet energy account, billed
    from the runner's capture log (the per-frame conversions actually
    made — with ``control=`` the closed loop's savings are real Joules
    here, not a duty-cycle estimate). ``adapt`` switches on online
    learning; in ``"label"`` mode the ground-truth ``labels`` double as
    the feedback signal.
    """
    runner = FleetRunner(model, config, chunk_size=chunk_size,
                         backend=backend, t_detection=t_detection,
                         block_d=block_d, adc_bits=adc_bits,
                         adc_sigma=adc_sigma, adc_key=adc_key, mesh=mesh,
                         adapt=adapt, precision=precision, control=control)
    feed = (labels if adapt is not None and adapt.mode == "label"
            else None)
    _, fired, gated = runner.process(frames, labels=feed)
    return fleet_report(fired, gated, labels, energy_params, precision,
                        capture=runner.capture_log)
