"""Baseline detectors the paper compares against (Table I, Figs. 11, 16).

* ``MLP`` — the "small multi-layer perceptron" baselines (2 and 4 layers).
* ``TinyConv`` — a YOLOv4-tiny stand-in: a small conv backbone + detection
  head, sized to a few M parameters. The real YOLOv4-tiny (CSP backbone,
  anchors) is out of scope for a radar-presence task; the paper itself uses
  it only as a presence score source, so a conv detector of the same
  capacity class is the honest equivalent. Its relative behaviour on radar
  data (weakest high-TPR ROC region, Table I) reproduces.

Both are written in pure JAX (pytrees of params + apply fns) and trained
with ``repro.train.optim.AdamW``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.train import optim

Array = jax.Array


def _dense_init(key, n_in, n_out):
    wkey, _ = jax.random.split(key)
    scale = jnp.sqrt(2.0 / n_in)
    return {"w": scale * jax.random.normal(wkey, (n_in, n_out)),
            "b": jnp.zeros((n_out,))}


def init_mlp(key: Array, n_in: int, hidden: int = 256,
             n_layers: int = 2) -> list[dict]:
    """n_layers counts hidden layers + output layer (paper: 2 and 4)."""
    sizes = [n_in] + [hidden] * (n_layers - 1) + [2]
    keys = jax.random.split(key, len(sizes) - 1)
    return [_dense_init(k, a, b)
            for k, a, b in zip(keys, sizes[:-1], sizes[1:])]


def mlp_apply(params: list[dict], x: Array) -> Array:
    """(N, n_in) -> (N, 2) logits."""
    h = x.reshape(x.shape[0], -1)
    h = h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-8)
    for i, layer in enumerate(params):
        h = h @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    return h


def _conv_init(key, kh, kw, cin, cout):
    scale = jnp.sqrt(2.0 / (kh * kw * cin))
    return {"w": scale * jax.random.normal(key, (kh, kw, cin, cout)),
            "b": jnp.zeros((cout,))}


def init_tiny_conv(key: Array, channels: tuple[int, ...] = (16, 32, 64)
                   ) -> dict:
    keys = jax.random.split(key, len(channels) + 1)
    convs = []
    cin = 1
    for k, cout in zip(keys[:-1], channels):
        convs.append(_conv_init(k, 3, 3, cin, cout))
        cin = cout
    head = _dense_init(keys[-1], cin, 2)
    return {"convs": convs, "head": head}


def tiny_conv_apply(params: dict, x: Array) -> Array:
    """(N, h, w) -> (N, 2) logits: conv/pool tower + GAP head."""
    h = x[..., None]
    for conv in params["convs"]:
        h = jax.lax.conv_general_dilated(
            h, conv["w"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC")) + conv["b"]
        h = jax.nn.relu(h)
        h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                  (1, 2, 2, 1), "VALID")
    h = jnp.mean(h, axis=(1, 2))                    # global average pool
    return h @ params["head"]["w"] + params["head"]["b"]


# ---------------------------------------------------------------------------
# Shared trainer
# ---------------------------------------------------------------------------

def _xent(logits: Array, labels: Array) -> Array:
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))


def train_classifier(key: Array, params, apply_fn, frags: Array,
                     labels: Array, *, epochs: int = 30,
                     batch_size: int = 64, lr: float = 1e-3):
    """Minibatch AdamW training; returns trained params."""
    opt = optim.AdamW(lr=lr, weight_decay=1e-4)
    opt_state = opt.init(params)
    n = frags.shape[0]
    steps = max(n // batch_size, 1)

    @jax.jit
    def step(params, opt_state, xb, yb):
        def loss_fn(p):
            return _xent(apply_fn(p, xb), yb)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        updates, opt_state = opt.update(grads, opt_state, params)
        return optim.apply_updates(params, updates), opt_state, loss

    for e in range(epochs):
        perm = jax.random.permutation(jax.random.fold_in(key, e), n)
        for i in range(steps):
            idx = perm[i * batch_size:(i + 1) * batch_size]
            params, opt_state, loss = step(params, opt_state,
                                           frags[idx], labels[idx])
    return params


def positive_score(apply_fn, params, frags: Array) -> Array:
    """Detection score: logit margin (same convention as the HDC model)."""
    logits = apply_fn(params, frags)
    return logits[:, 1] - logits[:, 0]
