"""Fragment dataset generation (paper §III-C step 1).

From a frame dataset with object masks, sample balanced positive fragments
(window contains an object center) and negative fragments (window is
object-free), matching the paper: "random sampling positive and negative
fragments from each frame ... it is also important to balance the number of
negative and positive samples."
"""

from __future__ import annotations

import numpy as np

Array = np.ndarray


def sample_fragments(frames, masks, *, h: int, w: int,
                     per_frame: int = 2, seed: int = 0
                     ) -> tuple[Array, Array]:
    """Balanced fragment dataset ``(frags (N,h,w), labels (N,))``.

    numpy-side (data pipeline, not jit) — runs once per training job.
    """
    frames = np.asarray(frames)
    masks = np.asarray(masks)
    rng = np.random.default_rng(seed)  # repro-lint: disable=RA002 (host-side training-data sampler, explicitly seeded; runs once per job, never under jit)
    H, W = frames.shape[1:]
    frags, labels = [], []

    for f, m in zip(frames, masks):
        ys, xs = np.nonzero(m > 0.5)
        has_obj = len(ys) > 0
        for _ in range(per_frame):
            if has_obj:
                # positive: window covering a random object pixel
                i = rng.integers(len(ys))
                cy = int(np.clip(ys[i] - rng.integers(h), 0, H - h))
                cx = int(np.clip(xs[i] - rng.integers(w), 0, W - w))
                window_mask = m[cy:cy + h, cx:cx + w]
                if window_mask.sum() > 0:
                    frags.append(f[cy:cy + h, cx:cx + w])
                    labels.append(1)
            # negative: rejection-sample an object-free window
            for _attempt in range(20):
                cy = int(rng.integers(0, H - h + 1))
                cx = int(rng.integers(0, W - w + 1))
                if masks is None or m[cy:cy + h, cx:cx + w].sum() == 0:
                    frags.append(f[cy:cy + h, cx:cx + w])
                    labels.append(0)
                    break

    frags = np.stack(frags).astype(np.float32)
    labels = np.asarray(labels, dtype=np.int32)

    # balance classes by subsampling the majority
    pos_idx = np.nonzero(labels == 1)[0]
    neg_idx = np.nonzero(labels == 0)[0]
    n = min(len(pos_idx), len(neg_idx))
    if n == 0:
        return frags, labels
    keep = np.concatenate([rng.permutation(pos_idx)[:n],
                           rng.permutation(neg_idx)[:n]])
    keep = rng.permutation(keep)
    return frags[keep], labels[keep]
