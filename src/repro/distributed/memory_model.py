"""Analytic per-device memory model (companion to memory_analysis()).

XLA:CPU's buffer assignment overestimates the TPU-resident peak (no
bf16-native dynamic-update-slice, weaker fusion, looser liveness — see
EXPERIMENTS.md §Dry-run caveats), so the fit-proof combines the compiled
``memory_analysis()`` with this analytic model computed from the *actual
shardings* the cell lowers with:

train:   params(fp32) + adam(mu,nu fp32) + grads(fp32, transient)
         + saved residuals (L x b_loc x s_shard x d, bf16, seq-parallel)
         + max transient (attention block scores / MoE buffers / loss chunk)
decode:  params(bf16-equivalent) + decode state + small transients
prefill: params + live activations (one layer) + logits
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax

from repro.distributed import sharding as shlib
from repro.models import common, lm


def _shards(mesh, spec) -> int:
    n = 1
    flat = []
    for p in spec:
        if p is None:
            continue
        if isinstance(p, (tuple, list)):
            flat.extend(p)
        else:
            flat.append(p)
    for ax in flat:
        n *= mesh.shape[ax]
    return n


def _tree_bytes_per_device(spec_tree, mesh, rules, bytes_per_el: int) -> int:
    total = 0
    leaves = jax.tree.leaves(spec_tree,
                             is_leaf=lambda x: isinstance(x, common.P))
    for p in leaves:
        sh = shlib.spec_for(p.shape, p.axes, mesh, rules)
        total += math.prod(p.shape) * bytes_per_el // _shards(mesh, sh)
    return total


@dataclass
class MemoryBreakdown:
    params_gb: float
    opt_state_gb: float
    grads_gb: float
    residuals_gb: float
    transient_gb: float
    state_gb: float = 0.0
    detail: dict = field(default_factory=dict)

    @property
    def total_gb(self) -> float:
        return (self.params_gb + self.opt_state_gb + self.grads_gb
                + self.residuals_gb + self.transient_gb + self.state_gb)

    @property
    def fits_v5e(self) -> bool:
        return self.total_gb <= 16.0


def analyze(cfg, shape, mesh, rules=None) -> MemoryBreakdown:
    model = lm.build(cfg)
    spec = model.spec()
    rules = dict(shlib.DEFAULT_RULES, **(rules or {}))

    mesh_axes = mesh.shape
    model_deg = mesh_axes.get("model", 1)
    data_deg = mesh_axes.get("data", 1) * mesh_axes.get("pod", 1)

    p32 = _tree_bytes_per_device(spec, mesh, rules, 4)
    b = shape.global_batch
    s = shape.seq_len
    d = cfg.d_model
    b_loc = max(b // data_deg, 1)

    if shape.kind == "train":
        params = p32
        opt = 2 * p32
        grads = p32
        s_shard = max(s // model_deg, 1) if s % model_deg == 0 else s
        resid = cfg.n_layers * b_loc * s_shard * d * 2
        h_loc = max(cfg.n_heads // model_deg, 1)
        qc = min(1024, s)
        attn_t = 2 * b_loc * h_loc * qc * s * 4          # scores + attn
        v_loc = max(cfg.vocab // model_deg, 1) if cfg.vocab % model_deg == 0 \
            else cfg.vocab
        loss_t = 3 * b_loc * min(1024, s) * v_loc * 4
        moe_t = 0
        if cfg.n_experts:
            n_tok = b_loc * s
            cap = int(n_tok * cfg.top_k * cfg.capacity_factor
                      / cfg.n_experts)
            e_loc = max(cfg.n_experts // model_deg, 1) \
                if cfg.n_experts % model_deg == 0 else cfg.n_experts
            cap_loc = cap if cfg.n_experts % model_deg == 0 \
                else max(cap // model_deg, 1)
            moe_t = 3 * e_loc * cap_loc * max(cfg.d_ff, d) * 2
        transient = max(attn_t, loss_t, moe_t) + 2 * b_loc * s * d * 2
        return MemoryBreakdown(
            params_gb=params / 1e9, opt_state_gb=opt / 1e9,
            grads_gb=grads / 1e9, residuals_gb=resid / 1e9,
            transient_gb=transient / 1e9,
            detail={"attn_t_gb": attn_t / 1e9, "loss_t_gb": loss_t / 1e9,
                    "moe_t_gb": moe_t / 1e9})

    # inference: bf16-weights footprint
    params = p32 // 2
    state_bytes = 0
    if shape.kind == "decode":
        st_spec = model.decode_state_spec(batch=b, max_seq=s)
        from repro.launch.steps import _decode_state_axes
        axes = _decode_state_axes(model)
        def is_axes(x):
            return (isinstance(x, tuple)
                    and all(a is None or isinstance(a, str) for a in x))

        flat_s = jax.tree.leaves(
            st_spec, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        flat_a = jax.tree.leaves(axes, is_leaf=is_axes)
        for sds, ax in zip(flat_s, flat_a):
            sh = shlib.spec_for(sds.shape, tuple(ax), mesh, rules)
            state_bytes += (math.prod(sds.shape) * sds.dtype.itemsize
                            // _shards(mesh, sh))
        transient = b_loc * d * 4 * 8
    else:  # prefill
        transient = (2 * b_loc * s * d * 2
                     + b_loc * max(cfg.n_heads // model_deg, 1)
                     * min(1024, s) * s * 4)
        v_loc = max(cfg.vocab // model_deg, 1) \
            if cfg.vocab % model_deg == 0 else cfg.vocab
        transient += b_loc * s * v_loc * 2     # output logits
    return MemoryBreakdown(
        params_gb=params / 1e9, opt_state_gb=0.0, grads_gb=0.0,
        residuals_gb=0.0, transient_gb=transient / 1e9,
        state_gb=state_bytes / 1e9)
