"""Distribution: logical-axis sharding rules, mesh helpers."""

from repro.distributed import sharding  # noqa: F401
