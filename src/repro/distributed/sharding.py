"""Logical-axis sharding (MaxText-style rules; DESIGN.md §5).

Every parameter/activation dimension carries a *logical* name ("embed",
"mlp", "heads", "act_batch", ...). A rules table maps logical names to mesh
axes. Hillclimbing a sharding = editing rules, never editing models.

Usage::

    with use_mesh(mesh, rules):
        y = model.apply(params, x)   # shard(...) constraints activate

Outside a mesh context every helper is a no-op, so single-device smoke
tests run the exact same model code.

The sensor fleet (``repro.sensing.fleet``) rides the same table as a 2-D
logical mesh: ``"sensors"`` partitions the stream axis over the data
mesh axes (``mesh_extent`` reports the raw extent so the fleet can PAD a
non-divisible S with masked slots) and ``"hyperdim"`` partitions the
kernels' hypervector-tile axis over the model axes (``spec_for`` drops
it when the tile count doesn't divide — graceful replication, never a
wrong answer). See the per-rule comments below and
``tests/test_parity_matrix.py`` for the bitwise-parity contract.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX = threading.local()


# Default rules for the production meshes (("pod",) "data", "model").
# Weights: TP dims over "model", FSDP dim over "data".
# Activations: batch over ("pod","data"); TP'd feature dims over "model".
DEFAULT_RULES: dict[str, tuple[str, ...] | None] = {
    # --- weight dims ---
    "embed": ("pod", "data"),    # FSDP/ZeRO-3: gathered per-layer under
                                 # scan; spans pods on the multi-pod mesh
    "mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "qkv_dim": None,
    "head_dim": None,
    "vocab": ("model",),
    "expert": ("model",),        # expert parallelism
    "expert_mlp": ("model",),    # fallback when n_experts can't take it
                                 # (e.g. grok's 8 experts on a 16-wide axis)
    "ssm_inner": ("model",),
    "ssm_state": None,
    "ssm_heads": ("model",),
    "conv_dim": ("model",),
    "conv_k": None,
    "layers": None,              # scan axis — never sharded
    "norm": None,
    # --- activation dims ---
    "act_batch": ("pod", "data"),
    # Sensor-fleet axis (repro.sensing.fleet): independent streams, so it
    # shards like a batch — data-parallel over pods/hosts, never "model".
    "sensors": ("pod", "data"),
    # Hypervector-dimension axis (repro.kernels.sliding_scores*): the HDC
    # dot products and norms are sums over D, so the D-tile axis (n_dt)
    # partitions like a TP feature dim over "model". Each device holds a
    # contiguous shard of class tiles + slabs; the cosine epilogue's fold
    # runs after a tiled all_gather that restores global tile order, so
    # sharded scores are bitwise-identical to unsharded (see
    # kernels/sliding_scores.py::_ordered_tile_fold).
    "hyperdim": ("model",),
    "act_seq": None,
    # Megatron-style sequence parallelism for the residual stream: layer
    # boundaries (= the per-layer remat checkpoints under scan) are sharded
    # along sequence over "model", shrinking saved residuals by the TP
    # degree. XLA inserts the all-gather at attention/MLP entry — same
    # volume as the TP all-reduce it replaces.
    "act_resid_seq": ("model",),
    "cache_seq": ("model",),     # used only when kv_heads can't take "model"
    "act_expert_cap": ("model",),  # MoE buffer cap dim when experts can't
    "act_embed": None,
    "act_heads": ("model",),
    "act_kv_heads": ("model",),
    "act_mlp": ("model",),
    "act_vocab": ("model",),
    "act_expert": ("model",),
    "act_ssm_heads": ("model",),
    "act_state": None,
}


@contextmanager
def use_mesh(mesh: Mesh, rules: dict | None = None):
    """Activate a mesh + rules for ``shard``/``logical_sharding`` calls."""
    prev = getattr(_CTX, "state", None)
    _CTX.state = (mesh, dict(DEFAULT_RULES, **(rules or {})))
    try:
        with mesh:
            yield
    finally:
        _CTX.state = prev


def current_mesh() -> Mesh | None:
    state = getattr(_CTX, "state", None)
    return state[0] if state else None


def current_rules() -> dict:
    state = getattr(_CTX, "state", None)
    return state[1] if state else dict(DEFAULT_RULES)


def mesh_extent(logical: str, mesh: Mesh | None = None,
                rules: dict | None = None) -> tuple[tuple[str, ...], int]:
    """Mesh axes a logical name maps to, ignoring divisibility.

    Returns ``(axes, k)`` where ``axes`` is the tuple of mesh axes the
    rules table maps ``logical`` to that actually exist in ``mesh`` and
    ``k`` is their total extent (product of sizes; 1 when unmapped or no
    mesh). Unlike :func:`spec_for`, this does NOT drop axes whose size
    fails to divide a dim — callers use it to *pad* a dim up to a
    multiple of ``k`` so the axis always shards (repro.sensing.fleet
    pads the sensor axis S with masked slots instead of falling back to
    an unsharded step).
    """
    mesh = mesh or current_mesh()
    rules = rules or current_rules()
    if mesh is None:
        return (), 1
    mapped = rules.get(logical)
    if mapped is None:
        return (), 1
    if isinstance(mapped, str):
        mapped = (mapped,)
    out = []
    k = 1
    for ax in mapped:
        if ax not in mesh.shape:
            continue
        out.append(ax)
        k *= mesh.shape[ax]
    return tuple(out), k


def padded_extent(n: int, logical: str, mesh: Mesh | None = None,
                  rules: dict | None = None) -> int:
    """Smallest multiple of ``logical``'s mesh extent that is >= ``n``.

    The slot-pool sizing rule: a fixed-capacity pool of ``n`` sensor
    slots (``repro.launch.serve.FleetService``) is rounded up to the
    "sensors" extent ONCE at construction, so the padded slot axis
    shards on any mesh and stream churn (attach/detach/ragged arrival)
    only ever flips ``slot_mask`` bits — array shapes, and hence the
    compiled step, never change. Without a mesh this is the identity.
    """
    _, k = mesh_extent(logical, mesh, rules)
    return -(-max(n, 1) // k) * k


def _axis_for(logical: str | None, rules: dict, mesh: Mesh,
              dim_size: int, taken: set) -> tuple[str, ...] | None:
    """Resolve one logical dim -> mesh axes, dropping non-divisible or
    already-used mesh axes (keeps heterogeneous configs lowering).

    This divisibility drop is the *fallback order* for sharded dims: a
    dim that can't take its mapped axes (size not a multiple) silently
    stays replicated rather than erroring. Callers that would rather pad
    than replicate (the fleet's sensors axis) use :func:`mesh_extent` to
    learn the full extent before resolution.
    """
    if logical is None:
        return None
    mapped = rules.get(logical)
    if mapped is None:
        return None
    if isinstance(mapped, str):
        mapped = (mapped,)
    out = []
    prod = 1
    for ax in mapped:
        if ax not in mesh.shape or ax in taken:
            continue
        n = mesh.shape[ax]
        if dim_size % (prod * n) != 0:
            continue
        out.append(ax)
        prod *= n
    return tuple(out) or None


#: logical names that claim mesh axes BEFORE fallback dims (e.g. the KV-head
#: dim outranks "cache_seq"; the expert dim outranks "act_expert_cap") —
#: fallbacks only shard when the preferred dim couldn't (non-divisible).
PRIORITY_NAMES = ("act_kv_heads", "act_heads", "act_expert", "expert",
                  "kv_heads", "heads", "act_ssm_heads")


def spec_for(shape: Sequence[int], axes: Sequence[str | None],
             mesh: Mesh | None = None, rules: dict | None = None) -> P:
    """PartitionSpec for an array of ``shape`` with logical ``axes``.

    Two-pass resolution: priority names first (so e.g. "act_kv_heads"
    claims "model" when divisible), then the remaining dims in order.
    """
    mesh = mesh or current_mesh()
    rules = rules or current_rules()
    assert len(shape) == len(axes), (shape, axes)
    if mesh is None:
        return P()
    taken: set = set()
    parts: list = [None] * len(shape)

    def passes():
        for i, (size, name) in enumerate(zip(shape, axes)):
            if name in PRIORITY_NAMES:
                yield i, size, name
        for i, (size, name) in enumerate(zip(shape, axes)):
            if name not in PRIORITY_NAMES:
                yield i, size, name

    for i, size, name in passes():
        resolved = _axis_for(name, rules, mesh, size, taken)
        if resolved:
            taken.update(resolved)
            parts[i] = resolved if len(resolved) > 1 else resolved[0]
    return P(*parts)


def logical_sharding(shape: Sequence[int], axes: Sequence[str | None],
                     mesh: Mesh | None = None,
                     rules: dict | None = None) -> NamedSharding | None:
    mesh = mesh or current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, spec_for(shape, axes, mesh, rules))


def shard(x: jax.Array, *axes: str | None) -> jax.Array:
    """``with_sharding_constraint`` by logical axis names (no-op w/o mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    sh = logical_sharding(x.shape, axes, mesh)
    return jax.lax.with_sharding_constraint(x, sh)
