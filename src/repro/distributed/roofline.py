"""Roofline-term extraction from compiled dry-run artifacts (DESIGN.md §6).

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (per the assignment).

* FLOPs / bytes: ``compiled.cost_analysis()``
* collective bytes: parsed from the optimized HLO text — sum of operand
  sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute ops (cost_analysis does not report them).
"""

from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link (per direction)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

#: ops counted as inter-chip collectives
_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[128,1024]' -> bytes. '(bf16[..], f32[..])' -> sum."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum output-shape bytes of every collective op in optimized HLO.

    Uses the *output* shape of each collective instruction (the payload
    that crosses the interconnect at least once); returns per-op totals.
    """
    out: dict[str, int] = {op: 0 for op in _COLL_OPS}
    for line in hlo_text.splitlines():
        s = line.strip()
        # "  name = bf16[...]{...} all-reduce(...)", possibly "-start"
        m = re.match(r"^[%\w.\-]+ = (.+?) ([\w\-]+)\(", s)
        if not m:
            continue
        shape_str, opname = m.group(1), m.group(2)
        base = opname.removesuffix("-start")
        if base in _COLL_OPS:
            out[base] += _shape_bytes(shape_str)
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_gflops: float            # total across chips (cost_analysis)
    hlo_gbytes: float
    coll_gbytes: float
    coll_breakdown: dict = field(default_factory=dict)
    model_gflops: float = 0.0    # 6*N*D useful flops
    per_device_peak_mem_gb: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.hlo_gflops * 1e9 / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_gbytes * 1e9 / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        # coll_gbytes is the PER-DEVICE payload (HLO shapes of a GSPMD
        # module are per-partition); one ICI link, conservative (v5e has
        # 4 links/chip; ring collectives can use 2+ concurrently).
        return self.coll_gbytes * 1e9 / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flop_ratio(self) -> float:
        return (self.model_gflops / self.hlo_gflops) if self.hlo_gflops \
            else 0.0

    @property
    def roofline_fraction(self) -> float:
        """T_compute / max-term: 1.0 = compute-bound at peak."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        return self.t_compute / t if t else 0.0

    @property
    def model_roofline_fraction(self) -> float:
        """Useful-FLOPs roofline fraction (penalizes remat/redundancy):
        time at peak for MODEL_FLOPS / dominant term."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        t_model = self.model_gflops * 1e9 / (self.chips * PEAK_FLOPS)
        return t_model / t if t else 0.0

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 useful_flop_ratio=self.useful_flop_ratio,
                 roofline_fraction=self.roofline_fraction,
                 model_roofline_fraction=self.model_roofline_fraction)
        return d


def model_flops(cfg, shape, n_params: int) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) useful training FLOPs; forward
    only (2*N*D) for prefill; 2*N_active per token for decode."""
    tokens = shape.global_batch * shape.seq_len
    n_active = active_params(cfg, n_params)
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    # decode: one token per sequence in the batch
    return 2.0 * n_active * shape.global_batch


def active_params(cfg, n_params: int) -> float:
    """Parameters touched per token (MoE: top_k of n_experts)."""
    if not cfg.n_experts:
        return float(n_params)
    # expert weights fraction: 3 matrices of (d_model x d_ff) per expert
    per_expert = 3 * cfg.d_model * cfg.d_ff
    expert_total = cfg.n_layers * cfg.n_experts * per_expert
    non_expert = n_params - expert_total
    return float(non_expert + cfg.n_layers * cfg.top_k * per_expert)


def from_compiled(compiled, *, arch: str, shape, mesh_name: str,
                  chips: int, cfg=None, n_params: int = 0) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    # cost_analysis of a GSPMD-partitioned module is PER DEVICE (verified
    # against a hand-counted sharded matmul); scale to global totals.
    # Caveat: while-loop bodies are counted ONCE, so roofline cells are
    # lowered with scan_layers=False (see launch/dryrun.py --unroll).
    flops = float(cost.get("flops", 0.0)) * chips
    byts = float(cost.get("bytes accessed", 0.0)) * chips
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    mem = compiled.memory_analysis()
    peak = getattr(mem, "peak_memory_in_bytes", 0) or (
        getattr(mem, "argument_size_in_bytes", 0)
        + getattr(mem, "temp_size_in_bytes", 0)
        + getattr(mem, "output_size_in_bytes", 0))
    mf = model_flops(cfg, shape, n_params) if cfg is not None else 0.0
    return Roofline(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_gflops=flops / 1e9, hlo_gbytes=byts / 1e9,
        coll_gbytes=sum(coll.values()) / 1e9,
        coll_breakdown={k: v / 1e9 for k, v in coll.items() if v},
        model_gflops=mf / 1e9,
        per_device_peak_mem_gb=peak / 1e9,
    )
