"""Runtime sanitizer harness: ``REPRO_SANITIZE=1`` turns the suite hostile.

Three independent checks, all zero-cost when disabled:

* **Global flags** (:func:`install_global_checks`): ``jax_debug_nans``
  (any NaN materializing out of a jitted computation raises at the op
  that produced it) and ``jax_check_tracer_leaks`` (a tracer escaping
  its trace — the root cause behind RA001-class bugs — raises instead
  of silently closing over stale state).

* **Transfer guard** (:func:`no_implicit_transfers`): wraps a dispatch
  loop in ``jax.transfer_guard("disallow")``. Explicit transfers —
  ``jax.device_put``, ``jax.device_get``, ``np.asarray(device_array)``
  — stay legal; *implicit* ones (a Python scalar silently promoted
  host->device per tick, ``float(arr[0])`` pulling a scalar mid-loop)
  raise. This is the runtime twin of lint rule RA003.

* **Compile ledger** (:class:`CompileLedger` / :func:`steady_state`):
  generalizes the ``compile_count()`` witness from the serving tests
  into a suite-wide monotone counter of XLA compiles, fed by
  ``jax.monitoring`` compilation events. ``steady_state()`` asserts a
  region triggers **zero** fresh compiles — the contract every
  post-warmup serving loop in this repo sells (runtime twin of RA005).
"""

from __future__ import annotations

import contextlib
import os

import jax

_ENV = "REPRO_SANITIZE"

# Fired (one or more times per compilation) only when XLA actually
# compiles; cache hits and warm steady-state steps emit nothing.
_COMPILE_EVENT = "/jax/compilation_cache/compile_requests_use_cache"


def enabled() -> bool:
    return os.environ.get(_ENV, "").strip() not in ("", "0", "false", "no")


class CompileLedger:
    """Monotone counter of XLA compile events for the whole process."""

    def __init__(self):
        self.events = 0
        self._installed = False

    def install(self):
        if self._installed:
            return self
        def _listener(event, **kwargs):
            if event == _COMPILE_EVENT:
                self.events += 1
        jax.monitoring.register_event_listener(_listener)
        self._installed = True
        return self

    @contextlib.contextmanager
    def expect_no_compiles(self, what="steady-state region"):
        before = self.events
        yield self
        grew = self.events - before
        if grew:
            raise AssertionError(
                "compile ledger: %s triggered %d fresh XLA compile event(s); "
                "steady-state loops must run entirely from the jit cache "
                "(lint rule RA005 is the static twin of this check)" % (what, grew)
            )


_LEDGER = CompileLedger()


def ledger() -> CompileLedger:
    """The process-wide ledger, installing the listener on first use."""
    return _LEDGER.install()


def steady_state(what="steady-state region"):
    """``with steady_state():`` asserts zero fresh compiles inside."""
    return ledger().expect_no_compiles(what)


@contextlib.contextmanager
def no_implicit_transfers(always=False):
    """Disallow implicit host<->device transfers inside the block.

    Active when ``always=True`` (regression tests for specific fixes)
    or when ``REPRO_SANITIZE=1`` (suite-wide hostile mode); a no-op
    otherwise so the guarded tests cost nothing in a normal run.
    """
    if always or enabled():
        with jax.transfer_guard("disallow"):
            yield
    else:
        yield


def install_global_checks():
    """Flip the NaN / tracer-leak config flags for the whole process."""
    jax.config.update("jax_debug_nans", True)
    jax.config.update("jax_check_tracer_leaks", True)


def install_if_enabled():
    """Conftest hook: activate everything iff REPRO_SANITIZE=1."""
    if not enabled():
        return False
    install_global_checks()
    ledger()
    return True
