"""Finding records and the repro-lint waiver directive syntax.

A finding is (rule, file, line, message). Waivers attach at the line of
the finding or the line directly above, as a comment of the form
``repro-lint: disable=RA003 (deliberate sync point)`` — one or more
rule codes, comma-separated, followed by a parenthesized reason.
File-level waivers use ``disable-file=`` instead and sit anywhere in
the file.

A waiver with no ``(reason)`` does not suppress anything — it is
reported as an RA000 finding of its own, so every suppression in the
tree carries a written justification.
"""

from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field

RULES = {
    "RA000": "malformed waiver (missing reason or unknown rule)",
    "RA001": "Python control flow on a traced value in jit-reachable code",
    "RA002": "impure call (np.random / time / I/O) in jit-reachable code",
    "RA003": "implicit host<->device sync in jit-reachable or hot serving code",
    "RA004": "name used after being donated to a donate_argnums jit",
    "RA005": "recompile hazard (transform built per-call / varying static arg)",
    "RA006": "Pallas launch contract violation (grid/BlockSpec/out_shape)",
}

_WAIVER_RE = re.compile(
    r"#\s*repro-lint:\s*(?P<kind>disable|disable-file)\s*=\s*"
    r"(?P<codes>RA\d{3}(?:\s*,\s*RA\d{3})*)\s*"
    r"(?:\((?P<reason>[^)]*)\))?"
)
_DIRECTIVE_RE = re.compile(r"#\s*repro-lint\s*:")


@dataclass
class Finding:
    rule: str
    path: str
    line: int
    message: str
    waived: bool = False
    waiver_reason: str = ""

    def render(self) -> str:
        tag = " [waived: %s]" % self.waiver_reason if self.waived else ""
        return "%s:%d: %s %s%s" % (self.path, self.line, self.rule, self.message, tag)


@dataclass
class Waivers:
    """Parsed waiver directives for one source file."""

    # line -> {code -> reason}; file_level: code -> reason
    by_line: dict = field(default_factory=dict)
    file_level: dict = field(default_factory=dict)
    malformed: list = field(default_factory=list)  # [(line, message)]
    used: set = field(default_factory=set)  # (line, code) pairs that suppressed

    def lookup(self, line: int, code: str):
        """Return the waiver reason covering ``code`` at ``line``, else None."""
        if code in self.file_level:
            return self.file_level[code]
        for probe in (line, line - 1):
            reason = self.by_line.get(probe, {}).get(code)
            if reason is not None:
                self.used.add((probe, code))
                return reason
        return None


def parse_waivers(text: str) -> Waivers:
    w = Waivers()
    for lineno, raw in enumerate(text.splitlines(), start=1):
        m = _WAIVER_RE.search(raw)
        if m is None:
            if _DIRECTIVE_RE.search(raw):
                w.malformed.append((lineno, "unparseable repro-lint directive"))
            continue
        codes = [c.strip() for c in m.group("codes").split(",")]
        reason = (m.group("reason") or "").strip()
        if not reason:
            w.malformed.append((lineno, "waiver for %s has no (reason)" % ",".join(codes)))
            continue
        bad = [c for c in codes if c not in RULES or c == "RA000"]
        if bad:
            w.malformed.append((lineno, "waiver names unknown rule %s" % ",".join(bad)))
            continue
        target = w.file_level if m.group("kind") == "disable-file" else w.by_line.setdefault(lineno, {})
        for code in codes:
            target[code] = reason
    return w


def apply_waivers(findings: list, waivers: Waivers, path: str) -> list:
    """Mark waived findings in place; append RA000s for malformed waivers."""
    for f in findings:
        reason = waivers.lookup(f.line, f.rule)
        if reason is not None:
            f.waived = True
            f.waiver_reason = reason
    out = list(findings)
    for line, msg in waivers.malformed:
        out.append(Finding("RA000", path, line, msg))
    return out


def findings_json(findings: list) -> str:
    payload = {
        "rules": RULES,
        "total": len(findings),
        "unwaived": sum(1 for f in findings if not f.waived),
        "findings": [asdict(f) for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule))],
    }
    return json.dumps(payload, indent=2, sort_keys=False)
