"""CLI: ``python -m repro.analysis [--check] [--json PATH] PATHS...``"""

from __future__ import annotations

import argparse
import sys

from repro.analysis.findings import findings_json
from repro.analysis.linter import lint_paths


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="repro.analysis",
        description="Repo-specific jit/Pallas lint pass (rules RA001-RA006).",
    )
    ap.add_argument("paths", nargs="*", default=["src"], help="files or directories")
    ap.add_argument(
        "--check", action="store_true",
        help="exit non-zero if any unwaived finding remains",
    )
    ap.add_argument("--json", metavar="PATH", help="write machine-readable findings")
    ap.add_argument(
        "--show-waived", action="store_true",
        help="also print findings suppressed by repro-lint waivers",
    )
    ns = ap.parse_args(argv)

    findings = lint_paths(ns.paths or ["src"])
    unwaived = [f for f in findings if not f.waived]
    waived = [f for f in findings if f.waived]

    for f in unwaived:
        print(f.render())
    if ns.show_waived:
        for f in waived:
            print(f.render())

    if ns.json:
        with open(ns.json, "w", encoding="utf-8") as fh:
            fh.write(findings_json(findings) + "\n")

    print(
        "repro.analysis: %d finding(s), %d unwaived, %d waived"
        % (len(findings), len(unwaived), len(waived)),
        file=sys.stderr,
    )
    if ns.check and unwaived:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
