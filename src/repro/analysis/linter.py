"""Driver: files -> Program -> rules -> waiver-filtered findings."""

from __future__ import annotations

import os

from repro.analysis.findings import Finding, apply_waivers, parse_waivers
from repro.analysis.reachability import Program, index_module
from repro.analysis.rules import RuleEngine


def _collect_files(paths):
    out = []
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs if d != "__pycache__")
                for f in sorted(files):
                    if f.endswith(".py"):
                        out.append(os.path.join(root, f))
        elif p.endswith(".py"):
            out.append(p)
    return out


def lint_sources(sources):
    """Lint {path: text} pairs together as one program.

    Returns the full findings list (waived findings included, marked).
    """
    modules = []
    findings = []
    for path, text in sources.items():
        try:
            modules.append(index_module(path, text))
        except SyntaxError as e:
            findings.append(
                Finding("RA000", path, e.lineno or 0, "syntax error: %s" % e.msg)
            )
    program = Program(modules)
    engine = RuleEngine(program)
    for idx in modules:
        engine.check_module(idx)
    by_path = {}
    for f in engine.findings:
        by_path.setdefault(f.path, []).append(f)
    for path, text in sources.items():
        waivers = parse_waivers(text)
        findings.extend(apply_waivers(by_path.get(path, []), waivers, path))
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


def lint_paths(paths):
    files = _collect_files(paths)
    sources = {}
    for path in files:
        with open(path, "r", encoding="utf-8") as fh:
            sources[path] = fh.read()
    return lint_sources(sources)


def lint_text(text, path="fixture.py"):
    """Lint a single in-memory module (test fixtures)."""
    return lint_sources({path: text})
