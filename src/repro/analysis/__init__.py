"""Repo-specific static analysis + runtime sanitizers for the jit runtime.

Every guarantee the serving stack sells — bitwise mesh parity, zero
recompiles under churn, int32 no-overflow, donation-safe async dispatch —
is dynamic: it holds only in whichever benchmark happens to exercise it.
This package checks the *invariant classes behind those guarantees*
statically, at PR time:

========  ==============================================================
RA001     Python control flow (``if``/``while``/``assert``/``bool()``)
          on traced values inside jit/scan/shard_map-reachable functions
          — a silent trace-time freeze or a ``TracerBoolConversionError``
          at the first real call.
RA002     Impurity inside jit-reachable code (``np.random``, ``time``,
          I/O, ``print``): runs at *trace* time, once, then never again —
          plus bare ``np.random`` anywhere in ``src/`` (the repo
          generates data with ``jax.random`` under explicit keys).
RA003     Implicit host<->device sync (``.item()``, ``float(arr)``,
          ``np.asarray`` on device values) inside jit-reachable code or
          the hot serving dispatch/collect paths of ``launch/serve.py``
          and ``launch/cascade.py``.
RA004     Use-after-donate: a name referenced after being passed at a
          ``donate_argnums`` position of a donating jit — the buffer the
          callee may already have aliased away.
RA005     Recompile hazards: constructing ``jax.jit``/``jax.vmap``/
          ``shard_map`` inside loops or hot serving paths (a fresh trace
          cache per tick), and loop-varying values at static argument
          positions of a known jit (a retrace per iteration).
RA006     Pallas launch contracts: BlockSpec ``index_map`` arity vs grid
          rank, index_map return rank vs block rank, ``out_specs`` vs
          ``out_shape`` arity, missing/mis-sized ``dimension_semantics``.
========  ==============================================================

Run it::

    PYTHONPATH=src python -m repro.analysis --check src

Deliberate violations carry an inline waiver **with a reason**::

    np.asarray(extrema)  # repro-lint: disable=RA003 (single fused fetch)

(or on the line above; ``# repro-lint: disable-file=RA002 (reason)``
waives a whole file). A waiver without a reason is itself an error.
``--json PATH`` writes machine-readable findings; ``--check`` exits
non-zero on any unwaived finding. The CI ``lint`` job gates both.

The runtime half lives in :mod:`repro.analysis.sanitize`:
``REPRO_SANITIZE=1 make test-shard1`` runs the suite with NaN checks,
tracer-leak checks and a suite-wide compile ledger active, and the
serving tests wrap their dispatch loops in a transfer guard.
"""

from repro.analysis.findings import Finding, findings_json
from repro.analysis.linter import lint_paths, lint_text

__all__ = ["Finding", "findings_json", "lint_paths", "lint_text"]
