"""Whole-program jit-reachability over the ``src/`` tree.

The rules in :mod:`repro.analysis.rules` need three global facts no
single-file pass can supply:

* which functions can end up *inside a trace* — decorated with or passed
  to ``jax.jit`` / ``lax.scan`` / ``shard_map`` / ``pallas_call`` /
  ``vmap`` (directly or through ``functools.partial``), plus everything
  they transitively call;
* which module-level / instance names are *jit aliases*
  (``step = jax.jit(fn, static_argnames=..., donate_argnums=...)``),
  with their static names and donated positions resolved — including
  through module constants like ``_STEP_STATIC``;
* which names in a given function resolve to which of the above.

A call *to* a jit alias is a trace boundary: the alias's target is a
root in its own right, but the caller does not become jit-reachable by
calling it. That is exactly the serving topology here — host-side
``dispatch()`` loops invoking module-jitted steps.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field


def _dotted(node: ast.AST):
    """Render a Name/Attribute chain as ``a.b.c``; None for anything else."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


_JAX_XFORMS = {"jit", "vmap", "pmap", "checkpoint", "remat"}
# control-flow primitives live under jax.lax only — jax.tree.map and
# friends are host-side and must NOT make their lambdas jit roots
_LAX_XFORMS = {
    "scan", "map", "while_loop", "fori_loop", "cond", "switch",
    "associative_scan",
}
_BARE_XFORMS = {"pallas_call", "shard_map"}


def is_transform(expanded: str) -> bool:
    if expanded is None:
        return False
    last = expanded.rsplit(".", 1)[-1]
    if last in _BARE_XFORMS:
        return True
    if last in _LAX_XFORMS:
        return expanded.startswith("jax.lax.") or expanded.startswith("lax.")
    return last in _JAX_XFORMS and (expanded.startswith("jax.") or expanded == last)


def is_jit_like(expanded: str) -> bool:
    """Transforms that take static_argnames / donate_argnums."""
    if expanded is None:
        return False
    last = expanded.rsplit(".", 1)[-1]
    return last in {"jit", "pmap"} and (expanded.startswith("jax.") or expanded == last)


@dataclass
class FunctionInfo:
    module: str
    qualname: str
    path: str
    node: ast.AST  # FunctionDef / AsyncFunctionDef / Lambda
    params: tuple = ()
    kwonly: tuple = ()
    calls: tuple = ()  # dotted callee strings, in source order
    callsites: tuple = ()  # (dotted callee, ast.Call) pairs

    @property
    def key(self) -> str:
        return "%s:%s" % (self.module, self.qualname)


@dataclass
class JitAlias:
    module: str
    qualname: str  # "super_chunk_step" or "CascadeService._jit"
    line: int
    target: str = ""  # dotted target as written ("" if unresolved)
    static_argnames: tuple = ()
    donate_argnums: tuple = ()

    @property
    def key(self) -> str:
        return "%s:%s" % (self.module, self.qualname)


@dataclass
class ModuleIndex:
    module: str
    path: str
    tree: ast.Module
    text: str
    imports: dict = field(default_factory=dict)  # alias -> dotted
    functions: dict = field(default_factory=dict)  # qualname -> FunctionInfo
    aliases: dict = field(default_factory=dict)  # qualname -> JitAlias
    constants: dict = field(default_factory=dict)  # NAME -> tuple of literals

    def expand(self, dotted: str) -> str:
        head, _, rest = dotted.partition(".")
        base = self.imports.get(head, head)
        return base + ("." + rest if rest else "")


def module_name_for(path: str) -> str:
    parts = path.replace("\\", "/").rstrip("/").split("/")
    stem = parts[-1][:-3] if parts[-1].endswith(".py") else parts[-1]
    parts = parts[:-1] + [stem]
    if "repro" in parts:
        parts = parts[parts.index("repro"):]
    else:
        parts = [stem]
    if parts[-1] == "__init__":
        parts = parts[:-1] or [stem]
    return ".".join(parts)


def _const_strings(node: ast.AST):
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if not (isinstance(e, ast.Constant) and isinstance(e.value, str)):
                return None
        return tuple(e.value for e in node.elts)
    return None


def _const_ints(node: ast.AST):
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        for e in node.elts:
            if not (isinstance(e, ast.Constant) and isinstance(e.value, int)):
                return None
        return tuple(e.value for e in node.elts)
    if isinstance(node, ast.IfExp):
        # e.g. donate_argnums=(1,) if donate else () -> union of branches
        a = _const_ints(node.body) or ()
        b = _const_ints(node.orelse) or ()
        return tuple(sorted(set(a) | set(b)))
    return None


def unwrap_partial(node: ast.AST, idx: ModuleIndex) -> ast.AST:
    """``functools.partial(f, ...)`` -> ``f`` (recursively)."""
    while isinstance(node, ast.Call):
        name = _dotted(node.func)
        if name is None:
            break
        if idx.expand(name).rsplit(".", 1)[-1] != "partial":
            break
        if not node.args:
            break
        node = node.args[0]
    return node


class _ModuleVisitor(ast.NodeVisitor):
    def __init__(self, idx: ModuleIndex):
        self.idx = idx
        self.scope = []  # class/function name stack
        self.roots = []  # dotted names (as written) of transform targets
        self.lambda_roots = []  # FunctionInfo for lambdas passed to transforms

    # -- imports ---------------------------------------------------------
    def visit_Import(self, node):
        for a in node.names:
            self.idx.imports[a.asname or a.name.split(".")[0]] = (
                a.name if a.asname else a.name.split(".")[0]
            )

    def visit_ImportFrom(self, node):
        base = node.module or ""
        for a in node.names:
            self.idx.imports[a.asname or a.name] = (base + "." if base else "") + a.name

    # -- scope tracking --------------------------------------------------
    def visit_ClassDef(self, node):
        self.scope.append(node.name)
        self.generic_visit(node)
        self.scope.pop()

    def _register_function(self, node):
        qual = ".".join(self.scope + [node.name])
        args = node.args
        params = tuple(a.arg for a in args.posonlyargs + args.args)
        kwonly = tuple(a.arg for a in args.kwonlyargs)
        callsites = tuple(
            (name, n)
            for name, n in (
                (_dotted(n.func), n) for n in ast.walk(node) if isinstance(n, ast.Call)
            ) if name
        )
        calls = tuple(name for name, _ in callsites)
        info = FunctionInfo(
            self.idx.module, qual, self.idx.path, node, params, kwonly, calls, callsites
        )
        self.idx.functions[qual] = info
        for dec in node.decorator_list:
            target = dec
            if isinstance(dec, ast.Call):
                name = _dotted(dec.func)
                if name and self.idx.expand(name).rsplit(".", 1)[-1] == "partial" and dec.args:
                    target = dec.args[0]  # @partial(jax.jit, ...)
                    self._maybe_alias_from_decorator(info, dec)
                else:
                    target = dec.func
            name = _dotted(target)
            if name and is_transform(self.idx.expand(name)):
                self.roots.append(qual)
                if is_jit_like(self.idx.expand(name)) and isinstance(dec, ast.Call):
                    self._maybe_alias_from_decorator(info, dec)

    def _maybe_alias_from_decorator(self, info, call_node):
        inner = None
        for a in call_node.args:
            name = _dotted(a)
            if name and is_jit_like(self.idx.expand(name)):
                inner = name
        outer = _dotted(call_node.func)
        if inner is None and not (outer and is_jit_like(self.idx.expand(outer))):
            return
        static, donate = self._jit_kwargs(call_node)
        if static or donate:
            self.idx.aliases[info.qualname] = JitAlias(
                self.idx.module, info.qualname, info.node.lineno,
                target=info.qualname, static_argnames=static, donate_argnums=donate,
            )

    def visit_FunctionDef(self, node):
        self._register_function(node)
        self.scope.append(node.name)
        self.generic_visit(node)
        self.scope.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    # -- jit aliases & transform-arg roots -------------------------------
    def _jit_kwargs(self, call):
        static, donate = (), ()
        for kw in call.keywords:
            if kw.arg == "static_argnames":
                static = _const_strings(kw.value)
                if static is None and isinstance(kw.value, ast.Name):
                    static = self.idx.constants.get(kw.value.id, ())
                static = static or ()
            elif kw.arg in ("donate_argnums", "donate_argnames"):
                donate = _const_ints(kw.value) or ()
        return tuple(static), tuple(donate)

    def visit_Assign(self, node):
        # module constants usable as static_argnames values
        if not self.scope and len(node.targets) == 1 and isinstance(node.targets[0], ast.Name):
            strings = _const_strings(node.value)
            if strings is not None:
                self.idx.constants[node.targets[0].id] = strings
        self._maybe_record_alias(node.targets, node.value)
        self.generic_visit(node)

    def _maybe_record_alias(self, targets, value):
        if not isinstance(value, ast.Call):
            return
        fname = _dotted(value.func)
        if fname is None or not is_transform(self.idx.expand(fname)):
            return
        # target function(s) of the transform become roots
        for a in value.args:
            src = unwrap_partial(a, self.idx)
            name = _dotted(src)
            if name:
                self.roots.append(name)
        if not is_jit_like(self.idx.expand(fname)):
            return
        static, donate = self._jit_kwargs(value)
        tgt = ""
        if value.args:
            tgt = _dotted(unwrap_partial(value.args[0], self.idx)) or ""
        for t in targets:
            qual = None
            if isinstance(t, ast.Name):
                qual = t.id
            elif isinstance(t, ast.Attribute) and isinstance(t.value, ast.Name) \
                    and t.value.id == "self":
                cls = next((s for s in self.scope if s[:1].isupper()), None)
                if cls:
                    qual = "%s.%s" % (cls, t.attr)
            if qual:
                self.idx.aliases[qual] = JitAlias(
                    self.idx.module, qual, value.lineno,
                    target=tgt, static_argnames=static, donate_argnums=donate,
                )

    def visit_Call(self, node):
        fname = _dotted(node.func)
        if fname and is_transform(self.idx.expand(fname)):
            for a in node.args:
                src = unwrap_partial(a, self.idx)
                name = _dotted(src)
                if name:
                    self.roots.append(name)
                elif isinstance(src, ast.Lambda):
                    qual = "lambda@%d" % src.lineno
                    args = src.args
                    info = FunctionInfo(
                        self.idx.module, qual, self.idx.path, src,
                        params=tuple(x.arg for x in args.posonlyargs + args.args),
                        kwonly=tuple(x.arg for x in args.kwonlyargs),
                        calls=tuple(
                            c for c in (
                                _dotted(n.func) for n in ast.walk(src)
                                if isinstance(n, ast.Call)
                            ) if c
                        ),
                    )
                    self.idx.functions[qual] = info
                    self.lambda_roots.append(qual)
        self.generic_visit(node)


def index_module(path: str, text: str, module: str = None) -> ModuleIndex:
    tree = ast.parse(text, filename=path)
    idx = ModuleIndex(module or module_name_for(path), path, tree, text)
    v = _ModuleVisitor(idx)
    v.visit(tree)
    idx._root_names = list(v.roots) + list(v.lambda_roots)  # resolved in Program
    return idx


class Program:
    """Cross-module index + jit-reachability BFS."""

    def __init__(self, modules):
        self.modules = {m.module: m for m in modules}
        self.functions = {}  # "module:qual" -> FunctionInfo
        self.aliases = {}  # "module:qual" -> JitAlias
        for m in modules:
            for f in m.functions.values():
                self.functions[f.key] = f
            for a in m.aliases.values():
                self.aliases[a.key] = a
        self.reachable = self._compute_reachable()

    # -- name resolution -------------------------------------------------
    def resolve_function(self, module: str, caller_qual: str, dotted: str):
        """Resolve a callee's dotted name (as written) to a function key."""
        idx = self.modules.get(module)
        if idx is None:
            return None
        if dotted.startswith("self."):
            cls = caller_qual.split(".")[0] if caller_qual else ""
            cand = "%s:%s.%s" % (module, cls, dotted[5:])
            if cand in self.functions:
                return cand
            return None
        if "." not in dotted:
            cand = "%s:%s" % (module, dotted)
            if cand in self.functions:
                return cand
            # nested defs called by bare name inside their enclosing function
            if caller_qual:
                cand = "%s:%s.%s" % (module, caller_qual, dotted)
                if cand in self.functions:
                    return cand
            # methods called as bare names inside their own class body
            if caller_qual and "." in caller_qual:
                cls = caller_qual.rsplit(".", 1)[0]
                cand = "%s:%s.%s" % (module, cls, dotted)
                if cand in self.functions:
                    return cand
        expanded = idx.expand(dotted)
        for mod in self.modules:
            if expanded.startswith(mod + "."):
                qual = expanded[len(mod) + 1:]
                cand = "%s:%s" % (mod, qual)
                if cand in self.functions:
                    return cand
        return None

    def resolve_alias(self, module: str, caller_qual: str, dotted: str):
        """Resolve a name (as written) to a JitAlias key, if it is one."""
        idx = self.modules.get(module)
        if idx is None:
            return None
        if dotted.startswith("self."):
            cls = caller_qual.split(".")[0] if caller_qual else ""
            cand = "%s:%s.%s" % (module, cls, dotted[5:])
            if cand in self.aliases:
                return cand
            return None
        cand = "%s:%s" % (module, dotted)
        if cand in self.aliases:
            return cand
        expanded = idx.expand(dotted)
        for mod in self.modules:
            if expanded.startswith(mod + "."):
                cand = "%s:%s" % (mod, expanded[len(mod) + 1:])
                if cand in self.aliases:
                    return cand
        return None

    # -- reachability ----------------------------------------------------
    def _compute_reachable(self):
        work = []
        for m in self.modules.values():
            for name in getattr(m, "_root_names", ()):
                key = self.resolve_function(m.module, "", name)
                if key is None and name in m.functions:
                    key = m.functions[name].key
                if key:
                    work.append(key)
        for a in self.aliases.values():
            if a.target:
                key = self.resolve_function(a.module, "", a.target)
                if key:
                    work.append(key)
        self.roots = set(work)
        seen = set()
        while work:
            key = work.pop()
            if key in seen or key not in self.functions:
                continue
            seen.add(key)
            f = self.functions[key]
            for callee in f.calls:
                # a call to a jit alias is a trace boundary, not an edge
                if self.resolve_alias(f.module, f.qualname, callee):
                    continue
                nxt = self.resolve_function(f.module, f.qualname, callee)
                if nxt and nxt not in seen:
                    work.append(nxt)
        return seen

    def is_reachable(self, info: FunctionInfo) -> bool:
        return info.key in self.reachable
