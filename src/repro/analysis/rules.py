"""The RA001–RA006 rule implementations.

Each rule is deliberately repo-shaped rather than fully general: the
goal is catching the hazard classes this codebase has actually hit
(trace-frozen control flow, per-tick transform construction, implicit
syncs on the serving path, use-after-donate on rotating buffers, Pallas
grid/BlockSpec drift) with near-zero false positives on the idioms the
repo relies on (kw-only static config, ``.shape`` peeks, explicit
``device_get`` at collect time). Anything the analysis cannot resolve
statically it skips silently — an unresolvable form is not a finding.
"""

from __future__ import annotations

import ast

from repro.analysis.findings import Finding
from repro.analysis.reachability import (
    FunctionInfo,
    ModuleIndex,
    Program,
    _dotted,
)

_SHAPE_ATTRS = {"shape", "ndim", "dtype", "size", "nbytes", "itemsize"}
_NEUTRAL_CALLS = {"len", "isinstance", "type", "id", "hash", "repr", "str"}
_SYNC_BUILTINS = {"int", "float", "complex"}
_SYNC_EXPANDED = {"numpy.asarray", "numpy.array", "numpy.ascontiguousarray", "jax.device_get"}
# metadata reads: host results with NO device transfer involved
_META_EXPANDED = {"numpy.shape", "numpy.ndim", "numpy.size", "numpy.result_type"}
_IMPURE_PREFIXES = ("numpy.random.", "time.", "random.")
_IMPURE_BUILTINS = {"open", "input", "print"}
_PER_CALL_XFORMS = {"jit", "vmap", "pmap", "shard_map", "pallas_call"}

# Host-side serving hot paths: per-tick dispatch/collect loops where an
# implicit sync stalls the async pipeline (RA003) and per-call transform
# construction grows a fresh trace cache every tick (RA005).
_HOT_FILES = ("launch/serve.py", "launch/cascade.py")
_HOT_FNS = {"dispatch", "collect", "_finish", "flush", "submit", "_launch", "pump"}


def _is_hot(info: FunctionInfo) -> bool:
    if not any(info.path.replace("\\", "/").endswith(f) for f in _HOT_FILES):
        return False
    return info.qualname.rsplit(".", 1)[-1] in _HOT_FNS


def _target_names(target):
    out = []
    for n in ast.walk(target):
        if isinstance(n, ast.Name):
            out.append(n.id)
    return out


def _static_compare(test) -> bool:
    """Comparisons that are trace-time dispatch, not traced control flow.

    ``x is None`` / ``x is not None`` and ``mode == "pseudo"``-style
    string comparisons always run on static Python values here — a
    traced array compared to a string would be a type error long before
    it was a tracer leak.
    """
    if not isinstance(test, ast.Compare):
        return False
    if all(isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops) and any(
        isinstance(c, ast.Constant) and c.value is None
        for c in list(test.comparators) + [test.left]
    ):
        return True
    return all(
        isinstance(c, ast.Constant) and isinstance(c.value, str)
        for c in test.comparators
    )


class _FnAncestry:
    """Which registered functions are lexically inside other functions."""

    def __init__(self, idx: ModuleIndex):
        self.spans = []
        for f in idx.functions.values():
            node = f.node
            end = getattr(node, "end_lineno", node.lineno)
            self.spans.append((node.lineno, end, f))

    def enclosing(self, f: FunctionInfo):
        lo = f.node.lineno
        for a, b, g in self.spans:
            if g is not f and a < lo and getattr(f.node, "end_lineno", lo) <= b:
                if not isinstance(g.node, ast.Lambda):
                    yield g


# ---------------------------------------------------------------------------
# RA001 / RA002 / RA003 inside jit-reachable functions: taint walk
# ---------------------------------------------------------------------------


class _TaintWalker:
    def __init__(self, engine, idx: ModuleIndex, info: FunctionInfo, tainted,
                 call_hook=None):
        self.engine = engine
        self.idx = idx
        self.info = info
        self.tainted = set(tainted)
        self.call_hook = call_hook

    def _emit(self, rule, node, msg):
        self.engine.emit(rule, self.idx.path, node.lineno, msg)

    # -- expression taint ------------------------------------------------
    def _call_kind(self, node: ast.Call):
        if isinstance(node.func, ast.Attribute) and node.func.attr == "item":
            return "item"
        name = _dotted(node.func)
        if name is None:
            return None
        expanded = self.idx.expand(name)
        if expanded in _SYNC_EXPANDED:
            return "sync"
        if name in _SYNC_BUILTINS:
            return "sync"
        if name == "bool":
            return "bool"
        if name in _NEUTRAL_CALLS or expanded in _META_EXPANDED:
            return "neutral"
        return None

    def taints(self, e) -> bool:
        if e is None or isinstance(e, ast.Constant):
            return False
        if isinstance(e, ast.Name):
            return e.id in self.tainted
        if isinstance(e, ast.Attribute):
            if e.attr in _SHAPE_ATTRS:
                return False
            return self.taints(e.value)
        if isinstance(e, ast.Call):
            kind = self._call_kind(e)
            if kind in ("sync", "bool", "neutral", "item"):
                return False
            parts = [e.func] if isinstance(e.func, ast.Attribute) else []
            parts += list(e.args) + [kw.value for kw in e.keywords]
            return any(self.taints(p) for p in parts)
        return any(self.taints(c) for c in ast.iter_child_nodes(e))

    # -- findings within one expression ----------------------------------
    def scan_expr(self, e):
        if e is None:
            return
        for node in ast.walk(e):
            if isinstance(node, (ast.Lambda,)):
                self._nested(node)
            elif isinstance(node, ast.IfExp):
                self._flag_test(node.test, "conditional expression")
            elif isinstance(node, ast.BoolOp):
                for v in node.values:
                    if not _static_compare(v) and self.taints(v):
                        self._emit("RA001", node, "`and`/`or` forces bool() on a traced value")
                        break
            elif isinstance(node, ast.Call):
                self._scan_call(node)

    def _scan_call(self, node: ast.Call):
        if self.call_hook is not None:
            self.call_hook(self, node)
        kind = self._call_kind(node)
        arg_tainted = any(self.taints(a) for a in node.args)
        if kind == "bool" and arg_tainted:
            self._emit("RA001", node, "bool() on a traced value")
        elif kind == "sync" and arg_tainted:
            self._emit(
                "RA003", node,
                "%s on a traced value forces a host sync inside jit-reachable code"
                % (_dotted(node.func) or "sync call"),
            )
        elif kind == "item" and self.taints(node.func.value):
            self._emit("RA003", node, ".item() on a traced value inside jit-reachable code")
        name = _dotted(node.func)
        if name is not None:
            expanded = self.idx.expand(name)
            if expanded.startswith(_IMPURE_PREFIXES) or name in _IMPURE_BUILTINS:
                self._emit(
                    "RA002", node,
                    "impure call %s runs at trace time, not per step" % (name + "()"),
                )

    def _flag_test(self, test, what):
        while isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
            test = test.operand
        if isinstance(test, ast.BoolOp):
            for v in test.values:
                self._flag_test(v, what)
            return
        if _static_compare(test):
            return
        if self.taints(test):
            self._emit("RA001", test, "Python %s on a traced value" % what)

    def _nested(self, node):
        if isinstance(node, ast.Lambda):
            params = [a.arg for a in node.args.posonlyargs + node.args.args]
            sub = _TaintWalker(self.engine, self.idx, self.info,
                               self.tainted | set(params), self.call_hook)
            sub.scan_expr(node.body)
            return
        # a nested def's params are tainted by what its call sites pass
        # (interprocedural fixpoint), not by fiat — pad_to(x, 0, block)
        # taints the array, not the static block multiple
        key = "%s:%s.%s" % (self.idx.module, self.info.qualname, node.name)
        param_taint = getattr(self.engine, "param_taint", {})
        if key in param_taint:
            params = set(param_taint[key])
        else:
            params = {a.arg for a in node.args.posonlyargs + node.args.args
                      if a.arg != "self"}
        sub = _TaintWalker(self.engine, self.idx, self.info,
                           self.tainted | params, self.call_hook)
        sub.walk(node.body)

    # -- statements ------------------------------------------------------
    def walk(self, stmts):
        for s in stmts:
            self._stmt(s)

    def _stmt(self, s):
        if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self._nested(s)
        elif isinstance(s, ast.If):
            self._flag_test(s.test, "if")
            self.scan_expr(s.test)
            self.walk(s.body)
            self.walk(s.orelse)
        elif isinstance(s, ast.While):
            self._flag_test(s.test, "while")
            self.scan_expr(s.test)
            self.walk(s.body)
            self.walk(s.orelse)
        elif isinstance(s, ast.Assert):
            self._flag_test(s.test, "assert")
            self.scan_expr(s.test)
        elif isinstance(s, ast.For):
            if self.taints(s.iter):
                self._emit("RA001", s, "for loop iterates a traced value")
            self.scan_expr(s.iter)
            if self.taints(s.iter):
                self.tainted.update(_target_names(s.target))
            self.walk(s.body)
            self.walk(s.orelse)
        elif isinstance(s, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            value = s.value
            self.scan_expr(value)
            t = value is not None and self.taints(value)
            targets = s.targets if isinstance(s, ast.Assign) else [s.target]
            for tg in targets:
                for name in _target_names(tg):
                    if isinstance(s, ast.AugAssign):
                        t = t or name in self.tainted
                    (self.tainted.add if t else self.tainted.discard)(name)
        elif isinstance(s, (ast.Return, ast.Expr)):
            self.scan_expr(s.value)
        elif isinstance(s, ast.With):
            for item in s.items:
                self.scan_expr(item.context_expr)
            self.walk(s.body)
        elif isinstance(s, ast.Try):
            self.walk(s.body)
            for h in s.handlers:
                self.walk(h.body)
            self.walk(s.orelse)
            self.walk(s.finalbody)
        elif isinstance(s, (ast.Raise,)):
            if s.exc is not None:
                self.scan_expr(s.exc)


# ---------------------------------------------------------------------------
# RA003 on host-side serving hot paths: device-likely value tracking
# ---------------------------------------------------------------------------


class _HotPathWalker:
    """Linear device-likely tracking through dispatch/collect bodies.

    Params (minus ``self``) and anything derived from them — iteration
    variables, subscripts, attribute loads like ``rec.logits``, results
    of jit-alias calls — are device-likely. Names rebound from
    ``np.asarray``/``int``/``float`` become host values. Explicit
    ``jax.device_get`` / ``.block_until_ready()`` are allowed: the rule
    flags only the *implicit* sync spellings.
    """

    def __init__(self, engine, idx: ModuleIndex, info: FunctionInfo, program: Program):
        self.engine = engine
        self.idx = idx
        self.info = info
        self.program = program
        self.device = {p for p in info.params if p != "self"} | set(info.kwonly)

    def _emit(self, node, msg):
        self.engine.emit("RA003", self.idx.path, node.lineno, msg)

    def _hostifying(self, node: ast.Call):
        name = _dotted(node.func)
        if name is None:
            return False
        if name in _SYNC_BUILTINS or name in _NEUTRAL_CALLS or name == "bool":
            return True
        expanded = self.idx.expand(name)
        return expanded in _SYNC_EXPANDED or expanded in _META_EXPANDED

    def devicey(self, e) -> bool:
        if e is None or isinstance(e, ast.Constant):
            return False
        if isinstance(e, ast.Name):
            return e.id in self.device
        if isinstance(e, ast.Attribute):
            if e.attr in _SHAPE_ATTRS:
                return False
            return self.devicey(e.value)
        if isinstance(e, ast.Subscript):
            return self.devicey(e.value)
        if isinstance(e, ast.Call):
            if self._hostifying(e):
                return False
            name = _dotted(e.func)
            if name is not None and self.program.resolve_alias(
                    self.idx.module, self.info.qualname, name):
                return True  # result of a jitted step: device array
            if isinstance(e.func, ast.Attribute) and self.devicey(e.func.value):
                return True  # method call on a device-likely container
            return any(self.devicey(c) for c in list(e.args) + [k.value for k in e.keywords])
        if isinstance(e, (ast.Tuple, ast.List, ast.IfExp, ast.Starred)):
            return any(self.devicey(c) for c in ast.iter_child_nodes(e))
        if isinstance(e, ast.GeneratorExp):
            return self.devicey(e.elt) or any(self.devicey(g.iter) for g in e.generators)
        return False

    def _scan_expr(self, e):
        if e is None:
            return
        for node in ast.walk(e):
            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Attribute) and node.func.attr == "item":
                if self.devicey(node.func.value):
                    self._emit(node, ".item() syncs the device pipeline in a hot serving path")
                continue
            name = _dotted(node.func)
            if name is None:
                continue
            expanded = self.idx.expand(name)
            devicey_arg = any(self.devicey(a) for a in node.args)
            if expanded in ("numpy.asarray", "numpy.array", "numpy.ascontiguousarray") \
                    and devicey_arg:
                self._emit(
                    node,
                    "%s() on a device value blocks on transfer in a hot serving path" % name,
                )
            elif name in _SYNC_BUILTINS and node.args and devicey_arg:
                self._emit(
                    node,
                    "%s() on a device value forces a scalar sync in a hot serving path" % name,
                )

    # GeneratorExp comprehension variables over device iterables
    def _bind_comprehensions(self, e):
        for node in ast.walk(e):
            if isinstance(node, (ast.GeneratorExp, ast.ListComp, ast.SetComp, ast.DictComp)):
                for g in node.generators:
                    if self.devicey(g.iter):
                        self.device.update(_target_names(g.target))

    def walk(self, stmts):
        for s in stmts:
            self._stmt(s)

    def _stmt(self, s):
        if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return
        if isinstance(s, ast.For):
            self._bind_comprehensions(s.iter)
            self._scan_expr(s.iter)
            if self.devicey(s.iter):
                self.device.update(_target_names(s.target))
            self.walk(s.body)
            self.walk(s.orelse)
            return
        if isinstance(s, (ast.If, ast.While)):
            self._bind_comprehensions(s.test)
            self._scan_expr(s.test)
            self.walk(s.body)
            self.walk(s.orelse)
            return
        if isinstance(s, ast.With):
            for item in s.items:
                self._scan_expr(item.context_expr)
            self.walk(s.body)
            return
        if isinstance(s, ast.Try):
            self.walk(s.body)
            for h in s.handlers:
                self.walk(h.body)
            self.walk(s.orelse)
            self.walk(s.finalbody)
            return
        for e in ast.iter_child_nodes(s):
            if isinstance(e, ast.expr):
                self._bind_comprehensions(e)
                self._scan_expr(e)
        if isinstance(s, (ast.Assign, ast.AugAssign, ast.AnnAssign)) and s.value is not None:
            d = self.devicey(s.value)
            targets = s.targets if isinstance(s, ast.Assign) else [s.target]
            for tg in targets:
                for name in _target_names(tg):
                    (self.device.add if d else self.device.discard)(name)


# ---------------------------------------------------------------------------
# RA004: use-after-donate
# ---------------------------------------------------------------------------


class _DonationWalker:
    def __init__(self, engine, idx: ModuleIndex, info: FunctionInfo, program: Program):
        self.engine = engine
        self.idx = idx
        self.info = info
        self.program = program
        self.donated = {}  # dotted token -> (alias qualname, donate line)
        self.local_aliases = {}  # local name -> set of alias keys

    def _alias_keys(self, name):
        if name in self.local_aliases:
            return self.local_aliases[name]
        key = self.program.resolve_alias(self.idx.module, self.info.qualname, name)
        return {key} if key else set()

    def _donate_positions(self, keys):
        pos = set()
        for k in keys:
            pos |= set(self.program.aliases[k].donate_argnums)
        return pos

    def walk(self, stmts):
        for s in stmts:
            self._stmt(s)

    def _stmt(self, s):
        if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            return
        if isinstance(s, ast.If):
            self._uses(s.test)
            a = dict(self.donated)
            self.walk(s.body)
            after_body = self.donated
            self.donated = a
            self.walk(s.orelse)
            self.donated = {**self.donated, **after_body}
            return
        if isinstance(s, (ast.For, ast.While)):
            head = s.iter if isinstance(s, ast.For) else s.test
            self._uses(head)
            # two passes: catch cross-iteration use-after-donate
            self.walk(s.body)
            self.walk(s.body)
            self.walk(s.orelse)
            return
        if isinstance(s, ast.With):
            for item in s.items:
                self._uses(item.context_expr)
            self.walk(s.body)
            return
        if isinstance(s, ast.Try):
            self.walk(s.body)
            for h in s.handlers:
                self.walk(h.body)
            self.walk(s.orelse)
            self.walk(s.finalbody)
            return
        # ordinary statement: uses first, then donations, then rebinds
        self._uses(s)
        for call in [n for n in ast.walk(s) if isinstance(n, ast.Call)]:
            self._apply_donations(call)
        if isinstance(s, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = s.targets if isinstance(s, ast.Assign) else [s.target]
            for tg in targets:
                tok = _dotted(tg)
                if tok:
                    self.donated.pop(tok, None)
                for name in _target_names(tg):
                    self.donated.pop(name, None)
            self._track_local_alias(s)
        if isinstance(s, ast.Delete):
            for tg in s.targets:
                tok = _dotted(tg)
                if tok:
                    self.donated.pop(tok, None)

    def _track_local_alias(self, s):
        if not isinstance(s, ast.Assign) or len(s.targets) != 1:
            return
        tg = s.targets[0]
        if not isinstance(tg, ast.Name):
            return
        v = s.value
        cands = []
        if isinstance(v, ast.IfExp):
            cands = [v.body, v.orelse]
        elif isinstance(v, (ast.Name, ast.Attribute)):
            cands = [v]
        keys = set()
        for c in cands:
            name = _dotted(c)
            if name:
                keys |= self._alias_keys(name)
        if keys:
            self.local_aliases[tg.id] = keys

    def _uses(self, node):
        if node is None:
            return
        for n in ast.walk(node):
            if not isinstance(n, (ast.Name, ast.Attribute)):
                continue
            if not isinstance(getattr(n, "ctx", None), ast.Load):
                continue
            tok = _dotted(n)
            if tok in self.donated:
                alias, line = self.donated[tok]
                self.engine.emit(
                    "RA004", self.idx.path, n.lineno,
                    "'%s' used after being donated to %s (line %d); the buffer "
                    "may already be aliased away" % (tok, alias, line),
                )
                self.donated.pop(tok, None)  # report once per donation

    def _apply_donations(self, call: ast.Call):
        name = _dotted(call.func)
        if name is None:
            return
        keys = self._alias_keys(name)
        if not keys:
            return
        for pos in self._donate_positions(keys):
            if pos < len(call.args):
                tok = _dotted(call.args[pos])
                if tok:
                    self.donated[tok] = (name, call.lineno)


# ---------------------------------------------------------------------------
# RA005: recompile hazards
# ---------------------------------------------------------------------------


class _RecompileWalker:
    def __init__(self, engine, idx: ModuleIndex, info: FunctionInfo, program: Program):
        self.engine = engine
        self.idx = idx
        self.info = info
        self.program = program
        self.hot = _is_hot(info)

    def run(self):
        self._walk(self.info.node.body, loop_vars=(), in_loop=False)

    def _walk(self, stmts, loop_vars, in_loop):
        for s in stmts:
            if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if isinstance(s, (ast.For, ast.While)):
                inner = tuple(loop_vars)
                if isinstance(s, ast.For):
                    inner = inner + tuple(_target_names(s.target))
                for e in ast.iter_child_nodes(s):
                    if isinstance(e, ast.expr):
                        self._exprs(e, loop_vars, in_loop)
                self._walk(s.body, inner, True)
                self._walk(s.orelse, loop_vars, in_loop)
                continue
            for e in ast.iter_child_nodes(s):
                if isinstance(e, ast.expr):
                    self._exprs(e, loop_vars, in_loop)
            for attr in ("body", "orelse", "finalbody"):
                sub = getattr(s, attr, None)
                if isinstance(sub, list) and sub and isinstance(sub[0], ast.stmt):
                    self._walk(sub, loop_vars, in_loop)
            for h in getattr(s, "handlers", []):
                self._walk(h.body, loop_vars, in_loop)

    def _exprs(self, e, loop_vars, in_loop):
        for node in ast.walk(e):
            if not isinstance(node, ast.Call):
                continue
            name = _dotted(node.func)
            if name is None:
                continue
            expanded = self.idx.expand(name)
            last = expanded.rsplit(".", 1)[-1]
            if last in _PER_CALL_XFORMS and (
                expanded.startswith("jax.") or last in ("shard_map", "pallas_call")
                or expanded == last
            ):
                if in_loop:
                    self.engine.emit(
                        "RA005", self.idx.path, node.lineno,
                        "%s constructed inside a loop: a fresh trace/cache entry "
                        "per iteration" % name,
                    )
                elif self.hot:
                    self.engine.emit(
                        "RA005", self.idx.path, node.lineno,
                        "%s constructed per call in hot serving path '%s': hoist "
                        "to module scope" % (name, self.info.qualname),
                    )
                continue
            if in_loop:
                self._check_static_args(node, name, loop_vars)

    def _check_static_args(self, call, name, loop_vars):
        key = self.program.resolve_alias(self.idx.module, self.info.qualname, name)
        if key is None:
            return
        alias = self.program.aliases[key]
        if not alias.static_argnames:
            return
        target_params = ()
        tkey = self.program.resolve_function(alias.module, "", alias.target) if alias.target else None
        if tkey:
            tf = self.program.functions[tkey]
            target_params = tf.params + tf.kwonly
        static = set(alias.static_argnames)
        hazards = []
        for i, a in enumerate(call.args):
            pname = target_params[i] if i < len(target_params) else None
            if pname in static and self._mentions(a, loop_vars):
                hazards.append(pname)
        for kw in call.keywords:
            if kw.arg in static and self._mentions(kw.value, loop_vars):
                hazards.append(kw.arg)
        for pname in hazards:
            self.engine.emit(
                "RA005", self.idx.path, call.lineno,
                "loop-varying value passed at static arg '%s' of %s: retrace "
                "per iteration" % (pname, name),
            )

    @staticmethod
    def _mentions(e, loop_vars):
        return any(
            isinstance(n, ast.Name) and n.id in loop_vars for n in ast.walk(e)
        )


# ---------------------------------------------------------------------------
# RA006: Pallas launch contracts
# ---------------------------------------------------------------------------


def _literal_len(node, local=None):
    """Static length of a tuple/list literal, through ``[x]*k`` and names."""
    if local and isinstance(node, ast.Name) and node.id in local:
        return _literal_len(local[node.id], None)
    if isinstance(node, (ast.Tuple, ast.List)):
        return len(node.elts)
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mult):
        if isinstance(node.right, ast.Constant) and isinstance(node.right.value, int):
            inner = _literal_len(node.left, local)
            if inner is not None:
                return inner * node.right.value
        if isinstance(node.left, ast.Constant) and isinstance(node.left.value, int):
            inner = _literal_len(node.right, local)
            if inner is not None:
                return inner * node.left.value
    return None


def _as_list(node, local=None):
    """Elements of a list/tuple literal, through names and ``[x]*k``."""
    if local and isinstance(node, ast.Name) and node.id in local:
        return _as_list(local[node.id], None)
    if isinstance(node, (ast.Tuple, ast.List)):
        return list(node.elts)
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mult):
        if isinstance(node.right, ast.Constant) and isinstance(node.right.value, int):
            inner = _as_list(node.left, local)
            if inner is not None:
                return inner * node.right.value
    return None


class _PallasChecker:
    def __init__(self, engine, idx: ModuleIndex):
        self.engine = engine
        self.idx = idx

    def run(self):
        for f in self.idx.functions.values():
            local = {}
            for n in ast.walk(f.node):
                if isinstance(n, ast.Assign) and len(n.targets) == 1 \
                        and isinstance(n.targets[0], ast.Name):
                    local[n.targets[0].id] = n.value
            for n in ast.walk(f.node):
                if isinstance(n, ast.Call):
                    name = _dotted(n.func)
                    if name and self.idx.expand(name).rsplit(".", 1)[-1] == "pallas_call":
                        self._check(n, local)

    def _emit(self, node, msg):
        self.engine.emit("RA006", self.idx.path, node.lineno, msg)

    def _kw(self, call, name):
        for kw in call.keywords:
            if kw.arg == name:
                return kw.value
        return None

    def _resolve(self, node, local):
        while isinstance(node, ast.Name) and node.id in local:
            nxt = local[node.id]
            if nxt is node:
                break
            node = nxt
        return node

    def _block_specs(self, node, local):
        """Yield BlockSpec constructor Call nodes from in_specs/out_specs."""
        elems = _as_list(node, local)
        if elems is None:
            elems = [node]  # single spec, not wrapped in a list
        for e in elems:
            e = self._resolve(e, local)
            if isinstance(e, ast.Call):
                name = _dotted(e.func)
                if name and self.idx.expand(name).rsplit(".", 1)[-1] == "BlockSpec":
                    yield e
                else:
                    yield None
            else:
                yield None

    def _check(self, call, local):
        grid_node = self._kw(call, "grid")
        grid_rank = None
        if grid_node is not None:
            g = self._resolve(grid_node, local)
            grid_rank = _literal_len(g, local)
            if grid_rank is None and not isinstance(g, (ast.Tuple, ast.List)):
                grid_rank = 1 if isinstance(g, (ast.Constant, ast.Name, ast.BinOp)) else None
                if not isinstance(g, ast.Constant):
                    grid_rank = None  # non-literal scalar grid: skip arity checks

        for role in ("in_specs", "out_specs"):
            specs_node = self._kw(call, role)
            if specs_node is None:
                continue
            for spec in self._block_specs(specs_node, local):
                if spec is None:
                    continue
                self._check_spec(spec, grid_rank, local)

        out_specs = self._kw(call, "out_specs")
        out_shape = self._kw(call, "out_shape")
        if out_specs is not None and out_shape is not None:
            n_specs = _literal_len(self._resolve(out_specs, local), local)
            n_shapes = _literal_len(self._resolve(out_shape, local), local)
            if n_specs is not None and n_shapes is not None and n_specs != n_shapes:
                self._emit(
                    call,
                    "out_specs has %d entries but out_shape has %d" % (n_specs, n_shapes),
                )
            self._check_out_ranks(out_specs, out_shape, local)

        self._check_dimension_semantics(call, grid_rank, local)

    def _check_spec(self, spec, grid_rank, local):
        args = list(spec.args)
        block_shape = args[0] if args else self._kw(spec, "block_shape")
        index_map = args[1] if len(args) > 1 else self._kw(spec, "index_map")
        block_rank = _literal_len(self._resolve(block_shape, local), local) \
            if block_shape is not None else None
        if index_map is None:
            return
        index_map = self._resolve(index_map, local)
        if not isinstance(index_map, ast.Lambda):
            return
        arity = len(index_map.args.posonlyargs + index_map.args.args)
        if grid_rank is not None and arity != grid_rank:
            self._emit(
                spec,
                "BlockSpec index_map takes %d grid indices but grid has rank %d"
                % (arity, grid_rank),
            )
        ret = index_map.body
        ret_len = len(ret.elts) if isinstance(ret, ast.Tuple) else 1
        if block_rank is not None and ret_len != block_rank:
            self._emit(
                spec,
                "BlockSpec index_map returns %d block coordinates but block_shape "
                "has rank %d" % (ret_len, block_rank),
            )

    def _check_out_ranks(self, out_specs, out_shape, local):
        specs = list(self._block_specs(out_specs, local))
        shapes = _as_list(self._resolve(out_shape, local), local)
        if shapes is None:
            shapes = [out_shape]
        for spec, shp in zip(specs, shapes):
            if spec is None:
                continue
            shp = self._resolve(shp, local)
            if not isinstance(shp, ast.Call):
                continue
            name = _dotted(shp.func)
            if not name or "ShapeDtypeStruct" not in name:
                continue
            shape_arg = shp.args[0] if shp.args else self._kw(shp, "shape")
            full_rank = _literal_len(self._resolve(shape_arg, local), local) \
                if shape_arg is not None else None
            args = list(spec.args)
            block_shape = args[0] if args else self._kw(spec, "block_shape")
            block_rank = _literal_len(self._resolve(block_shape, local), local) \
                if block_shape is not None else None
            if full_rank is not None and block_rank is not None and full_rank != block_rank:
                self._emit(
                    spec,
                    "out_spec block_shape rank %d does not match ShapeDtypeStruct "
                    "rank %d" % (block_rank, full_rank),
                )

    def _check_dimension_semantics(self, call, grid_rank, local):
        cp = self._kw(call, "compiler_params")
        if cp is None:
            self._emit(
                call,
                "pallas_call without compiler_params(dimension_semantics=...): "
                "grid axes default to arbitrary/sequential",
            )
            return
        cp = self._resolve(cp, local)
        ds = None
        if isinstance(cp, ast.Call):
            ds = self._kw(cp, "dimension_semantics")
        if isinstance(cp, ast.Dict):
            for k, v in zip(cp.keys, cp.values):
                if isinstance(k, ast.Constant) and k.value == "dimension_semantics":
                    ds = v
        if ds is None:
            self._emit(call, "compiler_params without dimension_semantics")
            return
        ds_len = _literal_len(self._resolve(ds, local), local)
        if ds_len is not None and grid_rank is not None and ds_len != grid_rank:
            self._emit(
                call,
                "dimension_semantics has %d entries but grid has rank %d"
                % (ds_len, grid_rank),
            )


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


class _NullEngine:
    def emit(self, *args, **kwargs):
        pass


class RuleEngine:
    def __init__(self, program: Program):
        self.program = program
        self.findings = []
        self._seen = set()
        self.param_taint = self._compute_param_taint()

    def _root_seed(self, info: FunctionInfo):
        statics = self._statics_for(info)
        seed = {p for p in info.params if p not in statics and p != "self"}
        if isinstance(info.node, ast.Lambda):
            seed |= set(info.kwonly) - statics
        return seed

    def _compute_param_taint(self):
        """Interprocedural param taint: seed jit roots, flow through calls.

        A transitively-reachable helper's param is traced only if some
        reachable caller actually passes a tainted expression at that
        position — ``spec_for(x.shape, axes, mesh)`` stays host-static
        while ``apply_nonlinearity(proj, b)`` taints ``proj``/``b``.
        Monotone, so a few fixpoint rounds over this repo converge.
        """
        program = self.program
        taint = {}
        for key in program.reachable:
            info = program.functions[key]
            taint[key] = self._root_seed(info) if key in program.roots else set()

        def hook(walker, call):
            name = _dotted(call.func)
            if name is None:
                return
            if program.resolve_alias(walker.idx.module, walker.info.qualname, name):
                return  # jit-alias boundary: target is seeded as a root
            tkey = program.resolve_function(walker.idx.module, walker.info.qualname, name)
            if tkey not in taint:
                return
            tf = program.functions[tkey]
            statics = self._statics_for(tf)
            params = list(tf.params)
            off = 1 if params[:1] == ["self"] else 0
            for i, a in enumerate(call.args):
                j = off + i
                if j < len(params) and params[j] not in statics \
                        and params[j] not in taint[tkey] and walker.taints(a):
                    taint[tkey].add(params[j])
                    hook.changed = True
            named = set(params) | set(tf.kwonly)
            for kw in call.keywords:
                if kw.arg in named and kw.arg not in statics \
                        and kw.arg not in taint[tkey] and walker.taints(kw.value):
                    taint[tkey].add(kw.arg)
                    hook.changed = True

        null = _NullEngine()
        for _ in range(8):
            hook.changed = False
            for key in program.reachable:
                info = program.functions[key]
                idx = program.modules.get(info.module)
                if idx is None:
                    continue
                walker = _TaintWalker(null, idx, info, taint[key], call_hook=hook)
                if isinstance(info.node, ast.Lambda):
                    walker.scan_expr(info.node.body)
                else:
                    walker.walk(info.node.body)
            if not hook.changed:
                break
        return taint

    def emit(self, rule, path, line, msg):
        key = (rule, path, line)
        if key in self._seen:
            return
        self._seen.add(key)
        self.findings.append(Finding(rule, path, line, msg))

    def _statics_for(self, info: FunctionInfo):
        static = set()
        for alias in self.program.aliases.values():
            if not alias.target:
                continue
            tkey = self.program.resolve_function(alias.module, "", alias.target)
            if tkey == info.key or alias.target == info.qualname and alias.module == info.module:
                static |= set(alias.static_argnames)
        return static

    def _standalone(self, idx: ModuleIndex, ancestry: _FnAncestry, info: FunctionInfo):
        """Analyze info at top level unless a reachable enclosing fn covers it."""
        for g in ancestry.enclosing(info):
            if self.program.is_reachable(g):
                return False
        return True

    def check_module(self, idx: ModuleIndex):
        ancestry = _FnAncestry(idx)
        for info in list(idx.functions.values()):
            reachable = self.program.is_reachable(info)
            if reachable and self._standalone(idx, ancestry, info):
                tainted = self.param_taint.get(info.key, self._root_seed(info))
                walker = _TaintWalker(self, idx, info, tainted)
                node = info.node
                if isinstance(node, ast.Lambda):
                    walker.scan_expr(node.body)
                else:
                    walker.walk(node.body)
            if not reachable and not isinstance(info.node, ast.Lambda):
                if _is_hot(info):
                    _HotPathWalker(self, idx, info, self.program).walk(info.node.body)
                _DonationWalker(self, idx, info, self.program).walk(info.node.body)
                _RecompileWalker(self, idx, info, self.program).run()
        # RA002 anywhere: bare numpy.random in src is a reproducibility smell
        for n in ast.walk(idx.tree):
            if isinstance(n, ast.Call):
                name = _dotted(n.func)
                if name and idx.expand(name).startswith("numpy.random."):
                    self.emit(
                        "RA002", idx.path, n.lineno,
                        "%s(): host RNG outside jax.random keys breaks replay "
                        "determinism" % name,
                    )
        _PallasChecker(self, idx).run()
        return self.findings
