"""Gated-frame → downstream-backbone cascade serving (the paper's loop).

HyperSense's system claim is gate-then-detect: the always-on HDC gate
runs on low-precision ADC data, and only the frames it passes are
high-precision captured and fed to the heavy downstream detector —
5.6x end-to-end vs an always-on YOLOv4 and up to 92.1% energy saving
(paper §V-E). The sensing runtime already produces exactly that feed:
every runner's ``drain_hp()`` delivers ``(absolute frame indices,
(M, H, W) HP frames)`` bursts. :class:`CascadeService` is the consumer
that closes the loop:

* **Fixed-shape batching.** Drains are ragged (a quiet tick drains 0
  frames, a bursty one dozens). Frames queue host-side and launch in
  fixed ``(batch_size, H, W)`` blocks — the tail pads with zero rows
  that are dropped on collect — so the backbone step compiles ONCE and
  ragged drain sizes can never retrace it
  (:meth:`~CascadeService.compile_count` witnesses, same contract as
  ``FleetService``).

* **Bitwise batching.** The detector step
  (:func:`repro.launch.steps.build_detector_cell`) maps the batch axis
  with ``jax.lax.map``, so a frame's logits are bit-identical whether
  it arrives alone, padded, or co-batched mid-burst — batched service
  output ≡ eager per-frame evaluation (:meth:`~CascadeService.eager`),
  gated in ``benchmarks/fig16_speedup.py --system --check``.

* **Async double-buffering** (PR-8 pattern). ``device_put`` starts the
  H2D copy immediately and the jitted step returns once *enqueued*, so
  backbone compute overlaps the gate's next ticks; up to
  ``max_inflight`` batches pipeline before the oldest is drained
  (back-pressure), and :meth:`~CascadeService.collect` blocks only on
  the oldest in-flight batch.

* **System accounting.** :meth:`~CascadeService.backbone_cost` reads
  the compiled step's XLA ``cost_analysis()`` (the roofline model's
  source) and :meth:`~CascadeService.system_energy` bills gate duty
  cycle × backbone cost against the always-on backbone
  (:func:`repro.core.energy.cascade_system` /
  :func:`~repro.core.energy.always_on_backbone`);
  :meth:`~CascadeService.roofline` models the per-batch step latency on
  the reference accelerator.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Hashable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import energy
from repro.distributed import roofline as roofline_mod
from repro.launch import steps

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CascadeBatch:
    """One collected backbone batch: per-frame logits + provenance.

    Row ``j`` of ``logits`` is the detector output for the frame the
    gate captured at absolute index ``frame_idx[j]`` on sensor
    ``sids[j]``; pad rows are already dropped. ``latency_s`` is wall
    time from the batch's dispatch to its outputs being host-resident.
    """
    seq: int
    sids: tuple
    frame_idx: np.ndarray          # (m,) int64 absolute gate indices
    logits: np.ndarray             # (m, n_out) float32
    n_padded: int                  # zero rows the fixed batch carried
    latency_s: float


@dataclasses.dataclass
class _InFlightBatch:
    seq: int
    t0: float
    logits: Array                  # (batch_size, n_out) device future
    rows: list                     # [(sid, abs_idx), ...] valid rows


class CascadeService:
    """Batched, double-buffered backbone serving over ``drain_hp`` feeds.

    ``params`` are :func:`repro.launch.steps.init_detector_params`-shaped
    (``{"backbone": ..., "embedder": ...}``) for an **embeds-in** ``cfg``
    (e.g. ``configs.get_smoke("hubert-xlarge")``). ``frame_hw`` must
    match the gate runners' frames; ``batch_size`` fixes the backbone
    step shape. With a ``mesh`` the backbone params shard across it.

    Feed it either directly (:meth:`submit` takes any ``drain_hp()``
    output) or via :meth:`pump`, which drains a
    :class:`~repro.launch.serve.FleetService`,
    :class:`~repro.sensing.fleet.FleetRunner`, or
    :class:`~repro.sensing.stream.StreamRunner` in place. Results come
    back through :meth:`collect`/:meth:`flush` as
    :class:`CascadeBatch` rows mapped back to (sensor, absolute frame).
    """

    def __init__(self, params, cfg: ModelConfig, *, batch_size: int,
                 frame_hw: tuple[int, int], patch: int = 8,
                 n_out: int = 2, mesh=None, max_inflight: int = 2,
                 j_per_flop: float = energy.EDGE_J_PER_FLOP):
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, "
                             f"got {max_inflight}")
        self.cfg = cfg
        self.batch_size = batch_size
        self.frame_hw = (int(frame_hw[0]), int(frame_hw[1]))
        self.patch = patch
        self.n_out = n_out
        self.max_inflight = max_inflight
        self.j_per_flop = j_per_flop
        self._mesh = mesh
        self._cell = steps.build_detector_cell(
            cfg, batch=batch_size, frame_hw=self.frame_hw, patch=patch,
            n_out=n_out, mesh=mesh)
        if mesh is None:
            self._jit = jax.jit(self._cell.step_fn)
        else:
            self._jit = jax.jit(self._cell.step_fn,
                                in_shardings=self._cell.in_shardings,
                                out_shardings=self._cell.out_shardings)
        if mesh is None:
            self._params = jax.tree.map(jnp.asarray, params)
        else:
            self._params = jax.tree.map(
                jax.device_put, params, self._cell.in_shardings[0])
        self._queue: collections.deque = collections.deque()
        self._pending: collections.deque[_InFlightBatch] = \
            collections.deque()
        self._ready: collections.deque[CascadeBatch] = collections.deque()
        self._compiled = None
        self._seq = 0
        self.frames_in = 0             # frames ever submitted
        self.frames_padded = 0         # zero slack rows ever launched
        self.batches = 0

    # ------------------------------------------------------------------
    # feed
    # ------------------------------------------------------------------

    def submit(self, sid: Hashable, idx, frames) -> int:
        """Enqueue one drain's frames; launches every full batch.

        ``(idx, frames)`` is a ``drain_hp()`` deliverable: ``(M,)``
        absolute indices + ``(M, H, W)`` HP frames — the empty case's
        ``(0, H, W)`` shape contract is exactly what lets a consumer
        like this concatenate drains blindly. Returns frames enqueued.
        """
        # repro-lint: disable=RA003 (admission boundary: ragged drains queue host-side until a full (B, H, W) batch launches)
        idx = np.asarray(idx, np.int64)
        frames = np.asarray(frames, np.float32)  # repro-lint: disable=RA003 (same admission boundary)
        if frames.ndim != 3 or frames.shape[0] != idx.shape[0]:
            raise ValueError(f"drain shapes disagree: idx {idx.shape}, "
                             f"frames {frames.shape}")
        if frames.shape[1:] != self.frame_hw:
            raise ValueError(f"frames are {frames.shape[1:]}, cascade "
                             f"was built for {self.frame_hw}")
        for j in range(idx.shape[0]):
            self._queue.append((sid, int(idx[j]), frames[j]))
        self.frames_in += int(idx.shape[0])
        while len(self._queue) >= self.batch_size:
            self._launch([self._queue.popleft()
                          for _ in range(self.batch_size)])
        return int(idx.shape[0])

    def pump(self, gate) -> int:
        """Drain a gate front-end into the queue; returns frames taken.

        Accepts a ``FleetService`` (per-sensor drains, keyed by sid), a
        ``FleetRunner`` (per-stream drains, keyed by row index), or a
        ``StreamRunner`` (single stream, sid 0).
        """
        taken = 0
        if hasattr(gate, "attached"):              # FleetService
            for sid in gate.attached:
                taken += self.submit(sid, *gate.drain_hp(sid))
        else:
            out = gate.drain_hp()
            if isinstance(out, list):              # FleetRunner
                for si, (idx, frames) in enumerate(out):
                    taken += self.submit(si, idx, frames)
            else:                                  # StreamRunner
                taken += self.submit(0, *out)
        return taken

    # ------------------------------------------------------------------
    # dispatch / collect (PR-8 double-buffering shape)
    # ------------------------------------------------------------------

    def _launch(self, rows: list) -> None:
        B = self.batch_size
        block = np.zeros((B, *self.frame_hw), np.float32)
        for j, (_, _, frame) in enumerate(rows):
            block[j] = frame
        dev = (jax.device_put(block) if self._mesh is None
               else jax.device_put(block, self._cell.in_shardings[1]))
        logits = self._jit(self._params, dev)      # async: enqueued, not run
        self._pending.append(_InFlightBatch(
            seq=self._seq, t0=time.perf_counter(), logits=logits,
            rows=[(sid, idx) for sid, idx, _ in rows]))
        self._seq += 1
        self.batches += 1
        self.frames_padded += B - len(rows)
        while len(self._pending) > self.max_inflight:
            self._ready.append(self._finish(self._pending.popleft()))

    def _finish(self, rec: _InFlightBatch) -> CascadeBatch:
        # repro-lint: disable=RA003 (designed sync point: blocks on the oldest in-flight batch only)
        logits = np.asarray(rec.logits)            # blocks on THIS batch
        m = len(rec.rows)
        return CascadeBatch(
            seq=rec.seq,
            sids=tuple(sid for sid, _ in rec.rows),
            frame_idx=np.asarray([i for _, i in rec.rows], np.int64),
            logits=logits[:m],
            n_padded=self.batch_size - m,
            latency_s=time.perf_counter() - rec.t0)

    def collect(self) -> CascadeBatch | None:
        """Oldest finished batch (FIFO), or None with nothing in flight."""
        if self._ready:
            return self._ready.popleft()
        if not self._pending:
            return None
        return self._finish(self._pending.popleft())

    def flush(self) -> list[CascadeBatch]:
        """Force the partial tail batch out and drain the pipeline."""
        if self._queue:
            self._launch([self._queue.popleft()
                          for _ in range(len(self._queue))])
        out = list(self._ready)
        self._ready.clear()
        while self._pending:
            out.append(self._finish(self._pending.popleft()))
        return out

    @property
    def queued(self) -> int:
        """Frames waiting for a full batch (flush() forces them)."""
        return len(self._queue)

    def compile_count(self) -> int:
        """XLA compilations of the backbone step — the ragged-drain
        no-retrace witness (must freeze after the first batch)."""
        return self._jit._cache_size()

    # ------------------------------------------------------------------
    # reference + accounting
    # ------------------------------------------------------------------

    def eager(self, frames) -> np.ndarray:
        """Per-frame reference evaluation: one step call per frame.

        Runs each ``(H, W)`` frame alone (row 0 of a zero-padded batch)
        through the SAME jitted step and returns ``(M, n_out)`` logits.
        The cascade's batched outputs must be bitwise-equal to this —
        the ``lax.map`` row independence makes it so by construction.
        """
        frames = np.asarray(frames, np.float32)
        out = np.empty((frames.shape[0], self.n_out), np.float32)
        block = np.zeros((self.batch_size, *self.frame_hw), np.float32)
        for j in range(frames.shape[0]):
            block[0] = frames[j]
            dev = (jax.device_put(block) if self._mesh is None
                   else jax.device_put(block, self._cell.in_shardings[1]))
            out[j] = np.asarray(self._jit(self._params, dev))[0]
        return out

    def _ensure_compiled(self):
        if self._compiled is None:
            abs_p, abs_f = self._cell.abstract_args
            self._compiled = self._jit.lower(abs_p, abs_f).compile()
        return self._compiled

    def backbone_cost(self) -> energy.BackboneCost:
        """Measured per-frame FLOPs/bytes/Joules of the compiled step."""
        return energy.backbone_cost(self._ensure_compiled(),
                                    self.batch_size,
                                    j_per_flop=self.j_per_flop)

    def roofline(self) -> roofline_mod.Roofline:
        """Roofline latency model of one backbone batch on the
        reference accelerator (the per-batch service step the gate's
        duty cycle amortizes)."""
        seq = steps.detector_seq_len(self.frame_hw, self.patch)
        shape = ShapeConfig(name=f"detector_b{self.batch_size}",
                            seq_len=seq, global_batch=self.batch_size,
                            kind="prefill")
        chips = self._mesh.size if self._mesh is not None else 1
        mesh_name = ("x".join(str(s) for s in
                              self._mesh.devices.shape)
                     if self._mesh is not None else "single")
        return roofline_mod.from_compiled(
            self._ensure_compiled(), arch=self.cfg.arch_id, shape=shape,
            mesh_name=mesh_name, chips=chips)

    def system_energy(self, log, params: energy.EnergyParams | None = None,
                      precision: str = "float32"
                      ) -> dict[str, energy.EnergyBreakdown]:
        """Per-frame system energy: this cascade vs the always-on backbone.

        ``log`` is the gate's :class:`~repro.core.sensor_control.
        CaptureLog` (closed loop — a real ``hp_bits`` is required);
        ``"cascade"`` bills LP sampling + HDC + duty-cycled HP capture +
        duty × measured backbone cost, ``"always_on"`` bills HP capture
        + backbone on every frame.
        """
        cost = self.backbone_cost()
        return {"cascade": energy.cascade_system(log, cost, params,
                                                 precision),
                "always_on": energy.always_on_backbone(cost, params)}
