"""Batched greedy decoding with a prefill-free cache (LM backbones).

Moved out of ``repro.launch.serve`` when that module became the sensor
fleet's serving layer; the downstream-backbone cascade (ROADMAP) serves
gated HP frames through models driven by this decode loop.

CPU smoke example:
  PYTHONPATH=src python -m repro.launch.decode --arch internlm2-1.8b \
      --smoke --batch 2 --prompt-len 8 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import lm


def greedy_decode(model: lm.Model, params, prompts: jax.Array,
                  gen: int, max_seq: int):
    """prompts: (b, p) int32. Feeds the prompt token-by-token (cache
    priming), then generates ``gen`` tokens greedily."""
    b, p = prompts.shape
    state = model.init_decode_state(batch=b, max_seq=max_seq)

    step = jax.jit(model.decode_step, donate_argnums=(1,))

    tok = prompts[:, 0:1]
    out = [tok]
    for t in range(p + gen - 1):
        logits, state = step(params, state,
                             lm.DecodeBatch(tokens=tok,
                                            index=jnp.int32(t)))
        nxt = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
        tok = prompts[:, t + 1:t + 2] if t + 1 < p else nxt.astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get_config(args.arch)
    if cfg.is_encoder:
        raise SystemExit("encoder-only arch has no decode step")
    model = lm.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.time()
    toks = greedy_decode(model, params, prompts, args.gen,
                         max_seq=args.prompt_len + args.gen)
    dt = time.time() - t0
    n_new = args.batch * args.gen
    print(f"generated {toks.shape} in {dt:.1f}s "
          f"({n_new / dt:.1f} tok/s incl. compile)")
    print(toks[:, :12])


if __name__ == "__main__":
    main()
