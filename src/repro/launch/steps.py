"""Step builders + input specs for every (arch x shape) dry-run cell.

``input_specs(cfg, shape)`` returns weak-type-correct ShapeDtypeStruct
stand-ins for every input of the step function (no device allocation);
``build_cell(cfg, shape, mesh)`` returns ``(step_fn, in_shardings,
out_shardings, abstract_args)`` ready for ``jax.jit(...).lower(...)``.

Cells:
* train  — full train step: loss + grads + AdamW update (donated state)
* prefill — forward logits over the full sequence
* decode — one-token serve step against a pre-filled KV cache / SSM state
* detector — fixed-batch frame classifier over an embeds-in backbone:
  the gated cascade's downstream step
  (:class:`repro.launch.cascade.CascadeService` batches HP frames
  drained from the gate runners through it — the gate→detect loop the
  paper serves end to end)
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as shlib
from repro.models import attention, lm, ssm, xlstm
from repro.models.lm import Batch, DecodeBatch
from repro.train import optim

Array = jax.Array


class Cell(NamedTuple):
    step_fn: Any
    in_shardings: Any
    out_shardings: Any
    abstract_args: tuple
    donate_argnums: tuple


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Batch:
    b, s = shape.global_batch, shape.seq_len
    tokens = None if cfg.embeds_in else _sds((b, s), jnp.int32)
    labels = _sds((b, s), jnp.int32)
    embeds = None
    if cfg.embeds_in:
        embeds = _sds((b, s, cfg.d_model), jnp.bfloat16)
    elif cfg.family == "vlm":
        embeds = _sds((b, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16)
    return Batch(tokens=tokens, labels=labels, embeds=embeds)


def _batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh,
                     rules=None) -> Batch:
    def sh(sds, axes):
        if sds is None:
            return None
        return shlib.logical_sharding(sds.shape, axes, mesh, rules)

    specs = _batch_specs(cfg, shape)
    return Batch(
        tokens=sh(specs.tokens, ("act_batch", "act_seq")),
        labels=sh(specs.labels, ("act_batch", "act_seq")),
        embeds=sh(specs.embeds, ("act_batch", "act_seq", "act_embed")),
    )


def _decode_state_axes(model: lm.Model):
    """Logical-axis tree matching ``decode_state_spec`` (leading layer dim)."""
    cfg = model.cfg
    if cfg.family in ("dense", "moe", "vlm"):
        ax = attention.cache_axes()
        return attention.KVCache(("layers", *ax.k), ("layers", *ax.v))
    if cfg.family == "hybrid":
        sax = ssm.state_axes()
        aax = attention.cache_axes()
        return {
            "mamba": ssm.SSMState(("layers", *sax.ssm),
                                  ("layers", *sax.conv)),
            "attn": attention.KVCache(("layers", *aax.k),
                                      ("layers", *aax.v)),
        }
    if cfg.family == "ssm":
        from repro.models.lm import _xlstm_kinds
        out = []
        for kind in _xlstm_kinds(cfg):
            out.append(xlstm.slstm_state_axes() if kind == "slstm"
                       else xlstm.mlstm_state_axes())
        return out
    raise ValueError(cfg.family)


def _tree_shardings(spec_tree, axes_tree, mesh, rules=None):
    return jax.tree.map(
        lambda sds, axes: shlib.logical_sharding(sds.shape, tuple(axes),
                                                 mesh, rules),
        spec_tree, axes_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _replicated(mesh):
    return NamedSharding(mesh, PS())


# ---------------------------------------------------------------------------
# Cell builders
# ---------------------------------------------------------------------------

def make_optimizer(cfg: ModelConfig) -> optim.AdamW:
    return optim.AdamW(lr=optim.warmup_cosine(3e-4, 2000, 100_000),
                       weight_decay=0.1)


def build_train_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
                     rules=None) -> Cell:
    model = lm.build(cfg)
    opt = make_optimizer(cfg)
    compute_dtype = model.compute_dtype

    def train_step(params, opt_state, batch):
        # mixed precision: cast fp32 master weights to bf16 ONCE, on their
        # FSDP shards, so the per-layer weight all-gather moves bf16 (2x
        # less ICI traffic) and the convert isn't re-done per use
        # (§Perf hillclimb C1).
        def cast(p):
            return p.astype(compute_dtype) if p.dtype == jnp.float32 else p

        cast_params = jax.tree.map(cast, params)
        loss, grads = jax.value_and_grad(
            lambda cp: model.loss(cp, batch))(cast_params)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        return params, opt_state, loss

    p_abs = model.abstract_params()
    p_sh = model.param_shardings(mesh, rules)
    opt_abs = optim.AdamWState(
        step=_sds((), jnp.int32),
        mu=jax.tree.map(lambda s: _sds(s.shape, s.dtype), p_abs),
        nu=jax.tree.map(lambda s: _sds(s.shape, s.dtype), p_abs))
    opt_sh = optim.AdamWState(step=_replicated(mesh), mu=p_sh, nu=p_sh)
    b_abs = _batch_specs(cfg, shape)
    b_sh = _batch_shardings(cfg, shape, mesh, rules)

    return Cell(
        step_fn=train_step,
        in_shardings=(p_sh, opt_sh, b_sh),
        out_shardings=(p_sh, opt_sh, _replicated(mesh)),
        abstract_args=(p_abs, opt_abs, b_abs),
        donate_argnums=(0, 1),
    )


def build_prefill_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
                       rules=None) -> Cell:
    model = lm.build(cfg)

    def prefill_step(params, batch):
        logits, _ = model.forward(params, batch)
        return logits

    p_abs = model.abstract_params()
    p_sh = model.param_shardings(mesh, rules)
    b_abs = _batch_specs(cfg, shape)
    b_sh = _batch_shardings(cfg, shape, mesh, rules)
    s_img = 0 if (cfg.family != "vlm" or cfg.embeds_in) \
        else 0  # vlm logits are text-only (image prefix stripped)
    out_shape = (shape.global_batch, shape.seq_len + s_img, cfg.vocab)
    out_sh = shlib.logical_sharding(out_shape,
                                    ("act_batch", "act_seq", "act_vocab"),
                                    mesh, rules)
    return Cell(
        step_fn=prefill_step,
        in_shardings=(p_sh, b_sh),
        out_shardings=out_sh,
        abstract_args=(p_abs, b_abs),
        donate_argnums=(),
    )


def build_decode_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
                      rules=None) -> Cell:
    model = lm.build(cfg)

    def serve_step(params, state, batch):
        logits, state = model.decode_step(params, state, batch)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1)
        return next_tok, state

    b = shape.global_batch
    p_abs = model.abstract_params()
    p_sh = model.param_shardings(mesh, rules)
    st_abs = model.decode_state_spec(batch=b, max_seq=shape.seq_len)
    st_ax = _decode_state_axes(model)
    st_sh = _tree_shardings(st_abs, st_ax, mesh, rules)
    db_abs = DecodeBatch(tokens=_sds((b, 1), jnp.int32),
                         index=_sds((), jnp.int32))
    db_sh = DecodeBatch(
        tokens=shlib.logical_sharding((b, 1), ("act_batch", None), mesh,
                                      rules),
        index=_replicated(mesh))
    tok_sh = shlib.logical_sharding((b,), ("act_batch",), mesh, rules)
    return Cell(
        step_fn=serve_step,
        in_shardings=(p_sh, st_sh, db_sh),
        out_shardings=(tok_sh, st_sh),
        abstract_args=(p_abs, st_abs, db_abs),
        donate_argnums=(1,),
    )


def detector_seq_len(frame_hw: tuple[int, int], patch: int) -> int:
    """Patch-token sequence length a detector frame unrolls to."""
    H, W = frame_hw
    if patch < 1 or H % patch or W % patch:
        raise ValueError(f"patch {patch} must divide frame {frame_hw}")
    return (H // patch) * (W // patch)


def build_detector_cell(cfg: ModelConfig, *, batch: int,
                        frame_hw: tuple[int, int], patch: int,
                        n_out: int = 2, mesh=None, rules=None) -> Cell:
    """Downstream-backbone detector step for the gated cascade.

    ``detector_step(params, frames)``: a fixed ``(batch, H, W)`` float32
    block of HP frames → ``(batch, n_out)`` float32 class logits. Each
    frame is patchified to ``seq = (H/patch)*(W/patch)`` tokens, linearly
    embedded (``params["embedder"]``: ``proj (patch², d_model)`` +
    ``pos (seq, d_model)``), and run through an **embeds-in** LM backbone
    (``params["backbone"]``); the last position's first ``n_out`` vocab
    logits are the detection head (at smoke scale the backbone is the
    hubert-style encoder — the cascade's stand-in for the paper's YOLO
    detector).

    The batch axis is ``jax.lax.map``, NOT ``vmap``: every row executes
    the identical unbatched program, so a frame's logits are bitwise
    independent of its batch position and of whatever else shares the
    batch — including zero-padded slack rows. That, by construction, is
    the cascade's parity gate (batched service output ≡ eager per-frame
    evaluation, ``benchmarks/fig16_speedup.py --system --check``); a
    vmapped/batched dot would reassociate with the batch extent (see
    ``fleet._per_stream_fold`` for the precedent).

    With a ``mesh`` the backbone params shard via ``param_shardings``
    (frames and the tiny embedder replicate); ``mesh=None`` builds an
    unsharded cell with ``None`` shardings.
    """
    if not cfg.embeds_in:
        raise ValueError(f"{cfg.arch_id}: detector backbone needs an "
                         "embeds-in config (the patch embedder replaces "
                         "the token embedding)")
    if n_out < 1 or n_out > cfg.vocab:
        raise ValueError(f"n_out {n_out} must be in [1, vocab={cfg.vocab}]")
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    model = lm.build(cfg)
    H, W = frame_hw
    seq = detector_seq_len(frame_hw, patch)

    def one_frame(params, frame):
        p = frame.reshape(H // patch, patch, W // patch, patch)
        p = p.transpose(0, 2, 1, 3).reshape(seq, patch * patch)
        emb = (p.astype(jnp.float32) @ params["embedder"]["proj"]
               + params["embedder"]["pos"])
        b1 = Batch(tokens=None, labels=jnp.zeros((1, seq), jnp.int32),
                   embeds=emb[None].astype(model.compute_dtype))
        logits, _ = model.forward(params["backbone"], b1)
        return logits[0, -1, :n_out].astype(jnp.float32)

    def detector_step(params, frames):
        return jax.lax.map(lambda f: one_frame(params, f), frames)

    p_abs = {
        "backbone": model.abstract_params(),
        "embedder": {
            "proj": _sds((patch * patch, cfg.d_model), jnp.float32),
            "pos": _sds((seq, cfg.d_model), jnp.float32),
        },
    }
    f_abs = _sds((batch, H, W), jnp.float32)
    if mesh is None:
        return Cell(step_fn=detector_step, in_shardings=None,
                    out_shardings=None, abstract_args=(p_abs, f_abs),
                    donate_argnums=())
    p_sh = {
        "backbone": model.param_shardings(mesh, rules),
        "embedder": {"proj": _replicated(mesh), "pos": _replicated(mesh)},
    }
    return Cell(step_fn=detector_step,
                in_shardings=(p_sh, _replicated(mesh)),
                out_shardings=_replicated(mesh),
                abstract_args=(p_abs, f_abs),
                donate_argnums=())


def init_detector_params(key, cfg: ModelConfig, *,
                         frame_hw: tuple[int, int], patch: int) -> dict:
    """Concrete detector params matching :func:`build_detector_cell`."""
    model = lm.build(cfg)
    seq = detector_seq_len(frame_hw, patch)
    k_b, k_p, k_q = jax.random.split(jax.random.PRNGKey(0)
                                     if isinstance(key, int) else key, 3)
    scale = 1.0 / float(patch)
    return {
        "backbone": model.init(k_b),
        "embedder": {
            "proj": scale * jax.random.normal(
                k_p, (patch * patch, cfg.d_model), jnp.float32),
            "pos": 0.02 * jax.random.normal(
                k_q, (seq, cfg.d_model), jnp.float32),
        },
    }


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
               rules=None) -> Cell:
    builder = {"train": build_train_cell,
               "prefill": build_prefill_cell,
               "decode": build_decode_cell}[shape.kind]
    return builder(cfg, shape, mesh, rules)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> tuple:
    """ShapeDtypeStruct stand-ins for every step input (no allocation)."""
    mesh = None
    # specs don't need a mesh; reuse the cell builder with a null mesh via
    # a tiny shim that skips shardings
    if shape.kind == "train":
        model = lm.build(cfg)
        p_abs = model.abstract_params()
        opt_abs = optim.AdamWState(
            step=_sds((), jnp.int32),
            mu=jax.tree.map(lambda s: _sds(s.shape, s.dtype), p_abs),
            nu=jax.tree.map(lambda s: _sds(s.shape, s.dtype), p_abs))
        return (p_abs, opt_abs, _batch_specs(cfg, shape))
    if shape.kind == "prefill":
        model = lm.build(cfg)
        return (model.abstract_params(), _batch_specs(cfg, shape))
    model = lm.build(cfg)
    st_abs = model.decode_state_spec(batch=shape.global_batch,
                                     max_seq=shape.seq_len)
    db = DecodeBatch(tokens=_sds((shape.global_batch, 1), jnp.int32),
                     index=_sds((), jnp.int32))
    return (model.abstract_params(), st_abs, db)
