"""Training launcher.

Single-process entry point; on a real cluster each host runs this under
``jax.distributed.initialize`` (the SPMD program is identical — pjit
shards over the global mesh). Cluster contract for 1000+ nodes:

* every host runs the same binary with ``--coordinator`` set; JAX's
  distributed runtime handles device enumeration
* node failure => the job scheduler relaunches all hosts; the loop
  resumes from the latest checkpoint (repro.train.loop), re-sharding to
  the new mesh if the topology changed (elastic)
* straggler mitigation: async checkpointing keeps the critical path
  clean; the scheduler-level replacement policy is out of scope here and
  documented in DESIGN.md §5.

Examples:
  # CPU smoke run (reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
      --steps 20 --batch 4 --seq 128
"""

from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.models import lm
from repro.train import loop as train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--coordinator", default=None,
                    help="host:port for jax.distributed (multi-host)")
    ap.add_argument("--process-id", type=int, default=0)
    ap.add_argument("--num-processes", type=int, default=1)
    args = ap.parse_args()

    if args.coordinator:
        jax.distributed.initialize(args.coordinator, args.num_processes,
                                   args.process_id)

    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get_config(args.arch)
    model = lm.build(cfg)
    tc = train_loop.TrainConfig(
        steps=args.steps, microbatches=args.microbatches,
        ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir, lr=args.lr)
    data = train_loop.synthetic_lm_data(cfg, args.batch, args.seq)
    result = train_loop.train(model, data, tc)
    print(f"done at step {result['step']}; "
          f"loss history: {[round(x, 3) for x in result['history']]}")


if __name__ == "__main__":
    main()
