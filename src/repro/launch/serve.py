"""Always-on fleet serving: async double-buffered ingestion + slot churn.

The paper's "Intelligent Sensor Control" system is *continuously
running*: ADC streams feed the HDC gate in real time, and the FPGA
design wins end-to-end because data movement overlaps compute. The
batch-mode :class:`~repro.sensing.fleet.FleetRunner` pays host→device
transfer serially before every kernel launch and freezes stream
membership at construction; :class:`FleetService` is the serving layer
on top of the same jitted fleet step that removes both limits.

**Double buffering** (:meth:`FleetService.dispatch` /
:meth:`~FleetService.collect`). ``dispatch`` assembles the next
super-chunk on host, ``jax.device_put``'s it (H2D copy begins
immediately), and launches the jitted fleet step — which, under JAX's
async dispatch, returns the instant the work is *enqueued*. The host is
already assembling and transferring tick ``t+1`` while the device still
executes tick ``t``: the send/await split of a DMA frame manager, at the
host↔device boundary (the in-kernel analog is the double-buffered DMA
pattern in the Pallas guide). ``collect`` blocks only on the *oldest*
in-flight chunk. The rotating buffers are **donated** where they can
alias: the raw super-chunk into the ADC-convert jit (float in, float
out — same buffer), and the carried
:class:`~repro.sensing.stream.StreamState` into the fleet step
(``super_chunk_step_donated``), so a service that steps forever rolls
the same device allocations instead of growing per chunk.

**Slot-pooled churn** (:meth:`~FleetService.attach` /
:meth:`~FleetService.detach`). The fleet step always runs at a fixed
``(n_slots, chunk_size, H, W)`` shape; sensors map onto slots and
membership/ragged arrival only flips bits in the step's ``slot_mask``
operand — PR 7's padded-slot machinery, reused as a pool. Churn
therefore NEVER changes an array shape and never triggers a recompile
(:meth:`~FleetService.compile_count` exposes the step's XLA compile
counter so callers can assert exactly that). ``park_masked`` step
semantics freeze a masked slot's hold/phase/classifier state in place,
and detach parks the slot's state host-side, so detach→reattach —
even through an intervening tenant in the same slot — restores a
sensor's adapted classifier, gate hold, ADC phase, and capture log
bitwise.

**Checkpointed online state** (:meth:`~FleetService.checkpoint` /
:meth:`~FleetService.restore`). The mutable fleet state — adapted
``class_hvs``, holds, phases, the slot table, parked sensors, per-sensor
capture logs — snapshots through
:class:`repro.ckpt.checkpoint.AsyncCheckpointer` (write happens on a
background thread; ``ckpt_every=N`` automates it per N chunks). Restore
into a freshly constructed service resumes the trace bitwise-identical
to an uninterrupted run (``tests/test_serve.py``).

``benchmarks/serve_throughput.py --check`` gates the service ≥ the
synchronous ``FleetRunner`` on frames/sec with bitwise-equal outputs on
the same churn-free trace, zero recompiles across a churn trace, and
bitwise checkpoint-restore.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Any, Hashable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.ckpt import checkpoint as ckpt_mod
from repro.core.hypersense import HyperSenseModel
from repro.core.online import AdaptConfig
from repro.core.sensor_control import (CaptureConfig, CaptureLog,
                                       ControllerConfig,
                                       assemble_capture_log, decimation)
from repro.distributed import sharding as shlib
from repro.sensing import adc as adc_sim
from repro.sensing import fleet as fleet_mod
from repro.sensing import stream as stream_mod
from repro.sensing.stream import StreamState, init_stream_state

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ServedChunk:
    """One collected tick: per-sensor outputs + the dispatch→collect lag.

    ``outputs[sid]`` is ``(scores (C,), fired (C,), gated (C,))`` numpy
    arrays for every sensor that delivered frames in the tick;
    ``sampled[sid]`` marks the frames its LP ADC actually converted
    (closed-loop mode). ``latency_s`` is wall time from ``dispatch``
    returning to the results being host-resident.
    """
    seq: int
    outputs: dict[Hashable, tuple[np.ndarray, np.ndarray, np.ndarray]]
    sampled: dict[Hashable, np.ndarray]
    latency_s: float


@dataclasses.dataclass
class _Parked:
    """Per-sensor state parked across detach (or never-yet-attached)."""
    uid: int
    n_seen: int
    hold: Any          # i32 scalar (device array — may still be in flight)
    phase: Any
    class_hvs: Any     # (2, D) in per-stream scope, else None


@dataclasses.dataclass
class _InFlight:
    """A dispatched, not-yet-collected tick (device futures + host meta)."""
    seq: int
    t0: float
    scores: Array
    fired: Array
    gated: Array
    sampled: Array
    sids: tuple                      # slot -> sid for arrival slots, else None
    starts: np.ndarray               # (S,) per-slot absolute frame base
    raw: np.ndarray | None           # host raw frames (HP capture only)


def _adc_convert_fn(frames: Array, keys: Array, starts: Array, *,
                    bits: int, sigma: float, codes: bool) -> Array:
    """Per-slot ADC front-end: one fused async unit ahead of the step.

    Each slot converts with its OWN noise key (folded per persistent
    sensor uid, not slot index) and its own absolute frame base, so a
    sensor's capture is bit-identical no matter which slot it lands in
    or how its stream interleaves with churn — the per-sensor twin of
    the runners' slicing invariance.
    """
    view = stream_mod.adc_view_codes if codes else stream_mod.adc_view
    return jax.vmap(lambda f, k, s0: view(f, bits, sigma=sigma, key=k,
                                          start_index=s0))(
                                              frames, keys, starts)


_ADC_STATIC = ("bits", "sigma", "codes")
#: float->float conversion aliases in place: the rotating raw super-chunk
#: buffer (fresh ``device_put`` each tick) is donated into its LP view.
_adc_convert = jax.jit(_adc_convert_fn, donate_argnums=(0,),
                       static_argnames=_ADC_STATIC)
#: float->integer codes cannot alias (dtype change) — no donation.
_adc_convert_codes = jax.jit(_adc_convert_fn, static_argnames=_ADC_STATIC)

#: uid-keyed noise: one key per slot, folded from the service key by the
#: slot's persistent sensor uid. Module-jitted so every dispatch tick
#: reuses one cache entry instead of building a fresh vmap per tick.
_fold_uid_keys = jax.jit(jax.vmap(jax.random.fold_in, in_axes=(None, 0)))


class FleetService:
    """Slot-pooled, double-buffered, checkpointed fleet serving.

    The always-on front door to the fleet runtime: sensors
    :meth:`attach` / :meth:`detach` dynamically (capacity is a fixed
    ``n_slots`` pool, rounded up to the mesh's "sensors" extent so the
    padded slot axis always shards), each service *tick* is one
    :meth:`dispatch` of ``chunk_size`` frames from whichever sensors
    have them ready (ragged arrival = absent from the dict), and
    :meth:`collect` returns finished ticks in FIFO order. Up to
    ``max_inflight`` ticks pipeline between host and device; state
    (classifier adaptation, gate hysteresis, closed-loop ADC phase)
    carries exactly as in :class:`~repro.sensing.fleet.FleetRunner`,
    whose jitted step this shares — with an all-true slot mask the two
    are bitwise identical.

    Config mirrors ``FleetRunner`` (``backend``, ``precision``,
    ``adc_bits``/``adc_sigma``, ``adapt``, ``control``, ``mesh``), plus:

    * ``n_slots`` — pool capacity (this replaces the runner's frozen S);
    * ``max_inflight`` — dispatched-but-uncollected ticks before
      ``dispatch`` itself drains the oldest (back-pressure);
    * ``ckpt_dir`` / ``ckpt_every`` / ``ckpt_keep`` — automatic async
      snapshots of the mutable fleet state every N ticks.

    Sensor ids must be JSON-serializable scalars (``str`` or ``int``) —
    they ride the checkpoint manifest.
    """

    def __init__(self, model: HyperSenseModel,
                 config: ControllerConfig | None = None, *,
                 n_slots: int, chunk_size: int = 32, backend: str = "jnp",
                 t_detection: int | None = None, block_d: int = 512,
                 adc_bits: int | None = None, adc_sigma: float = 0.0,
                 adc_key: Array | int = 0, mesh=None,
                 adapt: AdaptConfig | None = None,
                 precision: str = "float32",
                 control: CaptureConfig | None = None,
                 max_inflight: int = 2,
                 ckpt_dir: str | None = None, ckpt_every: int = 0,
                 ckpt_keep: int = 3):
        stream_mod.validate_runner_args(chunk_size, adc_bits, adc_sigma,
                                        precision)
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, "
                             f"got {max_inflight}")
        if ckpt_every and ckpt_dir is None:
            raise ValueError("ckpt_every > 0 needs ckpt_dir")
        self.model = model
        self.config = config or ControllerConfig()
        self.chunk_size = chunk_size
        self.backend = backend
        self.block_d = block_d
        self.t_detection = (model.t_detection if t_detection is None
                            else t_detection)
        self.adc_bits = adc_bits
        self.adc_sigma = adc_sigma
        self._adc_key = (jax.random.PRNGKey(adc_key)
                         if isinstance(adc_key, int) else adc_key)
        self.adapt = adapt
        self.precision = precision
        self.control = control
        self._decim = (None if control is None
                       else (decimation(self.config) if control.subsample
                             else 1))
        self.max_inflight = max_inflight
        self._mesh = mesh if mesh is not None else shlib.current_mesh()
        # capacity is padded ONCE: churn never re-pads, shapes never move
        self.n_slots = shlib.padded_extent(n_slots, "sensors", self._mesh)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self._ckpt = (ckpt_mod.AsyncCheckpointer(ckpt_dir, keep=ckpt_keep)
                      if ckpt_dir is not None else None)

        self._slots: list = [None] * self.n_slots   # slot -> sid
        self._by_sid: dict = {}                     # sid -> slot
        self._uids: dict = {}                       # sid -> persistent uid
        self._n_seen: dict = {}                     # sid -> abs frame count
        self._parked: dict[Any, _Parked] = {}
        self._logs: dict = {}      # sid -> (sampled blocks, gated blocks)
        self._hp: dict = {}        # sid -> [(abs_idx, frame), ...]
        self.hp_dropped = 0
        self._next_uid = 0
        self._seq = 0              # ticks dispatched so far
        self._frame_hw: tuple[int, int] | None = None
        self._frame_pixels = 0
        self._geom = None
        self._tiles = None
        self._step = None
        self._step_axes = None     # ("sensors" axes, k) resolved at build
        self._n_valid = jnp.int32(chunk_size)
        self._t_score = jnp.float32(model.t_score)
        # donated state rotates through the step forever — seed it with a
        # COPY so the model's own class_hvs buffer is never invalidated
        self._state = init_stream_state(
            jnp.array(np.asarray(model.class_hvs)), self.n_slots,
            per_stream=self._per_stream())
        self._pending: collections.deque[_InFlight] = collections.deque()
        self._ready: collections.deque[ServedChunk] = collections.deque()

    # ------------------------------------------------------------------
    # slot pool
    # ------------------------------------------------------------------

    def _per_stream(self) -> bool:
        return self.adapt is not None and self.adapt.scope == "per-stream"

    @property
    def attached(self) -> tuple:
        """Currently attached sensor ids, in slot order."""
        return tuple(sid for sid in self._slots if sid is not None)

    @property
    def free_slots(self) -> int:
        return sum(1 for sid in self._slots if sid is None)

    def uid(self, sid) -> int:
        """Persistent per-sensor uid (keys the ADC noise stream; survives
        detach/reattach and checkpoint/restore)."""
        return self._uids[sid]

    def attach(self, sid) -> int:
        """Claim a slot for ``sid``; returns the slot index.

        A previously detached sensor resumes its parked state — adapted
        classifier row, gate hold, ADC phase, frame counter, capture
        log — bitwise, even if other tenants used the slot meanwhile.
        """
        if not isinstance(sid, (str, int)):
            raise TypeError(f"sensor id must be str or int (rides the "
                            f"checkpoint manifest), got {type(sid)}")
        if sid in self._by_sid:
            raise ValueError(f"sensor {sid!r} already attached")
        try:
            slot = self._slots.index(None)
        except ValueError:
            raise RuntimeError(
                f"slot pool exhausted ({self.n_slots} slots, "
                f"{len(self._parked)} parked): detach a sensor or build "
                f"the service with more n_slots") from None
        st = self._state
        if sid in self._parked:
            p = self._parked.pop(sid)
            holds = st.holds.at[slot].set(p.hold)
            phases = st.phases.at[slot].set(p.phase)
            chvs = (st.class_hvs.at[slot].set(p.class_hvs)
                    if p.class_hvs is not None else st.class_hvs)
            self._n_seen[sid] = p.n_seen
            self._uids[sid] = p.uid
        else:
            holds = st.holds.at[slot].set(0)
            phases = st.phases.at[slot].set(0)
            chvs = (st.class_hvs.at[slot].set(self.model.class_hvs)
                    if st.class_hvs.ndim == 3 else st.class_hvs)
            self._n_seen[sid] = 0
            self._uids[sid] = self._next_uid
            self._next_uid += 1
            self._logs[sid] = ([], [])
            self._hp[sid] = []
        self._state = StreamState(class_hvs=chvs, holds=holds,
                                  phases=phases, frame_idx=st.frame_idx)
        self._slots[slot] = sid
        self._by_sid[sid] = slot
        return slot

    def detach(self, sid) -> None:
        """Release ``sid``'s slot, parking its state for reattach.

        Park is lazy device slices of the carried state — no pipeline
        sync: in-flight ticks keep executing and the parked values
        resolve whenever they are next needed.
        """
        slot = self._by_sid.pop(sid, None)
        if slot is None:
            raise ValueError(f"sensor {sid!r} is not attached")
        st = self._state
        self._parked[sid] = _Parked(
            uid=self._uids[sid], n_seen=self._n_seen[sid],
            hold=st.holds[slot], phase=st.phases[slot],
            class_hvs=(st.class_hvs[slot] if st.class_hvs.ndim == 3
                       else None))
        self._slots[slot] = None

    # ------------------------------------------------------------------
    # step plumbing (shared with FleetRunner)
    # ------------------------------------------------------------------

    def _ensure_geom(self, W: int):
        if self._geom is None:
            self._geom = stream_mod.model_geometry(
                self.model, W, self.block_d, self.precision)
        return self._geom

    def _ensure_tiles(self, W: int):
        if self._tiles is None:
            self._tiles = stream_mod.model_tiles(
                self.model, W, self.block_d, self.precision)
        return self._tiles

    def _ensure_step(self, W: int):
        """Build (once) the donated, park-masked fleet step + tile args."""
        if self.backend == "pallas" \
                or self.precision in adc_sim.INT_PRECISIONS:
            tiles = (self._ensure_geom(W) if self.adapt is not None
                     else self._ensure_tiles(W))
        else:
            tiles = None
        if self._step is None:
            m = self.model
            axes, k = fleet_mod._sensor_axes(self._mesh)
            hd_axes = fleet_mod._hyperdim_axes(self._mesh, tiles,
                                               self.backend, self.precision)
            self._step = fleet_mod._build_step(
                self._mesh, axes, hd_axes,
                fleet_mod._tiles_specs(tiles, hd_axes), donate=True,
                h=m.h, w=m.w, stride=m.stride,
                nonlinearity=m.nonlinearity, t_detection=self.t_detection,
                hold_frames=self.config.hold_frames, backend=self.backend,
                adapt=self.adapt, precision=self.precision,
                adc_lsb=self._adc_lsb, decim=self._decim, park_masked=True)
            self._step_axes = (axes, k)
        return self._step, tiles

    @property
    def _adc_lsb(self) -> float:
        return (adc_sim.lsb(self.adc_bits)
                if self.precision in adc_sim.INT_PRECISIONS else 1.0)

    def compile_count(self) -> int:
        """Cumulative XLA compilations of this service's step function.

        The churn contract's witness: after the warm-up tick, attach/
        detach/ragged arrival must leave this number frozen (asserted by
        ``tests/test_serve.py`` and ``benchmarks/serve_throughput.py
        --check``). Unsharded services share the module-level donated
        step's cache, so compare DELTAS around a trace, not absolutes.
        """
        step = self._step
        if step is None:
            return 0
        fn = step.func if isinstance(step, functools.partial) else step
        return fn._cache_size()

    def _put(self, x, spec=None):
        if self._mesh is None or spec is None:
            return jax.device_put(x)
        return jax.device_put(x, NamedSharding(self._mesh, spec))

    # ------------------------------------------------------------------
    # dispatch / collect
    # ------------------------------------------------------------------

    def dispatch(self, arrivals: dict, labels: dict | None = None) -> int:
        """Enqueue one service tick; returns its sequence number.

        ``arrivals`` maps attached sensor ids to ``(chunk_size, H, W)``
        frame blocks (raw float frames, or integer ADC codes under an
        integer precision); an attached sensor absent from the dict is
        masked for the tick — its carried state is parked in place, as
        if no time passed for it. ``labels`` (same keying, ``(C,)``
        ints) feeds ``adapt.mode == "label"`` updates.

        Returns as soon as the H2D transfer and the fleet step are
        *enqueued*; compute for up to ``max_inflight`` ticks overlaps
        the host assembling + transferring the next ones. Results come
        back through :meth:`collect`, oldest first.
        """
        C, S = self.chunk_size, self.n_slots
        label_mode = self.adapt is not None and self.adapt.mode == "label"
        if labels is not None and not label_mode:
            raise ValueError("labels passed without adapt.mode == 'label'")
        first = None
        for sid, fr in arrivals.items():
            if sid not in self._by_sid:
                raise ValueError(f"sensor {sid!r} is not attached")
            first = fr if first is None else first
        if first is not None and self._frame_hw is None:
            # shape peek only — np.shape reads .shape without pulling a
            # device arrival to host (the upload happens once, batched)
            shp = np.shape(first)
            if len(shp) != 3:
                raise ValueError(f"expected (chunk_size, H, W) arrival, "
                                 f"got shape {shp}")
            self._frame_hw = (int(shp[1]), int(shp[2]))
            self._frame_pixels = self._frame_hw[0] * self._frame_hw[1]
            if self.precision in adc_sim.INT_PRECISIONS:
                from repro.kernels import ops as kops
                kops.assert_int_datapath_fits(
                    self.adc_bits, *self._frame_hw, self.model.h,
                    self.model.w, stride=self.model.stride,
                    block_d=self.block_d,
                    packed=self.precision == "int4")
        H, W = self._frame_hw if self._frame_hw else (0, 0)
        if self._frame_hw is None:
            raise ValueError("first dispatch needs at least one arrival "
                             "to fix the frame shape")

        int_codes = (self.precision in adc_sim.INT_PRECISIONS
                     and all(np.issubdtype(np.result_type(f), np.integer)
                             for f in arrivals.values()) and arrivals)
        assemble = np.zeros((S, C, H, W),
                            np.int32 if int_codes else np.float32)
        mask_np = np.zeros((S,), bool)
        starts = np.zeros((S,), np.int32)
        uids = np.zeros((S,), np.int32)
        lab_np = np.zeros((S, C), np.int32)
        hp_k = stream_mod.resolve_hp_buffer(
            self.control, C,
            np.int32 if int_codes else np.float32)
        for sid, fr in arrivals.items():
            # repro-lint: disable=RA003 (admission boundary: ragged arrivals are normalized into the host assemble buffer, then uploaded once, batched)
            fr = np.asarray(fr)
            if fr.shape != (C, H, W):
                raise ValueError(
                    f"arrival for {sid!r} has shape {fr.shape}, expected "
                    f"(chunk_size, H, W) = {(C, H, W)} — a service tick "
                    f"is exactly one chunk; buffer partial chunks at the "
                    f"edge")
            slot = self._by_sid[sid]
            assemble[slot] = fr
            mask_np[slot] = True
            starts[slot] = self._n_seen[sid]
            uids[slot] = self._uids[sid]
            self._n_seen[sid] += C
            if label_mode:
                if labels is None or sid not in labels:
                    raise ValueError(f'adapt.mode == "label": arrival for '
                                     f"{sid!r} needs labels[{sid!r}]")
                # repro-lint: disable=RA003 (labels are caller-side host metadata, folded into the batched upload)
                lab_np[slot] = np.asarray(labels[sid], np.int32)

        axes = self._step_axes[0] if self._step_axes else \
            fleet_mod._sensor_axes(self._mesh)[0]
        s4 = P(axes, None, None, None) if axes else None
        s2 = P(axes, None) if axes else None
        s1 = P(axes) if axes else None
        frames = self._put(assemble, s4)      # H2D begins here, async
        mask = self._put(mask_np, s1)
        lab = self._put(lab_np, s2)

        if self.precision in adc_sim.INT_PRECISIONS and int_codes:
            # already-converted codes: concrete range check + pack (the
            # noise, if configured, applies before conversion — integer
            # input with sigma > 0 raises, as on the runners)
            frames = stream_mod.adc_view_codes(frames, self.adc_bits,
                                               sigma=self.adc_sigma)
        elif self.adc_bits is not None:
            keys = _fold_uid_keys(self._adc_key, self._put(uids, s1))
            codes = self.precision in adc_sim.INT_PRECISIONS
            conv = _adc_convert_codes if codes else _adc_convert
            frames = conv(frames, keys, self._put(starts, s1),
                          bits=self.adc_bits, sigma=self.adc_sigma,
                          codes=codes)

        step, tiles = self._ensure_step(W)
        m = self.model
        s, f, g, smp, new_state = step(
            frames, self._state, m.B0, m.b, tiles, self._t_score,
            self._n_valid, lab, mask)
        self._state = new_state
        self._seq += 1
        rec = _InFlight(
            seq=self._seq - 1, t0=time.perf_counter(), scores=s, fired=f,
            gated=g, sampled=smp,
            sids=tuple(sid if mask_np[i] else None
                       for i, sid in enumerate(self._slots)),
            starts=starts,
            raw=assemble if hp_k > 0 else None)
        self._pending.append(rec)
        while len(self._pending) > self.max_inflight:
            self._ready.append(self._finish(self._pending.popleft()))
        if self.ckpt_every and self._seq % self.ckpt_every == 0:
            self.checkpoint()
        return rec.seq

    def _finish(self, rec: _InFlight) -> ServedChunk:
        # collect IS the deliberate sync point of the pipeline: these
        # block only on the OLDEST in-flight tick, after max_inflight
        # newer ticks were already enqueued behind it.
        s = np.asarray(rec.scores)  # repro-lint: disable=RA003 (designed sync point: blocks on the oldest in-flight tick only)
        f = np.asarray(rec.fired)  # repro-lint: disable=RA003 (same designed sync point)
        g = np.asarray(rec.gated)  # repro-lint: disable=RA003 (same designed sync point)
        smp = np.asarray(rec.sampled)  # repro-lint: disable=RA003 (same designed sync point)
        latency = time.perf_counter() - rec.t0
        outputs, sampled = {}, {}
        for slot, sid in enumerate(rec.sids):
            if sid is None:
                continue
            outputs[sid] = (s[slot], f[slot], g[slot])
            sampled[sid] = smp[slot]
            logs = self._logs[sid]
            logs[0].append(smp[slot])
            logs[1].append(g[slot])
        if rec.raw is not None:
            hp_k = stream_mod.resolve_hp_buffer(self.control,
                                                self.chunk_size,
                                                rec.raw.dtype)
            # a detached-but-still-holding slot's gated output is masked
            # noise — it must not be HP-captured or counted as dropped
            act = np.array([sid is not None for sid in rec.sids])
            entries, dropped = stream_mod.collect_hp(
                rec.raw, g & act[:, None], self.chunk_size, hp_k,
                self.control.hp_bits, rec.starts)
            for slot, sid in enumerate(rec.sids):
                if sid is not None:
                    self._hp[sid].extend(entries[slot])
            self.hp_dropped += dropped
        return ServedChunk(seq=rec.seq, outputs=outputs, sampled=sampled,
                           latency_s=latency)

    def collect(self) -> ServedChunk | None:
        """Oldest finished tick (FIFO), or None when nothing is in flight.

        Blocks only until the oldest dispatched tick's outputs are
        host-resident — younger ticks keep executing behind it.
        """
        if self._ready:
            return self._ready.popleft()
        if not self._pending:
            return None
        return self._finish(self._pending.popleft())

    def flush(self) -> list[ServedChunk]:
        """Drain every in-flight tick (in order) — a full pipeline sync."""
        out = list(self._ready)
        self._ready.clear()
        while self._pending:
            out.append(self._finish(self._pending.popleft()))
        return out

    # ------------------------------------------------------------------
    # per-sensor views
    # ------------------------------------------------------------------

    def class_hvs_of(self, sid) -> np.ndarray:
        """The live ``(2, D)`` classifier serving ``sid`` (parked or
        attached). Shared scope returns the fleet classifier."""
        if self._state.class_hvs.ndim == 2:
            return np.asarray(self._state.class_hvs)
        if sid in self._parked:
            return np.asarray(self._parked[sid].class_hvs)
        return np.asarray(self._state.class_hvs[self._by_sid[sid]])

    def capture_log(self, sid) -> CaptureLog:
        """What ``sid``'s ADC actually converted so far (per-sensor
        billing ground truth; survives detach and checkpoint/restore)."""
        blocks = self._logs[sid]
        return assemble_capture_log(blocks[0], blocks[1],
                                    lp_bits=self.adc_bits,
                                    control=self.control,
                                    frame_pixels=self._frame_pixels)

    def drain_hp(self, sid) -> tuple[np.ndarray, np.ndarray]:
        """Take ``sid``'s high-precision burst frames captured so far
        (absolute frame indices + frames at ``control.hp_bits``). An
        empty drain keeps the real ``(0, H, W)`` frame shape
        (:func:`~repro.sensing.stream.hp_drain_arrays`) so cross-drain
        concatenation works — the cascade's contract."""
        idx, frames = stream_mod.hp_drain_arrays(self._hp[sid],
                                                 self._frame_hw)
        self._hp[sid] = []
        return idx, frames

    # ------------------------------------------------------------------
    # checkpoint / restore
    # ------------------------------------------------------------------

    def _snapshot(self) -> tuple[dict, dict]:
        """(single-level array tree, JSON extra) of the mutable state."""
        st = self._state
        tree = {"class_hvs": st.class_hvs, "holds": st.holds,
                "phases": st.phases, "frame_idx": st.frame_idx}
        parked_sids = list(self._parked)
        for i, sid in enumerate(parked_sids):
            p = self._parked[sid]
            tree[f"parked_hold_{i}"] = p.hold
            tree[f"parked_phase_{i}"] = p.phase
            if p.class_hvs is not None:
                tree[f"parked_chvs_{i}"] = p.class_hvs
        log_sids = list(self._logs)
        for i, sid in enumerate(log_sids):
            blocks = self._logs[sid]
            tree[f"log_sampled_{i}"] = (np.concatenate(blocks[0])
                                        if blocks[0]
                                        else np.zeros((0,), bool))
            tree[f"log_gated_{i}"] = (np.concatenate(blocks[1])
                                      if blocks[1]
                                      else np.zeros((0,), bool))
            # undrained HP burst frames ride the checkpoint too: the
            # cascade's deliverable must survive kill-and-resume, not
            # just the billing that accounts for it
            hp_idx, hp_frames = stream_mod.hp_drain_arrays(
                self._hp.get(sid, []), self._frame_hw)
            tree[f"hp_idx_{i}"] = hp_idx
            tree[f"hp_frames_{i}"] = hp_frames
        extra = {
            "chunks": self._seq,
            "slots": [[i, sid, self._uids[sid], self._n_seen[sid]]
                      for i, sid in enumerate(self._slots)
                      if sid is not None],
            "parked": [[sid, p.uid, p.n_seen,
                        f"parked_chvs_{i}" in tree]
                       for i, (sid, p) in enumerate(self._parked.items())],
            "log_sids": log_sids,
            "next_uid": self._next_uid,
            "frame_hw": list(self._frame_hw) if self._frame_hw else None,
            "n_slots": self.n_slots,
            "precision": self.precision,
        }
        return tree, extra

    def checkpoint(self) -> None:
        """Async snapshot of the mutable fleet state.

        Drains the in-flight pipeline into the ready queue first (their
        outputs stay collectable) so the saved state, frame counters and
        capture logs all describe the same tick boundary; the disk write
        then happens on the checkpointer's background thread while
        serving continues.
        """
        if self._ckpt is None:
            raise RuntimeError("service was built without ckpt_dir")
        while self._pending:
            self._ready.append(self._finish(self._pending.popleft()))
        tree, extra = self._snapshot()
        self._ckpt.save(self._seq, tree, extra=extra)

    def wait_ckpt(self) -> None:
        """Block until the last async checkpoint write is on disk."""
        if self._ckpt is not None:
            self._ckpt.wait()

    def restore(self, step: int | None = None) -> int:
        """Load fleet state from ``ckpt_dir`` into this (fresh) service.

        Rebuilds the slot table, parked pool, per-sensor counters and
        capture logs, and installs the saved ``StreamState`` — resuming
        the trace from the returned tick count is bitwise-identical to
        never having stopped (``tests/test_serve.py`` pins this on both
        backends). Construct the service with the SAME model/config as
        the saved run.
        """
        if self._ckpt is None:
            raise RuntimeError("service was built without ckpt_dir")
        if self._seq:
            raise RuntimeError("restore() needs a freshly constructed "
                               "service (no ticks dispatched)")
        leaves, extra = ckpt_mod.restore_tree(self.ckpt_dir, step=step)
        if extra["n_slots"] != self.n_slots:
            raise ValueError(f"checkpoint has n_slots={extra['n_slots']}, "
                             f"service has {self.n_slots}")
        if extra["precision"] != self.precision:
            raise ValueError(f"checkpoint precision {extra['precision']} "
                             f"!= service {self.precision}")
        self._state = StreamState(
            class_hvs=jnp.asarray(leaves["class_hvs"]),
            holds=jnp.asarray(leaves["holds"]),
            phases=jnp.asarray(leaves["phases"]),
            frame_idx=jnp.asarray(leaves["frame_idx"]))
        self._slots = [None] * self.n_slots
        self._by_sid, self._uids, self._n_seen = {}, {}, {}
        for slot, sid, uid, n_seen in extra["slots"]:
            self._slots[slot] = sid
            self._by_sid[sid] = slot
            self._uids[sid] = uid
            self._n_seen[sid] = n_seen
        self._parked = {}
        for i, (sid, uid, n_seen, has_chvs) in enumerate(extra["parked"]):
            self._parked[sid] = _Parked(
                uid=uid, n_seen=n_seen,
                hold=jnp.asarray(leaves[f"parked_hold_{i}"]),
                phase=jnp.asarray(leaves[f"parked_phase_{i}"]),
                class_hvs=(jnp.asarray(leaves[f"parked_chvs_{i}"])
                           if has_chvs else None))
            self._uids[sid] = uid
            self._n_seen[sid] = n_seen
        self._logs = {}
        self._hp = {}
        for i, sid in enumerate(extra["log_sids"]):
            self._logs[sid] = ([leaves[f"log_sampled_{i}"]]
                               if leaves[f"log_sampled_{i}"].size else [],
                               [leaves[f"log_gated_{i}"]]
                               if leaves[f"log_gated_{i}"].size else [])
            if f"hp_idx_{i}" in leaves:        # absent in pre-cascade ckpts
                self._hp[sid] = list(zip(
                    leaves[f"hp_idx_{i}"].tolist(),
                    leaves[f"hp_frames_{i}"].astype(np.float32)))
            else:
                self._hp[sid] = []
        self._next_uid = extra["next_uid"]
        self._seq = extra["chunks"]
        if extra["frame_hw"]:
            self._frame_hw = tuple(extra["frame_hw"])
            self._frame_pixels = self._frame_hw[0] * self._frame_hw[1]
        return self._seq
