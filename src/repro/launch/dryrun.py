import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
init, and the production meshes need 512 host-platform placeholder devices.

Per cell:
  * build the step function + shardings (repro.launch.steps)
  * ``jax.jit(step, in_shardings, out_shardings).lower(*specs).compile()``
  * print ``compiled.memory_analysis()`` (proves it fits) and
    ``cost_analysis()`` (FLOPs/bytes for the roofline)
  * append the roofline record to ``--out`` (JSON lines)

Usage:
  python -m repro.launch.dryrun --arch olmo-1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all            # every runnable cell
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro import configs
from repro.configs.base import applicable_shapes
from repro.distributed import roofline as rl
from repro.distributed import sharding as shlib
from repro.launch import steps
from repro.launch.mesh import make_production_mesh
from repro.models import common, lm


def mesh_for(name: str):
    return make_production_mesh(multi_pod=(name == "multi"))


# --------------------------------------------------------------------------
# Roofline mode: two-point layer scaling.
#
# HLO cost analysis counts while-loop bodies once, and fully-unrolled
# 95-layer stacks don't compile in reasonable time on this 1-core host.
# Layer stacks are homogeneous, so costs are affine in depth:
#     C(L) = fixed + L * per_layer
# Lower UNROLLED at two small depths (L1 < L2, chosen to preserve the
# block mix for hybrid/ssm archs), solve for (fixed, per_layer), and
# extrapolate to the full depth. Exact for FLOPs/bytes/collectives of
# homogeneous stacks; memory comes from the production (scan) lowering.
# --------------------------------------------------------------------------

def _probe_depths(cfg) -> tuple[int, int]:
    if cfg.family == "hybrid":
        k = cfg.shared_attn_every
        return k, 2 * k              # 1 and 2 shared-block invocations
    if cfg.family == "ssm" and cfg.slstm_every:
        k = cfg.slstm_every
        return k, 2 * k              # 1 and 2 sLSTM blocks
    return 2, 4


def _compile_cell(cfg, shape, mesh, rules):
    with shlib.use_mesh(mesh, rules):
        cell = steps.build_cell(cfg, shape, mesh, rules)
        jitted = jax.jit(cell.step_fn,
                         in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate_argnums)
        return jitted.lower(*cell.abstract_args).compile()


def run_cell_roofline(arch: str, shape_name: str, mesh_name: str = "single",
                      rules: dict | None = None,
                      out_path: str | None = None,
                      verbose: bool = True,
                      overrides: dict | None = None) -> dict:
    cfg = configs.get_config(arch).replace(scan_layers=False,
                                           **(overrides or {}))
    shape = configs.SHAPES[shape_name]
    mesh = mesh_for(mesh_name)
    chips = mesh.devices.size
    l_full = cfg.n_layers
    l1, l2 = _probe_depths(cfg)

    t0 = time.time()
    probes = {}
    for li in (l1, l2):
        compiled = _compile_cell(cfg.replace(n_layers=li), shape, mesh,
                                 rules)
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        coll = rl.collective_bytes(compiled.as_text())
        probes[li] = {
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll,
        }

    def affine(key):
        per_layer = (probes[l2][key] - probes[l1][key]) / (l2 - l1)
        fixed = probes[l1][key] - l1 * per_layer
        return fixed + l_full * per_layer

    coll_full = {}
    for op in set(probes[l1]["coll"]) | set(probes[l2]["coll"]):
        pl_ = (probes[l2]["coll"].get(op, 0)
               - probes[l1]["coll"].get(op, 0)) / (l2 - l1)
        coll_full[op] = max(0.0, probes[l1]["coll"].get(op, 0)
                            - l1 * pl_ + l_full * pl_)

    n_params = common.spec_param_count(lm.build(configs.get_config(arch)
                                                ).spec())
    rec = rl.Roofline(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_gflops=affine("flops") * chips / 1e9,
        hlo_gbytes=affine("bytes") * chips / 1e9,
        coll_gbytes=sum(coll_full.values()) / 1e9,
        coll_breakdown={k: v / 1e9 for k, v in coll_full.items() if v},
        model_gflops=rl.model_flops(cfg, shape, n_params) / 1e9,
    ).to_dict()
    rec.update(n_params=n_params, status="ok", mode="roofline",
               probe_depths=[l1, l2], total_s=round(time.time() - t0, 1))
    if verbose:
        print(f"=== ROOFLINE {arch} x {shape_name} x {mesh_name} "
              f"(probes L={l1},{l2} -> {l_full}) ===")
        print("terms (s): compute=%.4f memory=%.4f collective=%.4f -> %s"
              % (rec["t_compute"], rec["t_memory"], rec["t_collective"],
                 rec["bottleneck"]))
        print("roofline fraction=%.3f useful-flop ratio=%.3f  (%.0fs)" % (
            rec["roofline_fraction"], rec["useful_flop_ratio"],
            rec["total_s"]))
        print("collectives (GB/device):", rec["coll_breakdown"])
    if out_path:
        with open(out_path, "a") as f:
            f.write(json.dumps(rec) + "\n")
    return rec


def run_cell(arch: str, shape_name: str, mesh_name: str,
             rules: dict | None = None, out_path: str | None = None,
             verbose: bool = True, unroll: bool = False) -> dict:
    cfg = configs.get_config(arch)
    if unroll:
        # roofline-accurate lowering: HLO cost analysis counts while-loop
        # bodies once, so the roofline table is derived from python-loop
        # (unrolled) layer stacks; the production (scan) lowering is what
        # the plain dry-run compiles.
        cfg = cfg.replace(scan_layers=False)
    shape = configs.SHAPES[shape_name]
    mesh = mesh_for(mesh_name)
    chips = mesh.devices.size

    t0 = time.time()
    with shlib.use_mesh(mesh, rules):
        cell = steps.build_cell(cfg, shape, mesh, rules)
        jitted = jax.jit(cell.step_fn,
                         in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate_argnums)
        lowered = jitted.lower(*cell.abstract_args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    n_params = common.spec_param_count(lm.build(cfg).spec())
    rec = rl.from_compiled(compiled, arch=arch, shape=shape,
                           mesh_name=mesh_name, chips=chips, cfg=cfg,
                           n_params=n_params).to_dict()
    rec.update(n_params=n_params, lower_s=round(t_lower, 1),
               compile_s=round(t_compile, 1), status="ok",
               unrolled=unroll)

    if verbose:
        print(f"=== {arch} x {shape_name} x {mesh_name} "
              f"({chips} chips) ===")
        print(f"params: {n_params/1e9:.2f}B  "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s")
        print("memory_analysis:", mem)
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        print("cost_analysis: flops=%.3e bytes=%.3e" % (
            cost.get("flops", 0), cost.get("bytes accessed", 0)))
        print("collectives (GB):", rec["coll_breakdown"])
        print("terms (s): compute=%.4f memory=%.4f collective=%.4f -> %s"
              % (rec["t_compute"], rec["t_memory"], rec["t_collective"],
                 rec["bottleneck"]))
        print("roofline fraction=%.3f useful-flop ratio=%.3f" % (
            rec["roofline_fraction"], rec["useful_flop_ratio"]))

    if out_path:
        with open(out_path, "a") as f:
            f.write(json.dumps(rec) + "\n")
    return rec


def all_cells(mesh_names=("single", "multi")):
    for arch in configs.ARCH_IDS:
        cfg = configs.get_config(arch)
        shapes = applicable_shapes(cfg)
        for shape_name, sc in shapes.items():
            if sc is None:
                continue
            for mesh_name in mesh_names:
                yield arch, shape_name, mesh_name


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--rules", default=None,
                    help="JSON dict of sharding-rule overrides")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer stacks for loop-exact cost analysis")
    ap.add_argument("--roofline", action="store_true",
                    help="two-point layer-scaled roofline analysis")
    ap.add_argument("--override", default=None,
                    help="JSON dict of ModelConfig field overrides")
    args = ap.parse_args()
    rules = json.loads(args.rules) if args.rules else None

    if args.all:
        failures = []
        meshes = ("single",) if args.roofline else ("single", "multi")
        for arch, shape_name, mesh_name in all_cells(meshes):
            try:
                if args.roofline:
                    run_cell_roofline(arch, shape_name, mesh_name, rules,
                                      args.out)
                else:
                    run_cell(arch, shape_name, mesh_name, rules, args.out,
                             unroll=args.unroll)
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                failures.append((arch, shape_name, mesh_name, str(e)))
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps({
                            "arch": arch, "shape": shape_name,
                            "mesh": mesh_name, "status": "fail",
                            "error": str(e)[:500]}) + "\n")
        print(f"\n{len(failures)} failures")
        for f_ in failures:
            print("FAIL:", f_)
        return 1 if failures else 0

    if args.roofline:
        run_cell_roofline(args.arch, args.shape, args.mesh, rules, args.out,
                          overrides=json.loads(args.override)
                          if args.override else None)
    else:
        run_cell(args.arch, args.shape, args.mesh, rules, args.out,
                 unroll=args.unroll)
    return 0


if __name__ == "__main__":
    sys.exit(main())
