"""Grouped-query attention: full (train/prefill) + cached decode step.

Covers every assigned transformer family: MHA (kv=heads), GQA (kv<heads),
causal and bidirectional, optional QK-norm (Qwen3), RoPE.

Sharding: head dims carry the "heads"/"kv_heads" logical axes -> tensor
parallel over the "model" mesh axis; the KV cache shards batch over
("pod","data") and kv_heads over "model" when divisible.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import common
from repro.models.common import P

Array = jax.Array


class AttnConfig(NamedTuple):
    d_model: int
    n_heads: int
    kv_heads: int
    head_dim: int
    causal: bool = True
    rope_theta: float = 10000.0
    qk_norm: bool = False
    norm: str = "rmsnorm"
    q_chunk: int = 1024   # query-block size: caps the live score buffer


def spec(cfg: AttnConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.head_dim
    s = {
        "wq": P((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": P((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": P((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": P((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        s["q_norm"] = common.norm_spec(hd, cfg.norm)
        s["k_norm"] = common.norm_spec(hd, cfg.norm)
    return s


def _project_qkv(params: dict, x: Array, cfg: AttnConfig, positions: Array):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    if cfg.qk_norm:
        q = common.apply_norm(q, params["q_norm"], cfg.norm)
        k = common.apply_norm(k, params["k_norm"], cfg.norm)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "act_batch", "act_seq", "act_heads", None)
    k = shard(k, "act_batch", "act_seq", "act_kv_heads", None)
    v = shard(v, "act_batch", "act_seq", "act_kv_heads", None)
    return q, k, v


def _sdpa_block(q: Array, k: Array, v: Array, cfg: AttnConfig,
                q_positions: Array, k_positions: Array,
                k_mask: Array | None = None) -> Array:
    """One query block: (b, sq, h, hd) x (b, sk, kv, hd) -> (b, sq, h, hd).

    Scores are materialized with the (kv, group) dims merged so the full
    head dim (h = kv*group) can claim the "model" mesh axis even when
    kv_heads alone doesn't divide it.
    """
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    group = h // kv
    qg = q.reshape(b, sq, kv, group, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(hd)
    sk = k.shape[1]
    scores = scores.reshape(b, h, sq, sk)
    scores = shard(scores, "act_batch", "act_heads", None, None)
    neg = jnp.finfo(jnp.float32).min
    if cfg.causal:
        causal = q_positions[:, None] >= k_positions[None, :]   # (sq, sk)
        scores = jnp.where(causal[None, None, :, :], scores, neg)
    if k_mask is not None:                                      # (b, sk)
        scores = jnp.where(k_mask[:, None, None, :], scores, neg)
    attn = jax.nn.softmax(scores, axis=-1)
    attn = attn.reshape(b, kv, group, sq, sk)
    out = jnp.einsum("bkgqs,bskh->bqkgh", attn, v.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def _sdpa(q: Array, k: Array, v: Array, cfg: AttnConfig,
          q_positions: Array, k_positions: Array,
          k_mask: Array | None = None) -> Array:
    """Query-chunked attention: the live score buffer is capped at
    (b, h, q_chunk, sk) — flash-style blocking without the online-softmax
    pass (each query row still sees all keys, so per-block softmax is
    exact). Python loop, not lax.scan: keeps HLO cost analysis exact and
    lets XLA pipeline blocks."""
    sq = q.shape[1]
    qc = cfg.q_chunk
    if sq <= qc:
        return _sdpa_block(q, k, v, cfg, q_positions, k_positions, k_mask)
    outs = []
    for lo in range(0, sq, qc):
        hi = min(lo + qc, sq)       # ragged tail allowed (e.g. VLM prefix)
        sl = slice(lo, hi)
        # causal: skip key blocks that are entirely masked for this
        # query block (the flash-attention triangle-skipping trick)
        k_end = min(hi, k.shape[1]) if cfg.causal else k.shape[1]
        outs.append(_sdpa_block(
            q[:, sl], k[:, :k_end], v[:, :k_end], cfg, q_positions[sl],
            k_positions[:k_end],
            None if k_mask is None else k_mask[:, :k_end]))
    return jnp.concatenate(outs, axis=1)


def full(params: dict, x: Array, cfg: AttnConfig,
         positions: Array | None = None) -> Array:
    """Training / prefill attention over the whole sequence."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q, k, v = _project_qkv(params, x, cfg, positions)
    out = _sdpa(q, k, v, cfg, positions, positions)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return shard(out, "act_batch", "act_seq", "act_embed")


class KVCache(NamedTuple):
    """Decode-time cache: pre-filled keys/values + current length."""
    k: Array        # (b, max_s, kv, hd)
    v: Array        # (b, max_s, kv, hd)


def cache_spec(cfg: AttnConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> KVCache:
    shape = (batch, max_seq, cfg.kv_heads, cfg.head_dim)
    return KVCache(jax.ShapeDtypeStruct(shape, dtype),
                   jax.ShapeDtypeStruct(shape, dtype))


def cache_axes() -> KVCache:
    # "cache_seq" (not "act_seq"): for archs whose kv_heads don't divide the
    # model axis, the rules shard the cache along sequence instead (the
    # taken-set resolution in repro.distributed.sharding picks whichever
    # dim divides; attention softmax then reduces over the model axis).
    ax = ("act_batch", "cache_seq", "act_kv_heads", None)
    return KVCache(ax, ax)


def init_cache(cfg: AttnConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> KVCache:
    shape = (batch, max_seq, cfg.kv_heads, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def decode_step(params: dict, x: Array, cache: KVCache, index: Array,
                cfg: AttnConfig) -> tuple[Array, KVCache]:
    """One-token decode: x (b, 1, d); cache holds ``index`` valid tokens."""
    b = x.shape[0]
    positions = jnp.full((1,), index, dtype=jnp.int32)
    q, k_new, v_new = _project_qkv(params, x, cfg, positions)
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new.astype(cache.k.dtype),
                                            index, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new.astype(cache.v.dtype),
                                            index, axis=1)
    k = shard(k, "act_batch", "cache_seq", "act_kv_heads", None)
    v = shard(v, "act_batch", "cache_seq", "act_kv_heads", None)
    max_s = k.shape[1]
    k_positions = jnp.arange(max_s)
    valid = (k_positions <= index)[None, :].repeat(b, 0)      # (b, max_s)
    out = _sdpa(q, k.astype(q.dtype), v.astype(q.dtype), cfg,
                positions, k_positions, k_mask=valid)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    out = shard(out, "act_batch", "act_seq", "act_embed")
    return out, KVCache(k, v)
