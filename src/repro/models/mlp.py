"""Feed-forward blocks: SwiGLU dense MLP and top-k MoE.

MoE uses capacity-bounded sort-based dispatch (GShard-style capacity, but
scatter/gather instead of the O(N*E*C) one-hot einsum): tokens are ranked
within their assigned expert via an argsort; tokens beyond expert capacity
are dropped (standard). Experts shard over the "model" mesh axis (EP) —
with tokens sharded over "data", XLA inserts the all-to-all at the
dispatch/return boundaries.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.common import P

Array = jax.Array


# ---------------------------------------------------------------------------
# Dense SwiGLU
# ---------------------------------------------------------------------------

class MLPConfig(NamedTuple):
    d_model: int
    d_ff: int
    activation: str = "silu"     # silu (llama family) | gelu (encoders)
    gated: bool = True


def spec(cfg: MLPConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    s = {"w_up": P((d, f), ("embed", "mlp")),
         "w_down": P((f, d), ("mlp", "embed"))}
    if cfg.gated:
        s["w_gate"] = P((d, f), ("embed", "mlp"))
    return s


def _act(x: Array, kind: str) -> Array:
    return jax.nn.silu(x) if kind == "silu" else jax.nn.gelu(x)


def apply(params: dict, x: Array, cfg: MLPConfig) -> Array:
    dt = x.dtype
    up = x @ params["w_up"].astype(dt)
    up = shard(up, "act_batch", "act_seq", "act_mlp")
    if cfg.gated:
        gate = _act(x @ params["w_gate"].astype(dt), cfg.activation)
        h = gate * up
    else:
        h = _act(up, cfg.activation)
    out = h @ params["w_down"].astype(dt)
    return shard(out, "act_batch", "act_seq", "act_embed")


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------

class MoEConfig(NamedTuple):
    d_model: int
    d_ff: int                 # per-expert hidden
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    activation: str = "silu"
    router_aux_weight: float = 0.01
    dispatch_int8: bool = False   # quantize the EP dispatch gather payload


def moe_spec(cfg: MoEConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": P((d, e), ("embed", "expert")),
        "w_gate": P((e, d, f), ("expert", "embed", "expert_mlp")),
        "w_up": P((e, d, f), ("expert", "embed", "expert_mlp")),
        "w_down": P((e, f, d), ("expert", "expert_mlp", "embed")),
    }


def _capacity(n_tokens: int, cfg: MoEConfig) -> int:
    cap = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(cap, cfg.top_k)


def moe_apply(params: dict, x: Array, cfg: MoEConfig
              ) -> tuple[Array, Array]:
    """(b, s, d) -> ((b, s, d), aux_loss).

    Sort-based capacity dispatch:
      1. router softmax -> top-k (expert, weight) per token
      2. rank tokens within each expert (argsort by expert id)
      3. scatter into (E, C, d) buffers (drop beyond capacity)
      4. batched expert SwiGLU: (E, C, d) x (E, d, f)
      5. weighted scatter-add back to token positions
    """
    b, s, d = x.shape
    n = b * s
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(n, cfg)
    dt = x.dtype
    xf = x.reshape(n, d)

    # --- route ---
    logits = (xf.astype(jnp.float32)
              @ params["router"].astype(jnp.float32))           # (n, e)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_e = jax.lax.top_k(probs, k)                    # (n, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch): E * sum(frac_tokens * frac_prob)
    me = jnp.mean(probs, axis=0)                                # (e,)
    ce = jnp.mean(jax.nn.one_hot(gate_e[:, 0], e), axis=0)
    aux = cfg.router_aux_weight * e * jnp.sum(me * ce)

    # --- rank within expert ---
    flat_e = gate_e.reshape(-1)                                 # (n*k,)
    sort_idx = jnp.argsort(flat_e, stable=True)                 # (n*k,)
    sorted_e = flat_e[sort_idx]
    counts = jnp.bincount(flat_e, length=e)                     # (e,)
    offsets = jnp.cumsum(counts) - counts                       # (e,)
    pos_sorted = jnp.arange(n * k) - offsets[sorted_e]          # rank in expert
    pos = jnp.zeros(n * k, jnp.int32).at[sort_idx].set(
        pos_sorted.astype(jnp.int32))                           # (n*k,)
    keep = pos < cap

    # --- dispatch: scatter token INDICES (int32), then one row-gather ---
    # Scattering indices instead of activation rows keeps the cross-shard
    # payload at N*d (one all-gather of the token matrix) instead of
    # N*k*d (k copies of every token) — an 8x collective reduction for
    # top-8 routing (§Perf hillclimb C2).
    tok_idx = jnp.repeat(jnp.arange(n), k)                      # (n*k,)
    dest_e = jnp.where(keep, flat_e, e)         # overflow -> dropped row
    dest_c = jnp.where(keep, pos, 0)
    idx_buf = jnp.full((e + 1, cap), n, jnp.int32)  # n = zero-row sentinel
    idx_buf = idx_buf.at[dest_e, dest_c].set(tok_idx.astype(jnp.int32),
                                             mode="drop")
    if cfg.dispatch_int8:
        # int8-quantize the token matrix so the cross-shard dispatch
        # gather moves 2x less than bf16 (4x less than f32); per-token
        # symmetric scales ride along (n x 4 bytes). Expert MLPs tolerate
        # the ~1/127 relative error (§Perf hillclimb C6).
        scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1, keepdims=True),
                            1e-6).astype(jnp.float32) / 127.0
        xq = jnp.clip(jnp.round(xf.astype(jnp.float32) / scale),
                      -127, 127).astype(jnp.int8)
        xq_pad = jnp.concatenate([xq, jnp.zeros((1, d), jnp.int8)], axis=0)
        sc_pad = jnp.concatenate([scale, jnp.ones((1, 1), jnp.float32)],
                                 axis=0)
        buf = (xq_pad[idx_buf[:e]].astype(jnp.float32)
               * sc_pad[idx_buf[:e]]).astype(dt)                # (e, cap, d)
    else:
        xf_pad = jnp.concatenate([xf, jnp.zeros((1, d), dt)], axis=0)
        buf = xf_pad[idx_buf[:e]]                               # (e, cap, d)
    buf = shard(buf, "act_expert", "act_expert_cap", None)

    # --- expert compute (batched over experts) ---
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf,
                                  params["w_gate"].astype(dt))) \
        if cfg.activation == "silu" else \
        jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf,
                               params["w_gate"].astype(dt)))
    up = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(dt))
    hidden = shard(gate * up, "act_expert", "act_expert_cap", None)
    out_buf = jnp.einsum("ecf,efd->ecd", hidden,
                         params["w_down"].astype(dt))           # (e, cap, d)

    # --- return: gather expert outputs back to tokens, weighted combine ---
    flat_w = gate_w.reshape(-1).astype(dt)                      # (n*k,)
    expert_out = out_buf[dest_e.clip(0, e - 1), dest_c]         # (n*k, d)
    expert_out = jnp.where((keep & (dest_e < e))[:, None], expert_out, 0)
    combined = jnp.zeros((n, d), dt).at[tok_idx].add(
        expert_out * flat_w[:, None])
    out = combined.reshape(b, s, d)
    return shard(out, "act_batch", "act_seq", "act_embed"), aux
