"""Mamba-2 (SSD) blocks: chunked train/prefill scan + single-step decode.

Follows the SSD formulation of Mamba-2 [arXiv:2405.21060] (the
``ssd_minimal`` reference): within-chunk quadratic form + inter-chunk
recurrent state passing, implemented with ``jax.lax`` scans so the lowered
HLO stays compact for 38-95 layer stacks.

Sharding: the inner dim ("ssm_inner") and heads shard over "model";
the recurrent state (b, h, p, n) shards batch over ("pod","data") and
heads over "model".
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import common
from repro.models.common import P

Array = jax.Array


class SSMConfig(NamedTuple):
    d_model: int
    d_inner: int         # = expand * d_model (Mamba2 default expand=2)
    n_heads: int         # d_inner // head_dim
    head_dim: int
    d_state: int
    n_groups: int = 1
    d_conv: int = 4
    chunk: int = 256


def spec(cfg: SSMConfig) -> dict:
    d, di, h, n, g = (cfg.d_model, cfg.d_inner, cfg.n_heads, cfg.d_state,
                      cfg.n_groups)
    conv_dim = di + 2 * g * n
    d_in_proj = 2 * di + 2 * g * n + h
    return {
        "in_proj": P((d, d_in_proj), ("embed", "ssm_inner")),
        "conv_w": P((cfg.d_conv, conv_dim), ("conv_k", "conv_dim")),
        "conv_b": P((conv_dim,), ("conv_dim",), "zeros"),
        "A_log": P((h,), ("ssm_heads",), "zeros"),
        "D": P((h,), ("ssm_heads",), "ones"),
        "dt_bias": P((h,), ("ssm_heads",), "zeros"),
        "norm": {"scale": P((di,), ("norm",), "ones")},
        "out_proj": P((di, d), ("ssm_inner", "embed")),
    }


def _split_proj(zxbcdt: Array, cfg: SSMConfig):
    di, g, n, h = cfg.d_inner, cfg.n_groups, cfg.d_state, cfg.n_heads
    z, xs, B, C, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + g * n, 2 * di + 2 * g * n], axis=-1)
    return z, xs, B, C, dt


def _segsum(x: Array) -> Array:
    """(..., q) -> (..., q, q) lower-triangular segment sums."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x: Array, dt: Array, A: Array, B: Array, C: Array,
                chunk: int) -> tuple[Array, Array]:
    """SSD scan: returns (y, final_state).

    x: (b, s, h, p); dt: (b, s, h) (post-softplus); A: (h,) negative;
    B, C: (b, s, g, n). s must be a multiple of ``chunk``.
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert s % chunk == 0, (s, chunk)
    c = s // chunk
    rep = h // g

    xd = (x * dt[..., None]).astype(jnp.float32)             # discretized
    dA = (dt * A).astype(jnp.float32)                        # (b, s, h)

    def ch(t):  # (b, s, ...) -> (b, c, q, ...)
        return t.reshape(b, c, chunk, *t.shape[2:])

    xc, dAc = ch(xd), ch(dA)                                 # (b,c,q,h,p)
    Bc = jnp.repeat(ch(B.astype(jnp.float32)), rep, axis=3)  # (b,c,q,h,n)
    Cc = jnp.repeat(ch(C.astype(jnp.float32)), rep, axis=3)

    dA_t = jnp.moveaxis(dAc, -1, 2)                          # (b, c, h, q)
    dA_cs = jnp.cumsum(dA_t, axis=-1)                        # (b, c, h, q)
    L = jnp.exp(_segsum(dA_t))                               # (b, c, h, q, q)

    # within-chunk (diagonal blocks)
    y_diag = jnp.einsum("bcqhn,bckhn,bchqk,bckhp->bcqhp", Cc, Bc, L, xc)

    # per-chunk states
    decay_states = jnp.exp(dA_cs[..., -1:] - dA_cs)          # (b, c, h, q)
    states = jnp.einsum("bckhn,bchk,bckhp->bchpn", Bc, decay_states, xc)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cs[..., -1])                    # (b, c, h)

    def scan_fn(s_prev, inp):
        st, dec = inp                                        # (b,h,p,n),(b,h)
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    init = jnp.zeros((b, h, p, n), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)            # (b, c, h, p, n)

    state_decay_out = jnp.exp(dA_cs)                         # (b, c, h, q)
    y_off = jnp.einsum("bcqhn,bchpn,bchq->bcqhp", Cc, prev_states,
                       state_decay_out)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y.astype(x.dtype), final_state


class SSMState(NamedTuple):
    """Decode-time recurrent state."""
    ssm: Array       # (b, h, p, n) fp32
    conv: Array      # (b, d_conv - 1, conv_dim)


def state_spec(cfg: SSMConfig, batch: int,
               conv_dtype=jnp.bfloat16) -> SSMState:
    conv_dim = cfg.d_inner + 2 * cfg.n_groups * cfg.d_state
    return SSMState(
        jax.ShapeDtypeStruct(
            (batch, cfg.n_heads, cfg.head_dim, cfg.d_state), jnp.float32),
        jax.ShapeDtypeStruct((batch, cfg.d_conv - 1, conv_dim), conv_dtype))


def state_axes() -> SSMState:
    return SSMState(("act_batch", "act_ssm_heads", None, None),
                    ("act_batch", None, None))


def init_state(cfg: SSMConfig, batch: int,
               conv_dtype=jnp.bfloat16) -> SSMState:
    conv_dim = cfg.d_inner + 2 * cfg.n_groups * cfg.d_state
    return SSMState(
        jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.d_state),
                  jnp.float32),
        jnp.zeros((batch, cfg.d_conv - 1, conv_dim), conv_dtype))


def _causal_conv(xs: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv over (b, s, c) with kernel (k, c)."""
    k = w.shape[0]
    pad = jnp.pad(xs, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xs.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    return jax.nn.silu(out + b)


def apply(params: dict, x: Array, cfg: SSMConfig) -> Array:
    """Full-sequence Mamba2 mixer (train / prefill). (b, s, d) -> same."""
    b, s, _ = x.shape
    dt_ = x.dtype
    zxbcdt = x @ params["in_proj"].astype(dt_)
    z, xs, B, C, dtr = _split_proj(zxbcdt, cfg)
    xBC = _causal_conv(jnp.concatenate([xs, B, C], -1),
                       params["conv_w"].astype(dt_),
                       params["conv_b"].astype(dt_))
    xs, B, C = jnp.split(
        xBC, [cfg.d_inner, cfg.d_inner + cfg.n_groups * cfg.d_state], -1)
    dt = jax.nn.softplus(dtr.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xs.reshape(b, s, cfg.n_heads, cfg.head_dim)
    xh = shard(xh, "act_batch", "act_seq", "act_ssm_heads", None)
    Bh = B.reshape(b, s, cfg.n_groups, cfg.d_state)
    Ch = C.reshape(b, s, cfg.n_groups, cfg.d_state)
    y, _ = ssd_chunked(xh, dt, A, Bh, Ch, min(cfg.chunk, s))
    y = y + params["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(b, s, cfg.d_inner)
    y = common.rms_norm(y * jax.nn.silu(z), params["norm"]["scale"])
    out = y @ params["out_proj"].astype(dt_)
    return shard(out, "act_batch", "act_seq", "act_embed")


def decode_step(params: dict, x: Array, state: SSMState, cfg: SSMConfig
                ) -> tuple[Array, SSMState]:
    """One-token recurrent step. x: (b, 1, d)."""
    b = x.shape[0]
    dt_ = x.dtype
    zxbcdt = x[:, 0, :] @ params["in_proj"].astype(dt_)       # (b, dproj)
    z, xs, B, C, dtr = _split_proj(zxbcdt, cfg)

    # conv state update
    xBC_new = jnp.concatenate([xs, B, C], -1)                 # (b, conv_dim)
    conv_buf = jnp.concatenate(
        [state.conv, xBC_new[:, None, :].astype(state.conv.dtype)], axis=1)
    w = params["conv_w"].astype(dt_)                          # (k, conv_dim)
    out = jnp.einsum("bkc,kc->bc", conv_buf.astype(dt_), w)
    xBC = jax.nn.silu(out + params["conv_b"].astype(dt_))
    new_conv = conv_buf[:, 1:, :]
    xs, B, C = jnp.split(
        xBC, [cfg.d_inner, cfg.d_inner + cfg.n_groups * cfg.d_state], -1)

    dt = jax.nn.softplus(dtr.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (b, h)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A)                                      # (b, h)
    xh = xs.reshape(b, cfg.n_heads, cfg.head_dim).astype(jnp.float32)
    rep = cfg.n_heads // cfg.n_groups
    Bh = jnp.repeat(B.reshape(b, cfg.n_groups, cfg.d_state), rep,
                    axis=1).astype(jnp.float32)               # (b, h, n)
    Ch = jnp.repeat(C.reshape(b, cfg.n_groups, cfg.d_state), rep,
                    axis=1).astype(jnp.float32)
    dbx = jnp.einsum("bh,bhn,bhp->bhpn", dt, Bh, xh)
    new_ssm = state.ssm * dA[..., None, None] + dbx
    y = jnp.einsum("bhpn,bhn->bhp", new_ssm, Ch)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(b, cfg.d_inner).astype(dt_)
    y = common.rms_norm(y * jax.nn.silu(z), params["norm"]["scale"])
    out = (y @ params["out_proj"].astype(dt_))[:, None, :]
    return shard(out, "act_batch", "act_seq", "act_embed"), \
        SSMState(new_ssm, new_conv)
