"""Model zoo: every assigned architecture as composable JAX modules."""

from repro.models import (  # noqa: F401
    attention,
    common,
    lm,
    mlp,
    ssm,
    xlstm,
)
