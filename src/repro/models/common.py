"""Shared model substrate: param specs, norms, embeddings, RoPE.

Param definition uses a tiny single-source-of-truth spec system: every
parameter is declared once as :class:`P` (shape + logical sharding axes +
init); materialization (:func:`init_params`), abstract shapes
(:func:`abstract_params`) and shardings (:func:`param_shardings`) all
derive from the same spec — they cannot drift apart.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.distributed import sharding as shlib

Array = jax.Array


class P(NamedTuple):
    """Declaration of one parameter tensor."""
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"            # normal | zeros | ones
    scale: float | None = None      # stddev; default fan-in

    def with_layers(self, n_layers: int) -> "P":
        """Prefix a scan-stacked ``layers`` dim."""
        return P((n_layers, *self.shape), ("layers", *self.axes),
                 self.init, self.scale)


SpecTree = Any  # nested dict[str, P]


def map_layers(spec: SpecTree, n_layers: int) -> SpecTree:
    return jax.tree.map(lambda p: p.with_layers(n_layers), spec,
                        is_leaf=lambda x: isinstance(x, P))


def init_params(key: Array, spec: SpecTree,
                dtype: jnp.dtype = jnp.float32) -> dict:
    leaves, treedef = jax.tree.flatten(spec,
                                       is_leaf=lambda x: isinstance(x, P))
    keys = jax.random.split(key, len(leaves))

    def one(k, p: P):
        if p.init == "zeros":
            return jnp.zeros(p.shape, dtype)
        if p.init == "ones":
            return jnp.ones(p.shape, dtype)
        fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
        scale = p.scale if p.scale is not None else fan_in ** -0.5
        return (scale * jax.random.normal(k, p.shape)).astype(dtype)

    return jax.tree.unflatten(treedef, [one(k, p)
                                        for k, p in zip(keys, leaves)])


def abstract_params(spec: SpecTree,
                    dtype: jnp.dtype = jnp.float32) -> dict:
    """ShapeDtypeStruct tree (for ``.lower()`` without allocation)."""
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, dtype), spec,
        is_leaf=lambda x: isinstance(x, P))


def param_shardings(spec: SpecTree, mesh: Mesh,
                    rules: dict | None = None) -> dict:
    """NamedSharding tree from the declared logical axes."""
    return jax.tree.map(
        lambda p: shlib.logical_sharding(p.shape, p.axes, mesh, rules),
        spec, is_leaf=lambda x: isinstance(x, P))


def abstract_like(tree, mesh: Mesh | None = None, spec: SpecTree | None = None):
    """ShapeDtypeStruct tree with shardings attached (dry-run inputs)."""
    del mesh, spec
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def count_params(tree) -> int:
    sizes = [int(jnp.size(x)) if hasattr(x, "size") else 0
             for x in jax.tree.leaves(tree)]
    return sum(sizes)


def spec_param_count(spec: SpecTree) -> int:
    import math
    leaves = jax.tree.leaves(spec, is_leaf=lambda x: isinstance(x, P))
    return sum(math.prod(p.shape) for p in leaves)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: Array, weight: Array | None, eps: float = 1e-6) -> Array:
    """fp32 statistics, but no full fp32 activation copy: the upcast is
    consumed only by the variance reduction (fuses away), so no f32
    activation tensor exists to be gathered/reduced across shards
    (§Perf hillclimb C7)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                   keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    out = x * inv
    if weight is not None:
        out = out * weight.astype(x.dtype)
    return out


def layer_norm(x: Array, weight: Array | None = None,
               bias: Array | None = None, eps: float = 1e-5) -> Array:
    """Non-parametric when weight/bias are None (OLMo's LN).

    Same dtype discipline as :func:`rms_norm`: fp32 statistics only."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    out = (x - mu.astype(x.dtype)) * inv
    if weight is not None:
        out = out * weight.astype(x.dtype)
    if bias is not None:
        out = out + bias.astype(x.dtype)
    return out


def apply_norm(x: Array, params: dict | None, kind: str) -> Array:
    if kind == "rmsnorm":
        return rms_norm(x, params["scale"] if params else None)
    if kind == "layernorm":
        return layer_norm(x, params["scale"] if params else None,
                          params.get("bias") if params else None)
    if kind == "nonparametric_ln":
        return layer_norm(x, None, None)
    raise ValueError(kind)


def norm_spec(d: int, kind: str) -> dict:
    if kind == "rmsnorm":
        return {"scale": P((d,), ("norm",), "ones")}
    if kind == "layernorm":
        return {"scale": P((d,), ("norm",), "ones"),
                "bias": P((d,), ("norm",), "zeros")}
    if kind == "nonparametric_ln":
        return {}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x: (..., S, H, head_dim); positions: (..., S)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                    # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_spec(vocab: int, d: int) -> dict:
    return {"embedding": P((vocab, d), ("vocab", "embed"), "normal", 0.02)}


def embed(params: dict, tokens: Array, compute_dtype) -> Array:
    emb = params["embedding"].astype(compute_dtype)
    out = jnp.take(emb, tokens, axis=0)
    return shlib.shard(out, "act_batch", "act_seq", "act_embed")


def unembed_spec(vocab: int, d: int) -> dict:
    return {"kernel": P((d, vocab), ("embed", "vocab"))}


def unembed(params: dict, x: Array, compute_dtype) -> Array:
    logits = x.astype(compute_dtype) @ params["kernel"].astype(compute_dtype)
    return shlib.shard(logits, "act_batch", "act_seq", "act_vocab")
