"""LM model facade: every assigned architecture behind one API.

``build(cfg)`` -> :class:`Model` with

* ``spec()`` / ``init(key)`` / ``param_shardings(mesh)``
* ``forward(params, batch)``            — logits for train/prefill
* ``loss(params, batch)``               — next-token (or masked-encoder) loss
* ``decode_state_spec(batch, max_seq)`` — KV caches / SSM states
* ``decode_step(params, state, batch)`` — one-token serve step

Layer stacking: homogeneous families (dense/moe/encoder/vlm) use
``jax.lax.scan`` over stacked layer params (compact HLO for 95-layer
stacks) with per-layer remat. Heterogeneous families (zamba2 hybrid,
xlstm) use python loops over per-layer param lists — their layer counts
are small.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models import attention, common, mlp, ssm, xlstm
from repro.models.common import P

Array = jax.Array


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


def _attn_cfg(cfg: ModelConfig) -> attention.AttnConfig:
    return attention.AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, kv_heads=cfg.kv_heads,
        head_dim=cfg.resolved_head_dim, causal=cfg.causal and not cfg.is_encoder,
        rope_theta=cfg.rope_theta, qk_norm=cfg.qk_norm, norm=cfg.norm)


def _mlp_cfg(cfg: ModelConfig) -> mlp.MLPConfig:
    return mlp.MLPConfig(d_model=cfg.d_model, d_ff=cfg.d_ff,
                         activation=cfg.activation,
                         gated=cfg.activation == "silu")


def _moe_cfg(cfg: ModelConfig) -> mlp.MoEConfig:
    return mlp.MoEConfig(d_model=cfg.d_model, d_ff=cfg.d_ff,
                         n_experts=cfg.n_experts, top_k=cfg.top_k,
                         capacity_factor=cfg.capacity_factor,
                         activation=cfg.activation,
                         dispatch_int8=cfg.moe_dispatch_int8)


def _ssm_cfg(cfg: ModelConfig) -> ssm.SSMConfig:
    return ssm.SSMConfig(
        d_model=cfg.d_model, d_inner=cfg.d_inner, n_heads=cfg.ssm_heads,
        head_dim=cfg.ssm_head_dim, d_state=cfg.ssm_state,
        chunk=cfg.ssm_chunk)


def _xlstm_cfg(cfg: ModelConfig) -> xlstm.XLSTMConfig:
    return xlstm.XLSTMConfig(d_model=cfg.d_model, n_heads=cfg.n_heads,
                             chunk=cfg.ssm_chunk)


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Transformer layer (dense / moe / encoder / vlm — all share this block)
# ---------------------------------------------------------------------------

def _tf_layer_spec(cfg: ModelConfig) -> dict:
    s = {
        "attn_norm": common.norm_spec(cfg.d_model, cfg.norm),
        "attn": attention.spec(_attn_cfg(cfg)),
        "mlp_norm": common.norm_spec(cfg.d_model, cfg.norm),
    }
    if cfg.n_experts:
        s["moe"] = mlp.moe_spec(_moe_cfg(cfg))
    else:
        s["mlp"] = mlp.spec(_mlp_cfg(cfg))
    return s


@jax.custom_vjp
def _opt_barrier(x: Array) -> Array:
    """``optimization_barrier`` with an explicit gradient.

    jax 0.4.37 has no differentiation rule for the barrier primitive
    (added upstream later); the barrier is an optimization hint, so the
    cotangent passes straight through.
    """
    return jax.lax.optimization_barrier(x)


def _opt_barrier_fwd(x):
    return jax.lax.optimization_barrier(x), None


def _opt_barrier_bwd(_, g):
    return (g,)


_opt_barrier.defvjp(_opt_barrier_fwd, _opt_barrier_bwd)


def _seq_gather(x: Array) -> Array:
    """Explicit bf16 gather point for the sequence-parallel residual.

    The optimization barrier pins the collective to the low-precision
    tensor: without it XLA hoists the norm's f32 upcast above the
    all-gather, doubling SP collective bytes (§Perf hillclimb C3).
    """
    xg = shard(x, "act_batch", "act_seq", "act_embed")
    return _opt_barrier(xg)


def _to_resid(y: Array) -> Array:
    return shard(y, "act_batch", "act_resid_seq", "act_embed")


def _tf_layer(params: dict, x: Array, cfg: ModelConfig) -> tuple[Array, Array]:
    """Pre-norm transformer block (sequence-parallel residual stream).

    Returns (x, moe_aux)."""
    a = common.apply_norm(_seq_gather(x), params.get("attn_norm"), cfg.norm)
    x = x + _to_resid(attention.full(params["attn"], a, _attn_cfg(cfg)))
    m = common.apply_norm(_seq_gather(x), params.get("mlp_norm"), cfg.norm)
    if cfg.n_experts:
        out, aux = mlp.moe_apply(params["moe"], m, _moe_cfg(cfg))
    else:
        out, aux = mlp.apply(params["mlp"], m, _mlp_cfg(cfg)), 0.0
    return x + _to_resid(out), jnp.asarray(aux, jnp.float32)


def _tf_layer_decode(params: dict, x: Array, cache: attention.KVCache,
                     index: Array, cfg: ModelConfig
                     ) -> tuple[Array, attention.KVCache]:
    a = common.apply_norm(x, params.get("attn_norm"), cfg.norm)
    attn_out, cache = attention.decode_step(params["attn"], a, cache,
                                            index, _attn_cfg(cfg))
    x = x + attn_out
    m = common.apply_norm(x, params.get("mlp_norm"), cfg.norm)
    if cfg.n_experts:
        out, _ = mlp.moe_apply(params["moe"], m, _moe_cfg(cfg))
    else:
        out = mlp.apply(params["mlp"], m, _mlp_cfg(cfg))
    return x + out, cache


# ---------------------------------------------------------------------------
# Hybrid (zamba2) and xLSTM layer tables
# ---------------------------------------------------------------------------

def _hybrid_positions(cfg: ModelConfig) -> list[int]:
    """Mamba-layer indices after which the shared attn block runs."""
    if not cfg.shared_attn_every:
        return []
    return list(range(cfg.shared_attn_every - 1, cfg.n_layers,
                      cfg.shared_attn_every))


def _xlstm_kinds(cfg: ModelConfig) -> list[str]:
    if not cfg.slstm_every:
        return ["mlstm"] * cfg.n_layers
    return ["slstm" if (i + 1) % cfg.slstm_every == 0 else "mlstm"
            for i in range(cfg.n_layers)]


def _xlstm_segments(cfg: ModelConfig) -> list[tuple]:
    """[("m", lo, hi) | ("s", idx)] runs over the stacked param layout:
    consecutive mLSTM layers scan as one group."""
    kinds = _xlstm_kinds(cfg)
    segs: list[tuple] = []
    m_i = s_i = i = 0
    while i < len(kinds):
        if kinds[i] == "mlstm":
            lo = m_i
            while i < len(kinds) and kinds[i] == "mlstm":
                m_i += 1
                i += 1
            segs.append(("m", lo, m_i))
        else:
            segs.append(("s", s_i))
            s_i += 1
            i += 1
    return segs


# ---------------------------------------------------------------------------
# Model facade
# ---------------------------------------------------------------------------

class Batch(NamedTuple):
    """Inputs for train/prefill. ``embeds`` used by embeds-in stubs (audio)
    and VLM image prefixes; ``labels`` = -1 marks masked-out positions."""
    tokens: Array | None      # (b, s) int32 or None for embeds-in archs
    labels: Array             # (b, s) int32, -1 = ignore
    embeds: Array | None = None   # (b, s_img/s, d_model)


class DecodeBatch(NamedTuple):
    tokens: Array             # (b, 1) int32
    index: Array              # ()  current cache length


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.compute_dtype = _dtype(cfg.compute_dtype)

    # ----- specs -----

    def spec(self) -> dict:
        cfg = self.cfg
        s: dict[str, Any] = {}
        if not cfg.embeds_in:
            s["embed"] = common.embed_spec(cfg.vocab, cfg.d_model)
        s["final_norm"] = common.norm_spec(cfg.d_model, cfg.norm)
        s["unembed"] = common.unembed_spec(cfg.vocab, cfg.d_model)

        if cfg.family in ("dense", "moe", "encoder", "vlm"):
            layer = _tf_layer_spec(cfg)
            if cfg.scan_layers:
                s["layers"] = common.map_layers(layer, cfg.n_layers)
            else:
                s["layers"] = [layer for _ in range(cfg.n_layers)]
        elif cfg.family == "hybrid":
            mamba = ssm.spec(_ssm_cfg(cfg))
            s["layers"] = common.map_layers(mamba, cfg.n_layers)
            s["shared_attn"] = {
                "attn_norm": common.norm_spec(cfg.d_model, cfg.norm),
                "attn": attention.spec(_attn_cfg(cfg)),
                "mlp_norm": common.norm_spec(cfg.d_model, cfg.norm),
                "mlp": mlp.spec(_mlp_cfg(cfg)),
                "emb_proj": P((cfg.d_model, cfg.d_model),
                              ("embed", "embed")),
            }
        elif cfg.family == "ssm":  # xlstm
            xc = _xlstm_cfg(cfg)
            kinds = _xlstm_kinds(cfg)
            n_m = kinds.count("mlstm")
            n_s = kinds.count("slstm")
            s["layers"] = {
                "mlstm": common.map_layers(xlstm.mlstm_spec(xc), n_m)}
            if n_s:
                s["layers"]["slstm"] = common.map_layers(
                    xlstm.slstm_spec(xc), n_s)
        else:
            raise ValueError(cfg.family)
        return s

    def init(self, key: Array) -> dict:
        return common.init_params(key, self.spec(),
                                  _dtype(self.cfg.param_dtype))

    def abstract_params(self) -> dict:
        return common.abstract_params(self.spec(),
                                      _dtype(self.cfg.param_dtype))

    def param_shardings(self, mesh, rules=None) -> dict:
        return common.param_shardings(self.spec(), mesh, rules)

    # ----- forward (train / prefill) -----

    def _inputs_to_h(self, params: dict, batch: Batch) -> Array:
        cfg = self.cfg
        dt = self.compute_dtype
        if cfg.embeds_in:
            h = batch.embeds.astype(dt)
        else:
            h = common.embed(params["embed"], batch.tokens, dt)
            if cfg.family == "vlm" and batch.embeds is not None:
                img = shard(batch.embeds.astype(dt),
                            "act_batch", "act_seq", "act_embed")
                h = jnp.concatenate([img, h], axis=1)
        return h

    def forward(self, params: dict, batch: Batch) -> tuple[Array, Array]:
        """Returns (logits, moe_aux_loss)."""
        h, aux = self._trunk(params, batch)
        logits = common.unembed(params["unembed"], h, self.compute_dtype)
        if self.cfg.family == "vlm" and batch.embeds is not None \
                and not self.cfg.embeds_in:
            logits = logits[:, batch.embeds.shape[1]:, :]   # text positions
        return logits, aux

    def _trunk(self, params: dict, batch: Batch) -> tuple[Array, Array]:
        """Embed + layer stack + final norm -> (hidden, moe_aux)."""
        cfg = self.cfg
        h = self._inputs_to_h(params, batch)

        # sequence-parallel residual stream: the per-layer remat checkpoint
        # (= the scan carry / layer input) is sharded along seq over "model"
        def resid(x):
            return shard(x, "act_batch", "act_resid_seq", "act_embed")

        h = resid(h)
        if cfg.family in ("dense", "moe", "encoder", "vlm"):
            layer_fn = _remat(
                lambda p, x: _tf_layer(p, x, cfg), cfg)
            if cfg.scan_layers:
                def body(carry, layer_params):
                    x, aux = carry
                    x, a = layer_fn(layer_params, x)
                    return (resid(x), aux + a), None
                (h, aux), _ = jax.lax.scan(body, (h, jnp.float32(0.0)),
                                           params["layers"])
            else:
                aux = jnp.float32(0.0)
                for lp in params["layers"]:
                    h, a = layer_fn(lp, h)
                    h = resid(h)
                    aux = aux + a
        elif cfg.family == "hybrid":
            aux = jnp.float32(0.0)
            h = self._hybrid_forward(params, h)
        elif cfg.family == "ssm":
            aux = jnp.float32(0.0)
            xc = _xlstm_cfg(cfg)
            m_fn = _remat(lambda p, x: xlstm.mlstm_block(p, x, xc), cfg)
            s_fn = _remat(lambda p, x: xlstm.slstm_block(p, x, xc)[0], cfg)
            for seg in _xlstm_segments(cfg):
                if seg[0] == "m":     # consecutive mLSTM layers: one scan
                    _, lo, hi = seg
                    xs = jax.tree.map(lambda a: a[lo:hi],
                                      params["layers"]["mlstm"])

                    def body(x, lp):
                        return resid(m_fn(lp, x)), None

                    h, _ = jax.lax.scan(body, h, xs)
                else:
                    lp = jax.tree.map(lambda a: a[seg[1]],
                                      params["layers"]["slstm"])
                    h = resid(s_fn(lp, h))
        else:
            raise ValueError(cfg.family)

        h = common.apply_norm(h, params.get("final_norm"), cfg.norm)
        return h, aux

    def _hybrid_forward(self, params: dict, h: Array) -> Array:
        """Mamba backbone scanned in groups between shared-block stops.

        Grouped ``lax.scan`` keeps the HLO ~shared_attn_every-x smaller
        than a flat python loop (38 unrolled Mamba layers made GSPMD
        compile time explode)."""
        cfg = self.cfg
        scfg = _ssm_cfg(cfg)
        h0 = h  # original embeddings feed the shared block (zamba-style)
        mamba_fn = _remat(lambda p, x: x + ssm.apply(p, x, scfg), cfg)

        def resid(x):
            return shard(x, "act_batch", "act_resid_seq", "act_embed")

        def scan_group(h, lo, hi):
            xs = jax.tree.map(lambda a: a[lo:hi], params["layers"])

            def body(x, lp):
                return resid(mamba_fn(lp, x)), None

            h, _ = jax.lax.scan(body, h, xs)
            return h

        def shared_fn(p, x):
            inj = x + (h0 @ p["emb_proj"].astype(x.dtype))
            a = common.apply_norm(inj, p["attn_norm"], cfg.norm)
            x = x + attention.full(p["attn"], a, _attn_cfg(cfg))
            m = common.apply_norm(x, p["mlp_norm"], cfg.norm)
            return x + mlp.apply(p["mlp"], m, _mlp_cfg(cfg))

        shared_fn = _remat(shared_fn, cfg)
        k = cfg.shared_attn_every or cfg.n_layers
        lo = 0
        while lo < cfg.n_layers:
            hi = min(lo + k, cfg.n_layers)
            h = scan_group(h, lo, hi)
            if hi - lo == k and cfg.shared_attn_every:
                h = resid(shared_fn(params["shared_attn"], h))
            lo = hi
        return h

    # ----- loss / train -----

    #: seq-chunked cross entropy kicks in above this (seq x vocab) size
    _LOSS_CHUNK = 1024

    def loss(self, params: dict, batch: Batch) -> Array:
        """Next-token / masked NLL with *chunked* cross entropy: fp32
        logits never materialize for the full sequence — each seq chunk's
        logits are (re)computed inside a checkpointed block (forward and
        backward), capping the live loss buffer at (b, chunk, vocab)."""
        cfg = self.cfg
        h, aux = self._trunk(params, batch)
        if cfg.family == "vlm" and batch.embeds is not None \
                and not cfg.embeds_in:
            h = h[:, batch.embeds.shape[1]:, :]
        labels = batch.labels
        s = h.shape[1]
        ch = self._LOSS_CHUNK

        def chunk_nll(hc, lc):
            logits = common.unembed(params["unembed"], hc,
                                    self.compute_dtype)
            logits = logits.astype(jnp.float32)
            mask = (lc >= 0).astype(jnp.float32)
            safe = jnp.maximum(lc, 0)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, safe[..., None], -1)[..., 0]
            return ((logz - gold) * mask).sum(), mask.sum()

        if s <= ch or s % ch != 0 or cfg.vocab < 8192:
            nll, cnt = chunk_nll(h, labels)
        else:
            chunk_nll = jax.checkpoint(chunk_nll)
            nll = jnp.float32(0.0)
            cnt = jnp.float32(0.0)
            for i in range(s // ch):
                sl = slice(i * ch, (i + 1) * ch)
                n, c = chunk_nll(h[:, sl], labels[:, sl])
                nll, cnt = nll + n, cnt + c
        return nll / jnp.maximum(cnt, 1.0) + aux

    # ----- decode -----

    def decode_state_spec(self, batch: int, max_seq: int) -> Any:
        cfg = self.cfg
        acfg = _attn_cfg(cfg)
        if cfg.is_encoder:
            raise ValueError("encoder-only arch has no decode step")
        if cfg.family in ("dense", "moe", "vlm"):
            one = attention.cache_spec(acfg, batch, max_seq)
            return attention.KVCache(
                jax.ShapeDtypeStruct((cfg.n_layers, *one.k.shape),
                                     one.k.dtype),
                jax.ShapeDtypeStruct((cfg.n_layers, *one.v.shape),
                                     one.v.dtype))
        if cfg.family == "hybrid":
            sspec = ssm.state_spec(_ssm_cfg(cfg), batch)
            n_inv = len(_hybrid_positions(cfg))
            one = attention.cache_spec(acfg, batch, max_seq)
            return {
                "mamba": ssm.SSMState(
                    jax.ShapeDtypeStruct((cfg.n_layers, *sspec.ssm.shape),
                                         sspec.ssm.dtype),
                    jax.ShapeDtypeStruct((cfg.n_layers, *sspec.conv.shape),
                                         sspec.conv.dtype)),
                "attn": attention.KVCache(
                    jax.ShapeDtypeStruct((n_inv, *one.k.shape), one.k.dtype),
                    jax.ShapeDtypeStruct((n_inv, *one.v.shape), one.v.dtype)),
            }
        if cfg.family == "ssm":
            xc = _xlstm_cfg(cfg)
            return [xlstm.slstm_state_spec(xc, batch)
                    if kind == "slstm" else xlstm.mlstm_state_spec(xc, batch)
                    for kind in _xlstm_kinds(cfg)]
        raise ValueError(cfg.family)

    def init_decode_state(self, batch: int, max_seq: int) -> Any:
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.decode_state_spec(batch, max_seq))

    def decode_step(self, params: dict, state: Any, batch: DecodeBatch
                    ) -> tuple[Array, Any]:
        """One token for the whole stack -> (logits (b, 1, vocab), state)."""
        cfg = self.cfg
        dt = self.compute_dtype
        h = common.embed(params["embed"], batch.tokens, dt)
        index = batch.index

        if cfg.family in ("dense", "moe", "vlm"):
            def body(x, inp):
                lp, k_l, v_l = inp
                x, cache = _tf_layer_decode(
                    lp, x, attention.KVCache(k_l, v_l), index, cfg)
                return x, (cache.k, cache.v)

            if cfg.scan_layers:
                h, (ks, vs) = jax.lax.scan(
                    body, h, (params["layers"], state.k, state.v))
                state = attention.KVCache(ks, vs)
            else:
                ks, vs = [], []
                for i, lp in enumerate(params["layers"]):
                    h, (k_l, v_l) = body(h, (lp, state.k[i], state.v[i]))
                    ks.append(k_l)
                    vs.append(v_l)
                state = attention.KVCache(jnp.stack(ks), jnp.stack(vs))
        elif cfg.family == "hybrid":
            h, state = self._hybrid_decode(params, h, state, index)
        elif cfg.family == "ssm":
            xc = _xlstm_cfg(cfg)
            new_states = []
            m_i = s_i = 0
            for kind, st in zip(_xlstm_kinds(cfg), state):
                if kind == "slstm":
                    lp = jax.tree.map(lambda a: a[s_i],
                                      params["layers"]["slstm"])
                    h, st = xlstm.slstm_block_step(lp, h, st, xc)
                    s_i += 1
                else:
                    lp = jax.tree.map(lambda a: a[m_i],
                                      params["layers"]["mlstm"])
                    h, st = xlstm.mlstm_block_step(lp, h, st, xc)
                    m_i += 1
                new_states.append(st)
            state = new_states
        else:
            raise ValueError(cfg.family)

        h = common.apply_norm(h, params.get("final_norm"), cfg.norm)
        logits = common.unembed(params["unembed"], h, dt)
        return logits, state

    def _hybrid_decode(self, params, h, state, index):
        cfg = self.cfg
        scfg = _ssm_cfg(cfg)
        shared_at = _hybrid_positions(cfg)
        h0 = h
        new_ssm, new_conv = [], []
        attn_k, attn_v = [], []
        inv = 0
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            st = ssm.SSMState(state["mamba"].ssm[i], state["mamba"].conv[i])
            out, st = ssm.decode_step(lp, h, st, scfg)
            h = h + out
            new_ssm.append(st.ssm)
            new_conv.append(st.conv)
            if i in shared_at:
                p = params["shared_attn"]
                inj = h + (h0 @ p["emb_proj"].astype(h.dtype))
                a = common.apply_norm(inj, p["attn_norm"], cfg.norm)
                cache = attention.KVCache(state["attn"].k[inv],
                                          state["attn"].v[inv])
                attn_out, cache = attention.decode_step(
                    p["attn"], a, cache, index, _attn_cfg(cfg))
                h = h + attn_out
                m = common.apply_norm(h, p["mlp_norm"], cfg.norm)
                h = h + mlp.apply(p["mlp"], m, _mlp_cfg(cfg))
                attn_k.append(cache.k)
                attn_v.append(cache.v)
                inv += 1
        state = {
            "mamba": ssm.SSMState(jnp.stack(new_ssm), jnp.stack(new_conv)),
            "attn": attention.KVCache(jnp.stack(attn_k), jnp.stack(attn_v)),
        }
        return h, state


def build(cfg: ModelConfig) -> Model:
    return Model(cfg)
