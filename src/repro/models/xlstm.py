"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory, chunkwise-parallel)
and sLSTM (scalar memory, strictly recurrent), with exponential gating and
max-stabilizers.

mLSTM is computed in a chunkwise-parallel form (quadratic within chunks,
recurrent matrix-state across chunks — the TFLA-style schedule) so
prefill_32k lowers without an S^2 working set; decode is a single
recurrent step. sLSTM is a ``lax.scan`` over time (inherently sequential,
as in the paper) with block-diagonal per-head recurrence.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import common
from repro.models.common import P

Array = jax.Array


class XLSTMConfig(NamedTuple):
    d_model: int
    n_heads: int
    proj_factor: float = 2.0     # mLSTM inner expansion
    d_conv: int = 4
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return int(self.d_model * self.proj_factor)

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads

    @property
    def s_head_dim(self) -> int:
        return self.d_model // self.n_heads


# ---------------------------------------------------------------------------
# mLSTM cell — chunkwise parallel + single step
# ---------------------------------------------------------------------------

def mlstm_parallel(q, k, v, igate, fgate, chunk: int):
    """Full-sequence mLSTM: (b, s, h, dh) inputs, (b, s, h, dh) out.

    Chunkwise-parallel schedule (TFLA-style): all heavy einsums are
    *outside* the sequential carry — phase A computes per-chunk state
    contributions (vectorized over chunks), phase B scans only the cheap
    (C, n, m) carry recurrence, phase C combines intra-chunk quadratic
    attention with the carried inter-chunk states (vectorized again).
    Besides being the TPU-efficient shape (the scan body is O(dh^2)
    elementwise), this keeps HLO cost analysis honest: only negligible
    FLOPs live inside the while loop. igate/fgate are pre-activations
    (b, s, h).
    """
    b, s, h, dh = q.shape
    assert s % chunk == 0, (s, chunk)
    c = s // chunk
    k = k / jnp.sqrt(dh)
    flog = jax.nn.log_sigmoid(fgate.astype(jnp.float32))

    def to_chunks(t):   # (b, s, h, ...) -> (b, h, c, q, ...)
        t = t.reshape(b, c, chunk, h, *t.shape[3:])
        return jnp.moveaxis(t, 3, 1)

    qc = to_chunks(q.astype(jnp.float32))                    # (b,h,c,q,dh)
    kc = to_chunks(k.astype(jnp.float32))
    vc = to_chunks(v.astype(jnp.float32))
    ic = to_chunks(igate.astype(jnp.float32)[..., None])[..., 0]  # (b,h,c,q)
    fc = to_chunks(flog[..., None])[..., 0]

    # --- phase A: per-chunk aggregates (vectorized over c) ---
    F = jnp.cumsum(fc, axis=-1)                              # (b, h, c, q)
    F_tot = F[..., -1]                                       # (b, h, c)
    w_state = ic + (F_tot[..., None] - F)                    # (b, h, c, q)
    m_state = jnp.max(w_state, axis=-1)                      # (b, h, c)
    ws = jnp.exp(w_state - m_state[..., None])
    S_c = jnp.einsum("bhcj,bhcjd,bhcjv->bhcdv", ws, kc, vc)  # (b,h,c,dh,dh)
    n_c = jnp.einsum("bhcj,bhcjd->bhcd", ws, kc)             # (b,h,c,dh)

    # --- phase B: cheap carry scan over chunks ---
    init = (jnp.zeros((b, h, dh, dh), jnp.float32),
            jnp.zeros((b, h, dh), jnp.float32),
            jnp.zeros((b, h), jnp.float32))

    def scan_fn(carry, inp):
        C_p, n_p, m_p = carry
        S_i, nvec_i, m_st, f_tot = inp
        m_new = jnp.maximum(m_p + f_tot, m_st)
        dec = jnp.exp(m_p + f_tot - m_new)
        w_i = jnp.exp(m_st - m_new)
        C_new = dec[..., None, None] * C_p + w_i[..., None, None] * S_i
        n_new = dec[..., None] * n_p + w_i[..., None] * nvec_i
        return (C_new, n_new, m_new), (C_p, n_p, m_p)

    xs = (jnp.moveaxis(S_c, 2, 0), jnp.moveaxis(n_c, 2, 0),
          jnp.moveaxis(m_state, 2, 0), jnp.moveaxis(F_tot, 2, 0))
    final, (C_prev, n_prev, m_prev) = jax.lax.scan(scan_fn, init, xs)
    C_prev = jnp.moveaxis(C_prev, 0, 2)                      # (b,h,c,dh,dh)
    n_prev = jnp.moveaxis(n_prev, 0, 2)                      # (b,h,c,dh)
    m_prev = jnp.moveaxis(m_prev, 0, 2)                      # (b,h,c)

    # --- phase C: combine (vectorized over c) ---
    D = F[..., :, None] - F[..., None, :] + ic[..., None, :]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    D = jnp.where(tri, D, -jnp.inf)
    m_local = jnp.max(D, axis=-1)                            # (b, h, c, q)
    m_inter = F + m_prev[..., None]
    m_eff = jnp.maximum(m_local, m_inter)

    s_intra = jnp.exp(D - m_eff[..., None])                  # (b, h, c, q, q)
    qk = jnp.einsum("bhctd,bhcjd->bhctj", qc, kc)
    num = jnp.einsum("bhctj,bhctj,bhcjv->bhctv", qk, s_intra, vc)
    den = jnp.einsum("bhctj,bhctj->bhct", qk, s_intra)
    w_inter = jnp.exp(m_inter - m_eff)                       # (b, h, c, q)
    num = num + w_inter[..., None] * jnp.einsum("bhctd,bhcdv->bhctv",
                                                qc, C_prev)
    den = den + w_inter * jnp.einsum("bhctd,bhcd->bhct", qc, n_prev)
    n_t = jnp.maximum(jnp.abs(den), jnp.exp(-m_eff))
    h_t = num / n_t[..., None]                               # (b, h, c, q, dv)

    hs = jnp.moveaxis(h_t.reshape(b, h, s, dh), 1, 2)        # (b, s, h, dh)
    return hs.astype(q.dtype), final


def mlstm_step(q, k, v, igate, fgate, carry):
    """One-token recurrence. q,k,v: (b, h, dh); gates: (b, h)."""
    C_prev, n_prev, m_prev = carry
    dh = q.shape[-1]
    k = k.astype(jnp.float32) / jnp.sqrt(dh)
    q = q.astype(jnp.float32)
    v = v.astype(jnp.float32)
    flog = jax.nn.log_sigmoid(fgate.astype(jnp.float32))
    m_new = jnp.maximum(flog + m_prev, igate)
    fw = jnp.exp(flog + m_prev - m_new)
    iw = jnp.exp(igate - m_new)
    C_new = fw[..., None, None] * C_prev + \
        iw[..., None, None] * jnp.einsum("bhd,bhv->bhdv", k, v)
    n_new = fw[..., None] * n_prev + iw[..., None] * k
    num = jnp.einsum("bhd,bhdv->bhv", q, C_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n_new)),
                      jnp.exp(-m_new))
    return num / den[..., None], (C_new, n_new, m_new)


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM v1 pre-up-projection block)
# ---------------------------------------------------------------------------

def mlstm_spec(cfg: XLSTMConfig) -> dict:
    d, di, h = cfg.d_model, cfg.d_inner, cfg.n_heads
    return {
        "norm": common.norm_spec(d, "layernorm"),
        "w_up": P((d, 2 * di), ("embed", "ssm_inner")),
        "conv_w": P((cfg.d_conv, di), ("conv_k", "conv_dim")),
        "conv_b": P((di,), ("conv_dim",), "zeros"),
        "wq": P((di, di), ("ssm_inner", "qkv_dim")),
        "wk": P((di, di), ("ssm_inner", "qkv_dim")),
        "wv": P((di, di), ("ssm_inner", "qkv_dim")),
        "w_i": P((di, h), ("ssm_inner", "ssm_heads"), "normal", 0.01),
        "b_i": P((h,), ("ssm_heads",), "zeros"),
        "w_f": P((di, h), ("ssm_inner", "ssm_heads"), "normal", 0.01),
        "b_f": P((h,), ("ssm_heads",), "ones"),
        "out_norm": {"scale": P((di,), ("norm",), "ones")},
        "w_down": P((di, d), ("ssm_inner", "embed")),
    }


class MLSTMState(NamedTuple):
    C: Array      # (b, h, dh, dh) fp32
    n: Array      # (b, h, dh) fp32
    m: Array      # (b, h) fp32
    conv: Array   # (b, d_conv - 1, d_inner)


def mlstm_state_spec(cfg: XLSTMConfig, batch: int,
                     conv_dtype=jnp.bfloat16) -> MLSTMState:
    dh, h, di = cfg.head_dim, cfg.n_heads, cfg.d_inner
    return MLSTMState(
        jax.ShapeDtypeStruct((batch, h, dh, dh), jnp.float32),
        jax.ShapeDtypeStruct((batch, h, dh), jnp.float32),
        jax.ShapeDtypeStruct((batch, h), jnp.float32),
        jax.ShapeDtypeStruct((batch, cfg.d_conv - 1, di), conv_dtype))


def mlstm_state_axes() -> MLSTMState:
    return MLSTMState(("act_batch", "act_ssm_heads", None, None),
                      ("act_batch", "act_ssm_heads", None),
                      ("act_batch", "act_ssm_heads"),
                      ("act_batch", None, None))


def init_mlstm_state(cfg: XLSTMConfig, batch: int,
                     conv_dtype=jnp.bfloat16) -> MLSTMState:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        mlstm_state_spec(cfg, batch, conv_dtype))


def _causal_conv(xs: Array, w: Array, b: Array) -> Array:
    kk = w.shape[0]
    pad = jnp.pad(xs, ((0, 0), (kk - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xs.shape[1], :] * w[i][None, None, :]
              for i in range(kk))
    return jax.nn.silu(out + b)


def _mlstm_qkv_gates(params, x_norm, cfg, conv_fn):
    dt = x_norm.dtype
    up = x_norm @ params["w_up"].astype(dt)
    x_m, z = jnp.split(up, 2, axis=-1)
    x_c = conv_fn(x_m)
    q = x_c @ params["wq"].astype(dt)
    k = x_c @ params["wk"].astype(dt)
    v = x_m @ params["wv"].astype(dt)
    ig = (x_c @ params["w_i"].astype(dt)
          + params["b_i"].astype(dt)).astype(jnp.float32)
    fg = (x_c @ params["w_f"].astype(dt)
          + params["b_f"].astype(dt)).astype(jnp.float32)
    return q, k, v, ig, fg, z, x_m


def mlstm_block(params: dict, x: Array, cfg: XLSTMConfig) -> Array:
    """Full-sequence mLSTM block (residual inside). (b, s, d) -> same."""
    b, s, d = x.shape
    dt = x.dtype
    h, dh = cfg.n_heads, cfg.head_dim
    x_norm = common.apply_norm(x, params["norm"], "layernorm")

    def conv_fn(x_m):
        return _causal_conv(x_m, params["conv_w"].astype(dt),
                            params["conv_b"].astype(dt))

    q, k, v, ig, fg, z, _ = _mlstm_qkv_gates(params, x_norm, cfg, conv_fn)
    q = shard(q.reshape(b, s, h, dh), "act_batch", "act_seq",
              "act_ssm_heads", None)
    k = k.reshape(b, s, h, dh)
    v = v.reshape(b, s, h, dh)
    ht, _ = mlstm_parallel(q, k, v, ig, fg, min(cfg.chunk, s))
    ht = ht.reshape(b, s, cfg.d_inner)
    ht = common.rms_norm(ht, params["out_norm"]["scale"])
    out = (ht * jax.nn.silu(z)) @ params["w_down"].astype(dt)
    return x + shard(out, "act_batch", "act_seq", "act_embed")


def mlstm_block_step(params: dict, x: Array, state: MLSTMState,
                     cfg: XLSTMConfig) -> tuple[Array, MLSTMState]:
    """One-token mLSTM block. x: (b, 1, d)."""
    b = x.shape[0]
    dt = x.dtype
    h, dh = cfg.n_heads, cfg.head_dim
    x_norm = common.apply_norm(x[:, 0, :], params["norm"], "layernorm")

    new_conv_holder = {}

    def conv_fn(x_m):   # x_m: (b, d_inner) single step
        buf = jnp.concatenate(
            [state.conv, x_m[:, None, :].astype(state.conv.dtype)], axis=1)
        w = params["conv_w"].astype(dt)
        out = jnp.einsum("bkc,kc->bc", buf.astype(dt), w)
        new_conv_holder["conv"] = buf[:, 1:, :]
        return jax.nn.silu(out + params["conv_b"].astype(dt))

    q, k, v, ig, fg, z, _ = _mlstm_qkv_gates(params, x_norm, cfg, conv_fn)
    q = q.reshape(b, h, dh)
    k = k.reshape(b, h, dh)
    v = v.reshape(b, h, dh)
    ht, (C, n, m) = mlstm_step(q, k, v, ig, fg, (state.C, state.n, state.m))
    ht = ht.reshape(b, cfg.d_inner).astype(dt)
    ht = common.rms_norm(ht, params["out_norm"]["scale"])
    out = ((ht * jax.nn.silu(z)) @ params["w_down"].astype(dt))[:, None, :]
    return x + out, MLSTMState(C, n, m, new_conv_holder["conv"])


# ---------------------------------------------------------------------------
# sLSTM block — strictly recurrent scalar memory
# ---------------------------------------------------------------------------

def slstm_spec(cfg: XLSTMConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    dh = cfg.s_head_dim
    return {
        "norm": common.norm_spec(d, "layernorm"),
        "w": P((d, 4 * d), ("embed", "ssm_inner")),
        "r": P((4, h, dh, dh), (None, "ssm_heads", None, None),
               "normal", 0.02),
        "b": P((4 * d,), ("ssm_inner",), "zeros"),
        "out_norm": {"scale": P((d,), ("norm",), "ones")},
        "w_down": P((d, d), ("embed", "embed")),
    }


class SLSTMState(NamedTuple):
    c: Array     # (b, h, dh) fp32
    n: Array
    hid: Array
    m: Array     # (b, h, dh)


def slstm_state_spec(cfg: XLSTMConfig, batch: int) -> SLSTMState:
    h, dh = cfg.n_heads, cfg.s_head_dim
    s = jax.ShapeDtypeStruct((batch, h, dh), jnp.float32)
    return SLSTMState(s, s, s, s)


def slstm_state_axes() -> SLSTMState:
    ax = ("act_batch", "act_ssm_heads", None)
    return SLSTMState(ax, ax, ax, ax)


def init_slstm_state(cfg: XLSTMConfig, batch: int) -> SLSTMState:
    h, dh = cfg.n_heads, cfg.s_head_dim
    z = jnp.zeros((batch, h, dh), jnp.float32)
    return SLSTMState(z, z, z, z)


def _slstm_cell(wx: Array, r: Array, state: SLSTMState
                ) -> tuple[Array, SLSTMState]:
    """wx: (b, 4, h, dh) pre-activations from the input path."""
    rec = jnp.einsum("ghde,bhe->bghd", r.astype(jnp.float32), state.hid)
    zt, it, ft, ot = [wx.astype(jnp.float32)[:, j] + rec[:, j]
                      for j in range(4)]
    m_new = jnp.maximum(ft + state.m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(ft + state.m - m_new)
    c_new = f_p * state.c + i_p * jnp.tanh(zt)
    n_new = f_p * state.n + i_p
    hid = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1e-6)
    return hid, SLSTMState(c_new, n_new, hid, m_new)


def slstm_block(params: dict, x: Array, cfg: XLSTMConfig,
                state: SLSTMState | None = None
                ) -> tuple[Array, SLSTMState]:
    """Sequence sLSTM block via lax.scan. (b, s, d) -> same."""
    b, s, d = x.shape
    dt = x.dtype
    h, dh = cfg.n_heads, cfg.s_head_dim
    x_norm = common.apply_norm(x, params["norm"], "layernorm")
    wx = (x_norm @ params["w"].astype(dt)
          + params["b"].astype(dt))                       # (b, s, 4d)
    wx = wx.reshape(b, s, 4, h, dh)
    state = state if state is not None else init_slstm_state(cfg, b)

    def step(st, wx_t):
        hid, st = _slstm_cell(wx_t, params["r"], st)
        return st, hid

    state, hids = jax.lax.scan(step, state, jnp.moveaxis(wx, 1, 0))
    hids = jnp.moveaxis(hids, 0, 1).reshape(b, s, d).astype(dt)
    hids = common.rms_norm(hids, params["out_norm"]["scale"])
    out = hids @ params["w_down"].astype(dt)
    return x + shard(out, "act_batch", "act_seq", "act_embed"), state


def slstm_block_step(params: dict, x: Array, state: SLSTMState,
                     cfg: XLSTMConfig) -> tuple[Array, SLSTMState]:
    """One-token sLSTM block. x: (b, 1, d)."""
    out, state = slstm_block(params, x, cfg, state)
    return out, state
