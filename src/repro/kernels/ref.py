"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernel tests
``assert_allclose`` against (shape/dtype sweeps in
``tests/test_kernels.py``). They are *intentionally* the slow/clear
formulation — no reuse tricks — so a kernel bug cannot hide in a shared
shortcut.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import encoding
from repro.core.encoding import NonLin

Array = jnp.ndarray


def hdc_encode(x: Array, B: Array, b: Array,
               nonlinearity: NonLin = "rff") -> Array:
    """(N, n) @ (n, D) + fused nonlinearity -> (N, D). No normalization."""
    proj = x.astype(jnp.float32) @ B.astype(jnp.float32)
    return encoding.apply_nonlinearity(proj, b.astype(jnp.float32),
                                       nonlinearity)


def similarity(queries: Array, class_hvs: Array, eps: float = 1e-9) -> Array:
    """Cosine class scores: (N, D), (C, D) -> (N, C)."""
    q = queries.astype(jnp.float32)
    c = class_hvs.astype(jnp.float32)
    qn = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), eps)
    cn = c / jnp.maximum(jnp.linalg.norm(c, axis=-1, keepdims=True), eps)
    return qn @ cn.T


def fragment_scores(frame: Array, class_hvs: Array, B0: Array, b: Array, *,
                    h: int, w: int, stride: int,
                    nonlinearity: NonLin = "rff") -> Array:
    """Frame -> (my, mx) fragment detection-score map.

    Oracle = naive sliding encode (materialize every fragment, encode
    against the materialized permutation base) + cosine classifier;
    score = sim(positive) - sim(negative).
    """
    hv = encoding.encode_frame_naive(
        frame.astype(jnp.float32), B0.astype(jnp.float32),
        b.astype(jnp.float32), h=h, w=w, stride=stride,
        nonlinearity=nonlinearity, normalize=True)          # (my, mx, D)
    my, mx, dim = hv.shape
    s = similarity(hv.reshape(my * mx, dim), class_hvs)
    s = s[:, 1] - s[:, 0]
    return s.reshape(my, mx)
