"""Pallas TPU kernel: fused computation-reuse HyperSense frame scoring.

This is the paper's FPGA accelerator (§IV) adapted to TPU (DESIGN.md §3).
One kernel maps a sensor frame directly to the fragment score map:

  frame (H, W)  ->  scores-ingredients (my, mx) x 3

fusing, per grid cell:

  1. *rolled products + prefix sum* — each input element is multiplied with
     base-hypervector material exactly once per base row (the paper's
     computation reuse; the systolic FIFO becomes a running sum),
  2. *window differences* — every fragment's projection is
     ``P[kx+w] - P[kx]`` (the reuse of overlapping fragments),
  3. *normalization + RFF nonlinearity* — in the *unrolled* orientation:
     instead of cyclically rotating every (mx, D) projection back (the
     naive inverse of the permutation trick), the per-column *bias* and
     *class hypervectors* are pre-rotated once per model. A (D,)-vector
     rotation per fragment column, amortized over every frame forever,
     replaces an (mx, D) data rotation per frame — a beyond-paper
     optimization available because similarity is permutation-invariant.
  4. *classifier dot products* — positive/negative class dots and the query
     sum-of-squares accumulate across D tiles; the cosine epilogue runs
     host-side on the tiny (my, mx) outputs.

Grid: ``(N, my, n_dt)`` — frames and fragment rows parallel, hyperdimension
tiles as the sequential reduction. The batch axis is the streaming hot path:
one ``pallas_call`` scores a whole chunk of frames against a single
:class:`ScoreTiles` precompute (slabs/bias/class tiles are per-model, not
per-frame), replacing O(N) kernel launches with one. VMEM per step: frame
(H, W) + slab (h, TD+W) + bias/class tiles (mx, TD) + P scratch (W+1, TD) +
acc (mx, TD) — independent of N.

``fragment_scores`` (single frame) is a batch-of-1 call into the same
kernel; ``fragment_scores_batch`` is the chunked entry point used by
``repro.sensing.stream``.

Precomputation (once per model, host-side): circularly padded base slabs
and pre-rotated bias/class tiles — see :func:`precompute_tiles`.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.encoding import SHIFT, NonLin
from repro.kernels.compat import CompilerParams

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ScoreTiles:
    """Per-model precomputed kernel inputs (see module docstring)."""
    slabs: Array      # (n_dt, h, TD + W - 1) circularly padded base rows
    bias_t: Array     # (n_dt, mx, TD) pre-rotated RFF bias tiles
    cpos_t: Array     # (n_dt, mx, TD) pre-rotated positive class tiles
    cneg_t: Array     # (n_dt, mx, TD) pre-rotated negative class tiles
    cpos_norm: Array  # () L2 of positive class hypervector
    cneg_norm: Array  # () L2 of negative class hypervector
    block_d: int = dataclasses.field(metadata={"static": True})
    w: int = dataclasses.field(metadata={"static": True})
    stride: int = dataclasses.field(metadata={"static": True})


def precompute_tiles(B0: Array, b: Array, class_hvs: Array, *, W: int,
                     w: int, stride: int, block_d: int = 512) -> ScoreTiles:
    """Host-side, once per (model, frame-width): slabs + rotated tiles."""
    h, dim = B0.shape
    assert SHIFT == -1, "precompute assumes the paper's left-shift"
    td = block_d if dim % block_d == 0 else dim
    n_dt = dim // td
    mx = (W - w) // stride + 1

    pad = td + W - 1
    B0P = jnp.concatenate([B0, B0[:, :pad]], axis=1)
    slabs = jnp.stack([B0P[:, dt * td: dt * td + pad]
                       for dt in range(n_dt)])               # (n_dt,h,TD+W-1)

    # idx[dt, kx, j] = (dt*TD + j + kx*stride) % D   (rotation by fragment col)
    dts = jnp.arange(n_dt)[:, None, None] * td
    kxs = jnp.arange(mx)[None, :, None] * stride
    js = jnp.arange(td)[None, None, :]
    idx = (dts + js + kxs) % dim                            # (n_dt, mx, TD)
    return ScoreTiles(
        slabs=slabs.astype(jnp.float32),
        bias_t=b[idx].astype(jnp.float32),
        cpos_t=class_hvs[1][idx].astype(jnp.float32),
        cneg_t=class_hvs[0][idx].astype(jnp.float32),
        cpos_norm=jnp.linalg.norm(class_hvs[1].astype(jnp.float32)),
        cneg_norm=jnp.linalg.norm(class_hvs[0].astype(jnp.float32)),
        block_d=td,
        w=w,
        stride=stride,
    )


def window_norms(frame: Array, h: int, w: int, stride: int) -> Array:
    """(my, mx) L2 norms of every sliding window via a summed-area table."""
    H, W = frame.shape
    my = (H - h) // stride + 1
    mx = (W - w) // stride + 1
    f = frame.astype(jnp.float32)
    sq = jnp.cumsum(jnp.cumsum(f * f, axis=0), axis=1)
    sq = jnp.pad(sq, ((1, 0), (1, 0)))
    ky = jnp.arange(my) * stride
    kx = jnp.arange(mx) * stride
    win = (sq[ky[:, None] + h, kx[None, :] + w]
           - sq[ky[:, None] + h, kx[None, :]]
           - sq[ky[:, None], kx[None, :] + w]
           + sq[ky[:, None], kx[None, :]])
    return jnp.sqrt(jnp.maximum(win, 1e-16))


def window_norms_batch(frames: Array, h: int, w: int, stride: int) -> Array:
    """(N, my, mx) sliding-window L2 norms for a stack of frames."""
    return jax.vmap(lambda f: window_norms(f, h, w, stride))(frames)


def _score_kernel(frame_ref, slab_ref, bias_ref, cpos_ref, cneg_ref,
                  norm_ref, dpos_ref, dneg_ref, qq_ref, p_ref, acc_ref, *,
                  h: int, w: int, stride: int, W: int, mx: int, td: int,
                  n_dt: int, nonlinearity: NonLin):
    ky = pl.program_id(1)
    acc_ref[...] = jnp.zeros_like(acc_ref)

    def row_body(r, _):
        row = frame_ref[0, pl.ds(ky * stride + r, 1), :]     # (1, W)
        row = row.astype(jnp.float32)
        slab = slab_ref[0, pl.ds(r, 1), :][0]
        slab = slab.astype(jnp.float32)                      # (TD + W - 1,)

        # prefix sum of rolled products (the computation reuse)
        p_ref[pl.ds(0, 1), :] = jnp.zeros((1, td), jnp.float32)

        def i_body(i, running):
            seg = jax.lax.dynamic_slice(slab, (i,), (td,))
            x_i = jax.lax.dynamic_slice(row, (0, i), (1, 1))[0, 0]
            running = running + x_i * seg
            p_ref[pl.ds(i + 1, 1), :] = running[None, :]
            return running

        jax.lax.fori_loop(0, W, i_body, jnp.zeros((td,), jnp.float32))

        # window differences: every fragment reuses the shared prefix sum
        def k_body(kx, _):
            lo = p_ref[pl.ds(kx * stride, 1), :]
            hi = p_ref[pl.ds(kx * stride + w, 1), :]
            acc_ref[pl.ds(kx, 1), :] = acc_ref[pl.ds(kx, 1), :] + hi - lo
            return 0

        jax.lax.fori_loop(0, mx, k_body, 0)
        return 0

    jax.lax.fori_loop(0, h, row_body, 0)

    # normalization + nonlinearity + classifier dots (unrolled orientation)
    norms = norm_ref[0].astype(jnp.float32)                  # (1, mx)
    s_n = acc_ref[...] / jnp.maximum(norms[0][:, None], 1e-8)
    bias = bias_ref[0]                                       # (mx, TD)
    if nonlinearity == "rff":
        phi = jnp.cos(s_n + bias) * jnp.sin(s_n)
    elif nonlinearity == "sign":
        phi = jnp.sign(s_n)
    else:
        phi = s_n
    dpos = jnp.sum(phi * cpos_ref[0], axis=1)[None, None, :]  # (1, 1, mx)
    dneg = jnp.sum(phi * cneg_ref[0], axis=1)[None, None, :]
    qq = jnp.sum(phi * phi, axis=1)[None, None, :]

    @pl.when(pl.program_id(2) == 0)
    def _init():
        dpos_ref[...] = jnp.zeros_like(dpos_ref)
        dneg_ref[...] = jnp.zeros_like(dneg_ref)
        qq_ref[...] = jnp.zeros_like(qq_ref)

    dpos_ref[...] += dpos
    dneg_ref[...] += dneg
    qq_ref[...] += qq


@functools.partial(jax.jit, static_argnames=("h", "w", "stride",
                                             "nonlinearity", "interpret"))
def fragment_scores_batch(frames: Array, tiles: ScoreTiles, *, h: int,
                          w: int, stride: int,
                          nonlinearity: NonLin = "rff",
                          interpret: bool = False) -> Array:
    """(N, H, W) frames -> (N, my, mx) score maps in one kernel launch.

    The whole batch shares one :class:`ScoreTiles` precompute; the Pallas
    grid is ``(N, my, n_dt)`` with the batch/row axes parallel and the
    hyperdimension tiles as the sequential reduction.
    """
    N, H, W = frames.shape
    my = (H - h) // stride + 1
    mx = (W - w) // stride + 1
    n_dt, h_b, slab_len = tiles.slabs.shape
    td = tiles.block_d
    assert h_b == h and slab_len == td + W - 1, (tiles.slabs.shape, td, W)
    assert tiles.w == w and tiles.stride == stride

    norms = window_norms_batch(frames, h, w, stride)         # (N, my, mx)

    kern = functools.partial(
        _score_kernel, h=h, w=w, stride=stride, W=W, mx=mx, td=td,
        n_dt=n_dt, nonlinearity=nonlinearity)

    dpos, dneg, qq = pl.pallas_call(
        kern,
        grid=(N, my, n_dt),
        in_specs=[
            pl.BlockSpec((1, H, W), lambda n, i, j: (n, 0, 0)),    # frame
            pl.BlockSpec((1, h, slab_len), lambda n, i, j: (j, 0, 0)),
            pl.BlockSpec((1, mx, td), lambda n, i, j: (j, 0, 0)),  # bias
            pl.BlockSpec((1, mx, td), lambda n, i, j: (j, 0, 0)),  # cpos
            pl.BlockSpec((1, mx, td), lambda n, i, j: (j, 0, 0)),  # cneg
            pl.BlockSpec((1, 1, mx), lambda n, i, j: (n, i, 0)),   # norms
        ],
        out_specs=[
            pl.BlockSpec((1, 1, mx), lambda n, i, j: (n, i, 0)),
            pl.BlockSpec((1, 1, mx), lambda n, i, j: (n, i, 0)),
            pl.BlockSpec((1, 1, mx), lambda n, i, j: (n, i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((N, my, mx), jnp.float32)] * 3,
        scratch_shapes=[
            pltpu.VMEM((W + 1, td), jnp.float32),
            pltpu.VMEM((mx, td), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(frames, tiles.slabs, tiles.bias_t, tiles.cpos_t, tiles.cneg_t, norms)

    qn = jnp.maximum(jnp.sqrt(qq), 1e-9)
    return (dpos / (qn * jnp.maximum(tiles.cpos_norm, 1e-9))
            - dneg / (qn * jnp.maximum(tiles.cneg_norm, 1e-9)))


def fragment_scores(frame: Array, tiles: ScoreTiles, *, h: int, w: int,
                    stride: int, nonlinearity: NonLin = "rff",
                    interpret: bool = False) -> Array:
    """Frame -> (my, mx) fragment score map (sim(pos) - sim(neg))."""
    return fragment_scores_batch(frame[None], tiles, h=h, w=w,
                                 stride=stride, nonlinearity=nonlinearity,
                                 interpret=interpret)[0]
