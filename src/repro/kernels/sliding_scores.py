"""Pallas TPU kernel: fused computation-reuse HyperSense frame scoring.

This is the paper's FPGA accelerator (§IV) adapted to TPU (DESIGN.md §3).
One kernel maps a sensor frame directly to the fragment score map:

  frame (H, W)  ->  scores-ingredients (my, mx) x 3

fusing, per grid cell:

  1. *rolled products + prefix sum* — each input element is multiplied with
     base-hypervector material exactly once per base row (the paper's
     computation reuse; the systolic FIFO becomes a running sum),
  2. *window differences* — every fragment's projection is
     ``P[kx+w] - P[kx]`` (the reuse of overlapping fragments),
  3. *normalization + RFF nonlinearity* — in the *unrolled* orientation:
     instead of cyclically rotating every (mx, D) projection back (the
     naive inverse of the permutation trick), the per-column *bias* and
     *class hypervectors* are pre-rotated once per model. A (D,)-vector
     rotation per fragment column, amortized over every frame forever,
     replaces an (mx, D) data rotation per frame — a beyond-paper
     optimization available because similarity is permutation-invariant.
  4. *classifier dot products* — positive/negative class dots and the query
     sum-of-squares accumulate across D tiles; the cosine epilogue runs
     host-side on the tiny (my, mx) outputs.

Grid: ``(N, my, n_dt)`` — frames and fragment rows parallel, hyperdimension
tiles as the sequential reduction. The batch axis is the streaming hot path:
one ``pallas_call`` scores a whole chunk of frames against a single
:class:`ScoreTiles` precompute (slabs/bias/class tiles are per-model, not
per-frame), replacing O(N) kernel launches with one. VMEM per step: frame
(H, W) + slab (h, TD+W) + bias/class tiles (mx, TD) + P scratch (W+1, TD) +
acc (mx, TD) — independent of N.

``fragment_scores`` (single frame) is a batch-of-1 call into the same
kernel; ``fragment_scores_batch`` is the chunked entry point used by
``repro.sensing.stream``.

Precomputation is split along the *mutability* boundary of the model
(online learning — paper §I "real-time learning"):

* :class:`ScoreGeometry` — the expensive, class-independent part: circularly
  padded base slabs, the pre-rotated RFF bias tiles, and the rotation
  gather ``idx`` itself. Depends only on ``(B0, b, W, w, stride, block_d)``;
  computed host-side once per (model-geometry, frame-width) by
  :func:`precompute_geometry`.
* class tiles — the cheap, class-*dependent* part: the pre-rotated
  positive/negative class hypervector tiles plus their L2 norms. Produced
  from a geometry by the **jitted, device-side** :func:`retile_classes`:
  one gather per class through the stored ``idx`` plus two norms. Updating
  the classifier mid-stream (the online-learning hot path) costs a
  ``retile_classes`` call — never a host-side re-precompute.

:class:`ScoreTiles` = geometry + class tiles; :func:`precompute_tiles`
(the historical all-in-one entry point) is now exactly
``retile_classes(precompute_geometry(...), class_hvs)``.

For fleets adapting a *per-stream* classifier, ``fragment_scores_batch``
accepts class tiles with a leading stream axis (``frames_per_stream``):
the kernel grid is unchanged, but the class-tile BlockSpec index maps pick
stream ``n // C``'s tiles for batch element ``n`` — still ONE launch.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.encoding import SHIFT, NonLin, apply_nonlinearity
from repro.kernels.compat import CompilerParams

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ScoreGeometry:
    """Class-independent kernel precompute (see module docstring).

    Depends only on ``(B0, b)`` and the frame geometry — *not* on the class
    hypervectors, so it survives every online-learning model update. The
    stored rotation gather ``idx`` is what makes class updates cheap:
    re-tiling a new classifier is one gather through it per class.
    """
    slabs: Array      # (n_dt, h, TD + W - 1) circularly padded base rows
    bias_t: Array     # (n_dt, mx, TD) pre-rotated RFF bias tiles
    idx: Array        # (n_dt, mx, TD) i32 rotation gather into a (D,) vector
    block_d: int = dataclasses.field(metadata={"static": True})
    w: int = dataclasses.field(metadata={"static": True})
    stride: int = dataclasses.field(metadata={"static": True})


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ScoreTiles:
    """Geometry + class-dependent tiles: the full kernel input bundle.

    ``cpos_t``/``cneg_t`` are ``(n_dt, mx, TD)`` for a single shared
    classifier, or ``(S, n_dt, mx, TD)`` (with ``(S,)`` norms) for a fleet
    adapting per-stream classifiers (see :func:`fragment_scores_batch`).
    """
    geom: ScoreGeometry
    cpos_t: Array     # ([S,] n_dt, mx, TD) pre-rotated positive class tiles
    cneg_t: Array     # ([S,] n_dt, mx, TD) pre-rotated negative class tiles
    cpos_norm: Array  # ([S]) L2 of positive class hypervector
    cneg_norm: Array  # ([S]) L2 of negative class hypervector

    # Back-compat passthroughs (pre-split callers read these off the tiles).
    @property
    def slabs(self) -> Array:
        return self.geom.slabs

    @property
    def bias_t(self) -> Array:
        return self.geom.bias_t

    @property
    def block_d(self) -> int:
        return self.geom.block_d

    @property
    def w(self) -> int:
        return self.geom.w

    @property
    def stride(self) -> int:
        return self.geom.stride


def precompute_geometry(B0: Array, b: Array, *, W: int, w: int, stride: int,
                        block_d: int = 512) -> ScoreGeometry:
    """Host-side, once per (model-geometry, frame-width): slabs + bias + idx.

    The expensive precompute. Everything class-dependent is deferred to
    :func:`retile_classes` so the classifier can change without re-running
    this.
    """
    h, dim = B0.shape
    assert SHIFT == -1, "precompute assumes the paper's left-shift"
    td = block_d if dim % block_d == 0 else dim
    n_dt = dim // td
    mx = (W - w) // stride + 1

    pad = td + W - 1
    B0P = jnp.concatenate([B0, B0[:, :pad]], axis=1)
    slabs = jnp.stack([B0P[:, dt * td: dt * td + pad]
                       for dt in range(n_dt)])               # (n_dt,h,TD+W-1)

    # idx[dt, kx, j] = (dt*TD + j + kx*stride) % D   (rotation by fragment col)
    dts = jnp.arange(n_dt)[:, None, None] * td
    kxs = jnp.arange(mx)[None, :, None] * stride
    js = jnp.arange(td)[None, None, :]
    idx = (dts + js + kxs) % dim                            # (n_dt, mx, TD)
    return ScoreGeometry(
        slabs=slabs.astype(jnp.float32),
        bias_t=b[idx].astype(jnp.float32),
        idx=idx,
        block_d=td,
        w=w,
        stride=stride,
    )


@jax.jit
def retile_classes(geom: ScoreGeometry, class_hvs: Array) -> ScoreTiles:
    """Device-side classifier (re-)tiling: ``(2, D)`` -> :class:`ScoreTiles`.

    One gather per class through the stored rotation ``idx`` plus two norms
    — the entire cost of installing an updated classifier into the scoring
    kernel. Jitted: safe to call inside a larger jitted streaming step
    (the online-adaptation hot path) as well as standalone.

    ``vmap`` over ``class_hvs`` (``(S, 2, D)``) yields the per-stream tile
    stack the fleet's per-stream adaptation mode consumes.
    """
    cpos = class_hvs[1].astype(jnp.float32)
    cneg = class_hvs[0].astype(jnp.float32)
    return ScoreTiles(
        geom=geom,
        cpos_t=cpos[geom.idx],
        cneg_t=cneg[geom.idx],
        cpos_norm=jnp.linalg.norm(cpos),
        cneg_norm=jnp.linalg.norm(cneg),
    )


@jax.jit
def retile_classes_fleet(geom: ScoreGeometry, class_hvs: Array) -> ScoreTiles:
    """Per-stream classifier tiling: ``(S, 2, D)`` -> stacked tiles.

    The geometry stays shared (un-batched); only the class tiles and norms
    grow a leading stream axis, ready for
    ``fragment_scores_batch(..., frames_per_stream=C)``.
    """
    cpos = class_hvs[:, 1].astype(jnp.float32)               # (S, D)
    cneg = class_hvs[:, 0].astype(jnp.float32)
    return ScoreTiles(
        geom=geom,
        cpos_t=jax.vmap(lambda v: v[geom.idx])(cpos),        # (S,n_dt,mx,TD)
        cneg_t=jax.vmap(lambda v: v[geom.idx])(cneg),
        cpos_norm=jnp.linalg.norm(cpos, axis=-1),            # (S,)
        cneg_norm=jnp.linalg.norm(cneg, axis=-1),
    )


def precompute_tiles(B0: Array, b: Array, class_hvs: Array, *, W: int,
                     w: int, stride: int, block_d: int = 512) -> ScoreTiles:
    """Host-side, once per (model, frame-width): geometry + class tiles.

    The historical all-in-one entry point; now literally the composition
    ``retile_classes(precompute_geometry(...), class_hvs)``.
    """
    geom = precompute_geometry(B0, b, W=W, w=w, stride=stride,
                               block_d=block_d)
    return retile_classes(geom, class_hvs)


def window_norms(frame: Array, h: int, w: int, stride: int) -> Array:
    """(my, mx) L2 norms of every sliding window via a summed-area table."""
    H, W = frame.shape
    my = (H - h) // stride + 1
    mx = (W - w) // stride + 1
    f = frame.astype(jnp.float32)
    sq = jnp.cumsum(jnp.cumsum(f * f, axis=0), axis=1)
    sq = jnp.pad(sq, ((1, 0), (1, 0)))
    ky = jnp.arange(my) * stride
    kx = jnp.arange(mx) * stride
    win = (sq[ky[:, None] + h, kx[None, :] + w]
           - sq[ky[:, None] + h, kx[None, :]]
           - sq[ky[:, None], kx[None, :] + w]
           + sq[ky[:, None], kx[None, :]])
    return jnp.sqrt(jnp.maximum(win, 1e-16))


def window_norms_batch(frames: Array, h: int, w: int, stride: int) -> Array:
    """(N, my, mx) sliding-window L2 norms for a stack of frames."""
    return jax.vmap(lambda f: window_norms(f, h, w, stride))(frames)


def _score_kernel(frame_ref, slab_ref, bias_ref, cpos_ref, cneg_ref,
                  norm_ref, dpos_ref, dneg_ref, qq_ref, p_ref, acc_ref, *,
                  h: int, w: int, stride: int, W: int, mx: int, td: int,
                  n_dt: int, nonlinearity: NonLin):
    ky = pl.program_id(1)
    acc_ref[...] = jnp.zeros_like(acc_ref)

    def row_body(r, _):
        row = frame_ref[0, pl.ds(ky * stride + r, 1), :]     # (1, W)
        row = row.astype(jnp.float32)
        slab = slab_ref[0, pl.ds(r, 1), :][0]
        slab = slab.astype(jnp.float32)                      # (TD + W - 1,)

        # prefix sum of rolled products (the computation reuse)
        p_ref[pl.ds(0, 1), :] = jnp.zeros((1, td), jnp.float32)

        def i_body(i, running):
            seg = jax.lax.dynamic_slice(slab, (i,), (td,))
            x_i = jax.lax.dynamic_slice(row, (0, i), (1, 1))[0, 0]
            running = running + x_i * seg
            p_ref[pl.ds(i + 1, 1), :] = running[None, :]
            return running

        jax.lax.fori_loop(0, W, i_body, jnp.zeros((td,), jnp.float32))

        # window differences: every fragment reuses the shared prefix sum
        def k_body(kx, _):
            lo = p_ref[pl.ds(kx * stride, 1), :]
            hi = p_ref[pl.ds(kx * stride + w, 1), :]
            acc_ref[pl.ds(kx, 1), :] = acc_ref[pl.ds(kx, 1), :] + hi - lo
            return 0

        jax.lax.fori_loop(0, mx, k_body, 0)
        return 0

    jax.lax.fori_loop(0, h, row_body, 0)

    # normalization + nonlinearity + classifier dots (unrolled orientation)
    # — the nonlinearity is the ONE definition in repro.core.encoding,
    # shared with the int kernel and both jnp oracles (identical
    # expression, so this path stays bitwise-frozen)
    norms = norm_ref[0].astype(jnp.float32)                  # (1, mx)
    s_n = acc_ref[...] / jnp.maximum(norms[0][:, None], 1e-8)
    phi = apply_nonlinearity(s_n, bias_ref[0], nonlinearity)
    # Per-tile partial sums, one (1, 1, 1, mx) output block per D-tile.
    # The tiles are reduced OUTSIDE the kernel by _ordered_tile_fold so the
    # combine order is a fixed left-to-right fold regardless of how the
    # n_dt axis is sharded across devices — the basis of the bitwise
    # sharded == unsharded guarantee (see fragment_scores_batch).
    dpos_ref[...] = jnp.sum(phi * cpos_ref[0], axis=1)[None, None, None, :]
    dneg_ref[...] = jnp.sum(phi * cneg_ref[0], axis=1)[None, None, None, :]
    qq_ref[...] = jnp.sum(phi * phi, axis=1)[None, None, None, :]


def _ordered_tile_fold(parts: Array,
                       hyperdim_axes: tuple[str, ...] | None = None) -> Array:
    """Reduce a leading D-tile axis with a FIXED left-to-right fold.

    ``parts`` is ``(n_dt_local, ...)`` per-tile partial sums. When the
    tile axis is sharded over mesh axes ``hyperdim_axes``, a tiled
    ``all_gather`` first restores the *global* tile order, so every mesh
    shape folds the exact same floats in the exact same order and the
    result is bitwise-identical to the single-device reduction. A plain
    ``jnp.sum``/``psum`` would let XLA reassociate the adds and break
    that guarantee — do not "simplify" this into one.
    """
    if hyperdim_axes:
        parts = jax.lax.all_gather(parts, hyperdim_axes, axis=0, tiled=True)
    out = parts[0]
    for i in range(1, parts.shape[0]):
        out = out + parts[i]
    return out


@functools.partial(jax.jit, static_argnames=("h", "w", "stride",
                                             "nonlinearity", "interpret",
                                             "frames_per_stream",
                                             "hyperdim_axes"))
def fragment_scores_batch(frames: Array, tiles: ScoreTiles, *, h: int,
                          w: int, stride: int,
                          nonlinearity: NonLin = "rff",
                          interpret: bool = False,
                          frames_per_stream: int | None = None,
                          hyperdim_axes: tuple[str, ...] | None = None
                          ) -> Array:
    """(N, H, W) frames -> (N, my, mx) score maps in one kernel launch.

    The whole batch shares one :class:`ScoreGeometry` precompute; the
    Pallas grid is ``(N, my, n_dt)`` with the batch/row axes parallel.
    Each D-tile emits its own partial dot products; the tiles are folded
    outside the kernel in fixed left-to-right order (bitwise-stable).

    Inside a ``shard_map`` whose mesh partitions the tile axis over
    ``hyperdim_axes``, pass those axis names: ``tiles`` then holds this
    device's contiguous D-shard (``n_dt_local`` leading dim) and the fold
    is preceded by one tiled ``all_gather`` over the hyperdim axis — the
    single collective the D-sharded epilogue needs. Scores stay
    bitwise-identical to the unsharded launch for every mesh shape.

    With shared class tiles (``tiles.cpos_t.ndim == 3``) every frame is
    scored against the same classifier. With *per-stream* class tiles
    (``(S, n_dt, mx, TD)``, from ``vmap(retile_classes)``) the batch is
    interpreted as S streams of ``frames_per_stream`` frames each (must be
    static and divide N): batch element ``n`` reads stream ``n // C``'s
    class tiles via the BlockSpec index map — same grid, same kernel body,
    still ONE launch. That is the fleet's per-stream online-learning path.
    """
    N, H, W = frames.shape
    my = (H - h) // stride + 1
    mx = (W - w) // stride + 1
    n_dt, h_b, slab_len = tiles.slabs.shape
    td = tiles.block_d
    # repro-lint: disable=RA001 (td/tiles.w/tiles.stride are static aux fields of the tile pytree — concrete at trace time)
    assert h_b == h and slab_len == td + W - 1, (tiles.slabs.shape, td, W)
    assert tiles.w == w and tiles.stride == stride  # repro-lint: disable=RA001 (same static aux fields)

    per_stream = tiles.cpos_t.ndim == 4
    if per_stream:
        if frames_per_stream is None:
            raise ValueError("per-stream class tiles need frames_per_stream")
        C = frames_per_stream
        S = tiles.cpos_t.shape[0]
        if S * C != N:
            raise ValueError(f"per-stream tiles: S={S} streams x "
                             f"C={C} frames != batch N={N}")
        # (S, n_dt, mx, td) -> (S*n_dt, mx, td): batch n reads stream n//C.
        cpos_t = tiles.cpos_t.reshape(S * n_dt, mx, td)
        cneg_t = tiles.cneg_t.reshape(S * n_dt, mx, td)
        class_spec = pl.BlockSpec(
            (1, mx, td), lambda n, i, j: ((n // C) * n_dt + j, 0, 0))
    else:
        cpos_t, cneg_t = tiles.cpos_t, tiles.cneg_t
        class_spec = pl.BlockSpec((1, mx, td), lambda n, i, j: (j, 0, 0))

    norms = window_norms_batch(frames, h, w, stride)         # (N, my, mx)

    kern = functools.partial(
        _score_kernel, h=h, w=w, stride=stride, W=W, mx=mx, td=td,
        n_dt=n_dt, nonlinearity=nonlinearity)

    dpos, dneg, qq = pl.pallas_call(
        kern,
        grid=(N, my, n_dt),
        in_specs=[
            pl.BlockSpec((1, H, W), lambda n, i, j: (n, 0, 0)),    # frame
            pl.BlockSpec((1, h, slab_len), lambda n, i, j: (j, 0, 0)),
            pl.BlockSpec((1, mx, td), lambda n, i, j: (j, 0, 0)),  # bias
            class_spec,                                            # cpos
            class_spec,                                            # cneg
            pl.BlockSpec((1, 1, mx), lambda n, i, j: (n, i, 0)),   # norms
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, mx), lambda n, i, j: (j, n, i, 0)),
            pl.BlockSpec((1, 1, 1, mx), lambda n, i, j: (j, n, i, 0)),
            pl.BlockSpec((1, 1, 1, mx), lambda n, i, j: (j, n, i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((n_dt, N, my, mx),
                                        jnp.float32)] * 3,
        scratch_shapes=[
            pltpu.VMEM((W + 1, td), jnp.float32),
            pltpu.VMEM((mx, td), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel"),
        ),
        interpret=interpret,
    )(frames, tiles.slabs, tiles.bias_t, cpos_t, cneg_t, norms)

    dpos = _ordered_tile_fold(dpos, hyperdim_axes)
    dneg = _ordered_tile_fold(dneg, hyperdim_axes)
    qq = _ordered_tile_fold(qq, hyperdim_axes)

    qn = jnp.maximum(jnp.sqrt(qq), 1e-9)
    if per_stream:
        # per-stream classifier norms broadcast over that stream's frames
        rep = lambda v: jnp.repeat(v, C)[:, None, None]       # (N, 1, 1)
        return (dpos / (qn * jnp.maximum(rep(tiles.cpos_norm), 1e-9))
                - dneg / (qn * jnp.maximum(rep(tiles.cneg_norm), 1e-9)))
    return (dpos / (qn * jnp.maximum(tiles.cpos_norm, 1e-9))
            - dneg / (qn * jnp.maximum(tiles.cneg_norm, 1e-9)))


def fragment_scores(frame: Array, tiles: ScoreTiles, *, h: int, w: int,
                    stride: int, nonlinearity: NonLin = "rff",
                    interpret: bool = False) -> Array:
    """Frame -> (my, mx) fragment score map (sim(pos) - sim(neg))."""
    return fragment_scores_batch(frame[None], tiles, h=h, w=w,
                                 stride=stride, nonlinearity=nonlinearity,
                                 interpret=interpret)[0]
