"""Pallas TPU kernels for HyperSense's compute hot-spots (paper §IV).

* :mod:`repro.kernels.hdc_encode`     — fused RFF encoding matmul
* :mod:`repro.kernels.sliding_scores` — computation-reuse frame scoring
  (the paper's FPGA accelerator, TPU-adapted; DESIGN.md §3)
* :mod:`repro.kernels.similarity`     — fused cosine classifier
* :mod:`repro.kernels.ops`            — jit'd public wrappers
* :mod:`repro.kernels.ref`            — pure-jnp oracles for all of the above
* :mod:`repro.kernels.compat`         — jax version-compat shims
"""
