"""Pallas TPU kernel: MXU HDC encoding with in-VMEM permutation expansion.

The beyond-paper optimization for TPU (§Perf cell 3, EXPERIMENTS.md):

The paper's computation reuse saves *multiplies* — the right currency on
an FPGA. On TPU the MXU is ~50x denser than the VPU, so recomputing the
multiplies as a plain matmul beats the prefix-sum reuse. What the
permutation structure (Eq. 1) is *still* worth on TPU is **memory**: the
full base matrix ``B (h*w, D)`` (184 MB at the paper's operating point)
is generated from only the ``h`` generator rows ``B0 (h, D)`` (1.9 MB),
so this kernel keeps B0 resident in VMEM and materializes each MXU tile
of B on the fly — base HBM traffic drops by ``w`` (96x), turning the
memory-bound naive matmul into a compute-bound one at MXU speed.

Layout: fragments ``(N, h*w)`` row-major (row r, column j) -> flat index
``r*w + j`` pairs with ``B[r*w + j] = roll(B0[r], j*SHIFT)``. For an MXU
K-tile covering flat rows [k0, k0+bk) and a D-tile [d0, d0+bd), row
``r*w + j`` needs ``B0P[r, d0 + j : d0 + j + bd]`` — a dynamic slice of
the circularly padded generators. The kernel builds the (bk, bd) tile
with a ``fori_loop`` of row slices, then issues ``jnp.dot``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.encoding import SHIFT, NonLin
from repro.kernels.compat import CompilerParams


def _kernel(x_ref, b0p_ref, bias_ref, o_ref, acc_ref, btile_ref, *,
            nonlinearity: NonLin, n_k: int, bk: int, bd: int, w: int,
            dim: int):
    kk = pl.program_id(2)
    jd = pl.program_id(1)
    d0 = jd * bd

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # build the (bk, bd) base tile from the generators (VMEM-local)
    def row_body(i, _):
        flat = kk * bk + i
        r = flat // w
        j = flat % w
        # roll(B0[r], j*SHIFT)[d0:d0+bd] = B0P[r, d0+j : d0+j+bd] (SHIFT=-1)
        assert SHIFT == -1
        start = (d0 + j) % dim
        seg = b0p_ref[pl.ds(r, 1), pl.ds(start, bd)]
        btile_ref[pl.ds(i, 1), :] = seg.astype(btile_ref.dtype)
        return 0

    jax.lax.fori_loop(0, bk, row_body, 0)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        btile_ref[...],
        preferred_element_type=jnp.float32)

    @pl.when(kk == n_k - 1)
    def _epilogue():
        proj = acc_ref[...]
        bias = bias_ref[...].astype(jnp.float32)
        if nonlinearity == "rff":
            out = jnp.cos(proj + bias) * jnp.sin(proj)
        elif nonlinearity == "sign":
            out = jnp.sign(proj)
        else:
            out = proj
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("h", "w", "nonlinearity",
                                             "block_n", "block_d",
                                             "block_k", "interpret"))
def hdc_encode_perm(x: jax.Array, B0: jax.Array, b: jax.Array, *, h: int,
                    w: int, nonlinearity: NonLin = "rff",
                    block_n: int = 128, block_d: int = 512,
                    block_k: int = 256, interpret: bool = False
                    ) -> jax.Array:
    """Encode flattened fragments ``(N, h*w)`` against the
    permutation-structured base generated from ``B0 (h, D)``.

    Equivalent to ``hdc_encode(x, flat_perm_base(B0, w), b)`` but the
    expanded base never exists outside VMEM tiles.
    """
    n, k = x.shape
    assert k == h * w, (x.shape, h, w)
    dim = B0.shape[1]
    bn = min(block_n, max(8, n))
    bd = min(block_d, dim)
    bk = min(block_k, k)
    assert k % bk == 0, "h*w must divide block_k after clamping"
    assert dim % bd == 0, (dim, bd)

    def pad_to(a, axis, mult):
        rem = (-a.shape[axis]) % mult
        if rem == 0:
            return a
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, rem)
        return jnp.pad(a, widths)

    xp = pad_to(x, 0, bn)
    n_p = xp.shape[0]
    n_k = k // bk
    # circular pad so every (d0 + j, bd) slice is contiguous
    B0P = jnp.concatenate([B0, B0[:, :bd + w]], axis=1)
    biasp = b.reshape(1, -1)

    out = pl.pallas_call(
        functools.partial(_kernel, nonlinearity=nonlinearity, n_k=n_k,
                          bk=bk, bd=bd, w=w, dim=dim),
        grid=(n_p // bn, dim // bd, n_k),
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec(B0P.shape, lambda i, j, kk: (0, 0)),  # resident
            pl.BlockSpec((1, bd), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bn, bd), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_p, dim), x.dtype),
        scratch_shapes=[pltpu.VMEM((bn, bd), jnp.float32),
                        pltpu.VMEM((bk, bd), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xp, B0P, biasp)
    return out[:n]
