"""Pallas TPU kernel: fused cosine-similarity classifier.

``scores = normalize(Q) @ normalize(C)^T`` for query hypervectors
``Q (N, D)`` against class hypervectors ``C (C, D)``.

Fusion: query normalization (rsqrt of a row-reduction) happens in-kernel so
the normalized queries never hit HBM. The class matrix is tiny (C=2 for
HyperSense) and is loaded whole; class norms are folded in-kernel too.
Grid: ``(N/bn, D/bd)`` with D the sequential reduction axis — both the dot
products and the query sum-of-squares accumulate across D steps, and the
epilogue divides on the last step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _sim_kernel(q_ref, c_ref, o_ref, dots_ref, qq_ref, cc_ref, *, n_d: int,
                eps: float):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        dots_ref[...] = jnp.zeros_like(dots_ref)
        qq_ref[...] = jnp.zeros_like(qq_ref)
        cc_ref[...] = jnp.zeros_like(cc_ref)

    q = q_ref[...].astype(jnp.float32)            # (bn, bd)
    c = c_ref[...].astype(jnp.float32)            # (C, bd)
    dots_ref[...] += jax.lax.dot_general(
        q, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)       # (bn, C)
    qq_ref[...] += jnp.sum(q * q, axis=-1, keepdims=True)   # (bn, 1)
    cc_ref[...] += jnp.sum(c * c, axis=-1, keepdims=True).T  # (1, C)

    @pl.when(pl.program_id(1) == n_d - 1)
    def _epilogue():
        qn = jnp.maximum(jnp.sqrt(qq_ref[...]), eps)         # (bn, 1)
        cn = jnp.maximum(jnp.sqrt(cc_ref[...]), eps)         # (1, C)
        o_ref[...] = (dots_ref[...] / (qn * cn)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "block_d",
                                             "interpret"))
def similarity(queries: jax.Array, class_hvs: jax.Array, *,
               block_n: int = 256, block_d: int = 1024,
               interpret: bool = False, eps: float = 1e-9) -> jax.Array:
    """Cosine class scores ``(N, D), (C, D) -> (N, C)`` in fp32."""
    n, d = queries.shape
    c, d2 = class_hvs.shape
    assert d == d2
    bn = min(block_n, max(8, n))
    bd = min(block_d, d)

    def pad_to(a, axis, mult):
        rem = (-a.shape[axis]) % mult
        if rem == 0:
            return a
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, rem)
        return jnp.pad(a, widths)

    qp = pad_to(pad_to(queries, 0, bn), 1, bd)
    cp = pad_to(class_hvs, 1, bd)
    n_p, d_p = qp.shape
    n_d = d_p // bd

    out = pl.pallas_call(
        functools.partial(_sim_kernel, n_d=n_d, eps=eps),
        grid=(n_p // bn, n_d),
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
            pl.BlockSpec((c, bd), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bn, c), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_p, c), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bn, c), jnp.float32),
            pltpu.VMEM((bn, 1), jnp.float32),
            pltpu.VMEM((1, c), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qp, cp)
    return out[:n]
