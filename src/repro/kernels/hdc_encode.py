"""Pallas TPU kernel: fused HDC RFF encoding.

``phi(x) = cos(xB + b) * sin(xB)`` as a single tiled matmul with the
nonlinearity fused into the epilogue — the projection never round-trips to
HBM. Grid: ``(N/bn, D/bd, K/bk)`` with the K axis as the innermost
(sequential) reduction; accumulation is kept in an fp32 VMEM scratch and the
epilogue fires on the last K step.

Block shapes are MXU-aligned (multiples of 128 on the N/D axes; the
reduction axis ``bk`` is a VMEM-footprint knob). VMEM working set per step:
``bn*bk + bk*bd + 2*bn*bd`` floats.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.encoding import NonLin
from repro.kernels.compat import CompilerParams


def _encode_kernel(x_ref, b_mat_ref, bias_ref, o_ref, acc_ref, *,
                   nonlinearity: NonLin, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        b_mat_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        proj = acc_ref[...]
        bias = bias_ref[...].astype(jnp.float32)  # (1, bd)
        if nonlinearity == "rff":
            out = jnp.cos(proj + bias) * jnp.sin(proj)
        elif nonlinearity == "sign":
            out = jnp.sign(proj)
        else:  # linear
            out = proj
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("nonlinearity", "block_n", "block_d", "block_k",
                     "interpret"),
)
def hdc_encode(x: jax.Array, B: jax.Array, b: jax.Array, *,
               nonlinearity: NonLin = "rff", block_n: int = 128,
               block_d: int = 512, block_k: int = 512,
               interpret: bool = False) -> jax.Array:
    """Fused encode: ``(N, K) @ (K, D)`` + pointwise nonlinearity.

    Pads every axis up to its block multiple (masked out on the way back).
    """
    n, k = x.shape
    k2, d = B.shape
    assert k == k2, (x.shape, B.shape)
    bn = min(block_n, max(8, n))
    bd = min(block_d, d)
    bk = min(block_k, k)

    def pad_to(a, axis, mult):
        size = a.shape[axis]
        rem = (-size) % mult
        if rem == 0:
            return a
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, rem)
        return jnp.pad(a, widths)

    xp = pad_to(pad_to(x, 0, bn), 1, bk)
    Bp = pad_to(pad_to(B, 0, bk), 1, bd)
    biasp = pad_to(b.reshape(1, -1), 1, bd)
    n_p, k_p = xp.shape
    _, d_p = Bp.shape
    n_k = k_p // bk

    out = pl.pallas_call(
        functools.partial(_encode_kernel, nonlinearity=nonlinearity,
                          n_k=n_k),
        grid=(n_p // bn, d_p // bd, n_k),
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bd), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bd), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bn, bd), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_p, d_p), x.dtype),
        scratch_shapes=[pltpu.VMEM((bn, bd), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xp, Bp, biasp)
    return out[:n, :d]
