"""Pallas TPU kernel: low-precision integer HyperSense frame scoring.

The float path (:mod:`repro.kernels.sliding_scores`) consumes the ADC's
*reconstruction* ``codes * LSB`` — every kernel still does float32 work, so
the "energy-efficient low-precision ADC" of the paper buys nothing past the
converter. This module is the paper's actual FPGA datapath (§IV) brought to
the kernel level, following the SCM always-on HDC accelerator (Eggimann et
al., 2021) and the low-bitwidth hypervector-design line (Basaklar et al.,
2021): the raw integer **ADC codes** flow into the scoring kernel untouched,
every fragment projection accumulates in **int32**, and floats appear only
in the tiny similarity/normalization epilogue.

Why the integer path is *structurally* different (not just a dtype swap):

* **Expanded shifted slabs + vectorized prefix reuse.** The float kernel
  walks each frame row with an ``O(h*(W+mx))``-step scalar prefix-sum loop
  (the systolic FIFO in loop form). The int kernel *pre-expands* all ``W``
  cyclic shifts of every base row into one ``(h*W, TD)`` operand —
  affordable **because it is int8**: the expansion is 4x smaller than
  float32 and fits VMEM at deployment scale (h=16, W=128, TD=512 -> 1 MB
  int8/tile). The per-grid-step projection then keeps the paper's
  computation reuse with zero scalar loops: ``h`` wide elementwise
  products against the pre-shifted slabs fold into the per-column rolled
  sums ``G (W, TD)`` (each code multiplied once per base row — the reused
  product), and the fragment windows fall out of ONE small integer matmul
  ``win_mask (mx, W) @ G`` — MXU-shaped on TPU, vectorized in interpret
  mode. Same multiply count as the float kernel, none of its
  ``h*(W+mx)`` sequential loop steps — that is where the measured
  ``benchmarks/int_datapath.py`` throughput win comes from.
* **LSB cancellation.** The fragment projection is normalized by the
  window's L2 norm, so the ADC step size cancels:
  ``(LSB * acc) / (LSB * ||codes||) = acc / ||codes||``. Scores from the
  int path live on the same scale as the float path — ``t_score``
  thresholds and ROC sweeps transfer unchanged.
* **Scale cancellation in the cosine epilogue.** Class hypervectors are
  stored as int8 with a per-class scale; because the final score is a
  *cosine*, the class scale cancels against the class norm — the epilogue
  only ever needs the L2 norm of the *quantized* class vector. The only
  approximation the int path introduces is int8 rounding of the slabs and
  class tiles (AUC gap bounded in the benchmark ``--check``).

Accumulator discipline (all bounds checked by
:func:`assert_int_datapath_fits` + hypothesis property tests):

* window sum-of-squares: exact int32 summed-area table of ``codes**2``
  (``<= H*W*(2^bits-1)^2``) — the float SAT would lose exactness past
  2^24;
* fragment projection prefix sum: ``<= h*W*(2^bits-1)*127`` per entry —
  int32 with orders of magnitude of headroom at 8-bit codes and paper
  frame/window sizes.

Integer accumulation is associative, so the int path is **bitwise
deterministic across runs** regardless of scheduling — asserted in CI.

Precompute mirrors the float path's mutability split: class-independent
:class:`IntScoreGeometry` (quantized expanded slabs, window mask, rotation
gather) vs the jitted device-side :func:`retile_classes_int` /
:func:`retile_classes_int_fleet` (classifier install = gather + int8
quantize per class), so online adaptation never re-runs the host
precompute mid-stream.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.encoding import NonLin, apply_nonlinearity
from repro.kernels import sliding_scores as _ss
from repro.kernels.compat import CompilerParams

Array = jax.Array

INT32_MAX = 2**31 - 1

#: int8 symmetric quantization range (saturating at +-127 keeps the
#: representation sign-symmetric; -128 is never produced)
_QMAX = 127


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class IntScoreGeometry:
    """Class-independent int-kernel precompute (see module docstring).

    ``slab_mat`` is the *expanded shifted slab*:
    ``slab_mat[dt, r*W + i, j] = q(slabs[dt, r, i + j])`` — all ``W``
    cyclic shifts of every base row, int8-quantized with the shared
    ``slab_scale``. Multiplying frame row ``r``'s code ``i`` against
    ``slab_mat[dt, r*W + i, :]`` is the paper's reused rolled product;
    ``win_mask[kx, i] = [kx*stride <= i < kx*stride + w]`` aggregates the
    rolled sums into fragment windows as one small matmul.
    """
    slab_mat: Array    # (n_dt, h*W, TD) int8 expanded shifted slabs
    win_mask: Array    # (mx, W) int8 window-membership indicator
    bias_t: Array      # (n_dt, mx, TD) f32 pre-rotated RFF bias tiles
    idx: Array         # (n_dt, mx, TD) i32 rotation gather into a (D,) vec
    slab_scale: Array  # () f32: slab ~= slab_mat * slab_scale
    block_d: int = dataclasses.field(metadata={"static": True})
    w: int = dataclasses.field(metadata={"static": True})
    stride: int = dataclasses.field(metadata={"static": True})


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class IntScoreTiles:
    """Geometry + int8 class tiles: the int kernel's full input bundle.

    ``cpos_t``/``cneg_t`` are ``(n_dt, mx, TD)`` int8 for a shared
    classifier or ``(S, n_dt, mx, TD)`` (with ``(S,)`` norms) per-stream.
    ``c*_norm`` is the L2 norm of the *quantized* class vector — the
    per-class quantization scale cancels in the cosine epilogue, so it is
    never stored.
    """
    geom: IntScoreGeometry
    cpos_t: Array     # ([S,] n_dt, mx, TD) int8 positive class tiles
    cneg_t: Array     # ([S,] n_dt, mx, TD) int8 negative class tiles
    cpos_norm: Array  # ([S]) f32 L2 of the quantized positive class vector
    cneg_norm: Array  # ([S]) f32 L2 of the quantized negative class vector


# ---------------------------------------------------------------------------
# Accumulator bounds: the no-overflow contract of the int32 datapath
# ---------------------------------------------------------------------------

def int_datapath_bounds(adc_bits: int, H: int, W: int, h: int, w: int
                        ) -> dict:
    """Worst-case int32 accumulator magnitudes of the integer datapath.

    * ``sumsq`` — the summed-area table of squared codes over a full
      frame (the window-norm pass);
    * ``acc``  — one fragment projection: ``h*w`` products of a max code
      with a max int8 slab entry.

    Both must stay below ``INT32_MAX`` for the path to be exact.
    """
    cmax = (1 << adc_bits) - 1
    sumsq = H * W * cmax * cmax
    acc = h * w * cmax * _QMAX
    return {"sumsq": sumsq, "acc": acc, "int32_max": INT32_MAX,
            "fits": max(sumsq, acc) <= INT32_MAX}


def assert_int_datapath_fits(adc_bits: int, H: int, W: int, h: int,
                             w: int) -> None:
    """Raise unless every int32 accumulator of the datapath has headroom."""
    b = int_datapath_bounds(adc_bits, H, W, h, w)
    if not b["fits"]:
        raise ValueError(
            f"int8 datapath would overflow int32 at adc_bits={adc_bits}, "
            f"frame {H}x{W}, window {h}x{w}: worst-case accumulators "
            f"sumsq={b['sumsq']}, acc={b['acc']} exceed {INT32_MAX}; "
            f"use fewer ADC bits / smaller frames or precision='float32'")


# ---------------------------------------------------------------------------
# Precompute: geometry (host, per model-geometry) + class tiles (device)
# ---------------------------------------------------------------------------

def _quantize_sym(x: Array, scale: Array) -> Array:
    """Symmetric int8 quantization at a given positive scale."""
    return jnp.clip(jnp.round(x / scale), -_QMAX, _QMAX).astype(jnp.int8)


def precompute_geometry_int(B0: Array, b: Array, *, W: int, w: int,
                            stride: int, block_d: int = 512
                            ) -> IntScoreGeometry:
    """Host-side, once per (model-geometry, frame-width).

    Builds on the float :func:`~repro.kernels.sliding_scores.
    precompute_geometry` (same slab/bias/rotation content), then expands
    the ``W`` shifts of every slab row into the int8 matmul operand.
    """
    geom = _ss.precompute_geometry(B0, b, W=W, w=w, stride=stride,
                                   block_d=block_d)
    n_dt, h, _ = geom.slabs.shape
    td = geom.block_d

    # slab_mat[dt, r*W + i, j] = slabs[dt, r, i + j]
    shift_idx = jnp.arange(W)[:, None] + jnp.arange(td)[None, :]  # (W, TD)
    expanded = geom.slabs[:, :, shift_idx]            # (n_dt, h, W, TD)
    scale = jnp.maximum(jnp.max(jnp.abs(geom.slabs)), 1e-12) / _QMAX
    slab_mat = _quantize_sym(expanded, scale).reshape(n_dt, h * W, td)

    # win_mask[kx, i] = [kx*stride <= i < kx*stride + w]
    mx = (W - w) // stride + 1
    i = jnp.arange(W)[None, :]
    kx = jnp.arange(mx)[:, None] * stride
    win_mask = ((i >= kx) & (i < kx + w)).astype(jnp.int8)  # (mx, W)

    return IntScoreGeometry(slab_mat=slab_mat, win_mask=win_mask,
                            bias_t=geom.bias_t, idx=geom.idx,
                            slab_scale=scale.astype(jnp.float32),
                            block_d=td, w=w, stride=stride)


def _quantize_class(c: Array) -> tuple[Array, Array]:
    """Per-class int8 quantization: ``(codes (D,) int8, ||codes||_2 f32)``.

    The scale is *not* returned — it cancels in the cosine epilogue.
    """
    scale = jnp.maximum(jnp.max(jnp.abs(c)), 1e-12) / _QMAX
    q = _quantize_sym(c, scale)
    return q, jnp.linalg.norm(q.astype(jnp.float32))


@jax.jit
def retile_classes_int(geom: IntScoreGeometry, class_hvs: Array
                       ) -> IntScoreTiles:
    """Device-side classifier (re-)tiling: ``(2, D)`` -> int8 tiles.

    One gather + int8 quantize per class — the entire cost of installing
    an updated classifier into the int scoring kernel (the online-learning
    hot path never re-runs :func:`precompute_geometry_int`).
    """
    qpos, npos = _quantize_class(class_hvs[1].astype(jnp.float32))
    qneg, nneg = _quantize_class(class_hvs[0].astype(jnp.float32))
    return IntScoreTiles(geom=geom, cpos_t=qpos[geom.idx],
                         cneg_t=qneg[geom.idx],
                         cpos_norm=npos, cneg_norm=nneg)


@jax.jit
def retile_classes_int_fleet(geom: IntScoreGeometry, class_hvs: Array
                             ) -> IntScoreTiles:
    """Per-stream classifier tiling: ``(S, 2, D)`` -> stacked int8 tiles."""
    def one(chvs):
        qpos, npos = _quantize_class(chvs[1].astype(jnp.float32))
        qneg, nneg = _quantize_class(chvs[0].astype(jnp.float32))
        return qpos[geom.idx], qneg[geom.idx], npos, nneg

    cpos_t, cneg_t, npos, nneg = jax.vmap(one)(class_hvs)
    return IntScoreTiles(geom=geom, cpos_t=cpos_t, cneg_t=cneg_t,
                         cpos_norm=npos, cneg_norm=nneg)


def precompute_tiles_int(B0: Array, b: Array, class_hvs: Array, *, W: int,
                         w: int, stride: int, block_d: int = 512
                         ) -> IntScoreTiles:
    """Host-side all-in-one: geometry + int8 class tiles."""
    geom = precompute_geometry_int(B0, b, W=W, w=w, stride=stride,
                                   block_d=block_d)
    return retile_classes_int(geom, class_hvs)


# ---------------------------------------------------------------------------
# Window norms from raw codes (exact int32 summed-area table)
# ---------------------------------------------------------------------------

def window_sumsq_codes(codes: Array, h: int, w: int, stride: int) -> Array:
    """(my, mx) *exact* int32 sliding-window sums of squared ADC codes."""
    H, W = codes.shape
    my = (H - h) // stride + 1
    mx = (W - w) // stride + 1
    c = codes.astype(jnp.int32)
    sq = jnp.cumsum(jnp.cumsum(c * c, axis=0), axis=1)
    sq = jnp.pad(sq, ((1, 0), (1, 0)))
    ky = jnp.arange(my) * stride
    kx = jnp.arange(mx) * stride
    return (sq[ky[:, None] + h, kx[None, :] + w]
            - sq[ky[:, None] + h, kx[None, :]]
            - sq[ky[:, None], kx[None, :] + w]
            + sq[ky[:, None], kx[None, :]])


def window_norms_codes_batch(codes: Array, h: int, w: int,
                             stride: int) -> Array:
    """(N, my, mx) L2 norms of sliding code windows (float only at sqrt)."""
    ss = jax.vmap(lambda c: window_sumsq_codes(c, h, w, stride))(codes)
    return jnp.sqrt(ss.astype(jnp.float32))


# ---------------------------------------------------------------------------
# The kernel
# ---------------------------------------------------------------------------

def _int_window_acc(block, slab_mat, win_mask, *, h: int, W: int,
                    td: int) -> Array:
    """Shared int32 projection core: ``(h, W) codes -> (mx, TD)`` sums.

    The paper's computation reuse with zero scalar loops: the ``h``
    elementwise rolled products against the pre-shifted int8 slabs fold
    into the per-column rolled sums ``G (W, TD)`` — each code multiplied
    once per base row, never materializing ``(h, W, TD)`` — then ONE
    small integer matmul against the window indicator aggregates every
    fragment. Exact int32 arithmetic throughout.
    """
    slab3 = slab_mat.reshape(h, W, td)                    # int8 (lazy)
    codes = block.astype(jnp.int32)
    g = codes[0][:, None] * slab3[0]                      # (W, TD) int32
    for r in range(1, h):
        g = g + codes[r][:, None] * slab3[r]
    return jax.lax.dot_general(
        win_mask.astype(jnp.int32), g, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)                 # (mx, TD)


def _score_kernel_int(codes_ref, slab_ref, mask_ref, bias_ref, cpos_ref,
                      cneg_ref, norm_ref, dpos_ref, dneg_ref, qq_ref, *,
                      h: int, stride: int, w: int, W: int, mx: int,
                      td: int, nonlinearity: NonLin):
    ky = pl.program_id(1)
    block = codes_ref[0, pl.ds(ky * stride, h), :]        # (h, W) codes
    acc = _int_window_acc(block, slab_ref[0], mask_ref[...],
                          h=h, W=W, td=td)                # (mx, TD) int32

    # float epilogue: normalization (slab scale folded into norm_ref by the
    # caller), nonlinearity, classifier dots (class scale cancels in cosine)
    # the ONE nonlinearity definition (repro.core.encoding), shared with
    # the float kernel and the jnp oracle — plain jnp ops, pallas-safe
    norms = norm_ref[0].astype(jnp.float32)               # (1, mx)
    s_n = acc.astype(jnp.float32) / norms[0][:, None]
    phi = apply_nonlinearity(s_n, bias_ref[0], nonlinearity)  # (mx, TD)
    dpos = jnp.sum(phi * cpos_ref[0].astype(jnp.float32),
                   axis=1)[None, None, :]                 # (1, 1, mx)
    dneg = jnp.sum(phi * cneg_ref[0].astype(jnp.float32),
                   axis=1)[None, None, :]
    qq = jnp.sum(phi * phi, axis=1)[None, None, :]

    @pl.when(pl.program_id(2) == 0)
    def _init():
        dpos_ref[...] = jnp.zeros_like(dpos_ref)
        dneg_ref[...] = jnp.zeros_like(dneg_ref)
        qq_ref[...] = jnp.zeros_like(qq_ref)

    dpos_ref[...] += dpos
    dneg_ref[...] += dneg
    qq_ref[...] += qq


def _cosine_epilogue(dpos, dneg, qq, tiles, per_stream: bool, C: int):
    qn = jnp.maximum(jnp.sqrt(qq), 1e-9)
    if per_stream:
        rep = lambda v: jnp.repeat(v, C)[:, None, None]   # (N, 1, 1)
        return (dpos / (qn * jnp.maximum(rep(tiles.cpos_norm), 1e-9))
                - dneg / (qn * jnp.maximum(rep(tiles.cneg_norm), 1e-9)))
    return (dpos / (qn * jnp.maximum(tiles.cpos_norm, 1e-9))
            - dneg / (qn * jnp.maximum(tiles.cneg_norm, 1e-9)))


@functools.partial(jax.jit, static_argnames=("h", "w", "stride",
                                             "nonlinearity", "interpret",
                                             "frames_per_stream"))
def fragment_scores_batch_int(codes: Array, tiles: IntScoreTiles, *, h: int,
                              w: int, stride: int,
                              nonlinearity: NonLin = "rff",
                              interpret: bool = False,
                              frames_per_stream: int | None = None
                              ) -> Array:
    """(N, H, W) integer ADC codes -> (N, my, mx) score maps, ONE launch.

    The fused encode->score entry point of the int datapath: raw codes in,
    float score maps out — no float frame is ever materialized. Grid and
    BlockSpec layout mirror the float :func:`~repro.kernels.
    sliding_scores.fragment_scores_batch`, including the per-stream
    class-tile indexing (``frames_per_stream``) used by adapting fleets.
    """
    if not jnp.issubdtype(codes.dtype, jnp.integer):
        raise TypeError(f"int datapath consumes integer ADC codes, got "
                        f"{codes.dtype} — use adc.quantize_codes/pack_codes"
                        f" (or precision='float32')")
    N, H, W = codes.shape
    my = (H - h) // stride + 1
    mx = (W - w) // stride + 1
    geom = tiles.geom
    n_dt, hw, td = geom.slab_mat.shape
    assert hw == h * W and td == geom.block_d, (geom.slab_mat.shape, h, W)
    assert geom.w == w and geom.stride == stride

    per_stream = tiles.cpos_t.ndim == 4
    if per_stream:
        if frames_per_stream is None:
            raise ValueError("per-stream class tiles need frames_per_stream")
        C = frames_per_stream
        S = tiles.cpos_t.shape[0]
        if S * C != N:
            raise ValueError(f"per-stream tiles: S={S} streams x "
                             f"C={C} frames != batch N={N}")
        cpos_t = tiles.cpos_t.reshape(S * n_dt, mx, td)
        cneg_t = tiles.cneg_t.reshape(S * n_dt, mx, td)
        class_spec = pl.BlockSpec(
            (1, mx, td), lambda n, i, j: ((n // C) * n_dt + j, 0, 0))
    else:
        C = 0
        cpos_t, cneg_t = tiles.cpos_t, tiles.cneg_t
        class_spec = pl.BlockSpec((1, mx, td), lambda n, i, j: (j, 0, 0))

    # LSB-free normalization with the slab scale folded in:
    #   s_n = (acc * slab_scale) / ||codes||  =  acc / (||codes|| / scale)
    norms = window_norms_codes_batch(codes, h, w, stride)     # (N, my, mx)
    norms = jnp.maximum(norms, 1e-8) / geom.slab_scale

    kern = functools.partial(_score_kernel_int, h=h, stride=stride, w=w,
                             W=W, mx=mx, td=td, nonlinearity=nonlinearity)

    dpos, dneg, qq = pl.pallas_call(
        kern,
        grid=(N, my, n_dt),
        in_specs=[
            pl.BlockSpec((1, H, W), lambda n, i, j: (n, 0, 0)),    # codes
            pl.BlockSpec((1, hw, td), lambda n, i, j: (j, 0, 0)),  # slabs
            pl.BlockSpec((mx, W), lambda n, i, j: (0, 0)),         # mask
            pl.BlockSpec((1, mx, td), lambda n, i, j: (j, 0, 0)),  # bias
            class_spec,                                            # cpos
            class_spec,                                            # cneg
            pl.BlockSpec((1, 1, mx), lambda n, i, j: (n, i, 0)),   # norms
        ],
        out_specs=[
            pl.BlockSpec((1, 1, mx), lambda n, i, j: (n, i, 0)),
            pl.BlockSpec((1, 1, mx), lambda n, i, j: (n, i, 0)),
            pl.BlockSpec((1, 1, mx), lambda n, i, j: (n, i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((N, my, mx), jnp.float32)] * 3,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(codes, geom.slab_mat, geom.win_mask, geom.bias_t, cpos_t, cneg_t,
      norms)

    return _cosine_epilogue(dpos, dneg, qq, tiles, per_stream, C)


# ---------------------------------------------------------------------------
# Pure-jnp twin (the oracle AND the jnp-backend int path)
# ---------------------------------------------------------------------------

def _int_scores_shared(codes, geom: IntScoreGeometry, cpos_t, cneg_t, *,
                       h: int, w: int, stride: int,
                       nonlinearity: NonLin):
    """Shared-classifier jnp int path -> ``(dpos, dneg, qq) (N, my, mx)``.

    Same quantized operands and the same int32 accumulation as the kernel;
    only the (float) epilogue can differ by rounding. Materializes
    ``(N, my, mx, D)`` projections — the validation/CPU path, not the
    deployment one.
    """
    N, H, W = codes.shape
    my = (H - h) // stride + 1
    mx = (W - w) // stride + 1
    n_dt = geom.slab_mat.shape[0]
    td = geom.block_d
    ky = jnp.arange(my) * stride
    blocks = codes[:, ky[:, None] + jnp.arange(h)[None, :], :]  # (N,my,h,W)

    # same reuse core as the kernel, vmapped over (frame, row-band, D-tile)
    acc = jax.vmap(jax.vmap(lambda blk: jax.vmap(
        lambda slab: _int_window_acc(blk, slab, geom.win_mask, h=h, W=W,
                                     td=td))(geom.slab_mat)))(
                                         blocks)   # (N, my, n_dt, mx, TD)
    acc = acc.transpose(0, 1, 3, 2, 4)             # (N, my, mx, n_dt, TD)
    norms = window_norms_codes_batch(codes, h, w, stride)
    norms = jnp.maximum(norms, 1e-8) / geom.slab_scale
    s_n = acc.astype(jnp.float32) / norms[..., None, None]
    bias = geom.bias_t.transpose(1, 0, 2)[None, None]     # (1,1,mx,n_dt,TD)
    phi = apply_nonlinearity(s_n, bias, nonlinearity)
    cpos = cpos_t.transpose(1, 0, 2)[None, None].astype(jnp.float32)
    cneg = cneg_t.transpose(1, 0, 2)[None, None].astype(jnp.float32)
    dpos = jnp.sum(phi * cpos, axis=(3, 4))
    dneg = jnp.sum(phi * cneg, axis=(3, 4))
    qq = jnp.sum(phi * phi, axis=(3, 4))
    return dpos, dneg, qq


@functools.partial(jax.jit, static_argnames=("h", "w", "stride",
                                             "nonlinearity",
                                             "frames_per_stream"))
def fragment_scores_batch_int_ref(codes: Array, tiles: IntScoreTiles, *,
                                  h: int, w: int, stride: int,
                                  nonlinearity: NonLin = "rff",
                                  frames_per_stream: int | None = None
                                  ) -> Array:
    """Pure-jnp twin of :func:`fragment_scores_batch_int`.

    Identical quantized operands and int32 accumulation; serves as the
    parity oracle for the kernel and as the ``backend="jnp"`` execution of
    ``precision="int8"`` in the streaming runtimes.
    """
    if not jnp.issubdtype(codes.dtype, jnp.integer):
        raise TypeError(f"int datapath consumes integer ADC codes, got "
                        f"{codes.dtype}")
    geom = tiles.geom
    per_stream = tiles.cpos_t.ndim == 4
    if per_stream:
        if frames_per_stream is None:
            raise ValueError("per-stream class tiles need frames_per_stream")
        N, H, W = codes.shape
        S = tiles.cpos_t.shape[0]
        C = frames_per_stream
        if S * C != N:
            raise ValueError(f"per-stream tiles: S={S} streams x "
                             f"C={C} frames != batch N={N}")
        dpos, dneg, qq = jax.vmap(
            lambda cs, cp, cn: _int_scores_shared(
                cs, geom, cp, cn, h=h, w=w, stride=stride,
                nonlinearity=nonlinearity))(
                    codes.reshape(S, C, H, W), tiles.cpos_t, tiles.cneg_t)
        my_mx = dpos.shape[2:]
        dpos, dneg, qq = (x.reshape(N, *my_mx) for x in (dpos, dneg, qq))
    else:
        dpos, dneg, qq = _int_scores_shared(
            codes, geom, tiles.cpos_t, tiles.cneg_t, h=h, w=w,
            stride=stride, nonlinearity=nonlinearity)
    return _cosine_epilogue(dpos, dneg, qq, tiles, per_stream,
                            frames_per_stream or 0)
