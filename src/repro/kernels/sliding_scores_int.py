"""Pallas TPU kernel: low-precision integer HyperSense frame scoring.

The float path (:mod:`repro.kernels.sliding_scores`) consumes the ADC's
*reconstruction* ``codes * LSB`` — every kernel still does float32 work, so
the "energy-efficient low-precision ADC" of the paper buys nothing past the
converter. This module is the paper's actual FPGA datapath (§IV) brought to
the kernel level, following the SCM always-on HDC accelerator (Eggimann et
al., 2021) and the low-bitwidth hypervector-design line (Basaklar et al.,
2021): the raw integer **ADC codes** flow into the scoring kernel untouched,
every fragment projection accumulates in **int32**, and floats appear only
in the tiny similarity/normalization epilogue.

Why the integer path is *structurally* different (not just a dtype swap):

* **In-kernel rolling shifts over base slabs.** The float kernel walks each
  frame row with an ``O(h*(W+mx))``-step scalar prefix-sum loop (the
  systolic FIFO in loop form). The int kernel instead stores only the
  int8-quantized *base* slabs — the same circularly padded
  ``(n_dt, h, TD + W - 1)`` rows the float geometry keeps — and
  materializes every shifted view **inside** the grid step: one int32 MXU
  matmul ``codesᵀ (W, h) @ slabs (h, TD + W - 1)`` folds the ``h`` reused
  rolled products per column (summing over base rows *before* the shift is
  valid because shift extraction is linear), then ``log2(W)`` vectorized
  roll+select passes align row ``i`` by ``i`` so the per-column rolled
  sums ``G (W, TD)`` fall out as diagonals, and the fragment windows are
  ONE small integer matmul ``win_mask (mx, W) @ G``. The live set is
  ``O(window)`` in ``W`` — base slabs + a bounded per-chunk scratch —
  never the old all-``W`` pre-expanded ``(h*W, TD)`` operand whose VMEM
  footprint grew linearly in ``W`` and overran the budget exactly at
  deployment scale (h=16, W=4096, TD=512 -> 32 MB/tile; the new layout is
  ~100 KB of slabs). :func:`assert_int_datapath_fits` enforces the bound,
  and ``tests/test_workingset.py`` pins the regression: the expanded
  layout's byte count sits *over* the budget at large ``W`` while this
  layout stays under it.
* **Sub-byte precisions.** ``packed=True`` consumes the int4 wire format
  (two 4-bit codes per byte, :func:`repro.sensing.adc.pack_nibbles`) and
  unpacks nibbles in-kernel — halved code traffic, int32 accumulation
  unchanged. ``mode="binary"`` geometry sign-quantizes slabs to ±1 (scale
  = mean |slab|, the L2-optimal 1-bit approximation) and class HVs to ±1
  (norm ``sqrt(D)``): the XOR-popcount similarity of binarized HDC
  expressed as the same int8 matmuls, enabling reduced-D operating points
  (D-vs-AUC curve reported by ``benchmarks/int_datapath.py``).
* **LSB cancellation.** The fragment projection is normalized by the
  window's L2 norm, so the ADC step size cancels:
  ``(LSB * acc) / (LSB * ||codes||) = acc / ||codes||``. Scores from the
  int path live on the same scale as the float path — ``t_score``
  thresholds and ROC sweeps transfer unchanged.
* **Scale cancellation in the cosine epilogue.** Class hypervectors are
  stored as int8 with a per-class scale; because the final score is a
  *cosine*, the class scale cancels against the class norm — the epilogue
  only ever needs the L2 norm of the *quantized* class vector. The only
  approximation the int path introduces is int8 (or ±1) rounding of the
  slabs and class tiles (AUC gap bounded in the benchmark ``--check``).

Accumulator discipline (all bounds checked by
:func:`assert_int_datapath_fits` + hypothesis property tests):

* window sum-of-squares: exact int32 summed-area table of ``codes**2``
  (``<= H*W*(2^bits-1)^2``) — the float SAT would lose exactness past
  2^24;
* fragment projection: every partial sum — matmul entries, rolled
  diagonals, window aggregates — is ``<= h*w*(2^bits-1)*127`` in
  magnitude: int32 with orders of magnitude of headroom at 8-bit codes
  and paper frame/window sizes.

Integer accumulation is associative, so the int path is **bitwise
deterministic across runs** regardless of scheduling — asserted in CI.
(It is also why this rewrite is score-for-score bit-identical to the old
expanded-slab layout: same quantized int8 values, same exact integer sums,
same float epilogue — the golden int8 fixtures did not move.)

Precompute mirrors the float path's mutability split: class-independent
:class:`IntScoreGeometry` (quantized base slabs, window mask, rotation
gather) vs the jitted device-side :func:`retile_classes_int` /
:func:`retile_classes_int_fleet` (classifier install = gather + int8
quantize per class), so online adaptation never re-runs the host
precompute mid-stream.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.encoding import NonLin, apply_nonlinearity
from repro.kernels import sliding_scores as _ss
from repro.kernels.compat import CompilerParams

Array = jax.Array

INT32_MAX = 2**31 - 1

#: int8 symmetric quantization range (saturating at +-127 keeps the
#: representation sign-symmetric; -128 is never produced)
_QMAX = 127

#: static W-axis chunk of the in-kernel rolling-shift pass: bounds the
#: int32 scratch at O(_W_CHUNK * (TD + _W_CHUNK)) independent of W
_W_CHUNK = 128

#: per-grid-step VMEM working-set budget the int geometry must fit (half a
#: typical 16 MB TPU core VMEM, leaving room for double buffering). The old
#: expanded-slab layout exceeds this at large W; the rolling-shift layout
#: stays under it — see int_datapath_bounds / tests/test_workingset.py.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024

#: geometry quantization modes: "int8" (symmetric 8-bit slabs) or "binary"
#: (sign-quantized ±1 slabs and class HVs)
INT_MODES = ("int8", "binary")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class IntScoreGeometry:
    """Class-independent int-kernel precompute (see module docstring).

    ``slabs_q`` is the quantized **base** slab — the same circularly padded
    ``(n_dt, h, TD + W - 1)`` layout as the float
    :class:`~repro.kernels.sliding_scores.ScoreGeometry`, int8-quantized
    with the shared ``slab_scale`` (``mode="int8"``) or sign-quantized to
    ±1 with ``slab_scale = mean |slab|`` (``mode="binary"``). Every
    shifted view ``slabs_q[dt, r, i + j]`` the projection needs is built
    *inside* the kernel by rolling — nothing grows with ``W`` beyond the
    ``W - 1`` halo columns. ``win_mask[kx, i] = [kx*stride <= i <
    kx*stride + w]`` aggregates the rolled sums into fragment windows as
    one small matmul.
    """
    slabs_q: Array     # (n_dt, h, TD + W - 1) int8 quantized base slabs
    win_mask: Array    # (mx, W) int8 window-membership indicator
    bias_t: Array      # (n_dt, mx, TD) f32 pre-rotated RFF bias tiles
    idx: Array         # (n_dt, mx, TD) i32 rotation gather into a (D,) vec
    slab_scale: Array  # () f32: slab ~= slabs_q * slab_scale
    block_d: int = dataclasses.field(metadata={"static": True})
    w: int = dataclasses.field(metadata={"static": True})
    stride: int = dataclasses.field(metadata={"static": True})
    mode: str = dataclasses.field(default="int8", metadata={"static": True})


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class IntScoreTiles:
    """Geometry + quantized class tiles: the int kernel's input bundle.

    ``cpos_t``/``cneg_t`` are ``(n_dt, mx, TD)`` int8 for a shared
    classifier or ``(S, n_dt, mx, TD)`` (with ``(S,)`` norms) per-stream;
    ±1-valued under ``geom.mode == "binary"``. ``c*_norm`` is the L2 norm
    of the *quantized* class vector — the per-class quantization scale
    cancels in the cosine epilogue, so it is never stored.
    """
    geom: IntScoreGeometry
    cpos_t: Array     # ([S,] n_dt, mx, TD) int8 positive class tiles
    cneg_t: Array     # ([S,] n_dt, mx, TD) int8 negative class tiles
    cpos_norm: Array  # ([S]) f32 L2 of the quantized positive class vector
    cneg_norm: Array  # ([S]) f32 L2 of the quantized negative class vector


# ---------------------------------------------------------------------------
# int4 wire format (two 4-bit codes per byte along the row axis)
# ---------------------------------------------------------------------------

def _unpack_nibbles_i32(packed: Array) -> Array:
    """``(..., W/2)`` packed bytes -> ``(..., W)`` int32 4-bit codes.

    The kernel-side twin of :func:`repro.sensing.adc.unpack_nibbles`
    (low nibble first); parity between the two is pinned in
    ``tests/test_adc_quantize.py``.
    """
    p = packed.astype(jnp.int32)
    lo = jnp.bitwise_and(p, 0xF)
    hi = jnp.right_shift(p, 4)
    return jnp.concatenate([lo[..., None], hi[..., None]],
                           axis=-1).reshape(*p.shape[:-1], -1)


# ---------------------------------------------------------------------------
# Bounds: the no-overflow AND fits-VMEM contract of the int datapath
# ---------------------------------------------------------------------------

def int_datapath_bounds(adc_bits: int, H: int, W: int, h: int, w: int,
                        stride: int = 1, block_d: int = 512,
                        packed: bool = False) -> dict:
    """Worst-case int32 accumulators + VMEM working set of the datapath.

    Accumulator magnitudes (exactness contract):

    * ``sumsq`` — the summed-area table of squared codes over a full
      frame (the window-norm pass);
    * ``acc``  — one fragment projection: ``h*w`` products of a max code
      with a max int8 slab entry.

    Both must stay below ``INT32_MAX`` for the path to be exact.

    VMEM working set per grid step (scaling contract — the regression
    guard for the expanded-slab blow-up this layout replaced):

    * ``vmem_bytes`` — the rolling-shift layout: codes block + base slabs
      ``h * (TD + W - 1)`` + the bounded ``O(_W_CHUNK * TD)`` roll
      scratch + mask/bias/class/acc tiles. O(window) in ``W``.
    * ``vmem_expanded_bytes`` — what the old all-``W`` pre-expanded
      ``(h*W, TD)`` slab operand would have needed at the same config:
      linear in ``W``.
    * ``vmem_limit_bytes`` — the :data:`VMEM_BUDGET_BYTES` budget
      ``vmem_bytes`` must not exceed.

    ``stride``/``block_d`` default to the most conservative values
    (``stride=1`` maximizes the window count ``mx``); pass the real ones
    for a tight estimate. ``packed=True`` halves the code-block bytes
    (the int4 wire format).

    ``fits`` is the conjunction: accumulators exact AND working set under
    budget.
    """
    cmax = (1 << adc_bits) - 1
    sumsq = H * W * cmax * cmax
    acc = h * w * cmax * _QMAX

    td = block_d
    mx = max((W - w) // stride + 1, 1)
    wc = min(W, _W_CHUNK)
    codes_bytes = H * (W // 2 if packed else W)           # uint8 wire codes
    slab_bytes = h * (td + W - 1)                         # int8 base slabs
    scratch_bytes = 3 * wc * (td + wc - 1) * 4            # P + roll + select
    common = (codes_bytes + mx * W                        # codes + win_mask
              + mx * td * 4                               # f32 bias tile
              + 2 * mx * td                               # int8 class tiles
              + mx * td * 4)                              # int32 acc
    vmem = common + slab_bytes + scratch_bytes
    vmem_expanded = common + h * W * td                   # old (h*W, TD) slab

    return {"sumsq": sumsq, "acc": acc, "int32_max": INT32_MAX,
            "vmem_bytes": vmem, "vmem_expanded_bytes": vmem_expanded,
            "vmem_limit_bytes": VMEM_BUDGET_BYTES,
            "fits": (max(sumsq, acc) <= INT32_MAX
                     and vmem <= VMEM_BUDGET_BYTES)}


def assert_int_datapath_fits(adc_bits: int, H: int, W: int, h: int,
                             w: int, stride: int = 1, block_d: int = 512,
                             packed: bool = False) -> None:
    """Raise unless the int datapath is exact AND fits the VMEM budget.

    Two distinct failure modes, two distinct errors:

    * int32 accumulator overflow (too many ADC bits for the window size)
      — exactness would silently break;
    * per-grid-step working set over :data:`VMEM_BUDGET_BYTES` — the
      bound the old expanded-slab layout violated at large ``W`` (it
      stored all ``W`` shifts as an ``(h*W, TD)`` operand); the
      rolling-shift layout keeps the live set O(window), so tripping this
      now means a genuinely oversized (window, tile) configuration.
    """
    b = int_datapath_bounds(adc_bits, H, W, h, w, stride=stride,
                            block_d=block_d, packed=packed)
    if max(b["sumsq"], b["acc"]) > INT32_MAX:
        raise ValueError(
            f"int datapath would overflow int32 at adc_bits={adc_bits}, "
            f"frame {H}x{W}, window {h}x{w}: worst-case accumulators "
            f"sumsq={b['sumsq']}, acc={b['acc']} exceed {INT32_MAX}; "
            f"use fewer ADC bits / smaller frames or precision='float32'")
    if b["vmem_bytes"] > b["vmem_limit_bytes"]:
        raise ValueError(
            f"int datapath working set {b['vmem_bytes']} B exceeds the "
            f"{b['vmem_limit_bytes']} B VMEM budget at frame {H}x{W}, "
            f"window {h}x{w}, block_d={block_d}; shrink block_d or the "
            f"frame width")


# ---------------------------------------------------------------------------
# Precompute: geometry (host, per model-geometry) + class tiles (device)
# ---------------------------------------------------------------------------

def _quantize_sym(x: Array, scale: Array) -> Array:
    """Symmetric int8 quantization at a given positive scale."""
    return jnp.clip(jnp.round(x / scale), -_QMAX, _QMAX).astype(jnp.int8)


def precompute_geometry_int(B0: Array, b: Array, *, W: int, w: int,
                            stride: int, block_d: int = 512,
                            mode: str = "int8") -> IntScoreGeometry:
    """Host-side, once per (model-geometry, frame-width).

    Builds on the float :func:`~repro.kernels.sliding_scores.
    precompute_geometry` (same slab/bias/rotation content), then quantizes
    the base slabs *in place* — int8 at the shared max-abs scale
    (``mode="int8"``), or sign-quantized ±1 at ``scale = mean |slab|``
    (``mode="binary"``, the L2-optimal 1-bit scale a la XNOR-Net — it
    keeps the normalized projection on the float path's scale, which the
    RFF nonlinearity is sensitive to). No shift is ever materialized here:
    the kernel rolls them out per grid step.
    """
    if mode not in INT_MODES:
        raise ValueError(f"mode must be one of {INT_MODES}, got {mode!r}")
    geom = _ss.precompute_geometry(B0, b, W=W, w=w, stride=stride,
                                   block_d=block_d)
    if mode == "binary":
        scale = jnp.maximum(jnp.mean(jnp.abs(geom.slabs)), 1e-12)
        slabs_q = jnp.where(geom.slabs >= 0, 1, -1).astype(jnp.int8)
    else:
        scale = jnp.maximum(jnp.max(jnp.abs(geom.slabs)), 1e-12) / _QMAX
        slabs_q = _quantize_sym(geom.slabs, scale)

    # win_mask[kx, i] = [kx*stride <= i < kx*stride + w]
    mx = (W - w) // stride + 1
    i = jnp.arange(W)[None, :]
    kx = jnp.arange(mx)[:, None] * stride
    win_mask = ((i >= kx) & (i < kx + w)).astype(jnp.int8)  # (mx, W)

    return IntScoreGeometry(slabs_q=slabs_q, win_mask=win_mask,
                            bias_t=geom.bias_t, idx=geom.idx,
                            slab_scale=scale.astype(jnp.float32),
                            block_d=geom.block_d, w=w, stride=stride,
                            mode=mode)


def _quantize_class(c: Array, mode: str = "int8") -> tuple[Array, Array]:
    """Per-class quantization: ``(codes (D,) int8, ||codes||_2 f32)``.

    ``mode="int8"``: symmetric int8; ``mode="binary"``: sign-quantized ±1
    (norm ``sqrt(D)``). The scale is *not* returned — it cancels in the
    cosine epilogue either way.
    """
    if mode == "binary":
        q = jnp.where(c >= 0, 1, -1).astype(jnp.int8)
    else:
        scale = jnp.maximum(jnp.max(jnp.abs(c)), 1e-12) / _QMAX
        q = _quantize_sym(c, scale)
    return q, jnp.linalg.norm(q.astype(jnp.float32))


@jax.jit
def retile_classes_int(geom: IntScoreGeometry, class_hvs: Array
                       ) -> IntScoreTiles:
    """Device-side classifier (re-)tiling: ``(2, D)`` -> int8 tiles.

    One gather + quantize per class (int8 or ±1, per ``geom.mode``) — the
    entire cost of installing an updated classifier into the int scoring
    kernel (the online-learning hot path never re-runs
    :func:`precompute_geometry_int`).
    """
    qpos, npos = _quantize_class(class_hvs[1].astype(jnp.float32),
                                 geom.mode)
    qneg, nneg = _quantize_class(class_hvs[0].astype(jnp.float32),
                                 geom.mode)
    return IntScoreTiles(geom=geom, cpos_t=qpos[geom.idx],
                         cneg_t=qneg[geom.idx],
                         cpos_norm=npos, cneg_norm=nneg)


@jax.jit
def retile_classes_int_fleet(geom: IntScoreGeometry, class_hvs: Array
                             ) -> IntScoreTiles:
    """Per-stream classifier tiling: ``(S, 2, D)`` -> stacked int8 tiles."""
    def one(chvs):
        qpos, npos = _quantize_class(chvs[1].astype(jnp.float32), geom.mode)
        qneg, nneg = _quantize_class(chvs[0].astype(jnp.float32), geom.mode)
        return qpos[geom.idx], qneg[geom.idx], npos, nneg

    cpos_t, cneg_t, npos, nneg = jax.vmap(one)(class_hvs)
    return IntScoreTiles(geom=geom, cpos_t=cpos_t, cneg_t=cneg_t,
                         cpos_norm=npos, cneg_norm=nneg)


def precompute_tiles_int(B0: Array, b: Array, class_hvs: Array, *, W: int,
                         w: int, stride: int, block_d: int = 512,
                         mode: str = "int8") -> IntScoreTiles:
    """Host-side all-in-one: geometry + quantized class tiles."""
    geom = precompute_geometry_int(B0, b, W=W, w=w, stride=stride,
                                   block_d=block_d, mode=mode)
    return retile_classes_int(geom, class_hvs)


# ---------------------------------------------------------------------------
# Window norms from raw codes (exact int32 summed-area table)
# ---------------------------------------------------------------------------

def window_sumsq_codes(codes: Array, h: int, w: int, stride: int) -> Array:
    """(my, mx) *exact* int32 sliding-window sums of squared ADC codes."""
    H, W = codes.shape
    my = (H - h) // stride + 1
    mx = (W - w) // stride + 1
    c = codes.astype(jnp.int32)
    sq = jnp.cumsum(jnp.cumsum(c * c, axis=0), axis=1)
    sq = jnp.pad(sq, ((1, 0), (1, 0)))
    ky = jnp.arange(my) * stride
    kx = jnp.arange(mx) * stride
    return (sq[ky[:, None] + h, kx[None, :] + w]
            - sq[ky[:, None] + h, kx[None, :]]
            - sq[ky[:, None], kx[None, :] + w]
            + sq[ky[:, None], kx[None, :]])


def window_norms_codes_batch(codes: Array, h: int, w: int,
                             stride: int) -> Array:
    """(N, my, mx) L2 norms of sliding code windows (float only at sqrt)."""
    ss = jax.vmap(lambda c: window_sumsq_codes(c, h, w, stride))(codes)
    return jnp.sqrt(ss.astype(jnp.float32))


# ---------------------------------------------------------------------------
# The kernel
# ---------------------------------------------------------------------------

def _roll_diagonals(p: Array, rows: int, td: int) -> Array:
    """Extract ``g[l, j] = p[l, l + j]`` for ``j < td`` by rolling.

    ``log2(rows)`` vectorized roll+select passes align row ``l`` left by
    ``l`` (log-doubling over the bits of ``l``); composition of circular
    rolls is the circular roll of the sum, and ``l + j <= (rows - 1) +
    (td - 1) < p.shape[1]``, so no wrapped element is ever kept. Plain
    concatenate/where — TPU- and interpret-mode-safe, no scalar loops.
    """
    width = p.shape[1]
    row_iota = jax.lax.broadcasted_iota(jnp.int32, (rows, width), 0)
    shift = 1
    while shift < rows:
        rolled = jnp.concatenate([p[:, shift:], p[:, :shift]], axis=1)
        p = jnp.where((row_iota & shift) != 0, rolled, p)
        shift *= 2
    return p[:, :td]


def _int_window_acc(block, slabs_q, win_mask, *, h: int, W: int,
                    td: int) -> Array:
    """Shared int32 projection core: ``(h, W) codes -> (mx, TD)`` sums.

    The paper's computation reuse with an O(window) live set: summing over
    base rows commutes with shift extraction, so ONE int32 matmul
    ``codesᵀ @ slabs_q`` produces ``P[i, p] = Σ_r codes[r, i] *
    slabs_q[r, p]``; rolling row ``i`` left by ``i``
    (:func:`_roll_diagonals`) yields the per-column rolled sums
    ``G[i, j] = P[i, i + j]`` — each code multiplied once per base row,
    never materializing ``(h, W, TD)`` or the old pre-expanded
    ``(h*W, TD)`` slab — then ONE small integer matmul against the window
    indicator aggregates every fragment. The ``W`` axis is chunked
    statically (:data:`_W_CHUNK`) so the int32 scratch stays bounded
    regardless of frame width. Exact int32 arithmetic throughout, in a
    fixed association order (bitwise deterministic, and bit-identical to
    the retired expanded-slab accumulation).
    """
    codes = block.astype(jnp.int32)                       # (h, W)
    slabs = slabs_q.astype(jnp.int32)                     # (h, TD + W - 1)
    mask = win_mask.astype(jnp.int32)                     # (mx, W)
    acc = None
    for c0 in range(0, W, _W_CHUNK):
        cw = min(_W_CHUNK, W - c0)
        # P[l, p] = sum_r codes[r, c0 + l] * slabs[r, c0 + p]
        p = jax.lax.dot_general(
            codes[:, c0:c0 + cw], slabs[:, c0:c0 + td + cw - 1],
            (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)             # (cw, td+cw-1)
        g = _roll_diagonals(p, cw, td)                    # (cw, td)
        part = jax.lax.dot_general(
            mask[:, c0:c0 + cw], g, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)             # (mx, td)
        acc = part if acc is None else acc + part
    return acc


def _score_kernel_int(codes_ref, slab_ref, mask_ref, bias_ref, cpos_ref,
                      cneg_ref, norm_ref, dpos_ref, dneg_ref, qq_ref, *,
                      h: int, stride: int, w: int, W: int, mx: int,
                      td: int, nonlinearity: NonLin, packed: bool):
    ky = pl.program_id(1)
    block = codes_ref[0, pl.ds(ky * stride, h), :]        # (h, W[/2]) codes
    if packed:
        block = _unpack_nibbles_i32(block)                # (h, W) 4-bit
    acc = _int_window_acc(block, slab_ref[0], mask_ref[...],
                          h=h, W=W, td=td)                # (mx, TD) int32

    # float epilogue: normalization (slab scale folded into norm_ref by the
    # caller), nonlinearity, classifier dots (class scale cancels in cosine)
    # the ONE nonlinearity definition (repro.core.encoding), shared with
    # the float kernel and the jnp oracle — plain jnp ops, pallas-safe
    norms = norm_ref[0].astype(jnp.float32)               # (1, mx)
    s_n = acc.astype(jnp.float32) / norms[0][:, None]
    phi = apply_nonlinearity(s_n, bias_ref[0], nonlinearity)  # (mx, TD)
    # Per-tile partials, folded OUTSIDE the kernel in fixed order (shared
    # _ordered_tile_fold with the float kernel) — the D-tile axis can then
    # shard over the "hyperdim" mesh axis with bitwise-identical scores.
    dpos_ref[...] = jnp.sum(phi * cpos_ref[0].astype(jnp.float32),
                            axis=1)[None, None, None, :]  # (1, 1, 1, mx)
    dneg_ref[...] = jnp.sum(phi * cneg_ref[0].astype(jnp.float32),
                            axis=1)[None, None, None, :]
    qq_ref[...] = jnp.sum(phi * phi, axis=1)[None, None, None, :]


def _cosine_epilogue(dpos, dneg, qq, tiles, per_stream: bool, C: int):
    qn = jnp.maximum(jnp.sqrt(qq), 1e-9)
    if per_stream:
        rep = lambda v: jnp.repeat(v, C)[:, None, None]   # (N, 1, 1)
        return (dpos / (qn * jnp.maximum(rep(tiles.cpos_norm), 1e-9))
                - dneg / (qn * jnp.maximum(rep(tiles.cneg_norm), 1e-9)))
    return (dpos / (qn * jnp.maximum(tiles.cpos_norm, 1e-9))
            - dneg / (qn * jnp.maximum(tiles.cneg_norm, 1e-9)))


def _check_codes_integer(codes: Array) -> None:
    if not jnp.issubdtype(codes.dtype, jnp.integer):
        raise TypeError(f"int datapath consumes integer ADC codes, got "
                        f"{codes.dtype} — use adc.quantize_codes/pack_codes"
                        f" (or precision='float32')")


@functools.partial(jax.jit, static_argnames=("h", "w", "stride",
                                             "nonlinearity", "interpret",
                                             "frames_per_stream", "packed",
                                             "hyperdim_axes"))
def fragment_scores_batch_int(codes: Array, tiles: IntScoreTiles, *, h: int,
                              w: int, stride: int,
                              nonlinearity: NonLin = "rff",
                              interpret: bool = False,
                              frames_per_stream: int | None = None,
                              packed: bool = False,
                              hyperdim_axes: tuple[str, ...] | None = None
                              ) -> Array:
    """(N, H, W) integer ADC codes -> (N, my, mx) score maps, ONE launch.

    The fused encode->score entry point of the int datapath: raw codes in,
    float score maps out — no float frame is ever materialized, and no
    shifted slab either (rolled out in-kernel, see :func:`_int_window_acc`).
    With ``packed=True`` the input is the int4 wire format ``(N, H, W/2)``
    (two codes per byte, low nibble first); nibbles are unpacked inside
    the kernel, so the HBM->VMEM code traffic is halved. Grid and
    BlockSpec layout mirror the float :func:`~repro.kernels.
    sliding_scores.fragment_scores_batch`, including the per-stream
    class-tile indexing (``frames_per_stream``) used by adapting fleets.

    Inside a ``shard_map`` that partitions the D-tile axis, pass the mesh
    axis names as ``hyperdim_axes``: each device scores its local slab /
    class-tile shard and the per-tile partials are all_gathered (tiled,
    order-preserving) before the fixed-order fold — bitwise-identical to
    the unsharded launch (see ``sliding_scores._ordered_tile_fold``).
    """
    _check_codes_integer(codes)
    N, H, Wc = codes.shape
    W = Wc * 2 if packed else Wc
    my = (H - h) // stride + 1
    mx = (W - w) // stride + 1
    geom = tiles.geom
    n_dt, gh, slab_len = geom.slabs_q.shape
    td = geom.block_d
    # repro-lint: disable=RA001 (td/geom.w/geom.stride are static aux fields of the geometry pytree — concrete at trace time)
    assert gh == h and slab_len == td + W - 1, (geom.slabs_q.shape, h, W)
    assert geom.win_mask.shape == (mx, W), (geom.win_mask.shape, mx, W)
    assert geom.w == w and geom.stride == stride  # repro-lint: disable=RA001 (same static aux fields)

    per_stream = tiles.cpos_t.ndim == 4
    if per_stream:
        if frames_per_stream is None:
            raise ValueError("per-stream class tiles need frames_per_stream")
        C = frames_per_stream
        S = tiles.cpos_t.shape[0]
        if S * C != N:
            raise ValueError(f"per-stream tiles: S={S} streams x "
                             f"C={C} frames != batch N={N}")
        cpos_t = tiles.cpos_t.reshape(S * n_dt, mx, td)
        cneg_t = tiles.cneg_t.reshape(S * n_dt, mx, td)
        class_spec = pl.BlockSpec(
            (1, mx, td), lambda n, i, j: ((n // C) * n_dt + j, 0, 0))
    else:
        C = 0
        cpos_t, cneg_t = tiles.cpos_t, tiles.cneg_t
        class_spec = pl.BlockSpec((1, mx, td), lambda n, i, j: (j, 0, 0))

    # LSB-free normalization with the slab scale folded in:
    #   s_n = (acc * slab_scale) / ||codes||  =  acc / (||codes|| / scale)
    full = _unpack_nibbles_i32(codes) if packed else codes
    norms = window_norms_codes_batch(full, h, w, stride)      # (N, my, mx)
    norms = jnp.maximum(norms, 1e-8) / geom.slab_scale

    kern = functools.partial(_score_kernel_int, h=h, stride=stride, w=w,
                             W=W, mx=mx, td=td, nonlinearity=nonlinearity,
                             packed=packed)

    dpos, dneg, qq = pl.pallas_call(
        kern,
        grid=(N, my, n_dt),
        in_specs=[
            pl.BlockSpec((1, H, Wc), lambda n, i, j: (n, 0, 0)),   # codes
            pl.BlockSpec((1, h, slab_len),
                         lambda n, i, j: (j, 0, 0)),               # slabs
            pl.BlockSpec((mx, W), lambda n, i, j: (0, 0)),         # mask
            pl.BlockSpec((1, mx, td), lambda n, i, j: (j, 0, 0)),  # bias
            class_spec,                                            # cpos
            class_spec,                                            # cneg
            pl.BlockSpec((1, 1, mx), lambda n, i, j: (n, i, 0)),   # norms
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, mx), lambda n, i, j: (j, n, i, 0)),
            pl.BlockSpec((1, 1, 1, mx), lambda n, i, j: (j, n, i, 0)),
            pl.BlockSpec((1, 1, 1, mx), lambda n, i, j: (j, n, i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((n_dt, N, my, mx),
                                        jnp.float32)] * 3,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel"),
        ),
        interpret=interpret,
    )(codes, geom.slabs_q, geom.win_mask, geom.bias_t, cpos_t, cneg_t,
      norms)

    dpos = _ss._ordered_tile_fold(dpos, hyperdim_axes)
    dneg = _ss._ordered_tile_fold(dneg, hyperdim_axes)
    qq = _ss._ordered_tile_fold(qq, hyperdim_axes)

    return _cosine_epilogue(dpos, dneg, qq, tiles, per_stream, C)


# ---------------------------------------------------------------------------
# Pure-jnp twin (the oracle AND the jnp-backend int path)
# ---------------------------------------------------------------------------

def _int_scores_shared(codes, geom: IntScoreGeometry, cpos_t, cneg_t, *,
                       h: int, w: int, stride: int,
                       nonlinearity: NonLin,
                       hyperdim_axes: tuple[str, ...] | None = None):
    """Shared-classifier jnp int path -> ``(dpos, dneg, qq) (N, my, mx)``.

    Same quantized operands and the same int32 accumulation as the kernel
    (the identical :func:`_int_window_acc` core, vmapped); only the
    (float) epilogue can differ by rounding. The classifier dots reduce
    per D-tile first and then fold the tiles in the kernel's fixed
    left-to-right order (``_ordered_tile_fold``) — so this path, too, is
    bitwise-invariant to sharding the tile axis over ``hyperdim_axes``.
    Materializes ``(N, my, mx, D)`` projections — the validation/CPU
    path, not the deployment one.
    """
    N, H, W = codes.shape
    my = (H - h) // stride + 1
    mx = (W - w) // stride + 1
    n_dt = geom.slabs_q.shape[0]
    td = geom.block_d
    ky = jnp.arange(my) * stride
    blocks = codes[:, ky[:, None] + jnp.arange(h)[None, :], :]  # (N,my,h,W)

    # same reuse core as the kernel, vmapped over (frame, row-band, D-tile)
    acc = jax.vmap(jax.vmap(lambda blk: jax.vmap(
        lambda slab: _int_window_acc(blk, slab, geom.win_mask, h=h, W=W,
                                     td=td))(geom.slabs_q)))(
                                         blocks)   # (N, my, n_dt, mx, TD)
    acc = acc.transpose(0, 1, 3, 2, 4)             # (N, my, mx, n_dt, TD)
    norms = window_norms_codes_batch(codes, h, w, stride)
    norms = jnp.maximum(norms, 1e-8) / geom.slab_scale
    s_n = acc.astype(jnp.float32) / norms[..., None, None]
    bias = geom.bias_t.transpose(1, 0, 2)[None, None]     # (1,1,mx,n_dt,TD)
    phi = apply_nonlinearity(s_n, bias, nonlinearity)
    cpos = cpos_t.transpose(1, 0, 2)[None, None].astype(jnp.float32)
    cneg = cneg_t.transpose(1, 0, 2)[None, None].astype(jnp.float32)
    # per-tile partials (reduce TD only), then the shared fixed-order fold
    fold = lambda x: _ss._ordered_tile_fold(jnp.moveaxis(x, 3, 0),
                                            hyperdim_axes)
    dpos = fold(jnp.sum(phi * cpos, axis=4))       # (N, my, mx)
    dneg = fold(jnp.sum(phi * cneg, axis=4))
    qq = fold(jnp.sum(phi * phi, axis=4))
    return dpos, dneg, qq


@functools.partial(jax.jit, static_argnames=("h", "w", "stride",
                                             "nonlinearity",
                                             "frames_per_stream", "packed",
                                             "hyperdim_axes"))
def fragment_scores_batch_int_ref(codes: Array, tiles: IntScoreTiles, *,
                                  h: int, w: int, stride: int,
                                  nonlinearity: NonLin = "rff",
                                  frames_per_stream: int | None = None,
                                  packed: bool = False,
                                  hyperdim_axes: tuple[str, ...] | None
                                  = None) -> Array:
    """Pure-jnp twin of :func:`fragment_scores_batch_int`.

    Identical quantized operands and int32 accumulation (``packed`` codes
    are unpacked up front — nibble unpacking is value-exact, so the
    accumulation order is untouched); serves as the parity oracle for the
    kernel and as the ``backend="jnp"`` execution of the integer
    precisions in the streaming runtimes.
    """
    _check_codes_integer(codes)
    if packed:
        codes = _unpack_nibbles_i32(codes)
    geom = tiles.geom
    per_stream = tiles.cpos_t.ndim == 4
    if per_stream:
        if frames_per_stream is None:
            raise ValueError("per-stream class tiles need frames_per_stream")
        N, H, W = codes.shape
        S = tiles.cpos_t.shape[0]
        C = frames_per_stream
        if S * C != N:
            raise ValueError(f"per-stream tiles: S={S} streams x "
                             f"C={C} frames != batch N={N}")
        dpos, dneg, qq = jax.vmap(
            lambda cs, cp, cn: _int_scores_shared(
                cs, geom, cp, cn, h=h, w=w, stride=stride,
                nonlinearity=nonlinearity, hyperdim_axes=hyperdim_axes))(
                    codes.reshape(S, C, H, W), tiles.cpos_t, tiles.cneg_t)
        my_mx = dpos.shape[2:]
        dpos, dneg, qq = (x.reshape(N, *my_mx) for x in (dpos, dneg, qq))
    else:
        dpos, dneg, qq = _int_scores_shared(
            codes, geom, tiles.cpos_t, tiles.cneg_t, h=h, w=w,
            stride=stride, nonlinearity=nonlinearity,
            hyperdim_axes=hyperdim_axes)
    return _cosine_epilogue(dpos, dneg, qq, tiles, per_stream,
                            frames_per_stream or 0)
