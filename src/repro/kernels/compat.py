"""Version-compat shims for ``jax.experimental.pallas.tpu``.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` after
jax 0.4.37; the kernels in this package are written against the new name.
This module resolves whichever spelling the installed jax provides so the
kernels import cleanly on both sides of the rename.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
