"""Public jit'd wrappers for the Pallas kernels.

Auto-selects ``interpret=True`` on non-TPU backends so the same call sites
work on CPU (validation) and TPU (deployment). Also hosts the per-model
precompute cache used by the HyperSense scoring hot path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.encoding import NonLin
from repro.kernels import hdc_encode as _enc
from repro.kernels import similarity as _sim
from repro.kernels import sliding_scores as _ss
from repro.kernels import sliding_scores_int as _ssi

Array = jax.Array


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def hdc_encode(x: Array, B: Array, b: Array, *,
               nonlinearity: NonLin = "rff", normalize: bool = True,
               block_n: int = 128, block_d: int = 512,
               block_k: int = 512) -> Array:
    """Fused normalize + project + RFF nonlinearity (kernel-backed)."""
    if x.ndim > 2:
        x = x.reshape(x.shape[0], -1)
    if normalize:
        x = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-8)
    return _enc.hdc_encode(x, B, b, nonlinearity=nonlinearity,
                           block_n=block_n, block_d=block_d,
                           block_k=block_k, interpret=_interpret())


def similarity(queries: Array, class_hvs: Array, *, block_n: int = 256,
               block_d: int = 1024) -> Array:
    """Fused cosine class scores (kernel-backed)."""
    return _sim.similarity(queries, class_hvs, block_n=block_n,
                           block_d=block_d, interpret=_interpret())


precompute_tiles = _ss.precompute_tiles
precompute_geometry = _ss.precompute_geometry
retile_classes = _ss.retile_classes
retile_classes_fleet = _ss.retile_classes_fleet
ScoreTiles = _ss.ScoreTiles
ScoreGeometry = _ss.ScoreGeometry

# integer datapath twins (repro.kernels.sliding_scores_int): int8, the
# packed-int4 wire format, and the ±1 binary mode all share these
precompute_tiles_int = _ssi.precompute_tiles_int
precompute_geometry_int = _ssi.precompute_geometry_int
retile_classes_int = _ssi.retile_classes_int
retile_classes_int_fleet = _ssi.retile_classes_int_fleet
IntScoreTiles = _ssi.IntScoreTiles
IntScoreGeometry = _ssi.IntScoreGeometry
assert_int_datapath_fits = _ssi.assert_int_datapath_fits
int_datapath_bounds = _ssi.int_datapath_bounds


def fragment_score_map(frame: Array, class_hvs: Array, B0: Array, b: Array,
                       *, h: int, w: int, stride: int,
                       nonlinearity: NonLin = "rff",
                       tiles: _ss.ScoreTiles | None = None,
                       block_d: int = 512) -> Array:
    """Frame -> (my, mx) detection-score map via the reuse kernel.

    For repeated calls, precompute ``tiles`` once with
    :func:`precompute_tiles` and pass it in (the per-model rotation
    precompute is the whole point of the unrolled-orientation trick).
    """
    W = frame.shape[-1]
    if tiles is None:
        tiles = _ss.precompute_tiles(B0, b, class_hvs, W=W, w=w,
                                     stride=stride, block_d=block_d)
    return _ss.fragment_scores(frame, tiles, h=h, w=w, stride=stride,
                               nonlinearity=nonlinearity,
                               interpret=_interpret())


def fragment_score_map_batch(frames: Array, class_hvs: Array, B0: Array,
                             b: Array, *, h: int, w: int, stride: int,
                             nonlinearity: NonLin = "rff",
                             tiles: _ss.ScoreTiles | None = None,
                             block_d: int = 512,
                             hyperdim_axes: tuple[str, ...] | None = None
                             ) -> Array:
    """(N, H, W) frames -> (N, my, mx) score maps in ONE kernel launch.

    The streaming hot path: every frame in the chunk reuses the same
    :class:`ScoreTiles` precompute. Pass ``tiles`` explicitly when scoring
    many chunks with one model so the precompute is paid once.
    """
    W = frames.shape[-1]
    if tiles is None:
        tiles = _ss.precompute_tiles(B0, b, class_hvs, W=W, w=w,
                                     stride=stride, block_d=block_d)
    return _ss.fragment_scores_batch(frames, tiles, h=h, w=w, stride=stride,
                                     nonlinearity=nonlinearity,
                                     interpret=_interpret(),
                                     hyperdim_axes=hyperdim_axes)


def fragment_score_map_batch_int(codes: Array, class_hvs: Array, B0: Array,
                                 b: Array, *, h: int, w: int, stride: int,
                                 nonlinearity: NonLin = "rff",
                                 tiles: _ssi.IntScoreTiles | None = None,
                                 block_d: int = 512,
                                 packed: bool = False,
                                 mode: str = "int8",
                                 hyperdim_axes: tuple[str, ...] | None = None
                                 ) -> Array:
    """(N, H, W) integer ADC codes -> (N, my, mx) score maps, ONE launch.

    The integer datapath's streaming hot path: raw codes flow into the
    fused encode->score kernel untouched (int32 accumulation, shifted
    slabs rolled out in-kernel, float only at the similarity epilogue).
    ``packed=True`` consumes the int4 wire format (``(N, H, W/2)`` bytes,
    two codes each); ``mode`` selects the slab/class quantization
    ("int8" or "binary") when ``tiles`` is built here. Pass ``tiles``
    from :func:`precompute_tiles_int` to amortize the quantized
    precompute across chunks.
    """
    W = codes.shape[-1] * (2 if packed else 1)
    if tiles is None:
        tiles = _ssi.precompute_tiles_int(B0, b, class_hvs, W=W, w=w,
                                          stride=stride, block_d=block_d,
                                          mode=mode)
    return _ssi.fragment_scores_batch_int(codes, tiles, h=h, w=w,
                                          stride=stride,
                                          nonlinearity=nonlinearity,
                                          interpret=_interpret(),
                                          packed=packed,
                                          hyperdim_axes=hyperdim_axes)


def fragment_score_map_fleet_int(codes: Array, class_hvs: Array, B0: Array,
                                 b: Array, *, h: int, w: int, stride: int,
                                 nonlinearity: NonLin = "rff",
                                 tiles: _ssi.IntScoreTiles | None = None,
                                 block_d: int = 512,
                                 packed: bool = False,
                                 mode: str = "int8",
                                 hyperdim_axes: tuple[str, ...] | None = None
                                 ) -> Array:
    """(S, C, H, W) code super-chunk -> (S, C, my, mx), ONE launch.

    Int twin of :func:`fragment_score_map_fleet`: per-stream int8 (or ±1)
    class tiles (``tiles.cpos_t.ndim == 4``) ride the stream-indexed
    BlockSpecs of the shared grid; ``packed`` marks int4 wire codes.
    """
    S, C, H, W = codes.shape
    if tiles is not None and tiles.cpos_t.ndim == 4:
        maps = _ssi.fragment_scores_batch_int(
            codes.reshape(S * C, H, W), tiles, h=h, w=w, stride=stride,
            nonlinearity=nonlinearity, interpret=_interpret(),
            frames_per_stream=C, packed=packed,
            hyperdim_axes=hyperdim_axes)
    else:
        maps = fragment_score_map_batch_int(
            codes.reshape(S * C, H, W), class_hvs, B0, b, h=h, w=w,
            stride=stride, nonlinearity=nonlinearity, tiles=tiles,
            block_d=block_d, packed=packed, mode=mode,
            hyperdim_axes=hyperdim_axes)
    return maps.reshape(S, C, *maps.shape[1:])


def fragment_score_map_fleet(frames: Array, class_hvs: Array, B0: Array,
                             b: Array, *, h: int, w: int, stride: int,
                             nonlinearity: NonLin = "rff",
                             tiles: _ss.ScoreTiles | None = None,
                             block_d: int = 512,
                             hyperdim_axes: tuple[str, ...] | None = None
                             ) -> Array:
    """(S, C, H, W) super-chunk -> (S, C, my, mx) score maps, ONE launch.

    The fleet hot path: S concurrent sensor streams contribute C frames
    each; the ``S*C`` axis is flattened into the batch grid of
    :func:`fragment_score_map_batch`, so the whole fleet super-chunk is a
    single ``pallas_call`` against one shared :class:`ScoreTiles`
    precompute. The grid's batch axis is parallel, so per-frame numerics
    are identical to S independent per-stream calls.
    """
    S, C, H, W = frames.shape
    if tiles is not None and tiles.cpos_t.ndim == 4:
        # per-stream classifiers (online fleet adaptation): one launch,
        # stream-indexed class-tile BlockSpecs inside the shared grid.
        maps = _ss.fragment_scores_batch(
            frames.reshape(S * C, H, W), tiles, h=h, w=w, stride=stride,
            nonlinearity=nonlinearity, interpret=_interpret(),
            frames_per_stream=C, hyperdim_axes=hyperdim_axes)
    else:
        maps = fragment_score_map_batch(
            frames.reshape(S * C, H, W), class_hvs, B0, b, h=h, w=w,
            stride=stride, nonlinearity=nonlinearity, tiles=tiles,
            block_d=block_d, hyperdim_axes=hyperdim_axes)
    return maps.reshape(S, C, *maps.shape[1:])
