"""Logical-axis sharding rules engine + cell builders (no big compiles)."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.distributed import roofline as rl
from repro.distributed import sharding as shlib

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def mesh():
    # 1 real device: mesh (1, 1) exercises the rules code paths; axis
    # sizes of 1 accept any dim, so specs resolve like the big mesh.
    return jax.make_mesh((1, 1), ("data", "model"))


def test_spec_for_basic(mesh):
    spec = shlib.spec_for((64, 128), ("embed", "mlp"), mesh)
    assert spec == P("data", "model")


def test_spec_for_drops_non_divisible():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # simulate divisibility drop via a fake 16-wide axis: use rules math
    # directly through _axis_for
    taken = set()
    big_mesh_shape = {"data": 16, "model": 16}

    class FakeMesh:
        shape = big_mesh_shape

    got = shlib._axis_for("mlp", dict(shlib.DEFAULT_RULES), FakeMesh(),
                          24, taken)   # 24 % 16 != 0
    assert got is None
    got = shlib._axis_for("mlp", dict(shlib.DEFAULT_RULES), FakeMesh(),
                          32, taken)
    assert got == ("model",)


def test_priority_resolution_kv_before_cache_seq():
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    # kv divisible -> kv takes "model", cache_seq left unsharded
    spec = shlib.spec_for((128, 32768, 16, 128),
                          ("act_batch", "cache_seq", "act_kv_heads", None),
                          FakeMesh())
    assert spec[2] == "model" and spec[1] is None
    # kv NOT divisible -> cache_seq takes "model"
    spec = shlib.spec_for((128, 32768, 8, 128),
                          ("act_batch", "cache_seq", "act_kv_heads", None),
                          FakeMesh())
    assert spec[2] is None and spec[1] == "model"


def test_expert_cap_fallback():
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    # 128 experts divide -> expert dim sharded
    spec = shlib.spec_for((128, 2048, 512),
                          ("act_expert", "act_expert_cap", None), FakeMesh())
    assert spec[0] == "model" and spec[1] is None
    # 8 experts don't -> capacity dim sharded instead
    spec = shlib.spec_for((8, 2048, 512),
                          ("act_expert", "act_expert_cap", None), FakeMesh())
    assert spec[0] is None and spec[1] == "model"


def test_no_mesh_is_noop():
    x = jnp.zeros((4, 4))
    y = shlib.shard(x, "act_batch", "act_seq")
    assert y is x or (y == x).all()


def test_use_mesh_context(mesh):
    assert shlib.current_mesh() is None
    with shlib.use_mesh(mesh):
        assert shlib.current_mesh() is mesh
        x = jnp.zeros((4, 8))
        shlib.shard(x, "act_batch", None)   # must not raise
    assert shlib.current_mesh() is None


# ---------------------------------------------------------------------------
# Roofline helpers
# ---------------------------------------------------------------------------

def test_collective_bytes_parser():
    hlo = """
  %ar = bf16[16,1024]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = f32[8,256]{1,0} all-gather(%y), dimensions={0}
  %cp = bf16[4,4]{1,0} collective-permute(%z)
  %ars = bf16[16,1024]{1,0} all-reduce-start(%x)
  %add = f32[8,256]{1,0} add(%a, %b)
"""
    got = rl.collective_bytes(hlo)
    assert got["all-reduce"] == 16 * 1024 * 2 * 2   # incl. -start
    assert got["all-gather"] == 8 * 256 * 4
    assert got["collective-permute"] == 4 * 4 * 2
    assert got["all-to-all"] == 0


def test_roofline_terms_and_bottleneck():
    r = rl.Roofline(arch="x", shape="train_4k", mesh="single", chips=256,
                    hlo_gflops=1e6, hlo_gbytes=1e3, coll_gbytes=10.0,
                    model_gflops=5e5)
    assert r.t_compute == pytest.approx(1e15 / (256 * rl.PEAK_FLOPS))
    assert r.t_collective == pytest.approx(10e9 / rl.ICI_BW)
    assert r.bottleneck in ("compute", "memory", "collective")
    assert 0 < r.useful_flop_ratio <= 1.0


def test_active_params_moe():
    cfg = configs.get_config("qwen3-moe-235b-a22b")
    from repro.models import common, lm
    n = common.spec_param_count(lm.build(cfg).spec())
    act = rl.active_params(cfg, n)
    assert act < n * 0.2     # top-8 of 128 experts -> ~a22b of 235b
    dense_cfg = configs.get_config("olmo-1b")
    n2 = common.spec_param_count(lm.build(dense_cfg).spec())
    assert rl.active_params(dense_cfg, n2) == n2


def test_param_counts_match_reported_sizes():
    """Total params should be in the ballpark the arch names claim."""
    from repro.models import common, lm
    expect = {"olmo-1b": (1.0e9, 1.6e9),
              "deepseek-67b": (60e9, 72e9),
              "grok-1-314b": (250e9, 340e9),
              "qwen3-moe-235b-a22b": (180e9, 260e9),
              "xlstm-350m": (0.25e9, 0.6e9),
              "internlm2-1.8b": (1.5e9, 2.2e9)}
    for arch, (lo, hi) in expect.items():
        n = common.spec_param_count(lm.build(configs.get_config(arch)
                                             ).spec())
        assert lo <= n <= hi, (arch, n)


# ---------------------------------------------------------------------------
# hyperdim axis: mesh_extent + the D-shard retile invariant
# ---------------------------------------------------------------------------

def test_hyperdim_rule_registered():
    """The "hyperdim" logical axis claims the model mesh axis — the rule
    the 2-D fleet mesh rides on."""
    assert shlib.DEFAULT_RULES["hyperdim"] == ("model",)


def test_mesh_extent_basic(mesh):
    axes, k = shlib.mesh_extent("hyperdim", mesh)
    assert axes == ("model",) and k == 1
    axes, k = shlib.mesh_extent("sensors", mesh)
    assert axes == ("data",) and k == 1


def test_mesh_extent_multiplies_axis_sizes():
    class FakeMesh:
        shape = {"data": 4, "model": 2}

    axes, k = shlib.mesh_extent("hyperdim", FakeMesh())
    assert axes == ("model",) and k == 2
    axes, k = shlib.mesh_extent("sensors", FakeMesh())
    assert axes == ("data",) and k == 4


def test_mesh_extent_ignores_divisibility():
    """Unlike spec_for, mesh_extent reports the raw extent: the fleet
    uses it to PAD the sensor axis, so divisibility must not zero it."""
    class FakeMesh:
        shape = {"data": 8, "model": 1}

    axes, k = shlib.mesh_extent("sensors", FakeMesh())
    assert axes == ("data",) and k == 8          # S=5 pads to 8, not drops


def test_mesh_extent_unknown_or_meshless():
    assert shlib.mesh_extent("no_such_axis",
                             jax.make_mesh((1, 1), ("data", "model"))) \
        == ((), 1)
    assert shlib.mesh_extent("hyperdim", None) == ((), 1)

    class NoModelMesh:
        shape = {"data": 4}

    assert shlib.mesh_extent("hyperdim", NoModelMesh()) == ((), 1)


try:  # prefer the real library when installed (requirements-dev.txt)
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # fallback keeps these tests running without the dep
    from _hypothesis_fallback import hypothesis, st


@hypothesis.given(st.integers(1, 7), st.integers(0, 2 ** 31 - 1))
@hypothesis.settings(max_examples=20, deadline=None)
def test_retile_is_dshard_boundary_invariant(cut, seed):
    """Splitting the geometry's tile axis (the hyperdim shards) and
    retiling each piece reproduces the full retile bitwise: class tiles
    are a pure per-tile gather and the cosine norms come from the FULL
    class vector, so no D-shard boundary can perturb the scoring tiles.
    This is the invariant that lets the 2-D mesh replicate class_hvs and
    shard only the geometry."""
    import numpy as np

    from repro.kernels import sliding_scores as ss

    h, dim, W, w, stride, block_d = 6, 128, 24, 6, 3, 16
    key = jax.random.PRNGKey(seed)
    B0 = jax.random.normal(key, (h, dim))
    b = jax.random.uniform(jax.random.fold_in(key, 1), (dim,))
    chvs = jax.random.normal(jax.random.fold_in(key, 2), (2, dim))
    geom = ss.precompute_geometry(B0, b, W=W, w=w, stride=stride,
                                  block_d=block_d)
    n_dt = geom.slabs.shape[0]
    assert n_dt == dim // block_d == 8 and 1 <= cut < n_dt

    full = ss.retile_classes(geom, chvs)
    import dataclasses
    parts = []
    for sl in (slice(0, cut), slice(cut, n_dt)):
        shard = dataclasses.replace(geom, slabs=geom.slabs[sl],
                                    bias_t=geom.bias_t[sl],
                                    idx=geom.idx[sl])
        parts.append(ss.retile_classes(shard, chvs))
    np.testing.assert_array_equal(
        np.asarray(full.cpos_t),
        np.concatenate([np.asarray(p.cpos_t) for p in parts]))
    np.testing.assert_array_equal(
        np.asarray(full.cneg_t),
        np.concatenate([np.asarray(p.cneg_t) for p in parts]))
    for p in parts:     # norms are full-D: identical on every shard
        np.testing.assert_array_equal(np.asarray(full.cpos_norm),
                                      np.asarray(p.cpos_norm))
        np.testing.assert_array_equal(np.asarray(full.cneg_norm),
                                      np.asarray(p.cneg_norm))
