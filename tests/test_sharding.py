"""Logical-axis sharding rules engine + cell builders (no big compiles)."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.distributed import roofline as rl
from repro.distributed import sharding as shlib

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def mesh():
    # 1 real device: mesh (1, 1) exercises the rules code paths; axis
    # sizes of 1 accept any dim, so specs resolve like the big mesh.
    return jax.make_mesh((1, 1), ("data", "model"))


def test_spec_for_basic(mesh):
    spec = shlib.spec_for((64, 128), ("embed", "mlp"), mesh)
    assert spec == P("data", "model")


def test_spec_for_drops_non_divisible():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # simulate divisibility drop via a fake 16-wide axis: use rules math
    # directly through _axis_for
    taken = set()
    big_mesh_shape = {"data": 16, "model": 16}

    class FakeMesh:
        shape = big_mesh_shape

    got = shlib._axis_for("mlp", dict(shlib.DEFAULT_RULES), FakeMesh(),
                          24, taken)   # 24 % 16 != 0
    assert got is None
    got = shlib._axis_for("mlp", dict(shlib.DEFAULT_RULES), FakeMesh(),
                          32, taken)
    assert got == ("model",)


def test_priority_resolution_kv_before_cache_seq():
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    # kv divisible -> kv takes "model", cache_seq left unsharded
    spec = shlib.spec_for((128, 32768, 16, 128),
                          ("act_batch", "cache_seq", "act_kv_heads", None),
                          FakeMesh())
    assert spec[2] == "model" and spec[1] is None
    # kv NOT divisible -> cache_seq takes "model"
    spec = shlib.spec_for((128, 32768, 8, 128),
                          ("act_batch", "cache_seq", "act_kv_heads", None),
                          FakeMesh())
    assert spec[2] is None and spec[1] == "model"


def test_expert_cap_fallback():
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    # 128 experts divide -> expert dim sharded
    spec = shlib.spec_for((128, 2048, 512),
                          ("act_expert", "act_expert_cap", None), FakeMesh())
    assert spec[0] == "model" and spec[1] is None
    # 8 experts don't -> capacity dim sharded instead
    spec = shlib.spec_for((8, 2048, 512),
                          ("act_expert", "act_expert_cap", None), FakeMesh())
    assert spec[0] is None and spec[1] == "model"


def test_no_mesh_is_noop():
    x = jnp.zeros((4, 4))
    y = shlib.shard(x, "act_batch", "act_seq")
    assert y is x or (y == x).all()


def test_use_mesh_context(mesh):
    assert shlib.current_mesh() is None
    with shlib.use_mesh(mesh):
        assert shlib.current_mesh() is mesh
        x = jnp.zeros((4, 8))
        shlib.shard(x, "act_batch", None)   # must not raise
    assert shlib.current_mesh() is None


# ---------------------------------------------------------------------------
# Roofline helpers
# ---------------------------------------------------------------------------

def test_collective_bytes_parser():
    hlo = """
  %ar = bf16[16,1024]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = f32[8,256]{1,0} all-gather(%y), dimensions={0}
  %cp = bf16[4,4]{1,0} collective-permute(%z)
  %ars = bf16[16,1024]{1,0} all-reduce-start(%x)
  %add = f32[8,256]{1,0} add(%a, %b)
"""
    got = rl.collective_bytes(hlo)
    assert got["all-reduce"] == 16 * 1024 * 2 * 2   # incl. -start
    assert got["all-gather"] == 8 * 256 * 4
    assert got["collective-permute"] == 4 * 4 * 2
    assert got["all-to-all"] == 0


def test_roofline_terms_and_bottleneck():
    r = rl.Roofline(arch="x", shape="train_4k", mesh="single", chips=256,
                    hlo_gflops=1e6, hlo_gbytes=1e3, coll_gbytes=10.0,
                    model_gflops=5e5)
    assert r.t_compute == pytest.approx(1e15 / (256 * rl.PEAK_FLOPS))
    assert r.t_collective == pytest.approx(10e9 / rl.ICI_BW)
    assert r.bottleneck in ("compute", "memory", "collective")
    assert 0 < r.useful_flop_ratio <= 1.0


def test_active_params_moe():
    cfg = configs.get_config("qwen3-moe-235b-a22b")
    from repro.models import common, lm
    n = common.spec_param_count(lm.build(cfg).spec())
    act = rl.active_params(cfg, n)
    assert act < n * 0.2     # top-8 of 128 experts -> ~a22b of 235b
    dense_cfg = configs.get_config("olmo-1b")
    n2 = common.spec_param_count(lm.build(dense_cfg).spec())
    assert rl.active_params(dense_cfg, n2) == n2


def test_param_counts_match_reported_sizes():
    """Total params should be in the ballpark the arch names claim."""
    from repro.models import common, lm
    expect = {"olmo-1b": (1.0e9, 1.6e9),
              "deepseek-67b": (60e9, 72e9),
              "grok-1-314b": (250e9, 340e9),
              "qwen3-moe-235b-a22b": (180e9, 260e9),
              "xlstm-350m": (0.25e9, 0.6e9),
              "internlm2-1.8b": (1.5e9, 2.2e9)}
    for arch, (lo, hi) in expect.items():
        n = common.spec_param_count(lm.build(configs.get_config(arch)
                                             ).spec())
        assert lo <= n <= hi, (arch, n)
