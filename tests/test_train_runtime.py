"""Training runtime: optimizer, checkpoint/restart, compression, loop."""

import os

try:  # prefer the real library when installed (requirements-dev.txt)
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # fallback keeps these tests running without the dep
    from _hypothesis_fallback import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.ckpt import checkpoint as ckpt
from repro.models import lm
from repro.train import compress, loop as train_loop, optim

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    opt = optim.AdamW(lr=0.1, weight_decay=0.0)
    params = {"x": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}          # d/dx x^2
        updates, state = opt.update(grads, state, params)
        params = optim.apply_updates(params, updates)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0), "b": jnp.full((4,), 10.0)}
    clipped = optim.clip_by_global_norm(g, 1.0)
    assert abs(float(optim.global_norm(clipped)) - 1.0) < 1e-5
    small = {"a": jnp.full((4,), 0.01), "b": jnp.full((4,), 0.01)}
    same = optim.clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(same["a"]),
                               np.asarray(small["a"]))


def test_warmup_cosine_schedule():
    sched = optim.warmup_cosine(1.0, 10, 100)
    assert float(sched(jnp.int32(0))) == 0.0
    assert abs(float(sched(jnp.int32(10))) - 1.0) < 1e-6
    assert float(sched(jnp.int32(100))) <= 0.11
    assert float(sched(jnp.int32(5))) == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (8, 16)),
            "nested": {"b": jax.random.normal(k2, (4,)),
                       "step": jnp.int32(7)}}


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    d = str(tmp_path / "ck")
    ckpt.save(d, 10, tree, extra={"step": 10})
    restored, extra = ckpt.restore(d, tree)
    assert extra["step"] == 10
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b)), tree, restored)


def test_checkpoint_keep_k_and_latest(tmp_path):
    d = str(tmp_path / "ck")
    tree = _tree(jax.random.PRNGKey(1))
    for s in [1, 2, 3, 4, 5]:
        ckpt.save(d, s, tree, keep=2)
    steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert len(steps) == 2
    assert ckpt.latest_step(d) == 5


def test_checkpoint_crash_safety(tmp_path):
    """A leftover .tmp dir from a crash must not corrupt restore."""
    d = str(tmp_path / "ck")
    tree = _tree(jax.random.PRNGKey(2))
    ckpt.save(d, 1, tree)
    os.makedirs(os.path.join(d, "step_0000000002.tmp"))  # simulated crash
    assert ckpt.latest_step(d) == 1
    restored, _ = ckpt.restore(d, tree)
    ckpt.save(d, 3, tree)          # gc cleans the orphan
    assert not any(x.endswith(".tmp") for x in os.listdir(d))


def test_async_checkpointer(tmp_path):
    d = str(tmp_path / "ck")
    saver = ckpt.AsyncCheckpointer(d, keep=2)
    tree = _tree(jax.random.PRNGKey(3))
    saver.save(1, tree, extra={"step": 1})
    saver.wait()
    assert ckpt.latest_step(d) == 1


def test_elastic_reshard_restore(tmp_path):
    """Restore onto a different mesh (1-device here, but via explicit
    NamedSharding) — the elastic-rescale path."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    d = str(tmp_path / "ck")
    tree = _tree(jax.random.PRNGKey(4))
    ckpt.save(d, 1, tree)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda x: NamedSharding(mesh, P()), tree)
    restored, _ = ckpt.restore(d, tree, shardings=sh)
    assert restored["w"].sharding == NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

@hypothesis.given(st.integers(0, 2**16))
@hypothesis.settings(max_examples=10, deadline=None)
def test_compress_roundtrip_accuracy(seed):
    g = {"w": jax.random.normal(jax.random.PRNGKey(seed), (300,)),
         "b": jax.random.normal(jax.random.PRNGKey(seed + 1), (7, 13))}
    ef = compress.init_error_feedback(g)
    qg, ef2 = compress.compress_grads(g, ef)
    deq = compress.decompress_grads(qg, g)
    for k in g:
        err = np.abs(np.asarray(deq[k] - g[k]))
        scale = np.abs(np.asarray(g[k])).max()
        assert err.max() <= scale / 127.0 + 1e-6   # int8 quantization bound
        # error feedback carries exactly the quantization residual
        np.testing.assert_allclose(np.asarray(ef2[k]),
                                   np.asarray(g[k] - deq[k]), atol=1e-6)


def test_error_feedback_reduces_bias():
    """Mean of dequantized grads over steps converges to the true mean
    with EF (the residual is re-injected)."""
    g = {"w": jnp.full((64,), 0.101)}
    ef = compress.init_error_feedback(g)
    total = jnp.zeros((64,))
    for _ in range(50):
        qg, ef = compress.compress_grads(g, ef)
        total = total + compress.decompress_grads(qg, g)["w"]
    mean = total / 50
    np.testing.assert_allclose(np.asarray(mean), 0.101, rtol=1e-3)


def test_compression_ratio():
    g = {"w": jnp.zeros((10000,))}
    r = compress.compression_ratio(g)
    assert 0.25 <= r <= 0.30       # int8 + block scales ~ 0.27x of fp32


# ---------------------------------------------------------------------------
# Train loop: run, checkpoint, kill, resume
# ---------------------------------------------------------------------------

def test_train_loop_resume(tmp_path):
    cfg = configs.get_smoke("olmo-1b")
    model = lm.build(cfg)
    data = train_loop.synthetic_lm_data(cfg, batch=2, seq=16)
    tc = train_loop.TrainConfig(steps=6, ckpt_every=3, log_every=2,
                                ckpt_dir=str(tmp_path / "ck"), lr=1e-3)
    r1 = train_loop.train(model, data, tc)
    assert r1["step"] == 6
    assert ckpt.latest_step(tc.ckpt_dir) == 6

    # simulate failure + relaunch with more steps: resumes from 6
    tc2 = train_loop.TrainConfig(steps=8, ckpt_every=3, log_every=2,
                                 ckpt_dir=str(tmp_path / "ck"), lr=1e-3)
    data2 = train_loop.synthetic_lm_data(cfg, batch=2, seq=16, start_step=6)
    r2 = train_loop.train(model, data2, tc2)
    assert r2["step"] == 8


def test_train_loop_microbatched_matches_loss_scale(tmp_path):
    cfg = configs.get_smoke("internlm2-1.8b")
    model = lm.build(cfg)
    opt = optim.AdamW(lr=0.0)      # lr 0: params unchanged -> same loss
    params = model.init(jax.random.PRNGKey(0))
    data = train_loop.synthetic_lm_data(cfg, batch=4, seq=16)
    batch = next(data)
    s1 = train_loop.make_train_step(model, opt, microbatches=1)
    s2 = train_loop.make_train_step(model, opt, microbatches=2)
    _, _, m1 = s1(params, opt.init(params), batch)
    _, _, m2 = s2(params, opt.init(params), batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-3)
