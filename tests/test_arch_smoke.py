"""Per-architecture smoke tests (assignment deliverable f).

For every assigned architecture: instantiate the REDUCED same-family
config, run one forward + one train step + (where applicable) one decode
step on CPU, assert output shapes and absence of NaNs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.train import optim

jax.config.update("jax_platform_name", "cpu")

ARCHS = configs.ARCH_IDS


def make_batch(cfg, key, batch=2, seq=16):
    ks = jax.random.split(key, 3)
    tokens = jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab)
    labels = jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab)
    embeds = None
    if cfg.embeds_in:
        tokens = None
        embeds = jax.random.normal(ks[2], (batch, seq, cfg.d_model))
    elif cfg.family == "vlm":
        embeds = jax.random.normal(
            ks[2], (batch, cfg.n_image_tokens, cfg.d_model))
    return lm.Batch(tokens=tokens, labels=labels, embeds=embeds)


@pytest.fixture(scope="module")
def built():
    """Cache (model, params) per arch across tests in this module."""
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = configs.get_smoke(arch)
            model = lm.build(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, built):
    cfg, model, params = built(arch)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits, aux = model.forward(params, batch)
    assert logits.shape == (2, 16, cfg.vocab), logits.shape
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: NaN in logits"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch, built):
    cfg, model, params = built(arch)
    batch = make_batch(cfg, jax.random.PRNGKey(2))
    opt = optim.AdamW(lr=1e-3)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        updates, opt_state = opt.update(grads, opt_state, params)
        return optim.apply_updates(params, updates), opt_state, loss

    params2, opt_state, loss1 = step(params, opt_state, batch)
    _, _, loss2 = step(params2, opt_state, batch)
    assert bool(jnp.isfinite(loss1)) and bool(jnp.isfinite(loss2)), arch
    # loss should be near ln(vocab) initially and decrease on the same batch
    assert float(loss2) < float(loss1) + 0.1, (arch, loss1, loss2)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch, built):
    cfg, model, params = built(arch)
    if cfg.is_encoder:
        pytest.skip("encoder-only arch: no decode step")
    state = model.init_decode_state(batch=2, max_seq=8)
    tok = jnp.array([[3], [5]], jnp.int32)
    logits, state = model.decode_step(
        params, state, lm.DecodeBatch(tokens=tok, index=jnp.int32(0)))
    assert logits.shape == (2, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    logits, _ = model.decode_step(
        params, state, lm.DecodeBatch(tokens=tok, index=jnp.int32(1)))
    assert bool(jnp.all(jnp.isfinite(logits))), arch


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "zamba2-1.2b",
                                  "xlstm-350m", "qwen3-moe-235b-a22b"])
def test_decode_matches_forward(arch, built):
    """Prefill logits at position t == decode logits after t tokens.

    MoE capacity is lifted so no tokens drop: capacity-based dropping is a
    train-time batch effect that decode (one token at a time) cannot see.
    """
    cfg, model, params = built(arch)
    if cfg.n_experts:
        cfg = cfg.replace(capacity_factor=64.0)
        model = lm.build(cfg)
    seq = 8
    tokens = jax.random.randint(jax.random.PRNGKey(3), (2, seq), 0,
                                cfg.vocab)
    batch = lm.Batch(tokens=tokens,
                     labels=jnp.zeros_like(tokens), embeds=None)
    full_logits, _ = model.forward(params, batch)

    state = model.init_decode_state(batch=2, max_seq=seq)
    # make recurrent conv states fp32 for exact parity in fp32 smoke configs
    state = jax.tree.map(lambda x: x.astype(jnp.float32)
                         if x.dtype == jnp.bfloat16 else x, state)
    outs = []
    for t in range(seq):
        logits, state = model.decode_step(
            params, state,
            lm.DecodeBatch(tokens=tokens[:, t:t + 1], index=jnp.int32(t)))
        outs.append(logits)
    dec_logits = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), rtol=2e-2,
                               atol=2e-2)


def test_exact_configs_match_assignment():
    """The full configs carry the exact assigned hyperparameters."""
    expect = {
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        cfg = configs.get_config(arch)
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.kv_heads,
               cfg.d_ff, cfg.vocab)
        assert got == (L, d, h, kv, ff, v), (arch, got)
    assert configs.get_config("zamba2-1.2b").ssm_state == 64
    assert configs.get_config("qwen3-moe-235b-a22b").n_experts == 128
    assert configs.get_config("qwen3-moe-235b-a22b").top_k == 8
    assert configs.get_config("grok-1-314b").n_experts == 8
    assert configs.get_config("grok-1-314b").top_k == 2


def test_applicable_shapes_skip_rules():
    from repro.configs.base import applicable_shapes
    enc = applicable_shapes(configs.get_config("hubert-xlarge"))
    assert enc["decode_32k"] is None and enc["long_500k"] is None
    dense = applicable_shapes(configs.get_config("deepseek-67b"))
    assert dense["long_500k"] is None and dense["decode_32k"] is not None
    hyb = applicable_shapes(configs.get_config("zamba2-1.2b"))
    assert hyb["long_500k"] is not None
    sm = applicable_shapes(configs.get_config("xlstm-350m"))
    assert sm["long_500k"] is not None
