"""Large-W VMEM working-set regression for the int kernel geometry.

The retired layout pre-expanded all W cyclic shifts into an
``(n_dt, h*W, TD)`` int8 operand — linear in W, overrunning VMEM exactly
at deployment scale (h=16, W=4096, TD=512 -> 32 MB/tile). The
rolling-shift layout keeps only the padded base slabs plus a bounded
chunk scratch: O(window) in W. This file pins that asymmetry the way the
issue demands: the OLD layout's byte count asserted *over* the budget at
large W, the NEW one under it — so a future "optimization" that
re-materializes shifts cannot land silently.
"""

import jax
import numpy as np
import pytest

from repro.core import encoding
from repro.kernels import ops
from repro.kernels import sliding_scores_int as k_int
from repro.sensing import adc

jax.config.update("jax_platform_name", "cpu")

#: the deployment-scale config the old layout failed at (4-bit codes so
#: the sum-of-squares accumulator stays exact and the VMEM bound is the
#: only thing under test)
LARGE_W = dict(adc_bits=4, H=128, W=4096, h=16, w=16, stride=16,
               block_d=512)


def test_expanded_layout_over_budget_new_layout_under():
    b = k_int.int_datapath_bounds(**LARGE_W)
    # the regression proof: same config, old layout busts the budget...
    assert b["vmem_expanded_bytes"] > b["vmem_limit_bytes"]
    # ...while the rolling-shift working set fits with >2x headroom
    assert b["vmem_bytes"] <= b["vmem_limit_bytes"] // 2
    assert b["fits"]
    # and the guard accepts the config the old layout would have died on
    ops.assert_int_datapath_fits(**LARGE_W)


def test_working_set_is_o_window_in_w():
    """Doubling W doubles the expanded operand but only adds halo/mask
    bytes to the rolling-shift working set."""
    base = dict(LARGE_W)
    b1 = k_int.int_datapath_bounds(**base)
    base["W"] *= 2
    b2 = k_int.int_datapath_bounds(**base)
    exp_growth = b2["vmem_expanded_bytes"] - b1["vmem_expanded_bytes"]
    new_growth = b2["vmem_bytes"] - b1["vmem_bytes"]
    # the expanded operand alone grows by h * dW * td bytes
    assert exp_growth >= LARGE_W["h"] * LARGE_W["W"] * LARGE_W["block_d"]
    # the rolling layout only adds the terms both layouts share (codes
    # block, window mask, bias/class/acc tiles) plus W-1 halo columns —
    # its slab term grows by h * dW bytes, vs h * dW * td expanded
    shared_growth = new_growth - LARGE_W["h"] * LARGE_W["W"]  # minus halo
    assert (exp_growth - shared_growth
            >= LARGE_W["h"] * LARGE_W["W"] * LARGE_W["block_d"])
    assert new_growth < exp_growth / 5


def test_geometry_stores_no_expanded_operand():
    """IntScoreGeometry holds padded base slabs, not an (n_dt, h*W, TD)
    slab matrix — asserted structurally, not just via the byte model."""
    h, W, w, stride, D, td = 4, 96, 5, 3, 128, 32
    B0, b = encoding.make_perm_base_rows(jax.random.PRNGKey(0), h, D)
    geom = k_int.precompute_geometry_int(B0, b, W=W, w=w, stride=stride,
                                         block_d=td)
    assert not hasattr(geom, "slab_mat")
    n_dt = D // td
    assert geom.slabs_q.shape == (n_dt, h, td + W - 1)
    # per D-tile slab bytes: h * (td + W - 1), nowhere near h * W * td
    assert geom.slabs_q[0].size < h * W * td / 8


def test_oversized_new_layout_still_raises():
    """The bound is two-sided: a genuinely oversized (window, tile)
    config trips the VMEM branch of assert_int_datapath_fits too."""
    with pytest.raises(ValueError, match="working set"):
        ops.assert_int_datapath_fits(4, 64, 4096, 16, 16, stride=1,
                                     block_d=4096)


def test_large_w_kernel_matches_oracle():
    """4x the benchmark's default frame width, W past the roll-chunk
    boundary: the chunked rolling-shift kernel still matches the jnp
    quantized-operand oracle (and its geometry passes the VMEM guard)."""
    N, H, W, D, h, w, stride, bits = 2, 12, 144, 256, 4, 5, 4, 8
    frames = jax.random.uniform(jax.random.PRNGKey(1), (N, H, W),
                                maxval=1.5)
    codes = adc.pack_codes(adc.quantize_codes(frames, bits), bits)
    B0, b = encoding.make_perm_base_rows(jax.random.PRNGKey(2), h, D)
    C = jax.random.normal(jax.random.PRNGKey(3), (2, D))
    ops.assert_int_datapath_fits(bits, H, W, h, w, stride=stride,
                                 block_d=64)
    tiles = k_int.precompute_tiles_int(B0, b, C, W=W, w=w, stride=stride,
                                       block_d=64)
    got = k_int.fragment_scores_batch_int(codes, tiles, h=h, w=w,
                                          stride=stride, interpret=True)
    want = k_int.fragment_scores_batch_int_ref(codes, tiles, h=h, w=w,
                                               stride=stride)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
