"""HP-drain shape contract + gated cascade backbone serving.

The contracts pinned here (repro/sensing/stream.py drain machinery,
repro/launch/steps.py detector cell, repro/launch/cascade.py service):

* ``drain_hp()`` ALWAYS returns frames shaped ``(M, H, W)`` — an empty
  drain after any processed frame is ``(0, H, W)``, never ``(0, 0, 0)``,
  on all three runners (StreamRunner, FleetRunner, FleetService), so
  consumers can concatenate drains blindly;
* drained indices are ABSOLUTE frame numbers, strictly increasing
  across drains, and chunked drain+concat == one-shot drain bitwise;
* drain → checkpoint → restore preserves exactly the undrained frames;
* the detector step is bitwise batch-invariant (``lax.map`` rows), so
  CascadeService's padded async batches == eager per-frame evaluation
  with exactly one backbone compile across ragged drain sizes;
* ``energy.from_capture_log`` handles a depth-less (open-loop) log
  explicitly: ``on_missing_bits="params"`` bills the params' depths,
  ``"error"`` refuses — and the cascade accounting uses ``"error"``.
"""

import os

import jax
import numpy as np
import pytest

from repro import configs
from repro.core import encoding, energy, hypersense
from repro.core.sensor_control import (CaptureConfig, CaptureLog,
                                       ControllerConfig,
                                       assemble_capture_log)
from repro.launch import steps
from repro.launch.cascade import CascadeService
from repro.launch.serve import FleetService
from repro.sensing.fleet import FleetRunner
from repro.sensing.stream import StreamRunner, hp_drain_arrays

C = 4          # chunk size
HW = (16, 16)  # frame shape (divisible by the detector patch)
CFG = ControllerConfig(hold_frames=2, base_rate_hz=10.0,
                       active_rate_hz=30.0)
CTL = CaptureConfig(hp_bits=12)


def make_model(t_score):
    B0, b = encoding.make_perm_base_rows(jax.random.PRNGKey(1), 6, 64)
    C_hvs = jax.random.normal(jax.random.PRNGKey(2), (2, 64))
    return hypersense.HyperSenseModel(C_hvs, B0, b, 6, 6, 3,
                                      t_score=t_score, t_detection=1)


NEVER = 1e9    # t_score no frame reaches -> gate never fires
ALWAYS = -1e9  # every scored frame fires -> HP bursts everywhere


def frames_of(n, seed=0, s=None):
    rng = np.random.default_rng(seed)
    shape = (n, *HW) if s is None else (s, n, *HW)
    return rng.normal(size=shape).astype(np.float32)


def drain_of(kind, model, trace):
    """Build runner `kind`, process `trace` (N,H,W), return drain_hp()."""
    if kind == "stream":
        r = StreamRunner(model, CFG, chunk_size=C, block_d=64,
                         control=CTL)
        r.process(trace)
        return r.drain_hp()
    if kind == "fleet":
        r = FleetRunner(model, CFG, chunk_size=C, block_d=64, control=CTL)
        r.process(trace[None])
        return r.drain_hp()[0]
    svc = FleetService(model, CFG, n_slots=1, chunk_size=C, block_d=64,
                       control=CTL)
    svc.attach(0)
    for t in range(0, len(trace), C):
        svc.dispatch({0: trace[t:t + C]})
    svc.flush()
    return svc.drain_hp(0)


# ---------------------------------------------------------------------------
# the (0, H, W) empty-drain shape contract  [regression: was (0, 0, 0)]
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["stream", "fleet", "service"])
def test_empty_drain_keeps_frame_shape(kind):
    """A drain with nothing captured still carries the real frame shape
    — the old (0, 0, 0) placeholder broke np.concatenate for every
    downstream consumer."""
    idx, frames = drain_of(kind, make_model(NEVER), frames_of(2 * C))
    assert idx.shape == (0,)
    assert frames.shape == (0, *HW)
    assert frames.dtype == np.float32
    # and it concatenates against a real burst, which is the point
    burst = np.ones((3, *HW), np.float32)
    assert np.concatenate([frames, burst]).shape == (3, *HW)


def test_empty_drain_before_any_frame_has_unknown_shape():
    r = StreamRunner(make_model(NEVER), CFG, chunk_size=C, block_d=64,
                     control=CTL)
    idx, frames = r.drain_hp()    # no frame ever seen: H, W unknowable
    assert idx.shape == (0,) and frames.shape == (0, 0, 0)


def test_hp_drain_arrays_shapes():
    idx, frames = hp_drain_arrays([], (7, 9))
    assert frames.shape == (0, 7, 9) and idx.dtype == np.int64
    idx, frames = hp_drain_arrays([], None)
    assert frames.shape == (0, 0, 0)
    idx, frames = hp_drain_arrays([(5, np.ones((7, 9)))], (7, 9))
    assert idx.tolist() == [5] and frames.shape == (1, 7, 9)
    assert frames.dtype == np.float32


# ---------------------------------------------------------------------------
# drains concatenate: interleaved empty/non-empty == one-shot, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["stream", "fleet", "service"])
def test_interleaved_drains_concatenate_to_one_shot(kind):
    model = make_model(ALWAYS)
    trace = frames_of(4 * C)
    ref_idx, ref_frames = drain_of(kind, model, trace)
    assert len(ref_idx) > 0

    # same trace, drained after every chunk (plus immediate re-drains,
    # which are empty) — concatenation must reproduce the one-shot drain
    if kind == "stream":
        r = StreamRunner(model, CFG, chunk_size=C, block_d=64,
                         control=CTL)
        drains = []
        for t in range(0, len(trace), C):
            r.process(trace[t:t + C])
            drains.append(r.drain_hp())
            drains.append(r.drain_hp())          # empty, (0, H, W)
    elif kind == "fleet":
        r = FleetRunner(model, CFG, chunk_size=C, block_d=64, control=CTL)
        drains = []
        for t in range(0, len(trace), C):
            r.process(trace[None, t:t + C])
            drains.append(r.drain_hp()[0])
            drains.append(r.drain_hp()[0])
    else:
        svc = FleetService(model, CFG, n_slots=1, chunk_size=C,
                           block_d=64, control=CTL)
        svc.attach(0)
        drains = []
        for t in range(0, len(trace), C):
            svc.dispatch({0: trace[t:t + C]})
            svc.flush()
            drains.append(svc.drain_hp(0))
            drains.append(svc.drain_hp(0))
    assert any(len(i) == 0 for i, _ in drains)   # empties interleaved
    idx = np.concatenate([i for i, _ in drains])
    frames = np.concatenate([f for _, f in drains])
    np.testing.assert_array_equal(idx, ref_idx)
    np.testing.assert_array_equal(frames, ref_frames)
    # absolute, strictly increasing across drain boundaries
    assert (np.diff(idx) > 0).all()


def test_indices_stay_absolute_across_process_calls():
    model = make_model(ALWAYS)
    trace = frames_of(3 * C)
    r = StreamRunner(model, CFG, chunk_size=C, block_d=64, control=CTL)
    r.process(trace[:C])
    first, _ = r.drain_hp()
    r.process(trace[C:])
    second, _ = r.drain_hp()
    assert len(first) and len(second)
    assert second.min() >= C          # not restarted at 0 after a drain
    both = np.concatenate([first, second])
    assert (np.diff(both) > 0).all()


# ---------------------------------------------------------------------------
# drain → checkpoint → restore preserves exactly the undrained frames
# ---------------------------------------------------------------------------

def test_drain_checkpoint_restore_preserves_undrained(tmp_path):
    model = make_model(ALWAYS)
    trace = frames_of(4 * C)
    td = os.fspath(tmp_path)

    def build():
        return FleetService(model, CFG, n_slots=1, chunk_size=C,
                            block_d=64, control=CTL, ckpt_dir=td)

    svc = build()
    svc.attach(0)
    svc.dispatch({0: trace[0:C]})
    svc.dispatch({0: trace[C:2 * C]})
    svc.flush()
    taken_idx, _ = svc.drain_hp(0)        # drained BEFORE the snapshot
    assert len(taken_idx)
    svc.dispatch({0: trace[2 * C:3 * C]})  # undrained burst accumulates
    svc.dispatch({0: trace[3 * C:4 * C]})
    svc.checkpoint()
    svc.wait_ckpt()
    ref_idx, ref_frames = svc.drain_hp(0)
    assert len(ref_idx)

    svc2 = build()
    svc2.restore()
    got_idx, got_frames = svc2.drain_hp(0)
    np.testing.assert_array_equal(got_idx, ref_idx)     # only undrained
    np.testing.assert_array_equal(got_frames, ref_frames)
    assert not np.intersect1d(got_idx, taken_idx).size


# ---------------------------------------------------------------------------
# assemble_capture_log (the runners' shared log assembly)
# ---------------------------------------------------------------------------

def test_assemble_capture_log_empty_and_axis():
    log = assemble_capture_log([], [], lp_bits=4, control=CTL,
                               frame_pixels=64)
    assert log.sampled.shape == (0,) and log.hp_bits == CTL.hp_bits
    fleet = assemble_capture_log([], [], lp_bits=None, control=None,
                                 frame_pixels=64, axis=1)
    assert fleet.sampled.shape == (0, 0) and fleet.hp_bits is None
    two = assemble_capture_log([np.ones((2, 3), bool)] * 2,
                               [np.zeros((2, 3), bool)] * 2,
                               lp_bits=None, control=None,
                               frame_pixels=64, axis=1)
    assert two.sampled.shape == (2, 6)


# ---------------------------------------------------------------------------
# energy: explicit handling of a depth-less (open-loop) log
# ---------------------------------------------------------------------------

def _log(hp_bits):
    gated = np.zeros(10, bool)
    gated[3:5] = True
    return CaptureLog(sampled=np.ones(10, bool), gated=gated,
                      lp_bits=None, hp_bits=hp_bits, frame_pixels=64)


def test_missing_hp_bits_defaults_to_params_depths():
    p = energy.EnergyParams()
    open_loop = energy.from_capture_log(_log(None), p)
    closed = energy.from_capture_log(_log(p.adc_hp_bits), p)
    assert open_loop == closed            # the documented convention


def test_missing_hp_bits_error_mode():
    with pytest.raises(ValueError, match="hp_bits"):
        energy.from_capture_log(_log(None), on_missing_bits="error")
    energy.from_capture_log(_log(12), on_missing_bits="error")  # fine
    with pytest.raises(ValueError, match="on_missing_bits"):
        energy.from_capture_log(_log(12), on_missing_bits="zero")


def test_cascade_system_accounting():
    cost = energy.BackboneCost(flops=1e6, bytes=1e5, joules=1e-3)
    with pytest.raises(ValueError, match="hp_bits"):
        energy.cascade_system(_log(None), cost)
    duty = _log(12).gated.mean()
    casc = energy.cascade_system(_log(12), cost)
    always = energy.always_on_backbone(cost)
    assert casc.cloud == pytest.approx(duty * cost.joules)
    assert always.cloud == pytest.approx(cost.joules)
    assert casc.total < always.total      # sparse duty must win


# ---------------------------------------------------------------------------
# detector cell: bitwise batch invariance (the cascade's foundation)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def detector():
    cfg = configs.get_smoke("hubert-xlarge")
    params = steps.init_detector_params(jax.random.PRNGKey(7), cfg,
                                        frame_hw=HW, patch=8)
    return cfg, params


def test_detector_cell_bitwise_batch_invariant(detector):
    cfg, params = detector
    cell = steps.build_detector_cell(cfg, batch=3, frame_hw=HW, patch=8)
    step = jax.jit(cell.step_fn)
    batch = frames_of(3, seed=5)
    out = np.asarray(step(params, batch))
    perm = [2, 0, 1]
    out_perm = np.asarray(step(params, batch[perm]))
    np.testing.assert_array_equal(out[perm], out_perm)
    # a row's logits don't depend on what it is co-batched with
    alone = np.asarray(step(params, np.stack(
        [batch[1], np.zeros(HW, np.float32), np.zeros(HW, np.float32)])))
    np.testing.assert_array_equal(alone[0], out[1])


def test_detector_cell_validates(detector):
    cfg, _ = detector
    with pytest.raises(ValueError, match="divide"):
        steps.build_detector_cell(cfg, batch=2, frame_hw=(15, 16),
                                  patch=8)
    with pytest.raises(ValueError, match="embeds-in"):
        steps.build_detector_cell(configs.get_smoke("olmo-1b"), batch=2,
                                  frame_hw=HW, patch=8)


# ---------------------------------------------------------------------------
# CascadeService: gate feed → batched async backbone, bitwise + 1 compile
# ---------------------------------------------------------------------------

def test_cascade_matches_eager_across_ragged_drains(detector):
    cfg, params = detector
    casc = CascadeService(params, cfg, batch_size=4, frame_hw=HW)
    frames = frames_of(9, seed=6)
    casc.submit("a", np.arange(2), frames[:2])            # partial
    casc.submit("a", [], np.zeros((0, *HW), np.float32))  # empty drain
    casc.submit("b", np.arange(3), frames[2:5])           # fills batch 1
    casc.submit("a", 2 + np.arange(4), frames[5:])        # fills batch 2
    batches = casc.flush()                                # + padded tail
    assert casc.queued == 0
    assert sum(b.n_padded for b in batches) > 0           # tail padded
    served = np.concatenate([b.logits for b in batches])
    order = np.concatenate([b.frame_idx for b in batches])
    assert served.shape == (9, casc.n_out)
    np.testing.assert_array_equal(served, casc.eager(frames))
    assert casc.compile_count() == 1                      # never retraced
    # provenance survives batching: (sid, absolute idx) per row
    sids = [s for b in batches for s in b.sids]
    assert sids == ["a", "a", "b", "b", "b", "a", "a", "a", "a"]
    np.testing.assert_array_equal(order,
                                  [0, 1, 0, 1, 2, 2, 3, 4, 5])


def test_cascade_pump_closes_the_loop(detector):
    """StreamRunner gate → pump → backbone: the full paper loop, with
    results keyed by the gate's absolute frame indices."""
    cfg, params = detector
    model = make_model(ALWAYS)
    r = StreamRunner(model, CFG, chunk_size=C, block_d=64, control=CTL)
    casc = CascadeService(params, cfg, batch_size=4, frame_hw=HW)
    trace = frames_of(3 * C, seed=8)
    hp = {}
    for t in range(0, len(trace), C):
        r.process(trace[t:t + C])
        idx, frames = r.drain_hp()
        hp.update({int(i): f for i, f in zip(idx, frames)})
        casc.submit(0, idx, frames)      # what pump() does per drain
    assert len(hp)
    batches = casc.flush()
    got = {int(i): row for b in batches
           for i, row in zip(b.frame_idx, b.logits)}
    assert set(got) == set(hp)
    eager = casc.eager(np.stack([hp[i] for i in sorted(hp)]))
    for j, i in enumerate(sorted(hp)):
        np.testing.assert_array_equal(got[i], eager[j])
    assert casc.compile_count() == 1


def test_cascade_rejects_mismatched_frames(detector):
    cfg, params = detector
    casc = CascadeService(params, cfg, batch_size=2, frame_hw=HW)
    with pytest.raises(ValueError, match="cascade"):
        casc.submit(0, [0], np.zeros((1, 8, 8), np.float32))
    with pytest.raises(ValueError, match="disagree"):
        casc.submit(0, [0, 1], np.zeros((1, *HW), np.float32))


# ---------------------------------------------------------------------------
# sanitizer-harness regression (repro.analysis.sanitize)
# ---------------------------------------------------------------------------

from repro.analysis import sanitize  # noqa: E402


def test_warm_cascade_batches_are_compile_clean(detector, compile_ledger):
    """Post-warmup cascade batches run entirely from the jit cache.

    The backbone compiles exactly once for the fixed ``(B, H, W)`` batch
    shape; every later launch — ragged submits included — must trigger
    zero fresh XLA compiles, and submitting *device* drains must not
    perform implicit transfers (host queueing is the waived, explicit
    admission boundary).
    """
    cfg, params = detector
    casc = CascadeService(params, cfg, batch_size=2, frame_hw=HW)
    casc.submit(0, [0, 1], frames_of(2, seed=1))         # warmup batch
    casc.flush()
    with compile_ledger.expect_no_compiles("warm cascade batches"), \
            sanitize.no_implicit_transfers(always=True):
        dev = jax.device_put(frames_of(2, seed=2))
        casc.submit(1, [2, 3], dev)                       # device drain
        casc.submit(0, [4], frames_of(1, seed=3))         # ragged tail
        got = casc.flush()
    assert sum(len(b.frame_idx) for b in got) == 3
