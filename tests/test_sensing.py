"""Sensing substrate: synthetic data, ADC, fragments, control, energy."""

try:  # prefer the real library when installed (requirements-dev.txt)
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # fallback keeps these tests running without the dep
    from _hypothesis_fallback import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import energy
from repro.core.sensor_control import (ControllerConfig, SensorController,
                                       simulate_stream)
from repro.sensing import adc, fragments, synthetic

jax.config.update("jax_platform_name", "cpu")


def test_dataset_balanced_and_masks_match_labels():
    cfg = synthetic.RadarConfig(height=32, width=32)
    frames, masks, labels = synthetic.make_dataset(
        jax.random.PRNGKey(0), 40, cfg)
    assert frames.shape == (40, 32, 32)
    assert abs(float(labels.mean()) - 0.5) < 0.11
    has_mask = np.asarray(masks.sum(axis=(1, 2)) > 0)
    np.testing.assert_array_equal(has_mask, np.asarray(labels) == 1)


def test_positive_frames_brighter_at_mask():
    cfg = synthetic.RadarConfig(height=32, width=32)
    frames, masks, labels = synthetic.make_dataset(
        jax.random.PRNGKey(1), 40, cfg)
    pos = np.asarray(labels) == 1
    inside = float((frames * masks).sum() / np.maximum(masks.sum(), 1))
    outside = float(frames[pos].mean())
    assert inside > outside


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_adc_quantization_levels(bits):
    x = jnp.linspace(0, 1.5, 1000)
    q = adc.quantize(x, bits)
    assert len(np.unique(np.asarray(q))) <= 2 ** bits
    assert float(jnp.abs(q - x).max()) <= 1.5 / (2 ** bits - 1) / 2 + 1e-6


@hypothesis.given(st.integers(0, 2**16), st.integers(1, 12))
@hypothesis.settings(max_examples=25, deadline=None)
def test_adc_quantize_exactly_codes_times_lsb(seed, bits):
    """quantize(x, b) == quantize_codes(x, b) * (v_max / levels), exactly.

    The float reconstruction and the integer near-sensor datapath must be
    the same quantizer bit-for-bit (quantize is *defined* via the codes).
    Inputs include out-of-range values that exercise the clip.
    """
    x = jax.random.uniform(jax.random.PRNGKey(seed), (33, 17),
                           minval=-0.5, maxval=2.0)
    levels = (1 << bits) - 1
    q = adc.quantize(x, bits)
    codes = adc.quantize_codes(x, bits)
    np.testing.assert_array_equal(
        np.asarray(q),
        np.asarray(codes, np.float32) * np.float32(1.5 / levels))
    # idempotence: requantizing a reconstruction is the identity
    np.testing.assert_array_equal(np.asarray(adc.quantize(q, bits)),
                                  np.asarray(q))


def test_adc_codes_integer_range():
    x = jax.random.uniform(jax.random.PRNGKey(2), (16, 16), maxval=1.5)
    codes = adc.quantize_codes(x, 4)
    assert codes.dtype == jnp.int32
    assert int(codes.min()) >= 0 and int(codes.max()) <= 15


def test_fragment_sampling_balanced_and_correct():
    cfg = synthetic.RadarConfig(height=32, width=32)
    frames, masks, _ = synthetic.make_dataset(jax.random.PRNGKey(3), 30,
                                              cfg)
    frags, labels = fragments.sample_fragments(
        np.asarray(frames), np.asarray(masks), h=8, w=8, per_frame=2,
        seed=0)
    assert frags.shape[1:] == (8, 8)
    assert abs(float(labels.mean()) - 0.5) < 1e-6    # exactly balanced


def test_controller_hysteresis():
    c = SensorController(ControllerConfig(hold_frames=2))
    assert c.step(True) is True
    assert c.step(False) is True      # hold 1
    assert c.step(False) is True      # hold 2
    assert c.step(False) is False     # off
    c.reset()
    assert c.step(False) is False


def test_stats_from_empty_class_is_nan():
    """A stream with zero object frames has an UNDEFINED missed-positive
    rate — NaN, never a clamped perfect 0.0 (and symmetrically for
    false_active on an all-object stream)."""
    from repro.core.sensor_control import stats_from, stats_from_batch

    gated = np.array([True, False, True, False])
    no_pos = stats_from(gated.copy(), gated, np.zeros(4, np.int32))
    assert np.isnan(no_pos.missed_positive)
    assert no_pos.false_active == 0.5
    no_neg = stats_from(gated.copy(), gated, np.ones(4, np.int32))
    assert np.isnan(no_neg.false_active)
    assert no_neg.missed_positive == 0.5
    assert no_pos.duty_cycle == 0.5          # always defined
    # propagates per stream through the batch accounting
    batch = stats_from_batch(np.stack([gated, gated]),
                             np.stack([gated, gated]),
                             np.stack([np.zeros(4, np.int32),
                                       np.array([0, 1, 0, 1])]))
    assert np.isnan(batch[0].missed_positive)
    assert batch[1].missed_positive == 1.0   # gated exactly off-phase
    assert batch[1].false_active == 1.0


def test_simulate_stream_empty_class_nan():
    frames = np.zeros((5, 4, 4), np.float32)
    stats = simulate_stream(lambda f: False, frames, np.zeros(5),
                            ControllerConfig(hold_frames=0))
    assert np.isnan(stats.missed_positive)
    assert stats.false_active == 0.0


def test_simulate_stream_counts():
    frames = np.zeros((10, 4, 4), np.float32)
    labels = np.array([0, 0, 1, 1, 0, 0, 0, 1, 0, 0])
    # oracle gate: fire exactly on positives
    stats = simulate_stream(lambda f: False, frames, labels,
                            ControllerConfig(hold_frames=0))
    assert stats.duty_cycle == 0.0
    assert stats.missed_positive == 1.0
    i = iter(labels)
    stats = simulate_stream(lambda f: bool(next(i)), frames, labels,
                            ControllerConfig(hold_frames=0))
    assert stats.missed_positive == 0.0
    assert stats.false_active == 0.0


# ---------------------------------------------------------------------------
# Energy model
# ---------------------------------------------------------------------------

def test_energy_conventional_vs_hypersense():
    p = energy.EnergyParams()
    conv = energy.conventional(p)
    ours = energy.hypersense(fpr=0.05, tpr=0.95, p_object=0.01, params=p)
    s = energy.savings(ours, conv)
    assert 0.5 < s["total_saving"] < 1.0
    assert ours.total < conv.total


@hypothesis.given(st.floats(0.0, 1.0), st.floats(0.0, 1.0),
                  st.floats(0.0, 0.5))
@hypothesis.settings(max_examples=50, deadline=None)
def test_energy_monotone_in_duty_cycle(fpr, tpr, p_obj):
    """More gating-on -> more energy; never exceeds conventional+HDC."""
    p = energy.EnergyParams()
    base = energy.hypersense(0.0, 0.0, p_obj, p)
    ours = energy.hypersense(fpr, tpr, p_obj, p)
    full = energy.hypersense(1.0, 1.0, p_obj, p)
    assert base.total <= ours.total + 1e-9 <= full.total + 1e-9
    conv = energy.conventional(p)
    assert full.total <= conv.total + p.hdc_accel_j + p.adc_lp_j + 1e-9


def test_calibrated_energy_matches_table3():
    p = energy.calibrate()
    conv = energy.conventional(p)
    for fpr, (tot, edge, ql) in energy.PAPER_TABLE_III.items():
        ours = energy.hypersense(fpr, 1 - ql, 0.01, p)
        s = energy.savings(ours, conv)
        # the old abs()-wrapped unconstrained LM fit bottomed out at max
        # residual ~0.0302; the bounded trf fit must do no worse (it
        # actually improves to ~0.0202 — asserted so a regression back
        # to the masked-sign behavior is visible)
        assert abs(s["total_saving"] - tot) < 0.0302, fpr
        assert abs(s["edge_saving"] - edge) < 0.0302, fpr


def test_calibrate_fit_is_physical():
    """The bounded fit can only return non-negative Joule constants —
    no abs() folding of a sign-flipped optimum."""
    p = energy.calibrate()
    assert p.rf_frontend_j >= 0.0
    assert p.comm_j_per_mbit >= 0.0
    assert p.cloud_j >= 0.0


def test_compressive_sensing_between():
    p = energy.EnergyParams()
    conv, bdc = energy.conventional(p), energy.compressive_sensing(p)
    assert bdc.total < conv.total
    ours = energy.hypersense(0.05, 0.95, 0.01, p)
    assert ours.total < bdc.total     # paper Fig. 17: ours < BDC < conv
