"""HyperSenseGate: HDC front-end gating of backend compute."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fragment_model as fm, gate, hypersense
from repro.core.sensor_control import ControllerConfig
from repro.sensing import adc, fragments, synthetic

jax.config.update("jax_platform_name", "cpu")


def _gate(key, hold=0):
    cfg = synthetic.RadarConfig(height=32, width=32)
    frames, masks, _ = synthetic.make_dataset(key, 30, cfg)
    frames = adc.quantize(frames, 4)
    frs, labs = fragments.sample_fragments(
        np.asarray(frames), np.asarray(masks), h=8, w=8, per_frame=2,
        seed=0)
    model, _ = fm.train_fragment_model(
        jax.random.fold_in(key, 1), jnp.asarray(frs), jnp.asarray(labs),
        dim=512, epochs=4)
    B0 = model.B.reshape(8, 8, -1)[:, 0, :]
    hs = hypersense.from_fragment_model(model, B0, h=8, w=8, stride=4)
    # pick an operating T_score from validation negatives (80th pct)
    neg, _, _ = synthetic.make_dataset(jax.random.fold_in(key, 5), 12, cfg)
    scores = np.asarray(hypersense.frame_scores_batch(
        hs, adc.quantize(neg, 4), 0))
    hs = hs._replace(t_score=float(np.quantile(scores, 0.8)))
    return gate.HyperSenseGate(hs, ControllerConfig(hold_frames=hold)), cfg


def test_gate_reduces_backend_compute():
    g, cfg = _gate(jax.random.PRNGKey(0))
    stream, labels = synthetic.make_stream(jax.random.PRNGKey(1), 80, cfg,
                                           event_prob=0.03, event_len=6)
    stream = adc.quantize(stream, 4)
    kept, idx = g.filter(stream)
    assert kept.shape[0] == len(idx) == g.stats.n_passed
    acct = gate.backend_flops_saved(g.stats, flops_per_item=1e12)
    assert 0.0 <= acct["duty_cycle"] < 1.0
    assert acct["backend_saving"] == 1.0 - acct["duty_cycle"]
    # the gate passes a minority of an idle-dominated stream
    assert acct["duty_cycle"] < 0.9


def test_gate_hysteresis_expands_selection():
    g0, cfg = _gate(jax.random.PRNGKey(2), hold=0)
    g3, _ = _gate(jax.random.PRNGKey(2), hold=3)
    stream, _ = synthetic.make_stream(jax.random.PRNGKey(3), 60, cfg,
                                      event_prob=0.05, event_len=5)
    stream = adc.quantize(stream, 4)
    idx0 = g0.select(stream)
    idx3 = g3.select(stream)
    assert set(idx0).issubset(set(idx3))
    assert len(idx3) >= len(idx0)
