"""Online-learning runtime: kernels-to-fleet mutable model state (ISSUE 3).

Contracts:

* the chunked online path — ``online.chunk_update`` folded chunk-by-chunk,
  and the adaptive runners built on it — reproduces ``retrain_epoch`` over
  the same sample sequence *exactly*, for any chunk size, on both
  backends;
* ``adapt=None`` runners stay bitwise-identical to the frozen pipeline
  (batched kernel scoring + ``gate_scan``) on the pallas backend;
* installing a new classifier is ``retile_classes`` (bitwise-equal to the
  host ``precompute_tiles``) and the runners' tile caches are keyed on
  class-hv *identity* — a mutated model can never score via stale tiles;
* the fleet's per-stream adaptation (one launch, stream-indexed class
  tiles) matches S independent adaptive runners.
"""

try:  # prefer the real library when installed (requirements-dev.txt)
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # fallback keeps these tests running without the dep
    from _hypothesis_fallback import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import encoding, fragment_model as fm, hypersense, online
from repro.core.online import AdaptConfig
from repro.core.sensor_control import ControllerConfig
from repro.kernels import ops as kops
from repro.kernels import sliding_scores as k_ss
from repro.sensing import synthetic
from repro.sensing.fleet import FleetRunner
from repro.sensing.stream import (StreamRunner, _top_fragment_hvs,
                                  gate_scan, model_geometry)

jax.config.update("jax_platform_name", "cpu")


def key(i):
    return jax.random.PRNGKey(i)


def make_model(h=6, w=6, stride=3, D=128, t_score=-0.05, t_detection=2):
    B0, b = encoding.make_perm_base_rows(key(1), h, D)
    C = jax.random.normal(key(2), (2, D))
    return hypersense.HyperSenseModel(C, B0, b, h, w, stride,
                                      t_score=t_score,
                                      t_detection=t_detection)


def make_fleet(S, N, seed=10, height=24, width=24):
    cfg = synthetic.RadarConfig(height=height, width=width)
    frames, labels = [], []
    for s in range(S):
        f, _, y = synthetic.make_dataset(key(seed + s), N, cfg)
        frames.append(f)
        labels.append(np.asarray(y))
    return jnp.stack(frames), np.stack(labels)


# ---------------------------------------------------------------------------
# core rule: chunked online path == retrain_epoch
# ---------------------------------------------------------------------------

def test_online_update_is_retrain_step():
    hvs = jax.random.normal(key(0), (1, 64))
    chvs = jax.random.normal(key(1), (2, 64))
    y = jnp.array(1)
    got, _ = online.online_update(chvs, hvs[0], y, 0.7)
    want = fm.retrain_epoch(chvs, hvs, y[None], 0.7)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@hypothesis.given(st.integers(0, 2**16), st.integers(1, 60))
@hypothesis.settings(max_examples=15, deadline=None)
def test_chunked_online_equals_retrain_epoch_property(seed, chunk_size):
    """Folding chunk_update over ANY chunking of a sample sequence is
    bitwise the single retrain_epoch pass (the running-state property)."""
    k = key(seed)
    n = 37
    hvs = jax.random.normal(k, (n, 64))
    labels = jax.random.randint(jax.random.fold_in(k, 1), (n,), 0, 2)
    chvs0 = jax.random.normal(jax.random.fold_in(k, 2), (2, 64))
    want = fm.retrain_epoch(chvs0, hvs, labels, 0.8)
    chvs = chvs0
    for a in range(0, n, chunk_size):
        chvs, _ = online.chunk_update(chvs, hvs[a:a + chunk_size],
                                      labels[a:a + chunk_size], lr=0.8)
    np.testing.assert_array_equal(np.asarray(chvs), np.asarray(want))


def test_chunk_update_valid_mask_is_exact_noop():
    """Masked (padded-tail) samples leave the state bitwise untouched."""
    hvs = jax.random.normal(key(3), (10, 64))
    labels = jax.random.randint(key(4), (10,), 0, 2)
    chvs0 = jax.random.normal(key(5), (2, 64))
    want, _ = online.chunk_update(chvs0, hvs[:7], labels[:7])
    valid = jnp.arange(10) < 7
    got, wrong = online.chunk_update(chvs0, hvs, labels, valid=valid)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert not bool(np.asarray(wrong)[7:].any())


def test_pseudo_update_confidence_gate():
    hvs = jax.random.normal(key(6), (20, 64))
    chvs0 = jax.random.normal(key(7), (2, 64))
    # impossible confidence -> bitwise no-op
    same, did = online.chunk_update_pseudo(chvs0, hvs, confidence=10.0)
    np.testing.assert_array_equal(np.asarray(same), np.asarray(chvs0))
    assert not bool(np.asarray(did).any())
    # zero confidence -> every sample reinforces its predicted class
    moved, did = online.chunk_update_pseudo(chvs0, hvs, confidence=0.0)
    assert bool(np.asarray(did).all())
    assert not np.array_equal(np.asarray(moved), np.asarray(chvs0))


def test_apply_chunk_rejects_unknown_mode():
    with pytest.raises(ValueError):
        online.apply_chunk(AdaptConfig(mode="nope"),
                           jnp.zeros((2, 8)), jnp.zeros((1, 8)),
                           jnp.zeros(1, jnp.int32))


# ---------------------------------------------------------------------------
# kernel precompute split: geometry + retile
# ---------------------------------------------------------------------------

def test_retile_matches_precompute_tiles_bitwise():
    m = make_model()
    W = 24
    tiles = kops.precompute_tiles(m.B0, m.b, m.class_hvs, W=W, w=m.w,
                                  stride=m.stride, block_d=64)
    geom = kops.precompute_geometry(m.B0, m.b, W=W, w=m.w,
                                    stride=m.stride, block_d=64)
    got = kops.retile_classes(geom, m.class_hvs)
    for f in ("cpos_t", "cneg_t", "cpos_norm", "cneg_norm"):
        np.testing.assert_array_equal(np.asarray(getattr(tiles, f)),
                                      np.asarray(getattr(got, f)))
    np.testing.assert_array_equal(np.asarray(tiles.slabs),
                                  np.asarray(got.slabs))
    np.testing.assert_array_equal(np.asarray(tiles.bias_t),
                                  np.asarray(got.bias_t))


def test_per_stream_tiles_single_launch_matches_per_classifier():
    """(S, n_dt, mx, TD) class tiles + frames_per_stream: one launch,
    bitwise equal to separate launches per classifier."""
    m = make_model()
    W, C_frames = 24, 2
    geom = model_geometry(m, W, 64)
    chvs2 = jax.random.normal(key(8), (2, 128))
    frames = jax.random.uniform(key(9), (4, W, W))
    ps = k_ss.retile_classes_fleet(geom, jnp.stack([m.class_hvs, chvs2]))
    got = k_ss.fragment_scores_batch(frames, ps, h=m.h, w=m.w,
                                     stride=m.stride, interpret=True,
                                     frames_per_stream=C_frames)
    want = jnp.concatenate([
        k_ss.fragment_scores_batch(frames[:2],
                                   k_ss.retile_classes(geom, m.class_hvs),
                                   h=m.h, w=m.w, stride=m.stride,
                                   interpret=True),
        k_ss.fragment_scores_batch(frames[2:],
                                   k_ss.retile_classes(geom, chvs2),
                                   h=m.h, w=m.w, stride=m.stride,
                                   interpret=True)])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_per_stream_tiles_validates_batch_factorization():
    m = make_model()
    geom = model_geometry(m, 24, 64)
    ps = k_ss.retile_classes_fleet(geom, jnp.stack([m.class_hvs] * 2))
    frames = jax.random.uniform(key(9), (4, 24, 24))
    with pytest.raises(ValueError):
        k_ss.fragment_scores_batch(frames, ps, h=m.h, w=m.w,
                                   stride=m.stride, interpret=True)
    with pytest.raises(ValueError):
        k_ss.fragment_scores_batch(frames, ps, h=m.h, w=m.w,
                                   stride=m.stride, interpret=True,
                                   frames_per_stream=3)


# ---------------------------------------------------------------------------
# frozen path: adapt=None is the pre-refactor pipeline, bitwise (pallas)
# ---------------------------------------------------------------------------

def test_frozen_runner_bitwise_matches_direct_kernel_pipeline():
    """StreamRunner(adapt=None, backend="pallas") == hand-rolled frozen
    pipeline: host tiles -> fragment_scores_batch per chunk ->
    frame_detection_score -> threshold -> gate_scan. Bitwise."""
    m = make_model()
    frames, _ = make_fleet(S=1, N=19)
    frames = frames[0]
    r = StreamRunner(m, ControllerConfig(hold_frames=2), chunk_size=8,
                     backend="pallas", block_d=64)
    s_got, f_got, g_got = r.process(frames)

    tiles = kops.precompute_tiles(m.B0, m.b, m.class_hvs, W=24, w=m.w,
                                  stride=m.stride, block_d=64)
    s_ref, f_ref = [], []
    for a in range(0, 19, 8):
        chunk = frames[a:a + 8]
        n_valid = chunk.shape[0]
        if n_valid < 8:
            chunk = jnp.pad(chunk, ((0, 8 - n_valid), (0, 0), (0, 0)))
        maps = k_ss.fragment_scores_batch(chunk, tiles, h=m.h, w=m.w,
                                          stride=m.stride, interpret=True)
        s = jax.vmap(lambda mp: hypersense.frame_detection_score(
            mp, m.t_detection))(maps)[:n_valid]
        s_ref.append(np.asarray(s))
        f_ref.append(np.asarray(s) > m.t_score)
    s_ref = np.concatenate(s_ref)
    f_ref = np.concatenate(f_ref)
    g_ref, _ = gate_scan(jnp.asarray(f_ref), 2)
    np.testing.assert_array_equal(s_got, s_ref)
    np.testing.assert_array_equal(f_got, f_ref)
    np.testing.assert_array_equal(g_got, np.asarray(g_ref))


# ---------------------------------------------------------------------------
# adaptive runners == manual chunk-start-score + retrain-rule fold
# ---------------------------------------------------------------------------

def _manual_adaptive(m, frames, labels, chunk_size, backend, lr):
    """Reference: score each chunk with its chunk-start classifier, fold
    the top-fragment HVs through the retrain rule (== retrain_epoch over
    the extracted sample sequence)."""
    chvs = m.class_hvs
    scores = []
    n = frames.shape[0]
    mx = encoding.num_windows(frames.shape[-1], m.w, m.stride)
    for a in range(0, n, chunk_size):
        ch = frames[a:a + chunk_size]
        maps = jnp.stack([hypersense.fragment_score_map(
            f, chvs, m.B0, m.b, h=m.h, w=m.w, stride=m.stride,
            backend=backend) for f in ch])
        scores.append(np.asarray(jax.vmap(
            lambda mp: hypersense.frame_detection_score(
                mp, m.t_detection))(maps)))
        hv = _top_fragment_hvs(ch[None], maps[None], m.B0, m.b, h=m.h,
                               w=m.w, stride=m.stride, mx=mx,
                               nonlinearity=m.nonlinearity)[0]
        chvs = fm.retrain_epoch(chvs, hv,
                                jnp.asarray(labels[a:a + chunk_size]), lr)
    return np.concatenate(scores), np.asarray(chvs)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("chunk_size", [1, 4, 16])
def test_adaptive_runner_equals_retrain_fold(backend, chunk_size):
    """The chunked online path == retrain_epoch over the same extracted
    sample sequence — any chunk size, both backends (ISSUE 3 property)."""
    m = make_model()
    frames, labels = make_fleet(S=1, N=13)
    frames, labels = frames[0], labels[0]
    r = StreamRunner(m, ControllerConfig(hold_frames=2),
                     chunk_size=chunk_size, backend=backend, block_d=64,
                     adapt=AdaptConfig(mode="label", lr=0.4))
    s_got, _, _ = r.process(frames, labels=labels)
    s_want, chvs_want = _manual_adaptive(m, frames, labels, chunk_size,
                                         backend, 0.4)
    np.testing.assert_allclose(s_got, s_want, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(r.class_hvs), chvs_want,
                               rtol=1e-4, atol=1e-4)


def test_adaptive_runner_slicing_invariance():
    """Chunk boundaries are fixed by chunk_size and the carried state, so
    re-slicing process() calls must not change the learning trajectory
    when the slices align with chunk boundaries."""
    m = make_model()
    frames, labels = make_fleet(S=1, N=16)
    frames, labels = frames[0], labels[0]
    ad = AdaptConfig(mode="label", lr=0.4)
    whole = StreamRunner(m, ControllerConfig(hold_frames=2), chunk_size=4,
                         adapt=ad)
    s_all, _, _ = whole.process(frames, labels=labels)
    split = StreamRunner(m, ControllerConfig(hold_frames=2), chunk_size=4,
                         adapt=ad)
    parts = [split.process(frames[a:z], labels=labels[a:z])
             for a, z in [(0, 4), (4, 12), (12, 16)]]
    np.testing.assert_allclose(np.concatenate([p[0] for p in parts]),
                               s_all, rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(whole.class_hvs),
                                  np.asarray(split.class_hvs))


def test_stream_runner_rejects_per_stream_scope():
    with pytest.raises(ValueError):
        StreamRunner(make_model(),
                     adapt=AdaptConfig(mode="label", scope="per-stream"))


def test_adaptive_runner_requires_labels():
    m = make_model()
    r = StreamRunner(m, adapt=AdaptConfig(mode="label"))
    with pytest.raises(ValueError):
        r.process(jnp.zeros((4, 24, 24)))
    fr = FleetRunner(m, adapt=AdaptConfig(mode="label"))
    with pytest.raises(ValueError):
        fr.process(jnp.zeros((2, 4, 24, 24)))
    with pytest.raises(ValueError):       # wrong label shape
        fr.process(jnp.zeros((2, 4, 24, 24)), labels=np.zeros((2, 3)))


def test_stream_state_frame_idx_advances():
    m = make_model()
    r = StreamRunner(m, ControllerConfig(hold_frames=2), chunk_size=4)
    frames, _ = make_fleet(S=1, N=11)
    r.process(frames[0])
    assert int(np.asarray(r._state.frame_idx)) == 11


# ---------------------------------------------------------------------------
# fleet adaptation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_fleet_per_stream_adapt_equals_independent_runners(backend):
    """Per-stream fleet adaptation (ONE launch, stream-indexed class
    tiles) == S independent adaptive StreamRunners."""
    m = make_model()
    frames, labels = make_fleet(S=3, N=13)
    fr = FleetRunner(m, ControllerConfig(hold_frames=2), chunk_size=4,
                     backend=backend, block_d=64,
                     adapt=AdaptConfig(mode="label", lr=0.3,
                                       scope="per-stream"))
    s_f, f_f, g_f = fr.process(frames, labels=labels)
    assert fr.class_hvs.shape == (3, 2, 128)
    for s in range(3):
        r = StreamRunner(m, ControllerConfig(hold_frames=2), chunk_size=4,
                         backend=backend, block_d=64,
                         adapt=AdaptConfig(mode="label", lr=0.3))
        s_i, f_i, g_i = r.process(frames[s], labels=labels[s])
        np.testing.assert_allclose(s_f[s], s_i, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(fr.class_hvs)[s],
                                   np.asarray(r.class_hvs),
                                   rtol=1e-4, atol=1e-4)


def test_fleet_shared_adapt_folds_time_ordered():
    """Shared-scope fleet: ONE classifier, samples folded in time order
    (stream index breaks ties) == retrain_epoch over that ordering."""
    m = make_model()
    S, N, cs = 2, 8, 4
    frames, labels = make_fleet(S=S, N=N)
    fr = FleetRunner(m, ControllerConfig(hold_frames=2), chunk_size=cs,
                     adapt=AdaptConfig(mode="label", lr=0.4,
                                       scope="shared"))
    fr.process(frames, labels=labels)

    chvs = m.class_hvs
    mx = encoding.num_windows(frames.shape[-1], m.w, m.stride)
    for a in range(0, N, cs):
        ch = frames[:, a:a + cs]
        maps = jnp.stack([jnp.stack([hypersense.fragment_score_map(
            f, chvs, m.B0, m.b, h=m.h, w=m.w, stride=m.stride)
            for f in ch[s]]) for s in range(S)])
        hv = _top_fragment_hvs(ch, maps, m.B0, m.b, h=m.h, w=m.w,
                               stride=m.stride, mx=mx,
                               nonlinearity=m.nonlinearity)     # (S, C, D)
        c = ch.shape[1]
        hv_t = jnp.transpose(hv, (1, 0, 2)).reshape(c * S, -1)
        lab_t = jnp.asarray(labels[:, a:a + cs]).T.reshape(c * S)
        chvs = fm.retrain_epoch(chvs, hv_t, lab_t, 0.4)
    np.testing.assert_allclose(np.asarray(fr.class_hvs), np.asarray(chvs),
                               rtol=1e-4, atol=1e-4)


def test_fleet_shared_adapt_sharded_folds_time_ordered():
    """Shared-scope fleet UNDER a sensor mesh: the all-gathered fold —
    not a host fallback — still equals retrain_epoch over the global
    time-ordered sequence, with a non-divisible S exercising masked pad
    slots. The sharded run is also bitwise-equal to the unsharded one."""
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    from repro.distributed import sharding as shlib

    m = make_model()
    S, N, cs = 3, 8, 4                         # S=3 never divides >=2 devs
    frames, labels = make_fleet(S=S, N=N)

    def run(mesh):
        fr = FleetRunner(m, ControllerConfig(hold_frames=2), chunk_size=cs,
                         adapt=AdaptConfig(mode="label", lr=0.4,
                                           scope="shared"))
        if mesh is None:
            fr.process(frames, labels=labels)
        else:
            with shlib.use_mesh(mesh):
                fr.process(frames, labels=labels)
        return fr

    mesh = jax.make_mesh((jax.device_count(), 1), ("data", "model"))
    fr = run(mesh)
    # the step really sharded: no shared-scope fallback survives
    assert fr._step_key[1] == ("data",)
    np.testing.assert_array_equal(np.asarray(fr.class_hvs),
                                  np.asarray(run(None).class_hvs))

    chvs = m.class_hvs
    mx = encoding.num_windows(frames.shape[-1], m.w, m.stride)
    for a in range(0, N, cs):
        ch = frames[:, a:a + cs]
        maps = jnp.stack([jnp.stack([hypersense.fragment_score_map(
            f, chvs, m.B0, m.b, h=m.h, w=m.w, stride=m.stride)
            for f in ch[s]]) for s in range(S)])
        hv = _top_fragment_hvs(ch, maps, m.B0, m.b, h=m.h, w=m.w,
                               stride=m.stride, mx=mx,
                               nonlinearity=m.nonlinearity)     # (S, C, D)
        c = ch.shape[1]
        hv_t = jnp.transpose(hv, (1, 0, 2)).reshape(c * S, -1)
        lab_t = jnp.asarray(labels[:, a:a + cs]).T.reshape(c * S)
        chvs = fm.retrain_epoch(chvs, hv_t, lab_t, 0.4)
    np.testing.assert_allclose(np.asarray(fr.class_hvs), np.asarray(chvs),
                               rtol=1e-4, atol=1e-4)


def test_chunk_update_interleaved_mask_is_exact_noop():
    """Pad-slot samples land INTERLEAVED in the time-major fold (every
    frame contributes one sample per padded stream slot), not just at the
    tail — masked anywhere, they must leave the fold bitwise on the
    no-pad trajectory."""
    hvs = jax.random.normal(key(6), (12, 64))
    labels = jax.random.randint(key(7), (12,), 0, 2)
    chvs0 = jax.random.normal(key(8), (2, 64))
    keep = jnp.asarray([True, True, False, True, True, False,
                        True, True, False, True, True, False])
    want, _ = online.chunk_update(chvs0, hvs[keep], labels[keep])
    got, wrong = online.chunk_update(chvs0, hvs, labels, valid=keep)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert not bool(np.asarray(wrong)[~np.asarray(keep)].any())


def test_fleet_frozen_still_bitwise_after_refactor():
    """adapt=None fleet: still bitwise equal per-stream to frozen
    StreamRunners on pallas (the ISSUE 2 contract survives ISSUE 3)."""
    m = make_model()
    frames, _ = make_fleet(S=3, N=9)
    fr = FleetRunner(m, ControllerConfig(hold_frames=1), chunk_size=4,
                     backend="pallas", block_d=64)
    s_f, _, _ = fr.process(frames)
    for s in range(3):
        r = StreamRunner(m, ControllerConfig(hold_frames=1), chunk_size=4,
                         backend="pallas", block_d=64)
        s_i, _, _ = r.process(frames[s])
        np.testing.assert_array_equal(s_f[s], s_i)


# ---------------------------------------------------------------------------
# tile-cache identity keying (stale-precompute impossibility)
# ---------------------------------------------------------------------------

def test_set_class_hvs_refreshes_tiles_mid_stream():
    m = make_model()
    frames, _ = make_fleet(S=1, N=8)
    frames = frames[0]
    r = StreamRunner(m, ControllerConfig(hold_frames=2), chunk_size=4,
                     backend="pallas", block_d=64)
    s_before, _, _ = r.process(frames)
    chvs2 = jax.random.normal(key(30), (2, 128))
    r.set_class_hvs(chvs2)
    s_after, _, _ = r.process(frames)
    fresh = StreamRunner(m._replace(class_hvs=chvs2),
                         ControllerConfig(hold_frames=2), chunk_size=4,
                         backend="pallas", block_d=64)
    s_fresh, _, _ = fresh.process(frames)
    np.testing.assert_array_equal(s_after, s_fresh)
    assert not np.array_equal(s_before, s_after)


def test_fleet_set_class_hvs_refreshes_tiles_mid_stream():
    m = make_model()
    frames, _ = make_fleet(S=2, N=8)
    fr = FleetRunner(m, ControllerConfig(hold_frames=2), chunk_size=4,
                     backend="pallas", block_d=64)
    fr.process(frames)
    chvs2 = jax.random.normal(key(31), (2, 128))
    fr.set_class_hvs(chvs2)
    s_after, _, _ = fr.process(frames)
    fresh = FleetRunner(m._replace(class_hvs=chvs2),
                        ControllerConfig(hold_frames=2), chunk_size=4,
                        backend="pallas", block_d=64)
    s_fresh, _, _ = fresh.process(frames)
    np.testing.assert_array_equal(s_after, s_fresh)


def test_fleet_set_per_stream_class_hvs_before_first_process():
    """An (S, 2, D) classifier installed before any process() call must
    be honored (not silently replaced by the model's on first chunk)."""
    m = make_model()
    frames, labels = make_fleet(S=2, N=8)
    ad = AdaptConfig(mode="label", lr=0.0, scope="per-stream")
    chvs = jax.random.normal(key(32), (2, 2, 128))
    fr = FleetRunner(m, ControllerConfig(hold_frames=2), chunk_size=4,
                     adapt=ad)
    fr.set_class_hvs(chvs)
    s_got, _, _ = fr.process(frames, labels=labels)
    for s in range(2):
        r = StreamRunner(m._replace(class_hvs=chvs[s]),
                         ControllerConfig(hold_frames=2), chunk_size=4)
        s_i, _, _ = r.process(frames[s])
        np.testing.assert_allclose(s_got[s], s_i, rtol=1e-5, atol=1e-5)
    # ...and a per-stream stack without per-stream scope is rejected
    with pytest.raises(ValueError):
        FleetRunner(m, adapt=AdaptConfig(mode="label")).set_class_hvs(chvs)


def test_frozen_tile_cache_does_not_churn():
    """adapt=None: repeated process() calls must reuse the cached tiles
    object (identity key stable across chunks)."""
    m = make_model()
    frames, _ = make_fleet(S=1, N=8)
    r = StreamRunner(m, ControllerConfig(hold_frames=2), chunk_size=4,
                     backend="pallas", block_d=64)
    r.process(frames[0])
    first = r._tiles
    r.process(frames[0])
    assert r._tiles is first


# ---------------------------------------------------------------------------
# drift generators
# ---------------------------------------------------------------------------

def test_drift_stream_shapes_and_schedules():
    cfg = synthetic.RadarConfig(height=24, width=24)
    drift = synthetic.DriftConfig(background_gain=(0.0, 0.5),
                                  noise_sigma=(0.1, 0.3),
                                  object_intensity=(0.8, 0.4))
    frames, labels = synthetic.make_drift_stream(key(40), 60, cfg, drift,
                                                 event_prob=0.1,
                                                 event_len=5)
    assert frames.shape == (60, 24, 24)
    assert labels.shape == (60,)
    sched = synthetic.drift_schedule(60, (0.0, 0.5))
    assert sched[0] == 0.0 and sched[-1] == pytest.approx(0.5)
    # the background-gain ramp must show up: late background >> early
    f = np.asarray(frames)
    y = np.asarray(labels).astype(bool)
    early = f[:20][~y[:20]].mean() if (~y[:20]).any() else f[:20].mean()
    late = f[-20:][~y[-20:]].mean() if (~y[-20:]).any() else f[-20:].mean()
    assert late > early + 0.2


def test_drift_stream_defaults_match_make_stream_stats():
    """Default DriftConfig = no drift: same generator statistics as
    make_stream (same event machinery, same speckle law)."""
    cfg = synthetic.RadarConfig(height=64, width=64, noise_sigma=0.3)
    a, la = synthetic.make_drift_stream(key(41), 50, cfg)
    b, lb = synthetic.make_stream(key(41), 50, cfg)
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# satellite: detect_batch via the batched scorer; top_k order statistic
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_detect_batch_matches_per_frame_detect(backend):
    m = make_model(t_detection=1)
    frames, _ = make_fleet(S=1, N=7)
    frames = frames[0]
    got = hypersense.detect_batch(m, frames, backend=backend)
    want = jnp.stack([hypersense.detect(m, f, backend=backend)
                      for f in frames])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_detect_batch_t_detection_beyond_fragments_never_fires():
    m = make_model(t_detection=10_000)
    frames, _ = make_fleet(S=1, N=5)
    got = hypersense.detect_batch(m, frames[0])
    assert not bool(np.asarray(got).any())


@hypothesis.given(st.integers(0, 2**16), st.integers(0, 40))
@hypothesis.settings(max_examples=25, deadline=None)
def test_frame_detection_score_topk_equals_sort(seed, td):
    """lax.top_k path == the full-sort definition, any t_detection."""
    rng = np.random.RandomState(seed)
    scores = jnp.asarray(rng.randn(5, 6).astype(np.float32))
    flat = np.sort(np.asarray(scores).ravel())[::-1]
    k = min(td, flat.size - 1)
    got = hypersense.frame_detection_score(scores, td)
    assert float(got) == float(flat[k])
