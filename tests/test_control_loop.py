"""Closed-loop capture runtime invariants (ISSUE 5).

The contracts that make gate-driven variable-rate/-precision capture
safe to turn on:

* ``control_scan`` (the jittable rate-aware controller) is exactly
  :class:`~repro.core.sensor_control.RateController` for arbitrary
  decision sequences, decimations, and carried-in state;
* with the loop *disabled* (``subsample=False``, or
  ``base_rate_hz == active_rate_hz``) the closed-loop runners are
  **bitwise identical** to the open-loop runners — on both backends and
  both precisions;
* capture-log billing (:func:`repro.core.energy.from_capture_log`)
  reduces *exactly* to the duty-fraction account
  (:func:`~repro.core.energy.hypersense_measured`) when every frame is
  sampled, and strictly undercuts it when idle frames are skipped;
* the HP burst deliverable is the ``hp_bits`` quantization of the raw
  frames at exactly the gated indices, bounded by the buffer size;
* stream slicing stays invisible with the control state in the carry,
  and a closed-loop fleet equals independent closed-loop stream runners.
"""

try:  # prefer the real library when installed (requirements-dev.txt)
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # fallback keeps these tests running without the dep
    from _hypothesis_fallback import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import encoding, energy, hypersense
from repro.core.sensor_control import (CaptureConfig, CaptureLog,
                                       ControllerConfig, RateController,
                                       decimation, stats_from)
from repro.sensing import adc, synthetic
from repro.sensing.fleet import FleetRunner, fleet_report
from repro.sensing.stream import StreamRunner, control_scan, hp_capture

jax.config.update("jax_platform_name", "cpu")


def key(i):
    return jax.random.PRNGKey(i)


def make_model(h=6, w=6, stride=3, D=128, t_score=0.0, t_detection=2):
    B0, b = encoding.make_perm_base_rows(key(1), h, D)
    C = jax.random.normal(key(2), (2, D))
    return hypersense.HyperSenseModel(C, B0, b, h, w, stride,
                                      t_score=t_score,
                                      t_detection=t_detection)


_FRAMES = {}


def stream_inputs(n=41, seed=3):
    if (n, seed) not in _FRAMES:
        cfg = synthetic.RadarConfig(height=24, width=24)
        frames, _, labels = synthetic.make_dataset(key(seed), n, cfg)
        _FRAMES[(n, seed)] = (frames, np.asarray(labels))
    return _FRAMES[(n, seed)]


# ---------------------------------------------------------------------------
# control_scan == RateController
# ---------------------------------------------------------------------------

def test_decimation_values_and_validation():
    assert decimation(ControllerConfig(base_rate_hz=10,
                                       active_rate_hz=60)) == 6
    assert decimation(ControllerConfig(base_rate_hz=60,
                                       active_rate_hz=60)) == 1
    with pytest.raises(ValueError, match="cannot be slower"):
        decimation(ControllerConfig(base_rate_hz=60, active_rate_hz=10))
    with pytest.raises(ValueError, match="positive"):
        decimation(ControllerConfig(base_rate_hz=0.0))


@hypothesis.given(st.integers(0, 2**16), st.integers(0, 6),
                  st.integers(1, 8), st.integers(0, 6), st.integers(0, 7),
                  st.integers(1, 300))
@hypothesis.settings(max_examples=30, deadline=None)
def test_control_scan_matches_rate_controller(seed, hold, decim,
                                              init_hold, init_phase, n):
    """control_scan == RateController for arbitrary decision sequences,
    decimations, hold lengths, and carried-in (hold, phase) state."""
    rng = np.random.RandomState(seed)
    fired = rng.rand(n) < rng.uniform(0.0, 1.0)
    init_phase = min(init_phase, decim - 1)
    ctrl = RateController(ControllerConfig(
        base_rate_hz=60.0 / decim, active_rate_hz=60.0, hold_frames=hold))
    assert ctrl.decim == decim
    ctrl._hold, ctrl._phase = init_hold, init_phase
    want = [ctrl.step(bool(f)) for f in fired]
    smp, gt, holds, phases = control_scan(jnp.asarray(fired), hold, decim,
                                          init_hold, init_phase)
    np.testing.assert_array_equal(np.asarray(smp),
                                  np.array([w[0] for w in want]))
    np.testing.assert_array_equal(np.asarray(gt),
                                  np.array([w[1] for w in want]))
    assert int(holds[-1]) == ctrl._hold
    assert int(phases[-1]) == ctrl._phase
    # resuming from the carried state continues identically
    cut = rng.randint(1, n) if n > 1 else 1
    s_a, g_a, h_a, p_a = control_scan(jnp.asarray(fired[:cut]), hold,
                                      decim, init_hold, init_phase)
    s_b, g_b, _, _ = control_scan(jnp.asarray(fired[cut:]), hold, decim,
                                  h_a[-1], p_a[-1])
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(g_a), np.asarray(g_b)]),
        np.asarray(gt))
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(s_a), np.asarray(s_b)]),
        np.asarray(smp))


def test_control_scan_decim_one_is_gate_scan():
    """decim == 1: every frame sampled, gated == gate_scan bitwise."""
    from repro.sensing.stream import gate_scan
    rng = np.random.RandomState(7)
    fired = jnp.asarray(rng.rand(200) < 0.2)
    smp, gt, holds, _ = control_scan(fired, 3, 1)
    want_g, want_h = gate_scan(fired, 3)
    assert bool(np.asarray(smp).all())
    np.testing.assert_array_equal(np.asarray(gt), np.asarray(want_g))
    np.testing.assert_array_equal(np.asarray(holds), np.asarray(want_h))


def test_idle_decimation_schedule():
    """No detections: exactly one LP sample per decim period, starting at
    frame 0 — the base_rate_hz trickle."""
    smp, gt, _, _ = control_scan(jnp.zeros(20, bool), 3, 4)
    np.testing.assert_array_equal(np.asarray(smp),
                                  np.arange(20) % 4 == 0)
    assert not np.asarray(gt).any()


# ---------------------------------------------------------------------------
# closed loop disabled == open loop, bitwise (both backends/precisions)
# ---------------------------------------------------------------------------

RATES = ControllerConfig(base_rate_hz=10, active_rate_hz=60,
                         hold_frames=3)


@pytest.mark.parametrize("backend,precision", [
    ("jnp", "float32"), ("pallas", "float32"),
    ("jnp", "int8"), ("pallas", "int8"),
])
def test_disabled_control_bitwise_identical(backend, precision):
    """subsample=False AND base==active: both bitwise == control=None."""
    frames, _ = stream_inputs()
    model = make_model()
    kw = dict(chunk_size=8, backend=backend, precision=precision,
              block_d=64)
    if precision == "int8":
        kw["adc_bits"] = 8
    ref = StreamRunner(model, RATES, **kw)
    s0, f0, g0 = ref.process(frames)
    off = StreamRunner(model, RATES, **kw,
                       control=CaptureConfig(subsample=False, hp_buffer=0))
    s1, f1, g1 = off.process(frames)
    flat = ControllerConfig(base_rate_hz=60, active_rate_hz=60,
                            hold_frames=3)
    same = StreamRunner(model, flat, **kw,
                        control=CaptureConfig(hp_buffer=0))
    s2, f2, g2 = same.process(frames)
    for s, f, g in [(s1, f1, g1), (s2, f2, g2)]:
        np.testing.assert_array_equal(s, s0)
        np.testing.assert_array_equal(f, f0)
        np.testing.assert_array_equal(g, g0)
    assert off.capture_log.sampled.all()
    assert same._decim == 1 and off._decim == 1


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_fleet_disabled_control_bitwise_identical(backend):
    frames, _ = stream_inputs(22)
    fl = jnp.stack([frames[:11], frames[11:]])
    model = make_model()
    ref = FleetRunner(model, RATES, chunk_size=4, backend=backend,
                      block_d=64)
    s0, f0, g0 = ref.process(fl)
    off = FleetRunner(model, RATES, chunk_size=4, backend=backend,
                      block_d=64,
                      control=CaptureConfig(subsample=False, hp_buffer=0))
    s1, f1, g1 = off.process(fl)
    np.testing.assert_array_equal(s1, s0)
    np.testing.assert_array_equal(f1, f0)
    np.testing.assert_array_equal(g1, g0)
    assert off.capture_log.sampled.all()


# ---------------------------------------------------------------------------
# closed-loop semantics
# ---------------------------------------------------------------------------

def test_unsampled_frames_never_fire():
    """A frame the LP ADC skipped can never fire or open the gate."""
    frames, _ = stream_inputs()
    model = make_model()
    r = StreamRunner(model, RATES, chunk_size=8,
                     control=CaptureConfig(hp_buffer=0))
    _, fired, gated = r.process(frames)
    log = r.capture_log
    assert not (fired & ~log.sampled).any()
    # idle stretches are decimated: strictly fewer conversions than frames
    assert log.sampled.sum() < len(frames)
    # and every gated-on frame traces back to a sampled firing frame
    assert log.gated.shape == (len(frames),)


def test_closed_loop_slicing_invariance():
    """Arbitrary process() slicing is invisible to the closed loop — the
    (hold, phase) ADC state and the capture log carry across calls."""
    frames, _ = stream_inputs()
    model = make_model()
    whole = StreamRunner(model, RATES, chunk_size=8,
                         control=CaptureConfig())
    s_all, f_all, g_all = whole.process(frames)
    log_all = whole.capture_log
    idx_all, hp_all = whole.drain_hp()
    split = StreamRunner(model, RATES, chunk_size=8,
                         control=CaptureConfig())
    parts = [split.process(frames[a:z])
             for a, z in [(0, 7), (7, 10), (10, 41)]]
    np.testing.assert_array_equal(np.concatenate([p[0] for p in parts]),
                                  s_all)
    np.testing.assert_array_equal(np.concatenate([p[1] for p in parts]),
                                  f_all)
    np.testing.assert_array_equal(np.concatenate([p[2] for p in parts]),
                                  g_all)
    np.testing.assert_array_equal(split.capture_log.sampled,
                                  log_all.sampled)
    np.testing.assert_array_equal(split.capture_log.gated, log_all.gated)
    idx_s, hp_s = split.drain_hp()
    np.testing.assert_array_equal(idx_s, idx_all)
    if len(idx_all):
        np.testing.assert_array_equal(hp_s, hp_all)


def test_fleet_control_equals_independent_runners():
    """Closed-loop fleet == S independent closed-loop stream runners."""
    frames, _ = stream_inputs(22)
    fl = jnp.stack([frames[:11], frames[11:]])
    model = make_model()
    fleet = FleetRunner(model, RATES, chunk_size=4,
                        control=CaptureConfig())
    s, f, g = fleet.process(fl)
    flog = fleet.capture_log
    fhp = fleet.drain_hp()
    for si in range(2):
        r = StreamRunner(model, RATES, chunk_size=4,
                         control=CaptureConfig())
        s1, f1, g1 = r.process(fl[si])
        np.testing.assert_array_equal(s[si], s1)
        np.testing.assert_array_equal(f[si], f1)
        np.testing.assert_array_equal(g[si], g1)
        np.testing.assert_array_equal(flog.sampled[si],
                                      r.capture_log.sampled)
        idx1, hp1 = r.drain_hp()
        np.testing.assert_array_equal(fhp[si][0], idx1)
        if len(idx1):
            np.testing.assert_array_equal(fhp[si][1], hp1)


# ---------------------------------------------------------------------------
# HP burst deliverable (bounded gather buffer)
# ---------------------------------------------------------------------------

def test_hp_frames_are_hp_quantized_gated_frames():
    frames, _ = stream_inputs()
    model = make_model(t_score=-10.0, t_detection=0)  # fires on everything
    r = StreamRunner(model, RATES, chunk_size=8,
                     control=CaptureConfig(hp_bits=12))
    _, _, gated = r.process(frames)
    assert gated.all()
    idx, hp = r.drain_hp()
    np.testing.assert_array_equal(idx, np.arange(len(frames)))
    np.testing.assert_array_equal(
        hp, np.asarray(adc.quantize(frames, 12)))
    assert r.hp_dropped == 0
    # drained: a second drain is empty, new frames refill from abs index
    idx2, _ = r.drain_hp()
    assert len(idx2) == 0
    r.process(frames[:5])
    idx3, _ = r.drain_hp()
    np.testing.assert_array_equal(idx3, len(frames) + np.arange(5))


def test_hp_buffer_bound_drops_and_counts():
    """A chunk with more bursts than buffer slots keeps the FIRST k gated
    frames (in order) and counts the spill in hp_dropped."""
    frames, _ = stream_inputs()
    model = make_model(t_score=-10.0, t_detection=0)
    r = StreamRunner(model, RATES, chunk_size=8,
                     control=CaptureConfig(hp_bits=12, hp_buffer=2))
    r.process(frames[:16])
    idx, hp = r.drain_hp()
    np.testing.assert_array_equal(idx, [0, 1, 8, 9])  # first 2 per chunk
    assert r.hp_dropped == 16 - 4
    np.testing.assert_array_equal(
        hp, np.asarray(adc.quantize(frames, 12))[[0, 1, 8, 9]])


def test_hp_capture_helper_masks_padding():
    raw = jnp.asarray(np.random.RandomState(0).rand(6, 4, 4),
                      jnp.float32)
    gated = jnp.asarray([True, False, True, True, True, True])
    buf, idx, cnt = hp_capture(raw, gated, jnp.int32(4), 3, 10)
    # frames 4, 5 are padding (n_valid=4): only 0, 2, 3 qualify
    np.testing.assert_array_equal(np.asarray(idx), [0, 2, 3])
    assert int(cnt) == 3
    np.testing.assert_array_equal(
        np.asarray(buf), np.asarray(adc.quantize(raw, 10))[[0, 2, 3]])


def test_precoded_int8_input_requires_log_only():
    frames, _ = stream_inputs()
    codes = adc.pack_codes(adc.quantize_codes(frames, 8), 8)
    r = StreamRunner(make_model(), RATES, chunk_size=8, adc_bits=8,
                     precision="int8", control=CaptureConfig())
    with pytest.raises(ValueError, match="raw frames"):
        r.process(codes)
    ok = StreamRunner(make_model(), RATES, chunk_size=8, adc_bits=8,
                      precision="int8",
                      control=CaptureConfig(hp_buffer=0))
    ok.process(codes)
    assert ok.capture_log.sampled.sum() < len(frames)


# ---------------------------------------------------------------------------
# capture-log energy billing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("precision,adc_bits", [("float32", None),
                                                ("float32", 4),
                                                ("int8", 8)])
def test_capture_billing_equals_duty_billing_when_all_sampled(precision,
                                                              adc_bits):
    """Open loop (every frame LP-converted): from_capture_log reduces
    EXACTLY to hypersense_measured(duty) — same fields, bitwise."""
    frames, labels = stream_inputs()
    model = make_model()
    r = StreamRunner(model, RATES, chunk_size=8, adc_bits=adc_bits,
                     precision=precision)
    _, fired, gated = r.process(frames)
    log = r.capture_log
    assert log.sampled.all()
    stats = stats_from(fired, gated, labels)
    # exact reduction needs the params' LP depth to be the converter's
    # (with adc_bits=None the log falls back to the params' default)
    lp = adc_bits if adc_bits is not None else 4
    for params in [energy.EnergyParams(adc_lp_bits=lp),
                   energy.EnergyParams(adc_lp_bits=lp, adc_hp_j=0.4,
                                       cloud_j=2.0)]:
        got = energy.from_capture_log(log, params, precision)
        want = energy.hypersense_measured(stats.duty_cycle, params,
                                          precision)
        assert got == want


def test_capture_billing_undercuts_duty_billing_when_subsampled():
    """Idle decimation shows up as real Joules the duty-fraction account
    cannot see: lower adc + hdc terms, same comm/cloud at equal duty."""
    frames, labels = stream_inputs()
    model = make_model()
    r = StreamRunner(model, RATES, chunk_size=8,
                     control=CaptureConfig(hp_buffer=0))
    _, fired, gated = r.process(frames)
    log = r.capture_log
    assert 0 < log.sampled.sum() < len(frames)
    stats = stats_from(fired, gated, labels)
    got = energy.from_capture_log(log)
    approx = energy.hypersense_measured(stats.duty_cycle)
    assert got.adc < approx.adc
    assert got.hdc < approx.hdc
    assert got.comm == approx.comm and got.cloud == approx.cloud
    assert got.total < approx.total


def test_from_capture_log_bits_and_counts():
    """Per-frame bits billed via the SAR 2^bits model; samples_converted
    counts LP + HP conversions."""
    log = CaptureLog(sampled=np.array([True, False, True, True]),
                     gated=np.array([False, False, True, True]),
                     lp_bits=4, hp_bits=12, frame_pixels=100)
    p = energy.EnergyParams()
    got = energy.from_capture_log(log, p)
    assert got.adc == pytest.approx(0.75 * p.adc_lp_j + 0.5 * p.adc_hp_j)
    assert got.hdc == pytest.approx(0.75 * p.hdc_accel_j)
    assert got.comm == pytest.approx(0.5 * p.comm_j)
    assert log.samples_converted() == (3 + 2) * 100
    assert energy.adc_conversion_j(p.adc_lp_bits, p) == p.adc_lp_j


def test_fleet_report_prefers_capture_log():
    frames, labels = stream_inputs(22)
    fl = jnp.stack([frames[:11], frames[11:]])
    fla = np.stack([labels[:11], labels[11:]])
    runner = FleetRunner(make_model(), RATES, chunk_size=4,
                         control=CaptureConfig(hp_buffer=0))
    _, fired, gated = runner.process(fl)
    rep_log = fleet_report(fired, gated, fla,
                           capture=runner.capture_log)
    rep_duty = fleet_report(fired, gated, fla)
    assert rep_log.energy_total_j < rep_duty.energy_total_j
    assert rep_log.baseline_total_j == rep_duty.baseline_total_j


# ---------------------------------------------------------------------------
# stats NaN propagation (ISSUE 5 satellite)
# ---------------------------------------------------------------------------

def test_fleet_report_propagates_nan_stats():
    """A stream with no object frames reports NaN missed_positive (not a
    perfect 0.0) through stats_from_batch/fleet_report; energy billing
    (duty-based) is unaffected."""
    fired = np.zeros((2, 6), bool)
    gated = np.zeros((2, 6), bool)
    gated[1, ::2] = True
    labels = np.stack([np.zeros(6, np.int32), np.ones(6, np.int32)])
    rep = fleet_report(fired, gated, labels)
    assert np.isnan(rep.stats[0].missed_positive)
    assert rep.stats[0].false_active == 0.0
    assert np.isnan(rep.stats[1].false_active)
    assert rep.stats[1].missed_positive == 0.5
    assert np.isfinite(rep.energy_total_j)
