"""End-to-end behaviour tests for the paper's full system.

The complete Intelligent Sensor Control loop on synthetic radar data:
train gate -> pick operating point -> stream control -> energy accounting,
plus kernel-path equivalence of the production scoring path.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy, fragment_model as fm, hypersense, metrics
from repro.core.sensor_control import ControllerConfig, simulate_stream
from repro.sensing import adc, fragments, synthetic

jax.config.update("jax_platform_name", "cpu")

FRAG, DIM, STRIDE = 8, 1024, 4


def _train_gate(key, n_frames=40, size=32):
    cfg = synthetic.RadarConfig(height=size, width=size)
    frames, masks, labels = synthetic.make_dataset(key, n_frames, cfg)
    frames_lp = adc.quantize(frames, 4)
    frs, labs = fragments.sample_fragments(
        np.asarray(frames_lp), np.asarray(masks), h=FRAG, w=FRAG,
        per_frame=2, seed=0)
    model, _ = fm.train_fragment_model(
        jax.random.fold_in(key, 1), jnp.asarray(frs), jnp.asarray(labs),
        dim=DIM, epochs=6)
    B0 = model.B.reshape(FRAG, FRAG, -1)[:, 0, :]
    return model, B0, cfg


def test_end_to_end_sensor_control():
    key = jax.random.PRNGKey(0)
    model, B0, cfg = _train_gate(key)

    hs = hypersense.from_fragment_model(model, B0, h=FRAG, w=FRAG,
                                        stride=STRIDE)

    # operating point from a validation set
    vf, vm, vl = synthetic.make_dataset(jax.random.PRNGKey(5), 30, cfg)
    vf = adc.quantize(vf, 4)
    scores = np.asarray(hypersense.frame_scores_batch(hs, vf, 0))
    fpr, tpr, thr = metrics.roc_curve(scores, np.asarray(vl))
    assert metrics.auc(fpr, tpr) > 0.7, "gate must be informative"
    t_score = metrics.threshold_at_fpr(fpr, tpr, thr, 0.2)
    hs = hs._replace(t_score=float(t_score))

    # stream control: rare events
    stream, slabels = synthetic.make_stream(jax.random.PRNGKey(6), 120,
                                            cfg, event_prob=0.05,
                                            event_len=8)
    stream = adc.quantize(stream, 4)
    decide = jax.jit(lambda f: hypersense.detect(hs, f))
    stats = simulate_stream(lambda f: bool(decide(f)), np.asarray(stream),
                            np.asarray(slabels),
                            ControllerConfig(hold_frames=2))

    # the gate must save energy vs conventional while catching most events
    p = energy.calibrate()
    conv = energy.conventional(p)
    ours = energy.hypersense(stats.false_active,
                             1 - stats.missed_positive,
                             float(np.mean(slabels)), p)
    s = energy.savings(ours, conv)
    assert s["total_saving"] > 0.2, s
    assert stats.duty_cycle < 0.9
    # the detector beats the trivial all-off gate on recall
    assert stats.missed_positive < 0.8


def test_kernel_path_matches_jnp_path():
    """The Pallas production scoring path == pure-jnp reference path."""
    key = jax.random.PRNGKey(1)
    model, B0, cfg = _train_gate(key, n_frames=20)
    frame = adc.quantize(
        synthetic.render_frame(jax.random.PRNGKey(2), cfg, True)[0], 4)
    hs = hypersense.from_fragment_model(model, B0, h=FRAG, w=FRAG,
                                        stride=STRIDE)
    s_jnp = hypersense.score_frame(hs, frame, backend="jnp")
    s_pal = hypersense.score_frame(hs, frame, backend="pallas")
    np.testing.assert_allclose(np.asarray(s_jnp), np.asarray(s_pal),
                               rtol=5e-3, atol=5e-3)


def test_low_precision_adc_does_not_break_gate():
    """Paper premise: the HDC gate survives aggressive quantization."""
    key = jax.random.PRNGKey(3)
    model, B0, cfg = _train_gate(key)
    hs = hypersense.from_fragment_model(model, B0, h=FRAG, w=FRAG,
                                        stride=STRIDE)
    vf, _, vl = synthetic.make_dataset(jax.random.PRNGKey(7), 30, cfg)
    aucs = {}
    for bits in [12, 4, 3]:
        q = adc.quantize(vf, bits)
        scores = np.asarray(hypersense.frame_scores_batch(hs, q, 0))
        fpr, tpr, _ = metrics.roc_curve(scores, np.asarray(vl))
        aucs[bits] = metrics.auc(fpr, tpr)
    assert aucs[4] > 0.65
    assert aucs[4] > aucs[12] - 0.2   # trained on 4-bit: robust there
