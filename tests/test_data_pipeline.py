"""Data pipeline invariants: exactly-once resume, determinism."""

try:  # prefer the real library when installed (requirements-dev.txt)
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # fallback keeps these tests running without the dep
    from _hypothesis_fallback import hypothesis, st
import jax
import numpy as np

from repro import configs
from repro.train import loop as train_loop

jax.config.update("jax_platform_name", "cpu")


def test_synthetic_lm_data_exactly_once_resume():
    """Restarting the stream at step k reproduces the same batches the
    original stream would have produced from step k (exactly-once)."""
    cfg = configs.get_smoke("olmo-1b")
    a = train_loop.synthetic_lm_data(cfg, batch=2, seq=8)
    batches = [next(a) for _ in range(6)]
    b = train_loop.synthetic_lm_data(cfg, batch=2, seq=8, start_step=3)
    resumed = [next(b) for _ in range(3)]
    for orig, res in zip(batches[3:], resumed):
        np.testing.assert_array_equal(np.asarray(orig.tokens),
                                      np.asarray(res.tokens))
        np.testing.assert_array_equal(np.asarray(orig.labels),
                                      np.asarray(res.labels))


@hypothesis.given(st.integers(0, 50))
@hypothesis.settings(max_examples=8, deadline=None)
def test_synthetic_lm_data_deterministic(start):
    cfg = configs.get_smoke("internlm2-1.8b")
    a = train_loop.synthetic_lm_data(cfg, batch=2, seq=8, start_step=start)
    b = train_loop.synthetic_lm_data(cfg, batch=2, seq=8, start_step=start)
    ba, bb = next(a), next(b)
    np.testing.assert_array_equal(np.asarray(ba.tokens),
                                  np.asarray(bb.tokens))


def test_labels_are_shifted_tokens():
    cfg = configs.get_smoke("olmo-1b")
    batch = next(train_loop.synthetic_lm_data(cfg, batch=2, seq=8))
    np.testing.assert_array_equal(np.asarray(batch.labels[:, :-1]),
                                  np.asarray(batch.tokens[:, 1:]))


def test_embeds_in_arch_stream():
    cfg = configs.get_smoke("hubert-xlarge")
    batch = next(train_loop.synthetic_lm_data(cfg, batch=2, seq=8))
    assert batch.tokens is None
    assert batch.embeds.shape == (2, 8, cfg.d_model)
    assert int(batch.labels.max()) < cfg.vocab


def test_vlm_stream_has_image_prefix():
    cfg = configs.get_smoke("internvl2-76b")
    batch = next(train_loop.synthetic_lm_data(cfg, batch=2, seq=8))
    assert batch.embeds.shape == (2, cfg.n_image_tokens, cfg.d_model)
    assert batch.tokens.shape == (2, 8)
