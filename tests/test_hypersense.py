"""HyperSense frame model + fragment model behaviour tests."""

try:  # prefer the real library when installed (requirements-dev.txt)
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # fallback keeps these tests running without the dep
    from _hypothesis_fallback import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import encoding, fragment_model as fm, hypersense, metrics

jax.config.update("jax_platform_name", "cpu")


def _toy_fragment_task(key, n=200, h=8, w=8):
    k1, k2 = jax.random.split(key)
    noise = jax.random.normal(k1, (n, h, w)) * 0.3
    labels = jnp.arange(n) % 2
    yy, xx = jnp.mgrid[0:h, 0:w]
    blob = jnp.exp(-(((yy - h / 2) ** 2 + (xx - w / 2) ** 2) / 6.0))
    frags = noise + labels[:, None, None] * blob
    return frags, labels


def test_bundle_init_equals_manual_sum():
    hvs = jax.random.normal(jax.random.PRNGKey(0), (10, 64))
    labels = jnp.array([0, 1] * 5)
    chvs = fm.bundle_init(hvs, labels, 2)
    np.testing.assert_allclose(np.asarray(chvs[0]),
                               np.asarray(hvs[::2].sum(0)), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(chvs[1]),
                               np.asarray(hvs[1::2].sum(0)), rtol=1e-5)


def test_retraining_improves_or_keeps_accuracy():
    frags, labels = _toy_fragment_task(jax.random.PRNGKey(1))
    model, info = fm.train_fragment_model(
        jax.random.PRNGKey(2), frags, labels, dim=1024, epochs=8)
    accs = info["val_accuracy"]
    assert info["best"] >= accs[0] - 1e-9
    assert info["best"] > 0.9


def test_retrain_only_updates_on_mistakes():
    """If initial accuracy is 1.0, retraining must not change classes."""
    frags, labels = _toy_fragment_task(jax.random.PRNGKey(3), n=40)
    model, info = fm.train_fragment_model(
        jax.random.PRNGKey(4), frags, labels, dim=2048, epochs=1)
    hvs = encoding.encode_fragments(frags, model.B, model.b)
    if float(fm.accuracy(model.class_hvs, hvs, labels)) == 1.0:
        chvs2 = fm.retrain_epoch(model.class_hvs, hvs, labels)
        np.testing.assert_allclose(np.asarray(chvs2),
                                   np.asarray(model.class_hvs))


@hypothesis.given(st.integers(0, 1000))
@hypothesis.settings(max_examples=10, deadline=None)
def test_positive_score_monotone_with_argmax(seed):
    """score > 0 <=> argmax picks class 1 (cosine-margin consistency)."""
    k = jax.random.PRNGKey(seed)
    hvs = jax.random.normal(k, (20, 128))
    chvs = jax.random.normal(jax.random.fold_in(k, 1), (2, 128))
    s = fm.positive_score(chvs, hvs)
    pred = fm.predict(chvs, hvs)
    np.testing.assert_array_equal(np.asarray(s > 0), np.asarray(pred == 1))


def test_frame_detection_score_is_kth_statistic():
    scores = jnp.array([[0.9, 0.1], [0.5, 0.3]])
    assert float(hypersense.frame_detection_score(scores, 0)) == \
        pytest.approx(0.9)
    assert float(hypersense.frame_detection_score(scores, 2)) == \
        pytest.approx(0.3)
    # decision equivalence: count(s > t) > T  <=>  kth_largest > t
    for t in [0.0, 0.2, 0.4, 0.6, 1.0]:
        for T in [0, 1, 2, 3]:
            direct = int(jnp.sum(scores > t)) > T
            viakth = float(hypersense.frame_detection_score(
                scores, min(T, 3))) > t
            if T < 4:
                assert direct == viakth, (t, T)


def test_detect_batch_consistency():
    frames = jax.random.uniform(jax.random.PRNGKey(5), (3, 20, 20))
    B0, b = encoding.make_perm_base_rows(jax.random.PRNGKey(6), 5, 64)
    C = jax.random.normal(jax.random.PRNGKey(7), (2, 64))
    hs = hypersense.HyperSenseModel(
        class_hvs=C, B0=B0, b=b, h=5, w=5, stride=3, t_score=0.0,
        t_detection=1)
    batch = hypersense.detect_batch(hs, frames)
    single = [hypersense.detect(hs, f) for f in frames]
    np.testing.assert_array_equal(np.asarray(batch),
                                  np.asarray(jnp.stack(single)))


def test_roc_curve_properties():
    scores = np.random.default_rng(0).normal(size=200)
    labels = scores + np.random.default_rng(1).normal(size=200) > 0
    fpr, tpr, thr = metrics.roc_curve(scores, labels)
    assert fpr[0] == 0 and tpr[0] == 0
    assert fpr[-1] == 1 and tpr[-1] == 1
    assert np.all(np.diff(fpr) >= 0) and np.all(np.diff(tpr) >= 0)
    assert 0.5 < metrics.auc(fpr, tpr) <= 1.0
    assert 0 <= metrics.partial_auc_above_tpr(fpr, tpr) <= 0.2


@hypothesis.given(st.integers(0, 1000))
@hypothesis.settings(max_examples=20, deadline=None)
def test_auc_of_perfect_and_random_scores(seed):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 2, 100).astype(bool)
    hypothesis.assume(labels.any() and not labels.all())
    perfect = labels.astype(float)
    fpr, tpr, _ = metrics.roc_curve(perfect, labels)
    assert metrics.auc(fpr, tpr) == 1.0
    fpr, tpr, _ = metrics.roc_curve(-perfect, labels)
    assert metrics.auc(fpr, tpr) == 0.0
