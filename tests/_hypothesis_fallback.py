"""Minimal stand-in for ``hypothesis`` so tests collect without the dep.

The real library is preferred (``requirements-dev.txt`` pins it); this
fallback keeps the property *bodies* exercised in environments where it
cannot be installed. It implements exactly the API surface these tests
use:

  hypothesis.given / settings / assume
  strategies.integers / floats / booleans / sampled_from

``given`` replays each test ``max_examples`` times with deterministic
draws: the first two examples hit the strategy boundaries (min/max, first/
last), the rest are seeded-random. No shrinking, no database.

**A fallback run is never reported as a full pass.** After the replayed
examples all succeed, the wrapper raises an explicit ``pytest.skip``
naming the degraded mode, so a CI environment that silently lost the real
hypothesis shows ``s`` markers instead of green-washing property coverage
it does not have (ISSUE 4). Failures still fail: any assertion error in a
replayed example propagates before the skip is reached.
"""

from __future__ import annotations

import random
import types


class _Unsatisfied(Exception):
    """Raised by ``assume(False)``; the current example is discarded."""


def assume(condition) -> bool:
    if not condition:
        raise _Unsatisfied()
    return True


class _Strategy:
    def __init__(self, boundaries, draw):
        self._boundaries = list(boundaries)
        self._draw = draw

    def example(self, rng: random.Random, index: int):
        if index < len(self._boundaries):
            return self._boundaries[index]
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy([min_value, max_value],
                     lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy([min_value, max_value],
                     lambda rng: rng.uniform(min_value, max_value))


def booleans() -> _Strategy:
    return _Strategy([False, True], lambda rng: rng.random() < 0.5)


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy([elements[0], elements[-1]],
                     lambda rng: rng.choice(elements))


def settings(max_examples: int = 20, deadline=None, **_kw):
    def apply(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return apply


def given(*strategies):
    def decorate(fn):
        # NOT functools.wraps: pytest must see a () signature, or it would
        # try to resolve the generated arguments as fixtures.
        def wrapper(*args, **kwargs):
            max_examples = getattr(
                wrapper, "_fallback_max_examples",
                getattr(fn, "_fallback_max_examples", 20))
            rng = random.Random(0x48440)  # deterministic across runs
            ran = 0
            for i in range(max_examples):
                values = [s.example(rng, i) for s in strategies]
                try:
                    fn(*args, *values, **kwargs)
                    ran += 1
                except _Unsatisfied:
                    continue
            assert ran > 0, "every generated example was rejected by assume"
            # every example passed — but this was the degraded replay, not
            # real hypothesis: report it as an explicit skip so CI can't
            # green-wash missing property coverage. (Failures above have
            # already propagated; only successful runs reach this line.)
            import pytest
            pytest.skip(
                f"hypothesis not installed: fallback replayed {ran} "
                f"deterministic examples (boundary + seeded-random, no "
                f"shrinking) and all passed — install hypothesis for "
                f"full property testing")
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return decorate


strategies = types.SimpleNamespace(
    integers=integers, floats=floats, booleans=booleans,
    sampled_from=sampled_from)

hypothesis = types.SimpleNamespace(
    given=given, settings=settings, assume=assume, strategies=strategies)

st = strategies
