"""Per-kernel allclose tests: Pallas (interpret mode) vs pure-jnp oracles.

Sweeps shapes/dtypes per the deliverable: every kernel is validated against
``repro.kernels.ref`` on CPU via ``interpret=True``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import encoding
from repro.kernels import hdc_encode as k_enc
from repro.kernels import ops
from repro.kernels import ref
from repro.kernels import similarity as k_sim
from repro.kernels import sliding_scores as k_ss

jax.config.update("jax_platform_name", "cpu")


def key(i):
    return jax.random.PRNGKey(i)


TOL = {jnp.float32: dict(rtol=2e-4, atol=2e-4),
       jnp.bfloat16: dict(rtol=5e-2, atol=5e-2)}


# ---------------------------------------------------------------------------
# hdc_encode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(16, 64, 256), (100, 300, 1000),
                                   (7, 1000, 513), (1, 9, 2048)])
@pytest.mark.parametrize("nonlin", ["rff", "linear"])
def test_hdc_encode_sweep(shape, dtype, nonlin):
    n, k, d = shape
    x = jax.random.normal(key(0), (n, k), dtype=dtype)
    x = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-8)
    B = jax.random.normal(key(1), (k, d), dtype=dtype)
    b = jax.random.uniform(key(2), (d,), maxval=6.28)
    got = k_enc.hdc_encode(x, B, b, nonlinearity=nonlin, interpret=True,
                           block_n=32, block_d=256, block_k=128)
    want = ref.hdc_encode(x, B, b, nonlinearity=nonlin)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_hdc_encode_block_invariance():
    """Output must not depend on the tiling."""
    x = jax.random.normal(key(3), (33, 100))
    B = jax.random.normal(key(4), (100, 300))
    b = jax.random.uniform(key(5), (300,), maxval=6.28)
    outs = [k_enc.hdc_encode(x, B, b, interpret=True, block_n=bn,
                             block_d=bd, block_k=bk)
            for bn, bd, bk in [(8, 128, 32), (32, 300, 100), (16, 256, 64)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# similarity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(50, 300, 2), (128, 1024, 2),
                                   (3, 5000, 4), (257, 129, 3)])
def test_similarity_sweep(shape, dtype):
    n, d, c = shape
    q = jax.random.normal(key(6), (n, d), dtype=dtype)
    ch = jax.random.normal(key(7), (c, d), dtype=dtype)
    got = k_sim.similarity(q, ch, block_n=32, block_d=128, interpret=True)
    want = ref.similarity(q, ch)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **TOL[dtype])


# ---------------------------------------------------------------------------
# sliding_scores (the paper's computation-reuse accelerator)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stride", [1, 2, 4])
@pytest.mark.parametrize("hw", [(4, 5), (3, 3), (6, 4)])
@pytest.mark.parametrize("block_d", [32, 64, 1000])
def test_sliding_scores_sweep(hw, stride, block_d):
    h, w = hw
    H, W, D = 18, 22, 64
    frame = jax.random.uniform(key(8), (H, W))
    B0, b = encoding.make_perm_base_rows(key(9), h, D)
    C = jax.random.normal(key(10), (2, D))
    tiles = k_ss.precompute_tiles(B0, b, C, W=W, w=w, stride=stride,
                                  block_d=block_d)
    got = k_ss.fragment_scores(frame, tiles, h=h, w=w, stride=stride,
                               interpret=True)
    want = ref.fragment_scores(frame, C, B0, b, h=h, w=w, stride=stride)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("nonlin", ["rff", "linear"])
def test_sliding_scores_nonlinearities(nonlin):
    H, W, h, w, D = 12, 16, 3, 4, 96
    frame = jax.random.uniform(key(11), (H, W))
    B0, b = encoding.make_perm_base_rows(key(12), h, D)
    C = jax.random.normal(key(13), (2, D))
    tiles = k_ss.precompute_tiles(B0, b, C, W=W, w=w, stride=1, block_d=48)
    got = k_ss.fragment_scores(frame, tiles, h=h, w=w, stride=1,
                               nonlinearity=nonlin, interpret=True)
    want = ref.fragment_scores(frame, C, B0, b, h=h, w=w, stride=1,
                               nonlinearity=nonlin)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_window_norms_matches_direct():
    frame = jax.random.normal(key(14), (20, 24))
    norms = k_ss.window_norms(frame, 5, 6, 2)
    frags = encoding.extract_fragments(frame, 5, 6, 2)
    direct = jnp.linalg.norm(frags.reshape(*frags.shape[:2], -1), axis=-1)
    np.testing.assert_allclose(np.asarray(norms), np.asarray(direct),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# ops wrappers (the API the rest of the system calls)
# ---------------------------------------------------------------------------

def test_ops_encode_matches_core_encoding():
    frags = jax.random.normal(key(15), (10, 4, 4))
    B, b = encoding.make_iid_base(key(16), 16, 128)
    got = ops.hdc_encode(frags, B, b)
    want = encoding.encode_fragments(frags, B, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_ops_fragment_score_map_matches_jnp_path():
    from repro.core import hypersense
    H, W, h, w, D = 14, 14, 3, 3, 64
    frame = jax.random.uniform(key(17), (H, W))
    B0, b = encoding.make_perm_base_rows(key(18), h, D)
    C = jax.random.normal(key(19), (2, D))
    got = ops.fragment_score_map(frame, C, B0, b, h=h, w=w, stride=1)
    want = hypersense.fragment_score_map(frame, C, B0, b, h=h, w=w,
                                         stride=1, backend="jnp")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
