"""CI shard lists must partition the test suite (ISSUE 4 satellite).

The suite runs as two parallel CI shards defined in the Makefile
(``SHARD1_FILES`` / ``SHARD2_FILES``). A new test file that lands in
neither list would silently never run in CI — this meta-test turns that
into a hard failure, and also rejects double-booked files (which would
waste the wall-clock the split exists to save).
"""

import pathlib
import re

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _makefile_list(text: str, var: str) -> set[str]:
    m = re.search(rf"^{var}\s*=\s*((?:.*\\\n)*.*)$", text, re.M)
    assert m, f"{var} not found in Makefile"
    return set(m.group(1).replace("\\\n", " ").split())


def test_shards_partition_the_suite():
    text = (ROOT / "Makefile").read_text()
    shard1 = _makefile_list(text, "SHARD1_FILES")
    shard2 = _makefile_list(text, "SHARD2_FILES")
    actual = {f"tests/{p.name}"
              for p in (ROOT / "tests").glob("test_*.py")}
    assert shard1 & shard2 == set(), (
        f"files booked into both shards: {sorted(shard1 & shard2)}")
    missing = actual - (shard1 | shard2)
    assert not missing, (
        f"test files in neither CI shard (add to SHARD1_FILES or "
        f"SHARD2_FILES in the Makefile): {sorted(missing)}")
    stale = (shard1 | shard2) - actual
    assert not stale, f"shard lists reference missing files: {sorted(stale)}"
