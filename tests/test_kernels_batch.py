"""Batched sliding-scores kernel: parity vs per-frame and pure-jnp paths.

The batched kernel (grid ``(N, my, n_dt)``) must agree with (a) the
per-frame kernel it generalizes, and (b) the pure-jnp
``fragment_score_map`` oracle — across dtypes, strides, and non-divisible
``D % block_d``. Plus edge cases of ``frame_detection_score``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import encoding, hypersense
from repro.kernels import ops
from repro.kernels import ref
from repro.kernels import sliding_scores as k_ss

jax.config.update("jax_platform_name", "cpu")


def key(i):
    return jax.random.PRNGKey(i)


TOL = {jnp.float32: dict(rtol=2e-4, atol=2e-4),
       jnp.bfloat16: dict(rtol=5e-2, atol=5e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("stride", [1, 2, 4])
def test_batch_matches_per_frame_and_jnp(stride, dtype):
    N, H, W, D, h, w = 5, 18, 22, 64, 4, 5
    frames = jax.random.uniform(key(0), (N, H, W), dtype=jnp.float32)
    frames = frames.astype(dtype)
    B0, b = encoding.make_perm_base_rows(key(1), h, D)
    C = jax.random.normal(key(2), (2, D))
    tiles = k_ss.precompute_tiles(B0, b, C, W=W, w=w, stride=stride,
                                  block_d=32)
    got = k_ss.fragment_scores_batch(frames, tiles, h=h, w=w, stride=stride,
                                     interpret=True)
    assert got.shape[0] == N
    for i in range(N):
        per_frame = k_ss.fragment_scores(frames[i], tiles, h=h, w=w,
                                         stride=stride, interpret=True)
        np.testing.assert_allclose(np.asarray(got[i]),
                                   np.asarray(per_frame),
                                   rtol=1e-6, atol=1e-6)
        want = hypersense.fragment_score_map(
            frames[i].astype(jnp.float32), C, B0, b, h=h, w=w,
            stride=stride, backend="jnp")
        np.testing.assert_allclose(np.asarray(got[i], np.float32),
                                   np.asarray(want, np.float32),
                                   **TOL[dtype])


@pytest.mark.parametrize("block_d", [1000, 48])
def test_batch_non_divisible_block_d(block_d):
    """D % block_d != 0 collapses to a single D tile (and still matches)."""
    N, H, W, D, h, w, stride = 3, 14, 16, 96, 3, 4, 2
    frames = jax.random.uniform(key(3), (N, H, W))
    B0, b = encoding.make_perm_base_rows(key(4), h, D)
    C = jax.random.normal(key(5), (2, D))
    tiles = k_ss.precompute_tiles(B0, b, C, W=W, w=w, stride=stride,
                                  block_d=block_d)
    got = k_ss.fragment_scores_batch(frames, tiles, h=h, w=w, stride=stride,
                                     interpret=True)
    for i in range(N):
        want = ref.fragment_scores(frames[i], C, B0, b, h=h, w=w,
                                   stride=stride)
        np.testing.assert_allclose(np.asarray(got[i]), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("nonlin", ["rff", "linear"])
def test_batch_nonlinearities(nonlin):
    N, H, W, D, h, w = 2, 12, 16, 96, 3, 4
    frames = jax.random.uniform(key(6), (N, H, W))
    B0, b = encoding.make_perm_base_rows(key(7), h, D)
    C = jax.random.normal(key(8), (2, D))
    tiles = k_ss.precompute_tiles(B0, b, C, W=W, w=w, stride=1, block_d=48)
    got = k_ss.fragment_scores_batch(frames, tiles, h=h, w=w, stride=1,
                                     nonlinearity=nonlin, interpret=True)
    for i in range(N):
        want = ref.fragment_scores(frames[i], C, B0, b, h=h, w=w, stride=1,
                                   nonlinearity=nonlin)
        np.testing.assert_allclose(np.asarray(got[i]), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("H,W,h,w,stride", [
    (17, 23, 4, 5, 3),    # non-square; stride divides neither H-h nor W-w
    (19, 13, 6, 3, 4),    # W < H, W-w not divisible, single-column tail
    (15, 31, 5, 5, 7),    # wide frame, large stride -> tiny score map
])
def test_batch_odd_shapes_match_jnp(H, W, h, w, stride):
    """Non-square frames and strides that don't divide ``H - h``/``W - w``:
    the floor'd (my, mx) grid must agree with the jnp oracle everywhere."""
    N, D = 3, 64
    my = (H - h) // stride + 1
    mx = (W - w) // stride + 1
    assert (H - h) % stride != 0 or (W - w) % stride != 0
    frames = jax.random.uniform(key(20), (N, H, W))
    B0, b = encoding.make_perm_base_rows(key(21), h, D)
    C = jax.random.normal(key(22), (2, D))
    tiles = k_ss.precompute_tiles(B0, b, C, W=W, w=w, stride=stride,
                                  block_d=32)
    got = k_ss.fragment_scores_batch(frames, tiles, h=h, w=w, stride=stride,
                                     interpret=True)
    assert got.shape == (N, my, mx)
    for i in range(N):
        want = hypersense.fragment_score_map(frames[i], C, B0, b, h=h, w=w,
                                             stride=stride, backend="jnp")
        np.testing.assert_allclose(np.asarray(got[i]), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


def test_fleet_reshape_plumbing_matches_batch():
    """(S, C, H, W) fleet entry point == reshaped batch entry point."""
    S, C, H, W, D, h, w, stride = 3, 4, 14, 18, 64, 3, 4, 2
    frames = jax.random.uniform(key(23), (S, C, H, W))
    B0, b = encoding.make_perm_base_rows(key(24), h, D)
    Chv = jax.random.normal(key(25), (2, D))
    got = ops.fragment_score_map_fleet(frames, Chv, B0, b, h=h, w=w,
                                       stride=stride)
    want = ops.fragment_score_map_batch(frames.reshape(S * C, H, W), Chv,
                                        B0, b, h=h, w=w, stride=stride)
    assert got.shape == (S, C) + want.shape[1:]
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(want).reshape(got.shape))


def test_batch_of_one_equals_single():
    H, W, D, h, w, stride = 14, 14, 64, 3, 3, 1
    frame = jax.random.uniform(key(9), (H, W))
    B0, b = encoding.make_perm_base_rows(key(10), h, D)
    C = jax.random.normal(key(11), (2, D))
    tiles = k_ss.precompute_tiles(B0, b, C, W=W, w=w, stride=stride)
    batched = k_ss.fragment_scores_batch(frame[None], tiles, h=h, w=w,
                                         stride=stride, interpret=True)
    single = k_ss.fragment_scores(frame, tiles, h=h, w=w, stride=stride,
                                  interpret=True)
    np.testing.assert_array_equal(np.asarray(batched[0]),
                                  np.asarray(single))


def test_window_norms_batch_matches_per_frame():
    frames = jax.random.normal(key(12), (4, 20, 24))
    got = k_ss.window_norms_batch(frames, 5, 6, 2)
    for i in range(4):
        want = k_ss.window_norms(frames[i], 5, 6, 2)
        np.testing.assert_allclose(np.asarray(got[i]), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)


def test_ops_fragment_score_map_batch_matches_jnp():
    N, H, W, D, h, w, stride = 4, 14, 14, 64, 3, 3, 1
    frames = jax.random.uniform(key(13), (N, H, W))
    B0, b = encoding.make_perm_base_rows(key(14), h, D)
    C = jax.random.normal(key(15), (2, D))
    got = ops.fragment_score_map_batch(frames, C, B0, b, h=h, w=w,
                                       stride=stride)
    for i in range(N):
        want = hypersense.fragment_score_map(frames[i], C, B0, b, h=h, w=w,
                                             stride=stride, backend="jnp")
        np.testing.assert_allclose(np.asarray(got[i]), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


# (runner-level pallas==jnp parity lives in the backend x precision x
# adapt matrix: tests/test_parity_matrix.py. frame_scores_batch itself —
# the public batch-scoring API with its own precision/sequential routing —
# is pinned here across its full routing grid.)

@pytest.mark.parametrize("precision", ["float32", "int8"])
@pytest.mark.parametrize("sequential", [False, True])
def test_frame_scores_batch_routing_grid(precision, sequential):
    """Every (backend, precision, sequential) route returns the same frame
    scores: pallas==jnp per configuration, sequential==batched per
    configuration (int8 within exact-path tolerance, float32 vs its own
    batch exactly)."""
    N, H, W, D, h, w, stride = 6, 14, 14, 64, 3, 3, 2
    frames = jax.random.uniform(key(16), (N, H, W), maxval=1.5)
    B0, b = encoding.make_perm_base_rows(key(17), h, D)
    C = jax.random.normal(key(18), (2, D))
    model = hypersense.HyperSenseModel(C, B0, b, h, w, stride,
                                       t_score=0.0, t_detection=2)
    kw = dict(precision=precision, sequential=sequential)
    if precision == "int8":
        kw["adc_bits"] = 8
    got_p = hypersense.frame_scores_batch(model, frames, backend="pallas",
                                          **kw)
    got_j = hypersense.frame_scores_batch(model, frames, backend="jnp",
                                          **kw)
    np.testing.assert_allclose(np.asarray(got_p), np.asarray(got_j),
                               rtol=2e-4, atol=2e-4)
    # sequential is a memory strategy, not a numerics change
    ref = hypersense.frame_scores_batch(
        model, frames, backend="jnp",
        **{**kw, "sequential": False})
    np.testing.assert_allclose(np.asarray(got_j), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# frame_detection_score edge cases
# ---------------------------------------------------------------------------

def test_frame_detection_score_t_at_least_num_fragments_clamps():
    """t_detection >= #fragments clamps to the minimum (ROC stays defined)."""
    scores = jnp.asarray([[3.0, 1.0], [2.0, 4.0]])
    for td in (4, 5, 100):
        got = hypersense.frame_detection_score(scores, td)
        assert float(got) == 1.0  # smallest fragment score


def test_frame_detection_score_all_equal():
    scores = jnp.full((3, 3), 0.25)
    for td in (0, 4, 8, 20):
        assert float(hypersense.frame_detection_score(scores, td)) == 0.25


def test_frame_detection_score_order_statistic():
    scores = jnp.asarray([[0.75, -0.5], [0.125, 0.25]])
    assert float(hypersense.frame_detection_score(scores, 0)) == 0.75
    assert float(hypersense.frame_detection_score(scores, 1)) == 0.25
    assert float(hypersense.frame_detection_score(scores, 3)) == -0.5
