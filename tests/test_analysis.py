"""Seeded-defect fixtures for every repro.analysis rule + the sanitizers.

Each RA rule gets the three-way contract: fires on the bad form, stays
silent on the good form, and a ``repro-lint`` waiver (with a reason)
suppresses it. The final test self-applies the linter to the shipped
``src/`` tree — the same gate CI runs — so the tree can never drift
into unwaived findings without this suite noticing.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import findings_json, lint_text
from repro.analysis import sanitize
from repro.analysis.linter import lint_paths

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def fired(src, path="fixture.py"):
    """Unwaived rule codes for an in-memory module."""
    return [f.rule for f in lint_text(src, path) if not f.waived]


# ---------------------------------------------------------------------------
# RA001: traced control flow
# ---------------------------------------------------------------------------

def test_ra001_fires_on_if_over_traced():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    if x > 0:\n"
        "        return x\n"
        "    return -x\n"
    )
    assert "RA001" in fired(src)


def test_ra001_fires_on_while_assert_bool_for():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    assert x > 0\n"
        "    while x < 5:\n"
        "        x = x + 1\n"
        "    if bool(x):\n"
        "        for v in x:\n"
        "            x = x + v\n"
        "    return x\n"
    )
    assert fired(src).count("RA001") >= 4


def test_ra001_silent_on_static_forms():
    src = (
        "import jax\n"
        "import jax.numpy as jnp\n"
        "@jax.jit\n"
        "def f(x, mode='a'):\n"
        "    if x.shape[0] > 3:\n"          # shape probe: static
        "        x = x[:3]\n"
        "    if mode == 'a':\n"             # string dispatch: static
        "        return jnp.where(x > 0, x, -x)\n"
        "    if x is None:\n"               # None check: static
        "        return x\n"
        "    return x\n"
    )
    assert fired(src) == []


def test_ra001_interprocedural_taint_not_blanket():
    # traced value flows THROUGH a helper call: the helper's `a` is
    # tainted, its static `mult` is not
    src = (
        "import jax\n"
        "def helper(a, mult):\n"
        "    if mult == 8:\n"               # static at every call site
        "        return a\n"
        "    if a > 0:\n"                   # traced at the call site
        "        return -a\n"
        "    return a\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return helper(x, 8)\n"
    )
    out = lint_text(src)
    lines = [f.line for f in out if f.rule == "RA001" and not f.waived]
    assert lines == [5], "only the traced-param branch may fire"


def test_ra001_silent_outside_jit_reachable_code():
    src = (
        "def host(x):\n"
        "    if x > 0:\n"
        "        return x\n"
        "    return -x\n"
    )
    assert fired(src) == []


def test_ra001_waiver_with_reason_suppresses():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    # repro-lint: disable=RA001 (trace-time constant fold)\n"
        "    if x > 0:\n"
        "        return x\n"
        "    return -x\n"
    )
    out = lint_text(src)
    assert fired(src) == []
    waived = [f for f in out if f.waived]
    assert waived and waived[0].waiver_reason == "trace-time constant fold"


def test_ra000_waiver_without_reason_is_itself_a_finding():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    # repro-lint: disable=RA001\n"
        "    if x > 0:\n"
        "        return x\n"
        "    return -x\n"
    )
    assert sorted(fired(src)) == ["RA000", "RA001"]


# ---------------------------------------------------------------------------
# RA002: impurity
# ---------------------------------------------------------------------------

def test_ra002_fires_on_trace_time_impurity():
    src = (
        "import jax\n"
        "import numpy as np\n"
        "import time\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    t = time.time()\n"
        "    n = np.random.rand()\n"
        "    print(x)\n"
        "    return x + n + t\n"
    )
    assert fired(src).count("RA002") >= 3


def test_ra002_fires_on_host_np_random_anywhere():
    src = (
        "import numpy as np\n"
        "def gen(seed):\n"
        "    return np.random.default_rng(seed).normal(size=3)\n"
    )
    assert "RA002" in fired(src)


def test_ra002_silent_on_jax_random():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(key, x):\n"
        "    return x + jax.random.normal(key, x.shape)\n"
    )
    assert fired(src) == []


# ---------------------------------------------------------------------------
# RA003: implicit host<->device sync
# ---------------------------------------------------------------------------

def test_ra003_fires_in_jit_reachable_code():
    src = (
        "import jax\n"
        "import numpy as np\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    lo = float(x[0])\n"
        "    hi = np.asarray(x).max()\n"
        "    return lo + hi\n"
    )
    assert fired(src).count("RA003") == 2


def test_ra003_fires_in_hot_serving_path():
    src = (
        "import numpy as np\n"
        "class FleetService:\n"
        "    def dispatch(self, arrivals):\n"
        "        for sid, fr in arrivals.items():\n"
        "            peek = np.asarray(fr)\n"
        "            lo = float(fr[0])\n"
        "            v = fr.sum().item()\n"
        "        return peek, lo, v\n"
    )
    assert fired(src, "src/repro/launch/serve.py").count("RA003") == 3


def test_ra003_silent_on_explicit_and_host_forms():
    src = (
        "import jax\n"
        "import numpy as np\n"
        "class FleetService:\n"
        "    def dispatch(self, arrivals):\n"
        "        shp = np.shape(arrivals)\n"          # metadata peek
        "        host = jax.device_get(arrivals)\n"   # explicit transfer
        "        buf = np.zeros((4, 4))\n"
        "        buf2 = np.asarray(buf)\n"            # host-only value
        "        return shp, host, buf2\n"
    )
    assert fired(src, "src/repro/launch/serve.py") == []


def test_ra003_hot_path_only_applies_to_serving_files():
    src = (
        "import numpy as np\n"
        "class Thing:\n"
        "    def dispatch(self, arrivals):\n"
        "        return np.asarray(arrivals)\n"
    )
    assert fired(src, "src/repro/train/loop.py") == []


# ---------------------------------------------------------------------------
# RA004: use-after-donate
# ---------------------------------------------------------------------------

_DONATE_HEADER = (
    "import jax\n"
    "def _step(state, x):\n"
    "    return state + x\n"
    "step = jax.jit(_step, donate_argnums=(0,))\n"
)


def test_ra004_fires_on_use_after_donate():
    src = _DONATE_HEADER + (
        "def drive(state, xs):\n"
        "    out = step(state, xs)\n"
        "    return out + state\n"          # state's buffer is gone
    )
    assert "RA004" in fired(src)


def test_ra004_rebind_is_the_safe_idiom():
    src = _DONATE_HEADER + (
        "def drive(state, xs):\n"
        "    state = step(state, xs)\n"     # donate + rebind: safe
        "    return state\n"
    )
    assert fired(src) == []


def test_ra004_cross_iteration_donation():
    src = _DONATE_HEADER + (
        "def drive(state, chunks):\n"
        "    outs = []\n"
        "    for c in chunks:\n"
        "        outs.append(step(state, c))\n"   # donated on iter 1...
        "    return outs\n"                        # ...reused on iter 2
    )
    assert "RA004" in fired(src)


def test_ra004_conditional_alias_unions_donations():
    src = (
        "import jax\n"
        "def _f(state, x):\n"
        "    return state + x\n"
        "donating = jax.jit(_f, donate_argnums=(0,))\n"
        "plain = jax.jit(_f)\n"
        "def drive(state, x, fast):\n"
        "    fn = donating if fast else plain\n"
        "    out = fn(state, x)\n"
        "    return out + state\n"
    )
    assert "RA004" in fired(src)


# ---------------------------------------------------------------------------
# RA005: recompile hazards
# ---------------------------------------------------------------------------

def test_ra005_fires_on_transform_built_in_loop():
    src = (
        "import jax\n"
        "def drive(chunks):\n"
        "    outs = []\n"
        "    for c in chunks:\n"
        "        outs.append(jax.vmap(lambda v: v * 2)(c))\n"
        "    return outs\n"
    )
    assert "RA005" in fired(src)


def test_ra005_fires_on_transform_built_in_hot_path():
    src = (
        "import jax\n"
        "class FleetService:\n"
        "    def dispatch(self, arrivals):\n"
        "        return jax.vmap(lambda v: v * 2)(arrivals)\n"
    )
    assert "RA005" in fired(src, "src/repro/launch/serve.py")


def test_ra005_fires_on_loop_varying_static_arg():
    src = (
        "import jax\n"
        "def _step(x, *, bits):\n"
        "    return x * bits\n"
        "step = jax.jit(_step, static_argnames=('bits',))\n"
        "def sweep(x, depths):\n"
        "    for b in depths:\n"
        "        x = step(x, bits=b)\n"     # retrace per iteration
        "    return x\n"
    )
    assert "RA005" in fired(src)


def test_ra005_silent_on_module_level_and_static_config():
    src = (
        "import jax\n"
        "def _step(x, *, bits):\n"
        "    return x * bits\n"
        "step = jax.jit(_step, static_argnames=('bits',))\n"
        "DOUBLE = jax.vmap(lambda v: v * 2)\n"
        "def drive(x, chunks):\n"
        "    for c in chunks:\n"
        "        x = step(x + c, bits=8)\n"   # loop-invariant static
        "    return DOUBLE(x)\n"
    )
    assert fired(src) == []


def test_ra005_resolves_static_argnames_through_module_constants():
    src = (
        "import jax\n"
        "_STATIC = ('bits', 'mode')\n"
        "def _step(x, *, bits, mode):\n"
        "    return x * bits\n"
        "step = jax.jit(_step, static_argnames=_STATIC)\n"
        "def sweep(x, modes):\n"
        "    for m in modes:\n"
        "        x = step(x, bits=8, mode=m)\n"
        "    return x\n"
    )
    assert "RA005" in fired(src)


# ---------------------------------------------------------------------------
# RA006: Pallas launch contracts
# ---------------------------------------------------------------------------

_PALLAS_HEADER = (
    "import jax\n"
    "import jax.numpy as jnp\n"
    "from jax.experimental import pallas as pl\n"
    "from jax.experimental.pallas import tpu as pltpu\n"
    "def kernel(x_ref, o_ref):\n"
    "    o_ref[...] = x_ref[...]\n"
)


def test_ra006_fires_on_index_map_arity_mismatch():
    src = _PALLAS_HEADER + (
        "def launch(x):\n"
        "    return pl.pallas_call(\n"
        "        kernel,\n"
        "        grid=(4, 4),\n"
        "        in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],\n"
        "        out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),\n"
        "        out_shape=jax.ShapeDtypeStruct((32, 32), jnp.float32),\n"
        "        compiler_params=pltpu.CompilerParams(\n"
        "            dimension_semantics=('parallel', 'parallel')),\n"
        "    )(x)\n"
    )
    assert fired(src).count("RA006") == 1


def test_ra006_fires_on_missing_dimension_semantics():
    src = _PALLAS_HEADER + (
        "def launch(x):\n"
        "    return pl.pallas_call(\n"
        "        kernel,\n"
        "        grid=(4,),\n"
        "        in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],\n"
        "        out_specs=pl.BlockSpec((8, 8), lambda i: (i, 0)),\n"
        "        out_shape=jax.ShapeDtypeStruct((32, 8), jnp.float32),\n"
        "    )(x)\n"
    )
    assert "RA006" in fired(src)


def test_ra006_fires_on_out_spec_shape_arity_mismatches():
    src = _PALLAS_HEADER + (
        "def launch(x):\n"
        "    return pl.pallas_call(\n"
        "        kernel,\n"
        "        grid=(4,),\n"
        "        in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],\n"
        "        out_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))] * 2,\n"
        "        out_shape=[jax.ShapeDtypeStruct((32, 8, 1), jnp.float32)] * 3,\n"
        "        compiler_params=pltpu.CompilerParams(\n"
        "            dimension_semantics=('parallel',)),\n"
        "    )(x)\n"
    )
    out = fired(src)
    # 2 vs 3 outputs, and block rank 2 vs ShapeDtypeStruct rank 3
    assert out.count("RA006") == 2


def test_ra006_fires_on_index_map_return_vs_block_rank():
    src = _PALLAS_HEADER + (
        "def launch(x):\n"
        "    spec = pl.BlockSpec((8, 8), lambda i: (i, 0, 0))\n"
        "    return pl.pallas_call(\n"
        "        kernel,\n"
        "        grid=(4,),\n"
        "        in_specs=[spec],\n"
        "        out_specs=pl.BlockSpec((8, 8), lambda i: (i, 0)),\n"
        "        out_shape=jax.ShapeDtypeStruct((32, 8), jnp.float32),\n"
        "        compiler_params=pltpu.CompilerParams(\n"
        "            dimension_semantics=('parallel',)),\n"
        "    )(x)\n"
    )
    assert "RA006" in fired(src)


def test_ra006_silent_on_well_formed_launch():
    src = _PALLAS_HEADER + (
        "def launch(x):\n"
        "    n = x.shape[0] // 8\n"
        "    class_spec = pl.BlockSpec((8, 8), lambda i, j: (i, j))\n"
        "    return pl.pallas_call(\n"
        "        kernel,\n"
        "        grid=(n, 4),\n"
        "        in_specs=[class_spec],\n"
        "        out_specs=[pl.BlockSpec((8, 8), lambda i, j: (i, j))] * 2,\n"
        "        out_shape=[jax.ShapeDtypeStruct((32, 32), jnp.float32)] * 2,\n"
        "        compiler_params=pltpu.CompilerParams(\n"
        "            dimension_semantics=('parallel', 'parallel')),\n"
        "    )(x)\n"
    )
    assert fired(src) == []


# ---------------------------------------------------------------------------
# findings JSON + file-level waivers
# ---------------------------------------------------------------------------

def test_findings_json_shape():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    if x > 0:\n"
        "        return x\n"
        "    # repro-lint: disable=RA001 (deliberate)\n"
        "    if x < 0:\n"
        "        return -x\n"
        "    return x\n"
    )
    payload = json.loads(findings_json(lint_text(src)))
    assert payload["total"] == 2
    assert payload["unwaived"] == 1
    by_line = {f["line"]: f for f in payload["findings"]}
    assert by_line[4]["waived"] is False
    assert by_line[7]["waived"] is True
    assert by_line[7]["waiver_reason"] == "deliberate"
    assert payload["rules"]["RA001"]


def test_file_level_waiver():
    src = (
        "# repro-lint: disable-file=RA002 (host-side data generation module)\n"
        "import numpy as np\n"
        "def gen():\n"
        "    return np.random.rand()\n"
    )
    out = lint_text(src)
    assert fired(src) == []
    assert all(f.waived for f in out if f.rule == "RA002")


# ---------------------------------------------------------------------------
# self-application: the shipped tree stays clean (CI's lint gate)
# ---------------------------------------------------------------------------

def test_src_tree_has_zero_unwaived_findings():
    findings = lint_paths([os.path.join(REPO, "src")])
    unwaived = [f.render() for f in findings if not f.waived]
    assert unwaived == [], "\n".join(unwaived)
    # every surviving waiver carries a written reason
    for f in findings:
        if f.waived:
            assert f.waiver_reason.strip(), f.render()


# ---------------------------------------------------------------------------
# runtime sanitizers
# ---------------------------------------------------------------------------

def test_compile_ledger_counts_fresh_compiles_only():
    ledger = sanitize.ledger()

    @jax.jit
    def g(x):
        return x * 3 + 1

    # build every input up front: eager ops (+) compile kernels too, and
    # those events must not land inside the measured regions
    x = jnp.arange(7.0)
    x2 = (x + 1).block_until_ready()
    before = ledger.events
    g(x).block_until_ready()              # fresh compile
    assert ledger.events > before
    warm = ledger.events
    g(x2).block_until_ready()             # cache hit
    assert ledger.events == warm


def test_steady_state_raises_on_fresh_compile():
    @jax.jit
    def h(x):
        return x - 2

    x = jnp.arange(5.0)
    x2 = (x + 1).block_until_ready()      # pre-build: eager + compiles too
    xr = x.reshape(5, 1).block_until_ready()
    h(x).block_until_ready()              # warm the cache
    with sanitize.steady_state("warm region"):
        h(x2).block_until_ready()         # fine: cached
    with pytest.raises(AssertionError, match="compile ledger"):
        with sanitize.steady_state("cold region"):
            h(xr).block_until_ready()     # new shape: compiles


def test_transfer_guard_blocks_implicit_transfers():
    y = jnp.arange(4.0)
    with sanitize.no_implicit_transfers(always=True):
        host = np.asarray(y)              # explicit d2h: allowed
        dev = jax.device_put(host)        # explicit h2d: allowed
        with pytest.raises(Exception, match="[Dd]isallow"):
            float(y[0])                   # implicit scalar pull
    assert host.shape == dev.shape


def test_sanitize_enabled_env_parsing(monkeypatch):
    for raw, want in [("", False), ("0", False), ("false", False),
                      ("1", True), ("true", True), ("yes", True)]:
        monkeypatch.setenv("REPRO_SANITIZE", raw)
        assert sanitize.enabled() is want
    monkeypatch.delenv("REPRO_SANITIZE")
    assert sanitize.enabled() is False
    # disabled guard is a transparent no-op
    with sanitize.no_implicit_transfers():
        assert float(jnp.arange(3.0)[1]) == 1.0
