"""Property tests for the ADC quantize/pack layer.

The integer datapaths' correctness rests on four invariants of the
conversion layer, exercised here as hypothesis properties plus
exhaustive depth sweeps:

* **round-trip**  — ``pack -> unpack`` is the identity, and
  re-converting a reconstruction reproduces the same codes;
* **idempotence** — requantizing a quantized frame changes nothing (the
  property that makes pre-quantized and internally-quantized streams
  indistinguishable to the runners);
* **monotonicity** — the converter is order-preserving: brighter input
  can never produce a smaller code (so ADC quantization can only merge,
  never invert, fragment-score orderings of constant-shape inputs);
* **no-overflow** — at the maximum supported ``adc_bits`` and window
  sizes the int32 accumulators of the integer datapath stay within
  bounds, and the in-path sums equal an exact int64 recomputation.
"""

try:  # prefer the real library when installed (requirements-dev.txt)
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # fallback keeps these tests running without the dep
    from _hypothesis_fallback import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import sliding_scores_int as k_int
from repro.sensing import adc

jax.config.update("jax_platform_name", "cpu")


@hypothesis.given(st.integers(0, 2**16), st.integers(1, 16))
@hypothesis.settings(max_examples=25, deadline=None)
def test_pack_unpack_round_trip(seed, bits):
    """pack -> unpack is the identity on every representable code — at
    every depth the wire format supports, incl. the 9-16-bit uint16
    branch (the high-precision burst depths)."""
    x = jax.random.uniform(jax.random.PRNGKey(seed), (13, 11),
                           minval=-0.3, maxval=1.8)
    codes = adc.quantize_codes(x, bits)
    packed = adc.pack_codes(codes, bits)
    assert packed.dtype == adc.codes_dtype(bits)
    np.testing.assert_array_equal(np.asarray(adc.unpack_codes(packed)),
                                  np.asarray(codes))


def test_codes_dtype_stays_narrow_above_8_bits():
    """9-16-bit codes ride uint16 (2 bytes), not int32 — the wire-format
    memory-traffic claim must hold for the HP burst depths too."""
    assert adc.codes_dtype(8) == jnp.uint8
    for bits in (9, 12, 16):
        assert adc.codes_dtype(bits) == jnp.uint16
        # max code of the depth survives the pack exactly
        top = jnp.full((3,), (1 << bits) - 1, jnp.int32)
        packed = adc.pack_codes(top, bits)
        assert packed.dtype == jnp.uint16
        np.testing.assert_array_equal(np.asarray(adc.unpack_codes(packed)),
                                      np.asarray(top))
    assert adc.codes_dtype(17) == jnp.int32


@hypothesis.given(st.integers(0, 2**16), st.integers(1, 16))
@hypothesis.settings(max_examples=25, deadline=None)
def test_quantize_per_frame_uniform_depth_matches_quantize(seed, bits):
    """At one uniform depth the per-frame-bits converter IS quantize;
    bits == 0 frames (skipped by the closed loop) come back all-zero."""
    x = jax.random.uniform(jax.random.PRNGKey(seed), (5, 9, 7),
                           minval=-0.3, maxval=1.8)
    per = adc.quantize_per_frame(x, jnp.full((5,), bits, jnp.int32))
    np.testing.assert_array_equal(np.asarray(per),
                                  np.asarray(adc.quantize(x, bits)))
    codes = adc.quantize_codes_per_frame(x, jnp.full((5,), bits,
                                                     jnp.int32))
    np.testing.assert_array_equal(np.asarray(codes),
                                  np.asarray(adc.quantize_codes(x, bits)))
    skipped = adc.quantize_per_frame(x, jnp.zeros((5,), jnp.int32))
    assert not np.asarray(skipped).any()


def test_quantize_per_frame_mixed_depths():
    """One batch mixing skipped / LP / HP frames converts each at its own
    depth — the closed-loop capture primitive."""
    x = jax.random.uniform(jax.random.PRNGKey(3), (3, 8, 8), maxval=1.5)
    bits = jnp.asarray([0, 4, 12], jnp.int32)
    got = np.asarray(adc.quantize_per_frame(x, bits))
    assert not got[0].any()
    np.testing.assert_array_equal(got[1], np.asarray(adc.quantize(x[1], 4)))
    np.testing.assert_array_equal(got[2],
                                  np.asarray(adc.quantize(x[2], 12)))


@pytest.mark.parametrize("bits", range(1, 17))
def test_per_frame_converter_bit_exact_exhaustive(bits):
    """quantize_codes_per_frame == quantize_codes at EVERY depth 1..16,
    on the inputs where the two implementations could plausibly split:
    the exact code grid, every half-LSB rounding boundary, zero,
    full-scale, and the clip edges just outside [0, V_MAX].

    The per-frame converter computes ``levels`` as a traced float32
    ``left_shift`` where the static converter uses a Python int — this
    pins that the two arithmetic routes round identically (both levels
    values are <= 65535 < 2**24, hence exact in float32; a future depth
    above 24 bits would NOT be, which is why the sweep is exhaustive
    rather than sampled)."""
    levels = (1 << bits) - 1
    k = np.arange(levels + 1, dtype=np.float64)
    grid = (k / levels * adc.V_MAX).astype(np.float32)          # exact codes
    half = ((k[:-1] + 0.5) / levels * adc.V_MAX).astype(np.float32)
    edges = np.array([0.0, adc.V_MAX, -1e-6, adc.V_MAX + 1e-6,
                      -1.0, 2.0 * adc.V_MAX], np.float32)
    rng = np.random.default_rng(bits)
    dense = rng.uniform(-0.2, 1.7, 4096).astype(np.float32)
    x = jnp.asarray(np.concatenate([grid, half, edges, dense]))[None]
    a = np.asarray(adc.quantize_codes(x, bits))
    b = np.asarray(adc.quantize_codes_per_frame(x, jnp.asarray([bits])))
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0 and a.max() == levels
    # the reconstruction twin agrees with the static reconstruction too
    np.testing.assert_array_equal(
        np.asarray(adc.quantize_per_frame(x, jnp.asarray([bits]))),
        np.asarray(adc.quantize(x, bits)))


def test_per_frame_converter_empty_batch():
    """A zero-frame batch converts to a zero-frame code array (the empty
    early-return contract check_codes_range also honours)."""
    x = jnp.zeros((0, 4, 4))
    out = adc.quantize_codes_per_frame(x, jnp.zeros((0,), jnp.int32))
    assert out.shape == (0, 4, 4)
    adc.check_codes_range(out, 8)  # must not raise on empty


@pytest.mark.parametrize("bits", [1, 2, 3, 4])
def test_nibble_pack_round_trip(bits):
    """pack_nibbles -> unpack_nibbles is the identity on every code the
    int4 wire format admits, and the kernel-side unpacker agrees with
    the host-side one bit for bit."""
    x = jax.random.uniform(jax.random.PRNGKey(bits), (3, 6, 10),
                           minval=-0.2, maxval=1.7)
    codes = adc.pack_codes(adc.quantize_codes(x, bits), bits)
    packed = adc.pack_nibbles(codes)
    assert packed.dtype == jnp.uint8
    assert packed.shape == (3, 6, 5)
    np.testing.assert_array_equal(np.asarray(adc.unpack_nibbles(packed)),
                                  np.asarray(codes, np.int32))
    np.testing.assert_array_equal(
        np.asarray(k_int._unpack_nibbles_i32(jnp.asarray(packed))),
        np.asarray(codes, np.int32))


def test_nibble_pack_rejects_odd_width():
    codes = jnp.zeros((4, 7), jnp.uint8)
    with pytest.raises(ValueError, match="even"):
        adc.pack_nibbles(codes)


@hypothesis.given(st.integers(0, 2**16), st.integers(1, 12))
@hypothesis.settings(max_examples=25, deadline=None)
def test_reconstruction_code_round_trip(seed, bits):
    """quantize_codes(quantize(x)) == quantize_codes(x): the float
    reconstruction carries exactly its codes, nothing more."""
    x = jax.random.uniform(jax.random.PRNGKey(seed), (9, 17),
                           minval=-0.5, maxval=2.0)
    codes = adc.quantize_codes(x, bits)
    again = adc.quantize_codes(adc.quantize(x, bits), bits)
    np.testing.assert_array_equal(np.asarray(again), np.asarray(codes))


@hypothesis.given(st.integers(0, 2**16), st.integers(1, 12))
@hypothesis.settings(max_examples=25, deadline=None)
def test_quantize_idempotent(seed, bits):
    x = jax.random.uniform(jax.random.PRNGKey(seed), (9, 17),
                           minval=-0.5, maxval=2.0)
    q = adc.quantize(x, bits)
    np.testing.assert_array_equal(np.asarray(adc.quantize(q, bits)),
                                  np.asarray(q))


@hypothesis.given(st.integers(0, 2**16), st.integers(1, 12))
@hypothesis.settings(max_examples=25, deadline=None)
def test_quantize_codes_monotone(seed, bits):
    """x <= y (elementwise) implies codes(x) <= codes(y)."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = jax.random.uniform(k1, (257,), minval=-0.5, maxval=2.0)
    bump = jax.random.uniform(k2, (257,), minval=0.0, maxval=1.0)
    cx = np.asarray(adc.quantize_codes(x, bits))
    cy = np.asarray(adc.quantize_codes(x + bump, bits))
    assert (cy >= cx).all()
    # and the code range is the advertised one
    assert cx.min() >= 0 and cx.max() <= (1 << bits) - 1


@hypothesis.given(st.integers(0, 2**16), st.integers(1, 8),
                  st.integers(2, 16))
@hypothesis.settings(max_examples=15, deadline=None)
def test_int_accumulators_no_overflow_at_bounds(seed, bits, win):
    """At max-magnitude codes, the int32 window sum-of-squares equals the
    exact int64 value — for every (adc_bits, window) the bounds admit."""
    H = W = max(win * 2, 16)
    if not k_int.int_datapath_bounds(bits, H, W, win, win)["fits"]:
        hypothesis.assume(False)
    key = jax.random.PRNGKey(seed)
    # adversarial worst case: many max codes
    sel = jax.random.bernoulli(key, 0.9, (H, W))
    codes = jnp.where(sel, (1 << bits) - 1, 0).astype(jnp.int32)
    got = np.asarray(k_int.window_sumsq_codes(codes, win, win, 1))
    c64 = np.asarray(codes, np.int64)
    my = H - win + 1
    want = np.zeros((my, my), np.int64)
    for y in range(my):
        for x in range(my):
            blk = c64[y:y + win, x:x + win]
            want[y, x] = (blk * blk).sum()
    assert (want <= k_int.INT32_MAX).all()
    np.testing.assert_array_equal(got.astype(np.int64), want)


def test_max_supported_bits_fit_paper_scale():
    """8-bit codes on 128x128 frames with 16x16 windows — the paper's
    deployment envelope — fit the int32 datapath with headroom."""
    b = k_int.int_datapath_bounds(8, 128, 128, 16, 16)
    assert b["fits"]
    assert b["sumsq"] * 2 <= k_int.INT32_MAX  # >= 2x headroom
