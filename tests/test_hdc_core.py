"""Unit + property tests for the HDC core (ops, encoding equivalences)."""

try:  # prefer the real library when installed (requirements-dev.txt)
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # fallback keeps these tests running without the dep
    from _hypothesis_fallback import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import encoding, hdc

jax.config.update("jax_platform_name", "cpu")


def key(i=0):
    return jax.random.PRNGKey(i)


def rand_hv(k, dim=256):
    return jax.random.normal(k, (dim,))


# ---------------------------------------------------------------------------
# HDC operation properties (paper §III-A)
# ---------------------------------------------------------------------------

@hypothesis.given(st.integers(0, 2**16), st.integers(64, 512))
@hypothesis.settings(max_examples=20, deadline=None)
def test_bundle_similar_to_components(seed, dim):
    k1, k2 = jax.random.split(key(seed))
    h1, h2 = rand_hv(k1, dim), rand_hv(k2, dim)
    b = hdc.bundle(h1, h2)
    assert hdc.cosine_similarity(b, h1) > 0.3
    assert hdc.cosine_similarity(b, h2) > 0.3


@hypothesis.given(st.integers(0, 2**16))
@hypothesis.settings(max_examples=20, deadline=None)
def test_bind_dissimilar_but_similarity_preserving(seed):
    dim = 2048
    k1, k2, k3 = jax.random.split(key(seed), 3)
    h1, h2, v = rand_hv(k1, dim), rand_hv(k2, dim), rand_hv(k3, dim)
    bound = hdc.bind(v, h1)
    # dissimilar to both operands
    assert abs(hdc.cosine_similarity(bound, h1)) < 0.2
    assert abs(hdc.cosine_similarity(bound, v)) < 0.2
    # similarity preservation: sim(v*h1, v*h2) ~ sim(h1, h2) in expectation
    s_bound = hdc.cosine_similarity(hdc.bind(v, h1), hdc.bind(v, h2))
    s_raw = hdc.cosine_similarity(h1, h2)
    assert abs(float(s_bound) - float(s_raw)) < 0.35


@hypothesis.given(st.integers(0, 2**16), st.integers(1, 64))
@hypothesis.settings(max_examples=20, deadline=None)
def test_permute_orthogonal_and_invertible(seed, shift):
    dim = 2048
    h = rand_hv(key(seed), dim)
    p = hdc.permute(h, shift)
    assert abs(hdc.cosine_similarity(p, h)) < 0.15
    np.testing.assert_allclose(np.asarray(hdc.permute(p, -shift)),
                               np.asarray(h))


def test_class_scores_matches_pairwise():
    q = jax.random.normal(key(1), (5, 128))
    c = jax.random.normal(key(2), (3, 128))
    scores = hdc.class_scores(q, c)
    for i in range(5):
        for j in range(3):
            np.testing.assert_allclose(
                float(scores[i, j]),
                float(hdc.cosine_similarity(q[i], c[j])), rtol=1e-5)


# ---------------------------------------------------------------------------
# Encoding (paper §III-A, §IV-B)
# ---------------------------------------------------------------------------

def test_rff_encoding_preserves_similarity_ordering():
    """phi preserves the notion of similarity: close inputs -> similar HVs."""
    k1, k2 = jax.random.split(key(3))
    B, b = encoding.make_iid_base(k1, 64, 4096)
    x = jax.random.normal(k2, (64,))
    x_close = x + 0.05 * jax.random.normal(key(4), (64,))
    x_far = jax.random.normal(key(5), (64,))
    hx = encoding.apply_nonlinearity(x @ B, b)
    hc = encoding.apply_nonlinearity(x_close @ B, b)
    hf = encoding.apply_nonlinearity(x_far @ B, b)
    assert hdc.cosine_similarity(hx, hc) > hdc.cosine_similarity(hx, hf)


def test_perm_base_structure():
    """Eq. 1: B[r, j+1] is the permutation of B[r, j]."""
    B0, _ = encoding.make_perm_base_rows(key(6), 3, 128)
    B = encoding.expand_perm_base(B0, 4)
    assert B.shape == (3, 4, 128)
    for r in range(3):
        for j in range(3):
            np.testing.assert_allclose(
                np.asarray(B[r, j + 1]),
                np.asarray(hdc.permute(B[r, j], encoding.SHIFT)))


@pytest.mark.parametrize("stride", [1, 2, 3])
@pytest.mark.parametrize("hw", [(3, 4), (5, 5), (2, 7)])
def test_reuse_equals_naive(hw, stride):
    """The TPU prefix-sum reuse is numerically identical to naive encode."""
    h, w = hw
    frame = jax.random.normal(key(7), (17, 19))
    B0, b = encoding.make_perm_base_rows(key(8), h, 96)
    naive = encoding.encode_frame_naive(frame, B0, b, h=h, w=w,
                                        stride=stride)
    reuse = encoding.encode_frame_reuse(frame, B0, b, h=h, w=w,
                                        stride=stride)
    np.testing.assert_allclose(np.asarray(naive), np.asarray(reuse),
                               rtol=3e-5, atol=3e-5)


@hypothesis.given(st.integers(0, 2**16), st.sampled_from(["linear", "rff"]),
                  st.booleans())
@hypothesis.settings(max_examples=10, deadline=None)
def test_reuse_equals_naive_property(seed, nonlin, normalize):
    frame = jax.random.normal(key(seed), (12, 12))
    B0, b = encoding.make_perm_base_rows(key(seed + 1), 3, 64)
    naive = encoding.encode_frame_naive(frame, B0, b, h=3, w=3, stride=2,
                                        nonlinearity=nonlin,
                                        normalize=normalize)
    reuse = encoding.encode_frame_reuse(frame, B0, b, h=3, w=3, stride=2,
                                        nonlinearity=nonlin,
                                        normalize=normalize)
    np.testing.assert_allclose(np.asarray(naive), np.asarray(reuse),
                               rtol=1e-4, atol=1e-4)


def test_extract_fragments_matches_manual():
    frame = jnp.arange(6 * 7, dtype=jnp.float32).reshape(6, 7)
    frags = encoding.extract_fragments(frame, 2, 3, 2)
    assert frags.shape == (3, 3, 2, 3)
    np.testing.assert_allclose(np.asarray(frags[1, 2]),
                               np.asarray(frame[2:4, 4:7]))


def test_num_windows_skipped_area():
    # 13 wide, window 4, stride 3 -> starts at 0,3,6,9 (9+4=13 fits) = 4
    assert encoding.num_windows(13, 4, 3) == 4
    # stride 5 -> 0,5 (5+4=9 fits), 10+4=14 doesn't -> 2 (skipped area)
    assert encoding.num_windows(13, 4, 5) == 2


def test_encode_fragments_normalization():
    frags = jax.random.normal(key(9), (4, 3, 3)) * 100.0
    B, b = encoding.make_iid_base(key(10), 9, 64)
    h1 = encoding.encode_fragments(frags, B, b)
    h2 = encoding.encode_fragments(frags * 5.0, B, b)  # scale-invariant
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-4,
                               atol=1e-5)
