"""Shared pytest plumbing: golden-fixture update flag + JAX map-count relief.

``pytest tests/test_golden.py --update-golden`` regenerates the checked-in
reference outputs under ``tests/golden/`` instead of comparing against
them. Regenerating is a *reviewed* action — the diff of the golden files
IS the behavior change.

The module-teardown hook below keeps a long single-process run of the
whole suite under Linux's ``vm.max_map_count`` ceiling (default 65530).
Every live XLA:CPU executable holds a triplet of anonymous mmap'd
JIT-code regions, and jitted entry points referenced from module state
(runners, memoized helpers, ``functools.partial`` closures) keep their
executables alive for the life of the process. With enough test modules
the map count walks into the ceiling and the *next* LLVM compile dies
with a SIGSEGV when ``mmap`` fails — the failure surfaces in whichever
test happens to compile last, not in the one that created the pressure.
``jax.clear_caches()`` drops the executables (and their maps) at module
boundaries, but only once the process is actually map-heavy, so cheap
modules don't pay recompilation for shared jitted paths.

``REPRO_SANITIZE=1`` additionally arms the runtime sanitizer harness
(:mod:`repro.analysis.sanitize`) for the whole run: ``jax_debug_nans``
+ ``jax_check_tracer_leaks`` process-wide, the suite-wide compile
ledger (so ``steady_state()`` regions fail on any fresh XLA compile),
and the transfer guard inside every ``no_implicit_transfers()`` block.
"""

import pytest

from repro.analysis import sanitize as _sanitize

_SANITIZING = _sanitize.install_if_enabled()

# Clear compiled-executable caches once the process holds this many
# memory maps. Well under the 65530 default ceiling, with headroom for
# the heaviest single module (~15k maps) on top before the next check.
_MAP_COUNT_HIGH_WATER = 25_000


def _map_count():
    """Current number of memory maps, or None where /proc is unavailable."""
    try:
        with open("/proc/self/maps", "rb") as fh:
            return sum(1 for _ in fh)
    except OSError:
        return None


def pytest_addoption(parser):
    parser.addoption(
        "--update-golden", action="store_true", default=False,
        help="rewrite tests/golden/*.json from the current implementation "
             "instead of asserting against it")


def pytest_report_header(config):
    if _SANITIZING:
        return ("repro sanitizers: ON (debug_nans, tracer-leak checks, "
                "compile ledger, transfer guard)")
    return None


@pytest.fixture
def compile_ledger():
    """The process-wide compile ledger (installs its listener on first use).

    Tests assert steady-state regions with ``ledger.expect_no_compiles()``
    (or the ``sanitize.steady_state()`` shorthand): any fresh XLA compile
    inside the block fails the test.
    """
    return _sanitize.ledger()


@pytest.fixture(autouse=True, scope="module")
def _relieve_jax_map_pressure():
    yield
    n = _map_count()
    # No /proc (non-Linux): clear unconditionally — slower, never fatal.
    if n is not None and n < _MAP_COUNT_HIGH_WATER:
        return
    import jax

    jax.clear_caches()
