"""Shared pytest plumbing: the golden-fixture update flag.

``pytest tests/test_golden.py --update-golden`` regenerates the checked-in
reference outputs under ``tests/golden/`` instead of comparing against
them. Regenerating is a *reviewed* action — the diff of the golden files
IS the behavior change.
"""


def pytest_addoption(parser):
    parser.addoption(
        "--update-golden", action="store_true", default=False,
        help="rewrite tests/golden/*.json from the current implementation "
             "instead of asserting against it")
