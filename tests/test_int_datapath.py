"""Integer ADC-code datapath: kernel correctness, bounds, determinism.

The int kernel (``repro.kernels.sliding_scores_int``) must (a) agree
bitwise-closely with its pure-jnp quantized-operand oracle across shapes,
strides, D tilings and per-stream class tiles — in every mode: int8,
packed int4 wire codes, and the ±1 binary geometry, (b) track the float
path within quantization tolerance, (c) never overflow its int32
accumulators at the advertised bounds, and (d) be bitwise deterministic
across runs. The large-W VMEM working-set regression lives in
``test_workingset.py``; cross-backend / cross-precision *ranking*
contracts live in ``test_parity_matrix.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import encoding
from repro.kernels import ops
from repro.kernels import sliding_scores as k_ss
from repro.kernels import sliding_scores_int as k_int
from repro.sensing import adc

jax.config.update("jax_platform_name", "cpu")


def key(i):
    return jax.random.PRNGKey(i)


def make_inputs(seed, N, H, W, D, h, bits=8):
    frames = jax.random.uniform(key(seed), (N, H, W), maxval=1.5)
    codes = adc.pack_codes(adc.quantize_codes(frames, bits), bits)
    B0, b = encoding.make_perm_base_rows(key(seed + 1), h, D)
    C = jax.random.normal(key(seed + 2), (2, D))
    return frames, codes, B0, b, C


@pytest.mark.parametrize("stride", [1, 2, 4])
def test_int_kernel_matches_jnp_oracle(stride):
    """Pallas int kernel == pure-jnp int oracle (same quantized operands,
    same exact int32 accumulation; only float-epilogue rounding differs)."""
    N, H, W, D, h, w = 5, 18, 22, 64, 4, 5
    _, codes, B0, b, C = make_inputs(0, N, H, W, D, h)
    tiles = k_int.precompute_tiles_int(B0, b, C, W=W, w=w, stride=stride,
                                       block_d=32)
    got = k_int.fragment_scores_batch_int(codes, tiles, h=h, w=w,
                                          stride=stride, interpret=True)
    want = k_int.fragment_scores_batch_int_ref(codes, tiles, h=h, w=w,
                                               stride=stride)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_int_path_tracks_float_path():
    """Same ADC capture through both datapaths: scores agree to the int8
    slab/class rounding (small vs the score dynamic range)."""
    N, H, W, D, h, w, stride = 6, 20, 24, 128, 4, 5, 2
    frames, codes, B0, b, C = make_inputs(10, N, H, W, D, h)
    ft = k_ss.precompute_tiles(B0, b, C, W=W, w=w, stride=stride,
                               block_d=64)
    fs = k_ss.fragment_scores_batch(adc.quantize(frames, 8), ft, h=h, w=w,
                                    stride=stride, interpret=True)
    it = k_int.precompute_tiles_int(B0, b, C, W=W, w=w, stride=stride,
                                    block_d=64)
    si = k_int.fragment_scores_batch_int(codes, it, h=h, w=w,
                                         stride=stride, interpret=True)
    span = float(jnp.max(fs) - jnp.min(fs))
    assert float(jnp.abs(si - fs).max()) < 0.05 * max(span, 0.1)


@pytest.mark.parametrize("H,W,h,w,stride", [
    (17, 23, 4, 5, 3),    # non-square; stride divides neither H-h nor W-w
    (19, 13, 6, 3, 4),    # W < H, single-column tail
    (15, 31, 5, 5, 7),    # wide frame, large stride -> tiny score map
])
def test_int_kernel_odd_shapes(H, W, h, w, stride):
    N, D = 3, 64
    _, codes, B0, b, C = make_inputs(20, N, H, W, D, h)
    tiles = k_int.precompute_tiles_int(B0, b, C, W=W, w=w, stride=stride,
                                       block_d=32)
    got = k_int.fragment_scores_batch_int(codes, tiles, h=h, w=w,
                                          stride=stride, interpret=True)
    my = (H - h) // stride + 1
    mx = (W - w) // stride + 1
    assert got.shape == (N, my, mx)
    want = k_int.fragment_scores_batch_int_ref(codes, tiles, h=h, w=w,
                                               stride=stride)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("block_d", [1000, 48])
def test_int_kernel_non_divisible_block_d(block_d):
    """D % block_d != 0 collapses to a single D tile (and still matches)."""
    N, H, W, D, h, w, stride = 3, 14, 16, 96, 3, 4, 2
    _, codes, B0, b, C = make_inputs(30, N, H, W, D, h)
    tiles = k_int.precompute_tiles_int(B0, b, C, W=W, w=w, stride=stride,
                                       block_d=block_d)
    got = k_int.fragment_scores_batch_int(codes, tiles, h=h, w=w,
                                          stride=stride, interpret=True)
    want = k_int.fragment_scores_batch_int_ref(codes, tiles, h=h, w=w,
                                               stride=stride)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_int_per_stream_tiles_one_launch():
    """(S, n_dt, mx, TD) int8 class tiles: batch element n reads stream
    n // C's classifier — matches scoring each stream separately."""
    S, C_, H, W, D, h, w, stride = 3, 4, 14, 18, 64, 3, 4, 2
    _, codes, B0, b, _ = make_inputs(40, S * C_, H, W, D, h)
    chvs = jax.random.normal(key(43), (S, 2, D))
    geom = k_int.precompute_geometry_int(B0, b, W=W, w=w, stride=stride,
                                         block_d=32)
    fleet_tiles = k_int.retile_classes_int_fleet(geom, chvs)
    got = k_int.fragment_scores_batch_int(codes, fleet_tiles, h=h, w=w,
                                          stride=stride, interpret=True,
                                          frames_per_stream=C_)
    per = codes.reshape(S, C_, H, W)
    for s in range(S):
        tiles_s = k_int.retile_classes_int(geom, chvs[s])
        want = k_int.fragment_scores_batch_int(per[s], tiles_s, h=h, w=w,
                                               stride=stride,
                                               interpret=True)
        np.testing.assert_allclose(np.asarray(got[s * C_:(s + 1) * C_]),
                                   np.asarray(want), rtol=1e-6, atol=1e-6)


def test_retile_matches_precompute_tiles_int():
    """precompute_tiles_int == retile_classes_int(precompute_geometry_int)
    bitwise — the online-learning install path can't drift from the
    offline one."""
    H, W, D, h, w, stride = 14, 16, 96, 3, 4, 2
    _, _, B0, b, C = make_inputs(50, 1, H, W, D, h)
    a = k_int.precompute_tiles_int(B0, b, C, W=W, w=w, stride=stride,
                                   block_d=48)
    geom = k_int.precompute_geometry_int(B0, b, W=W, w=w, stride=stride,
                                         block_d=48)
    c = k_int.retile_classes_int(geom, C)
    np.testing.assert_array_equal(np.asarray(a.cpos_t), np.asarray(c.cpos_t))
    np.testing.assert_array_equal(np.asarray(a.cneg_t), np.asarray(c.cneg_t))
    assert float(a.cpos_norm) == float(c.cpos_norm)


def test_window_norms_codes_exact_and_lsb_free():
    """The int32 SAT norm is exact: equals the int64 numpy ground truth,
    and (x LSB) equals the float path's window norms on reconstructions."""
    H, W, h, w, stride, bits = 20, 24, 5, 6, 2, 8
    frames = jax.random.uniform(key(60), (3, H, W), maxval=1.5)
    codes = adc.quantize_codes(frames, bits)
    got = k_int.window_norms_codes_batch(codes, h, w, stride)
    c = np.asarray(codes, np.int64)
    for i in range(3):
        my = (H - h) // stride + 1
        mx = (W - w) // stride + 1
        want = np.zeros((my, mx))
        for y in range(my):
            for x in range(mx):
                win = c[i, y * stride:y * stride + h,
                        x * stride:x * stride + w]
                want[y, x] = np.sqrt((win * win).sum())
        np.testing.assert_allclose(np.asarray(got[i]), want, rtol=1e-6)
    # LSB cancellation: float norms of the reconstruction = LSB * int norms
    fnorms = k_ss.window_norms_batch(adc.quantize(frames, bits), h, w,
                                     stride)
    np.testing.assert_allclose(np.asarray(fnorms),
                               np.asarray(got) * adc.lsb(bits),
                               rtol=1e-5, atol=1e-6)


def test_int_datapath_bounds_contract():
    b = ops.int_datapath_bounds(8, 128, 128, 16, 16)
    assert b["fits"]                       # the paper's scale is safe
    assert not ops.int_datapath_bounds(12, 512, 512, 16, 16)["fits"]
    with pytest.raises(ValueError):
        ops.assert_int_datapath_fits(12, 512, 512, 16, 16)
    ops.assert_int_datapath_fits(8, 128, 128, 16, 16)   # no raise


def test_int_kernel_worst_case_no_overflow():
    """All-max codes at max adc_bits: the int accumulators sit at their
    documented worst case and still match an exact int64 recomputation."""
    H, W, D, h, w, stride, bits = 12, 16, 32, 3, 4, 2, 8
    codes = jnp.full((1, H, W), (1 << bits) - 1, jnp.int32)
    B0, b_ = encoding.make_perm_base_rows(key(70), h, D)
    C = jax.random.normal(key(71), (2, D))
    tiles = k_int.precompute_tiles_int(B0, b_, C, W=W, w=w, stride=stride,
                                       block_d=D)
    got = k_int.fragment_scores_batch_int(codes, tiles, h=h, w=w,
                                          stride=stride, interpret=True)
    assert np.isfinite(np.asarray(got)).all()
    # int64 ground-truth accumulation of the projection for one fragment:
    # expand the window's shifted views from the padded base slabs (the
    # kernel rolls these out in-place; slabs_q[dt, r, i + j] is the value
    # the old pre-expanded layout stored at slab_mat[dt, r*W + i, j])
    base = np.asarray(tiles.geom.slabs_q, np.int64)[0]      # (h, D+W-1)
    slab = np.stack([base[:, i:i + D] for i in range(W)], axis=1)
    cmax = (1 << bits) - 1
    acc64 = slab[:, 0:w, :].sum(axis=(0, 1)) * cmax
    assert np.abs(acc64).max() <= ops.int_datapath_bounds(
        bits, H, W, h, w)["acc"]
    # the in-path int32 accumulation must equal the int64 one (no wrap)
    ref = k_int.fragment_scores_batch_int_ref(codes, tiles, h=h, w=w,
                                              stride=stride)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("variant", ["int8", "int4-packed", "binary"])
def test_int_scores_bitwise_deterministic(variant):
    """Every accumulation order the int kernel ships — int8, the packed
    int4 unpack-then-accumulate, and the ±1 binary matmuls — is exact
    integer arithmetic in a fixed association, hence bitwise stable."""
    N, H, W, D, h, w, stride = 4, 16, 16, 64, 4, 4, 2
    bits = 4 if variant == "int4-packed" else 8
    _, codes, B0, b, C = make_inputs(80, N, H, W, D, h, bits=bits)
    mode = "binary" if variant == "binary" else "int8"
    tiles = k_int.precompute_tiles_int(B0, b, C, W=W, w=w, stride=stride,
                                       block_d=32, mode=mode)
    packed = variant == "int4-packed"
    if packed:
        codes = adc.pack_nibbles(codes)
    a = k_int.fragment_scores_batch_int(codes, tiles, h=h, w=w,
                                        stride=stride, interpret=True,
                                        packed=packed)
    b2 = k_int.fragment_scores_batch_int(codes, tiles, h=h, w=w,
                                         stride=stride, interpret=True,
                                         packed=packed)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b2))


def test_int4_packed_matches_unpacked_bitwise():
    """Nibble packing is pure wire format: the kernel's in-place unpack
    reproduces the unpacked-codes scores bit for bit, and both match the
    jnp oracle fed the same packed bytes."""
    N, H, W, D, h, w, stride = 4, 16, 18, 64, 4, 5, 2
    _, codes, B0, b, C = make_inputs(110, N, H, W, D, h, bits=4)
    tiles = k_int.precompute_tiles_int(B0, b, C, W=W, w=w, stride=stride,
                                       block_d=32)
    packed = adc.pack_nibbles(codes)
    got_u = k_int.fragment_scores_batch_int(codes, tiles, h=h, w=w,
                                            stride=stride, interpret=True)
    got_p = k_int.fragment_scores_batch_int(packed, tiles, h=h, w=w,
                                            stride=stride, interpret=True,
                                            packed=True)
    np.testing.assert_array_equal(np.asarray(got_u), np.asarray(got_p))
    ref_p = k_int.fragment_scores_batch_int_ref(packed, tiles, h=h, w=w,
                                                stride=stride, packed=True)
    np.testing.assert_allclose(np.asarray(got_p), np.asarray(ref_p),
                               rtol=1e-6, atol=1e-6)


def test_binary_mode_kernel_matches_oracle():
    """mode="binary": slabs and class tiles really are ±1, the kernel
    still matches the quantized-operand oracle, and scores are finite."""
    N, H, W, D, h, w, stride = 4, 18, 22, 64, 4, 5, 2
    _, codes, B0, b, C = make_inputs(120, N, H, W, D, h)
    tiles = k_int.precompute_tiles_int(B0, b, C, W=W, w=w, stride=stride,
                                       block_d=32, mode="binary")
    assert set(np.unique(np.asarray(tiles.geom.slabs_q))) <= {-1, 1}
    assert set(np.unique(np.asarray(tiles.cpos_t))) <= {-1, 1}
    assert float(tiles.cpos_norm) == pytest.approx(np.sqrt(D))
    got = k_int.fragment_scores_batch_int(codes, tiles, h=h, w=w,
                                          stride=stride, interpret=True)
    want = k_int.fragment_scores_batch_int_ref(codes, tiles, h=h, w=w,
                                               stride=stride)
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_pack_nibbles_needs_even_width_and_geometry_mode_guard():
    with pytest.raises(ValueError):
        adc.pack_nibbles(jnp.zeros((2, 4, 15), jnp.int32))
    B0, b_ = encoding.make_perm_base_rows(key(130), 3, 32)
    with pytest.raises(ValueError):
        k_int.precompute_geometry_int(B0, b_, W=14, w=3, stride=2,
                                      block_d=32, mode="ternary")


def test_int_kernel_rejects_float_frames():
    """The fused entry consumes codes; float frames are a usage bug."""
    frames, _, B0, b, C = make_inputs(90, 2, 14, 14, 32, 3)
    tiles = k_int.precompute_tiles_int(B0, b, C, W=14, w=3, stride=2,
                                       block_d=32)
    with pytest.raises(TypeError):
        k_int.fragment_scores_batch_int(frames, tiles, h=3, w=3, stride=2,
                                        interpret=True)
    with pytest.raises(TypeError):
        k_int.fragment_scores_batch_int_ref(frames, tiles, h=3, w=3,
                                            stride=2)


def test_ops_int_entry_points_route():
    """ops wrappers: batch entry == kernel; fleet entry == reshaped batch."""
    S, C_, H, W, D, h, w, stride = 2, 3, 14, 16, 64, 3, 4, 2
    _, codes, B0, b, C = make_inputs(100, S * C_, H, W, D, h)
    got_b = ops.fragment_score_map_batch_int(codes, C, B0, b, h=h, w=w,
                                             stride=stride, block_d=32)
    tiles = k_int.precompute_tiles_int(B0, b, C, W=W, w=w, stride=stride,
                                       block_d=32)
    want = k_int.fragment_scores_batch_int(codes, tiles, h=h, w=w,
                                           stride=stride, interpret=True)
    np.testing.assert_array_equal(np.asarray(got_b), np.asarray(want))
    got_f = ops.fragment_score_map_fleet_int(
        codes.reshape(S, C_, H, W), C, B0, b, h=h, w=w, stride=stride,
        block_d=32)
    assert got_f.shape == (S, C_) + want.shape[1:]
    np.testing.assert_array_equal(np.asarray(got_f).reshape(want.shape),
                                  np.asarray(want))
