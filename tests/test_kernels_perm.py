"""hdc_encode_perm kernel (beyond-paper MXU + in-VMEM base expansion)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import encoding
from repro.kernels import ref
from repro.kernels.hdc_encode_perm import hdc_encode_perm

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("shape", [(10, 4, 8, 128, 16, 64),
                                   (7, 3, 5, 90, 15, 45),
                                   (16, 8, 8, 256, 64, 128)])
def test_perm_kernel_matches_expanded_base(shape):
    n, h, w, dim, bk, bd = shape
    key = jax.random.PRNGKey(0)
    B0, b = encoding.make_perm_base_rows(key, h, dim)
    x = jax.random.normal(jax.random.fold_in(key, 1), (n, h * w))
    x = x / jnp.linalg.norm(x, axis=-1, keepdims=True)
    got = hdc_encode_perm(x, B0, b, h=h, w=w, block_n=8, block_d=bd,
                          block_k=bk, interpret=True)
    want = ref.hdc_encode(x, encoding.flat_perm_base(B0, w), b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("nonlin", ["linear", "sign"])
def test_perm_kernel_nonlinearities(nonlin):
    n, h, w, dim = 6, 2, 4, 64
    key = jax.random.PRNGKey(1)
    B0, b = encoding.make_perm_base_rows(key, h, dim)
    x = jax.random.normal(jax.random.fold_in(key, 2), (n, h * w))
    got = hdc_encode_perm(x, B0, b, h=h, w=w, nonlinearity=nonlin,
                          block_n=8, block_d=32, block_k=8, interpret=True)
    want = ref.hdc_encode(x, encoding.flat_perm_base(B0, w), b,
                          nonlinearity=nonlin)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_perm_kernel_bf16():
    n, h, w, dim = 8, 4, 4, 128
    key = jax.random.PRNGKey(2)
    B0, b = encoding.make_perm_base_rows(key, h, dim)
    x = jax.random.normal(jax.random.fold_in(key, 3),
                          (n, h * w)).astype(jnp.bfloat16)
    got = hdc_encode_perm(x, B0.astype(jnp.bfloat16), b, h=h, w=w,
                          block_n=8, block_d=64, block_k=16,
                          interpret=True)
    want = ref.hdc_encode(x, encoding.flat_perm_base(B0, w), b)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)
