"""Fleet streaming runtime: S-stream batched path == S independent runners.

The contract (ISSUE 2 acceptance): ``FleetRunner`` over S streams returns
per-stream results/``StreamStats`` identical to S independent
``StreamRunner`` instances — on both the ``jnp`` and ``pallas`` backends,
with and without the ADC in the loop, and unchanged under sensor-axis
sharding (``shard_map`` no-ops to the same numbers on one device; the CI
multi-device job runs the same tests on a real 8-device host mesh).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import encoding, energy, hypersense
from repro.core.sensor_control import ControllerConfig
from repro.distributed import sharding as shlib
from repro.sensing import adc, synthetic
from repro.sensing.fleet import (FleetRunner, fleet_report, simulate_fleet)
from repro.sensing.stream import StreamRunner, simulate_stream_batched

jax.config.update("jax_platform_name", "cpu")


def key(i):
    return jax.random.PRNGKey(i)


def make_model(h=6, w=6, stride=3, D=128, t_score=-0.05, t_detection=2):
    B0, b = encoding.make_perm_base_rows(key(1), h, D)
    C = jax.random.normal(key(2), (2, D))
    return hypersense.HyperSenseModel(C, B0, b, h, w, stride,
                                      t_score=t_score,
                                      t_detection=t_detection)


def make_fleet(S, N, seed=10, height=24, width=24):
    cfg = synthetic.RadarConfig(height=height, width=width)
    frames, labels = [], []
    for s in range(S):
        f, _, y = synthetic.make_dataset(key(seed + s), N, cfg)
        frames.append(f)
        labels.append(np.asarray(y))
    return jnp.stack(frames), np.stack(labels)


def assert_streams_equal(fleet_out, per_stream_outs):
    s_f, f_f, g_f = fleet_out
    for s, (s_i, f_i, g_i) in enumerate(per_stream_outs):
        np.testing.assert_allclose(s_f[s], s_i, rtol=1e-6, atol=1e-6)
        np.testing.assert_array_equal(f_f[s], f_i)
        np.testing.assert_array_equal(g_f[s], g_i)


# ---------------------------------------------------------------------------
# fleet == S independent StreamRunners
#
# (the backend-parametrized fleet==independent-runners and the pallas
# bitwise-parity tests moved into the backend x precision x adapt matrix:
# tests/test_parity_matrix.py. What stays here is the StreamStats
# derivation, which the matrix does not cover.)
# ---------------------------------------------------------------------------

def test_fleet_stats_match_independent_simulations():
    model = make_model()
    frames, labels = make_fleet(S=4, N=21)
    cfg = ControllerConfig(hold_frames=2)
    fr = FleetRunner(model, cfg, chunk_size=8, block_d=64)
    out = fr.process(frames)
    # the derived StreamStats are identical, stream by stream
    rep = fleet_report(out[1], out[2], labels)
    assert rep.n_sensors == 4 and rep.n_frames == 21
    for s in range(4):
        ref = simulate_stream_batched(model, frames[s], labels[s], cfg,
                                      chunk_size=8, block_d=64)
        got = rep.stats[s]
        np.testing.assert_array_equal(got.decisions, ref.decisions)
        np.testing.assert_array_equal(got.gated_on, ref.gated_on)
        assert got.duty_cycle == ref.duty_cycle
        assert got.missed_positive == ref.missed_positive
        assert got.false_active == ref.false_active


def test_fleet_state_carries_across_process_calls():
    model = make_model()
    frames, _ = make_fleet(S=3, N=23)
    cfg = ControllerConfig(hold_frames=3)
    whole = FleetRunner(model, cfg, chunk_size=8)
    s_all, f_all, g_all = whole.process(frames)
    split = FleetRunner(model, cfg, chunk_size=8)
    parts = [split.process(frames[:, a:z])
             for a, z in [(0, 7), (7, 10), (10, 23)]]
    np.testing.assert_allclose(
        np.concatenate([p[0] for p in parts], axis=1), s_all,
        rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(
        np.concatenate([p[1] for p in parts], axis=1), f_all)
    np.testing.assert_array_equal(
        np.concatenate([p[2] for p in parts], axis=1), g_all)


def test_fleet_rejects_bad_inputs():
    model = make_model()
    with pytest.raises(ValueError):
        FleetRunner(model, chunk_size=0)
    with pytest.raises(ValueError):            # noise without an ADC
        FleetRunner(model, adc_sigma=0.05)
    r = FleetRunner(model)
    with pytest.raises(ValueError):
        r.process(jnp.zeros((4, 24, 24)))          # missing sensor axis
    frames, _ = make_fleet(S=2, N=5)
    r.process(frames)
    with pytest.raises(ValueError):                # fleet size changed
        r.process(jnp.zeros((3, 5, 24, 24)))


# ---------------------------------------------------------------------------
# ADC in the loop
# ---------------------------------------------------------------------------

def test_fleet_adc_internal_equals_prequantized():
    model = make_model()
    frames, _ = make_fleet(S=3, N=13)
    cfg = ControllerConfig(hold_frames=2)
    internal = FleetRunner(model, cfg, chunk_size=4, adc_bits=4)
    s_i, f_i, g_i = internal.process(frames)
    pre = FleetRunner(model, cfg, chunk_size=4)
    s_p, f_p, g_p = pre.process(adc.quantize(frames, 4))
    np.testing.assert_array_equal(s_i, s_p)
    np.testing.assert_array_equal(f_i, f_p)
    np.testing.assert_array_equal(g_i, g_p)


def test_fleet_noisy_adc_matches_independent_runners():
    """Per-(stream, frame-index) noise keys: the fleet's ADC captures are
    exactly the ones S independent runners with folded keys would see."""
    model = make_model()
    frames, _ = make_fleet(S=3, N=11)
    cfg = ControllerConfig(hold_frames=2)
    base = jax.random.PRNGKey(5)
    fr = FleetRunner(model, cfg, chunk_size=4, adc_bits=4, adc_sigma=0.02,
                     adc_key=base)
    out = fr.process(frames)
    singles = []
    for s in range(3):
        r = StreamRunner(model, cfg, chunk_size=4, adc_bits=4,
                         adc_sigma=0.02,
                         adc_key=jax.random.fold_in(base, s))
        singles.append(r.process(frames[s]))
    assert_streams_equal(out, singles)


# ---------------------------------------------------------------------------
# sensor-axis sharding (shard_map)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_fleet_sharded_matches_unsharded(backend):
    """Under a mesh the sensor axis is shard_map'd; results are unchanged.

    On a 1-device host this exercises the shard_map code path with a
    trivial mesh; the CI job forces 8 host devices so the same assertion
    covers a real multi-device partitioning of the sensor axis.
    """
    model = make_model()
    S = 8
    frames, _ = make_fleet(S=S, N=7)
    cfg = ControllerConfig(hold_frames=2)
    plain = FleetRunner(model, cfg, chunk_size=4, backend=backend,
                        block_d=64)
    s0, f0, g0 = plain.process(frames)
    n_dev = jax.device_count()
    data = n_dev if S % n_dev == 0 else 1
    mesh = jax.make_mesh((data, n_dev // data), ("data", "model"))
    with shlib.use_mesh(mesh):
        sharded = FleetRunner(model, cfg, chunk_size=4, backend=backend,
                              block_d=64)
        s1, f1, g1 = sharded.process(frames)
    np.testing.assert_allclose(s0, s1, rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(f0, f1)
    np.testing.assert_array_equal(g0, g1)


def test_fleet_int8_per_stream_adapt_backend_parity():
    """The per-stream-adapt x int8 cell: retile_classes_int_fleet feeding
    the kernel's stream-indexed int8 class tiles must agree with the jnp
    oracle, and the per-stream classifiers must actually diverge."""
    from repro.core.online import AdaptConfig

    model = make_model()
    frames, labels = make_fleet(S=3, N=9)
    cfg = ControllerConfig(hold_frames=1)
    ad = AdaptConfig(mode="label", lr=1.0, scope="per-stream")
    outs = {}
    for backend in ("jnp", "pallas"):
        r = FleetRunner(model, cfg, chunk_size=4, backend=backend,
                        block_d=64, adc_bits=8, precision="int8", adapt=ad)
        outs[backend] = r.process(frames, labels=labels)
        assert r.class_hvs.shape[0] == 3
        # streams saw different samples -> different classifiers
        assert not np.allclose(np.asarray(r.class_hvs[0]),
                               np.asarray(r.class_hvs[1]))
    np.testing.assert_allclose(outs["pallas"][0], outs["jnp"][0],
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(outs["pallas"][1], outs["jnp"][1])


def test_fleet_closed_loop_sharded_matches_unsharded():
    """The closed capture loop composes with sensor-axis sharding: the
    per-stream (hold, phase) ADC state rides the partitioned StreamState
    and the control scan emits no collectives — shard_map'd closed-loop
    super-chunks == the unsharded step, capture log included."""
    from repro.core.sensor_control import CaptureConfig

    model = make_model()
    S = 8
    frames, _ = make_fleet(S=S, N=7)
    cfg = ControllerConfig(base_rate_hz=15, active_rate_hz=60,
                           hold_frames=2)
    plain = FleetRunner(model, cfg, chunk_size=4, block_d=64,
                        control=CaptureConfig(hp_buffer=0))
    s0, f0, g0 = plain.process(frames)
    n_dev = jax.device_count()
    data = n_dev if S % n_dev == 0 else 1
    mesh = jax.make_mesh((data, n_dev // data), ("data", "model"))
    with shlib.use_mesh(mesh):
        sharded = FleetRunner(model, cfg, chunk_size=4, block_d=64,
                              control=CaptureConfig(hp_buffer=0))
        s1, f1, g1 = sharded.process(frames)
    np.testing.assert_allclose(s0, s1, rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(f0, f1)
    np.testing.assert_array_equal(g0, g1)
    np.testing.assert_array_equal(plain.capture_log.sampled,
                                  sharded.capture_log.sampled)
    assert plain.capture_log.sampled.sum() < S * 7   # loop actually closed


def test_fleet_int8_sharded_matches_unsharded():
    """The int8 ADC-code datapath composes with sensor-axis sharding:
    shard_map'd integer super-chunks == the unsharded step (the int tiles
    ride the replicated spec exactly like the float tiles)."""
    model = make_model()
    S = 8
    frames, _ = make_fleet(S=S, N=6)
    cfg = ControllerConfig(hold_frames=2)
    plain = FleetRunner(model, cfg, chunk_size=4, block_d=64, adc_bits=8,
                        precision="int8")
    s0, f0, g0 = plain.process(frames)
    n_dev = jax.device_count()
    data = n_dev if S % n_dev == 0 else 1
    mesh = jax.make_mesh((data, n_dev // data), ("data", "model"))
    with shlib.use_mesh(mesh):
        sharded = FleetRunner(model, cfg, chunk_size=4, block_d=64,
                              adc_bits=8, precision="int8")
        s1, f1, g1 = sharded.process(frames)
    np.testing.assert_allclose(s0, s1, rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(f0, f1)
    np.testing.assert_array_equal(g0, g1)


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >1 device "
                           "(XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8)")
def test_fleet_sensor_axis_actually_partitioned():
    """With a real multi-device mesh the "sensors" rule claims the data
    axis — the step's sharded inputs split S across devices."""
    mesh = jax.make_mesh((jax.device_count(), 1), ("data", "model"))
    with shlib.use_mesh(mesh):
        spec = shlib.spec_for((jax.device_count() * 2,), ("sensors",))
    assert spec[0] is not None


@pytest.mark.parametrize("S", [3, 5, 9])
def test_fleet_non_divisible_sensor_axis_pads_and_shards(S):
    """S that doesn't divide the mesh is padded with masked slots — the
    step still shard_maps (never an unsharded fallback, never an error)
    and every real stream's outputs are bitwise-identical. On the CI
    8-device mesh S=5 pads to 8 and S=9 pads to 16."""
    from repro.sensing import fleet as fleet_mod

    model = make_model()
    frames, _ = make_fleet(S=S, N=5)
    cfg = ControllerConfig(hold_frames=1)
    if jax.device_count() % S == 0:
        pytest.skip(f"device count divisible by {S}")
    plain = FleetRunner(model, cfg, chunk_size=4)
    s0, f0, g0 = plain.process(frames)
    mesh = jax.make_mesh((jax.device_count(), 1), ("data", "model"))
    with shlib.use_mesh(mesh):
        r = FleetRunner(model, cfg, chunk_size=4)
        # the sensors axis must still be claimed (padding, not fallback)
        axes, k = fleet_mod._sensor_axes(mesh)
        assert axes == ("data",) and k == jax.device_count()
        s1, f1, g1 = r.process(frames)
        assert r._step_key[1] == ("data",)   # the built step is sharded
    np.testing.assert_array_equal(s0, s1)
    np.testing.assert_array_equal(f0, f1)
    np.testing.assert_array_equal(g0, g1)
    # carried state stays at the real S (pad slots never leak out)
    assert r.holds.shape == (S,)


def test_fleet_shared_adapt_sharded_no_fallback():
    """Shared-scope online adaptation now shards (all_gathered samples +
    replicated fold) instead of falling back to the unsharded step, and
    the adapted classifier matches unsharded bitwise."""
    from repro.core.online import AdaptConfig
    from repro.sensing import fleet as fleet_mod

    model = make_model()
    S = 8
    frames, labels = make_fleet(S=S, N=7)
    cfg = ControllerConfig(hold_frames=1)
    ad = AdaptConfig(mode="label", lr=0.5, scope="shared")
    plain = FleetRunner(model, cfg, chunk_size=4, adapt=ad)
    s0, f0, g0 = plain.process(frames, labels=labels)
    mesh = jax.make_mesh((jax.device_count(), 1), ("data", "model"))
    with shlib.use_mesh(mesh):
        r = FleetRunner(model, cfg, chunk_size=4, adapt=ad)
        s1, f1, g1 = r.process(frames, labels=labels)
        assert r._step_key[1] == ("data",)   # sharded, no fallback
    np.testing.assert_array_equal(s0, s1)
    np.testing.assert_array_equal(f0, f1)
    np.testing.assert_array_equal(g0, g1)
    np.testing.assert_array_equal(np.asarray(plain.class_hvs),
                                  np.asarray(r.class_hvs))
    # the shared classifier actually moved (the fold is not a no-op)
    assert not np.allclose(np.asarray(r.class_hvs),
                           np.asarray(model.class_hvs))


# ---------------------------------------------------------------------------
# fleet energy report
# ---------------------------------------------------------------------------

def test_simulate_fleet_report_accounting():
    model = make_model()
    frames, labels = make_fleet(S=4, N=16)
    rep = simulate_fleet(model, frames, labels,
                         ControllerConfig(hold_frames=2), chunk_size=8)
    assert rep.n_sensors == 4 and rep.n_frames == 16
    assert len(rep.stats) == 4
    duties = [s.duty_cycle for s in rep.stats]
    assert rep.duty_cycle == pytest.approx(float(np.mean(duties)))
    # totals: sum of per-stream measured breakdowns x frames
    p = energy.EnergyParams()
    want = sum(energy.hypersense_measured(d, p).total for d in duties) * 16
    assert rep.energy_total_j == pytest.approx(want)
    assert rep.baseline_total_j == pytest.approx(
        energy.conventional(p).total * 4 * 16)
    # an idle-dominated fleet saves energy vs always-on
    assert 0.0 < rep.total_saving < 1.0


def test_hypersense_measured_consistent_with_roc_form():
    p = energy.EnergyParams()
    d = energy.duty_cycle(0.1, 0.95, 0.01)
    a = energy.hypersense(0.1, 0.95, 0.01, p)
    b = energy.hypersense_measured(d, p)
    assert a == b


def test_int8_precision_bills_cheaper_hdc():
    """The int8 datapath reduces exactly the always-on HDC component."""
    p = energy.EnergyParams()
    f32 = energy.hypersense_measured(0.1, p)
    i8 = energy.hypersense_measured(0.1, p, precision="int8")
    assert i8.hdc == pytest.approx(f32.hdc * p.hdc_int8_factor)
    assert (i8.sensor, i8.adc, i8.comm, i8.cloud) == (
        f32.sensor, f32.adc, f32.comm, f32.cloud)
    assert i8.total < f32.total
    with pytest.raises(ValueError):
        energy.hypersense_measured(0.1, p, precision="fp16")
    # ...and the fleet report threads it through
    model = make_model()
    frames, labels = make_fleet(S=2, N=8)
    r = FleetRunner(model, ControllerConfig(hold_frames=1), chunk_size=4,
                    adc_bits=8, precision="int8")
    _, fired, gated = r.process(frames)
    rep_i8 = fleet_report(fired, gated, labels, precision="int8")
    rep_f32 = fleet_report(fired, gated, labels)
    assert rep_i8.energy_total_j < rep_f32.energy_total_j
