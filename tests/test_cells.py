"""Cell builders: every (arch x shape) constructs specs + shardings.

No compilation (that's the dry-run's job) — this guards the construction
path: abstract args, sharding trees, decode-state specs, skip rules.
Runs on a 1x1 mesh with the production axis names, so every rules code
path executes.
"""

import jax
import pytest

from repro import configs
from repro.configs.base import applicable_shapes
from repro.launch import steps

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


ALL_CELLS = [
    (arch, shape_name)
    for arch in configs.ARCH_IDS
    for shape_name, sc in applicable_shapes(configs.get_config(arch)).items()
    if sc is not None
]


def test_cell_count_matches_assignment():
    # 40 assigned cells, 9 skipped by the assignment's own rules
    assert len(ALL_CELLS) == 31


@pytest.mark.parametrize("arch,shape_name", ALL_CELLS)
def test_build_cell(arch, shape_name, mesh):
    cfg = configs.get_config(arch)
    shape = configs.SHAPES[shape_name]
    cell = steps.build_cell(cfg, shape, mesh)
    # abstract args: pure ShapeDtypeStructs (no device allocation)
    for leaf in jax.tree.leaves(cell.abstract_args):
        assert isinstance(leaf, jax.ShapeDtypeStruct), type(leaf)
    # sharding trees structurally match the args where present
    n_args = len(cell.abstract_args)
    assert len(cell.in_shardings) == n_args


@pytest.mark.parametrize("arch,shape_name", ALL_CELLS)
def test_input_specs_shapes(arch, shape_name):
    cfg = configs.get_config(arch)
    shape = configs.SHAPES[shape_name]
    args = steps.input_specs(cfg, shape)
    if shape.kind == "train":
        params, opt, batch = args
        assert batch.labels.shape == (shape.global_batch, shape.seq_len)
    elif shape.kind == "prefill":
        params, batch = args
        assert batch.labels.shape == (shape.global_batch, shape.seq_len)
    else:
        params, state, db = args
        assert db.tokens.shape == (shape.global_batch, 1)
        # decode state exists and carries the full cache length somewhere
        leaves = jax.tree.leaves(
            state, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        assert leaves, arch
        if cfg.family in ("dense", "moe", "vlm"):
            assert any(shape.seq_len in leaf.shape for leaf in leaves), \
                "KV cache must span the assigned context length"


def test_encoder_has_no_decode_cell():
    cfg = configs.get_config("hubert-xlarge")
    with pytest.raises(ValueError):
        steps.input_specs(cfg, configs.SHAPES["decode_32k"])
