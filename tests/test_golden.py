"""Golden regression fixtures: the paper-facing numbers, frozen.

Small seeded ``StreamRunner`` / ``FleetRunner`` scenarios with their full
outputs (scores, gate decisions, ``StreamStats``, energy totals) checked
into ``tests/golden/*.json``. A refactor that shifts any of these numbers
— however plausibly — fails here first and must regenerate the fixtures
*explicitly* (``pytest tests/test_golden.py --update-golden``), making the
change visible in review instead of silently drifting the reproduction.

Scores (all precisions — recorded rounded to 6 decimals) are compared
with a small float tolerance (``SCORE_ATOL``, covering cross-platform
BLAS reduction order); gate decisions and stats counts are compared
exactly, and every scenario asserts its scores sit ``DECISION_MARGIN``
clear of the firing threshold so jitter within tolerance can never flip
a recorded decision.
"""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import encoding, hypersense
from repro.core.online import AdaptConfig
from repro.core.sensor_control import ControllerConfig, stats_from
from repro.sensing import synthetic
from repro.sensing.fleet import FleetRunner, fleet_report
from repro.sensing.stream import StreamRunner

jax.config.update("jax_platform_name", "cpu")

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"
SCORE_ATOL = 5e-5


def make_model(h=6, w=6, stride=3, D=128, t_score=-0.05, t_detection=2):
    B0, b = encoding.make_perm_base_rows(jax.random.PRNGKey(1), h, D)
    C = jax.random.normal(jax.random.PRNGKey(2), (2, D))
    return hypersense.HyperSenseModel(C, B0, b, h, w, stride,
                                      t_score=t_score,
                                      t_detection=t_detection)


def make_stream_inputs(n=17, seed=10):
    cfg = synthetic.RadarConfig(height=24, width=24)
    frames, _, labels = synthetic.make_dataset(
        jax.random.PRNGKey(seed), n, cfg)
    return frames, np.asarray(labels)


#: every recorded score must sit at least this clear of the firing
#: threshold, so platform-level float jitter (bounded by SCORE_ATOL,
#: itself far above observed cross-BLAS drift) can never flip a golden
#: gate decision — asserted for EVERY scenario at build time (i.e. on
#: each compare and each --update-golden). 5x SCORE_ATOL.
DECISION_MARGIN = 5 * SCORE_ATOL


def _assert_decision_margin(scores, t_score):
    margin = float(np.abs(np.asarray(scores) - t_score).min())
    assert margin > DECISION_MARGIN, (
        f"golden scenario has a score within {margin:.2e} of t_score — "
        f"platform jitter could flip a recorded gate decision; reseed or "
        f"move t_score")


def _stream_payload(scores, fired, gated, labels, t_score):
    _assert_decision_margin(scores, t_score)
    stats = stats_from(fired, gated, labels)
    return {
        "scores": [round(float(s), 6) for s in np.asarray(scores).ravel()],
        "fired": np.asarray(fired).ravel().astype(int).tolist(),
        "gated": np.asarray(gated).ravel().astype(int).tolist(),
        "stats": {
            "duty_cycle": round(float(stats.duty_cycle), 6),
            "missed_positive": round(float(stats.missed_positive), 6),
            "false_active": round(float(stats.false_active), 6),
        },
    }


def scenario_stream_frozen():
    """Frozen single stream, ADC in the loop, jnp backend."""
    frames, labels = make_stream_inputs()
    model = make_model()
    r = StreamRunner(model, ControllerConfig(hold_frames=2),
                     chunk_size=5, adc_bits=4)
    return _stream_payload(*r.process(frames), labels, model.t_score)


def scenario_stream_int8():
    """The int8 ADC-code datapath on the same stream."""
    frames, labels = make_stream_inputs()
    model = make_model()
    r = StreamRunner(model, ControllerConfig(hold_frames=2),
                     chunk_size=5, adc_bits=8, precision="int8")
    return _stream_payload(*r.process(frames), labels, model.t_score)


def scenario_stream_int4():
    """The packed int4 wire format (two codes per byte) on the same
    stream — pins the nibble pack/unpack round trip end to end."""
    frames, labels = make_stream_inputs()
    model = make_model()
    r = StreamRunner(model, ControllerConfig(hold_frames=2),
                     chunk_size=5, adc_bits=4, precision="int4")
    return _stream_payload(*r.process(frames), labels, model.t_score)


def scenario_stream_binary():
    """The bipolar binary gate (sign-quantized slabs AND class HVs) on
    the same stream — pins the +-1 datapath's scores and decisions."""
    frames, labels = make_stream_inputs()
    model = make_model()
    r = StreamRunner(model, ControllerConfig(hold_frames=2),
                     chunk_size=5, adc_bits=8, precision="binary")
    return _stream_payload(*r.process(frames), labels, model.t_score)


def scenario_stream_adaptive():
    """Label-feedback online learning (the mutable-model hot path)."""
    frames, labels = make_stream_inputs(seed=11)
    model = make_model()
    r = StreamRunner(model, ControllerConfig(hold_frames=2),
                     chunk_size=5,
                     adapt=AdaptConfig(mode="label", lr=0.5))
    out = r.process(frames, labels=labels)
    payload = _stream_payload(*out, labels, model.t_score)
    # the adapted classifier itself is part of the contract
    payload["class_hvs_checksum"] = round(
        float(jnp.sum(jnp.abs(r.class_hvs))), 4)
    return payload


def scenario_fleet():
    """Two-sensor fleet + the energy account billed from its duty cycle."""
    cfg = synthetic.RadarConfig(height=24, width=24)
    frames = jnp.stack([
        synthetic.make_dataset(jax.random.PRNGKey(20 + s), 11, cfg)[0]
        for s in range(2)])
    labels = np.stack([
        np.asarray(synthetic.make_dataset(jax.random.PRNGKey(20 + s), 11,
                                          cfg)[2])
        for s in range(2)])
    model = make_model()
    r = FleetRunner(model, ControllerConfig(hold_frames=1),
                    chunk_size=4, adc_bits=4)
    scores, fired, gated = r.process(frames)
    _assert_decision_margin(scores, model.t_score)
    rep = fleet_report(fired, gated, labels)
    return {
        "scores": [round(float(s), 6) for s in scores.ravel()],
        "fired": fired.ravel().astype(int).tolist(),
        "gated": gated.ravel().astype(int).tolist(),
        "duty_cycle": round(rep.duty_cycle, 6),
        "energy_total_j": round(rep.energy_total_j, 6),
        "total_saving": round(rep.total_saving, 6),
    }


def scenario_fleet_sharded():
    """Closed-loop control + shared adaptation + int8, on a 4x2
    (sensors x hyperdim) mesh with S=3 padding the 4-way sensor axis —
    the full 2-D shard_map datapath in one frozen fixture. Bitwise parity
    with the unsharded runner is pinned in test_parity_matrix.py; this
    pins the VALUES (and, via test_golden_fleet_sharded_replays_bitwise,
    replay determinism) against silent drift."""
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    from repro.core.sensor_control import CaptureConfig
    from repro.distributed import sharding as shlib

    cfg = synthetic.RadarConfig(height=24, width=24)
    sets = [synthetic.make_dataset(jax.random.PRNGKey(30 + s), 11, cfg)
            for s in range(3)]
    frames = jnp.stack([st[0] for st in sets])
    labels = np.stack([np.asarray(st[2]) for st in sets])
    model = make_model()
    with shlib.use_mesh(jax.make_mesh((4, 2), ("data", "model"))):
        r = FleetRunner(model,
                        ControllerConfig(base_rate_hz=20.0,
                                         active_rate_hz=60.0,
                                         hold_frames=2),
                        chunk_size=4, backend="jnp", block_d=16,
                        adc_bits=8, precision="int8",
                        adapt=AdaptConfig(mode="label", lr=0.5,
                                          scope="shared"),
                        control=CaptureConfig())
        scores, fired, gated = r.process(frames, labels=labels)
    # the step must really have sharded BOTH axes — a fallback would
    # freeze fallback numbers into the fixture
    assert r._step_key[1] == ("data",) and r._step_key[2] == ("model",)
    _assert_decision_margin(scores, model.t_score)
    rep = fleet_report(fired, gated, labels, capture=r.capture_log)
    return {
        "scores": [round(float(s), 6) for s in scores.ravel()],
        "fired": fired.ravel().astype(int).tolist(),
        "gated": gated.ravel().astype(int).tolist(),
        "sampled": np.asarray(r.capture_log.sampled).ravel()
                     .astype(int).tolist(),
        "duty_cycle": round(rep.duty_cycle, 6),
        "energy_total_j": round(rep.energy_total_j, 6),
        "class_hvs_checksum": round(
            float(jnp.sum(jnp.abs(r.class_hvs))), 4),
    }


SCENARIOS = {
    "stream_frozen": scenario_stream_frozen,
    "stream_int8": scenario_stream_int8,
    "stream_int4": scenario_stream_int4,
    "stream_binary": scenario_stream_binary,
    "stream_adaptive": scenario_stream_adaptive,
    "fleet": scenario_fleet,
    "fleet_sharded": scenario_fleet_sharded,
}


def _assert_matches(got, want, path=""):
    """Recursive compare: exact for ints/bools/strings, atol for floats."""
    assert type(got) is type(want), f"{path}: {type(got)} vs {type(want)}"
    if isinstance(want, dict):
        assert got.keys() == want.keys(), f"{path}: keys differ"
        for k in want:
            _assert_matches(got[k], want[k], f"{path}.{k}")
    elif isinstance(want, list):
        assert len(got) == len(want), f"{path}: length differs"
        if want and isinstance(want[0], float):
            np.testing.assert_allclose(got, want, atol=SCORE_ATOL,
                                       err_msg=path)
        else:
            for i, (g, w) in enumerate(zip(got, want)):
                _assert_matches(g, w, f"{path}[{i}]")
    elif isinstance(want, float):
        assert got == pytest.approx(want, abs=SCORE_ATOL), path
    else:
        assert got == want, path


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_golden(name, request):
    path = GOLDEN_DIR / f"{name}.json"
    got = SCENARIOS[name]()
    if request.config.getoption("--update-golden"):
        GOLDEN_DIR.mkdir(exist_ok=True)
        path.write_text(json.dumps(got, indent=1) + "\n")
        pytest.skip(f"golden fixture {path.name} regenerated")
    assert path.exists(), (
        f"missing golden fixture {path} — run "
        f"pytest tests/test_golden.py --update-golden and review the diff")
    want = json.loads(path.read_text())
    _assert_matches(got, want, name)


def test_golden_fleet_sharded_replays_bitwise():
    """Two independent builds of the sharded-fleet scenario — fresh
    runners, fresh compiles — produce the IDENTICAL payload, float for
    float: the mesh datapath (collectives included) is deterministic, so
    the golden fixture is replayable, not a lucky snapshot."""
    a = scenario_fleet_sharded()
    b = scenario_fleet_sharded()
    assert a == b

