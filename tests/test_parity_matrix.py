"""The backend x precision x adapt parity matrix.

ONE parametrized surface replaces the ad-hoc per-file backend-parity
tests that used to live in ``test_kernels_batch.py`` / ``test_fleet.py``:

* **backend parity** — for every (precision, adapt) cell, the ``pallas``
  kernel path and the ``jnp`` path produce the same stream outputs
  (scores allclose, gate decisions identical) — across all four
  datapaths (float32 / int8 / packed int4 / binary);
* **precision ranking parity** — for every (backend, adapt, int
  precision) cell, the integer datapath's frame scores *rank*
  identically to the float path's at the matching ADC depth wherever
  the float scores are separated by more than the quantization margin
  (and the absolute perturbation stays under half that margin — which
  makes the ranking assertion a real constraint, not a tautology).
  ``binary`` is deliberately absent here: sign-quantizing both slabs
  and class HVs perturbs scores by ~2x the span at this D (measured),
  so binary holds only the weaker backend/fleet/decision parities and
  its accuracy story lives in the benchmark's D-vs-AUC curve;
* **fleet parity** — for every (backend, precision) cell, ``FleetRunner``
  equals S independent ``StreamRunner``s stream-for-stream;
* **mesh parity** — for every (mesh shape, precision, adapt scope) cell,
  the 2-D (sensors x hyperdim) ``shard_map``'d fleet produces scores,
  gate decisions, AND adapted classifiers bitwise-identical to the
  unsharded runner. Shapes whose device product exceeds the host run
  only under the CI multi-device job (``XLA_FLAGS=--xla_force_host_
  platform_device_count=8``); ``FLEET_TEST_MESH=4x2`` filters the matrix
  to one shape so CI can fan the shapes out across jobs.

Every cell shares ONE module-cached scenario (a gate trained on the
synthetic distribution, so scores are well spread), keeping the matrix
cheap: each runner executes once and every assertion reads the cache.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fragment_model as fm, hypersense
from repro.core.online import AdaptConfig
from repro.core.sensor_control import ControllerConfig
from repro.sensing import fragments, synthetic
from repro.sensing.fleet import FleetRunner
from repro.sensing.stream import StreamRunner

jax.config.update("jax_platform_name", "cpu")

BACKENDS = ["jnp", "pallas"]
PRECISIONS = ["float32", "int8", "int4", "binary"]
#: integer precisions that hold the strict ranking-parity contract
#: against the float path at the matching ADC depth (binary does not —
#: see the module docstring)
RANKED_PRECISIONS = ["int8", "int4"]
ADAPTS = [None, "label"]

FRAME, FRAG, STRIDE, DIM = 24, 6, 3, 128
N_STREAM, S_FLEET, N_FLEET = 21, 2, 10
BITS = 8
#: ADC depth each precision runs at (int4 packs two codes per byte, so
#: it is capped at 4 bits; binary sign-quantizes 8-bit-code projections)
PREC_BITS = {"float32": BITS, "int8": BITS, "int4": 4, "binary": BITS}
#: float-score separation below which integer ranking flips are
#: tolerated, as a fraction of the scenario's score span; the matrix
#: also asserts the integer perturbation is < margin / 2, so order on
#: separated pairs is a guaranteed-yet-nontrivial invariant
MARGIN_FRAC = 0.25

_CACHE = {}


def _scenario():
    if _CACHE:
        return _CACHE
    cfg = synthetic.RadarConfig(height=FRAME, width=FRAME)
    frames, masks, labels = synthetic.make_dataset(
        jax.random.PRNGKey(0), 40, cfg)
    frs, labs = fragments.sample_fragments(
        np.asarray(frames), np.asarray(masks), h=FRAG, w=FRAG,
        per_frame=2, seed=0)
    fmodel, _ = fm.train_fragment_model(
        jax.random.PRNGKey(1), jnp.asarray(frs), jnp.asarray(labs),
        dim=DIM, epochs=6)
    B0 = fmodel.B.reshape(FRAG, FRAG, -1)[:, 0, :]
    # t_score sits between the positive/negative score bands (asserted in
    # test_scenario_gate_is_nondegenerate), so gate parity is meaningful
    model = hypersense.from_fragment_model(fmodel, B0, h=FRAG, w=FRAG,
                                           stride=STRIDE, t_score=0.0125,
                                           t_detection=1)
    s_frames, _, s_labels = synthetic.make_dataset(
        jax.random.PRNGKey(2), N_STREAM, cfg)
    f_sets = [synthetic.make_dataset(jax.random.PRNGKey(3 + s), N_FLEET,
                                     cfg) for s in range(S_FLEET)]
    f_frames = jnp.stack([fs[0] for fs in f_sets])
    f_labels = np.stack([np.asarray(fs[2]) for fs in f_sets])
    _CACHE.update(model=model, frames=s_frames,
                  labels=np.asarray(s_labels), fleet=f_frames,
                  fleet_labels=f_labels, runs={})
    return _CACHE


def _run_stream(backend, precision, adapt, bits=None):
    sc = _scenario()
    bits = PREC_BITS[precision] if bits is None else bits
    k = ("stream", backend, precision, adapt, bits)
    if k not in sc["runs"]:
        a = (AdaptConfig(mode="label", lr=0.5) if adapt == "label"
             else None)
        r = StreamRunner(sc["model"], ControllerConfig(hold_frames=2),
                         chunk_size=8, backend=backend, block_d=64,
                         adc_bits=bits, precision=precision, adapt=a)
        feed = sc["labels"] if adapt == "label" else None
        sc["runs"][k] = r.process(sc["frames"], labels=feed)
    return sc["runs"][k]


def _run_fleet(backend, precision):
    sc = _scenario()
    k = ("fleet", backend, precision)
    if k not in sc["runs"]:
        r = FleetRunner(sc["model"], ControllerConfig(hold_frames=2),
                        chunk_size=4, backend=backend, block_d=64,
                        adc_bits=PREC_BITS[precision], precision=precision)
        sc["runs"][k] = r.process(sc["fleet"])
    return sc["runs"][k]


def _run_fleet_singles(backend, precision):
    sc = _scenario()
    k = ("fleet-singles", backend, precision)
    if k not in sc["runs"]:
        outs = []
        for s in range(S_FLEET):
            r = StreamRunner(sc["model"], ControllerConfig(hold_frames=2),
                             chunk_size=4, backend=backend, block_d=64,
                             adc_bits=PREC_BITS[precision],
                             precision=precision)
            outs.append(r.process(sc["fleet"][s]))
        sc["runs"][k] = outs
    return sc["runs"][k]


# ---------------------------------------------------------------------------
# backend parity: pallas == jnp in every (precision, adapt) cell
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("adapt", ADAPTS)
@pytest.mark.parametrize("precision", PRECISIONS)
def test_backend_parity(precision, adapt):
    s_j, f_j, g_j = _run_stream("jnp", precision, adapt)
    s_p, f_p, g_p = _run_stream("pallas", precision, adapt)
    np.testing.assert_allclose(s_p, s_j, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(f_p, f_j)
    np.testing.assert_array_equal(g_p, g_j)


# ---------------------------------------------------------------------------
# precision parity: int8/int4 rank like float32 in every (backend, adapt)
# cell, at the matching ADC depth
# ---------------------------------------------------------------------------

def test_scenario_gate_is_nondegenerate():
    """The shared scenario must exercise both gate outcomes — otherwise
    the matrix's fired/gated equalities would be vacuous."""
    _, fired, _ = _run_stream("jnp", "float32", None)
    assert fired.any() and not fired.all()


@pytest.mark.parametrize("iprec", RANKED_PRECISIONS)
@pytest.mark.parametrize("adapt", ADAPTS)
@pytest.mark.parametrize("backend", BACKENDS)
def test_precision_ranking_parity(backend, adapt, iprec):
    """The float comparator runs at the SAME ADC depth as the integer
    path (float32@4bits for int4) — so the margin bounds quantization
    *of the datapath*, not of the converter."""
    bits = PREC_BITS[iprec]
    s_f, _, _ = _run_stream(backend, "float32", adapt, bits=bits)
    s_i, _, _ = _run_stream(backend, iprec, adapt)
    margin = MARGIN_FRAC * float(s_f.max() - s_f.min())
    # absolute perturbation stays under half the separation margin...
    assert np.abs(s_i - s_f).max() < margin / 2
    # ...so separated pairs must rank identically — and the scenario has
    # to actually contain separated pairs for this to mean anything
    df = s_f[:, None] - s_f[None, :]
    di = s_i[:, None] - s_i[None, :]
    sep = np.abs(df) > margin
    assert sep.sum() > 0.3 * sep.size, "scenario lost its score spread"
    assert (np.sign(di[sep]) == np.sign(df[sep])).all()


@pytest.mark.parametrize("iprec", ["int8", "int4", "binary"])
def test_precision_scores_not_identical(iprec):
    """Each integer precision really is a different datapath (guards
    against the precision flag silently routing to the float kernel, or
    int4/binary silently routing to int8)."""
    s_f, _, _ = _run_stream("pallas", "float32", None)
    s_i, _, _ = _run_stream("pallas", iprec, None)
    assert np.abs(s_i - s_f).max() > 0.0
    if iprec != "int8":
        s_8, _, _ = _run_stream("pallas", "int8", None)
        assert np.abs(s_i - s_8).max() > 0.0


def test_stream_runner_deterministic_per_precision():
    """Two fresh runners over the same frames produce bitwise-identical
    scores for every precision — the deterministic-accumulation-order
    contract at the runner level (the kernel-level twin lives in
    test_int_datapath.py)."""
    sc = _scenario()
    for precision in PRECISIONS:
        runs = []
        for _ in range(2):
            r = StreamRunner(sc["model"], ControllerConfig(hold_frames=2),
                             chunk_size=8, backend="pallas", block_d=64,
                             adc_bits=PREC_BITS[precision],
                             precision=precision)
            runs.append(r.process(sc["frames"]))
        np.testing.assert_array_equal(runs[0][0], runs[1][0])
        np.testing.assert_array_equal(runs[0][2], runs[1][2])


# ---------------------------------------------------------------------------
# fleet parity: FleetRunner == S independent StreamRunners per cell
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("precision", PRECISIONS)
@pytest.mark.parametrize("backend", BACKENDS)
def test_fleet_matches_independent_runners(backend, precision):
    s_f, f_f, g_f = _run_fleet(backend, precision)
    singles = _run_fleet_singles(backend, precision)
    for s, (s_i, f_i, g_i) in enumerate(singles):
        np.testing.assert_allclose(s_f[s], s_i, rtol=1e-6, atol=1e-6)
        np.testing.assert_array_equal(f_f[s], f_i)
        np.testing.assert_array_equal(g_f[s], g_i)


def test_fleet_pallas_bitwise_matches_stream_runner():
    """The kernel grid's batch axis is parallel: flattening S*C changes
    nothing at all (stronger than allclose) — on both precisions."""
    for precision in PRECISIONS:
        s_f, _, _ = _run_fleet("pallas", precision)
        singles = _run_fleet_singles("pallas", precision)
        for s, (s_i, _, _) in enumerate(singles):
            np.testing.assert_array_equal(s_f[s], s_i)


# ---------------------------------------------------------------------------
# mesh parity: every (mesh shape, precision, adapt scope) cell of the 2-D
# (sensors x hyperdim) sharded fleet is BITWISE-identical to unsharded
# ---------------------------------------------------------------------------

#: (data, model) mesh shapes of the acceptance matrix. The fleet's S=2
#: pads up to the data extent (masked slots), and the hyperdim rule
#: claims "model" for the n_dt = DIM / MESH_BLOCK_D = 8 tile axis — so
#: 4x2/2x4/1x8 really partition D across devices.
MESH_SHAPES = {"1x1": (1, 1), "8x1": (8, 1), "4x2": (4, 2),
               "2x4": (2, 4), "1x8": (1, 8)}
#: block_d for the mesh cells: n_dt = 128/16 = 8 divides every model-axis
#: extent in MESH_SHAPES, so the hyperdim axis shards in every shape
MESH_BLOCK_D = 16
#: backend per precision: pallas pins the kernel path (float + the packed
#: int kernel); jnp pins the tiled oracle the int precisions serve from
#: on CPU fleets. int8-pallas-sharded is covered by tests/test_fleet.py
#: and the golden fixture.
MESH_BACKEND = {"float32": "pallas", "int8": "jnp", "int4": "pallas",
                "binary": "jnp"}
SCOPES = ["shared", "per-stream"]


def _mesh_or_skip(name: str):
    want = os.environ.get("FLEET_TEST_MESH")
    if want and name != want:
        pytest.skip(f"FLEET_TEST_MESH={want} filters out {name}")
    shape = MESH_SHAPES[name]
    if shape[0] * shape[1] > jax.device_count():
        pytest.skip(f"mesh {name} needs {shape[0] * shape[1]} devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    return jax.make_mesh(shape, ("data", "model"))


def _run_fleet_mesh(precision, scope, mesh_name=None):
    sc = _scenario()
    k = ("fleet-mesh", precision, scope, mesh_name)
    if k not in sc["runs"]:
        def go():
            r = FleetRunner(sc["model"], ControllerConfig(hold_frames=2),
                            chunk_size=4, backend=MESH_BACKEND[precision],
                            block_d=MESH_BLOCK_D,
                            adc_bits=PREC_BITS[precision],
                            precision=precision,
                            adapt=AdaptConfig(mode="label", lr=0.5,
                                              scope=scope))
            s, f, g = r.process(sc["fleet"], labels=sc["fleet_labels"])
            return s, f, g, np.asarray(r.class_hvs)

        if mesh_name is None:
            sc["runs"][k] = go()
        else:
            from repro.distributed import sharding as shlib
            with shlib.use_mesh(_mesh_or_skip(mesh_name)):
                sc["runs"][k] = go()
    return sc["runs"][k]


@pytest.mark.parametrize("scope", SCOPES)
@pytest.mark.parametrize("precision", PRECISIONS)
@pytest.mark.parametrize("mesh_name", list(MESH_SHAPES))
def test_mesh_matrix_bitwise(mesh_name, precision, scope):
    """Sharded scores, gate decisions, and adapted class_hvs are
    bitwise-identical to the unsharded runner in every cell — the
    ordered tile fold + all_gathered shared-scope fold guarantee, not an
    allclose."""
    got = _run_fleet_mesh(precision, scope, mesh_name)   # skips w/o mesh
    want = _run_fleet_mesh(precision, scope, None)
    for name, a, b in zip(("scores", "fired", "gated", "class_hvs"),
                          want, got):
        np.testing.assert_array_equal(a, b, err_msg=name)


def test_mesh_matrix_adapts_nontrivially():
    """The mesh cells' classifiers actually moved — so the class_hvs
    equality above compares real adapted state, not the initial model."""
    sc = _scenario()
    for scope in SCOPES:
        chvs = _run_fleet_mesh("float32", scope, None)[3]
        assert not np.allclose(chvs, np.asarray(sc["model"].class_hvs))
