"""FleetService: slot-pooled, double-buffered, checkpointed serving.

The serving layer's contracts (repro/launch/serve.py):

* churn-free service == synchronous FleetRunner, bitwise, per backend;
* ANY attach/detach/ragged-arrival schedule == independent StreamRunner
  per sensor, bitwise — including adapted per-stream classifiers and
  ADC noise keyed by persistent sensor uid (property-based);
* detach -> reattach restores a sensor's adapted classifier, gate hold,
  and capture log exactly, through intervening slot tenants;
* churn never recompiles the fleet step (fixed shapes, mask-only);
* checkpoint kill-and-resume is bitwise on both backends;
* pipelining depth (max_inflight) is invisible to results (FIFO).
"""

import os
import tempfile

try:  # prefer the real library when installed (requirements-dev.txt)
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # fallback keeps these tests running without the dep
    from _hypothesis_fallback import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import encoding, hypersense
from repro.core.online import AdaptConfig
from repro.core.sensor_control import CaptureConfig, ControllerConfig
from repro.launch.serve import FleetService
from repro.sensing.fleet import FleetRunner
from repro.sensing.stream import StreamRunner


def make_model(h=6, w=6, stride=3, D=64, t_score=-0.05, t_detection=2):
    B0, b = encoding.make_perm_base_rows(jax.random.PRNGKey(1), h, D)
    C = jax.random.normal(jax.random.PRNGKey(2), (2, D))
    return hypersense.HyperSenseModel(C, B0, b, h, w, stride,
                                      t_score=t_score,
                                      t_detection=t_detection)


def make_trace(S, N, height=18, width=18, seed=3):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(S, N, height, width)).astype(np.float32)


CFG = ControllerConfig(hold_frames=2)
C = 4   # chunk_size everywhere here


def drain(svc, got):
    for ch in svc.flush():
        for sid, out in ch.outputs.items():
            got.setdefault(sid, []).append(out)


def cat(got_sid):
    return [np.concatenate([o[j] for o in got_sid]) for j in range(3)]


# ---------------------------------------------------------------------------
# churn-free == FleetRunner, bitwise, per backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("kw", [
    {},
    {"adc_bits": 5, "adc_sigma": 0.02},
    {"adapt": AdaptConfig(mode="pseudo", scope="shared", lr=0.3)},
    {"control": CaptureConfig(hp_bits=12, hp_buffer=2)},
], ids=["frozen", "adc-noise", "adapt-shared", "closed-loop"])
def test_churn_free_bitwise_vs_fleet_runner(backend, kw):
    model = make_model()
    S, T = 3, 4
    trace = make_trace(S, T * C)
    runner = FleetRunner(model, CFG, chunk_size=C, backend=backend,
                         block_d=64, **kw)
    s_ref, f_ref, g_ref = runner.process(trace)

    svc = FleetService(model, CFG, n_slots=S, chunk_size=C,
                       backend=backend, block_d=64, **kw)
    for i in range(S):
        svc.attach(i)
    got = {}
    for t in range(T):
        svc.dispatch({i: trace[i, t * C:(t + 1) * C] for i in range(S)})
    drain(svc, got)
    for i in range(S):
        s, f, g = cat(got[i])
        np.testing.assert_array_equal(s, s_ref[i])
        np.testing.assert_array_equal(f, f_ref[i])
        np.testing.assert_array_equal(g, g_ref[i])
        log = svc.capture_log(i)
        np.testing.assert_array_equal(log.sampled,
                                      runner.capture_log.sampled[i])
        np.testing.assert_array_equal(log.gated,
                                      runner.capture_log.gated[i])
    if "control" in kw:
        ref_hp = runner.drain_hp()
        for i in range(S):
            idx, frames = svc.drain_hp(i)
            np.testing.assert_array_equal(idx, ref_hp[i][0])
            np.testing.assert_array_equal(frames, ref_hp[i][1])
        assert svc.hp_dropped == runner.hp_dropped


# ---------------------------------------------------------------------------
# slot-pool churn == independent StreamRunners (property-based)
# ---------------------------------------------------------------------------

@hypothesis.given(st.integers(0, 2 ** 31 - 1), st.booleans())
@hypothesis.settings(max_examples=8, deadline=None)
def test_any_churn_schedule_matches_independent_runners(seed, adapt_on):
    """Random attach/detach/silence schedule: every sensor's served
    outputs, capture log and (with per-stream adapt) final classifier
    are bitwise an independent StreamRunner's over just its own frames —
    whatever slots it landed in, whoever shared the step with it."""
    model = make_model()
    rng = np.random.default_rng(seed)
    n_sensors, n_slots, T = 5, 3, 6
    trace = make_trace(n_sensors, T * C, seed=seed % 1000)
    adapt = (AdaptConfig(mode="pseudo", scope="per-stream", lr=0.3)
             if adapt_on else None)
    kw = dict(chunk_size=C, backend="jnp", adc_bits=5, adc_sigma=0.02)
    svc = FleetService(model, CFG, n_slots=n_slots, adapt=adapt, **kw)

    attached, fed, got = set(), {}, {}
    warm = False                # first dispatch must carry >= 1 arrival
    for t in range(T):
        # mutate membership: random attach (if capacity) / detach
        if attached and rng.random() < 0.3:
            gone = rng.choice(sorted(attached))
            svc.detach(int(gone))
            attached.discard(int(gone))
        if svc.free_slots and rng.random() < 0.7:
            cand = [i for i in range(n_sensors) if i not in attached]
            if cand:
                sid = int(rng.choice(cand))
                svc.attach(sid)
                attached.add(sid)
        # ragged arrival: each attached sensor delivers this tick or not
        arrivals = {}
        for sid in sorted(attached):
            if rng.random() < 0.8:
                n0 = fed.setdefault(sid, 0)
                arrivals[sid] = trace[sid, n0:n0 + C]
                fed[sid] = n0 + C
        if not arrivals and not warm:
            continue            # frame shape not fixed yet — no tick
        warm = True
        svc.dispatch(arrivals)
    drain(svc, got)

    base_key = jax.random.PRNGKey(0)   # FleetService's default adc_key
    for sid, n in fed.items():
        ref = StreamRunner(
            model, CFG,
            adapt=(AdaptConfig(mode="pseudo", scope="shared", lr=0.3)
                   if adapt_on else None),
            adc_key=jax.random.fold_in(base_key, svc.uid(sid)), **kw)
        s_ref, f_ref, g_ref = ref.process(trace[sid, :n])
        s, f, g = cat(got[sid])
        np.testing.assert_array_equal(s, s_ref)
        np.testing.assert_array_equal(f, f_ref)
        np.testing.assert_array_equal(g, g_ref)
        log = svc.capture_log(sid)
        np.testing.assert_array_equal(log.sampled, ref.capture_log.sampled)
        np.testing.assert_array_equal(log.gated, ref.capture_log.gated)
        if adapt_on:
            np.testing.assert_array_equal(svc.class_hvs_of(sid),
                                          np.asarray(ref.class_hvs))


def test_detach_reattach_restores_adapted_classifier_exactly():
    """A detached sensor's adapted class_hvs survives an intervening
    tenant in its slot and is restored bitwise on reattach."""
    model = make_model()
    adapt = AdaptConfig(mode="pseudo", scope="per-stream", lr=0.3)
    trace = make_trace(3, 6 * C)
    svc = FleetService(model, CFG, n_slots=1, chunk_size=C, backend="jnp",
                       adapt=adapt)
    svc.attach("a")
    svc.dispatch({"a": trace[0, 0:C]})
    svc.dispatch({"a": trace[0, C:2 * C]})
    svc.flush()
    chvs_a = svc.class_hvs_of("a")
    assert not np.array_equal(chvs_a, np.asarray(model.class_hvs)), \
        "sanity: adaptation must have moved the classifier"
    svc.detach("a")
    np.testing.assert_array_equal(svc.class_hvs_of("a"), chvs_a)
    # another tenant adapts in the same slot
    svc.attach("b")
    svc.dispatch({"b": trace[1, 0:C]})
    svc.flush()
    svc.detach("b")
    # reattach: parked classifier restored bitwise, and it keeps adapting
    # exactly as an uninterrupted runner would
    svc.attach("a")
    np.testing.assert_array_equal(svc.class_hvs_of("a"), chvs_a)
    svc.dispatch({"a": trace[0, 2 * C:3 * C]})
    svc.flush()
    ref = StreamRunner(model, CFG, chunk_size=C, backend="jnp",
                       adapt=AdaptConfig(mode="pseudo", scope="shared",
                                         lr=0.3))
    ref.process(trace[0, :3 * C])
    np.testing.assert_array_equal(svc.class_hvs_of("a"),
                                  np.asarray(ref.class_hvs))


def test_churn_never_recompiles_the_step():
    model = make_model()
    trace = make_trace(4, 8 * C)
    svc = FleetService(model, CFG, n_slots=2, chunk_size=C, backend="jnp")
    svc.attach(0)
    svc.dispatch({0: trace[0, 0:C]})      # warmup fixes the trace
    svc.flush()
    c0 = svc.compile_count()
    svc.attach(1)
    svc.dispatch({0: trace[0, C:2 * C], 1: trace[1, 0:C]})
    svc.detach(0)
    svc.dispatch({1: trace[1, C:2 * C]})
    svc.dispatch({})                      # fully silent tick
    svc.attach(2)
    svc.dispatch({2: trace[2, 0:C]})
    svc.flush()
    assert svc.compile_count() == c0, \
        "slot churn must only flip slot_mask bits, never retrace"


# ---------------------------------------------------------------------------
# checkpoint / restore
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_checkpoint_kill_and_resume_bitwise(backend, tmp_path):
    """A service killed after an async snapshot and restored into a
    fresh process-equivalent resumes the trace bitwise — outputs, logs,
    adapted classifier, parked sensors, HP deliverables."""
    model = make_model()
    adapt = AdaptConfig(mode="pseudo", scope="per-stream", lr=0.3)
    ctl = CaptureConfig(hp_bits=12, hp_buffer=2)
    cfg = ControllerConfig(hold_frames=2, base_rate_hz=10.0,
                           active_rate_hz=30.0)
    trace = make_trace(2, 6 * C)
    td = os.fspath(tmp_path)

    def build():
        return FleetService(model, cfg, n_slots=2, chunk_size=C,
                            backend=backend, block_d=64, adapt=adapt,
                            adc_bits=5, adc_sigma=0.02, control=ctl,
                            ckpt_dir=td)

    svc = build()
    svc.attach("x")
    svc.attach("y")
    svc.dispatch({"x": trace[0, 0:C], "y": trace[1, 0:C]})
    svc.detach("y")                       # parked at snapshot time
    svc.dispatch({"x": trace[0, C:2 * C]})
    svc.flush()
    svc.drain_hp("x")                     # pre-snapshot HP already taken
    svc.checkpoint()
    svc.wait_ckpt()

    def continuation(s):
        s.attach("y")
        s.dispatch({"x": trace[0, 2 * C:3 * C], "y": trace[1, C:2 * C]})
        out = {}
        drain(s, out)
        return out

    ref = continuation(svc)
    svc2 = build()
    assert svc2.restore() == 2
    assert svc2.attached == ("x",)
    got = continuation(svc2)
    assert set(ref) == set(got)
    for sid in ref:
        for a, b in zip(cat(ref[sid]), cat(got[sid])):
            np.testing.assert_array_equal(a, b)
    for sid in ("x", "y"):
        np.testing.assert_array_equal(svc.class_hvs_of(sid),
                                      svc2.class_hvs_of(sid))
        for a, b in zip(
                (svc.capture_log(sid).sampled, svc.capture_log(sid).gated),
                (svc2.capture_log(sid).sampled,
                 svc2.capture_log(sid).gated)):
            np.testing.assert_array_equal(a, b)
    idx, frames = svc.drain_hp("x")       # post-snapshot captures only
    idx2, frames2 = svc2.drain_hp("x")
    np.testing.assert_array_equal(idx, idx2)
    np.testing.assert_array_equal(frames, frames2)


def test_ckpt_every_autosnapshots(tmp_path):
    from repro.ckpt import checkpoint as ckpt_mod
    model = make_model()
    trace = make_trace(1, 4 * C)
    svc = FleetService(model, CFG, n_slots=1, chunk_size=C, backend="jnp",
                       ckpt_dir=os.fspath(tmp_path), ckpt_every=2)
    svc.attach(0)
    for t in range(4):
        svc.dispatch({0: trace[0, t * C:(t + 1) * C]})
    svc.flush()
    svc.wait_ckpt()
    assert ckpt_mod.latest_step(os.fspath(tmp_path)) == 4


def test_restore_guards():
    model = make_model()
    with tempfile.TemporaryDirectory() as td:
        svc = FleetService(model, CFG, n_slots=1, chunk_size=C,
                           backend="jnp", ckpt_dir=td)
        svc.attach(0)
        svc.dispatch({0: make_trace(1, C)[0]})
        svc.flush()
        svc.checkpoint()
        svc.wait_ckpt()
        with pytest.raises(RuntimeError, match="freshly constructed"):
            svc.restore()
        other = FleetService(model, CFG, n_slots=5, chunk_size=C,
                             backend="jnp", ckpt_dir=td)
        with pytest.raises(ValueError, match="n_slots"):
            other.restore()
    with pytest.raises(RuntimeError, match="ckpt_dir"):
        FleetService(model, CFG, n_slots=1, chunk_size=C).checkpoint()
    with pytest.raises(ValueError, match="ckpt_dir"):
        FleetService(model, CFG, n_slots=1, chunk_size=C, ckpt_every=2)


# ---------------------------------------------------------------------------
# pipelining / pool mechanics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("inflight", [1, 2, 4])
def test_max_inflight_is_invisible_to_results(inflight):
    model = make_model()
    S, T = 2, 5
    trace = make_trace(S, T * C)
    ref_svc = FleetService(model, CFG, n_slots=S, chunk_size=C,
                           backend="jnp", max_inflight=2)
    svc = FleetService(model, CFG, n_slots=S, chunk_size=C,
                       backend="jnp", max_inflight=inflight)
    outs = []
    for s in (ref_svc, svc):
        for i in range(S):
            s.attach(i)
        got = {}
        seqs = []
        for t in range(T):
            s.dispatch({i: trace[i, t * C:(t + 1) * C] for i in range(S)})
        for ch in s.flush():
            seqs.append(ch.seq)
            for sid, out in ch.outputs.items():
                got.setdefault(sid, []).append(out)
        assert seqs == sorted(seqs), "collect must be FIFO"
        outs.append(got)
    for i in range(S):
        for a, b in zip(cat(outs[0][i]), cat(outs[1][i])):
            np.testing.assert_array_equal(a, b)
    assert svc.collect() is None          # drained


def test_slot_pool_errors():
    model = make_model()
    svc = FleetService(model, CFG, n_slots=1, chunk_size=C, backend="jnp")
    svc.attach("a")
    with pytest.raises(ValueError, match="already attached"):
        svc.attach("a")
    with pytest.raises(RuntimeError, match="exhausted"):
        svc.attach("b")
    with pytest.raises(ValueError, match="not attached"):
        svc.detach("b")
    with pytest.raises(TypeError, match="str or int"):
        svc.attach(("tuple", "sid"))
    with pytest.raises(ValueError, match="not attached"):
        svc.dispatch({"b": make_trace(1, C)[0]})
    with pytest.raises(ValueError, match="expected"):
        svc.dispatch({"a": make_trace(1, C + 1)[0]})
    with pytest.raises(ValueError, match="labels"):
        svc.dispatch({"a": make_trace(1, C)[0]},
                     labels={"a": np.zeros(C, np.int32)})
    with pytest.raises(ValueError, match="n_slots"):
        FleetService(model, CFG, n_slots=0, chunk_size=C)
    with pytest.raises(ValueError, match="max_inflight"):
        FleetService(model, CFG, n_slots=1, chunk_size=C, max_inflight=0)


def test_detach_frees_capacity_for_new_tenant():
    model = make_model()
    trace = make_trace(2, 2 * C)
    svc = FleetService(model, CFG, n_slots=1, chunk_size=C, backend="jnp")
    svc.attach("a")
    svc.dispatch({"a": trace[0, 0:C]})
    svc.detach("a")
    assert svc.free_slots == 1
    svc.attach("b")                       # reuses the slot
    svc.dispatch({"b": trace[1, 0:C]})
    got = {}
    drain(svc, got)
    # b's outputs are a fresh stream's, not a continuation of a's
    ref = StreamRunner(model, CFG, chunk_size=C, backend="jnp")
    s_ref, f_ref, g_ref = ref.process(trace[1, 0:C])
    np.testing.assert_array_equal(cat(got["b"])[0], s_ref)
    np.testing.assert_array_equal(cat(got["b"])[2], g_ref)
    # a's uid persists while parked
    assert svc.uid("a") != svc.uid("b")


# ---------------------------------------------------------------------------
# mesh-sharded service (8-device host mesh jobs only)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8")
def test_mesh_sharded_service_matches_unsharded():
    """n_slots pads up to the mesh "sensors" extent and the sharded
    service's churn trace is bitwise the unsharded one."""
    from repro.distributed import sharding as shlib
    model = make_model()
    trace = make_trace(3, 4 * C)
    mesh = jax.make_mesh((8, 1), ("data", "model"))

    def play(svc):
        svc.attach(0)
        svc.dispatch({0: trace[0, 0:C]})
        svc.attach(1)
        svc.dispatch({0: trace[0, C:2 * C], 1: trace[1, 0:C]})
        svc.detach(0)
        svc.dispatch({1: trace[1, C:2 * C]})
        got = {}
        drain(svc, got)
        return got

    with shlib.use_mesh(mesh):
        sharded = FleetService(model, CFG, n_slots=3, chunk_size=C,
                               backend="jnp", adc_bits=5, adc_sigma=0.02)
        assert sharded.n_slots == 8, "capacity must pad to the mesh extent"
        got = play(sharded)
    ref = play(FleetService(model, CFG, n_slots=3, chunk_size=C,
                            backend="jnp", adc_bits=5, adc_sigma=0.02))
    assert set(got) == set(ref)
    for sid in ref:
        for a, b in zip(cat(got[sid]), cat(ref[sid])):
            np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# sanitizer-harness regressions (repro.analysis.sanitize)
# ---------------------------------------------------------------------------

from repro.analysis import sanitize  # noqa: E402
from repro.launch import serve as serve_mod  # noqa: E402


def test_warm_dispatch_is_compile_and_transfer_clean(compile_ledger):
    """Pins the fixes behind lint findings RA003/RA005 in ``dispatch()``.

    After warmup, serving ticks on the uid-keyed ADC path — whose
    per-tick ``jax.vmap`` used to rebuild a fresh trace every call, and
    whose shape probe used to round-trip the first arrival through
    ``np.asarray`` — must trigger ZERO fresh XLA compiles and no
    implicit host<->device transfers, even for device-array arrivals.
    """
    model = make_model()
    trace = make_trace(2, 6 * C)
    svc = FleetService(model, CFG, n_slots=2, chunk_size=C, backend="jnp",
                       adc_bits=5, adc_sigma=0.02)
    svc.attach(0)
    svc.attach(1)
    svc.dispatch({0: trace[0, :C], 1: trace[1, :C]})     # warmup compiles
    svc.flush()
    dev = jax.device_put(trace[0, C:2 * C])              # device arrival
    with compile_ledger.expect_no_compiles("warm dispatch ticks"), \
            sanitize.no_implicit_transfers(always=True):
        svc.dispatch({0: dev, 1: trace[1, C:2 * C]})
        svc.dispatch({0: trace[0, 2 * C:3 * C]})
    assert svc.flush(), "guarded ticks must still produce results"


def test_uid_key_fold_is_hoisted_not_per_tick():
    """The ADC key fold is one module-level jit, reused across ticks."""
    model = make_model()
    trace = make_trace(1, 8 * C)
    svc = FleetService(model, CFG, n_slots=1, chunk_size=C, backend="jnp",
                       adc_bits=5, adc_sigma=0.02)
    svc.attach(0)
    svc.dispatch({0: trace[0, :C]})          # first tick traces the fold
    after_first = serve_mod._fold_uid_keys._cache_size()
    for t in range(1, 4):
        svc.dispatch({0: trace[0, t * C:(t + 1) * C]})
    svc.flush()
    assert serve_mod._fold_uid_keys._cache_size() == after_first, \
        "per-tick key folding must reuse one jitted trace per fleet shape"


def test_device_arrivals_bitwise_match_host_arrivals():
    """``np.shape``/``np.result_type`` probes see device and host arrivals
    identically — same outputs bitwise, including int-codes detection."""
    model = make_model()
    trace = make_trace(1, 2 * C)
    codes = np.clip(np.abs(trace) * 8, 0, 31).astype(np.int32)

    def play(arrival_of):
        svc = FleetService(model, CFG, n_slots=1, chunk_size=C,
                           backend="jnp", precision="int8", adc_bits=5)
        svc.attach(0)
        for t in range(2):
            svc.dispatch({0: arrival_of(codes[0, t * C:(t + 1) * C])})
        got = {}
        drain(svc, got)
        return got

    host = play(lambda a: a)
    dev = play(jax.device_put)
    for a, b in zip(cat(host[0]), cat(dev[0])):
        np.testing.assert_array_equal(a, b)
