"""Streaming runtime: chunked batched path == frame-at-a-time reference.

A deterministic synthetic stream through chunked scoring +
``SensorController`` gating must produce *identical* ``StreamStats`` to the
existing per-frame ``simulate_stream``; the ``gate_scan`` hysteresis must
match the stateful controller bit-for-bit; and chunk size must be
invisible (including non-divisible tails and state across ``process``
calls).
"""

try:  # prefer the real library when installed (requirements-dev.txt)
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # fallback keeps these tests running without the dep
    from _hypothesis_fallback import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import encoding, hypersense
from repro.core.sensor_control import (ControllerConfig, SensorController,
                                       simulate_stream)
from repro.sensing import adc, synthetic
from repro.sensing.stream import (StreamRunner, gate_scan,
                                  simulate_stream_batched)

jax.config.update("jax_platform_name", "cpu")


def key(i):
    return jax.random.PRNGKey(i)


def make_model(h=6, w=6, stride=3, D=128, t_score=0.0, t_detection=2):
    B0, b = encoding.make_perm_base_rows(key(1), h, D)
    C = jax.random.normal(key(2), (2, D))
    return hypersense.HyperSenseModel(C, B0, b, h, w, stride,
                                      t_score=t_score,
                                      t_detection=t_detection)


# ---------------------------------------------------------------------------
# gate_scan == SensorController
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("hold", [0, 1, 3, 7])
def test_gate_scan_matches_controller(hold):
    rng = np.random.RandomState(hold)
    fired = rng.rand(300) < 0.15
    ctrl = SensorController(ControllerConfig(hold_frames=hold))
    want = np.array([ctrl.step(bool(f)) for f in fired])
    got, holds = gate_scan(jnp.asarray(fired), hold)
    np.testing.assert_array_equal(np.asarray(got), want)
    # resuming from an intermediate hold state must continue identically
    cut = 117
    got_a, holds_a = gate_scan(jnp.asarray(fired[:cut]), hold)
    got_b, _ = gate_scan(jnp.asarray(fired[cut:]), hold, holds_a[-1])
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(got_a), np.asarray(got_b)]), want)


@hypothesis.given(st.integers(0, 2**16), st.integers(0, 6),
                  st.integers(0, 6), st.integers(1, 400))
@hypothesis.settings(max_examples=30, deadline=None)
def test_gate_scan_matches_controller_property(seed, hold, init_hold, n):
    """gate_scan == SensorController for *arbitrary* decision sequences —
    any length, any hold_frames (incl. 0), any carried-in init_hold."""
    rng = np.random.RandomState(seed)
    fired = rng.rand(n) < rng.uniform(0.0, 1.0)
    ctrl = SensorController(ControllerConfig(hold_frames=hold))
    ctrl._hold = init_hold
    want_g, want_h = [], []
    for f in fired:
        want_g.append(ctrl.step(bool(f)))
        want_h.append(ctrl._hold)
    got_g, got_h = gate_scan(jnp.asarray(fired), hold, init_hold)
    np.testing.assert_array_equal(np.asarray(got_g), np.array(want_g))
    np.testing.assert_array_equal(np.asarray(got_h), np.array(want_h))


@hypothesis.given(st.integers(0, 2**16), st.integers(0, 5),
                  st.integers(2, 50))
@hypothesis.settings(max_examples=30, deadline=None)
def test_gate_scan_split_resume_property(seed, hold, n):
    """Splitting a decision sequence at any point and resuming from the
    carried hold state is invisible — for every cut position."""
    rng = np.random.RandomState(seed)
    fired = rng.rand(n) < 0.3
    want, _ = gate_scan(jnp.asarray(fired), hold)
    cut = rng.randint(1, n)
    got_a, holds_a = gate_scan(jnp.asarray(fired[:cut]), hold)
    got_b, _ = gate_scan(jnp.asarray(fired[cut:]), hold, holds_a[-1])
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(got_a), np.asarray(got_b)]),
        np.asarray(want))


# ---------------------------------------------------------------------------
# chunked streaming == frame-at-a-time simulate_stream
# ---------------------------------------------------------------------------

def _reference_stats(model, frames, labels, config):
    decide = jax.jit(lambda f: hypersense.detect(model, f))
    return simulate_stream(lambda f: bool(decide(f)), np.asarray(frames),
                           np.asarray(labels), config)


@pytest.mark.parametrize("chunk_size", [1, 5, 16, 64])
def test_batched_stream_matches_reference(chunk_size):
    model = make_model()
    cfg = synthetic.RadarConfig(height=24, width=24)
    frames, _, labels = synthetic.make_dataset(key(3), 41, cfg)
    config = ControllerConfig(hold_frames=2)
    ref = _reference_stats(model, frames, labels, config)
    got = simulate_stream_batched(model, frames, labels, config,
                                  chunk_size=chunk_size, backend="jnp")
    np.testing.assert_array_equal(got.decisions, ref.decisions)
    np.testing.assert_array_equal(got.gated_on, ref.gated_on)
    assert got.duty_cycle == ref.duty_cycle
    assert got.missed_positive == ref.missed_positive
    assert got.false_active == ref.false_active


def test_batched_stream_pallas_backend_matches_reference():
    model = make_model()
    cfg = synthetic.RadarConfig(height=24, width=24)
    frames, _, labels = synthetic.make_dataset(key(4), 19, cfg)
    config = ControllerConfig(hold_frames=1)
    ref = _reference_stats(model, frames, labels, config)
    got = simulate_stream_batched(model, frames, labels, config,
                                  chunk_size=8, backend="pallas",
                                  block_d=64)
    np.testing.assert_array_equal(got.decisions, ref.decisions)
    np.testing.assert_array_equal(got.gated_on, ref.gated_on)


def test_t_detection_beyond_fragment_count_never_fires():
    """detect() can never fire when t_detection >= my*mx; stream agrees."""
    model = make_model(t_detection=10_000)
    cfg = synthetic.RadarConfig(height=24, width=24)
    frames, _, labels = synthetic.make_dataset(key(5), 9, cfg)
    got = simulate_stream_batched(model, frames, labels,
                                  ControllerConfig(hold_frames=2),
                                  chunk_size=4, backend="jnp")
    assert not got.decisions.any()
    assert not got.gated_on.any()
    ref = _reference_stats(model, frames, labels,
                           ControllerConfig(hold_frames=2))
    np.testing.assert_array_equal(got.decisions, ref.decisions)


def test_runner_state_carries_across_process_calls():
    """Feeding the stream in arbitrary slices == feeding it at once."""
    model = make_model()
    cfg = synthetic.RadarConfig(height=24, width=24)
    frames, _, _ = synthetic.make_dataset(key(6), 23, cfg)
    whole = StreamRunner(model, ControllerConfig(hold_frames=3),
                         chunk_size=8)
    s_all, f_all, g_all = whole.process(frames)
    split = StreamRunner(model, ControllerConfig(hold_frames=3),
                         chunk_size=8)
    parts = [split.process(frames[a:z])
             for a, z in [(0, 7), (7, 10), (10, 23)]]
    np.testing.assert_allclose(np.concatenate([p[0] for p in parts]), s_all,
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.concatenate([p[1] for p in parts]),
                                  f_all)
    np.testing.assert_array_equal(np.concatenate([p[2] for p in parts]),
                                  g_all)


_PROP = {}


def _prop_fixture():
    """Module-cached model + stream + whole-stream reference outputs."""
    if not _PROP:
        model = make_model()
        cfg = synthetic.RadarConfig(height=24, width=24)
        frames, _, _ = synthetic.make_dataset(key(7), 31, cfg)
        ref = {}
        for chunk_size in (1, 3, 8, 32):
            r = StreamRunner(model, ControllerConfig(hold_frames=3),
                             chunk_size=chunk_size)
            ref[chunk_size] = r.process(frames)
        # chunk size itself must be invisible
        for chunk_size in (3, 8, 32):
            np.testing.assert_allclose(ref[chunk_size][0], ref[1][0],
                                       rtol=1e-6, atol=1e-6)
            np.testing.assert_array_equal(ref[chunk_size][1], ref[1][1])
            np.testing.assert_array_equal(ref[chunk_size][2], ref[1][2])
        _PROP.update(model=model, frames=frames, ref=ref)
    return _PROP


@hypothesis.given(st.integers(0, 2**16), st.sampled_from([1, 3, 8, 32]))
@hypothesis.settings(max_examples=12, deadline=None)
def test_runner_slicing_invariance_property(seed, chunk_size):
    """process() output is invariant to HOW the stream is sliced into
    successive calls — random split points, random chunk_size (the
    generalization of test_runner_state_carries_across_process_calls)."""
    p = _prop_fixture()
    frames, (s_all, f_all, g_all) = p["frames"], p["ref"][chunk_size]
    n = frames.shape[0]
    rng = np.random.RandomState(seed)
    n_cuts = rng.randint(0, 6)
    cuts = sorted(rng.choice(np.arange(1, n), size=n_cuts, replace=False))
    bounds = [0, *cuts, n]
    runner = StreamRunner(p["model"], ControllerConfig(hold_frames=3),
                          chunk_size=chunk_size)
    parts = [runner.process(frames[a:z])
             for a, z in zip(bounds[:-1], bounds[1:])]
    np.testing.assert_allclose(np.concatenate([q[0] for q in parts]),
                               s_all, rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.concatenate([q[1] for q in parts]),
                                  f_all)
    np.testing.assert_array_equal(np.concatenate([q[2] for q in parts]),
                                  g_all)


def test_runner_pallas_tail_chunk_padding():
    """n % chunk_size != 0 on the *pallas* backend: the padded tail chunk
    goes through the kernel and is masked identically to the jnp path."""
    model = make_model()
    cfg = synthetic.RadarConfig(height=24, width=24)
    frames, _, labels = synthetic.make_dataset(key(8), 11, cfg)
    config = ControllerConfig(hold_frames=2)
    ref = _reference_stats(model, frames, labels, config)
    got = simulate_stream_batched(model, frames, labels, config,
                                  chunk_size=8, backend="pallas",
                                  block_d=64)
    np.testing.assert_array_equal(got.decisions, ref.decisions)
    np.testing.assert_array_equal(got.gated_on, ref.gated_on)
    assert got.duty_cycle == ref.duty_cycle


def test_runner_adc_internal_equals_prequantized():
    """StreamRunner(adc_bits=b).process(raw) == plain runner fed
    adc.quantize(raw, b): quantization inside the runner is exactly the
    public quantize, and quantize is idempotent."""
    model = make_model()
    cfg = synthetic.RadarConfig(height=24, width=24)
    frames, _, _ = synthetic.make_dataset(key(9), 13, cfg)
    internal = StreamRunner(model, ControllerConfig(hold_frames=2),
                            chunk_size=4, adc_bits=4)
    s_i, f_i, g_i = internal.process(frames)
    pre = StreamRunner(model, ControllerConfig(hold_frames=2), chunk_size=4)
    s_p, f_p, g_p = pre.process(adc.quantize(frames, 4))
    np.testing.assert_array_equal(s_i, s_p)
    np.testing.assert_array_equal(f_i, f_p)
    np.testing.assert_array_equal(g_i, g_p)
    # ...and feeding an already-quantized stream through the ADC runner
    # changes nothing (idempotence end-to-end)
    internal.reset()
    s_q, f_q, g_q = internal.process(adc.quantize(frames, 4))
    np.testing.assert_array_equal(s_q, s_i)
    np.testing.assert_array_equal(f_q, f_i)
    np.testing.assert_array_equal(g_q, g_i)


def test_runner_reset():
    model = make_model(t_detection=0, t_score=-10.0)  # fires on everything
    frames = jnp.asarray(np.random.RandomState(0).rand(4, 24, 24),
                         jnp.float32)
    r = StreamRunner(model, ControllerConfig(hold_frames=3), chunk_size=4)
    _, fired, _ = r.process(frames)
    assert fired.all()
    assert int(np.asarray(r._hold)) == 3
    r.reset()
    assert int(np.asarray(r._hold)) == 0


def test_runner_rejects_bad_chunk_size():
    with pytest.raises(ValueError):
        StreamRunner(make_model(), chunk_size=0)


def test_runner_rejects_sigma_without_bits():
    """adc_sigma without adc_bits would be silently ignored — reject it."""
    with pytest.raises(ValueError):
        StreamRunner(make_model(), adc_sigma=0.05)


# ---------------------------------------------------------------------------
# int8 ADC-code datapath through the runner
# ---------------------------------------------------------------------------

def test_runner_int8_requires_adc_bits_and_valid_precision():
    with pytest.raises(ValueError):
        StreamRunner(make_model(), precision="int8")    # no converter depth
    with pytest.raises(ValueError):
        StreamRunner(make_model(), precision="fp16", adc_bits=8)


def test_adc_view_codes_rejects_out_of_range_codes():
    """Codes from a deeper converter must be rejected, not silently
    wrapped modulo 256 by the uint8 pack."""
    from repro.sensing.stream import adc_view_codes

    frames = jnp.asarray(np.random.RandomState(0).rand(3, 24, 24) * 1.5,
                         jnp.float32)
    codes12 = adc.quantize_codes(frames, 12)        # values up to 4095
    with pytest.raises(ValueError, match="outside"):
        adc_view_codes(codes12, 8)
    # matching depth passes through exactly
    np.testing.assert_array_equal(
        np.asarray(adc_view_codes(codes12, 12)), np.asarray(codes12))
    r = StreamRunner(make_model(), chunk_size=4, adc_bits=8,
                     precision="int8")
    with pytest.raises(ValueError, match="outside"):
        r.process(codes12)


def test_runner_int8_internal_equals_precoded():
    """Feeding raw frames through the internal ADC == feeding the packed
    codes directly: the code stream is the runner's native input."""
    from repro.sensing.stream import adc_view_codes

    model = make_model()
    cfg = synthetic.RadarConfig(height=24, width=24)
    frames, _, _ = synthetic.make_dataset(key(14), 13, cfg)
    internal = StreamRunner(model, ControllerConfig(hold_frames=2),
                            chunk_size=4, adc_bits=8, precision="int8")
    s_i, f_i, g_i = internal.process(frames)
    codes = adc_view_codes(frames, 8)
    assert codes.dtype == jnp.uint8
    pre = StreamRunner(model, ControllerConfig(hold_frames=2),
                       chunk_size=4, adc_bits=8, precision="int8")
    s_p, f_p, g_p = pre.process(codes)
    np.testing.assert_array_equal(s_i, s_p)
    np.testing.assert_array_equal(f_i, f_p)
    np.testing.assert_array_equal(g_i, g_p)


def test_runner_int8_slicing_invariance():
    """The int8 path preserves the runners' core contract: output is
    invariant to how the stream is sliced into process() calls."""
    model = make_model()
    cfg = synthetic.RadarConfig(height=24, width=24)
    frames, _, _ = synthetic.make_dataset(key(15), 23, cfg)
    whole = StreamRunner(model, ControllerConfig(hold_frames=3),
                         chunk_size=8, adc_bits=8, precision="int8")
    s_all, f_all, g_all = whole.process(frames)
    split = StreamRunner(model, ControllerConfig(hold_frames=3),
                         chunk_size=8, adc_bits=8, precision="int8")
    parts = [split.process(frames[a:z])
             for a, z in [(0, 7), (7, 10), (10, 23)]]
    np.testing.assert_array_equal(np.concatenate([p[0] for p in parts]),
                                  s_all)
    np.testing.assert_array_equal(np.concatenate([p[1] for p in parts]),
                                  f_all)
    np.testing.assert_array_equal(np.concatenate([p[2] for p in parts]),
                                  g_all)
