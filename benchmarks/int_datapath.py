"""Integer ADC-code datapaths: throughput, working set, accuracy, determinism.

The claims behind the low-precision integer datapaths:

* ``throughput`` — the fused encode->score int kernel
  (:mod:`repro.kernels.sliding_scores_int`: rolling in-kernel shifts over
  the padded base slabs, one window matmul per grid step) processes a
  chunk at least as fast as the float kernel at chunk sizes >= 8, AND at
  least as fast as the *retired expanded-slab layout* (reconstructed
  locally here as a baseline twin: the ``(h*W, TD)`` pre-shifted slab
  whose VMEM footprint grew linearly in W). On CPU all paths run in
  Pallas interpret mode, so the ratios — not the absolute fps — are the
  claim; on TPU the int paths additionally ride the int8 MXU and the
  4x (int8) / 8x (packed int4) smaller operand traffic.
* ``working set`` — at W four times the benchmark frame the rolling
  kernel still matches its jnp oracle and
  ``assert_int_datapath_fits`` admits the geometry; the byte model pins
  that the same config's *expanded* layout would not have fit.
* ``auc parity`` — integer rounding of slabs/class tiles costs
  essentially no detection quality: frame-score AUC on the synthetic
  stream AND on a drifted stream is within ``AUC_TOL`` of the float
  path fed the same ADC capture, for ``int8`` (8-bit codes) and packed
  ``int4`` (4-bit codes vs float at 4 bits).
* ``binary curve`` — the bipolar +-1 gate is a *reduced-D operating
  point*: its D-vs-AUC tradeoff is reported (not gated point-by-point —
  sign-quantizing both slabs and class HVs degrades with growing D as
  the class prototypes' disagreement margin thins), with a sanity gate
  on the best point of the curve.
* ``determinism`` — integer accumulation is associative: the int path is
  bitwise identical across *separate compilations* of the kernel
  (``jax.clear_caches()`` between runs, so this is not a cached-executable
  tautology; cross-process reproducibility follows from the same
  property).

Run:  PYTHONPATH=src python benchmarks/int_datapath.py [--check]
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import fragment_model as fm, hypersense, metrics
from repro.core.encoding import apply_nonlinearity, make_perm_base_rows
from repro.kernels import ops
from repro.kernels import sliding_scores_int as k_int
from repro.kernels.compat import CompilerParams
from repro.sensing import adc, fragments, synthetic

# CPU-tractable scale (interpret mode); chunk >= 8 is the claimed regime.
FRAME = 32
FRAG = 8
STRIDE = 4
DIM = 256
BLOCK_D = 128
CHUNK = 16
BITS = 8

# the AUC scenario uses a *trained* gate so scores are meaningful
AUC_DIM = 512
N_STREAM = 160
AUC_TOL = 0.01

# binary is evaluated as a curve over model dimensionality; the sanity
# gate is on the best point (small D — see the module docstring)
BINARY_DIMS = (128, 256, 512)
BINARY_MIN_BEST_AUC = 0.85

# the large-W regression check: 4x the benchmark frame width. D must
# cover the slab halo (td + W - 1 <= D), hence the dedicated dims.
LARGE_W = 4 * FRAME
LARGE_W_DIM = 256
LARGE_W_BLOCK_D = 128


def _time(fn, reps: int) -> float:
    fn()  # warmup / compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


# ---------------------------------------------------------------------------
# Expanded-slab baseline twin (the RETIRED layout, kept only as a yardstick)
# ---------------------------------------------------------------------------

def _expanded_kernel(codes_ref, slab_ref, mask_ref, bias_ref, cpos_ref,
                     cneg_ref, norm_ref, dpos_ref, dneg_ref, qq_ref, *,
                     h: int, stride: int, w: int, W: int, mx: int,
                     td: int, nonlinearity: str):
    """The pre-rolling-shift kernel body: consumes the ``(h*W, TD)``
    expanded shifted slab the old layout materialized in HBM and pulled
    whole into VMEM. Epilogue identical to the live kernel — only the
    projection core differs, which is exactly what the race measures."""
    ky = pl.program_id(1)
    block = codes_ref[0, pl.ds(ky * stride, h), :]
    slab3 = slab_ref[0].reshape(h, W, td)
    codes = block.astype(jnp.int32)
    g = codes[0][:, None] * slab3[0].astype(jnp.int32)
    for r in range(1, h):
        g = g + codes[r][:, None] * slab3[r].astype(jnp.int32)
    acc = jax.lax.dot_general(
        mask_ref[...].astype(jnp.int32), g, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    norms = norm_ref[0].astype(jnp.float32)
    s_n = acc.astype(jnp.float32) / norms[0][:, None]
    phi = apply_nonlinearity(s_n, bias_ref[0], nonlinearity)
    dpos = jnp.sum(phi * cpos_ref[0].astype(jnp.float32),
                   axis=1)[None, None, :]
    dneg = jnp.sum(phi * cneg_ref[0].astype(jnp.float32),
                   axis=1)[None, None, :]
    qq = jnp.sum(phi * phi, axis=1)[None, None, :]

    @pl.when(pl.program_id(2) == 0)
    def _init():
        dpos_ref[...] = jnp.zeros_like(dpos_ref)
        dneg_ref[...] = jnp.zeros_like(dneg_ref)
        qq_ref[...] = jnp.zeros_like(qq_ref)

    dpos_ref[...] += dpos
    dneg_ref[...] += dneg
    qq_ref[...] += qq


def _expand_slabs(geom: k_int.IntScoreGeometry, W: int) -> jnp.ndarray:
    """Re-materialize the retired ``(n_dt, h*W, TD)`` operand from the
    compact padded base slabs (bit-identical: the old layout quantized
    before expanding, so slices of ``slabs_q`` ARE its rows)."""
    n_dt, h, _ = geom.slabs_q.shape
    td = geom.block_d
    rows = jnp.stack([geom.slabs_q[:, :, i:i + td] for i in range(W)],
                     axis=2)                       # (n_dt, h, W, td)
    return rows.reshape(n_dt, h * W, td)


@functools.partial(jax.jit, static_argnames=("h", "w", "stride"))
def _expanded_scores(codes, slab_mat, tiles, *, h: int, w: int,
                     stride: int):
    """Batch wrapper for the baseline twin (single-model tiles only)."""
    N, H, W = codes.shape
    my = (H - h) // stride + 1
    mx = (W - w) // stride + 1
    geom = tiles.geom
    n_dt = slab_mat.shape[0]
    td = geom.block_d
    norms = k_int.window_norms_codes_batch(codes, h, w, stride)
    norms = jnp.maximum(norms, 1e-8) / geom.slab_scale
    kern = functools.partial(_expanded_kernel, h=h, stride=stride, w=w,
                             W=W, mx=mx, td=td, nonlinearity="rff")
    dpos, dneg, qq = pl.pallas_call(
        kern,
        grid=(N, my, n_dt),
        in_specs=[
            pl.BlockSpec((1, H, W), lambda n, i, j: (n, 0, 0)),
            pl.BlockSpec((1, h * W, td), lambda n, i, j: (j, 0, 0)),
            pl.BlockSpec((mx, W), lambda n, i, j: (0, 0)),
            pl.BlockSpec((1, mx, td), lambda n, i, j: (j, 0, 0)),
            pl.BlockSpec((1, mx, td), lambda n, i, j: (j, 0, 0)),
            pl.BlockSpec((1, mx, td), lambda n, i, j: (j, 0, 0)),
            pl.BlockSpec((1, 1, mx), lambda n, i, j: (n, i, 0)),
        ],
        out_specs=[pl.BlockSpec((1, 1, mx), lambda n, i, j: (n, i, 0))] * 3,
        out_shape=[jax.ShapeDtypeStruct((N, my, mx), jnp.float32)] * 3,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=True,
    )(codes, slab_mat, geom.win_mask, geom.bias_t, tiles.cpos_t,
      tiles.cneg_t, norms)
    return k_int._cosine_epilogue(dpos, dneg, qq, tiles, False, 0)


def throughput(n_frames: int = CHUNK, reps: int = 8) -> dict:
    """Chunk throughput: float kernel vs rolling-shift int8 kernel vs the
    retired expanded-slab baseline, same model, same ADC capture."""
    B0, b = make_perm_base_rows(jax.random.PRNGKey(0), FRAG, DIM)
    chvs = jax.random.normal(jax.random.PRNGKey(1), (2, DIM))
    frames = jax.random.uniform(jax.random.PRNGKey(2),
                                (n_frames, FRAME, FRAME), maxval=1.5)
    # both paths see the SAME ADC capture: float gets the reconstruction,
    # int gets the raw codes
    codes = adc.pack_codes(adc.quantize_codes(frames, BITS), BITS)
    recon = adc.quantize(frames, BITS)
    ftiles = ops.precompute_tiles(B0, b, chvs, W=FRAME, w=FRAG,
                                  stride=STRIDE, block_d=BLOCK_D)
    itiles = ops.precompute_tiles_int(B0, b, chvs, W=FRAME, w=FRAG,
                                      stride=STRIDE, block_d=BLOCK_D)
    slab_mat = jax.block_until_ready(_expand_slabs(itiles.geom, FRAME))

    t_f = _time(lambda: jax.block_until_ready(
        ops.fragment_score_map_batch(recon, chvs, B0, b, h=FRAG, w=FRAG,
                                     stride=STRIDE, tiles=ftiles)), reps)
    t_i = _time(lambda: jax.block_until_ready(
        ops.fragment_score_map_batch_int(codes, chvs, B0, b, h=FRAG,
                                         w=FRAG, stride=STRIDE,
                                         tiles=itiles)), reps)
    t_e = _time(lambda: jax.block_until_ready(
        _expanded_scores(codes, slab_mat, itiles, h=FRAG, w=FRAG,
                         stride=STRIDE)), reps)
    # the race is only fair if both kernels compute the same thing
    s_new = np.asarray(ops.fragment_score_map_batch_int(
        codes, chvs, B0, b, h=FRAG, w=FRAG, stride=STRIDE, tiles=itiles))
    s_exp = np.asarray(_expanded_scores(codes, slab_mat, itiles, h=FRAG,
                                        w=FRAG, stride=STRIDE))
    np.testing.assert_allclose(s_new, s_exp, rtol=1e-6, atol=1e-6)
    return {"float_fps": n_frames / t_f, "int8_fps": n_frames / t_i,
            "expanded_fps": n_frames / t_e, "speedup": t_f / t_i,
            "speedup_vs_expanded": t_e / t_i, "chunk": n_frames}


# ---------------------------------------------------------------------------
# Large-W working set
# ---------------------------------------------------------------------------

def large_w_check() -> dict:
    """W = 4x the benchmark frame: the rolling kernel matches its jnp
    oracle (exact integer core, tolerance-level float epilogue) where
    the retired layout's byte model says it would not have fit a
    deployment-scale VMEM working set."""
    H, W = FRAME, LARGE_W
    D, td = LARGE_W_DIM, LARGE_W_BLOCK_D
    ops.assert_int_datapath_fits(BITS, H, W, FRAG, FRAG, stride=STRIDE,
                                 block_d=td)
    # the deployment-scale asymmetry the rewrite exists for: rolling fits,
    # expanded does not (16x16 windows over W=4096 at 4-bit codes)
    bounds = k_int.int_datapath_bounds(4, 128, 4096, 16, 16, stride=16,
                                       block_d=512)
    assert bounds["fits"], "rolling layout must admit deployment scale"
    assert bounds["vmem_expanded_bytes"] > bounds["vmem_limit_bytes"], (
        "byte model lost the expanded-layout regression")

    B0, b = make_perm_base_rows(jax.random.PRNGKey(5), FRAG, D)
    chvs = jax.random.normal(jax.random.PRNGKey(6), (2, D))
    frames = jax.random.uniform(jax.random.PRNGKey(7), (4, H, W),
                                maxval=1.5)
    codes = adc.pack_codes(adc.quantize_codes(frames, BITS), BITS)
    tiles = k_int.precompute_tiles_int(B0, b, chvs, W=W, w=FRAG,
                                       stride=STRIDE, block_d=td)
    got = np.asarray(k_int.fragment_scores_batch_int(
        codes, tiles, h=FRAG, w=FRAG, stride=STRIDE, interpret=True))
    want = np.asarray(k_int.fragment_scores_batch_int_ref(
        codes, tiles, h=FRAG, w=FRAG, stride=STRIDE))
    return {"W": W, "oracle_max_err": float(np.abs(got - want).max()),
            "guard_ok": True,
            "expanded_would_fit": bool(
                bounds["vmem_expanded_bytes"] <= bounds["vmem_limit_bytes"])}


# ---------------------------------------------------------------------------
# Accuracy
# ---------------------------------------------------------------------------

def _train_gate(cfg, dim: int):
    """Fragment model trained on the clean distribution (as adaptation.py)."""
    frames, masks, _ = synthetic.make_dataset(jax.random.PRNGKey(0), 60,
                                              cfg)
    frs, labs = fragments.sample_fragments(
        np.asarray(frames), np.asarray(masks), h=FRAG, w=FRAG,
        per_frame=2, seed=0)
    model, _ = fm.train_fragment_model(
        jax.random.PRNGKey(1), jnp.asarray(frs), jnp.asarray(labs),
        dim=dim, epochs=8)
    B0 = model.B.reshape(FRAG, FRAG, -1)[:, 0, :]
    return hypersense.from_fragment_model(model, B0, h=FRAG, w=FRAG,
                                          stride=STRIDE, t_detection=1)


def _auc(scores, labels) -> float:
    fpr, tpr, _ = metrics.roc_curve(np.asarray(scores), np.asarray(labels))
    return float(metrics.auc(fpr, tpr))


def auc_parity(backend: str = "pallas") -> dict:
    """Frame-score AUC: float vs int8 (8-bit codes) and float-at-4-bits
    vs packed int4, on synthetic + drift. Each integer path is compared
    against the float path fed the SAME ADC capture depth, so the gap
    isolates the datapath, not the converter."""
    cfg = synthetic.RadarConfig(height=FRAME, width=FRAME)
    hs = _train_gate(cfg, AUC_DIM)
    drift = synthetic.DriftConfig(background_gain=(0.0, 0.5),
                                  noise_sigma=(0.12, 0.25),
                                  object_intensity=(0.8, 0.45))
    scenarios = {
        "synthetic": synthetic.make_stream(
            jax.random.PRNGKey(3), N_STREAM, cfg, event_prob=0.08,
            event_len=10),
        "drift": synthetic.make_drift_stream(
            jax.random.PRNGKey(4), N_STREAM, cfg, drift, event_prob=0.08,
            event_len=10),
    }
    out = {"backend": backend}
    for name, (frames, labels) in scenarios.items():
        s_f = hypersense.frame_scores_batch(
            hs, adc.quantize(frames, BITS), backend=backend)
        s_i = hypersense.frame_scores_batch(hs, frames, backend=backend,
                                            precision="int8",
                                            adc_bits=BITS)
        s_f4 = hypersense.frame_scores_batch(
            hs, adc.quantize(frames, 4), backend=backend)
        s_i4 = hypersense.frame_scores_batch(hs, frames, backend=backend,
                                             precision="int4", adc_bits=4)
        out[f"{name}_float_auc"] = _auc(s_f, labels)
        out[f"{name}_int8_auc"] = _auc(s_i, labels)
        out[f"{name}_gap"] = abs(out[f"{name}_float_auc"]
                                 - out[f"{name}_int8_auc"])
        out[f"{name}_int4_auc"] = _auc(s_i4, labels)
        out[f"{name}_int4_gap"] = abs(_auc(s_f4, labels)
                                      - out[f"{name}_int4_auc"])
    return out


def binary_curve(backend: str = "pallas") -> dict:
    """The binary gate's D-vs-AUC tradeoff on the synthetic stream.

    Reported as a curve because it is NOT monotone-up in D: the float
    gate saturates while double sign-quantization (slabs AND class HVs)
    erodes the class prototypes' disagreement margin as D grows — the
    binary gate is a reduced-D operating point, and the sanity gate
    anchors on the best point of the curve accordingly.
    """
    cfg = synthetic.RadarConfig(height=FRAME, width=FRAME)
    frames, labels = synthetic.make_stream(
        jax.random.PRNGKey(3), N_STREAM, cfg, event_prob=0.08,
        event_len=10)
    out = {"backend": backend}
    best = 0.0
    for dim in BINARY_DIMS:
        hs = _train_gate(cfg, dim)
        s_f = hypersense.frame_scores_batch(
            hs, adc.quantize(frames, BITS), backend=backend)
        s_b = hypersense.frame_scores_batch(hs, frames, backend=backend,
                                            precision="binary",
                                            adc_bits=BITS)
        out[f"d{dim}_float_auc"] = _auc(s_f, labels)
        out[f"d{dim}_binary_auc"] = _auc(s_b, labels)
        best = max(best, out[f"d{dim}_binary_auc"])
    out["best_binary_auc"] = best
    return out


def determinism() -> dict:
    """Int-path runs must be bitwise identical across fresh compilations.

    ``jax.clear_caches()`` between the two runs discards the compiled
    executable, so the comparison spans two independent compiles — a
    scheduling- or layout-dependent reduction would be free to differ.
    """
    B0, b = make_perm_base_rows(jax.random.PRNGKey(7), FRAG, DIM)
    chvs = jax.random.normal(jax.random.PRNGKey(8), (2, DIM))
    frames = jax.random.uniform(jax.random.PRNGKey(9),
                                (CHUNK, FRAME, FRAME), maxval=1.5)
    codes = adc.pack_codes(adc.quantize_codes(frames, BITS), BITS)
    itiles = ops.precompute_tiles_int(B0, b, chvs, W=FRAME, w=FRAG,
                                      stride=STRIDE, block_d=BLOCK_D)
    a = np.asarray(ops.fragment_score_map_batch_int(
        codes, chvs, B0, b, h=FRAG, w=FRAG, stride=STRIDE, tiles=itiles))
    jax.clear_caches()
    b_ = np.asarray(ops.fragment_score_map_batch_int(
        codes, chvs, B0, b, h=FRAG, w=FRAG, stride=STRIDE, tiles=itiles))
    return {"bitwise_equal": bool((a == b_).all())}


def run(n_frames: int = CHUNK, reps: int = 8,
        backend: str = "pallas") -> list[dict]:
    """Benchmark-driver entry point (``python -m benchmarks.run``)."""
    t = throughput(n_frames, reps)
    lw = large_w_check()
    a = auc_parity(backend)
    bc = binary_curve(backend)
    d = determinism()
    return [
        {"name": "int_datapath/throughput",
         "float_fps": f"{t['float_fps']:.1f}",
         "int8_fps": f"{t['int8_fps']:.1f}",
         "expanded_fps": f"{t['expanded_fps']:.1f}",
         "speedup": f"{t['speedup']:.2f}x",
         "speedup_vs_expanded": f"{t['speedup_vs_expanded']:.2f}x",
         "chunk": t["chunk"]},
        {"name": "int_datapath/large_w",
         "W": lw["W"],
         "oracle_max_err": f"{lw['oracle_max_err']:.2e}",
         "guard_ok": lw["guard_ok"],
         "expanded_would_fit": lw["expanded_would_fit"]},
        {"name": "int_datapath/auc",
         "synthetic_float": f"{a['synthetic_float_auc']:.4f}",
         "synthetic_int8": f"{a['synthetic_int8_auc']:.4f}",
         "synthetic_gap": f"{a['synthetic_gap']:.4f}",
         "synthetic_int4": f"{a['synthetic_int4_auc']:.4f}",
         "synthetic_int4_gap": f"{a['synthetic_int4_gap']:.4f}",
         "drift_float": f"{a['drift_float_auc']:.4f}",
         "drift_int8": f"{a['drift_int8_auc']:.4f}",
         "drift_gap": f"{a['drift_gap']:.4f}",
         "drift_int4": f"{a['drift_int4_auc']:.4f}",
         "drift_int4_gap": f"{a['drift_int4_gap']:.4f}",
         "backend": a["backend"]},
        {"name": "int_datapath/binary_curve",
         **{f"d{dim}": f"{bc[f'd{dim}_binary_auc']:.4f}"
            for dim in BINARY_DIMS},
         **{f"d{dim}_float": f"{bc[f'd{dim}_float_auc']:.4f}"
            for dim in BINARY_DIMS},
         "best": f"{bc['best_binary_auc']:.4f}"},
        {"name": "int_datapath/determinism",
         "bitwise_equal": d["bitwise_equal"]},
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=CHUNK,
                    help="chunk size (>= 8 is the claimed regime)")
    ap.add_argument("--reps", type=int, default=8)
    ap.add_argument("--backend", default="pallas",
                    choices=["jnp", "pallas"],
                    help="backend for the AUC scenarios")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless int8 fps >= float fps AND "
                         ">= the expanded-slab baseline at chunk >= 8, "
                         f"AUC gaps <= {AUC_TOL} for int8 and int4, the "
                         f"binary curve peaks >= {BINARY_MIN_BEST_AUC}, "
                         "the large-W kernel matches its oracle, and the "
                         "int path is bitwise deterministic")
    args = ap.parse_args()

    rows = run(args.frames, args.reps, args.backend)
    vals = {}
    for row in rows:
        name = row.pop("name")
        vals[name] = dict(row)
        print(name + "," + ",".join(f"{k}={v}" for k, v in row.items()))

    if args.check:
        t = vals["int_datapath/throughput"]
        lw = vals["int_datapath/large_w"]
        a = vals["int_datapath/auc"]
        bc = vals["int_datapath/binary_curve"]
        d = vals["int_datapath/determinism"]
        if float(t["int8_fps"]) < float(t["float_fps"]):
            raise SystemExit(
                f"REGRESSION: int8 path {t['int8_fps']} fps < float path "
                f"{t['float_fps']} fps at chunk {t['chunk']}")
        if float(t["int8_fps"]) < float(t["expanded_fps"]):
            raise SystemExit(
                f"REGRESSION: rolling-shift kernel {t['int8_fps']} fps < "
                f"expanded-slab baseline {t['expanded_fps']} fps at chunk "
                f"{t['chunk']} — the VMEM fix must not cost throughput")
        # the integer projection core is exact; the float cosine epilogue
        # reduces in a different order than the jnp oracle, so the match
        # is tolerance-level, not bitwise (determinism is gated separately)
        if float(lw["oracle_max_err"]) > 1e-6:
            raise SystemExit(
                f"REGRESSION: large-W (W={lw['W']}) kernel deviates from "
                f"the oracle by {lw['oracle_max_err']}")
        if lw["expanded_would_fit"] not in (False, "False"):
            raise SystemExit(
                "REGRESSION: byte model claims the expanded layout fits "
                "deployment scale — the working-set regression is gone")
        for scen in ("synthetic", "drift"):
            if float(a[f"{scen}_gap"]) > AUC_TOL:
                raise SystemExit(
                    f"REGRESSION: int8 AUC gap {a[f'{scen}_gap']} > "
                    f"{AUC_TOL} on the {scen} scenario "
                    f"(float {a[f'{scen}_float']}, int8 "
                    f"{a[f'{scen}_int8']})")
            if float(a[f"{scen}_int4_gap"]) > AUC_TOL:
                raise SystemExit(
                    f"REGRESSION: int4 AUC gap {a[f'{scen}_int4_gap']} > "
                    f"{AUC_TOL} on the {scen} scenario "
                    f"(int4 {a[f'{scen}_int4']})")
        if float(bc["best"]) < BINARY_MIN_BEST_AUC:
            raise SystemExit(
                f"REGRESSION: binary gate's best AUC {bc['best']} < "
                f"{BINARY_MIN_BEST_AUC} anywhere on D in {BINARY_DIMS}")
        if d["bitwise_equal"] is not True and d["bitwise_equal"] != "True":
            raise SystemExit("REGRESSION: int path not bitwise "
                             "deterministic across runs")
        print("int_datapath/check,ok=True")


if __name__ == "__main__":
    main()
