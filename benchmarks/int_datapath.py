"""Int8 ADC-code datapath: throughput, accuracy parity, determinism.

The three claims behind the low-precision integer datapath (ISSUE 4):

* ``throughput`` — the fused encode->score int kernel
  (:mod:`repro.kernels.sliding_scores_int`: expanded shifted int8 slabs,
  rolled-sum reuse, one window matmul per grid step) processes a chunk at
  least as fast as the float kernel at chunk sizes >= 8. On CPU both run
  in Pallas interpret mode, so the ratio — not the absolute fps — is the
  claim; on TPU the int path additionally rides the int8 MXU and 4x
  smaller operand traffic.
* ``auc-parity`` — int8 rounding of slabs/class tiles costs essentially
  no detection quality: frame-score AUC on the synthetic stream AND on a
  drifted stream is within ``AUC_TOL`` of the float path fed the same
  ADC capture.
* ``determinism`` — integer accumulation is associative: the int path is
  bitwise identical across *separate compilations* of the kernel
  (``jax.clear_caches()`` between runs, so this is not a cached-executable
  tautology; cross-process reproducibility follows from the same
  property).

Run:  PYTHONPATH=src python benchmarks/int_datapath.py [--check]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fragment_model as fm, hypersense, metrics
from repro.core.encoding import make_perm_base_rows
from repro.kernels import ops
from repro.sensing import adc, fragments, synthetic

# CPU-tractable scale (interpret mode); chunk >= 8 is the claimed regime.
FRAME = 32
FRAG = 8
STRIDE = 4
DIM = 256
BLOCK_D = 128
CHUNK = 16
BITS = 8

# the AUC scenario uses a *trained* gate so scores are meaningful
AUC_DIM = 512
N_STREAM = 160
AUC_TOL = 0.01


def _time(fn, reps: int) -> float:
    fn()  # warmup / compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def throughput(n_frames: int = CHUNK, reps: int = 8) -> dict:
    """Chunk throughput: float kernel vs fused int8 kernel, same model."""
    B0, b = make_perm_base_rows(jax.random.PRNGKey(0), FRAG, DIM)
    chvs = jax.random.normal(jax.random.PRNGKey(1), (2, DIM))
    frames = jax.random.uniform(jax.random.PRNGKey(2),
                                (n_frames, FRAME, FRAME), maxval=1.5)
    # both paths see the SAME ADC capture: float gets the reconstruction,
    # int gets the raw codes
    codes = adc.pack_codes(adc.quantize_codes(frames, BITS), BITS)
    recon = adc.quantize(frames, BITS)
    ftiles = ops.precompute_tiles(B0, b, chvs, W=FRAME, w=FRAG,
                                  stride=STRIDE, block_d=BLOCK_D)
    itiles = ops.precompute_tiles_int(B0, b, chvs, W=FRAME, w=FRAG,
                                      stride=STRIDE, block_d=BLOCK_D)

    t_f = _time(lambda: jax.block_until_ready(
        ops.fragment_score_map_batch(recon, chvs, B0, b, h=FRAG, w=FRAG,
                                     stride=STRIDE, tiles=ftiles)), reps)
    t_i = _time(lambda: jax.block_until_ready(
        ops.fragment_score_map_batch_int(codes, chvs, B0, b, h=FRAG,
                                         w=FRAG, stride=STRIDE,
                                         tiles=itiles)), reps)
    return {"float_fps": n_frames / t_f, "int8_fps": n_frames / t_i,
            "speedup": t_f / t_i, "chunk": n_frames}


def _train_gate(cfg, dim: int):
    """Fragment model trained on the clean distribution (as adaptation.py)."""
    frames, masks, _ = synthetic.make_dataset(jax.random.PRNGKey(0), 60,
                                              cfg)
    frs, labs = fragments.sample_fragments(
        np.asarray(frames), np.asarray(masks), h=FRAG, w=FRAG,
        per_frame=2, seed=0)
    model, _ = fm.train_fragment_model(
        jax.random.PRNGKey(1), jnp.asarray(frs), jnp.asarray(labs),
        dim=dim, epochs=8)
    B0 = model.B.reshape(FRAG, FRAG, -1)[:, 0, :]
    return hypersense.from_fragment_model(model, B0, h=FRAG, w=FRAG,
                                          stride=STRIDE, t_detection=1)


def _auc(scores, labels) -> float:
    fpr, tpr, _ = metrics.roc_curve(np.asarray(scores), np.asarray(labels))
    return float(metrics.auc(fpr, tpr))


def auc_parity(backend: str = "pallas") -> dict:
    """Frame-score AUC, float vs int8 datapath, synthetic + drift."""
    cfg = synthetic.RadarConfig(height=FRAME, width=FRAME)
    hs = _train_gate(cfg, AUC_DIM)
    drift = synthetic.DriftConfig(background_gain=(0.0, 0.5),
                                  noise_sigma=(0.12, 0.25),
                                  object_intensity=(0.8, 0.45))
    scenarios = {
        "synthetic": synthetic.make_stream(
            jax.random.PRNGKey(3), N_STREAM, cfg, event_prob=0.08,
            event_len=10),
        "drift": synthetic.make_drift_stream(
            jax.random.PRNGKey(4), N_STREAM, cfg, drift, event_prob=0.08,
            event_len=10),
    }
    out = {"backend": backend}
    for name, (frames, labels) in scenarios.items():
        recon = adc.quantize(frames, BITS)
        s_f = hypersense.frame_scores_batch(hs, recon, backend=backend)
        s_i = hypersense.frame_scores_batch(hs, frames, backend=backend,
                                            precision="int8",
                                            adc_bits=BITS)
        out[f"{name}_float_auc"] = _auc(s_f, labels)
        out[f"{name}_int8_auc"] = _auc(s_i, labels)
        out[f"{name}_gap"] = abs(out[f"{name}_float_auc"]
                                 - out[f"{name}_int8_auc"])
    return out


def determinism() -> dict:
    """Int-path runs must be bitwise identical across fresh compilations.

    ``jax.clear_caches()`` between the two runs discards the compiled
    executable, so the comparison spans two independent compiles — a
    scheduling- or layout-dependent reduction would be free to differ.
    """
    B0, b = make_perm_base_rows(jax.random.PRNGKey(7), FRAG, DIM)
    chvs = jax.random.normal(jax.random.PRNGKey(8), (2, DIM))
    frames = jax.random.uniform(jax.random.PRNGKey(9),
                                (CHUNK, FRAME, FRAME), maxval=1.5)
    codes = adc.pack_codes(adc.quantize_codes(frames, BITS), BITS)
    itiles = ops.precompute_tiles_int(B0, b, chvs, W=FRAME, w=FRAG,
                                      stride=STRIDE, block_d=BLOCK_D)
    a = np.asarray(ops.fragment_score_map_batch_int(
        codes, chvs, B0, b, h=FRAG, w=FRAG, stride=STRIDE, tiles=itiles))
    jax.clear_caches()
    b_ = np.asarray(ops.fragment_score_map_batch_int(
        codes, chvs, B0, b, h=FRAG, w=FRAG, stride=STRIDE, tiles=itiles))
    return {"bitwise_equal": bool((a == b_).all())}


def run(n_frames: int = CHUNK, reps: int = 8,
        backend: str = "pallas") -> list[dict]:
    """Benchmark-driver entry point (``python -m benchmarks.run``)."""
    t = throughput(n_frames, reps)
    a = auc_parity(backend)
    d = determinism()
    return [
        {"name": "int_datapath/throughput",
         "float_fps": f"{t['float_fps']:.1f}",
         "int8_fps": f"{t['int8_fps']:.1f}",
         "speedup": f"{t['speedup']:.2f}x",
         "chunk": t["chunk"]},
        {"name": "int_datapath/auc",
         "synthetic_float": f"{a['synthetic_float_auc']:.4f}",
         "synthetic_int8": f"{a['synthetic_int8_auc']:.4f}",
         "synthetic_gap": f"{a['synthetic_gap']:.4f}",
         "drift_float": f"{a['drift_float_auc']:.4f}",
         "drift_int8": f"{a['drift_int8_auc']:.4f}",
         "drift_gap": f"{a['drift_gap']:.4f}",
         "backend": a["backend"]},
        {"name": "int_datapath/determinism",
         "bitwise_equal": d["bitwise_equal"]},
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=CHUNK,
                    help="chunk size (>= 8 is the claimed regime)")
    ap.add_argument("--reps", type=int, default=8)
    ap.add_argument("--backend", default="pallas",
                    choices=["jnp", "pallas"],
                    help="backend for the AUC scenarios")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless int8 fps >= float fps at "
                         f"chunk >= 8, AUC gap <= {AUC_TOL} on both "
                         "scenarios, and the int path is bitwise "
                         "deterministic")
    args = ap.parse_args()

    rows = run(args.frames, args.reps, args.backend)
    vals = {}
    for row in rows:
        name = row.pop("name")
        vals[name] = dict(row)
        print(name + "," + ",".join(f"{k}={v}" for k, v in row.items()))

    if args.check:
        t = vals["int_datapath/throughput"]
        a = vals["int_datapath/auc"]
        d = vals["int_datapath/determinism"]
        if float(t["int8_fps"]) < float(t["float_fps"]):
            raise SystemExit(
                f"REGRESSION: int8 path {t['int8_fps']} fps < float path "
                f"{t['float_fps']} fps at chunk {t['chunk']}")
        for scen in ("synthetic", "drift"):
            if float(a[f"{scen}_gap"]) > AUC_TOL:
                raise SystemExit(
                    f"REGRESSION: int8 AUC gap {a[f'{scen}_gap']} > "
                    f"{AUC_TOL} on the {scen} scenario "
                    f"(float {a[f'{scen}_float']}, int8 "
                    f"{a[f'{scen}_int8']})")
        if d["bitwise_equal"] is not True and d["bitwise_equal"] != "True":
            raise SystemExit("REGRESSION: int path not bitwise "
                             "deterministic across runs")
        print("int_datapath/check,ok=True")


if __name__ == "__main__":
    main()
