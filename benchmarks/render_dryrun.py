"""Render the dry-run summary table from results/dryrun.jsonl."""

from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def load(path):
    rows = {}
    if not os.path.exists(path):
        return []
    with open(path) as f:
        for line in f:
            if line.strip():
                r = json.loads(line)
                rows[(r.get("arch"), r.get("shape"), r.get("mesh"))] = r
    return list(rows.values())


def render(rows) -> str:
    ok = [r for r in rows if r.get("status") == "ok"]
    fail = [r for r in rows if r.get("status") != "ok"]
    out = [f"Dry-run cells compiled OK: {len(ok)}; failed: {len(fail)}\n\n"]
    out.append("| arch | shape | mesh | chips | compile (s) | "
               "coll GB/dev |\n|---|---|---|---|---|---|\n")
    for r in sorted(ok, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        out.append(
            "| {arch} | {shape} | {mesh} | {chips} | {compile_s} | "
            "{coll:.1f} |\n".format(
                coll=r.get("coll_gbytes", 0.0), **r))
    for r in fail:
        out.append(f"| {r.get('arch')} | {r.get('shape')} | "
                   f"{r.get('mesh')} | - | FAIL | - |\n")
    return "".join(out)


if __name__ == "__main__":
    print(render(load(os.path.join(RESULTS, "dryrun.jsonl"))))
