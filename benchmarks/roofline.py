"""Roofline table renderer: reads the dry-run JSONL into the
EXPERIMENTS.md §Roofline markdown table."""

from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def load(path: str) -> list[dict]:
    rows = []
    if not os.path.exists(path):
        return rows
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    # keep the last record per (arch, shape, mesh); ok supersedes fail
    dedup = {}
    for r in rows:
        key = (r.get("arch"), r.get("shape"), r.get("mesh"))
        if key in dedup and dedup[key].get("status") == "ok" \
                and r.get("status") != "ok":
            continue
        dedup[key] = r
    return list(dedup.values())


def render_roofline(rows: list[dict]) -> str:
    hdr = ("| arch | shape | T_comp (s) | T_mem (s) | T_coll (s) | "
           "bottleneck | roofline frac | useful-FLOP ratio |\n"
           "|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        if r.get("status") != "ok":
            out.append(f"| {r.get('arch')} | {r.get('shape')} | - | - | - "
                       f"| FAIL | - | - |\n")
            continue
        out.append(
            "| {arch} | {shape} | {t_compute:.4f} | {t_memory:.4f} | "
            "{t_collective:.4f} | {bottleneck} | {roofline_fraction:.3f} "
            "| {useful_flop_ratio:.3f} |\n".format(**r))
    return "".join(out)


def run() -> list[dict]:
    rows = load(os.path.join(RESULTS, "roofline_baseline.jsonl"))
    out = []
    for r in rows:
        if r.get("status") != "ok":
            out.append({"name": f"roofline/{r.get('arch')}/"
                                f"{r.get('shape')}", "status": "fail"})
            continue
        out.append({
            "name": f"roofline/{r['arch']}/{r['shape']}",
            "bottleneck": r["bottleneck"],
            "t_dominant_s": round(max(r["t_compute"], r["t_memory"],
                                      r["t_collective"]), 4),
            "roofline_fraction": round(r["roofline_fraction"], 3),
        })
    return out


if __name__ == "__main__":
    rows = load(os.path.join(RESULTS, "roofline_baseline.jsonl"))
    print(render_roofline(rows))
