"""Paper Fig. 16 + Table II: computation-reuse speedup, and the
gate → detector system cascade.

Default mode — two measurements:

1. **Operation counts** (exact, platform-independent): multiplies needed
   to encode one frame, naive vs computation-reuse — the paper's
   accelerator claim. reuse_factor ~ w / stride.
2. **Wall-clock on this host** (CPU, jnp paths): naive sliding encode vs
   reuse encode vs MLP per-fragment inference — the Fig. 16 model
   comparison, at reduced scale. TPU projections belong to the roofline
   analysis (EXPERIMENTS.md §Roofline).

``--system`` mode — the paper's end-to-end claim (5.6x vs an always-on
YOLOv4-class detector; up to 92.1% energy saving): a closed-loop
``FleetService`` gate runs over a sparse-event stream, its HP burst
drains are pumped into a :class:`repro.launch.cascade.CascadeService`
backbone, and the system energy account bills gate duty cycle x
measured backbone cost against the always-on backbone. ``--check``
enforces three gates:

* ``bitwise``   — cascade (batched, zero-padded, async) logits are
  bitwise-equal to eager per-frame backbone evaluation;
* ``recompiles`` — the backbone step compiles exactly once across all
  ragged drain sizes (fixed ``(B, H, W)`` launches);
* ``energy``    — duty-cycled system cost is strictly below the
  always-on backbone at matched missed positives (the always-on
  backbone evaluates every frame, so it misses nothing — the cascade
  is only credited if it wins despite that benefit of the doubt).

Run:  PYTHONPATH=src python benchmarks/fig16_speedup.py [--system] [--check]
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import encoding

SIZE = 16
DIM = 8192
STRIDE = 2

# --system scale: the control_loop benchmark's gate recipe (32x32 frames,
# sparse events) feeding the smoke embeds-in backbone.
BATCH = 8
PATCH = 8


def op_counts(frame: int, h: int, w: int, stride: int, dim: int) -> dict:
    my = encoding.num_windows(frame, h, stride)
    mx = encoding.num_windows(frame, w, stride)
    naive_mults = my * mx * h * w * dim
    # reuse: one product per (pixel-row, base-row) pair per dim + adds
    reuse_mults = frame * h * frame * dim // 1  # n_y*h rows x n_x elements
    return {"fragments": my * mx,
            "naive_mults": naive_mults,
            "reuse_mults": reuse_mults,
            "mult_reduction": round(naive_mults / reuse_mults, 2)}


def run() -> list[dict]:
    rows = []
    ops = op_counts(common.FRAME, SIZE, SIZE, STRIDE, DIM)
    rows.append({"name": "fig16/op_counts", **ops})

    model, _, _, _ = common.hdc_model(SIZE, DIM)
    _, _, fte, _, _ = common.dataset()
    frame = jnp.asarray(fte[0])
    B0 = model.B.reshape(SIZE, SIZE, DIM)[:, 0, :]

    t_naive = common.timed(jax.jit(lambda f: encoding.encode_frame_naive(
        f, B0, model.b, h=SIZE, w=SIZE, stride=STRIDE)), frame)
    t_reuse = common.timed(jax.jit(lambda f: encoding.encode_frame_reuse(
        f, B0, model.b, h=SIZE, w=SIZE, stride=STRIDE)), frame)
    rows.append({"name": "fig16/wallclock_cpu",
                 "naive_ms": round(t_naive * 1e3, 2),
                 "reuse_ms": round(t_reuse * 1e3, 2),
                 "speedup": round(t_naive / t_reuse, 2),
                 "note": "CPU jnp; TPU projection in EXPERIMENTS §Roofline"})

    # MLP per-frame cost (all fragments through a 2-layer MLP)
    from repro.sensing import baselines
    p = baselines.init_mlp(jax.random.PRNGKey(0), SIZE * SIZE, n_layers=2)

    def mlp_frame(f):
        frags = encoding.extract_fragments(f, SIZE, SIZE, STRIDE)
        flat = frags.reshape(-1, SIZE * SIZE)
        return baselines.mlp_apply(p, flat)

    t_mlp = common.timed(jax.jit(mlp_frame), frame)
    rows.append({"name": "fig16/vs_mlp",
                 "hdc_reuse_ms": round(t_reuse * 1e3, 2),
                 "mlp_ms": round(t_mlp * 1e3, 2),
                 "paper_speedup_vs_mlp": 2.4})
    return rows


def run_system() -> list[dict]:
    """Gate → detector full loop: serve, account, and gate the cascade."""
    from benchmarks import control_loop as cl
    from repro import configs
    from repro.core.sensor_control import (CaptureConfig, ControllerConfig,
                                           stats_from)
    from repro.launch import cascade, serve, steps
    from repro.sensing import synthetic

    hw = (cl.FRAME, cl.FRAME)
    cfg = synthetic.RadarConfig(height=cl.FRAME, width=cl.FRAME)
    hs = cl._train_gate(cfg)
    stream, labels = synthetic.make_drift_stream(
        jax.random.PRNGKey(3), cl.N_STREAM, cfg, synthetic.DriftConfig(),
        event_prob=cl.EVENT_PROB, event_len=cl.EVENT_LEN)
    stream, labels = np.asarray(stream), np.asarray(labels)
    n = (len(stream) // cl.CHUNK) * cl.CHUNK   # service ticks are whole chunks
    stream, labels = stream[:n], labels[:n]

    control = ControllerConfig(base_rate_hz=cl.BASE_HZ,
                               active_rate_hz=cl.ACTIVE_HZ,
                               hold_frames=cl.HOLD)
    svc = serve.FleetService(hs, control, n_slots=1, chunk_size=cl.CHUNK,
                             control=CaptureConfig())
    sid = "radar-0"
    svc.attach(sid)

    mcfg = configs.get_smoke("hubert-xlarge")
    params = steps.init_detector_params(jax.random.PRNGKey(7), mcfg,
                                        frame_hw=hw, patch=PATCH)
    casc = cascade.CascadeService(params, mcfg, batch_size=BATCH,
                                  frame_hw=hw, patch=PATCH)

    # serve the stream; pump ragged HP drains into the cascade as they land
    fired = np.zeros(len(stream), bool)
    gated = np.zeros(len(stream), bool)

    def take(chunk):
        _, f, g = chunk.outputs[sid]
        n = take.seen
        fired[n:n + len(f)], gated[n:n + len(g)] = f, g
        take.seen += len(f)

    take.seen = 0
    drain_sizes, hp_idx, hp_frames = [], [], []

    def drain():
        idx, frames = svc.drain_hp(sid)
        drain_sizes.append(len(idx))
        hp_idx.append(idx)
        hp_frames.append(frames)          # (M, H, W) even when M == 0
        casc.submit(sid, idx, frames)

    for t in range(0, len(stream), cl.CHUNK):
        svc.dispatch({sid: stream[t:t + cl.CHUNK]})
        chunk = svc.collect()
        if chunk is not None:
            take(chunk)
        drain()
    for chunk in svc.flush():
        take(chunk)
    drain()
    batches = casc.flush()

    # (a) bitwise: batched async service == eager per-frame evaluation
    # of the SAME drained HP captures (concatenation across ragged
    # drains is exactly what the (0, H, W) empty-drain contract buys)
    hp_idx = np.concatenate(hp_idx).astype(np.int64)
    hp_frames = np.concatenate(hp_frames)
    by_idx = {int(i): hp_frames[j] for j, i in enumerate(hp_idx)}
    order = np.concatenate([b.frame_idx for b in batches]).astype(np.int64)
    served = np.concatenate([b.logits for b in batches])
    eager = casc.eager(np.stack([by_idx[int(i)] for i in order]))
    bitwise = bool(np.array_equal(served, eager))

    # (b) one compile across ragged drains
    recompiles = casc.compile_count()

    # (c) system energy: duty-cycled cascade vs always-on backbone,
    # at matched missed positives (always-on evaluates EVERY frame →
    # missed_positive 0 <= the gate's — strictly harder to beat).
    log = svc.capture_log(sid)
    stats = stats_from(fired, gated, labels)
    sys_e = casc.system_energy(log)
    e_casc, e_always = sys_e["cascade"], sys_e["always_on"]
    cost = casc.backbone_cost()
    rl = casc.roofline()

    uniq = sorted(set(drain_sizes))
    rows = [
        {"name": "fig16/system_serve",
         "frames": len(stream), "hp_frames": int(casc.frames_in),
         "duty": round(float(np.asarray(log.gated, bool).mean()), 4),
         "missed_positive": round(float(stats.missed_positive), 4),
         "drain_sizes": f"{min(uniq)}..{max(uniq)}({len(uniq)} distinct)",
         "backbone_batches": casc.batches,
         "padded_rows": casc.frames_padded,
         "bitwise_vs_eager": bitwise,
         "backbone_recompiles": recompiles},
        {"name": "fig16/system_energy",
         "backbone_j_per_frame": f"{cost.joules:.3e}",
         "cascade_j_per_frame": f"{e_casc.total:.3e}",
         "always_on_j_per_frame": f"{e_always.total:.3e}",
         "system_saving": f"{1 - e_casc.total / e_always.total:.1%}",
         "backbone_step_ms_roofline":
             round(max(rl.t_compute, rl.t_memory) * 1e3, 4),
         "paper_saving": "92.1%"},
    ]
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--system", action="store_true",
                    help="serve the gate → detector cascade end to end "
                         "instead of the encode microbenchmarks")
    ap.add_argument("--check", action="store_true",
                    help="with --system: exit nonzero unless cascade == "
                         "eager bitwise, the backbone compiled exactly "
                         "once, and duty-cycled system energy beats the "
                         "always-on backbone; without: sanity-check the "
                         "reuse op-count reduction")
    common.add_json_arg(ap)
    args = ap.parse_args()

    rows = run_system() if args.system else run()
    vals = {}
    for row in rows:
        vals[row["name"]] = row
        print(row["name"] + "," + ",".join(
            f"{k}={v}" for k, v in row.items() if k != "name"))
    if args.json:
        name = "fig16_system" if args.system else "fig16_speedup"
        print("wrote", common.write_json(args.json, name, rows))

    if args.check and args.system:
        serve_row = vals["fig16/system_serve"]
        if serve_row["bitwise_vs_eager"] is not True:
            raise SystemExit(
                "REGRESSION: cascade-served backbone logits are not "
                "bitwise-equal to eager per-frame evaluation")
        if serve_row["backbone_recompiles"] != 1:
            raise SystemExit(
                f"REGRESSION: backbone step compiled "
                f"{serve_row['backbone_recompiles']}x — ragged HP drains "
                f"must reuse the one fixed-shape executable")
        e = vals["fig16/system_energy"]
        if not (float(e["cascade_j_per_frame"])
                < float(e["always_on_j_per_frame"])):
            raise SystemExit(
                "REGRESSION: duty-cycled cascade energy "
                f"{e['cascade_j_per_frame']} J/frame is not below the "
                f"always-on backbone {e['always_on_j_per_frame']} J/frame "
                "at matched missed positives")
        print("fig16/system_check,ok=True")
    elif args.check:
        ops = vals["fig16/op_counts"]
        if ops["mult_reduction"] < 2.0:
            raise SystemExit(
                f"REGRESSION: computation-reuse multiply reduction "
                f"{ops['mult_reduction']}x < 2x")
        print("fig16/check,ok=True")


if __name__ == "__main__":
    main()
