"""Paper Fig. 16 + Table II: computation-reuse speedup.

Two measurements:

1. **Operation counts** (exact, platform-independent): multiplies needed
   to encode one frame, naive vs computation-reuse — the paper's
   accelerator claim. reuse_factor ~ w / stride.
2. **Wall-clock on this host** (CPU, jnp paths): naive sliding encode vs
   reuse encode vs MLP per-fragment inference — the Fig. 16 model
   comparison, at reduced scale. TPU projections belong to the roofline
   analysis (EXPERIMENTS.md §Roofline).

Paper: 5.6x vs YOLOv4 / 2.4x vs MLP on Jetson; FPGA 303 FPS.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import encoding

SIZE = 16
DIM = 8192
STRIDE = 2


def op_counts(frame: int, h: int, w: int, stride: int, dim: int) -> dict:
    my = encoding.num_windows(frame, h, stride)
    mx = encoding.num_windows(frame, w, stride)
    naive_mults = my * mx * h * w * dim
    # reuse: one product per (pixel-row, base-row) pair per dim + adds
    reuse_mults = frame * h * frame * dim // 1  # n_y*h rows x n_x elements
    return {"fragments": my * mx,
            "naive_mults": naive_mults,
            "reuse_mults": reuse_mults,
            "mult_reduction": round(naive_mults / reuse_mults, 2)}


def _time(fn, *args, reps: int = 3) -> float:
    fn(*args).block_until_ready()          # compile + warm
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.time() - t0) / reps


def run() -> list[dict]:
    rows = []
    ops = op_counts(common.FRAME, SIZE, SIZE, STRIDE, DIM)
    rows.append({"name": "fig16/op_counts", **ops})

    model, _, _, _ = common.hdc_model(SIZE, DIM)
    _, _, fte, _, _ = common.dataset()
    frame = jnp.asarray(fte[0])
    B0 = model.B.reshape(SIZE, SIZE, DIM)[:, 0, :]

    t_naive = _time(jax.jit(lambda f: encoding.encode_frame_naive(
        f, B0, model.b, h=SIZE, w=SIZE, stride=STRIDE)), frame)
    t_reuse = _time(jax.jit(lambda f: encoding.encode_frame_reuse(
        f, B0, model.b, h=SIZE, w=SIZE, stride=STRIDE)), frame)
    rows.append({"name": "fig16/wallclock_cpu",
                 "naive_ms": round(t_naive * 1e3, 2),
                 "reuse_ms": round(t_reuse * 1e3, 2),
                 "speedup": round(t_naive / t_reuse, 2),
                 "note": "CPU jnp; TPU projection in EXPERIMENTS §Roofline"})

    # MLP per-frame cost (all fragments through a 2-layer MLP)
    from repro.sensing import baselines
    p = baselines.init_mlp(jax.random.PRNGKey(0), SIZE * SIZE, n_layers=2)

    def mlp_frame(f):
        frags = encoding.extract_fragments(f, SIZE, SIZE, STRIDE)
        flat = frags.reshape(-1, SIZE * SIZE)
        return baselines.mlp_apply(p, flat)

    t_mlp = _time(jax.jit(mlp_frame), frame)
    rows.append({"name": "fig16/vs_mlp",
                 "hdc_reuse_ms": round(t_reuse * 1e3, 2),
                 "mlp_ms": round(t_mlp * 1e3, 2),
                 "paper_speedup_vs_mlp": 2.4})
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
