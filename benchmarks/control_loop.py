"""Closed-loop sensor control: ADC conversions saved at matched quality.

The paper's headline mechanism (§III-B): HyperSense "controls the ADC
modules' data generation rate based on object presence predictions". The
closed-loop runtime (``StreamRunner(control=CaptureConfig(...))``) makes
the ``ControllerConfig`` rates real — idle frames are LP-converted at
``base_rate_hz`` only, gate bursts capture every frame and turn on the
high-precision path. Two claims, both enforced by ``--check``:

* ``samples`` — on a sparse-event synthetic stream the closed loop
  converts **>= 2x fewer ADC samples** than always-on capture *at matched
  missed_positive*: the always-on baseline is swept over its score
  threshold and compared at the operating point with the fewest
  conversions whose missed-positive rate is still no worse than the
  closed loop's (i.e. the baseline gets every benefit of the doubt — it
  just can never stop converting the idle frames).
* ``parity`` — with control *disabled* (``subsample=False``, and
  separately ``base_rate_hz == active_rate_hz``) the closed-loop runner's
  scores/fired/gated are **bitwise identical** to the open-loop runner:
  the control plumbing costs nothing when it is off.

Also reported: the capture-log energy account
(:func:`repro.core.energy.from_capture_log`) for both regimes — the
closed loop's savings are billed from conversions actually made, not a
duty-cycle approximation.

Run:  PYTHONPATH=src python benchmarks/control_loop.py [--check]
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy, fragment_model as fm, hypersense, metrics
from repro.core.sensor_control import (CaptureConfig, CaptureLog,
                                       ControllerConfig, decimation,
                                       stats_from)
from repro.sensing import fragments, synthetic
from repro.sensing.stream import StreamRunner, gate_scan

# CPU-tractable scale: sparse events (the paper's "activity of interest
# is infrequent" regime) so idle decimation has something to save.
FRAME = 32
FRAG = 8
STRIDE = 4
DIM = 1024
N_STREAM = 400
CHUNK = 32
EVENT_PROB = 0.008
EVENT_LEN = 12
BASE_HZ = 10.0
ACTIVE_HZ = 60.0
HOLD = 6


def _train_gate(cfg):
    """Small Fragment-model gate at an FPR-targeted operating point."""
    frames, masks, _ = synthetic.make_dataset(jax.random.PRNGKey(0), 60,
                                              cfg)
    frs, labs = fragments.sample_fragments(
        np.asarray(frames), np.asarray(masks), h=FRAG, w=FRAG,
        per_frame=2, seed=0)
    model, _ = fm.train_fragment_model(
        jax.random.PRNGKey(1), jnp.asarray(frs), jnp.asarray(labs),
        dim=DIM, epochs=8)
    B0 = model.B.reshape(FRAG, FRAG, -1)[:, 0, :]
    hs = hypersense.from_fragment_model(model, B0, h=FRAG, w=FRAG,
                                        stride=STRIDE, t_detection=1)
    te_frames, _, te_labels = synthetic.make_dataset(
        jax.random.PRNGKey(2), 32, cfg)
    scores = np.asarray(hypersense.frame_scores_batch(hs, te_frames, 0,
                                                      sequential=True))
    fpr, tpr, thr = metrics.roc_curve(scores, np.asarray(te_labels))
    t_score = metrics.threshold_at_fpr(fpr, tpr, thr, 0.1)
    return hs._replace(t_score=float(t_score))


def _samples(log) -> int:
    return log.samples_converted()


def _matched_always_on(scores, labels, hold: int, target_missed: float,
                       pixels: int
                       ) -> tuple[int, float, float, np.ndarray]:
    """Cheapest always-on operating point no worse than the closed loop:
    ``(samples_converted, duty, missed_positive, gated)``.

    The always-on runner's scores are threshold-independent, so the sweep
    replays ``gate_scan`` per candidate threshold — no re-scoring. Picks
    the point with the fewest total conversions (LP every frame + HP on
    gated frames) whose ``missed_positive <= target``; always exists
    because gating everything misses nothing. Rates come from the same
    :func:`~repro.core.sensor_control.stats_from` accounting as the
    closed-loop side of the comparison (so an event-free stream — NaN
    target — is rejected up front, not silently matched).
    """
    if not np.isfinite(target_missed):
        raise SystemExit(
            "control_loop benchmark stream has no positive frames "
            "(missed_positive is NaN) — matched comparison is undefined; "
            "raise EVENT_PROB / N_STREAM")
    best = None
    for t in np.unique(np.asarray(scores)):
        for cand in (t, np.nextafter(t, -np.inf)):
            fired = np.asarray(scores) > cand
            gated = np.asarray(gate_scan(jnp.asarray(fired), hold)[0])
            stats = stats_from(fired, gated, labels)
            if stats.missed_positive <= target_missed + 1e-12:
                samples = (len(labels) + int(gated.sum())) * pixels
                if best is None or samples < best[0]:
                    best = (samples, stats.duty_cycle,
                            stats.missed_positive, gated)
    return best


def run(backend: str = "jnp") -> list[dict]:
    cfg = synthetic.RadarConfig(height=FRAME, width=FRAME)
    hs = _train_gate(cfg)
    stream, labels = synthetic.make_drift_stream(
        jax.random.PRNGKey(3), N_STREAM, cfg, synthetic.DriftConfig(),
        event_prob=EVENT_PROB, event_len=EVENT_LEN)
    labels = np.asarray(labels)
    control = ControllerConfig(base_rate_hz=BASE_HZ,
                               active_rate_hz=ACTIVE_HZ,
                               hold_frames=HOLD)
    pixels = FRAME * FRAME

    # --- closed loop -----------------------------------------------------
    closed = StreamRunner(hs, control, chunk_size=CHUNK, backend=backend,
                          control=CaptureConfig(hp_buffer=0))
    _, fired_c, gated_c = closed.process(stream)
    log_c = closed.capture_log
    stats_c = stats_from(fired_c, gated_c, labels)
    e_closed = energy.from_capture_log(log_c)

    # --- always-on baseline at matched missed_positive -------------------
    always = StreamRunner(hs, control, chunk_size=CHUNK, backend=backend)
    scores_a, fired_a, gated_a = always.process(stream)
    samples_a, duty_a, missed_a, gated_m = _matched_always_on(
        scores_a, labels, HOLD, stats_c.missed_positive, pixels)
    # bill the baseline AT the matched operating point (every frame
    # LP-converted, the matched threshold's gate pattern HP-converted)
    e_always = energy.from_capture_log(CaptureLog(
        sampled=np.ones_like(gated_m), gated=gated_m,
        frame_pixels=pixels))

    reduction = samples_a / max(_samples(log_c), 1)

    # --- parity: the closed loop off == the open loop --------------------
    off = StreamRunner(hs, control, chunk_size=CHUNK, backend=backend,
                       control=CaptureConfig(subsample=False, hp_buffer=0))
    s_off, f_off, g_off = off.process(stream)
    flat = ControllerConfig(base_rate_hz=ACTIVE_HZ,
                            active_rate_hz=ACTIVE_HZ, hold_frames=HOLD)
    same = StreamRunner(hs, flat, chunk_size=CHUNK, backend=backend,
                        control=CaptureConfig(hp_buffer=0))
    s_same, f_same, g_same = same.process(stream)
    parity = bool((s_off == scores_a).all() and (f_off == fired_a).all()
                  and (g_off == gated_a).all()
                  and (s_same == scores_a).all()
                  and (f_same == fired_a).all()
                  and (g_same == gated_a).all())

    return [
        {"name": "control_loop/closed",
         "samples_converted": _samples(log_c),
         "sampled_frac": f"{float(log_c.sampled.mean()):.3f}",
         "duty": f"{stats_c.duty_cycle:.3f}",
         "missed_positive": f"{stats_c.missed_positive:.3f}",
         "energy_j_per_frame": f"{e_closed.total:.4f}",
         "decim": decimation(control), "backend": backend},
        {"name": "control_loop/always_on_matched",
         "samples_converted": samples_a,
         "duty": f"{duty_a:.3f}",
         "missed_positive": f"{missed_a:.3f}",
         "energy_j_per_frame": f"{e_always.total:.4f}",
         "backend": backend},
        {"name": "control_loop/samples_reduction",
         "value": f"{reduction:.2f}x", "backend": backend},
        {"name": "control_loop/parity_when_disabled",
         "bitwise_equal": parity, "backend": backend},
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless the closed loop converts "
                         ">= 2x fewer ADC samples than the matched "
                         "always-on baseline AND disabling control is "
                         "bitwise-invisible")
    args = ap.parse_args()

    rows = run(args.backend)
    vals = {}
    for row in rows:
        name = row.pop("name")
        vals[name] = dict(row)
        print(name + "," + ",".join(f"{k}={v}" for k, v in row.items()))

    if args.check:
        red = float(vals["control_loop/samples_reduction"]["value"][:-1])
        if red < 2.0:
            raise SystemExit(
                f"REGRESSION: closed-loop samples reduction {red:.2f}x "
                f"< 2x vs matched always-on capture")
        if vals["control_loop/parity_when_disabled"]["bitwise_equal"] \
                is not True:
            raise SystemExit(
                "REGRESSION: closed-loop runner with control disabled is "
                "not bitwise-identical to the open-loop runner")
        print("control_loop/check,ok=True")


if __name__ == "__main__":
    main()
