"""Streaming scorer throughput: batched kernel vs sequential vs jnp-vmap.

The tentpole measurement for the batched streaming pipeline: frames/sec of
the HyperSense frame-scoring hot path (fragment score map ->
frame_detection_score) under three execution strategies:

* ``jnp-vmap``     — pure-jnp scoring vmapped over the chunk
* ``seq-kernel``   — the sliding-scores kernel, one launch PER FRAME
  (the pre-batching hot path: O(N) dispatches)
* ``batch-kernel`` — ONE launch per chunk, grid ``(N, my, n_dt)``,
  sharing a single ScoreTiles precompute

On CPU the kernel paths run in Pallas interpret mode, so absolute numbers
are small; the *ratio* batch-kernel/seq-kernel is the claim being checked
(one launch amortizes dispatch + norms + epilogue over the chunk). On TPU
the same code compiles and the gap widens.

Run:  PYTHONPATH=src python benchmarks/stream_throughput.py [--frames 32]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import hypersense
from repro.core.encoding import make_perm_base_rows
from repro.kernels import ops

# CPU-tractable scale (interpret mode executes grid steps in Python).
FRAME = 32
FRAG = 8
STRIDE = 4
DIM = 256
BLOCK_D = 128
REPS = 3


def _make_model(dim: int, frag: int, stride: int):
    B0, b = make_perm_base_rows(jax.random.PRNGKey(0), frag, dim)
    C = jax.random.normal(jax.random.PRNGKey(1), (2, dim))
    return hypersense.HyperSenseModel(C, B0, b, frag, frag, stride,
                                      t_score=0.0, t_detection=2)


def _time(fn, reps: int = REPS) -> float:
    """Best-of-N wall time: min suppresses scheduler noise on shared CPUs."""
    fn()  # warmup / compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(n_frames: int = FRAME, frame: int = FRAME, frag: int = FRAG,
        stride: int = STRIDE, dim: int = DIM, reps: int = REPS):
    model = _make_model(dim, frag, stride)
    frames = jax.random.uniform(jax.random.PRNGKey(2),
                                (n_frames, frame, frame))
    tiles = ops.precompute_tiles(model.B0, model.b, model.class_hvs,
                                 W=frame, w=frag, stride=stride,
                                 block_d=BLOCK_D)

    def jnp_vmap():
        jax.block_until_ready(
            hypersense.frame_scores_batch(model, frames, backend="jnp"))

    def seq_kernel():
        for i in range(n_frames):
            s = ops.fragment_score_map(
                frames[i], model.class_hvs, model.B0, model.b, h=frag,
                w=frag, stride=stride, tiles=tiles)
            jax.block_until_ready(
                hypersense.frame_detection_score(s, model.t_detection))

    def batch_kernel():
        jax.block_until_ready(
            hypersense.frame_scores_batch(model, frames, backend="pallas",
                                          tiles=tiles))

    rows = []
    fps = {}
    for name, fn in [("jnp-vmap", jnp_vmap), ("seq-kernel", seq_kernel),
                     ("batch-kernel", batch_kernel)]:
        dt = _time(fn, reps)
        fps[name] = n_frames / dt
        rows.append({"name": f"stream_throughput/{name}",
                     "frames_per_sec": f"{fps[name]:.1f}",
                     "ms_per_chunk": f"{dt * 1e3:.1f}",
                     "batch": n_frames})
    rows.append({"name": "stream_throughput/batch_vs_seq_speedup",
                 "value": f"{fps['batch-kernel'] / fps['seq-kernel']:.2f}x",
                 "batch": n_frames})
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=FRAME,
                    help="chunk size (batch of frames per step)")
    ap.add_argument("--frame-size", type=int, default=FRAME)
    ap.add_argument("--frag", type=int, default=FRAG)
    ap.add_argument("--stride", type=int, default=STRIDE)
    ap.add_argument("--dim", type=int, default=DIM)
    ap.add_argument("--reps", type=int, default=REPS)
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless batch-kernel >= seq-kernel "
                         "frames/sec (the batching claim; use batch >= 8)")
    args = ap.parse_args()
    rows = run(args.frames, args.frame_size, args.frag, args.stride,
               args.dim, args.reps)
    fps = {}
    for row in rows:
        name = row.pop("name")
        if "frames_per_sec" in row:
            fps[name.split("/")[-1]] = float(row["frames_per_sec"])
        print(name + "," + ",".join(f"{k}={v}" for k, v in row.items()))
    if args.check and fps["batch-kernel"] < fps["seq-kernel"]:
        raise SystemExit(
            f"REGRESSION: batch-kernel {fps['batch-kernel']:.1f} fps < "
            f"seq-kernel {fps['seq-kernel']:.1f} fps at batch "
            f"{args.frames}")


if __name__ == "__main__":
    main()
