"""Shared benchmark substrate: dataset + trained models, cached on disk.

All paper benchmarks reproduce on the synthetic radar dataset (CRUW
stand-in, DESIGN.md §1) at a CPU-tractable scale:
64x64 frames, 16x16 default fragments, D=2048 default dimensionality.
The paper's relative claims (model ordering, hyperparameter trends,
energy arithmetic) are scale-invariant; exact operating points that
depend on CRUW are reported next to the paper's numbers with that caveat.
"""

from __future__ import annotations

import json
import os
import pickle
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fragment_model as fm
from repro.core import metrics
from repro.core.encoding import encode_fragments
from repro.sensing import adc, fragments, synthetic

CACHE = os.environ.get("REPRO_BENCH_CACHE", "/tmp/repro_bench_cache")

# Difficulty calibrated so the paper's regime holds: scarce training data,
# low-precision ADC, noisier deployment than training (sensor drift) plus
# impulse interference spikes on the test stream — the "raw noisy
# low-precision sensor data" setting HyperSense targets (paper §I, §III-B).
FRAME = 64
N_TRAIN_FRAMES = 60
N_TEST_FRAMES = 100
LOW_BITS = 4
TRAIN_NOISE = 0.20
TEST_NOISE = 0.30
IMPULSE_P = 0.03          # interference spike probability (test only)
DEFAULT_DIM = 8192
DEFAULT_EPOCHS = 20


def _cache_path(name: str) -> str:
    os.makedirs(CACHE, exist_ok=True)
    return os.path.join(CACHE, name + ".pkl")


def cached(name: str, builder):
    path = _cache_path(name)
    if os.path.exists(path):
        with open(path, "rb") as f:
            return pickle.load(f)
    out = builder()
    with open(path, "wb") as f:
        pickle.dump(out, f)
    return out


def _radar_cfg(noise: float) -> synthetic.RadarConfig:
    return synthetic.RadarConfig(
        height=FRAME, width=FRAME, noise_sigma=noise,
        intensity_lo=0.25, intensity_hi=0.6,
        blob_sigma_lo=1.5, blob_sigma_hi=4.0)


def dataset():
    """(train_frames, train_masks, test_frames, test_masks, test_labels)
    — low-precision (4-bit ADC) views, as the HDC gate sees them. The test
    stream is noisier than training (drift) + impulse interference."""
    def build():
        ftr, mtr, _ = synthetic.make_dataset(
            jax.random.PRNGKey(0), N_TRAIN_FRAMES, _radar_cfg(TRAIN_NOISE))
        fte, mte, lte = synthetic.make_dataset(
            jax.random.PRNGKey(1), N_TEST_FRAMES, _radar_cfg(TEST_NOISE))
        spikes = (jax.random.uniform(jax.random.PRNGKey(9), fte.shape)
                  < IMPULSE_P).astype(jnp.float32)
        fte = jnp.clip(fte + spikes * 1.2, 0, 1.5)
        ftr = adc.quantize(ftr, LOW_BITS)
        fte = adc.quantize(fte, LOW_BITS)
        return (np.asarray(ftr), np.asarray(mtr), np.asarray(fte),
                np.asarray(mte), np.asarray(lte))

    return cached("dataset", build)


def fragment_sets(size: int, per_frame: int = 2):
    """Balanced train/test fragments at the given fragment size."""
    def build():
        ftr, mtr, fte, mte, _ = dataset()
        tr = fragments.sample_fragments(ftr, mtr, h=size, w=size,
                                        per_frame=per_frame, seed=0)
        te = fragments.sample_fragments(fte, mte, h=size, w=size,
                                        per_frame=3, seed=1)
        return tr, te

    return cached(f"frags_{size}", build)


def hdc_model(size: int = 16, dim: int = DEFAULT_DIM,
              epochs: int = DEFAULT_EPOCHS):
    """Trained Fragment model (permutation base, RFF) + test scores."""
    def build():
        (ftr, ltr), (fte, lte) = fragment_sets(size)
        model, info = fm.train_fragment_model(
            jax.random.PRNGKey(42), jnp.asarray(ftr), jnp.asarray(ltr),
            dim=dim, epochs=epochs)
        hv_te = encode_fragments(jnp.asarray(fte), model.B, model.b)
        scores = np.asarray(fm.positive_score(model.class_hvs, hv_te))
        return model, info, scores, lte

    return cached(f"hdc_{size}_{dim}", build)


def timed(fn, *args, reps: int = 3) -> float:
    """Mean seconds per call, compiled/warm, **synced every rep**.

    JAX dispatch is async: timing a loop of un-synced calls and blocking
    only on the last result measures enqueue cost for reps-1 of them and
    lets later dispatches overlap earlier compute — a systematic
    under-estimate. Every benchmark times through here so each rep pays
    its own ``block_until_ready()``.
    """
    jax.block_until_ready(fn(*args))       # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def roc_of(scores, labels):
    fpr, tpr, thr = metrics.roc_curve(scores, labels)
    return {"fpr": fpr, "tpr": tpr, "thr": thr,
            "auc": metrics.auc(fpr, tpr),
            "pauc08": metrics.partial_auc_above_tpr(fpr, tpr, 0.8)}


# --- machine-readable results ----------------------------------------------
# Every benchmark prints CSV rows for humans; `--json PATH` additionally
# writes the SAME rows as `BENCH_<name>.json` for dashboards/regression
# tooling. PATH may be a directory (the canonical filename is appended)
# or an explicit file path. `benchmarks/run.py --json-dir` fans this out
# across every suite.

def add_json_arg(ap) -> None:
    """The shared ``--json PATH`` benchmark flag (one spelling, one help
    string — every benchmark CLI registers it through here)."""
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the result rows as JSON: to "
                         "PATH/BENCH_<name>.json if PATH is a directory, "
                         "else to PATH itself")


def json_path(arg: str, name: str) -> str:
    """Resolve the ``--json`` argument to a concrete file path."""
    if os.path.isdir(arg) or arg.endswith(os.sep):
        return os.path.join(arg, f"BENCH_{name}.json")
    return arg


def _jsonable(v):
    if isinstance(v, (np.generic, jnp.ndarray)) and np.ndim(v) == 0:
        return np.asarray(v).item()
    if isinstance(v, (np.ndarray, jnp.ndarray, list, tuple)):
        return [_jsonable(x) for x in np.asarray(v).tolist()]
    return v


def write_json(arg: str, name: str, rows: list[dict],
               meta: dict | None = None) -> str:
    """Write ``rows`` (each still carrying its ``name`` key) as
    ``BENCH_<name>.json``; returns the path written."""
    path = json_path(arg, name)
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    payload = {"benchmark": name,
               "rows": [{k: _jsonable(v) for k, v in r.items()}
                        for r in rows]}
    if meta:
        payload["meta"] = {k: _jsonable(v) for k, v in meta.items()}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path
