"""Paper Fig. 17 + Table III: end-to-end energy saving vs quality loss.

Three reproductions:
  1. paper operating points (TPR implied by the paper's quality loss) with
     DEFAULT literature constants;
  2. same with constants CALIBRATED to Table III (least squares, 3 free
     scalars — repro.core.energy.calibrate);
  3. OUR trained HyperSense model's ROC operating points on the synthetic
     dataset, through the same energy model + the sensor-control stream
     simulation (duty cycle measured, not assumed).
"""

from __future__ import annotations


from benchmarks import common
from repro.core import energy, metrics

P_OBJECT = 0.01


def run() -> list[dict]:
    rows = []
    for label, params in [("default", energy.EnergyParams()),
                          ("calibrated", energy.calibrate(P_OBJECT))]:
        conv = energy.conventional(params)
        bdc = energy.compressive_sensing(params)
        rows.append({"name": f"table3/{label}/compressive_sensing",
                     "total_saving": round(
                         energy.savings(bdc, conv)["total_saving"], 4)})
        for fpr, (tot, edge, ql) in energy.PAPER_TABLE_III.items():
            ours = energy.hypersense(fpr, 1 - ql, P_OBJECT, params)
            s = energy.savings(ours, conv)
            rows.append({
                "name": f"table3/{label}/fpr{fpr}",
                "total_saving": round(s["total_saving"], 4),
                "paper_total": tot,
                "edge_saving": round(s["edge_saving"], 4),
                "paper_edge": edge,
                "quality_loss": ql,
            })

    # our model's ROC -> achievable operating points on synthetic data
    _, _, scores, labels = common.hdc_model(16)
    fpr_arr, tpr_arr, _ = metrics.roc_curve(scores, labels)
    params = energy.calibrate(P_OBJECT)
    conv = energy.conventional(params)
    for target in [0.05, 0.1, 0.2, 0.3]:
        tpr = metrics.tpr_at_fpr(fpr_arr, tpr_arr, target)
        ours = energy.hypersense(target, tpr, P_OBJECT, params)
        s = energy.savings(ours, conv)
        rows.append({
            "name": f"table3/ours_fpr{target}",
            "tpr": round(tpr, 4),
            "total_saving": round(s["total_saving"], 4),
            "edge_saving": round(s["edge_saving"], 4),
            "quality_loss": round(energy.quality_loss(tpr), 4),
        })
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
