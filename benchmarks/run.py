"""Benchmark driver — one module per paper table/figure.

Prints ``name,value,derived`` CSV rows per benchmark plus a summary.
``python -m benchmarks.run [--only table1] [--json-dir out/]`` —
``--json-dir`` additionally writes each suite's rows as
``BENCH_<suite>.json`` (``benchmarks.common.write_json``).
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

SUITES = ["table1_auc", "fig12_thresholds", "fig13_stride",
          "fig15_fragsize_dim", "fig16_speedup", "stream_throughput",
          "fleet_throughput", "serve_throughput", "adaptation",
          "int_datapath", "control_loop", "table3_energy",
          "hypersense_roofline", "roofline"]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--json-dir", metavar="DIR", default=None,
                    help="write each suite's rows as DIR/BENCH_<suite>"
                         ".json in addition to the CSV stdout")
    args = ap.parse_args()

    failures = []
    for suite in SUITES:
        if args.only and args.only not in suite:
            continue
        t0 = time.time()
        print(f"\n===== {suite} =====", flush=True)
        try:
            mod = __import__(f"benchmarks.{suite}", fromlist=["run"])
            rows = mod.run()
            if args.json_dir:
                from benchmarks import common
                path = common.write_json(
                    args.json_dir + "/", suite, rows,
                    meta={"elapsed_s": round(time.time() - t0, 2)})
                print(f"[{suite}] json -> {path}")
            for row in rows:
                row = dict(row)
                name = row.pop("name")
                kv = ",".join(f"{k}={v}" for k, v in row.items())
                print(f"{name},{kv}")
            print(f"[{suite}] ok in {time.time() - t0:.1f}s", flush=True)
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failures.append(suite)
    if failures:
        print(f"\nFAILED suites: {failures}")
        return 1
    print("\nall benchmark suites passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
