"""Paper Fig. 12: exploring T_score x T_detection on the HyperSense model.

Reproduces the claim: different T_detection choices give DIFFERENT ROC
curves (a family, not a single curve), so the operating T_detection must
be selected per target FPR. Reports the best frame-level F1 over the
(T_score, T_detection) grid and per-T_detection AUC.

Efficiency note: the fragment score MAP per frame is independent of
T_detection (only the k-th-order-statistic readout differs), so maps are
computed once and every T_detection row derives from the same cache.
"""

from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import hypersense, metrics

SIZE = 16
DIM = 8192
STRIDE = 8
N_FRAMES = 48
T_DETS = [0, 1, 2, 4, 8]


def score_maps():
    """(N, my, mx) fragment score maps for the test frames (cached)."""
    def build():
        import jax
        import jax.numpy as jnp
        model, _, _, _ = common.hdc_model(SIZE, DIM)
        _, _, fte, _, lte = common.dataset()
        B0 = model.B.reshape(SIZE, SIZE, DIM)[:, 0, :]
        hs = hypersense.HyperSenseModel(
            class_hvs=model.class_hvs, B0=B0, b=model.b, h=SIZE, w=SIZE,
            stride=STRIDE, t_score=0.0, t_detection=0)
        score = jax.jit(lambda f: hypersense.score_frame(hs, f))
        maps = np.stack([np.asarray(score(jnp.asarray(f)))
                         for f in fte[:N_FRAMES]])
        return maps, lte[:N_FRAMES]

    return common.cached(f"fig12_maps_{N_FRAMES}", build)


def run() -> list[dict]:
    maps, labels = score_maps()
    rows = []
    best = {"f1": -1.0}
    for t_det in T_DETS:
        flat = maps.reshape(maps.shape[0], -1)
        k = min(t_det, flat.shape[1] - 1)
        scores = np.sort(flat, axis=1)[:, ::-1][:, k]   # (T+1)-th largest
        fpr, tpr, thr = metrics.roc_curve(scores, labels)
        auc = metrics.auc(fpr, tpr)
        f1s = [metrics.f1_score(scores > t, labels)
               for t in np.quantile(scores, np.linspace(0.05, 0.95, 19))]
        f1 = float(np.max(f1s))
        rows.append({"name": f"fig12/t_det_{t_det}", "auc": round(auc, 4),
                     "best_f1": round(f1, 4)})
        if f1 > best["f1"]:
            best = {"f1": round(f1, 4), "t_det": t_det}
    rows.append({"name": "fig12/best", **best})
    aucs = [r["auc"] for r in rows if "auc" in r]
    rows.append({"name": "fig12/roc_family_spread",
                 "auc_spread": round(float(np.ptp(aucs)), 4),
                 "claim": "distinct T_detection -> distinct ROC curves"})
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
