"""Paper Table I + Fig. 11: partial AUC (TPR > 0.8) of the Fragment model
vs MLP (2/4 layers) and a tiny-conv (YOLOv4-tiny stand-in).

Paper values (CRUW, fragment 128): HDC 0.1739 > MLP2 0.1685 > MLP4 0.1681
>> YOLO-tiny 0.0803. The claim validated here is the ORDERING (HDC best
in the high-TPR region on noisy low-precision radar-like data) and the
magnitude band; absolute values differ on the synthetic stand-in.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.sensing import baselines

SIZE = 16
DIM = 8192


def run() -> list[dict]:
    rows = []
    (ftr, ltr), (fte, lte) = common.fragment_sets(SIZE)

    t0 = time.time()
    _, info, scores, lte_ = common.hdc_model(SIZE, DIM)
    r = common.roc_of(scores, lte_)
    rows.append({"name": "table1/hdc_2k", "paper": 0.1739,
                 "pauc08": r["pauc08"], "auc": r["auc"],
                 "train_s": round(time.time() - t0, 1)})

    def bench_baseline(name, params, apply_fn, epochs=25, paper=None):
        t0 = time.time()
        p = baselines.train_classifier(
            jax.random.PRNGKey(7), params, apply_fn,
            jnp.asarray(ftr), jnp.asarray(ltr), epochs=epochs)
        s = np.asarray(baselines.positive_score(apply_fn, p,
                                                jnp.asarray(fte)))
        r = common.roc_of(s, lte)
        rows.append({"name": f"table1/{name}", "paper": paper,
                     "pauc08": r["pauc08"], "auc": r["auc"],
                     "train_s": round(time.time() - t0, 1)})

    n_in = SIZE * SIZE
    bench_baseline("mlp2", baselines.init_mlp(jax.random.PRNGKey(1), n_in,
                                              n_layers=2),
                   baselines.mlp_apply, paper=0.1685)
    bench_baseline("mlp4", baselines.init_mlp(jax.random.PRNGKey(2), n_in,
                                              n_layers=4),
                   baselines.mlp_apply, paper=0.1681)
    bench_baseline("tiny_conv",
                   baselines.init_tiny_conv(jax.random.PRNGKey(3)),
                   baselines.tiny_conv_apply, epochs=15, paper=0.0803)
    rows.append({
        "name": "table1/note",
        "claim": "HDC > MLP2/MLP4 ordering reproduces on noisy "
                 "low-precision data; the conv stand-in is a purpose-"
                 "built 25k-param blob classifier and is STRONGER than "
                 "YOLOv4-tiny-on-radar (detector-head calibration + "
                 "natural-image priors caused the paper's YOLO result), "
                 "so its row does not reproduce the paper's weakest-"
                 "baseline placement -- see EXPERIMENTS.md"})
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
