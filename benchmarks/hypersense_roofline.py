"""§Perf cell 3: roofline of the paper's own workload on TPU v5e.

Frame scoring at the paper's FPGA operating point (128x128 frame,
fragment 96, stride 8, D=5000, fused classifier) under the v5e model
(197 TFLOP/s MXU bf16, ~4 TFLOP/s VPU fp32, 819 GB/s HBM, VMEM-resident
working sets). Three implementations:

  A. paper-faithful reuse (VPU prefix-sum; our sliding_scores kernel) —
     multiplies cut by ~w/stride, but every MAC runs on the VPU.
  B. naive MXU matmul with the full expanded base streamed from HBM —
     maximal FLOPs at MXU speed, but 184 MB of base traffic per frame
     batch tile.
  C. OURS (beyond paper): MXU matmul + in-VMEM permutation expansion
     (kernels/hdc_encode_perm.py) — the paper's Eq.1 structure repurposed
     to kill base HBM traffic instead of multiplies.

Modeled times = max(compute term, memory term) per frame; exact op/byte
counts, no wall-clock (CPU host). Also cross-checks A vs B flop counts
with XLA cost_analysis on the jnp paths.
"""

from __future__ import annotations

MXU = 197e12          # bf16 FLOP/s
VPU = 4e12            # fp32 FLOP/s (VPU, ~MXU/50)
HBM = 819e9           # B/s
VMEM = 64e6           # conservative usable VMEM bytes

FRAME = 128
FRAG = 96
STRIDE = 8
DIM = 5000
BATCH = 16            # frames per dispatch (amortizes base streaming)


def _windows(n, w, s):
    return (n - w) // s + 1


def run() -> list[dict]:
    m = _windows(FRAME, FRAG, STRIDE) ** 2            # fragments/frame
    hw = FRAG * FRAG
    rows = []

    # --- A: paper-faithful reuse (VPU) ---
    vpu_macs = FRAME * FRAG * FRAME * DIM             # rolled products
    vpu_adds = vpu_macs                               # prefix sums
    t_comp_a = (2 * vpu_macs + vpu_adds) / VPU
    bytes_a = (FRAME * FRAME * 4                      # frame
               + FRAG * (DIM + FRAME) * 4             # slabs (resident-able)
               + 3 * m * DIM * 4 / BATCH              # rotated tiles, amort.
               + m * 3 * 4)                           # outputs
    t_mem_a = bytes_a / HBM
    rows.append({"name": "hypersense_roofline/A_reuse_vpu",
                 "t_compute_us": round(t_comp_a * 1e6, 1),
                 "t_memory_us": round(t_mem_a * 1e6, 1),
                 "t_frame_us": round(max(t_comp_a, t_mem_a) * 1e6, 1),
                 "bound": "compute" if t_comp_a > t_mem_a else "memory"})

    # --- B: naive MXU with streamed base ---
    mxu_flops = 2 * m * hw * DIM
    t_comp_b = mxu_flops / MXU
    base_bytes = hw * DIM * 4
    bytes_b = base_bytes / BATCH + m * hw * 4 + m * DIM * 2
    t_mem_b = bytes_b / HBM
    rows.append({"name": "hypersense_roofline/B_naive_mxu_streamed",
                 "t_compute_us": round(t_comp_b * 1e6, 1),
                 "t_memory_us": round(t_mem_b * 1e6, 1),
                 "t_frame_us": round(max(t_comp_b, t_mem_b) * 1e6, 1),
                 "base_mb_per_batch": round(base_bytes / 1e6, 1),
                 "bound": "compute" if t_comp_b > t_mem_b else "memory"})

    # --- C: ours — MXU + in-VMEM permutation expansion ---
    b0_bytes = FRAG * (DIM + FRAG) * 4                # B0P resident
    assert b0_bytes < VMEM
    bytes_c = b0_bytes / BATCH + m * hw * 4 + m * DIM * 2
    # tile-build copies are VMEM-local; add 10% VPU overhead for them
    t_comp_c = mxu_flops / MXU * 1.1
    t_mem_c = bytes_c / HBM
    rows.append({"name": "hypersense_roofline/C_mxu_vmem_perm (ours)",
                 "t_compute_us": round(t_comp_c * 1e6, 1),
                 "t_memory_us": round(t_mem_c * 1e6, 1),
                 "t_frame_us": round(max(t_comp_c, t_mem_c) * 1e6, 1),
                 "b0_resident_mb": round(b0_bytes / 1e6, 2),
                 "bound": "compute" if t_comp_c > t_mem_c else "memory"})

    t_a = max(t_comp_a, t_mem_a)
    t_b = max(t_comp_b, t_mem_b)
    t_c = max(t_comp_c, t_mem_c)
    rows.append({
        "name": "hypersense_roofline/summary",
        "speedup_C_vs_A": round(t_a / t_c, 1),
        "speedup_C_vs_B": round(t_b / t_c, 1),
        "fps_C": int(1 / t_c),
        "paper_fpga_fps": 303,
        "note": "TPU MXU favors recompute-over-reuse; Eq.1 permutation "
                "structure repurposed to cut base HBM traffic 96x",
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
