"""Fleet scoring throughput: batched super-chunks vs a loop of runners.

The tentpole measurement for the multi-sensor runtime: total frames/sec of
S concurrent sensor streams under two execution strategies:

* ``looped-runners`` — a Python loop over S independent ``StreamRunner``
  instances, i.e. S jitted steps (S kernel launches on the ``pallas``
  backend) per chunk interval — the pre-fleet way to serve S sensors;
* ``fleet-batched``  — one ``FleetRunner`` consuming ``(S, C, H, W)``
  super-chunks: the S*C axis is flattened into a single kernel grid, ONE
  launch per super-chunk, one shared ScoreTiles precompute, and one
  vmapped ``gate_scan`` carrying all S hold states.

Both paths produce identical per-stream results (tests/test_fleet.py);
this benchmark measures only the dispatch/batching win. On CPU the pallas
paths run in interpret mode, so absolute numbers are small; the *ratio*
fleet/looped is the claim being checked (``--check`` enforces it at
S >= 4). On TPU the same code compiles and the gap widens.

Run:  PYTHONPATH=src python benchmarks/fleet_throughput.py [--sensors 4]
"""

from __future__ import annotations

import argparse
import os
import sys
import time

if "--mesh" in sys.argv:
    # the mesh sweep needs the forced-8-device host platform, and the
    # flag only takes effect before jax initializes — self-serve it so
    # `python benchmarks/fleet_throughput.py --mesh` works standalone
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8").strip()

import jax

from repro.core import hypersense
from repro.core.encoding import make_perm_base_rows
from repro.core.sensor_control import ControllerConfig
from repro.sensing.fleet import FleetRunner
from repro.sensing.stream import StreamRunner

# CPU-tractable scale (interpret mode executes grid steps in Python).
SENSORS = 4
FRAMES = 16          # per stream, per timed pass
CHUNK = 4            # small chunks -> more launches -> the amortization
                     # (the thing being measured) dominates the pass
FRAME = 32
FRAG = 8
STRIDE = 8           # small (my, n_dt) grid keeps per-launch work low, so
DIM = 256            # the S-fold launch fan-in is what gets measured
BLOCK_D = 256
REPS = 3


def _make_model(dim: int, frag: int, stride: int):
    B0, b = make_perm_base_rows(jax.random.PRNGKey(0), frag, dim)
    C = jax.random.normal(jax.random.PRNGKey(1), (2, dim))
    return hypersense.HyperSenseModel(C, B0, b, frag, frag, stride,
                                      t_score=0.0, t_detection=2)


def _time(fn, reps: int = REPS) -> float:
    """Best-of-N wall time: min suppresses scheduler noise on shared CPUs."""
    fn()  # warmup: jit compile + tiles precompute
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(sensors: int = SENSORS, n_frames: int = FRAMES, chunk: int = CHUNK,
        frame: int = FRAME, frag: int = FRAG, stride: int = STRIDE,
        dim: int = DIM, backend: str = "pallas", reps: int = REPS):
    model = _make_model(dim, frag, stride)
    config = ControllerConfig(hold_frames=3)
    frames = jax.random.uniform(jax.random.PRNGKey(2),
                                (sensors, n_frames, frame, frame))
    total = sensors * n_frames

    runners = [StreamRunner(model, config, chunk_size=chunk,
                            backend=backend, block_d=BLOCK_D)
               for _ in range(sensors)]
    fleet = FleetRunner(model, config, chunk_size=chunk, backend=backend,
                        block_d=BLOCK_D)

    def looped():
        for s, r in enumerate(runners):
            r.process(frames[s])

    def batched():
        fleet.process(frames)

    rows = []
    fps = {}
    for name, fn in [("looped-runners", looped),
                     ("fleet-batched", batched)]:
        dt = _time(fn, reps)
        fps[name] = total / dt
        rows.append({"name": f"fleet_throughput/{name}",
                     "frames_per_sec": f"{fps[name]:.1f}",
                     "ms_per_pass": f"{dt * 1e3:.1f}",
                     "sensors": sensors, "backend": backend})
    rows.append({"name": "fleet_throughput/fleet_vs_looped_speedup",
                 "value": f"{fps['fleet-batched'] / fps['looped-runners']:.2f}x",
                 "sensors": sensors, "backend": backend})
    return rows


# --- 2-D mesh sweep ---------------------------------------------------------
# Scale the fleet along BOTH logical axes on the forced-8-device host
# mesh: the sensor axis to S=1024 streams (8x1 mesh), and the hyperdim
# axis to D=16384 (1x8 mesh) — a config the VMEM byte model certifies
# cannot run single-slab on one device, but whose 8-way D-shard fits.
MESH_SWEEP_S = (8, 64, 256, 1024)
MESH_FRAME = 16       # small frames keep the S=1024 jnp-oracle pass in RAM
MESH_CHUNK = 2
MESH_FRAMES = 2       # per stream, per timed pass
MESH_BIG_DIM = 16384
MESH_BIG_BLOCK_D = 2048    # 8-way D-shard: one 2048-wide tile per device
MESH_BIG_FRAME = 64
MESH_BIG_S = 4


def run_mesh(reps: int = REPS, check: bool = False):
    import numpy as np

    from repro.distributed import sharding as shlib
    from repro.kernels.sliding_scores_int import int_datapath_bounds

    if jax.device_count() < 8:
        raise SystemExit(
            f"--mesh needs 8 devices, got {jax.device_count()} — the "
            "self-set XLA_FLAGS came too late (jax already initialized?)")

    rows = []
    model = _make_model(DIM, FRAG, STRIDE)
    config = ControllerConfig(hold_frames=3)

    def make_fleet():
        # jnp backend + int8: the tiled-oracle path every host serves the
        # int datapath from — and the fastest way to reach S=1024 on CPU
        return FleetRunner(model, config, chunk_size=MESH_CHUNK,
                           backend="jnp", block_d=BLOCK_D, adc_bits=8,
                           precision="int8")

    # sensor-axis sweep on the 8x1 mesh
    mesh_s = jax.make_mesh((8, 1), ("data", "model"))
    for S in MESH_SWEEP_S:
        frames = jax.random.uniform(jax.random.PRNGKey(2),
                                    (S, MESH_FRAMES, MESH_FRAME,
                                     MESH_FRAME))
        fleet = make_fleet()
        with shlib.use_mesh(mesh_s):
            dt = _time(lambda: fleet.process(frames), reps)
        rows.append({"name": f"fleet_throughput/mesh_8x1_S{S}",
                     "frames_per_sec": f"{S * MESH_FRAMES / dt:.1f}",
                     "ms_per_pass": f"{dt * 1e3:.1f}",
                     "sensors": S, "mesh": "8x1"})

    # parity gate: the sharded sweep config is BITWISE the unsharded one
    frames = jax.random.uniform(jax.random.PRNGKey(2),
                                (8, MESH_FRAMES, MESH_FRAME, MESH_FRAME))
    with shlib.use_mesh(mesh_s):
        got = make_fleet().process(frames)
    want = make_fleet().process(frames)
    bitwise = all(np.array_equal(np.asarray(a), np.asarray(b))
                  for a, b in zip(got, want))
    rows.append({"name": "fleet_throughput/mesh_parity_bitwise",
                 "value": str(bitwise).lower(), "mesh": "8x1"})
    if check and not bitwise:
        raise SystemExit("REGRESSION: 8x1-mesh fleet outputs differ from "
                         "the unsharded runner")

    # hyperdim-axis scale-out: D=16384 on the 1x8 mesh. One device would
    # need the whole hypervector resident per grid step (block_d = D) —
    # the byte model rejects that working set; the 8-way D-shard's
    # per-device 2048-wide tile fits with room to spare.
    single = int_datapath_bounds(8, MESH_BIG_FRAME, MESH_BIG_FRAME,
                                 FRAG, FRAG, stride=STRIDE,
                                 block_d=MESH_BIG_DIM)
    shard = int_datapath_bounds(8, MESH_BIG_FRAME, MESH_BIG_FRAME,
                                FRAG, FRAG, stride=STRIDE,
                                block_d=MESH_BIG_BLOCK_D)
    rows.append({"name": "fleet_throughput/mesh_1x8_D16384_vmem",
                 "single_device_bytes": single["vmem_bytes"],
                 "single_device_fits": str(single["fits"]).lower(),
                 "sharded_bytes": shard["vmem_bytes"],
                 "sharded_fits": str(shard["fits"]).lower(),
                 "limit_bytes": single["vmem_limit_bytes"]})
    if check and (single["fits"] or not shard["fits"]):
        raise SystemExit(
            "REGRESSION: VMEM byte model no longer certifies the D=16384 "
            f"scale-out (single fits={single['fits']}, "
            f"shard fits={shard['fits']})")

    big_model = _make_model(MESH_BIG_DIM, FRAG, STRIDE)
    big = FleetRunner(big_model, config, chunk_size=MESH_CHUNK,
                      backend="jnp", block_d=MESH_BIG_BLOCK_D, adc_bits=8,
                      precision="int8")
    frames = jax.random.uniform(jax.random.PRNGKey(3),
                                (MESH_BIG_S, MESH_FRAMES, MESH_BIG_FRAME,
                                 MESH_BIG_FRAME))
    with shlib.use_mesh(jax.make_mesh((1, 8), ("data", "model"))):
        dt = _time(lambda: big.process(frames), reps)
        assert big._step_key[2] == ("model",), \
            "D=16384 fleet did not shard the hyperdim axis"
    rows.append({"name": "fleet_throughput/mesh_1x8_D16384",
                 "frames_per_sec": f"{MESH_BIG_S * MESH_FRAMES / dt:.1f}",
                 "ms_per_pass": f"{dt * 1e3:.1f}",
                 "dim": MESH_BIG_DIM, "mesh": "1x8"})
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sensors", type=int, default=SENSORS,
                    help="number of concurrent sensor streams S")
    ap.add_argument("--frames", type=int, default=FRAMES,
                    help="frames per stream per timed pass")
    ap.add_argument("--chunk", type=int, default=CHUNK)
    ap.add_argument("--frame-size", type=int, default=FRAME)
    ap.add_argument("--frag", type=int, default=FRAG)
    ap.add_argument("--stride", type=int, default=STRIDE)
    ap.add_argument("--dim", type=int, default=DIM)
    ap.add_argument("--backend", default="pallas",
                    choices=["pallas", "jnp"])
    ap.add_argument("--reps", type=int, default=REPS)
    ap.add_argument("--mesh", action="store_true",
                    help="run the 2-D mesh sweep instead: sensor axis to "
                         "S=1024 (8x1) and hyperdim axis to D=16384 "
                         "(1x8) on a forced-8-device host mesh; --check "
                         "gates bitwise parity + the VMEM certification")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless fleet-batched >= "
                         "looped-runners frames/sec (the fleet batching "
                         "claim; use --sensors >= 4). With --mesh: gate "
                         "mesh parity and the D=16384 VMEM certification")
    try:
        from benchmarks import common   # -m benchmarks.run / repo root
    except ImportError:
        import common                   # standalone: script dir on path
    common.add_json_arg(ap)
    args = ap.parse_args()
    if args.mesh:
        rows = run_mesh(args.reps, check=args.check)
        if args.json:
            print("json ->", common.write_json(args.json,
                                               "fleet_throughput_mesh",
                                               rows))
        for row in rows:
            name = row.pop("name")
            print(name + "," + ",".join(f"{k}={v}"
                                        for k, v in row.items()))
        return
    rows = run(args.sensors, args.frames, args.chunk, args.frame_size,
               args.frag, args.stride, args.dim, args.backend, args.reps)
    if args.json:
        print("json ->", common.write_json(args.json, "fleet_throughput",
                                           rows))
    fps = {}
    for row in rows:
        name = row.pop("name")
        if "frames_per_sec" in row:
            fps[name.split("/")[-1]] = float(row["frames_per_sec"])
        print(name + "," + ",".join(f"{k}={v}" for k, v in row.items()))
    if args.check and fps["fleet-batched"] < fps["looped-runners"]:
        raise SystemExit(
            f"REGRESSION: fleet-batched {fps['fleet-batched']:.1f} fps < "
            f"looped-runners {fps['looped-runners']:.1f} fps at "
            f"S={args.sensors}")


if __name__ == "__main__":
    main()
