"""Fleet scoring throughput: batched super-chunks vs a loop of runners.

The tentpole measurement for the multi-sensor runtime: total frames/sec of
S concurrent sensor streams under two execution strategies:

* ``looped-runners`` — a Python loop over S independent ``StreamRunner``
  instances, i.e. S jitted steps (S kernel launches on the ``pallas``
  backend) per chunk interval — the pre-fleet way to serve S sensors;
* ``fleet-batched``  — one ``FleetRunner`` consuming ``(S, C, H, W)``
  super-chunks: the S*C axis is flattened into a single kernel grid, ONE
  launch per super-chunk, one shared ScoreTiles precompute, and one
  vmapped ``gate_scan`` carrying all S hold states.

Both paths produce identical per-stream results (tests/test_fleet.py);
this benchmark measures only the dispatch/batching win. On CPU the pallas
paths run in interpret mode, so absolute numbers are small; the *ratio*
fleet/looped is the claim being checked (``--check`` enforces it at
S >= 4). On TPU the same code compiles and the gap widens.

Run:  PYTHONPATH=src python benchmarks/fleet_throughput.py [--sensors 4]
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.core import hypersense
from repro.core.encoding import make_perm_base_rows
from repro.core.sensor_control import ControllerConfig
from repro.sensing.fleet import FleetRunner
from repro.sensing.stream import StreamRunner

# CPU-tractable scale (interpret mode executes grid steps in Python).
SENSORS = 4
FRAMES = 16          # per stream, per timed pass
CHUNK = 4            # small chunks -> more launches -> the amortization
                     # (the thing being measured) dominates the pass
FRAME = 32
FRAG = 8
STRIDE = 8           # small (my, n_dt) grid keeps per-launch work low, so
DIM = 256            # the S-fold launch fan-in is what gets measured
BLOCK_D = 256
REPS = 3


def _make_model(dim: int, frag: int, stride: int):
    B0, b = make_perm_base_rows(jax.random.PRNGKey(0), frag, dim)
    C = jax.random.normal(jax.random.PRNGKey(1), (2, dim))
    return hypersense.HyperSenseModel(C, B0, b, frag, frag, stride,
                                      t_score=0.0, t_detection=2)


def _time(fn, reps: int = REPS) -> float:
    """Best-of-N wall time: min suppresses scheduler noise on shared CPUs."""
    fn()  # warmup: jit compile + tiles precompute
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(sensors: int = SENSORS, n_frames: int = FRAMES, chunk: int = CHUNK,
        frame: int = FRAME, frag: int = FRAG, stride: int = STRIDE,
        dim: int = DIM, backend: str = "pallas", reps: int = REPS):
    model = _make_model(dim, frag, stride)
    config = ControllerConfig(hold_frames=3)
    frames = jax.random.uniform(jax.random.PRNGKey(2),
                                (sensors, n_frames, frame, frame))
    total = sensors * n_frames

    runners = [StreamRunner(model, config, chunk_size=chunk,
                            backend=backend, block_d=BLOCK_D)
               for _ in range(sensors)]
    fleet = FleetRunner(model, config, chunk_size=chunk, backend=backend,
                        block_d=BLOCK_D)

    def looped():
        for s, r in enumerate(runners):
            r.process(frames[s])

    def batched():
        fleet.process(frames)

    rows = []
    fps = {}
    for name, fn in [("looped-runners", looped),
                     ("fleet-batched", batched)]:
        dt = _time(fn, reps)
        fps[name] = total / dt
        rows.append({"name": f"fleet_throughput/{name}",
                     "frames_per_sec": f"{fps[name]:.1f}",
                     "ms_per_pass": f"{dt * 1e3:.1f}",
                     "sensors": sensors, "backend": backend})
    rows.append({"name": "fleet_throughput/fleet_vs_looped_speedup",
                 "value": f"{fps['fleet-batched'] / fps['looped-runners']:.2f}x",
                 "sensors": sensors, "backend": backend})
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sensors", type=int, default=SENSORS,
                    help="number of concurrent sensor streams S")
    ap.add_argument("--frames", type=int, default=FRAMES,
                    help="frames per stream per timed pass")
    ap.add_argument("--chunk", type=int, default=CHUNK)
    ap.add_argument("--frame-size", type=int, default=FRAME)
    ap.add_argument("--frag", type=int, default=FRAG)
    ap.add_argument("--stride", type=int, default=STRIDE)
    ap.add_argument("--dim", type=int, default=DIM)
    ap.add_argument("--backend", default="pallas",
                    choices=["pallas", "jnp"])
    ap.add_argument("--reps", type=int, default=REPS)
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless fleet-batched >= "
                         "looped-runners frames/sec (the fleet batching "
                         "claim; use --sensors >= 4)")
    args = ap.parse_args()
    rows = run(args.sensors, args.frames, args.chunk, args.frame_size,
               args.frag, args.stride, args.dim, args.backend, args.reps)
    fps = {}
    for row in rows:
        name = row.pop("name")
        if "frames_per_sec" in row:
            fps[name.split("/")[-1]] = float(row["frames_per_sec"])
        print(name + "," + ",".join(f"{k}={v}" for k, v in row.items()))
    if args.check and fps["fleet-batched"] < fps["looped-runners"]:
        raise SystemExit(
            f"REGRESSION: fleet-batched {fps['fleet-batched']:.1f} fps < "
            f"looped-runners {fps['looped-runners']:.1f} fps at "
            f"S={args.sensors}")


if __name__ == "__main__":
    main()
