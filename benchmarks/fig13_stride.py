"""Paper Fig. 13: stride size vs performance vs computation.

Claims reproduced:
  * larger stride -> larger skipped area -> (trend) lower F1/AUC;
  * computation (#fragments) falls quadratically with stride, so the
    operating point is the largest stride matching stride-1 performance.

Efficiency: stride-s windows are a sub-grid of the stride-2 windows, so
every stride row derives EXACTLY from one cached stride-2 score-map pass
(the reuse encoder's cost is stride-independent, so this is a 4x saving).
"""

from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import encoding, hypersense, metrics

SIZE = 16
DIM = 8192
BASE_STRIDE = 2
N_FRAMES = 48


def base_maps():
    """(N, my, mx) stride-2 fragment score maps (cached)."""
    def build():
        import jax
        import jax.numpy as jnp
        model, _, _, _ = common.hdc_model(SIZE, DIM)
        _, _, fte, _, lte = common.dataset()
        B0 = model.B.reshape(SIZE, SIZE, DIM)[:, 0, :]
        hs = hypersense.HyperSenseModel(
            class_hvs=model.class_hvs, B0=B0, b=model.b, h=SIZE, w=SIZE,
            stride=BASE_STRIDE, t_score=0.0, t_detection=0)
        score = jax.jit(lambda f: hypersense.score_frame(hs, f))
        maps = np.stack([np.asarray(score(jnp.asarray(f)))
                         for f in fte[:N_FRAMES]])
        return maps, lte[:N_FRAMES]

    return common.cached(f"fig13_maps_{N_FRAMES}", build)


def run() -> list[dict]:
    maps, labels = base_maps()
    rows = []
    frame = common.FRAME
    for stride in [2, 4, 8, 10, 16]:
        step = stride // BASE_STRIDE
        sub = maps[:, ::step, ::step]
        m = encoding.num_windows(frame, SIZE, stride)
        sub = sub[:, :m, :m]
        skipped_frac = 1.0 - ((m - 1) * stride + SIZE) ** 2 / frame ** 2
        scores = sub.reshape(sub.shape[0], -1).max(axis=1)  # t_det=0 score
        fpr, tpr, thr = metrics.roc_curve(scores, labels)
        f1s = [metrics.f1_score(scores > t, labels)
               for t in np.quantile(scores, np.linspace(0.05, 0.95, 19))]
        rows.append({
            "name": f"fig13/stride_{stride}",
            "fragments_per_frame": int(m * m),
            "skipped_area_frac": round(float(skipped_frac), 4),
            "auc": round(metrics.auc(fpr, tpr), 4),
            "best_f1": round(float(np.max(f1s)), 4),
        })
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
